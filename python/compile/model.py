"""L2: the JAX mini model zoo (forward passes), built on the L1 Pallas
kernels in `kernels/`.

Mini counterparts of the paper's workload models (32×32×3 images / 16-
token sequences instead of 224×224 ImageNet — the scheduler exercises the
same code paths at tractable CPU cost; see DESIGN.md §1):

  convnet1/2/3   — §6.2's LeNet-style ConvNets (varying filter widths)
  alexnet_mini   — plain conv stack + FC head
  mobilenet_mini — depthwise-separable convolutions
  vgg_mini       — deeper conv stack (the compute-heavy tenant)
  resnet_mini    — residual blocks
  bert_mini      — 2-block Transformer encoder (fused Pallas attention)

Weights are *runtime inputs* (not baked constants): the HLO stays small,
and the Rust runtime owns model loading — regenerating bit-identical
weights via the same splitmix64 scheme (`det_weights`), which is what
makes the cross-language self-check in `aot.py` possible.
"""

import numpy as np

import jax.numpy as jnp

from .kernels import attention as attn_k
from .kernels import conv as conv_k
from .kernels import matmul as mm_k
from .kernels import norm as norm_k

# ---------------------------------------------------------------------------
# Deterministic cross-language weight init (splitmix64).
# ---------------------------------------------------------------------------

_SM64_GAMMA = np.uint64(0x9E3779B97F4A7C15)
_SM64_M1 = np.uint64(0xBF58476D1CE4E5B9)
_SM64_M2 = np.uint64(0x94D049BB133111EB)


def _splitmix64(z):
    z = (z + _SM64_GAMMA).astype(np.uint64)
    z = ((z ^ (z >> np.uint64(30))) * _SM64_M1).astype(np.uint64)
    z = ((z ^ (z >> np.uint64(27))) * _SM64_M2).astype(np.uint64)
    return z ^ (z >> np.uint64(31))


def det_weights(shape, seed, scale):
    """Deterministic uniform weights in [-scale, scale].

    Element i of parameter `seed` is `splitmix64(seed*2^32 + i)` mapped
    to [0,1) by its top 53 bits. The Rust runtime implements the exact
    same function (`runtime::det_weights`), so both sides materialize
    bit-identical f32 weights.
    """
    n = int(np.prod(shape))
    base = np.uint64(seed) << np.uint64(32)
    idx = base + np.arange(n, dtype=np.uint64)
    z = _splitmix64(idx)
    u = (z >> np.uint64(11)).astype(np.float64) * (1.0 / (1 << 53))
    vals = ((2.0 * u - 1.0) * scale).astype(np.float32)
    return vals.reshape(shape)


# ---------------------------------------------------------------------------
# Parameter-spec machinery.
# ---------------------------------------------------------------------------


class Spec:
    """Ordered parameter specification for one model."""

    def __init__(self):
        self.params = []  # (name, shape, scale)

    def add(self, name, shape, fan_in):
        scale = float(1.0 / np.sqrt(max(fan_in, 1)))
        self.params.append((name, tuple(int(s) for s in shape), scale))
        return len(self.params) - 1

    def materialize(self):
        """Deterministic weights; parameter k uses seed k."""
        return [det_weights(shape, k, scale) for k, (_, shape, scale) in enumerate(self.params)]


# ---------------------------------------------------------------------------
# Model definitions. Each `build_*` returns (spec, apply_fn) where
# apply_fn(x, *params) is jit/AOT-friendly.
# ---------------------------------------------------------------------------


def _convnet(widths, fc_dim):
    """§6.2 LeNet-style ConvNet: 3 convs (2 pooled), 2 FC layers."""
    c1, c2, c3 = widths
    spec = Spec()
    spec.add("conv1_w", (5, 5, 3, c1), 5 * 5 * 3)
    spec.add("conv1_b", (c1,), 1)
    spec.add("conv2_w", (5, 5, c1, c2), 5 * 5 * c1)
    spec.add("conv2_b", (c2,), 1)
    spec.add("conv3_w", (3, 3, c2, c3), 3 * 3 * c2)
    spec.add("conv3_b", (c3,), 1)
    flat = 5 * 5 * c3
    spec.add("fc1_w", (flat, fc_dim), flat)
    spec.add("fc1_b", (fc_dim,), 1)
    spec.add("fc2_w", (fc_dim, 10), fc_dim)
    spec.add("fc2_b", (10,), 1)

    def apply(x, *p):
        # x: [B, 32, 32, 3]
        y = conv_k.conv2d(x, p[0], p[1], padding=2, activation="relu")  # 32
        y = conv_k.avg_pool2(y)  # 16
        y = conv_k.conv2d(y, p[2], p[3], padding=2, activation="relu")  # 16
        y = conv_k.avg_pool2(y)  # 8
        y = conv_k.conv2d(y, p[4], p[5], padding=0, activation="relu")  # 6 -> wait 8-3+1=6
        y = y[:, :5, :5, :]  # crop to 5×5 (fixed flat dim)
        y = y.reshape(y.shape[0], -1)
        y = mm_k.linear(y, p[6], p[7], activation="relu")
        return mm_k.linear(y, p[8], p[9])

    return spec, apply


def build_convnet1():
    return _convnet((8, 16, 32), 64)


def build_convnet2():
    return _convnet((16, 24, 48), 64)


def build_convnet3():
    return _convnet((16, 32, 64), 128)


def build_alexnet_mini():
    spec = Spec()
    spec.add("c1_w", (3, 3, 3, 16), 27)
    spec.add("c1_b", (16,), 1)
    spec.add("c2_w", (3, 3, 16, 32), 144)
    spec.add("c2_b", (32,), 1)
    spec.add("c3_w", (3, 3, 32, 64), 288)
    spec.add("c3_b", (64,), 1)
    spec.add("fc1_w", (8 * 8 * 64, 128), 8 * 8 * 64)
    spec.add("fc1_b", (128,), 1)
    spec.add("fc2_w", (128, 10), 128)
    spec.add("fc2_b", (10,), 1)

    def apply(x, *p):
        y = conv_k.conv2d(x, p[0], p[1], padding=1, activation="relu")  # 32
        y = conv_k.max_pool2(y)  # 16
        y = conv_k.conv2d(y, p[2], p[3], padding=1, activation="relu")  # 16
        y = conv_k.max_pool2(y)  # 8
        y = conv_k.conv2d(y, p[4], p[5], padding=1, activation="relu")  # 8
        y = y.reshape(y.shape[0], -1)
        y = mm_k.linear(y, p[6], p[7], activation="relu")
        return mm_k.linear(y, p[8], p[9])

    return spec, apply


def build_mobilenet_mini():
    spec = Spec()
    spec.add("c1_w", (3, 3, 3, 16), 27)
    spec.add("c1_b", (16,), 1)
    spec.add("dw1_w", (3, 3, 16), 9)
    spec.add("pw1_w", (1, 1, 16, 32), 16)
    spec.add("pw1_b", (32,), 1)
    spec.add("dw2_w", (3, 3, 32), 9)
    spec.add("pw2_w", (1, 1, 32, 64), 32)
    spec.add("pw2_b", (64,), 1)
    spec.add("fc_w", (64, 10), 64)
    spec.add("fc_b", (10,), 1)

    def apply(x, *p):
        y = conv_k.conv2d(x, p[0], p[1], padding=1, activation="relu")  # 32
        y = conv_k.max_pool2(y)  # 16
        y = conv_k.depthwise3x3(y, p[2])
        y = conv_k.conv2d(y, p[3], p[4], activation="relu")  # pointwise
        y = conv_k.max_pool2(y)  # 8
        y = conv_k.depthwise3x3(y, p[5])
        y = conv_k.conv2d(y, p[6], p[7], activation="relu")
        y = y.mean(axis=(1, 2))  # global average pool -> [B, 64]
        return mm_k.linear(y, p[8], p[9])

    return spec, apply


def build_vgg_mini():
    spec = Spec()
    dims = [(3, 32), (32, 32), (32, 64), (64, 64)]
    for i, (cin, cout) in enumerate(dims):
        spec.add(f"c{i}_w", (3, 3, cin, cout), 9 * cin)
        spec.add(f"c{i}_b", (cout,), 1)
    spec.add("fc1_w", (8 * 8 * 64, 128), 8 * 8 * 64)
    spec.add("fc1_b", (128,), 1)
    spec.add("fc2_w", (128, 10), 128)
    spec.add("fc2_b", (10,), 1)

    def apply(x, *p):
        y = conv_k.conv2d(x, p[0], p[1], padding=1, activation="relu")  # 32
        y = conv_k.conv2d(y, p[2], p[3], padding=1, activation="relu")
        y = conv_k.max_pool2(y)  # 16
        y = conv_k.conv2d(y, p[4], p[5], padding=1, activation="relu")
        y = conv_k.conv2d(y, p[6], p[7], padding=1, activation="relu")
        y = conv_k.max_pool2(y)  # 8
        y = y.reshape(y.shape[0], -1)
        y = mm_k.linear(y, p[8], p[9], activation="relu")
        return mm_k.linear(y, p[10], p[11])

    return spec, apply


def build_resnet_mini():
    spec = Spec()
    spec.add("c0_w", (3, 3, 3, 32), 27)
    spec.add("c0_b", (32,), 1)
    for blk in range(2):
        spec.add(f"b{blk}_c1_w", (3, 3, 32, 32), 288)
        spec.add(f"b{blk}_c1_b", (32,), 1)
        spec.add(f"b{blk}_c2_w", (3, 3, 32, 32), 288)
        spec.add(f"b{blk}_c2_b", (32,), 1)
    spec.add("fc_w", (32, 10), 32)
    spec.add("fc_b", (10,), 1)

    def apply(x, *p):
        y = conv_k.conv2d(x, p[0], p[1], padding=1, activation="relu")  # 32
        y = conv_k.max_pool2(y)  # 16
        i = 2
        for _ in range(2):
            z = conv_k.conv2d(y, p[i], p[i + 1], padding=1, activation="relu")
            z = conv_k.conv2d(z, p[i + 2], p[i + 3], padding=1)
            y = jnp.maximum(y + z, 0.0)  # residual + relu
            i += 4
        y = y.mean(axis=(1, 2))  # [B, 32]
        return mm_k.linear(y, p[i], p[i + 1])

    return spec, apply


def build_bert_mini(seq_len=16, d_model=64, n_blocks=2, d_ff=128):
    spec = Spec()
    for blk in range(n_blocks):
        for nm in ("q", "k", "v", "o"):
            spec.add(f"b{blk}_{nm}_w", (d_model, d_model), d_model)
        spec.add(f"b{blk}_ln1_g", (d_model,), 1)
        spec.add(f"b{blk}_ln1_b", (d_model,), 1)
        spec.add(f"b{blk}_ff1_w", (d_model, d_ff), d_model)
        spec.add(f"b{blk}_ff1_b", (d_ff,), 1)
        spec.add(f"b{blk}_ff2_w", (d_ff, d_model), d_ff)
        spec.add(f"b{blk}_ff2_b", (d_model,), 1)
        spec.add(f"b{blk}_ln2_g", (d_model,), 1)
        spec.add(f"b{blk}_ln2_b", (d_model,), 1)
    spec.add("head_w", (d_model, 10), d_model)
    spec.add("head_b", (10,), 1)

    def apply(x, *p):
        # x: [B, T, D] pre-embedded tokens.
        b, t, d = x.shape
        y = x
        i = 0
        for _ in range(n_blocks):
            q = mm_k.matmul(y.reshape(b * t, d), p[i]).reshape(b, t, d)
            k = mm_k.matmul(y.reshape(b * t, d), p[i + 1]).reshape(b, t, d)
            v = mm_k.matmul(y.reshape(b * t, d), p[i + 2]).reshape(b, t, d)
            a = attn_k.attention(q, k, v)
            a = mm_k.matmul(a.reshape(b * t, d), p[i + 3]).reshape(b, t, d)
            y = y + a
            y2 = norm_k.layernorm(y.reshape(b * t, d), p[i + 4], p[i + 5])
            h = mm_k.linear(y2, p[i + 6], p[i + 7], activation="gelu")
            h = mm_k.linear(h, p[i + 8], p[i + 9])
            y = y + h.reshape(b, t, d)
            y = norm_k.layernorm(y.reshape(b * t, d), p[i + 10], p[i + 11]).reshape(b, t, d)
            i += 12
        pooled = y.mean(axis=1)  # [B, D]
        return mm_k.linear(pooled, p[i], p[i + 1])

    return spec, apply


# ---------------------------------------------------------------------------
# Registry.
# ---------------------------------------------------------------------------

MODELS = {
    "convnet1": build_convnet1,
    "convnet2": build_convnet2,
    "convnet3": build_convnet3,
    "alexnet_mini": build_alexnet_mini,
    "mobilenet_mini": build_mobilenet_mini,
    "vgg_mini": build_vgg_mini,
    "resnet_mini": build_resnet_mini,
    "bert_mini": build_bert_mini,
}


def input_shape(name, batch):
    """Input tensor shape for a model at a batch size."""
    if name == "bert_mini":
        return (batch, 16, 64)
    return (batch, 32, 32, 3)


def build(name):
    """Return (spec, apply_fn) for a registered model."""
    return MODELS[name]()


def deterministic_input(shape):
    """The fixed self-check input: normalized iota (same on both sides)."""
    n = int(np.prod(shape))
    return (np.arange(n, dtype=np.float32) / n - 0.5).reshape(shape)
