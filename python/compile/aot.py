"""AOT lowering: JAX models → HLO *text* artifacts + manifest.

Interchange is HLO text, NOT a serialized HloModuleProto: jax ≥ 0.5
emits 64-bit instruction ids that the xla crate's XLA (xla_extension
0.5.1) rejects; the text parser reassigns ids (see
/opt/xla-example/README.md).

Each artifact is one (model, batch) pair whose entry computation takes
`(input, *weights)` and returns a 1-tuple. `manifest.json` records, per
artifact: shapes, parameter specs (name/shape/seed/scale for the
splitmix64 weights the Rust runtime regenerates), and a self-check
(expected logits for the deterministic iota input) proving the Rust
PJRT path computes exactly what JAX computed at build time.

Usage: python -m compile.aot --out ../artifacts [--models a,b] [--batches 1,16]
"""

import argparse
import hashlib
import json
import os

import numpy as np

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M

jax.config.update("jax_platform_name", "cpu")


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def lower_model(name: str, batch: int):
    """Lower `name` at `batch`; returns (hlo_text, manifest_entry)."""
    spec, apply = M.build(name)
    in_shape = M.input_shape(name, batch)
    params = spec.materialize()

    def fn(x, *ps):
        return (apply(x, *ps),)

    example = [jax.ShapeDtypeStruct(in_shape, jnp.float32)] + [
        jax.ShapeDtypeStruct(p.shape, jnp.float32) for p in params
    ]
    lowered = jax.jit(fn).lower(*example)
    hlo = to_hlo_text(lowered)

    # Self-check: run the real computation on the deterministic input.
    x = M.deterministic_input(in_shape)
    out = np.asarray(jax.jit(fn)(x, *params)[0])
    entry = {
        "model": name,
        "batch": batch,
        "input_shape": list(in_shape),
        "output_shape": list(out.shape),
        "params": [
            {"name": nm, "shape": list(shape), "seed": k, "scale": scale}
            for k, (nm, shape, scale) in enumerate(spec.params)
        ],
        "selfcheck": {
            "input": "iota",
            "output_sum": float(out.sum()),
            "output_first8": [float(v) for v in out.ravel()[:8]],
        },
        "hlo_sha256": hashlib.sha256(hlo.encode()).hexdigest(),
    }
    return hlo, entry


DEFAULT_MODELS = list(M.MODELS.keys())
DEFAULT_BATCHES = [1, 4, 16]


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--models", default=",".join(DEFAULT_MODELS))
    ap.add_argument("--batches", default=",".join(str(b) for b in DEFAULT_BATCHES))
    args = ap.parse_args()

    os.makedirs(args.out, exist_ok=True)
    models = [m for m in args.models.split(",") if m]
    batches = [int(b) for b in args.batches.split(",") if b]

    manifest = {"format": 1, "artifacts": []}
    for name in models:
        for batch in batches:
            hlo, entry = lower_model(name, batch)
            fname = f"{name}_b{batch}.hlo.txt"
            path = os.path.join(args.out, fname)
            with open(path, "w") as f:
                f.write(hlo)
            entry["file"] = fname
            manifest["artifacts"].append(entry)
            print(f"  {fname}: {len(hlo)} chars, out_sum={entry['selfcheck']['output_sum']:.4f}")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2, sort_keys=True)
    print(f"wrote {len(manifest['artifacts'])} artifacts + manifest.json to {args.out}")


if __name__ == "__main__":
    main()
