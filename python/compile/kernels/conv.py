"""L1 Pallas kernels for convolutions.

Two paths, mirroring how CNNs split in the paper's kernel analysis
(Fig. 5: pointwise/expand convs are matmul-shaped and compute-bound;
depthwise convs are memory-bound with low arithmetic intensity):

- `conv2d`: standard convolution as im2col (pure indexing, done in XLA)
  feeding the tiled Pallas `matmul` — the compute-bound hot path hits
  the MXU-shaped kernel.
- `depthwise3x3`: a dedicated Pallas kernel, grid over channels, each
  step holding one padded channel plane in VMEM (scratchpad-resident
  stencil, the TPU analogue of the paper's low-GPU%-demand kernels).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from . import matmul as mm


def _im2col(x, kh, kw, stride):
    """[B,H,W,C] -> [B*OH*OW, KH*KW*C] patches (SAME=VALID padding done
    by caller)."""
    b, h, w, c = x.shape
    oh = (h - kh) // stride + 1
    ow = (w - kw) // stride + 1
    patches = jax.lax.conv_general_dilated_patches(
        x.transpose(0, 3, 1, 2),  # NCHW
        filter_shape=(kh, kw),
        window_strides=(stride, stride),
        padding="VALID",
    )  # [B, C*KH*KW, OH, OW]
    patches = patches.transpose(0, 2, 3, 1).reshape(b * oh * ow, c * kh * kw)
    return patches, oh, ow


def conv2d(x, w, b=None, stride=1, padding=0, activation=None):
    """2D convolution via im2col + Pallas matmul.

    x: [B, H, W, Cin], w: [KH, KW, Cin, Cout] -> [B, OH, OW, Cout]
    """
    if padding:
        x = jnp.pad(x, ((0, 0), (padding, padding), (padding, padding), (0, 0)))
    kh, kw, cin, cout = w.shape
    bsz = x.shape[0]
    patches, oh, ow = _im2col(x, kh, kw, stride)
    # conv_general_dilated_patches yields C-major patches: [C, KH, KW].
    wmat = w.transpose(2, 0, 1, 3).reshape(kh * kw * cin, cout)
    y = mm.matmul(patches, wmat)
    y = y.reshape(bsz, oh, ow, cout)
    if b is not None:
        y = y + b
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    return y


def _dw_kernel(x_ref, w_ref, o_ref):
    # Blocks carry the leading singleton channel dim: x_ref[0] is the
    # padded [B, H+2, W+2] plane of this grid step's channel.
    x = x_ref[0]
    w = w_ref[0]
    acc = jnp.zeros_like(x[:, 1:-1, 1:-1])
    h = x.shape[1] - 2
    wd = x.shape[2] - 2
    for di in range(3):
        for dj in range(3):
            acc = acc + x[:, di : di + h, dj : dj + wd] * w[di, dj]
    o_ref[0] = acc


@jax.jit
def depthwise3x3(x, w):
    """Depthwise 3×3 convolution (stride 1, SAME) as a Pallas kernel.

    x: [B, H, W, C], w: [3, 3, C] -> [B, H, W, C]
    Grid over channels: each grid step holds one padded channel plane in
    VMEM — B·(H+2)·(W+2)·4 bytes — and applies the 9-tap stencil.
    """
    b, h, wd, c = x.shape
    xp = jnp.pad(x, ((0, 0), (1, 1), (1, 1), (0, 0)))
    # Channel-major layout so the grid maps one channel per step.
    xc = xp.transpose(3, 0, 1, 2)  # [C, B, H+2, W+2]
    wc = w.transpose(2, 0, 1)  # [C, 3, 3]
    out = pl.pallas_call(
        _dw_kernel,
        grid=(c,),
        in_specs=[
            pl.BlockSpec((1, b, h + 2, wd + 2), lambda i: (i, 0, 0, 0)),
            pl.BlockSpec((1, 3, 3), lambda i: (i, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, b, h, wd), lambda i: (i, 0, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((c, b, h, wd), jnp.float32),
        interpret=True,
    )(xc, wc)
    return out.transpose(1, 2, 3, 0)


def avg_pool2(x):
    """2×2 average pooling, stride 2. x: [B, H, W, C]."""
    b, h, w, c = x.shape
    x = x[:, : h - h % 2, : w - w % 2, :]
    x = x.reshape(b, h // 2, 2, w // 2, 2, c)
    return x.mean(axis=(2, 4))


def max_pool2(x):
    """2×2 max pooling, stride 2."""
    b, h, w, c = x.shape
    x = x[:, : h - h % 2, : w - w % 2, :]
    x = x.reshape(b, h // 2, 2, w // 2, 2, c)
    return x.max(axis=(2, 4))


def dw_vmem_bytes(b: int, h: int, w: int) -> int:
    """VMEM per grid step of `depthwise3x3` (one padded channel, f32)."""
    return 4 * (b * (h + 2) * (w + 2) + 9 + b * h * w)
