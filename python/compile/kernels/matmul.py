"""L1 Pallas kernel: tiled matmul with optional fused bias + activation.

The serving hot-spot of every model in the zoo (conv via im2col, FC
layers, attention projections) funnels through this kernel.

TPU mapping (DESIGN.md §Hardware-Adaptation): the paper's insight is that
kernels have bounded inherent parallelism, so right-sizing the compute
slice wastes nothing. Here the BlockSpec grid expresses exactly that
inherent parallelism: the output is tiled (TM × TN) so each grid step
streams one A-row-panel and one B-column-panel HBM→VMEM and issues an
MXU-shaped contraction. Tiles are capped at 128 (the MXU systolic-array
edge); K is kept resident per step.

VMEM per grid step = TM·K + K·TN + TM·TN floats — reported by
`vmem_bytes()` and asserted < 16 MiB in tests (the per-core VMEM budget).

Kernels run `interpret=True`: real-TPU lowering emits Mosaic custom-calls
the CPU PJRT plugin cannot execute; interpret mode lowers to plain HLO,
which is what `aot.py` ships to the Rust runtime.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU systolic array edge (v4/v5): align tiles to this when possible.
MXU_EDGE = 128


def _tile(dim: int) -> int:
    """Largest divisor of `dim` that is ≤ MXU_EDGE (prefer exact MXU)."""
    if dim >= MXU_EDGE and dim % MXU_EDGE == 0:
        return MXU_EDGE
    for cand in (64, 32, 16, 8, 4, 2, 1):
        if dim % cand == 0 and cand <= dim:
            return cand
    return 1


def _matmul_kernel(x_ref, w_ref, o_ref, *, activation):
    acc = jnp.dot(x_ref[...], w_ref[...], preferred_element_type=jnp.float32)
    if activation == "relu":
        acc = jnp.maximum(acc, 0.0)
    elif activation == "gelu":
        acc = jax.nn.gelu(acc)
    o_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("activation",))
def matmul(x, w, activation=None):
    """`activation(x @ w)` as a tiled Pallas kernel.

    x: [M, K], w: [K, N] -> [M, N]   (float32)
    """
    m, k = x.shape
    k2, n = w.shape
    assert k == k2, f"contraction mismatch: {x.shape} @ {w.shape}"
    tm, tn = _tile(m), _tile(n)
    grid = (m // tm, n // tn)
    return pl.pallas_call(
        functools.partial(_matmul_kernel, activation=activation),
        grid=grid,
        in_specs=[
            pl.BlockSpec((tm, k), lambda i, j: (i, 0)),
            pl.BlockSpec((k, tn), lambda i, j: (0, j)),
        ],
        out_specs=pl.BlockSpec((tm, tn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(x, w)


def linear(x, w, b, activation=None):
    """Fused dense layer: activation(x @ w + b).

    Bias-add runs outside the kernel (XLA fuses it); the contraction —
    the FLOPs that matter — is the Pallas kernel.
    """
    y = matmul(x, w)
    y = y + b
    if activation == "relu":
        y = jnp.maximum(y, 0.0)
    elif activation == "gelu":
        y = jax.nn.gelu(y)
    return y


def vmem_bytes(m: int, k: int, n: int) -> int:
    """Estimated VMEM footprint (bytes) of one grid step (f32)."""
    tm, tn = _tile(m), _tile(n)
    return 4 * (tm * k + k * tn + tm * tn)


def mxu_utilization(m: int, k: int, n: int) -> float:
    """Fraction of MXU lanes a grid step's tiles occupy (structure-level
    estimate: tile_m/128 × tile_n/128, the quantity to maximize when
    choosing block shapes — see DESIGN.md §Perf)."""
    tm, tn = _tile(m), _tile(n)
    return min(tm / MXU_EDGE, 1.0) * min(tn / MXU_EDGE, 1.0)
