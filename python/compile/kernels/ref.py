"""Pure-jnp oracles for every Pallas kernel (the CORE correctness
signal): pytest sweeps shapes with hypothesis and asserts allclose
between kernel and oracle. No pallas imports here — these must stay
independent of the code under test."""

import jax
import jax.numpy as jnp


def _act(y, activation):
    if activation == "relu":
        return jnp.maximum(y, 0.0)
    if activation == "gelu":
        return jax.nn.gelu(y)
    return y


def matmul_ref(x, w, activation=None):
    return _act(jnp.dot(x, w), activation)


def linear_ref(x, w, b, activation=None):
    return _act(jnp.dot(x, w) + b, activation)


def conv2d_ref(x, w, b=None, stride=1, padding=0, activation=None):
    """x: [B,H,W,Cin], w: [KH,KW,Cin,Cout] (NHWC/HWIO)."""
    y = jax.lax.conv_general_dilated(
        x,
        w,
        window_strides=(stride, stride),
        padding=[(padding, padding), (padding, padding)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
    )
    if b is not None:
        y = y + b
    return _act(y, activation)


def depthwise3x3_ref(x, w):
    """x: [B,H,W,C], w: [3,3,C] — stride 1, SAME padding."""
    c = x.shape[-1]
    wk = w.reshape(3, 3, 1, c)  # HWIO with feature_group_count=C
    return jax.lax.conv_general_dilated(
        x,
        wk,
        window_strides=(1, 1),
        padding=[(1, 1), (1, 1)],
        dimension_numbers=("NHWC", "HWIO", "NHWC"),
        feature_group_count=c,
    )


def attention_ref(q, k, v):
    d = q.shape[-1]
    scores = jnp.einsum("btd,bsd->bts", q, k) / jnp.sqrt(jnp.float32(d))
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bts,bsd->btd", p, v)


def layernorm_ref(x, gamma, beta, eps=1e-5):
    mean = x.mean(axis=-1, keepdims=True)
    var = x.var(axis=-1, keepdims=True)
    return (x - mean) / jnp.sqrt(var + eps) * gamma + beta


def avg_pool2_ref(x):
    b, h, w, c = x.shape
    x = x[:, : h - h % 2, : w - w % 2, :]
    return x.reshape(b, h // 2, 2, w // 2, 2, c).mean(axis=(2, 4))
