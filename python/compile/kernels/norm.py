"""L1 Pallas kernel: layer normalization (rows of [N, D]).

Memory-bound (arithmetic intensity ≈ 2 FLOP/byte — the GNMT-LSTM side of
the paper's Table 2 split), so the kernel's job is purely to keep each
row resident in VMEM for the two reduction passes + scale/shift, one HBM
read and one write per element.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

EPS = 1e-5


def _ln_kernel(x_ref, g_ref, b_ref, o_ref):
    x = x_ref[...]
    mean = x.mean(axis=-1, keepdims=True)
    var = ((x - mean) ** 2).mean(axis=-1, keepdims=True)
    xhat = (x - mean) * jax.lax.rsqrt(var + EPS)
    o_ref[...] = xhat * g_ref[...] + b_ref[...]


@jax.jit
def layernorm(x, gamma, beta):
    """Row-wise layernorm. x: [N, D]; gamma, beta: [D]."""
    n, d = x.shape
    # Row-tile the grid; D stays resident.
    tn = 8 if n % 8 == 0 else 1
    return pl.pallas_call(
        _ln_kernel,
        grid=(n // tn,),
        in_specs=[
            pl.BlockSpec((tn, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tn, d), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, d), jnp.float32),
        interpret=True,
    )(x, gamma, beta)
