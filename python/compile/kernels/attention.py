"""L1 Pallas kernel: fused single-head scaled-dot-product attention.

Used by `bert_mini` (the paper's Transformer workload, §4.4.2). The whole
softmax(QKᵀ/√d)·V chain for one (batch, head) runs inside a single grid
step, keeping the T×T score matrix in VMEM instead of round-tripping it
through HBM — the flash-attention-style fusion, sized for the tiny
sequence lengths of the mini zoo (T ≤ 128 keeps T² scores ≤ 64 KiB).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _attn_kernel(q_ref, k_ref, v_ref, o_ref):
    q = q_ref[0]  # [T, D]
    k = k_ref[0]
    v = v_ref[0]
    d = q.shape[-1]
    scores = jnp.dot(q, k.T, preferred_element_type=jnp.float32)
    scores = scores / jnp.sqrt(jnp.float32(d))
    # Numerically stable softmax, fully in-register/VMEM.
    m = scores.max(axis=-1, keepdims=True)
    e = jnp.exp(scores - m)
    p = e / e.sum(axis=-1, keepdims=True)
    o_ref[0] = jnp.dot(p, v, preferred_element_type=jnp.float32)


@jax.jit
def attention(q, k, v):
    """Fused attention. q, k, v: [B, T, D] -> [B, T, D]."""
    b, t, d = q.shape
    return pl.pallas_call(
        _attn_kernel,
        grid=(b,),
        in_specs=[pl.BlockSpec((1, t, d), lambda i: (i, 0, 0))] * 3,
        out_specs=pl.BlockSpec((1, t, d), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, t, d), jnp.float32),
        interpret=True,
    )(q, k, v)


def vmem_bytes(t: int, d: int) -> int:
    """VMEM per grid step: Q,K,V,O panels + the T×T score matrix (f32)."""
    return 4 * (4 * t * d + t * t)
