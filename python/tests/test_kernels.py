"""L1 correctness: every Pallas kernel vs its pure-jnp oracle, with
hypothesis sweeping shapes — the CORE correctness signal of the compile
path (kernels run interpret=True, the exact lowering shipped to Rust)."""

import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

import jax.numpy as jnp

from compile.kernels import attention as attn_k
from compile.kernels import conv as conv_k
from compile.kernels import matmul as mm_k
from compile.kernels import norm as norm_k
from compile.kernels import ref

SETTINGS = dict(max_examples=20, deadline=None)


def rnd(rng, *shape):
    return rng.standard_normal(shape).astype(np.float32)


@settings(**SETTINGS)
@given(
    m=st.sampled_from([1, 2, 4, 8, 16, 64, 128, 256]),
    k=st.integers(1, 96),
    n=st.sampled_from([1, 2, 8, 10, 16, 32, 128]),
    act=st.sampled_from([None, "relu", "gelu"]),
    seed=st.integers(0, 2**31),
)
def test_matmul_matches_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w = rnd(rng, m, k), rnd(rng, k, n)
    got = mm_k.matmul(x, w, activation=act)
    want = ref.matmul_ref(x, w, activation=act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(
    m=st.sampled_from([2, 8, 32]),
    k=st.integers(1, 64),
    n=st.sampled_from([4, 10, 16]),
    act=st.sampled_from([None, "relu"]),
    seed=st.integers(0, 2**31),
)
def test_linear_matches_ref(m, k, n, act, seed):
    rng = np.random.default_rng(seed)
    x, w, b = rnd(rng, m, k), rnd(rng, k, n), rnd(rng, n)
    got = mm_k.linear(x, w, b, activation=act)
    want = ref.linear_ref(x, w, b, activation=act)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 4),
    hw=st.sampled_from([6, 8, 12, 16]),
    cin=st.sampled_from([1, 3, 8]),
    cout=st.sampled_from([4, 8, 16]),
    ksp=st.sampled_from([(1, 1, 0), (3, 1, 1), (3, 2, 1), (5, 1, 2)]),
    seed=st.integers(0, 2**31),
)
def test_conv2d_matches_ref(b, hw, cin, cout, ksp, seed):
    k, stride, pad = ksp
    rng = np.random.default_rng(seed)
    x = rnd(rng, b, hw, hw, cin)
    w = rnd(rng, k, k, cin, cout)
    bias = rnd(rng, cout)
    got = conv_k.conv2d(x, w, bias, stride=stride, padding=pad, activation="relu")
    want = ref.conv2d_ref(x, w, bias, stride=stride, padding=pad, activation="relu")
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 4),
    hw=st.sampled_from([4, 8, 16]),
    c=st.sampled_from([1, 4, 16]),
    seed=st.integers(0, 2**31),
)
def test_depthwise_matches_ref(b, hw, c, seed):
    rng = np.random.default_rng(seed)
    x = rnd(rng, b, hw, hw, c)
    w = rnd(rng, 3, 3, c)
    got = conv_k.depthwise3x3(x, w)
    want = ref.depthwise3x3_ref(x, w)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 4),
    t=st.sampled_from([4, 8, 16, 32]),
    d=st.sampled_from([8, 16, 64]),
    seed=st.integers(0, 2**31),
)
def test_attention_matches_ref(b, t, d, seed):
    rng = np.random.default_rng(seed)
    q, k, v = (rnd(rng, b, t, d) for _ in range(3))
    got = attn_k.attention(q, k, v)
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-5)


def test_attention_softmax_stability():
    # Large logits must not overflow (stable softmax inside the kernel).
    q = np.full((1, 4, 8), 100.0, dtype=np.float32)
    k = np.full((1, 4, 8), 100.0, dtype=np.float32)
    v = np.ones((1, 4, 8), dtype=np.float32)
    out = np.asarray(attn_k.attention(q, k, v))
    assert np.isfinite(out).all()
    np.testing.assert_allclose(out, 1.0, rtol=1e-5)


@settings(**SETTINGS)
@given(
    n=st.sampled_from([1, 2, 8, 24, 64]),
    d=st.sampled_from([4, 16, 64]),
    seed=st.integers(0, 2**31),
)
def test_layernorm_matches_ref(n, d, seed):
    rng = np.random.default_rng(seed)
    x = rnd(rng, n, d)
    g, b = rnd(rng, d), rnd(rng, d)
    got = norm_k.layernorm(x, g, b)
    want = ref.layernorm_ref(x, g, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(**SETTINGS)
@given(
    b=st.integers(1, 3),
    hw=st.sampled_from([4, 8, 16]),
    c=st.sampled_from([2, 8]),
    seed=st.integers(0, 2**31),
)
def test_pool_matches_ref(b, hw, c, seed):
    rng = np.random.default_rng(seed)
    x = rnd(rng, b, hw, hw, c)
    np.testing.assert_allclose(conv_k.avg_pool2(x), ref.avg_pool2_ref(x), rtol=1e-6)
    # Max pool: compare against direct reshape-max.
    want = x.reshape(b, hw // 2, 2, hw // 2, 2, c).max(axis=(2, 4))
    np.testing.assert_allclose(conv_k.max_pool2(x), want, rtol=1e-6)


def test_vmem_budgets():
    """Structure-level perf contract: every kernel's per-grid-step VMEM
    footprint stays under the 16 MiB per-core budget for zoo shapes."""
    VMEM = 16 * 1024 * 1024
    # Largest matmul in the zoo: vgg_mini im2col at batch 16.
    assert mm_k.vmem_bytes(16 * 32 * 32, 9 * 64, 64) < VMEM
    assert conv_k.dw_vmem_bytes(16, 16, 16) < VMEM
    assert attn_k.vmem_bytes(16, 64) < VMEM


def test_mxu_tiles_for_zoo_shapes():
    """The hot matmuls should reach full 128-edge MXU tiles."""
    assert mm_k.mxu_utilization(16 * 32 * 32, 27, 16) > 0.1
    assert mm_k.mxu_utilization(16384, 288, 128) == 1.0


@pytest.mark.parametrize("m,k,n", [(1, 1, 1), (1, 7, 1), (3, 5, 7)])
def test_matmul_degenerate_shapes(m, k, n):
    rng = np.random.default_rng(0)
    x, w = rnd(rng, m, k), rnd(rng, k, n)
    np.testing.assert_allclose(
        mm_k.matmul(x, w), ref.matmul_ref(x, w), rtol=1e-5, atol=1e-5
    )


def test_matmul_is_deterministic():
    rng = np.random.default_rng(1)
    x, w = rnd(rng, 32, 16), rnd(rng, 16, 8)
    a = np.asarray(mm_k.matmul(x, w))
    b = np.asarray(mm_k.matmul(x, w))
    np.testing.assert_array_equal(a, b)
