"""L2 correctness: model zoo shapes, determinism, and cross-language
weight-init contract."""

import numpy as np
import pytest

import jax

from compile import model as M


@pytest.mark.parametrize("name", list(M.MODELS.keys()))
@pytest.mark.parametrize("batch", [1, 3])
def test_model_shapes(name, batch):
    spec, apply = M.build(name)
    params = spec.materialize()
    x = M.deterministic_input(M.input_shape(name, batch))
    out = np.asarray(jax.jit(lambda x, *p: apply(x, *p))(x, *params))
    assert out.shape == (batch, 10), f"{name}: {out.shape}"
    assert np.isfinite(out).all(), f"{name} produced non-finite logits"


@pytest.mark.parametrize("name", ["convnet1", "bert_mini"])
def test_model_deterministic(name):
    spec, apply = M.build(name)
    params = spec.materialize()
    x = M.deterministic_input(M.input_shape(name, 2))
    f = jax.jit(lambda x, *p: apply(x, *p))
    a, b = np.asarray(f(x, *params)), np.asarray(f(x, *params))
    np.testing.assert_array_equal(a, b)


def test_batch_consistency():
    """Row i of a batched forward equals the single-row forward (no
    cross-batch leakage through the kernels)."""
    spec, apply = M.build("convnet1")
    params = spec.materialize()
    xb = M.deterministic_input(M.input_shape("convnet1", 4))
    f = jax.jit(lambda x, *p: apply(x, *p))
    full = np.asarray(f(xb, *params))
    for i in range(4):
        row = np.asarray(f(xb[i : i + 1], *params))
        np.testing.assert_allclose(full[i : i + 1], row, rtol=2e-4, atol=2e-4)


def test_det_weights_known_values():
    """Pin the splitmix64 weight-init contract: these exact values are
    re-derived by the Rust runtime (runtime::det_weights). If this test
    changes, rust/src/runtime tests must change identically."""
    w = M.det_weights((4,), seed=0, scale=1.0)
    z = M._splitmix64(np.arange(4, dtype=np.uint64))
    u = (z >> np.uint64(11)).astype(np.float64) / (1 << 53)
    np.testing.assert_allclose(w, (2 * u - 1).astype(np.float32))
    # Different seeds decorrelate.
    w2 = M.det_weights((4,), seed=1, scale=1.0)
    assert not np.allclose(w, w2)
    # Scale applies linearly.
    w3 = M.det_weights((4,), seed=0, scale=0.5)
    np.testing.assert_allclose(w3, w * 0.5, rtol=1e-6)


def test_det_weights_distribution():
    w = M.det_weights((10_000,), seed=7, scale=1.0)
    assert abs(float(w.mean())) < 0.03
    assert 0.5 < float(w.std()) < 0.65  # uniform on [-1,1]: σ = 1/√3
    assert w.min() >= -1.0 and w.max() <= 1.0


def test_param_counts_reasonable():
    for name in M.MODELS:
        spec, _ = M.build(name)
        n = sum(int(np.prod(shape)) for _, shape, _ in spec.params)
        assert 1_000 < n < 2_000_000, f"{name}: {n} params"


def test_deterministic_input_contract():
    x = M.deterministic_input((2, 2))
    np.testing.assert_allclose(x, [[-0.5, -0.25], [0.0, 0.25]])
