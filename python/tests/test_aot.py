"""AOT path: HLO text artifacts are produced, parseable-looking, and the
manifest self-check matches a fresh recomputation."""

import json
import subprocess
import sys
from pathlib import Path

import numpy as np
import pytest

import jax

from compile import aot, model as M


def test_lower_model_produces_hlo_text():
    hlo, entry = aot.lower_model("convnet1", 1)
    assert "ENTRY" in hlo and "ROOT" in hlo, "not HLO text"
    # Weights are runtime inputs: the ENTRY signature takes the input
    # plus one argument per weight. (Nested computations also contain
    # `parameter(` lines, so count args on the ENTRY line only.)
    lines = hlo.splitlines()
    start = next(i for i, l in enumerate(lines) if l.startswith("ENTRY"))
    block = []
    for l in lines[start + 1 :]:
        if l.strip() == "}":
            break
        block.append(l)
    n_params = sum(" parameter(" in l for l in block)
    assert n_params == 1 + len(entry["params"]), n_params
    assert entry["input_shape"] == [1, 32, 32, 3]
    assert entry["output_shape"] == [1, 10]


def test_selfcheck_reproducible():
    hlo1, e1 = aot.lower_model("bert_mini", 1)
    hlo2, e2 = aot.lower_model("bert_mini", 1)
    assert e1["hlo_sha256"] == e2["hlo_sha256"], "lowering must be deterministic"
    assert e1["selfcheck"] == e2["selfcheck"]


def test_selfcheck_matches_direct_eval():
    _, entry = aot.lower_model("alexnet_mini", 1)
    spec, apply = M.build("alexnet_mini")
    params = spec.materialize()
    x = M.deterministic_input(M.input_shape("alexnet_mini", 1))
    out = np.asarray(jax.jit(lambda x, *p: apply(x, *p))(x, *params))
    assert abs(entry["selfcheck"]["output_sum"] - float(out.sum())) < 1e-4
    np.testing.assert_allclose(
        entry["selfcheck"]["output_first8"], out.ravel()[:8], rtol=1e-5, atol=1e-6
    )


def test_cli_writes_artifacts(tmp_path):
    out = tmp_path / "artifacts"
    subprocess.run(
        [
            sys.executable,
            "-m",
            "compile.aot",
            "--out",
            str(out),
            "--models",
            "convnet1",
            "--batches",
            "1",
        ],
        check=True,
        cwd=Path(__file__).resolve().parents[1],
    )
    manifest = json.loads((out / "manifest.json").read_text())
    assert len(manifest["artifacts"]) == 1
    a = manifest["artifacts"][0]
    assert (out / a["file"]).exists()
    text = (out / a["file"]).read_text()
    assert "ENTRY" in text
    # Manifest hash matches the file on disk.
    import hashlib

    assert hashlib.sha256(text.encode()).hexdigest() == a["hlo_sha256"]


@pytest.mark.parametrize("batch", [1, 4])
def test_batch_dim_propagates(batch):
    _, entry = aot.lower_model("vgg_mini", batch)
    assert entry["input_shape"][0] == batch
    assert entry["output_shape"][0] == batch
