//! §6.2 / Fig. 9d: the ideal kernel-granularity preemptive scheduler vs
//! D-STACK, GSLICE and temporal sharing on the three LeNet-style
//! ConvNets — utilization and throughput.
//!
//!     cargo run --release --example ideal_vs_dstack

use dstack::config::{build_policy, PolicyKind};
use dstack::profile::{convnets, V100};
use dstack::sched::ideal::run_ideal;
use dstack::sim::{entries_at_optimum, Sim, SimConfig};
use dstack::workload::{merged_stream, Arrivals};

fn main() {
    let profiles = convnets();
    let horizon_ms = 5_000.0;

    // Saturating closed-loop-like workload for the sim policies.
    let entries = entries_at_optimum(&profiles);
    let specs: Vec<_> = profiles
        .iter()
        .map(|p| (Arrivals::Poisson { rate: 2_000.0 }, p.slo_ms))
        .collect();
    let reqs = merged_stream(&specs, horizon_ms, 11);

    println!("policy          util%   thpt(img/s)  per-model");
    for kind in [PolicyKind::Temporal, PolicyKind::Gslice, PolicyKind::Dstack] {
        let mut pol = build_policy(kind, &entries);
        let mut sim =
            Sim::new(SimConfig { horizon_ms, ..Default::default() }, entries.clone());
        let rep = sim.run(pol.as_mut(), &reqs);
        println!(
            "{:<15} {:>5.1}   {:>10.0}  {:?}",
            kind.name(),
            rep.mean_utilization() * 100.0,
            rep.total_throughput(),
            rep.throughput().iter().map(|t| t.round()).collect::<Vec<_>>()
        );
    }

    let ideal = run_ideal(&profiles, &V100, 16, horizon_ms, 100);
    println!(
        "{:<15} {:>5.1}   {:>10.0}  {:?}",
        "ideal",
        ideal.utilization * 100.0,
        ideal.throughput.iter().sum::<f64>(),
        ideal.throughput.iter().map(|t| t.round()).collect::<Vec<_>>()
    );
}
