//! Fig. 11b: D-STACK adapting to dynamically varying request rates.
//! Five sessions T0–T4; in each, one model's rate drops and the others
//! opportunistically absorb the freed GPU capacity.
//!
//!     cargo run --release --example dynamic_rates

use dstack::config::{build_policy, PolicyKind};
use dstack::profile::by_name;
use dstack::sim::{entries_at_optimum, Sim, SimConfig};
use dstack::workload::{merged_stream, Arrivals};

fn main() {
    let names = ["alexnet", "mobilenet", "resnet50", "vgg19"];
    let profiles: Vec<_> = names.iter().map(|n| by_name(n).unwrap()).collect();
    let entries = entries_at_optimum(&profiles);

    // 2 s per phase; in phase k (k>0), model k-1's rate drops to 30%.
    let phase_ms = 2_000.0;
    let base = [700.0, 700.0, 320.0, 160.0];
    let mut specs = Vec::new();
    for (i, p) in profiles.iter().enumerate() {
        let mut segments = vec![(0.0, base[i])];
        for k in 1..5usize {
            let rate = if k - 1 == i { base[i] * 0.3 } else { base[i] };
            segments.push((k as f64 * phase_ms, rate));
        }
        specs.push((Arrivals::trace(segments), p.slo_ms));
    }
    let horizon = 5.0 * phase_ms;
    let reqs = merged_stream(&specs, horizon, 3);

    let mut pol = build_policy(PolicyKind::Dstack, &entries);
    let mut sim = Sim::new(SimConfig { horizon_ms: horizon, gantt: true, ..Default::default() },
        entries.clone());
    let rep = sim.run(pol.as_mut(), &reqs);

    // Report per-phase throughput from the Gantt log.
    let gantt = sim.gpu.gantt.as_ref().unwrap();
    println!("phase   {:>10} {:>10} {:>10} {:>10}   util%", names[0], names[1], names[2], names[3]);
    for k in 0..5u64 {
        let lo = k * 2_000_000;
        let hi = lo + 2_000_000;
        let mut items = [0u64; 4];
        let mut busy_pct_us = 0.0f64;
        for e in gantt.iter().filter(|e| e.start >= lo && e.start < hi) {
            items[e.model] += 1;
            busy_pct_us += e.pct as f64 * (e.end.min(hi) - e.start) as f64;
        }
        println!(
            "T{k}      {:>10} {:>10} {:>10} {:>10}   {:>5.1}",
            items[0], items[1], items[2], items[3],
            busy_pct_us / (100.0 * 2_000_000.0) * 100.0
        );
    }
    println!("\n(total served: {:.0} req/s, violations {:.1}%)",
        rep.total_throughput(), rep.violation_fraction() * 100.0);
}
