//! Long-tail model fleet under the lifecycle memory manager.
//!
//! 24 models with Zipf(1.1) popularity — ~26 GiB of weights — serve on
//! two V100s whose resident budget holds fewer than half of them. The
//! head of the distribution stays warm; the tail is faulted in on
//! demand (evicting colder models), idles back out to zero, and pays
//! its cold-start delay as end-to-end latency. Warmness-aware routing
//! keeps each model's traffic on its warm replica; warm-oblivious JSQ
//! spills to cold replicas whenever a queue forms, thrashing the store.
//!
//!     cargo run --release --example lifecycle_longtail

use dstack::cluster::{GpuSched, PlacementPolicy, RoutingPolicy};
use dstack::lifecycle::{longtail_gpus, longtail_workload, serve_longtail, LifecycleCfg};

fn main() {
    let horizon_ms = 8_000.0;
    let seed = 42;
    let (profiles, rates, reqs) = longtail_workload(24, 1.1, 600.0, horizon_ms, seed);
    let gpus = longtail_gpus();
    let total_mem: u64 = profiles.iter().map(|p| p.mem_mib).sum();
    let cfg = LifecycleCfg { mem_budget_mib: 4_096, ..Default::default() };
    println!(
        "{} models, {} MiB of weights vs {} MiB resident budget, {} requests over {:.0} s",
        profiles.len(),
        total_mem,
        2 * cfg.mem_budget_mib,
        reqs.len(),
        horizon_ms / 1_000.0
    );

    let run = |warm: bool| {
        serve_longtail(
            &profiles,
            &rates,
            &gpus,
            PlacementPolicy::LoadBalance,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            &LifecycleCfg { warm_routing: warm, ..cfg.clone() },
            reqs.clone(),
            horizon_ms,
            seed,
        )
    };

    for (label, warm) in [("warm-oblivious JSQ", false), ("warmness-aware JSQ", true)] {
        let rep = run(warm);
        let stats = rep.lifecycle.as_ref().expect("lifecycle stats");
        println!("\n== {label} ==");
        println!(
            "  head: {:<14} {:>6.0} req/s    tail (last): {:<14} {:>5.1} req/s",
            profiles[0].name,
            rep.throughput[0],
            profiles[23].name,
            rep.throughput[23]
        );
        println!(
            "  total {:.0} req/s, goodput {:.0} req/s in SLO, {:.0} viol/s",
            rep.total_throughput(),
            stats.goodput_rps,
            rep.violations_per_sec.iter().sum::<f64>()
        );
        println!(
            "  {} cold starts (p99 delay {:.0} ms), {} warm hits, {} evictions, \
             {} scale-to-zero, {} MiB loaded",
            stats.cold_starts,
            stats.cold_start_p99_ms,
            stats.warm_hits,
            stats.evictions,
            stats.scale_to_zero,
            stats.mib_loaded
        );
        println!(
            "  peak resident MiB per GPU: {:?} (budget {})",
            stats.peak_resident_mib, cfg.mem_budget_mib
        );
    }
}
