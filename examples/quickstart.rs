//! Quickstart: load an AOT artifact, validate its numerics against the
//! JAX self-check, and time single inferences through the PJRT runtime.
//!
//!     make artifacts && cargo run --release --example quickstart

use dstack::runtime::{artifacts_dir, iota_input, Runtime};
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let mut rt = Runtime::new(&artifacts_dir())?;
    println!("artifacts: {} models", rt.manifest.models().len());

    for (model, batch) in [("alexnet_mini", 1u32), ("alexnet_mini", 16), ("bert_mini", 16)] {
        let loaded = rt.load(model, batch)?;
        loaded.selfcheck()?;
        let x = iota_input(&loaded.artifact.input_shape);
        // Warm up, then time.
        loaded.infer(&x)?;
        let t0 = Instant::now();
        let iters = 20;
        for _ in 0..iters {
            loaded.infer(&x)?;
        }
        let ms = t0.elapsed().as_secs_f64() * 1_000.0 / iters as f64;
        println!(
            "{model:>16} b{batch:<3} selfcheck OK   {ms:7.2} ms/batch   {:8.0} items/s",
            batch as f64 / (ms / 1_000.0)
        );
    }

    // The §5 optimizer on the paper-calibrated profiles (Table 6).
    println!("\nTable 6 operating points (paper-calibrated profiles):");
    for row in dstack::optimizer::table6(&dstack::profile::zoo()) {
        println!(
            "  {:<10} knee {:>3}%  slo {:>5.0} ms  batch {:>2}  runtime {:>5.1} ms",
            row.model, row.knee_pct, row.slo_ms, row.batch, row.runtime_ms
        );
    }
    Ok(())
}
