//! END-TO-END DRIVER (the DESIGN.md §5 "E2E" row): serve four real mini
//! models through the full stack — JAX/Pallas-compiled HLO artifacts,
//! PJRT execution, request router, batcher, and the real-time D-STACK
//! dispatcher — under an open-loop Poisson workload, and report measured
//! latency/throughput/SLO attainment against a Triton-style FCFS
//! baseline.
//!
//!     make artifacts && cargo run --release --example serve_multimodel
//!
//! Flags: --seconds N (default 10) --rate-scale X (default 1.0)

use dstack::coordinator::{Coordinator, ServeConfig, ServeModel, ServePolicy};
use dstack::runtime::{artifacts_dir, Runtime};
use dstack::util::cli::Args;
use std::time::Duration;

fn main() -> anyhow::Result<()> {
    let args = Args::from_env();
    let seconds = args.get_f64("seconds", 10.0);
    let scale = args.get_f64("rate-scale", 1.0);

    // The C-4 mix of the paper, mapped to the mini zoo. Rates follow the
    // SLO-inverse-proportional split of §7, scaled to CPU capacity.
    let models = vec![
        ServeModel { name: "mobilenet_mini".into(), rate: 60.0 * scale, slo_ms: 100.0 },
        ServeModel { name: "alexnet_mini".into(), rate: 60.0 * scale, slo_ms: 100.0 },
        ServeModel { name: "resnet_mini".into(), rate: 30.0 * scale, slo_ms: 200.0 },
        ServeModel { name: "vgg_mini".into(), rate: 15.0 * scale, slo_ms: 400.0 },
    ];

    for policy in [ServePolicy::Fifo, ServePolicy::DstackRt] {
        let rt = Runtime::new(&artifacts_dir())?;
        let mut coord = Coordinator::new(rt);
        let cfg = ServeConfig {
            models: models.clone(),
            policy,
            duration: Duration::from_secs_f64(seconds),
            seed: 42,
        };
        let rep = coord.serve(&cfg)?;
        println!("\n=== policy: {} ({}s wall) ===", rep.policy, rep.wall_s.round());
        println!("{}", rep.render());
        println!(
            "total throughput: {:.0} req/s   SLO violation fraction: {:.3}",
            rep.total_throughput(),
            rep.violation_fraction()
        );
    }
    Ok(())
}
