//! Adaptive control plane vs static placement under rate drift.
//!
//! ResNet-50 and VGG-19 swap hot/cold roles halfway through the run
//! while AlexNet and Mobilenet offer steady load (see
//! `workload::drift_rates`). A static knee packing must be solved for
//! the per-model peaks — which never occur simultaneously — and rejects
//! two models outright; the adaptive control plane places for the live
//! rate estimates and migrates replicas when its drift detector fires.
//!
//!     cargo run --release --example adaptive_rebalance

use dstack::cluster::{serve_cluster, GpuSched, PlacementPolicy, RoutingPolicy};
use dstack::controlplane::{drift_gpus, drift_workload, run_adaptive, AdaptiveCfg};

fn main() {
    let horizon_ms = 10_000.0;
    let seed = 42;
    let (profiles, initial, peak, reqs) = drift_workload(horizon_ms, seed);
    let gpus = drift_gpus();
    let names: Vec<&str> = profiles.iter().map(|p| p.name.as_str()).collect();
    println!(
        "drifting workload on 2xV100 ({} requests over {:.0} s, drift at {:.0} s)",
        reqs.len(),
        horizon_ms / 1_000.0,
        horizon_ms / 2_000.0
    );

    let run_static = |rates: &[f64], label: &str| {
        let r = serve_cluster(
            &profiles,
            rates,
            &gpus,
            PlacementPolicy::FirstFitDecreasing,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            reqs.clone(),
            horizon_ms,
            seed,
        );
        println!("\n== {label} ==");
        for (m, name) in names.iter().enumerate() {
            println!(
                "  {:<10} admitted={:<5} served={:>6} rejected={:>6} ({:.0} req/s)",
                name, r.admitted[m], r.served[m], r.rejected[m], r.throughput[m]
            );
        }
        println!("  total {:.0} req/s", r.total_throughput());
        r
    };
    let stat_peak = run_static(&peak, "static placement (peak rates)");
    run_static(&initial, "static placement (t=0 rates)");

    let adap = run_adaptive(
        &profiles,
        &initial,
        &gpus,
        PlacementPolicy::FirstFitDecreasing,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        &AdaptiveCfg::default(),
        reqs.clone(),
        horizon_ms,
        seed,
    );
    println!("\n== adaptive control plane ==");
    for (m, name) in names.iter().enumerate() {
        println!(
            "  {:<10} admitted={:<5} served={:>6} rejected={:>6} ({:.0} req/s)",
            name, adap.admitted[m], adap.served[m], adap.rejected[m], adap.throughput[m]
        );
    }
    println!("  total {:.0} req/s", adap.total_throughput());
    let stats = adap.adaptive.as_ref().expect("adaptive stats");
    println!(
        "  {} replans, {} rebalances (+{}/-{} replicas, {:.0} ms migration) at {:?} ms",
        stats.replans,
        stats.rebalances,
        stats.replicas_added,
        stats.replicas_removed,
        stats.migration_ms,
        stats.rebalance_times_us.iter().map(|t| t / 1_000).collect::<Vec<_>>()
    );
    println!(
        "  p99 before/after first rebalance (ms): {:?} / {:?}",
        stats.p99_before_ms.iter().map(|v| v.round()).collect::<Vec<_>>(),
        stats.p99_after_ms.iter().map(|v| v.round()).collect::<Vec<_>>()
    );

    println!(
        "\nadaptive vs static-peak: {:.0} vs {:.0} req/s ({:.2}x)",
        adap.total_throughput(),
        stat_peak.total_throughput(),
        adap.total_throughput() / stat_peak.total_throughput().max(1e-9)
    );
}
