//! Fig. 12: the 4×T4 cluster — exclusive GPUs vs temporal sharing vs
//! D-STACK on every GPU.
//!
//!     cargo run --release --example cluster_sim

use dstack::cluster::{run_cluster, ClusterPolicy};
use dstack::profile::{by_name, T4};
use dstack::workload::{merged_stream, Arrivals};

fn main() {
    let names = ["mobilenet", "alexnet", "resnet50", "vgg19"];
    let profiles: Vec<_> = names.iter().map(|n| by_name(n).unwrap()).collect();
    let rates = [150.0, 150.0, 900.0, 450.0];
    let horizon_ms = 8_000.0;
    let specs: Vec<_> = profiles
        .iter()
        .zip(rates)
        .map(|(p, r)| (Arrivals::Poisson { rate: r }, p.slo_ms))
        .collect();
    let reqs = merged_stream(&specs, horizon_ms, 77);

    println!("policy        total(req/s)  per-model  mean-util%");
    for pol in [ClusterPolicy::Exclusive, ClusterPolicy::TemporalAll, ClusterPolicy::DstackAll] {
        let r = run_cluster(&profiles, &T4, 4, &reqs, horizon_ms, pol);
        println!(
            "{:<12} {:>12.0}  {:?}  {:>6.1}",
            r.policy,
            r.total_throughput(),
            r.throughput.iter().map(|t| t.round()).collect::<Vec<_>>(),
            r.mean_utilization() * 100.0
        );
    }
}
