//! Fig. 12 and beyond: the paper's fixed 4×T4 layouts (exclusive GPUs vs
//! temporal sharing vs D-STACK on every GPU) followed by the cluster
//! placement engine — knee-packed placement, replication of hot models,
//! and load-aware routing — including a heterogeneous V100+T4 cluster.
//!
//!     cargo run --release --example cluster_sim

use dstack::cluster::{
    fig12_workload, run_cluster, serve_cluster, ClusterPolicy, GpuSched, PlacementPolicy,
    RoutingPolicy,
};
use dstack::profile::{GpuSpec, T4, V100};

fn main() {
    let horizon_ms = 8_000.0;
    let (profiles, rates, reqs) = fig12_workload(horizon_ms, 77);
    let names: Vec<&str> = profiles.iter().map(|p| p.name.as_str()).collect();

    println!("== paper scenarios (fixed layouts, 4xT4, round-robin split) ==");
    println!("{:<22} {:>12}  per-model  mean-util%", "policy", "total(req/s)");
    for pol in [ClusterPolicy::Exclusive, ClusterPolicy::TemporalAll, ClusterPolicy::DstackAll] {
        let r = run_cluster(&profiles, &T4, 4, reqs.clone(), horizon_ms, pol);
        println!(
            "{:<22} {:>12.0}  {:?}  {:>6.1}",
            r.policy,
            r.total_throughput(),
            r.throughput.iter().map(|t| t.round()).collect::<Vec<_>>(),
            r.mean_utilization() * 100.0
        );
    }

    println!();
    println!("== placement engine (knee-packed, replicated, load-aware routing) ==");
    let t4x4: Vec<GpuSpec> = vec![T4.clone(); 4];
    let hetero: Vec<GpuSpec> = vec![V100.clone(), V100.clone(), T4.clone(), T4.clone()];
    let scenarios: [(&str, &Vec<GpuSpec>, PlacementPolicy, RoutingPolicy); 3] = [
        ("ffd+jsq 4xT4", &t4x4, PlacementPolicy::FirstFitDecreasing, RoutingPolicy::JoinShortestQueue),
        ("lb+p2c  4xT4", &t4x4, PlacementPolicy::LoadBalance, RoutingPolicy::PowerOfTwoChoices),
        ("ffd+jsq 2xV100+2xT4", &hetero, PlacementPolicy::FirstFitDecreasing, RoutingPolicy::JoinShortestQueue),
    ];
    for (label, gpus, placement, routing) in scenarios {
        let r = serve_cluster(
            &profiles, &rates, gpus, placement, routing, GpuSched::Dstack, reqs.clone(), horizon_ms,
            77,
        );
        println!(
            "{:<22} {:>12.0}  {:?}  {:>6.1}",
            label,
            r.total_throughput(),
            r.throughput.iter().map(|t| t.round()).collect::<Vec<_>>(),
            r.mean_utilization() * 100.0
        );
        for (g, gr) in r.per_gpu.iter().enumerate() {
            let models: Vec<String> = gr
                .models
                .iter()
                .map(|s| format!("{}@{}%", names[s.model], s.pct))
                .collect();
            println!(
                "    gpu{g} {:<5} knee_load {:>3}%  util {:>5.1}%  [{}]",
                gr.gpu,
                gr.knee_load_pct,
                gr.utilization * 100.0,
                models.join(" ")
            );
        }
    }
}
