//! Minimal API-compatible stand-in for the `anyhow` crate.
//!
//! The build image has no reachable crates registry (see DESIGN.md §3),
//! so the subset of `anyhow` the codebase uses is implemented here: the
//! [`Error`] type with source preservation, the [`Result`] alias, the
//! [`anyhow!`]/[`bail!`]/[`ensure!`] macros and the [`Context`]
//! extension trait. Swapping in the real crate is a one-line Cargo.toml
//! change; no call site would differ.

use std::error::Error as StdError;
use std::fmt;

/// A boxed, context-carrying error. Like `anyhow::Error`, this
/// deliberately does *not* implement `std::error::Error`, which is what
/// permits the blanket `From<E: std::error::Error>` conversion below.
pub struct Error {
    msg: String,
    source: Option<Box<dyn StdError + Send + Sync + 'static>>,
}

/// `Result<T, anyhow::Error>` with the error type defaulted.
pub type Result<T, E = Error> = std::result::Result<T, E>;

impl Error {
    /// Construct from a displayable message.
    pub fn msg<M: fmt::Display>(message: M) -> Error {
        Error { msg: message.to_string(), source: None }
    }

    /// Construct from a concrete error, preserving it as the source.
    pub fn new<E: StdError + Send + Sync + 'static>(error: E) -> Error {
        Error { msg: error.to_string(), source: Some(Box::new(error)) }
    }

    /// Wrap with higher-level context (rendered as `context: cause`).
    pub fn context<C: fmt::Display>(self, context: C) -> Error {
        Error { msg: format!("{context}: {}", self.msg), source: self.source }
    }

    /// The deepest retained source error, if any.
    pub fn source(&self) -> Option<&(dyn StdError + 'static)> {
        self.source.as_deref().map(|e| e as &(dyn StdError + 'static))
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "{}", self.msg)?;
        let mut src = self.source();
        while let Some(e) = src {
            write!(f, "\n\nCaused by:\n    {e}")?;
            src = e.source();
        }
        Ok(())
    }
}

impl<E: StdError + Send + Sync + 'static> From<E> for Error {
    fn from(error: E) -> Error {
        Error::new(error)
    }
}

/// Context-attachment extension for `Result`, mirroring `anyhow::Context`.
pub trait Context<T> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error>;
    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error>;
}

impl<T, E: Into<Error>> Context<T> for Result<T, E> {
    fn context<C: fmt::Display>(self, context: C) -> Result<T, Error> {
        self.map_err(|e| e.into().context(context))
    }

    fn with_context<C: fmt::Display, F: FnOnce() -> C>(self, f: F) -> Result<T, Error> {
        self.map_err(|e| e.into().context(f()))
    }
}

/// Construct an [`Error`] from a format string or any displayable value.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => { $crate::Error::msg(::std::format!($msg)) };
    ($err:expr $(,)?) => { $crate::Error::msg($err) };
    ($fmt:expr, $($arg:tt)*) => { $crate::Error::msg(::std::format!($fmt, $($arg)*)) };
}

/// Return early with an error built by [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($args:tt)*) => {
        return ::std::result::Result::Err($crate::anyhow!($($args)*))
    };
}

/// Return early with an error unless the condition holds.
#[macro_export]
macro_rules! ensure {
    ($cond:expr $(,)?) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!(
                "condition failed: {}", ::std::stringify!($cond)
            ));
        }
    };
    ($cond:expr, $($args:tt)*) => {
        if !($cond) {
            return ::std::result::Result::Err($crate::anyhow!($($args)*));
        }
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read_to_string("/definitely/not/a/real/path/9f3a")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let err = io_fail().unwrap_err();
        assert!(err.source().is_some(), "io::Error retained as source");
    }

    #[test]
    fn context_wraps_message() {
        let r: Result<(), std::io::Error> = Err(std::io::Error::new(
            std::io::ErrorKind::NotFound,
            "missing",
        ));
        let err = r.with_context(|| "reading manifest").unwrap_err();
        assert!(err.to_string().starts_with("reading manifest: "), "{err}");
    }

    #[test]
    fn macros_build_errors() {
        let e = anyhow!("knee at {}%", 40);
        assert_eq!(e.to_string(), "knee at 40%");
        let s: String = "plain".into();
        assert_eq!(anyhow!(s).to_string(), "plain");

        fn bails(x: u32) -> Result<u32> {
            ensure!(x < 10, "x too big: {x}");
            if x == 7 {
                bail!("unlucky");
            }
            Ok(x)
        }
        assert_eq!(bails(3).unwrap(), 3);
        assert_eq!(bails(7).unwrap_err().to_string(), "unlucky");
        assert_eq!(bails(12).unwrap_err().to_string(), "x too big: 12");
    }

    #[test]
    fn debug_renders_cause_chain() {
        let err = io_fail().unwrap_err().context("loading artifacts");
        let dbg = format!("{err:?}");
        assert!(dbg.contains("loading artifacts"), "{dbg}");
        assert!(dbg.contains("Caused by"), "{dbg}");
    }
}
