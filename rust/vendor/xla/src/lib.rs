//! Stub of the `xla-rs` PJRT API surface used by `dstack::runtime`.
//!
//! The build image ships no native XLA/PJRT library, so this crate
//! provides the exact types and signatures the runtime compiles against
//! while returning a descriptive error the moment a client is created.
//! `dstack::runtime::Runtime::new` therefore fails cleanly, the PJRT
//! integration tests skip (same path as "artifacts not built"), and the
//! entire virtual-time experiment surface — which never touches PJRT —
//! builds and runs everywhere. Linking a real backend is a Cargo.toml
//! swap to the actual `xla` crate; no call site changes.

use std::borrow::Borrow;
use std::error::Error as StdError;
use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error` closely enough for `?`-conversion
/// into `anyhow::Error` (it implements `std::error::Error`).
#[derive(Debug, Clone)]
pub struct Error {
    msg: String,
}

impl Error {
    fn unavailable(what: &str) -> Error {
        Error {
            msg: format!(
                "{what}: XLA/PJRT native backend is not available in this build \
                 (stub `xla` crate; virtual-time experiments do not require it)"
            ),
        }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl StdError for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Host-side tensor literal.
#[derive(Debug, Clone)]
pub struct Literal {
    _private: (),
}

impl Literal {
    /// Build a rank-1 f32 literal (stub: shape/data are not retained —
    /// no executable can exist to consume them).
    pub fn vec1(_data: &[f32]) -> Literal {
        Literal { _private: () }
    }

    pub fn reshape(&self, _dims: &[i64]) -> Result<Literal> {
        Ok(Literal { _private: () })
    }

    pub fn to_tuple1(&self) -> Result<Literal> {
        Err(Error::unavailable("Literal::to_tuple1"))
    }

    pub fn to_vec<T>(&self) -> Result<Vec<T>> {
        Err(Error::unavailable("Literal::to_vec"))
    }
}

/// Parsed HLO module (stub: checks the file exists, retains nothing).
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    _private: (),
}

impl HloModuleProto {
    pub fn from_text_file<P: AsRef<Path>>(path: P) -> Result<HloModuleProto> {
        let p = path.as_ref();
        if !p.exists() {
            return Err(Error { msg: format!("HLO text file not found: {}", p.display()) });
        }
        Ok(HloModuleProto { _private: () })
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _private: (),
}

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation { _private: () }
    }
}

/// Device-resident buffer returned by execution.
#[derive(Debug)]
pub struct PjRtBuffer {
    _private: (),
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Err(Error::unavailable("PjRtBuffer::to_literal_sync"))
    }
}

/// Compiled executable handle.
#[derive(Debug)]
pub struct PjRtLoadedExecutable {
    _private: (),
}

impl PjRtLoadedExecutable {
    pub fn execute<L: Borrow<Literal>>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::unavailable("PjRtLoadedExecutable::execute"))
    }
}

/// PJRT client. The stub refuses to construct one, which is the single
/// choke point that makes every downstream path unreachable.
#[derive(Debug)]
pub struct PjRtClient {
    _private: (),
}

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Err(Error::unavailable("PjRtClient::cpu"))
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::unavailable("PjRtClient::compile"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn client_construction_fails_with_clear_message() {
        let err = PjRtClient::cpu().unwrap_err();
        assert!(err.to_string().contains("not available"), "{err}");
    }

    #[test]
    fn literal_builders_are_inert() {
        let l = Literal::vec1(&[1.0, 2.0]).reshape(&[2, 1]).unwrap();
        assert!(l.to_vec::<f32>().is_err());
        assert!(l.to_tuple1().is_err());
    }

    #[test]
    fn hlo_from_missing_file_errors() {
        assert!(HloModuleProto::from_text_file("/no/such/file.hlo").is_err());
    }
}
