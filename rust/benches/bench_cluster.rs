//! Cluster benchmarks: the Fig. 12 fixed layouts plus the placement
//! engine, on identical seeded workloads.
//!
//! The headline acceptance comparison: a heterogeneous 2×V100 + 2×T4
//! cluster with knee-packed (FFD) placement and join-shortest-queue
//! routing must reach at least the legacy round-robin `DstackAll`
//! aggregate throughput of the 4×T4 layout on the same request stream.

use dstack::bench::{bench, Bench};
use dstack::cluster::{
    fig12_workload, run_cluster, serve_cluster, ClusterPolicy, GpuSched, PlacementPolicy,
    RoutingPolicy,
};
use dstack::profile::{GpuSpec, T4, V100};

fn main() {
    let horizon_ms = 2_000.0;
    let (profiles, rates, reqs) = fig12_workload(horizon_ms, 77);
    let cfg = Bench::quick();

    let mut legacy_dstack = 0.0;
    for pol in [ClusterPolicy::Exclusive, ClusterPolicy::TemporalAll, ClusterPolicy::DstackAll] {
        let mut total = 0.0;
        bench(&format!("cluster/{pol:?}"), &cfg, || {
            total = run_cluster(&profiles, &T4, 4, reqs.clone(), horizon_ms, pol)
                .total_throughput();
        });
        println!("    -> total {total:.0} req/s");
        if pol == ClusterPolicy::DstackAll {
            legacy_dstack = total;
        }
    }

    let t4x4: Vec<GpuSpec> = vec![T4.clone(); 4];
    let hetero: Vec<GpuSpec> = vec![V100.clone(), V100.clone(), T4.clone(), T4.clone()];
    let scenarios: [(&str, &Vec<GpuSpec>, RoutingPolicy); 3] = [
        ("placed/ffd+rr_4xT4", &t4x4, RoutingPolicy::RoundRobin),
        ("placed/ffd+jsq_4xT4", &t4x4, RoutingPolicy::JoinShortestQueue),
        ("placed/ffd+jsq_2xV100+2xT4", &hetero, RoutingPolicy::JoinShortestQueue),
    ];
    let mut hetero_jsq = 0.0;
    for (label, gpus, routing) in scenarios {
        let mut total = 0.0;
        bench(label, &cfg, || {
            total = serve_cluster(
                &profiles,
                &rates,
                gpus,
                PlacementPolicy::FirstFitDecreasing,
                routing,
                GpuSched::Dstack,
                reqs.clone(),
                horizon_ms,
                7,
            )
            .total_throughput();
        });
        println!("    -> total {total:.0} req/s");
        if label.ends_with("2xV100+2xT4") {
            hetero_jsq = total;
        }
    }

    println!(
        "acceptance: hetero ffd+jsq {hetero_jsq:.0} req/s vs legacy DstackAll RR {legacy_dstack:.0} req/s \
         ({:.2}x)",
        hetero_jsq / legacy_dstack.max(1e-9)
    );
    assert!(
        hetero_jsq >= legacy_dstack,
        "heterogeneous JSQ cluster ({hetero_jsq:.0} req/s) must reach the legacy \
         round-robin DstackAll throughput ({legacy_dstack:.0} req/s)"
    );

    let summary = dstack::bench::write_summary(std::path::Path::new("."), "cluster").unwrap();
    println!("machine-readable summary: {}", summary.display());
}
