//! Fig. 12 benchmark: the 4xT4 cluster simulation across placements.

use dstack::bench::{bench, Bench};
use dstack::cluster::{run_cluster, ClusterPolicy};
use dstack::profile::{by_name, T4};
use dstack::workload::{merged_stream, Arrivals};

fn main() {
    let names = ["mobilenet", "alexnet", "resnet50", "vgg19"];
    let profiles: Vec<_> = names.iter().map(|n| by_name(n).unwrap()).collect();
    let rates = [150.0, 150.0, 900.0, 450.0];
    let specs: Vec<_> = profiles
        .iter()
        .zip(rates)
        .map(|(p, r)| (Arrivals::Poisson { rate: r }, p.slo_ms))
        .collect();
    let reqs = merged_stream(&specs, 2_000.0, 77);
    let cfg = Bench::quick();
    for pol in [ClusterPolicy::Exclusive, ClusterPolicy::TemporalAll, ClusterPolicy::DstackAll] {
        let mut total = 0.0;
        bench(&format!("cluster/{pol:?}"), &cfg, || {
            total = run_cluster(&profiles, &T4, 4, &reqs, 2_000.0, pol).total_throughput();
        });
        println!("    -> total {total:.0} req/s");
    }
}
