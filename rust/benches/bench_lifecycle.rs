//! Long-tail lifecycle benchmarks plus the warmness acceptance
//! comparison: on a 24-model Zipf(1.1) fleet whose weights oversubscribe
//! the resident budget 3×, warmness-aware routing (cold-start penalty
//! folded into the JSQ cost) must achieve at least the goodput of
//! warm-oblivious JSQ at no worse an SLO miss rate — spilling a request
//! to a cold replica pays a weight upload that dwarfs every SLO, and
//! evicts a warm model to do it.

use dstack::bench::{bench, Bench};
use dstack::cluster::{GpuSched, PlacementPolicy, RoutingPolicy};
use dstack::lifecycle::{longtail_gpus, longtail_workload, serve_longtail, LifecycleCfg};

fn main() {
    let horizon_ms = 4_000.0;
    let seed = 77;
    let (profiles, rates, reqs) = longtail_workload(24, 1.1, 600.0, horizon_ms, seed);
    let gpus = longtail_gpus();
    let cfg = Bench::quick();
    let base = LifecycleCfg { mem_budget_mib: 4_096, ..Default::default() };

    let mut run = |label: &str, warm: bool| {
        let lcfg = LifecycleCfg { warm_routing: warm, ..base.clone() };
        let mut goodput = 0.0;
        let mut viol = 0.0;
        let mut cold = 0;
        let mut evictions = 0;
        bench(label, &cfg, || {
            let r = serve_longtail(
                &profiles,
                &rates,
                &gpus,
                PlacementPolicy::LoadBalance,
                RoutingPolicy::JoinShortestQueue,
                GpuSched::Dstack,
                &lcfg,
                reqs.clone(),
                horizon_ms,
                seed,
            );
            let stats = r.lifecycle.as_ref().expect("lifecycle stats");
            goodput = stats.goodput_rps;
            cold = stats.cold_starts;
            evictions = stats.evictions;
            viol = r.violations_per_sec.iter().sum();
        });
        println!(
            "    -> goodput {goodput:.0} req/s in SLO, {viol:.0} viol/s, \
             {cold} cold starts, {evictions} evictions"
        );
        (goodput, viol)
    };

    let (oblivious_goodput, oblivious_viol) = run("lifecycle/warm_oblivious_jsq", false);
    let (warm_goodput, warm_viol) = run("lifecycle/warmness_aware_jsq", true);

    let summary = dstack::bench::write_summary(std::path::Path::new("."), "lifecycle").unwrap();
    println!("machine-readable summary: {}", summary.display());

    println!(
        "acceptance: warmness-aware {warm_goodput:.0} req/s goodput vs warm-oblivious \
         {oblivious_goodput:.0} req/s ({:.2}x), viol/s {warm_viol:.0} vs {oblivious_viol:.0}",
        warm_goodput / oblivious_goodput.max(1e-9)
    );
    assert!(
        warm_goodput >= oblivious_goodput,
        "warmness-aware routing ({warm_goodput:.0} req/s goodput) must reach warm-oblivious \
         JSQ ({oblivious_goodput:.0} req/s) on the long-tail fleet"
    );
    assert!(
        warm_viol <= oblivious_viol + 1e-9,
        "warmness-aware routing must not miss more SLOs ({warm_viol:.2}/s) than \
         warm-oblivious JSQ ({oblivious_viol:.2}/s)"
    );
}
