//! Table 1 benchmark: wall-clock cost of simulating the Triton vs
//! D-STACK task-completion experiment, plus the regenerated metric.

use dstack::bench::{bench, Bench};
use dstack::figures;

fn main() {
    // The actual experiment (also validates the metric each iteration).
    let cfg = Bench::quick();
    let mut last = (0.0, 0.0);
    bench("table1/full_experiment", &cfg, || {
        let d = figures::table1();
        let triton: f64 = d.rows[0][1].parse().unwrap();
        let dstack: f64 = d.rows[1][1].parse().unwrap();
        last = (triton, dstack);
    });
    println!(
        "table1 result: triton {:.1}s dstack {:.1}s ({:.0}% reduction; paper: 58.6 -> 35.6, 37%)",
        last.0,
        last.1,
        (1.0 - last.1 / last.0) * 100.0
    );

    let summary = dstack::bench::write_summary(std::path::Path::new("."), "table1").unwrap();
    println!("machine-readable summary: {}", summary.display());
}
