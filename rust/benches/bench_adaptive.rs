//! Control-plane benchmarks on the drifting-rate workload, plus the
//! adaptive acceptance comparison: on a trace whose hot models swap
//! halfway through the run, the adaptive control plane must serve
//! strictly more than the static peak-rate placement while violating
//! SLOs no more often — the whole point of re-optimizing at runtime.

use dstack::bench::{bench, Bench};
use dstack::cluster::{serve_cluster, GpuSched, PlacementPolicy, RoutingPolicy};
use dstack::controlplane::{drift_gpus, drift_workload, run_adaptive, AdaptiveCfg};

fn main() {
    let horizon_ms = 4_000.0;
    let seed = 77;
    let (profiles, initial, peak, reqs) = drift_workload(horizon_ms, seed);
    let gpus = drift_gpus();
    let cfg = Bench::quick();
    let acfg = AdaptiveCfg { interval_ms: 250.0, ..Default::default() };

    let mut static_total = 0.0;
    let mut static_viol = 0.0;
    bench("adaptive/static_peak_placement", &cfg, || {
        let r = serve_cluster(
            &profiles,
            &peak,
            &gpus,
            PlacementPolicy::FirstFitDecreasing,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            reqs.clone(),
            horizon_ms,
            seed,
        );
        static_total = r.total_throughput();
        static_viol = r.violations_per_sec.iter().sum();
    });
    println!("    -> total {static_total:.0} req/s, {static_viol:.0} viol/s");

    let mut adaptive_total = 0.0;
    let mut adaptive_viol = 0.0;
    let mut rebalances = 0;
    bench("adaptive/control_plane", &cfg, || {
        let r = run_adaptive(
            &profiles,
            &initial,
            &gpus,
            PlacementPolicy::FirstFitDecreasing,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            &acfg,
            reqs.clone(),
            horizon_ms,
            seed,
        );
        adaptive_total = r.total_throughput();
        adaptive_viol = r.violations_per_sec.iter().sum();
        rebalances = r.adaptive.as_ref().map_or(0, |a| a.rebalances);
    });
    println!(
        "    -> total {adaptive_total:.0} req/s, {adaptive_viol:.0} viol/s, {rebalances} rebalances"
    );

    println!(
        "acceptance: adaptive {adaptive_total:.0} req/s vs static-peak {static_total:.0} req/s \
         ({:.2}x), viol/s {adaptive_viol:.0} vs {static_viol:.0}",
        adaptive_total / static_total.max(1e-9)
    );
    assert!(
        adaptive_total > static_total,
        "adaptive ({adaptive_total:.0} req/s) must beat the static peak-rate placement \
         ({static_total:.0} req/s) on the drifting trace"
    );
    assert!(
        adaptive_viol <= static_viol,
        "adaptive must not violate more SLOs ({adaptive_viol:.0}/s) than static \
         ({static_viol:.0}/s)"
    );

    let summary = dstack::bench::write_summary(std::path::Path::new("."), "adaptive").unwrap();
    println!("machine-readable summary: {}", summary.display());
}
