//! Fig. 11a benchmark: the C-2/3/4/7 multiplexing sweep across all five
//! policies — end-to-end simulation cost per mix, plus headline output.

use dstack::bench::{bench, Bench};
use dstack::config::{build_policy, PolicyKind};
use dstack::profile::by_name;
use dstack::sim::{entries_at_optimum, Sim, SimConfig};
use dstack::workload::{fig11a_rates, merged_stream, Arrivals};

fn run_mix(mix: &str, kind: PolicyKind, horizon_ms: f64) -> (f64, f64) {
    let spec = fig11a_rates(mix);
    let profiles: Vec<_> = spec.iter().map(|(n, _)| by_name(n).unwrap()).collect();
    let entries = entries_at_optimum(&profiles);
    let specs: Vec<_> = spec
        .iter()
        .zip(&profiles)
        .map(|((_, r), p)| (Arrivals::Poisson { rate: *r }, p.slo_ms))
        .collect();
    let reqs = merged_stream(&specs, horizon_ms, 21);
    let mut pol = build_policy(kind, &entries);
    let cfg = SimConfig {
        horizon_ms,
        allow_oversub: kind == PolicyKind::FixedBatch,
        ..Default::default()
    };
    let mut sim = Sim::new(cfg, entries);
    let rep = sim.run(pol.as_mut(), &reqs);
    (rep.total_throughput(), rep.violation_fraction())
}

fn main() {
    let cfg = Bench::quick();
    for mix in ["C-2", "C-4", "C-7"] {
        for kind in [PolicyKind::Temporal, PolicyKind::Dstack] {
            let mut out = (0.0, 0.0);
            bench(&format!("multiplex/{mix}/{}", kind.name()), &cfg, || {
                out = run_mix(mix, kind, 2_000.0);
            });
            println!("    -> thpt {:.0} req/s, viol {:.3}", out.0, out.1);
        }
    }

    let summary = dstack::bench::write_summary(std::path::Path::new("."), "multiplex").unwrap();
    println!("machine-readable summary: {}", summary.display());
}
