//! Wall-clock of the cluster execution core on a 16-GPU Zipf fleet —
//! the workload class the ROADMAP names as the bottleneck for 10+ GPU
//! sweeps. Two cases:
//!
//! **Quantized** (2 ms ingress ticks, JSQ): a batched front-end hands
//! the cluster its accepted requests once per tick, so barriers are
//! *fat* — every one routes a burst touching most engines — and the
//! worker-pool fan-out is what pays. Asserts serial-vs-parallel
//! byte-identity and (on multi-core hosts) parallel speedup > 1.0.
//!
//! **Un-quantized** (raw Poisson arrivals, Zipf(1.1), RR): every
//! arrival is its own barrier, the epoch loop's worst case — one epoch
//! per request and an O(GPUs) scan each time, O(G·R) coordination for
//! engine-local work. The sparse core routes the same stream through
//! per-engine lookahead + barrier elision (whole inter-event spans
//! batched into timestamped injection rounds). Asserts epoch-vs-sparse
//! byte-identity and (on multi-core hosts) sparse wall-clock ≤ epoch
//! wall-clock, and records the sparse-vs-epoch speedup plus the
//! barrier-elision ratio in `BENCH_parallel.json` for the CI summary.
//!
//! **Unified** (drifting Zipf(1.1) popularity, RR, full-device memory):
//! the unified control plane replans mid-flight (drift-triggered
//! replica surgery at tick barriers) while the warm span between
//! control events stays elidable — the proof that lifecycle-style
//! drivers ride the sparse fast path instead of falling back to
//! per-arrival epoch barriers. Asserts epoch-vs-sparse byte-identity
//! and `barriers_elided > 0` across replans.

use dstack::bench::Bench;
use dstack::cluster::{
    place, run_placement_with, ExecMode, ExecOpts, GpuSched, Parallelism, PlacementPolicy,
    RoutingPolicy,
};
use dstack::lifecycle::{longtail_workload, LifecycleCfg};
use dstack::profile::{GpuSpec, V100};
use dstack::unified::{drifting_longtail_workload, run_unified_with, unified_gpus, UnifiedCfg};
use dstack::util::json::Json;
use dstack::workload::Request;
use std::time::Duration;

const N_GPUS: usize = 16;
const N_MODELS: usize = 32;

fn fleet(
    alpha: f64,
    total_rps: f64,
    horizon_ms: f64,
    seed: u64,
) -> (Vec<dstack::profile::ModelProfile>, Vec<GpuSpec>, dstack::cluster::Placement, Vec<Request>)
{
    let (profiles, rates, reqs) = longtail_workload(N_MODELS, alpha, total_rps, horizon_ms, seed);
    let gpus: Vec<GpuSpec> = vec![V100.clone(); N_GPUS];
    let pl = place(&profiles, &rates, &gpus, PlacementPolicy::LoadBalance);
    (profiles, gpus, pl, reqs)
}

fn main() {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    // Best-of-5 minima: robust against transient load on shared CI
    // runners (GitHub-hosted ubuntu runners have 4 vCPUs, which leaves
    // real margin; a loaded 2-core host is the worst case and still
    // measures the minimum over five runs of each mode).
    let cfg = Bench::default()
        .warmup(Duration::from_millis(200))
        .measure(Duration::from_millis(1_500))
        .iters(5, 50);

    // ---- case 1: quantized ingress ticks, JSQ, serial vs parallel ----
    let horizon_ms = 5_000.0;
    const TICK_US: u64 = 2_000;
    let (profiles, gpus, pl, mut reqs) = fleet(0.9, 6_000.0, horizon_ms, 99);
    // Quantize arrivals to the ingress tick (deadlines shift with their
    // arrival so each request keeps its full SLO window).
    for r in reqs.iter_mut() {
        let q = (r.arrival / TICK_US) * TICK_US;
        r.deadline -= r.arrival - q;
        r.arrival = q;
    }
    let hosted: usize = pl.hosted.iter().map(|h| h.len()).sum();
    println!(
        "fleet: {N_MODELS} models ({hosted} replicas) on {N_GPUS}xV100, 6000 req/s, \
         {} requests over {horizon_ms:.0} ms, ingress tick {} ms",
        reqs.len(),
        TICK_US / 1_000
    );
    let run_q = |opts: ExecOpts| {
        run_placement_with(
            &profiles,
            &gpus,
            &pl,
            reqs.clone(),
            horizon_ms,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            7,
            "bench_parallel",
            opts,
        )
    };

    // Determinism first: the parallel report must be byte-identical.
    let a = run_q(ExecOpts::with_threads(Parallelism::Threads(1))).to_json().to_string_compact();
    let b = run_q(ExecOpts::with_threads(Parallelism::Threads(threads)))
        .to_json()
        .to_string_compact();
    assert_eq!(a, b, "threads={threads} report diverged from the serial report");
    println!("determinism: threads=1 and threads={threads} reports are byte-identical");

    let serial = cfg.run("parallel/serial", || {
        dstack::bench::black_box(run_q(ExecOpts::with_threads(Parallelism::Threads(1))));
    });
    let parallel = cfg.run(&format!("parallel/threads={threads}"), || {
        dstack::bench::black_box(run_q(ExecOpts::with_threads(Parallelism::Threads(threads))));
    });

    // Best-of-N: wall-clock minima are the robust speedup statistic.
    let serial_ms = serial.min_ns * 1e-6;
    let parallel_ms = parallel.min_ns * 1e-6;
    let speedup = serial_ms / parallel_ms.max(1e-9);
    println!(
        "serial {serial_ms:.1} ms vs parallel({threads}) {parallel_ms:.1} ms -> {speedup:.2}x"
    );

    // ---- case 2: un-quantized Zipf(1.1) arrivals, RR, epoch vs sparse ----
    let unq_horizon_ms = 4_000.0;
    let (uprofiles, ugpus, upl, ureqs) = fleet(1.1, 6_000.0, unq_horizon_ms, 101);
    println!(
        "un-quantized case: Zipf(1.1), {} raw arrivals over {unq_horizon_ms:.0} ms, RR routing",
        ureqs.len()
    );
    let run_u = |mode: ExecMode| {
        run_placement_with(
            &uprofiles,
            &ugpus,
            &upl,
            ureqs.clone(),
            unq_horizon_ms,
            RoutingPolicy::RoundRobin,
            GpuSched::Dstack,
            7,
            "bench_parallel_unq",
            ExecOpts { threads: Parallelism::Threads(threads), mode, ..Default::default() },
        )
    };
    let epoch_rep = run_u(ExecMode::Epoch);
    let sparse_rep = run_u(ExecMode::Sparse);
    assert_eq!(
        epoch_rep.to_json().to_string_compact(),
        sparse_rep.to_json().to_string_compact(),
        "sparse report diverged from the epoch report"
    );
    println!("determinism: epoch and sparse reports are byte-identical");
    let sparse_stats = sparse_rep.exec.expect("exec stats attached");

    let epoch = cfg.run("parallel/unquantized_epoch", || {
        dstack::bench::black_box(run_u(ExecMode::Epoch));
    });
    let sparse = cfg.run("parallel/unquantized_sparse", || {
        dstack::bench::black_box(run_u(ExecMode::Sparse));
    });
    let epoch_ms = epoch.min_ns * 1e-6;
    let sparse_ms = sparse.min_ns * 1e-6;
    let sparse_speedup = epoch_ms / sparse_ms.max(1e-9);
    println!(
        "un-quantized: epoch {epoch_ms:.1} ms vs sparse {sparse_ms:.1} ms -> \
         {sparse_speedup:.2}x ({} of {} barriers elided, {:.0}%, max lookahead {:.1} ms)",
        sparse_stats.barriers_elided,
        sparse_stats.barriers_elided + sparse_stats.epochs,
        sparse_stats.elision_ratio() * 100.0,
        sparse_stats.max_lookahead_us as f64 / 1_000.0
    );

    // ---- case 3: unified control plane, RR, drift replans mid-span ----
    // Full-device budgets keep every replica warm at t=0, so the warm
    // span is elidable from the first arrival; the popularity rotation
    // then forces drift replans whose replica surgery lands at tick
    // barriers *inside* the elided stream.
    let uni_horizon_ms = 4_000.0;
    let (nprofiles, nrates, nreqs) =
        drifting_longtail_workload(N_MODELS, 1.1, 6_000.0, uni_horizon_ms, 103);
    let ngpus = unified_gpus(N_GPUS);
    let ucfg = UnifiedCfg {
        lifecycle: LifecycleCfg {
            mem_budget_mib: 0, // full device: the whole fleet stays resident
            idle_timeout_ms: 0.0,
            min_replicas: 1,
            ..Default::default()
        },
        ..Default::default()
    };
    println!(
        "unified case: drifting Zipf(1.1), {} raw arrivals over {uni_horizon_ms:.0} ms, \
         RR routing, full-device residency",
        nreqs.len()
    );
    let run_uni = |mode: ExecMode| {
        run_unified_with(
            &nprofiles,
            &nrates,
            &ngpus,
            PlacementPolicy::LoadBalance,
            RoutingPolicy::RoundRobin,
            GpuSched::Dstack,
            &ucfg,
            nreqs.clone(),
            uni_horizon_ms,
            103,
            ExecOpts { threads: Parallelism::Threads(threads), mode, ..Default::default() },
        )
    };
    let uni_epoch_rep = run_uni(ExecMode::Epoch);
    let uni_sparse_rep = run_uni(ExecMode::Sparse);
    assert_eq!(
        uni_epoch_rep.to_json().to_string_compact(),
        uni_sparse_rep.to_json().to_string_compact(),
        "unified sparse report diverged from the epoch report"
    );
    println!("determinism: unified epoch and sparse reports are byte-identical");
    let uni_stats = uni_sparse_rep.exec.expect("exec stats attached");
    let uni_replans = uni_sparse_rep.adaptive.as_ref().map_or(0, |a| a.replans);
    assert!(
        uni_stats.barriers_elided > 0,
        "unified driver fell back to per-arrival epoch barriers: {uni_stats:?}"
    );
    assert!(uni_replans > 0, "popularity rotation triggered no replans");

    let uni_epoch = cfg.run("parallel/unified_epoch", || {
        dstack::bench::black_box(run_uni(ExecMode::Epoch));
    });
    let uni_sparse = cfg.run("parallel/unified_sparse", || {
        dstack::bench::black_box(run_uni(ExecMode::Sparse));
    });
    let uni_epoch_ms = uni_epoch.min_ns * 1e-6;
    let uni_sparse_ms = uni_sparse.min_ns * 1e-6;
    let uni_speedup = uni_epoch_ms / uni_sparse_ms.max(1e-9);
    println!(
        "unified: epoch {uni_epoch_ms:.1} ms vs sparse {uni_sparse_ms:.1} ms -> \
         {uni_speedup:.2}x ({} barriers elided across {} replans, {:.0}% elision)",
        uni_stats.barriers_elided,
        uni_replans,
        uni_stats.elision_ratio() * 100.0
    );

    let json = Json::obj(vec![
        ("bench", Json::from("parallel")),
        ("gpus", Json::from(N_GPUS as u64)),
        ("models", Json::from(N_MODELS as u64)),
        ("requests", Json::from(reqs.len() as u64)),
        ("threads", Json::from(threads as u64)),
        ("serial_ms", Json::from(serial_ms)),
        ("parallel_ms", Json::from(parallel_ms)),
        ("speedup", Json::from(speedup)),
        (
            "unquantized",
            Json::obj(vec![
                ("requests", Json::from(ureqs.len() as u64)),
                ("epoch_ms", Json::from(epoch_ms)),
                ("sparse_ms", Json::from(sparse_ms)),
                ("sparse_speedup", Json::from(sparse_speedup)),
                ("elision_ratio", Json::from(sparse_stats.elision_ratio())),
                ("exec", sparse_stats.to_json()),
            ]),
        ),
        (
            "unified",
            Json::obj(vec![
                ("requests", Json::from(nreqs.len() as u64)),
                ("epoch_ms", Json::from(uni_epoch_ms)),
                ("sparse_ms", Json::from(uni_sparse_ms)),
                ("sparse_speedup", Json::from(uni_speedup)),
                ("replans", Json::from(uni_replans)),
                ("elision_ratio", Json::from(uni_stats.elision_ratio())),
                ("exec", uni_stats.to_json()),
            ]),
        ),
        (
            "results",
            Json::Arr(vec![
                serial.to_json(),
                parallel.to_json(),
                epoch.to_json(),
                sparse.to_json(),
                uni_epoch.to_json(),
                uni_sparse.to_json(),
            ]),
        ),
    ]);
    let path = std::path::Path::new("BENCH_parallel.json");
    dstack::util::write_file(path, &json.to_string_pretty()).unwrap();
    println!("machine-readable summary: {}", path.display());

    // Gates. Single-core hosts (CI fallback runners) can't speed up at
    // all; on multi-core hosts the fan-out must beat serial stepping on
    // the quantized fleet, and sparse barriers must not lose to epoch
    // barriers on the un-quantized fleet (elision removes per-arrival
    // coordination entirely, so the margin is wide). A loaded 2-3-core
    // box can't guarantee a strict quantized win over measurement
    // noise, so there that gate is no-material-regression — the JSON
    // summary records the exact ratios either way.
    if threads >= 4 {
        assert!(
            speedup > 1.0,
            "parallel stepping ({parallel_ms:.1} ms on {threads} threads) must beat the \
             serial path ({serial_ms:.1} ms) on a 16-GPU fleet"
        );
    } else if threads > 1 {
        assert!(
            speedup > 0.9,
            "parallel stepping ({parallel_ms:.1} ms on {threads} threads) regressed \
             materially vs serial ({serial_ms:.1} ms)"
        );
    }
    if threads >= 4 {
        assert!(
            sparse_speedup > 1.0,
            "sparse barriers ({sparse_ms:.1} ms) must not lose to epoch barriers \
             ({epoch_ms:.1} ms) on the un-quantized Zipf stream"
        );
    } else if threads > 1 {
        // Same rationale as the quantized gate: a loaded 2-3-core box
        // can't guarantee a strict win over measurement noise.
        assert!(
            sparse_speedup > 0.9,
            "sparse barriers ({sparse_ms:.1} ms) regressed materially vs epoch \
             ({epoch_ms:.1} ms) on the un-quantized Zipf stream"
        );
    }
    if threads > 1 {
        assert!(
            sparse_stats.elision_ratio() > 0.5,
            "RR stream should elide most barriers, got {:.2}",
            sparse_stats.elision_ratio()
        );
    }
}
