//! Serial-vs-parallel wall-clock of the cluster execution core on a
//! 16-GPU Zipf fleet — the workload class the ROADMAP names as the
//! wall-clock bottleneck for 10+ GPU sweeps.
//!
//! Setup: 32 Zipf(0.9)-popular models knee-packed onto 16 V100s and
//! served through `run_placement` with JSQ routing and per-GPU D-STACK
//! schedulers. Arrivals are quantized to a 2 ms ingress tick (a batched
//! front-end handing the cluster its accepted requests once per tick),
//! which is also what makes the epochs of the execution core *fat*:
//! every barrier routes a burst that touches most engines, so the
//! fanned-out stepping has real work per epoch. Un-quantized streams
//! barrier at every single arrival; those epochs fall under the core's
//! fan-out threshold and run inline, so the parallel path degrades to
//! serial instead of losing time to synchronization.
//!
//! Asserts (1) byte-identical reports between `threads = 1` and the
//! parallel run — determinism is the contract that makes the pool safe
//! to default on — and (2) wall-clock speedup > 1.0 whenever the host
//! actually has more than one core. Writes `BENCH_parallel.json` with
//! the headline serial/parallel wall-clock numbers (best-of-N ms) for
//! the perf trajectory CI uploads.

use dstack::bench::Bench;
use dstack::cluster::{
    place, run_placement_with, GpuSched, Parallelism, PlacementPolicy, RoutingPolicy,
};
use dstack::lifecycle::longtail_workload;
use dstack::profile::{GpuSpec, V100};
use dstack::util::json::Json;
use std::time::Duration;

fn main() {
    let horizon_ms = 5_000.0;
    let n_gpus = 16usize;
    let n_models = 32usize;
    let total_rps = 6_000.0;
    const TICK_US: u64 = 2_000;

    let (profiles, rates, mut reqs) =
        longtail_workload(n_models, 0.9, total_rps, horizon_ms, 99);
    // Quantize arrivals to the ingress tick (deadlines shift with their
    // arrival so each request keeps its full SLO window).
    for r in reqs.iter_mut() {
        let q = (r.arrival / TICK_US) * TICK_US;
        r.deadline -= r.arrival - q;
        r.arrival = q;
    }
    let gpus: Vec<GpuSpec> = vec![V100.clone(); n_gpus];
    let pl = place(&profiles, &rates, &gpus, PlacementPolicy::LoadBalance);
    let hosted: usize = pl.hosted.iter().map(|h| h.len()).sum();
    println!(
        "fleet: {n_models} models ({hosted} replicas) on {n_gpus}xV100, {total_rps:.0} req/s, \
         {} requests over {horizon_ms:.0} ms, ingress tick {} ms",
        reqs.len(),
        TICK_US / 1_000
    );

    let run = |threads: Parallelism| {
        run_placement_with(
            &profiles,
            &gpus,
            &pl,
            &reqs,
            horizon_ms,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            7,
            "bench_parallel",
            threads,
        )
    };

    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);

    // Determinism first: the parallel report must be byte-identical.
    let a = run(Parallelism::Threads(1)).to_json().to_string_compact();
    let b = run(Parallelism::Threads(threads)).to_json().to_string_compact();
    assert_eq!(a, b, "threads={threads} report diverged from the serial report");
    println!("determinism: threads=1 and threads={threads} reports are byte-identical");

    // Best-of-5 minima: robust against transient load on shared CI
    // runners (GitHub-hosted ubuntu runners have 4 vCPUs, which leaves
    // real margin; a loaded 2-core host is the worst case and still
    // measures the minimum over five runs of each mode).
    let cfg = Bench::default()
        .warmup(Duration::from_millis(200))
        .measure(Duration::from_millis(1_500))
        .iters(5, 50);
    let serial = cfg.run("parallel/serial", || {
        dstack::bench::black_box(run(Parallelism::Threads(1)));
    });
    let parallel = cfg.run(&format!("parallel/threads={threads}"), || {
        dstack::bench::black_box(run(Parallelism::Threads(threads)));
    });

    // Best-of-N: wall-clock minima are the robust speedup statistic.
    let serial_ms = serial.min_ns * 1e-6;
    let parallel_ms = parallel.min_ns * 1e-6;
    let speedup = serial_ms / parallel_ms.max(1e-9);
    println!(
        "serial {serial_ms:.1} ms vs parallel({threads}) {parallel_ms:.1} ms -> {speedup:.2}x"
    );

    let json = Json::obj(vec![
        ("bench", Json::from("parallel")),
        ("gpus", Json::from(n_gpus as u64)),
        ("models", Json::from(n_models as u64)),
        ("requests", Json::from(reqs.len() as u64)),
        ("threads", Json::from(threads as u64)),
        ("serial_ms", Json::from(serial_ms)),
        ("parallel_ms", Json::from(parallel_ms)),
        ("speedup", Json::from(speedup)),
        ("results", Json::Arr(vec![serial.to_json(), parallel.to_json()])),
    ]);
    let path = std::path::Path::new("BENCH_parallel.json");
    dstack::util::write_file(path, &json.to_string_pretty()).unwrap();
    println!("machine-readable summary: {}", path.display());

    // Single-core hosts (CI fallback runners) can't speed up at all. On
    // hosts with >= 4 cores (GitHub-hosted runners included) the
    // fan-out must strictly beat the serial path on this fleet; a
    // loaded 2-3-core box can't guarantee a strict win over measurement
    // noise, so there the gate is no-material-regression — the JSON
    // summary records the exact ratio either way.
    if threads >= 4 {
        assert!(
            speedup > 1.0,
            "parallel stepping ({parallel_ms:.1} ms on {threads} threads) must beat the \
             serial path ({serial_ms:.1} ms) on a 16-GPU fleet"
        );
    } else if threads > 1 {
        assert!(
            speedup > 0.9,
            "parallel stepping ({parallel_ms:.1} ms on {threads} threads) regressed \
             materially vs serial ({serial_ms:.1} ms)"
        );
    }
}
