//! L3 hot-path microbenchmarks (§Perf): the operations executed per
//! simulated/served event — capacity queries, plan construction, the
//! dynamic pass — plus a full event-loop throughput figure.

use dstack::bench::{bench, black_box, Bench};
use dstack::config::{build_policy, PolicyKind};
use dstack::profile::by_name;
use dstack::sched::CapTimeline;
use dstack::sim::{entries_at_optimum, Sim, SimConfig};
use dstack::workload::{merged_stream, slo_proportional_rates, Arrivals};

fn main() {
    // CapTimeline peak query under a realistic reservation count.
    let mut tl = CapTimeline::new();
    for i in 0..24u64 {
        tl.add(i * 4_000, i * 4_000 + 9_000, 20 + (i % 3) as u32 * 10);
    }
    let cfg = Bench::default().units(1.0);
    bench("hotpath/captimeline_peak", &cfg, || {
        black_box(tl.peak(black_box(37_000), black_box(65_000)));
    });
    bench("hotpath/captimeline_earliest_fit", &cfg, || {
        black_box(tl.earliest_fit(0, 100_000, 8_000, 40, 100));
    });

    // Full-engine throughput: events/s through the D-STACK policy.
    let names = ["mobilenet", "alexnet", "resnet50", "vgg19"];
    let profiles: Vec<_> = names.iter().map(|n| by_name(n).unwrap()).collect();
    let entries = entries_at_optimum(&profiles);
    let slos: Vec<f64> = profiles.iter().map(|p| p.slo_ms).collect();
    let rates = slo_proportional_rates(1_900.0, &slos);
    let specs: Vec<_> = profiles
        .iter()
        .zip(&rates)
        .map(|(p, &r)| (Arrivals::Poisson { rate: r }, p.slo_ms))
        .collect();
    let reqs = merged_stream(&specs, 2_000.0, 7);
    let n_reqs = reqs.len() as f64;
    let cfg = Bench::quick().units(n_reqs);
    bench("hotpath/dstack_2s_c4_sim(requests/s)", &cfg, || {
        let mut pol = build_policy(PolicyKind::Dstack, &entries);
        let mut sim =
            Sim::new(SimConfig { horizon_ms: 2_000.0, ..Default::default() }, entries.clone());
        black_box(sim.run(pol.as_mut(), &reqs));
    });
    bench("hotpath/temporal_2s_c4_sim(requests/s)", &cfg, || {
        let mut pol = build_policy(PolicyKind::Temporal, &entries);
        let mut sim =
            Sim::new(SimConfig { horizon_ms: 2_000.0, ..Default::default() }, entries.clone());
        black_box(sim.run(pol.as_mut(), &reqs));
    });

    let summary = dstack::bench::write_summary(std::path::Path::new("."), "scheduler_hotpath")
        .unwrap();
    println!("machine-readable summary: {}", summary.display());
}
