//! Regeneration cost of the analytic figures (2-8): these exercise the
//! analytic model + optimizer hot paths (latency surface evaluations).

use dstack::bench::{bench, Bench};
use dstack::figures;

fn main() {
    let cfg = Bench::quick();
    bench("figures/fig2_latency_surface", &cfg, || {
        assert!(!figures::fig2().rows.is_empty());
    });
    bench("figures/fig4_analytic_curves", &cfg, || {
        assert!(!figures::fig4ab().rows.is_empty());
    });
    bench("figures/fig7_efficacy_surface", &cfg, || {
        assert!(!figures::fig7().rows.is_empty());
    });
    bench("figures/table6_optimizer", &cfg, || {
        assert!(!figures::table6().rows.is_empty());
    });

    let summary = dstack::bench::write_summary(std::path::Path::new("."), "figures").unwrap();
    println!("machine-readable summary: {}", summary.display());
}
