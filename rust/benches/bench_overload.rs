//! Goodput under a flash crowd: the 4-model zoo mix on 2×V100 + T4
//! takes a 5× spike on resnet50 (3–5 s of 8 s) and is served three
//! ways — shed-only (PR 9 deadline admission, nothing else), retry-only
//! (backoff + breakers, no variants) and full brownout (declared int8
//! variants served when primary admission fails). Acceptance: brownout
//! goodput strictly beats shed-only at a no-worse critical-class
//! SLO-miss rate, with exact request conservation in every run
//! (served — primary or degraded — plus dropped plus each typed reject
//! equals offered). Writes `BENCH_overload.json` for the CI
//! degraded-share/breaker/retry summary.

use dstack::bench::Bench;
use dstack::cluster::{ClusterReport, ExecOpts, GpuSched, PlacementPolicy, RoutingPolicy};
use dstack::faults::ResilienceCfg;
use dstack::overload::{expand_profiles, OverloadCfg, OverloadSpec, VariantMap, VariantSpec};
use dstack::profile::{by_name, ModelProfile, T4, V100};
use dstack::util::json::Json;
use dstack::workload::{merged_stream, Arrivals, MaterializedStream};
use std::time::Duration;

const HORIZON_MS: f64 = 8_000.0;
const SEED: u64 = 42;

fn main() {
    let base: Vec<ModelProfile> = ["resnet50", "mobilenet", "alexnet", "vgg19"]
        .iter()
        .map(|n| by_name(n).unwrap())
        .collect();
    let decls = vec![
        (
            0usize,
            VariantSpec { name: "resnet50_int8".into(), knee_pct: 20, latency_scale: 0.5, mem_mib: 400 },
        ),
        (
            3usize,
            VariantSpec { name: "vgg19_int8".into(), knee_pct: 30, latency_scale: 0.55, mem_mib: 600 },
        ),
    ];
    let (expanded, map) = expand_profiles(&base, &decls).expect("valid variant declarations");
    let specs = vec![
        (
            Arrivals::Flash { base: 300.0, mult: 5.0, spike_start_ms: 3_000.0, spike_ms: 2_000.0 },
            base[0].slo_ms,
        ),
        (Arrivals::Poisson { rate: 400.0 }, base[1].slo_ms),
        (Arrivals::Poisson { rate: 300.0 }, base[2].slo_ms),
        (Arrivals::Poisson { rate: 160.0 }, base[3].slo_ms),
    ];
    let reqs = merged_stream(&specs, HORIZON_MS, SEED);
    let offered: u64 = reqs.len() as u64;
    let base_rates = vec![300.0, 400.0, 300.0, 160.0];
    let mut exp_rates = base_rates.clone();
    exp_rates.resize(expanded.len(), 0.0);
    let gpus = [V100.clone(), V100.clone(), T4.clone()];
    let fcfg = ResilienceCfg {
        admission: true,
        hedge: false,
        bulk_models: vec!["vgg19".into()],
        ..Default::default()
    };
    println!(
        "flash crowd: {} requests over {HORIZON_MS:.0} ms on 2xV100+T4; \
         resnet50 spikes 5x over 3000-5000 ms",
        reqs.len()
    );

    let run = |profiles: &[ModelProfile], rates: &[f64], ovl: Option<&OverloadSpec>| {
        dstack::cluster::serve_cluster_stream_overload(
            profiles,
            rates,
            &gpus,
            PlacementPolicy::LoadBalance,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            MaterializedStream::new(reqs.clone(), profiles.len()),
            HORIZON_MS,
            SEED,
            ExecOpts::default(),
            Some(&fcfg),
            ovl,
        )
    };

    let ocfg = OverloadCfg { max_retries: 2, breaker_k: 8, ..Default::default() };
    let brown_spec = OverloadSpec { cfg: ocfg.clone(), map: map.clone() };
    let retry_spec = OverloadSpec {
        cfg: OverloadCfg { brownout: false, ..ocfg },
        map: VariantMap::trivial(base.len()),
    };

    let shed = run(&base, &base_rates, None);
    let retry = run(&base, &base_rates, Some(&retry_spec));
    let brown = run(&expanded, &exp_rates, Some(&brown_spec));

    // Exact conservation: every offered request is served (on its
    // primary or a degraded variant), dropped at the horizon, or a
    // typed reject — nothing lost, nothing double-counted.
    let conserved = |rep: &ClusterReport, label: &str| {
        let acc: u64 = (0..rep.served.len())
            .map(|m| rep.served[m] + rep.dropped[m] + rep.rejected[m])
            .sum();
        assert_eq!(acc, offered, "{label}: conservation violated");
    };
    conserved(&shed, "shed");
    conserved(&retry, "retry");
    conserved(&brown, "brownout");
    // Typed-reject exactness: shed-only rejects are all deadline or
    // unroutable; with retries armed they are all retry_exhausted.
    let shed_res = shed.resilience.as_ref().expect("resilience stats");
    assert_eq!(
        shed.rejected.iter().sum::<u64>(),
        shed_res.deadline_rejects_critical
            + shed_res.deadline_rejects_bulk
            + shed_res.unroutable_rejects,
        "shed-only rejects must all carry a deadline/unroutable type"
    );
    for (rep, label) in [(&retry, "retry"), (&brown, "brownout")] {
        let o = rep.overload.as_ref().expect("overload stats");
        assert_eq!(
            rep.rejected.iter().sum::<u64>(),
            o.retry_exhausted_total(),
            "{label}: with retries armed every terminal reject is retry_exhausted"
        );
    }

    let horizon_s = HORIZON_MS / 1_000.0;
    let goodput = |rep: &ClusterReport| {
        rep.served.iter().sum::<u64>() as f64 / horizon_s
            - rep.violations_per_sec.iter().sum::<f64>()
    };
    // Critical-class miss rate: violations per served request over the
    // non-bulk families (everything but vgg19 and its variant).
    let crit_miss_rate = |rep: &ClusterReport, profiles: &[ModelProfile]| {
        let (mut viol, mut served) = (0.0f64, 0u64);
        for m in 0..profiles.len() {
            if profiles[m].name.starts_with("vgg19") {
                continue;
            }
            viol += rep.violations_per_sec[m];
            served += rep.served[m];
        }
        viol * horizon_s / served.max(1) as f64
    };
    let (sg, rg, bg) = (goodput(&shed), goodput(&retry), goodput(&brown));
    let (sm, bm) = (crit_miss_rate(&shed, &base), crit_miss_rate(&brown, &expanded));
    let bo = brown.overload.as_ref().unwrap();
    let ro = retry.overload.as_ref().unwrap();
    let degraded_share_pct =
        100.0 * bo.degraded_served_total() as f64 / brown.served.iter().sum::<u64>().max(1) as f64;
    let retry_success_pct =
        100.0 * bo.retries_succeeded as f64 / bo.retries_scheduled.max(1) as f64;
    println!(
        "shed-only: {sg:.0} req/s goodput, crit miss rate {:.4}",
        sm
    );
    println!(
        "retry-only: {rg:.0} req/s goodput, {} retries ({} served), {} breaker trips",
        ro.retries_scheduled, ro.retries_succeeded, ro.breaker_trips
    );
    println!(
        "brownout:  {bg:.0} req/s goodput, crit miss rate {bm:.4}, \
         {} degraded served ({degraded_share_pct:.1}% of served), retry success {retry_success_pct:.0}%",
        bo.degraded_served_total()
    );

    // Wall-clock cost of each front door through the flash.
    let cfg = Bench::default()
        .warmup(Duration::from_millis(200))
        .measure(Duration::from_millis(1_200))
        .iters(5, 50);
    let shed_r = cfg.run("overload/shed_only", || {
        dstack::bench::black_box(run(&base, &base_rates, None));
    });
    let retry_r = cfg.run("overload/retry_breaker", || {
        dstack::bench::black_box(run(&base, &base_rates, Some(&retry_spec)));
    });
    let brown_r = cfg.run("overload/brownout", || {
        dstack::bench::black_box(run(&expanded, &exp_rates, Some(&brown_spec)));
    });
    let (shed_ms, retry_ms, brown_ms) =
        (shed_r.min_ns * 1e-6, retry_r.min_ns * 1e-6, brown_r.min_ns * 1e-6);
    println!(
        "wall-clock: shed {shed_ms:.1} ms, retry {retry_ms:.1} ms, brownout {brown_ms:.1} ms"
    );

    let side = |rep: &ClusterReport, wall_ms: f64, profiles: &[ModelProfile]| {
        let mut pairs = vec![
            ("goodput_rps", Json::from(goodput(rep))),
            ("crit_miss_rate", Json::from(crit_miss_rate(rep, profiles))),
            ("served", Json::from(rep.served.iter().sum::<u64>())),
            ("rejected", Json::from(rep.rejected.iter().sum::<u64>())),
            ("wall_ms", Json::from(wall_ms)),
        ];
        if let Some(o) = &rep.overload {
            pairs.push(("overload", o.to_json()));
        }
        Json::obj(pairs)
    };
    let json = Json::obj(vec![
        ("bench", Json::from("overload")),
        ("requests", Json::from(offered)),
        ("horizon_ms", Json::from(HORIZON_MS)),
        ("shed", side(&shed, shed_ms, &base)),
        ("retry", side(&retry, retry_ms, &base)),
        ("brownout", side(&brown, brown_ms, &expanded)),
        ("goodput_gain", Json::from(bg / sg.max(1e-9))),
        ("degraded_share_pct", Json::from(degraded_share_pct)),
        ("breaker_trips", Json::from(bo.breaker_trips)),
        ("retry_success_pct", Json::from(retry_success_pct)),
        (
            "results",
            Json::Arr(vec![shed_r.to_json(), retry_r.to_json(), brown_r.to_json()]),
        ),
    ]);
    let path = std::path::Path::new("BENCH_overload.json");
    dstack::util::write_file(path, &json.to_string_pretty()).unwrap();
    println!("machine-readable summary: {}", path.display());

    // Gates: brownout must convert shed capacity into degraded-served
    // goodput without trading critical-class SLO misses for it.
    assert!(
        bo.degraded_served_total() > 0,
        "the flash must push requests onto the declared variants"
    );
    assert!(
        bg > sg,
        "brownout goodput ({bg:.0} req/s) must strictly beat shed-only ({sg:.0} req/s) \
         through the flash window"
    );
    assert!(
        bm <= sm + 1e-9,
        "brownout must not raise the critical-class miss rate ({bm:.4} vs shed {sm:.4})"
    );
}
