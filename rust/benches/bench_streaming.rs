//! Memory flatness of the streaming arrival path: the cluster core
//! pulls requests from a lazy [`dstack::workload::MergedStream`], so
//! resident workload state is O(backlog) — per-model generator heads
//! plus at most one elision chunk — no matter how many requests the
//! horizon holds. This bench drives the Fig. 12 model mix on 4×T4
//! (RR routing, sparse barriers) at growing request counts up to 10⁷
//! (`DSTACK_STREAM_REQUESTS` overrides) and records the execution
//! core's peak-RSS proxy, `peak_in_flight` — the maximum number of
//! requests buffered anywhere between generator and engines:
//!
//! - **equivalence**: at the smallest size, the streamed report is
//!   byte-identical to the fully materialized `Vec<Request>` path;
//! - **flatness**: `peak_in_flight` stays bounded by a constant
//!   (≤ elision chunk + merge heads) across a 100× size sweep —
//!   under 1% of the total at 10⁶ and under 0.1% at 10⁷ — while a
//!   materialized run would hold every request at once;
//! - **observability**: a disabled recorder attaches nothing and an
//!   enabled one (default knobs) leaves the report bytes untouched;
//!   with sampling + bounded histograms at the 10⁶-request tier the
//!   kept-event count and per-window bucket count stay flat while
//!   candidates scale with the workload, and the recorder's wall-clock
//!   overhead is measured for the CI job summary.
//!
//! Results land in `BENCH_streaming.json` for the CI job summary.

use dstack::cluster::{
    fig12_specs, serve_cluster_stream, serve_cluster_with, ExecMode, ExecOpts, GpuSched,
    Parallelism, PlacementPolicy, RoutingPolicy,
};
use dstack::profile::{GpuSpec, T4};
use dstack::util::json::Json;
use dstack::workload::{merged_stream, Arrivals, MergedStream};
use std::time::Instant;

const SEED: u64 = 77;

fn main() {
    let threads = std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1);
    let opts = ExecOpts {
        threads: Parallelism::Threads(threads),
        mode: ExecMode::Sparse,
        ..Default::default()
    };
    let target: u64 = std::env::var("DSTACK_STREAM_REQUESTS")
        .ok()
        .and_then(|s| s.parse().ok())
        .unwrap_or(10_000_000);

    let (profiles, rates, specs) = fig12_specs();
    let total_rps: f64 = rates.iter().sum();
    let gpus: Vec<GpuSpec> = vec![T4.clone(); 4];
    // Scale the horizon so the Poisson mix offers ~`n` requests.
    let horizon_for = |n: u64| (n as f64 / total_rps) * 1_000.0;

    let run_streamed = |specs: &[(Arrivals, f64)], horizon_ms: f64, o: ExecOpts| {
        let stream = MergedStream::new(specs, horizon_ms, SEED);
        serve_cluster_stream(
            &profiles,
            &rates,
            &gpus,
            PlacementPolicy::FirstFitDecreasing,
            RoutingPolicy::RoundRobin,
            GpuSched::Dstack,
            stream,
            horizon_ms,
            SEED,
            o,
        )
    };

    // ---- equivalence: streamed vs materialized, byte-identical ----
    let eq_horizon = horizon_for(target.min(100_000));
    let streamed = run_streamed(&specs, eq_horizon, opts);
    let reqs = merged_stream(&specs, eq_horizon, SEED);
    let n_eq = reqs.len();
    let materialized = serve_cluster_with(
        &profiles,
        &rates,
        &gpus,
        PlacementPolicy::FirstFitDecreasing,
        RoutingPolicy::RoundRobin,
        GpuSched::Dstack,
        reqs,
        eq_horizon,
        SEED,
        opts,
    );
    assert_eq!(
        streamed.to_json().to_string_compact(),
        materialized.to_json().to_string_compact(),
        "streamed report diverged from the materialized report"
    );
    println!("determinism: streamed and materialized reports are byte-identical ({n_eq} requests)");

    // ---- flatness: peak_in_flight across a 100x size sweep ----
    // The sparse core buffers at most one elision chunk plus the k
    // merge heads at any instant; anything past ~2x that bound means
    // the lazy path silently materialized somewhere.
    const FLAT_BOUND: u64 = 2_048;
    let sizes = [target / 100, target / 10, target];
    let mut sweep = Vec::new();
    for &n in &sizes {
        let horizon_ms = horizon_for(n);
        let t0 = Instant::now();
        let rep = run_streamed(&specs, horizon_ms, opts);
        let wall = t0.elapsed();
        let x = rep.exec.as_ref().expect("exec stats attached");
        let (streamed_n, peak) = (x.requests_streamed, x.peak_in_flight);
        let pct = 100.0 * peak as f64 / streamed_n.max(1) as f64;
        println!(
            "n≈{n}: {streamed_n} requests streamed in {:.1} s ({:.0} req/s sim), \
             peak_in_flight {peak} ({pct:.4}% of total)",
            wall.as_secs_f64(),
            streamed_n as f64 / wall.as_secs_f64().max(1e-9),
        );
        assert!(
            peak <= FLAT_BOUND,
            "peak_in_flight {peak} exceeds the O(1) bound {FLAT_BOUND} at n={n}"
        );
        sweep.push(Json::obj(vec![
            ("target", Json::from(n)),
            ("requests_streamed", Json::from(streamed_n)),
            ("peak_in_flight", Json::from(peak)),
            ("peak_pct_of_total", Json::from(pct)),
            ("wall_s", Json::from(wall.as_secs_f64())),
            ("exec", x.to_json()),
        ]));
    }
    // The headline gate: at the full target the in-flight peak is a
    // vanishing fraction of the workload (flat memory, not O(total)).
    let last = sizes[sizes.len() - 1];
    let peak_last = sweep
        .last()
        .and_then(|j| j.get("peak_in_flight"))
        .and_then(Json::as_u64)
        .expect("sweep recorded");
    assert!(
        (peak_last as f64) < 0.01 * last as f64,
        "peak_in_flight {peak_last} is not < 1% of {last} requests"
    );

    // ---- observability: zero cost off, flat memory on ----
    // Off is the default everywhere above: no payload is attached and
    // (checked at the equivalence size) turning the recorder ON with
    // default knobs does not move a byte of the report either.
    assert!(streamed.obs.is_none(), "recording off must attach no obs payload");
    let obs_default = dstack::obs::ObsCfg { trace: true, timeseries: true, ..Default::default() };
    let traced = run_streamed(&specs, eq_horizon, ExecOpts { obs: obs_default, ..opts });
    assert_eq!(
        streamed.to_json().to_string_compact(),
        traced.to_json().to_string_compact(),
        "enabling the recorder changed the report bytes"
    );
    // Sampled recording at the 10^6-request tier: kept events and
    // histogram buckets stay bounded while candidates scale with the
    // workload — the flat-memory contract for always-on tracing.
    let obs_n = (target / 10).max(100_000);
    let obs_horizon = horizon_for(obs_n);
    let t0 = Instant::now();
    let plain = run_streamed(&specs, obs_horizon, opts);
    let wall_off = t0.elapsed();
    let sampled = dstack::obs::ObsCfg {
        trace: true,
        timeseries: true,
        sample_request: 256,
        sample_gpu: 64,
        exact_latencies: false,
        ..Default::default()
    };
    let t0 = Instant::now();
    let rep = run_streamed(&specs, obs_horizon, ExecOpts { obs: sampled, ..opts });
    let wall_on = t0.elapsed();
    // Counters must not move when exact latency vectors are dropped —
    // only the p99 source changes (histogram, ~1% relative error).
    assert_eq!(plain.served, rep.served, "sampled recording changed served counts");
    assert_eq!(plain.dropped, rep.dropped, "sampled recording changed drop counts");
    let obs = rep.obs.as_ref().expect("recording on");
    let served_total: u64 = rep.served.iter().sum();
    assert!(obs.candidates() > served_total, "recorder witnessed fewer events than completions");
    assert!(
        obs.events_recorded() < obs.candidates() / 32,
        "sampling kept {} of {} candidates — memory is not flat",
        obs.events_recorded(),
        obs.candidates()
    );
    let max_buckets = obs
        .lanes
        .iter()
        .flat_map(|l| l.windows.iter())
        .map(|w| w.lat.n_buckets())
        .max()
        .unwrap_or(0);
    assert!(max_buckets <= 1_000, "window histogram grew {max_buckets} buckets — not log-bounded");
    let overhead_pct =
        100.0 * (wall_on.as_secs_f64() - wall_off.as_secs_f64()) / wall_off.as_secs_f64().max(1e-9);
    println!(
        "observability: n≈{obs_n}: {} events kept of {} candidates ({} windows, \
         ≤{max_buckets} hist buckets/window), recorder overhead {overhead_pct:+.1}%",
        obs.events_recorded(),
        obs.candidates(),
        obs.n_windows(),
    );
    let obs_json = Json::obj(vec![
        ("target", Json::from(obs_n)),
        ("candidates", Json::from(obs.candidates())),
        ("events_recorded", Json::from(obs.events_recorded())),
        ("sampled_out", Json::from(obs.sampled_out())),
        ("n_windows", Json::from(obs.n_windows() as u64)),
        ("max_window_hist_buckets", Json::from(max_buckets as u64)),
        ("wall_off_s", Json::from(wall_off.as_secs_f64())),
        ("wall_on_s", Json::from(wall_on.as_secs_f64())),
        ("overhead_pct", Json::from(overhead_pct)),
    ]);

    let json = Json::obj(vec![
        ("bench", Json::from("streaming")),
        ("models", Json::from(profiles.len() as u64)),
        ("gpus", Json::from(4u64)),
        ("threads", Json::from(threads as u64)),
        ("target_requests", Json::from(target)),
        ("equivalence_requests", Json::from(n_eq as u64)),
        ("flat_bound", Json::from(FLAT_BOUND)),
        ("sweep", Json::Arr(sweep)),
        ("observability", obs_json),
    ]);
    let path = std::path::Path::new("BENCH_streaming.json");
    dstack::util::write_file(path, &json.to_string_pretty()).unwrap();
    println!("machine-readable summary: {}", path.display());
}
