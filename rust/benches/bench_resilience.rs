//! Goodput under engine failure: the canonical 24-model Zipf(1.1)
//! long-tail fleet on 2×V100 loses GPU 1 mid-horizon (degrade at 1.5 s,
//! down at 2.5 s, back at 4 s of 6 s) and is served twice — once behind
//! the resilient front door (cascade re-route of the drained queue +
//! hedged re-dispatch off the degraded engine) and once naive (drained
//! requests rejected, no hedging). Acceptance: hedged+cascade recovery
//! strictly out-goodputs naive at no worse an SLO-miss rate, with zero
//! requests lost or double-served in either run (served + dropped +
//! rejected == offered, per model). A faults-off baseline bounds the
//! fault layer's overhead on the healthy path. Writes
//! `BENCH_resilience.json` for the CI availability/goodput summary.

use dstack::bench::Bench;
use dstack::cluster::{ClusterReport, ExecOpts, GpuSched, PlacementPolicy, RoutingPolicy};
use dstack::faults::{FaultEvent, FaultKind, ResilienceCfg};
use dstack::gpu::ms_to_us;
use dstack::lifecycle::{longtail_gpus, longtail_workload, serve_longtail_stream_faults, LifecycleCfg};
use dstack::util::json::Json;
use dstack::workload::MaterializedStream;
use std::time::Duration;

const N_MODELS: usize = 24;
const TOTAL_RPS: f64 = 600.0;
const HORIZON_MS: f64 = 6_000.0;
const SEED: u64 = 42;

fn main() {
    let (profiles, rates, reqs) = longtail_workload(N_MODELS, 1.1, TOTAL_RPS, HORIZON_MS, SEED);
    let gpus = longtail_gpus();
    let lcfg = LifecycleCfg { mem_budget_mib: 4_096, ..Default::default() };
    let events = vec![
        FaultEvent { t: ms_to_us(1_500.0), gpu: 1, kind: FaultKind::Degraded },
        FaultEvent { t: ms_to_us(2_500.0), gpu: 1, kind: FaultKind::Down },
        FaultEvent { t: ms_to_us(4_000.0), gpu: 1, kind: FaultKind::Up },
    ];
    let mut offered = vec![0u64; profiles.len()];
    for r in &reqs {
        offered[r.model] += 1;
    }
    println!(
        "fleet: {N_MODELS} models on 2xV100, {TOTAL_RPS:.0} req/s, {} requests over \
         {HORIZON_MS:.0} ms; GPU 1 degraded at 1500 ms, down 2500-4000 ms",
        reqs.len()
    );

    let run = |faults: Option<&ResilienceCfg>| {
        serve_longtail_stream_faults(
            &profiles,
            &rates,
            &gpus,
            PlacementPolicy::LoadBalance,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            &lcfg,
            MaterializedStream::new(reqs.clone(), profiles.len()),
            HORIZON_MS,
            SEED,
            ExecOpts::default(),
            faults,
        )
    };
    let conserved = |rep: &ClusterReport, label: &str| {
        for m in 0..offered.len() {
            assert_eq!(
                rep.served[m] + rep.dropped[m] + rep.rejected[m],
                offered[m],
                "{label}: model {m} lost or double-served requests"
            );
        }
    };

    let hedged_cfg = ResilienceCfg { events: events.clone(), ..Default::default() };
    let naive_cfg =
        ResilienceCfg { events, reroute: false, hedge: false, ..Default::default() };

    let hedged = run(Some(&hedged_cfg));
    let naive = run(Some(&naive_cfg));
    conserved(&hedged, "hedged");
    conserved(&naive, "naive");

    let goodput = |rep: &ClusterReport| rep.lifecycle.as_ref().expect("lifecycle stats").goodput_rps;
    let viol = |rep: &ClusterReport| rep.violations_per_sec.iter().sum::<f64>();
    let (hg, ng) = (goodput(&hedged), goodput(&naive));
    let (hv, nv) = (viol(&hedged), viol(&naive));
    let hres = hedged.resilience.as_ref().expect("resilience stats");
    let nres = naive.resilience.as_ref().expect("resilience stats");
    println!(
        "hedged+cascade: {hg:.0} req/s goodput, {hv:.1} viol/s, {} rerouted, \
         {}/{} hedges won, availability {:.2}%",
        hres.rerouted_on_failure, hres.hedges_won, hres.hedges_fired, hres.availability_pct
    );
    println!(
        "naive:          {ng:.0} req/s goodput, {nv:.1} viol/s, {} rerouted, \
         availability {:.2}%",
        nres.rerouted_on_failure, nres.availability_pct
    );

    // Wall-clock: what the fault layer costs, and what each front door
    // costs through the outage.
    let cfg = Bench::default()
        .warmup(Duration::from_millis(200))
        .measure(Duration::from_millis(1_200))
        .iters(5, 50);
    let base_r = cfg.run("resilience/faults_off", || {
        dstack::bench::black_box(run(None));
    });
    let hedged_r = cfg.run("resilience/hedged_cascade", || {
        dstack::bench::black_box(run(Some(&hedged_cfg)));
    });
    let naive_r = cfg.run("resilience/naive", || {
        dstack::bench::black_box(run(Some(&naive_cfg)));
    });
    let (base_ms, hedged_ms, naive_ms) =
        (base_r.min_ns * 1e-6, hedged_r.min_ns * 1e-6, naive_r.min_ns * 1e-6);
    println!(
        "wall-clock: faults off {base_ms:.1} ms, hedged {hedged_ms:.1} ms, naive {naive_ms:.1} ms"
    );

    let side = |rep: &ClusterReport, wall_ms: f64| {
        let res = rep.resilience.as_ref().unwrap();
        Json::obj(vec![
            ("goodput_rps", Json::from(goodput(rep))),
            ("viol_per_sec", Json::from(viol(rep))),
            ("degraded_goodput_rps", Json::from(res.degraded_goodput_rps)),
            ("availability_pct", Json::from(res.availability_pct)),
            ("rerouted_on_failure", Json::from(res.rerouted_on_failure)),
            ("hedges_fired", Json::from(res.hedges_fired)),
            ("hedges_won", Json::from(res.hedges_won)),
            ("unroutable_rejects", Json::from(res.unroutable_rejects)),
            ("wall_ms", Json::from(wall_ms)),
        ])
    };
    let json = Json::obj(vec![
        ("bench", Json::from("resilience")),
        ("models", Json::from(N_MODELS as u64)),
        ("gpus", Json::from(gpus.len() as u64)),
        ("requests", Json::from(reqs.len() as u64)),
        ("horizon_ms", Json::from(HORIZON_MS)),
        ("hedged", side(&hedged, hedged_ms)),
        ("naive", side(&naive, naive_ms)),
        ("faults_off_ms", Json::from(base_ms)),
        ("goodput_gain", Json::from(hg / ng.max(1e-9))),
        (
            "results",
            Json::Arr(vec![base_r.to_json(), hedged_r.to_json(), naive_r.to_json()]),
        ),
    ]);
    let path = std::path::Path::new("BENCH_resilience.json");
    dstack::util::write_file(path, &json.to_string_pretty()).unwrap();
    println!("machine-readable summary: {}", path.display());

    // Gates: the resilient front door must strictly beat the naive one
    // through the outage without trading SLO misses for it, and the
    // cascade must actually engage.
    assert!(hres.rerouted_on_failure > 0, "cascade re-route never engaged");
    assert_eq!(nres.rerouted_on_failure, 0, "naive run must not re-route");
    assert!(
        hg > ng,
        "hedged+cascade goodput ({hg:.0} req/s) must strictly beat naive ({ng:.0} req/s) \
         through the engine-down window"
    );
    assert!(
        hv <= nv + 1e-9,
        "hedged+cascade must not miss more SLOs ({hv:.2}/s) than naive ({nv:.2}/s)"
    );
}
