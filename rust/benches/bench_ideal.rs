//! Fig. 9d benchmark: the ideal kernel-granularity scheduler's slot loop
//! (100 µs slots) and the D-STACK comparison run.

use dstack::bench::{bench, Bench};
use dstack::profile::{convnets, V100};
use dstack::sched::ideal::run_ideal;

fn main() {
    let cfg = Bench::quick().units(10_000.0); // slots per 1 s horizon
    let profiles = convnets();
    bench("ideal/1s_horizon_100us_slots", &cfg, || {
        let rep = run_ideal(&profiles, &V100, 16, 1_000.0, 100);
        assert!(rep.utilization > 0.5);
    });
    let rep = run_ideal(&profiles, &V100, 16, 5_000.0, 100);
    println!(
        "ideal (5s): util {:.1}% thpt {:.0} img/s (paper: ~95% util)",
        rep.utilization * 100.0,
        rep.throughput.iter().sum::<f64>()
    );

    let summary = dstack::bench::write_summary(std::path::Path::new("."), "ideal").unwrap();
    println!("machine-readable summary: {}", summary.display());
}
