//! Deterministic observability: virtual-time event tracing and windowed
//! time-series metrics (DESIGN.md §4.11).
//!
//! Every run used to collapse into end-of-run aggregates
//! ([`crate::metrics::RunReport`] / [`crate::cluster::ClusterReport`]),
//! which hides exactly the phenomena the unified driver exists to
//! manage: drift replans, eviction cascades, cold-start stalls, flash
//! crowds. This module adds a [`Recorder`] that every per-GPU engine
//! ([`crate::sim::Sim`]) and every cluster driver carries, capturing
//!
//! - **request lifecycle events** — arrive → route → enqueue →
//!   complete / drop / reject;
//! - **GPU occupancy spans** — one span per launched batch, with its
//!   deployed GPU% and useful (knee-capped) GPU%;
//! - **control-plane events** — replan, eviction, cold load,
//!   scale-to-zero;
//!
//! into per-lane buffers that [`ObsReport`] merges by the
//! mode-invariant key `(virtual_time, lane, kind, kind_seq)` and
//! exports as Chrome/Perfetto trace-event JSON
//! ([`ObsReport::to_perfetto`], `dstack … --emit-trace`).
//!
//! # Why trace bytes are identical across `exec_mode` × threads
//!
//! The execution core's contract (exec.rs, DESIGN.md §4.7–4.8) is that
//! each engine's *trajectory* — its sequence of injections, launches,
//! completions and drops, each stamped with its own virtual time — is a
//! pure function of the scenario, independent of barrier granularity
//! and thread count. The recorder only ever records at those
//! state-mutation points, never at bare `step_to` calls, so each
//! per-lane buffer holds the same multiset of events in any mode. What
//! *can* differ between modes is the cross-kind interleaving within a
//! buffer (a run-ahead engine drains a completion before a barrier-time
//! injection is recorded; an epoch engine records them in the opposite
//! order). Two consequences:
//!
//! - sampling counters are **per event kind** ([`Recorder`] keeps one
//!   counter per [`EventKind`]), because the per-kind sequence *is*
//!   mode-invariant while the cross-kind record order is not;
//! - the merge key ends with `(kind, kind_seq)`, not buffer position,
//!   so the final sort is independent of record order.
//!
//! Sampling is a deterministic keep-1-in-N per category
//! ([`ObsCfg::sample_request`] / `sample_gpu` / `sample_control`): an
//! event is kept iff `splitmix(seed, kind, kind_seq) % N == 0`. The
//! same seed always keeps the same events, in any mode, at any thread
//! count — that is what `tests/obs_trace.rs` locks.
//!
//! # Windowed time-series
//!
//! With `timeseries` on, the recorder also accumulates fixed
//! virtual-time windows ([`ObsCfg::window_us`] wide) of per-model
//! throughput, queue depth (sampled at window boundaries), SLO misses,
//! drops, per-GPU busy/knee occupancy, a per-window latency histogram,
//! and — on the control lane — replan/eviction/cold-load/scale-to-zero
//! counts plus per-GPU warm-set size. Counter metrics land in the
//! window containing their event time; level metrics (queue depth,
//! warm-set size) are sampled at each window's start boundary, with a
//! mutation at exactly `k·W` counted *after* the `k`-th sample. Events
//! at `t ≥ horizon` (batches draining past the horizon) clamp into the
//! last window. The merged series serializes via
//! [`ObsReport::timeseries_json`] (`--emit-timeseries`) and renders as
//! `figures::fig17`; it is **never** part of
//! [`crate::cluster::ClusterReport::to_json`], so existing report and
//! golden bytes are unchanged whether or not recording is enabled.

use crate::gpu::Us;
use crate::util::json::Json;
use crate::util::stats::LogHistogram;

/// Observability configuration — rides on
/// [`crate::cluster::ExecOpts`] into every driver and on
/// [`crate::sim::SimConfig`] into every engine. All-integer fields so
/// the carrying structs stay `Copy + Eq`.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct ObsCfg {
    /// Record discrete events (the Perfetto trace).
    pub trace: bool,
    /// Accumulate windowed time-series metrics.
    pub timeseries: bool,
    /// Time-series window width in virtual µs (> 0).
    pub window_us: u64,
    /// Keep 1 in N request-lifecycle events (arrive/route/reject/
    /// enqueue/complete/drop). 1 = keep all.
    pub sample_request: u32,
    /// Keep 1 in N GPU occupancy spans (batch launches).
    pub sample_gpu: u32,
    /// Keep 1 in N control-plane events.
    pub sample_control: u32,
    /// Seed of the deterministic sampling hash.
    pub sampling_seed: u64,
    /// Keep the exact per-request latency vectors
    /// (`ModelMetrics::latencies_ms` / `completions_us`). Default
    /// *true* — report bytes and goldens are unchanged. `false` bounds
    /// memory at 10⁷-request scale: quantiles then come from the
    /// ~1%-relative-error [`LogHistogram`] instead.
    pub exact_latencies: bool,
}

impl Default for ObsCfg {
    fn default() -> Self {
        ObsCfg {
            trace: false,
            timeseries: false,
            window_us: 500_000,
            sample_request: 1,
            sample_gpu: 1,
            sample_control: 1,
            sampling_seed: 0,
            exact_latencies: true,
        }
    }
}

impl ObsCfg {
    /// Any event/time-series recording at all? (`exact_latencies` alone
    /// is not recording — it only gates the metrics vectors.)
    pub fn enabled(&self) -> bool {
        self.trace || self.timeseries
    }

    /// Validate invariants shared by config parsing and CLI overlays.
    pub fn validate(&self) -> Result<(), String> {
        if self.window_us == 0 {
            return Err("observability.window_ms must be > 0".into());
        }
        if self.sample_request == 0 || self.sample_gpu == 0 || self.sample_control == 0 {
            return Err("observability sampling rates must be ≥ 1 (keep 1 in N)".into());
        }
        Ok(())
    }
}

/// What happened. Discriminants are the merge tie-break rank.
#[repr(u8)]
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum EventKind {
    /// Request arrived at the front door (driver lane; `a` = request id).
    Arrive = 0,
    /// Router picked a replica (`a` = request id, `b` = target GPU).
    Route = 1,
    /// Admission control turned the request away (`a` = request id).
    Reject = 2,
    /// Request entered an engine queue (`a` = request id).
    Enqueue = 3,
    /// Batch occupancy span (`a` = batch size, `b` = duration µs;
    /// `pct`/`useful` ride in the span payload).
    Batch = 4,
    /// Request completed (`a` = request id, `b` = latency µs).
    Complete = 5,
    /// Request dropped — expired or still queued at the horizon
    /// (`a` = request id).
    Drop = 6,
    /// Control plane re-solved placement (`a` = trigger code).
    Replan = 7,
    /// Model evicted from a GPU's store (`a` = GPU, `b` = MiB freed).
    Evict = 8,
    /// Cold weight load began (`a` = GPU, `b` = load ms).
    ColdLoad = 9,
    /// Idle model scaled to zero (`a` = GPU).
    ScaleZero = 10,
    /// Engine failed or degraded — fault injection ([`crate::faults`]);
    /// `a` = GPU, `b` = 1 for `engine_degraded`, 0 for a hard down.
    EngineDown = 11,
    /// Engine back in service (restore matured; `a` = GPU).
    EngineUp = 12,
    /// Stuck request speculatively re-dispatched off a degraded engine
    /// (`a` = request id, `b` = winning target GPU).
    Hedge = 13,
}

pub(crate) const N_KINDS: usize = 14;

impl EventKind {
    pub fn name(&self) -> &'static str {
        match self {
            EventKind::Arrive => "arrive",
            EventKind::Route => "route",
            EventKind::Reject => "reject",
            EventKind::Enqueue => "enqueue",
            EventKind::Batch => "batch",
            EventKind::Complete => "complete",
            EventKind::Drop => "drop",
            EventKind::Replan => "replan",
            EventKind::Evict => "evict",
            EventKind::ColdLoad => "cold_load",
            EventKind::ScaleZero => "scale_to_zero",
            EventKind::EngineDown => "engine_down",
            EventKind::EngineUp => "engine_up",
            EventKind::Hedge => "hedge",
        }
    }

    /// Sampling/filter category.
    pub fn category(&self) -> Category {
        match self {
            EventKind::Arrive
            | EventKind::Route
            | EventKind::Reject
            | EventKind::Enqueue
            | EventKind::Complete
            | EventKind::Drop => Category::Request,
            EventKind::Batch => Category::Gpu,
            EventKind::Replan
            | EventKind::Evict
            | EventKind::ColdLoad
            | EventKind::ScaleZero
            | EventKind::EngineDown
            | EventKind::EngineUp
            | EventKind::Hedge => Category::Control,
        }
    }
}

/// Event-category filter/sampling domain.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Category {
    Request,
    Gpu,
    Control,
}

impl Category {
    pub fn name(&self) -> &'static str {
        match self {
            Category::Request => "request",
            Category::Gpu => "gpu",
            Category::Control => "control",
        }
    }
}

/// One recorded event. `model` indexes the recording lane's name table
/// ([`EngineObs::names`]); payload semantics per [`EventKind`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Event {
    /// Virtual time (µs).
    pub t: Us,
    pub kind: EventKind,
    /// Lane-local model index (`u32::MAX` = none, e.g. replans).
    pub model: u32,
    /// Per-kind sequence number at record time (pre-sampling) — the
    /// mode-invariant merge tie-break.
    pub kseq: u64,
    pub a: u64,
    pub b: u64,
    /// Deployed GPU% (Batch spans only).
    pub pct: u32,
    /// Useful (knee-capped) GPU% (Batch spans only).
    pub useful: u32,
}

pub(crate) const NO_MODEL: u32 = u32::MAX;

/// One fixed virtual-time bucket of the time-series. Engine lanes fill
/// the request/GPU fields; the control lane fills the control fields.
#[derive(Debug, Clone, Default)]
pub struct Window {
    pub arrivals: u64,
    pub served: u64,
    pub slo_miss: u64,
    pub dropped: u64,
    /// GPU busy µs attributed to this window (span overlap).
    pub busy_us: u64,
    /// Knee-capped useful GPU%·µs attributed to this window — divide by
    /// `100 · window_us` for knee load 0..1.
    pub knee_pct_us: u64,
    /// Backlog (queued + in-flight items) at the window's start
    /// boundary.
    pub queue_depth: u64,
    /// Served counts per lane-local model index.
    pub served_by_model: Vec<u64>,
    /// Latencies (ms) of completions in this window.
    pub lat: LogHistogram,
    pub replans: u64,
    pub evictions: u64,
    pub cold_loads: u64,
    pub scale_zeros: u64,
    /// Warm-set size per GPU at the window's start boundary (control
    /// lane only).
    pub warm_by_gpu: Vec<u64>,
}

/// Boundary-sampling level tracker: `flush(t)` writes the current level
/// into every not-yet-sampled window whose start boundary is ≤ `t`,
/// *before* the mutation at `t` applies — so an event exactly on a
/// boundary lands after that boundary's sample.
#[derive(Debug, Clone, Default)]
struct LevelTrack {
    level: i64,
    /// Next window index whose start boundary still needs a sample.
    /// Window 0's start (t = 0) always samples the initial level.
    next: u64,
}

/// Per-lane deterministic recorder. One lives inside every
/// [`crate::sim::Sim`]; each cluster driver owns one more for the
/// control lane. Cheap when disabled: every hook early-outs on two
/// bools.
#[derive(Debug, Clone)]
pub struct Recorder {
    cfg: ObsCfg,
    horizon: Us,
    n_windows: u64,
    events: Vec<Event>,
    windows: Vec<Window>,
    kind_seq: [u64; N_KINDS],
    sampled_out: u64,
    depth: LevelTrack,
    warm: Vec<LevelTrack>,
}

impl Recorder {
    pub fn new(cfg: ObsCfg, horizon: Us) -> Recorder {
        let n_windows =
            if cfg.enabled() && horizon > 0 { horizon.div_ceil(cfg.window_us.max(1)) } else { 0 };
        Recorder {
            cfg,
            horizon,
            n_windows,
            events: Vec::new(),
            windows: Vec::new(),
            kind_seq: [0; N_KINDS],
            sampled_out: 0,
            depth: LevelTrack::default(),
            warm: Vec::new(),
        }
    }

    /// Disabled singleton — what a `Sim` built without observability
    /// carries. Zero allocations.
    pub fn off() -> Recorder {
        Recorder::new(ObsCfg::default(), 0)
    }

    /// Any recording at all? Hooks guard on this first.
    #[inline]
    pub fn on(&self) -> bool {
        self.cfg.trace || self.cfg.timeseries
    }

    #[inline]
    pub fn cfg(&self) -> &ObsCfg {
        &self.cfg
    }

    fn sample_every(&self, cat: Category) -> u32 {
        match cat {
            Category::Request => self.cfg.sample_request,
            Category::Gpu => self.cfg.sample_gpu,
            Category::Control => self.cfg.sample_control,
        }
    }

    /// Record one event candidate: bump the per-kind counter, apply the
    /// deterministic sampling decision, keep or drop.
    pub fn event(&mut self, kind: EventKind, t: Us, model: u32, a: u64, b: u64) {
        self.span(kind, t, model, a, b, 0, 0)
    }

    /// [`Self::event`] with occupancy payload (Batch spans).
    #[allow(clippy::too_many_arguments)]
    pub fn span(
        &mut self,
        kind: EventKind,
        t: Us,
        model: u32,
        a: u64,
        b: u64,
        pct: u32,
        useful: u32,
    ) {
        if !self.cfg.trace {
            return;
        }
        let kseq = self.kind_seq[kind as usize];
        self.kind_seq[kind as usize] += 1;
        let every = self.sample_every(kind.category());
        if !keep(self.cfg.sampling_seed, kind as u8, kseq, every) {
            self.sampled_out += 1;
            return;
        }
        self.events.push(Event { t, kind, model, kseq, a, b, pct, useful });
    }

    /// Window index for an event at `t` (clamped into the last window
    /// for `t ≥ horizon`); `None` when the series is off or empty.
    fn widx(&self, t: Us) -> Option<usize> {
        if !self.cfg.timeseries || self.n_windows == 0 {
            return None;
        }
        Some(((t / self.cfg.window_us) as usize).min(self.n_windows as usize - 1))
    }

    fn window_mut(&mut self, t: Us) -> Option<&mut Window> {
        let i = self.widx(t)?;
        if self.windows.len() <= i {
            self.windows.resize_with(i + 1, Window::default);
        }
        Some(&mut self.windows[i])
    }

    /// An arrival entered this lane's queues at `t`.
    pub fn count_arrival(&mut self, t: Us) {
        if let Some(w) = self.window_mut(t) {
            w.arrivals += 1;
        }
        self.depth_delta(t, 1);
    }

    /// A request of lane-local `model` completed at `t`.
    pub fn count_completion(&mut self, t: Us, model: usize, lat_ms: f64, in_slo: bool) {
        if let Some(w) = self.window_mut(t) {
            w.served += 1;
            if !in_slo {
                w.slo_miss += 1;
            }
            if w.served_by_model.len() <= model {
                w.served_by_model.resize(model + 1, 0);
            }
            w.served_by_model[model] += 1;
            w.lat.push(lat_ms);
        }
    }

    /// A request was dropped at `t` (expired, or queued at horizon).
    pub fn count_drop(&mut self, t: Us) {
        if let Some(w) = self.window_mut(t) {
            w.dropped += 1;
        }
        self.depth_delta(t, -1);
    }

    /// Attribute a batch occupancy span `[t0, t0 + dur)` with useful
    /// GPU% `useful` across the windows it overlaps, and drop `batch`
    /// items from the backlog level.
    pub fn count_span(&mut self, t0: Us, dur: Us, useful: u32, batch: u32) {
        self.depth_delta(t0, -(batch as i64));
        if !self.cfg.timeseries || self.n_windows == 0 {
            return;
        }
        let wus = self.cfg.window_us;
        let mut t = t0;
        let end = t0 + dur;
        while t < end {
            let i = self.widx(t).expect("timeseries on");
            // Window i covers [i·W, (i+1)·W), except the last, which
            // absorbs everything to `end` (horizon clamp).
            let wend = if i as u64 + 1 >= self.n_windows { end } else { (i as u64 + 1) * wus };
            let overlap = wend.min(end) - t;
            let w = self.window_mut(t).expect("timeseries on");
            w.busy_us += overlap;
            w.knee_pct_us += useful as u64 * overlap;
            t = wend.max(t + 1);
        }
    }

    fn depth_delta(&mut self, t: Us, delta: i64) {
        if !self.cfg.timeseries || self.n_windows == 0 {
            return;
        }
        // Sample every boundary ≤ t before applying the mutation.
        let bound = (t / self.cfg.window_us).min(self.n_windows - 1);
        while self.depth.next <= bound {
            let i = self.depth.next as usize;
            if self.windows.len() <= i {
                self.windows.resize_with(i + 1, Window::default);
            }
            self.windows[i].queue_depth = self.depth.level.max(0) as u64;
            self.depth.next += 1;
        }
        self.depth.level += delta;
    }

    /// Control-lane counter events that also mark the window
    /// (replan/evict/cold-load/scale-to-zero tallies).
    pub fn count_control(&mut self, kind: EventKind, t: Us) {
        if let Some(w) = self.window_mut(t) {
            match kind {
                EventKind::Replan => w.replans += 1,
                EventKind::Evict => w.evictions += 1,
                EventKind::ColdLoad => w.cold_loads += 1,
                EventKind::ScaleZero => w.scale_zeros += 1,
                _ => {}
            }
        }
    }

    /// Set GPU `g`'s warm-set size to `level` at `t` (control lane;
    /// boundary-sampled like queue depth).
    pub fn warm_level(&mut self, g: usize, t: Us, level: u64) {
        if !self.cfg.timeseries || self.n_windows == 0 {
            return;
        }
        if self.warm.len() <= g {
            self.warm.resize_with(g + 1, LevelTrack::default);
        }
        let bound = (t / self.cfg.window_us).min(self.n_windows - 1);
        while self.warm[g].next <= bound {
            let i = self.warm[g].next as usize;
            if self.windows.len() <= i {
                self.windows.resize_with(i + 1, Window::default);
            }
            let w = &mut self.windows[i];
            if w.warm_by_gpu.len() <= g {
                w.warm_by_gpu.resize(g + 1, 0);
            }
            w.warm_by_gpu[g] = self.warm[g].level.max(0) as u64;
            self.warm[g].next += 1;
        }
        self.warm[g].level = level as i64;
    }

    /// Events recorded so far (post-sampling).
    pub fn events_recorded(&self) -> u64 {
        self.events.len() as u64
    }

    /// Flush level tracks through the horizon, pad the window vector to
    /// its full length, and hand the lane's data over. `names` is the
    /// lane's model-index → name table for export.
    pub fn finish(&mut self, names: Vec<String>) -> EngineObs {
        if self.cfg.timeseries && self.n_windows > 0 {
            // Terminal flush: sample every remaining boundary at the
            // final level, then pad.
            let last = self.horizon;
            self.depth_delta(last, 0);
            for g in 0..self.warm.len() {
                let lvl = self.warm[g].level.max(0) as u64;
                self.warm_level(g, last, lvl);
            }
            if self.windows.len() < self.n_windows as usize {
                self.windows.resize_with(self.n_windows as usize, Window::default);
            }
        }
        let candidates: u64 = self.kind_seq.iter().sum();
        EngineObs {
            events: std::mem::take(&mut self.events),
            windows: std::mem::take(&mut self.windows),
            names,
            candidates,
            sampled_out: self.sampled_out,
        }
    }
}

/// Deterministic keep-1-in-N decision (splitmix64 finalizer over
/// `(seed, kind, per-kind seq)` — the per-kind sequence is
/// mode-invariant, see the module docs).
fn keep(seed: u64, kind: u8, seq: u64, every: u32) -> bool {
    if every <= 1 {
        return true;
    }
    let mut x = seed
        ^ (kind as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15)
        ^ seq.wrapping_mul(0xD1B5_4A32_D192_ED03);
    x ^= x >> 30;
    x = x.wrapping_mul(0xBF58_476D_1CE4_E5B9);
    x ^= x >> 27;
    x = x.wrapping_mul(0x94D0_49BB_1331_11EB);
    x ^= x >> 31;
    x % every as u64 == 0
}

/// One lane's finished observability data (an engine's, or the
/// driver's control lane).
#[derive(Debug, Clone, Default)]
pub struct EngineObs {
    pub events: Vec<Event>,
    pub windows: Vec<Window>,
    /// Lane-local model index → model name.
    pub names: Vec<String>,
    /// Event candidates seen (pre-sampling).
    pub candidates: u64,
    /// Candidates dropped by sampling.
    pub sampled_out: u64,
}

/// The run's merged observability report. Rides on
/// [`crate::cluster::ClusterReport::obs`] but — like `ExecStats` — is
/// **never** serialized by `ClusterReport::to_json`, so enabling
/// recording cannot move report or golden bytes.
#[derive(Debug, Clone)]
pub struct ObsReport {
    pub cfg: ObsCfg,
    pub horizon_us: Us,
    /// One lane per GPU (index = GPU index; idle GPUs contribute an
    /// empty lane).
    pub lanes: Vec<EngineObs>,
    /// The driver's control lane (lane id = `lanes.len()` on export).
    pub control: EngineObs,
}

impl ObsReport {
    /// Merge per-lane buffers into one report. Drivers call this after
    /// finalizing engines; returns `None` when recording was off.
    pub fn collect(
        cfg: ObsCfg,
        horizon_us: Us,
        lanes: Vec<EngineObs>,
        control: EngineObs,
    ) -> Option<ObsReport> {
        if !cfg.enabled() {
            return None;
        }
        Some(ObsReport { cfg, horizon_us, lanes, control })
    }

    pub fn events_recorded(&self) -> u64 {
        self.lanes.iter().map(|l| l.events.len() as u64).sum::<u64>()
            + self.control.events.len() as u64
    }

    pub fn candidates(&self) -> u64 {
        self.lanes.iter().map(|l| l.candidates).sum::<u64>() + self.control.candidates
    }

    pub fn sampled_out(&self) -> u64 {
        self.lanes.iter().map(|l| l.sampled_out).sum::<u64>() + self.control.sampled_out
    }

    /// Number of time-series windows (0 when the series is off).
    pub fn n_windows(&self) -> usize {
        self.lanes
            .iter()
            .map(|l| l.windows.len())
            .chain(std::iter::once(self.control.windows.len()))
            .max()
            .unwrap_or(0)
    }

    /// All events across lanes, sorted by the mode-invariant key
    /// `(t, lane, kind, kind_seq)`. The control lane sorts after the
    /// engine lanes (`lane = lanes.len()`).
    pub fn merged_events(&self) -> Vec<(usize, &Event)> {
        let mut all: Vec<(usize, &Event)> = Vec::with_capacity(self.events_recorded() as usize);
        for (lane, l) in self.lanes.iter().enumerate() {
            all.extend(l.events.iter().map(|e| (lane, e)));
        }
        let cl = self.lanes.len();
        all.extend(self.control.events.iter().map(|e| (cl, e)));
        all.sort_unstable_by_key(|(lane, e)| (e.t, *lane, e.kind as u8, e.kseq));
        all
    }

    fn lane_name(&self, lane: usize, model: u32) -> &str {
        if model == NO_MODEL {
            return "";
        }
        let names =
            if lane < self.lanes.len() { &self.lanes[lane].names } else { &self.control.names };
        names.get(model as usize).map(|s| s.as_str()).unwrap_or("")
    }

    /// Chrome/Perfetto trace-event JSON (the `--emit-trace` payload).
    /// Deterministic byte-for-byte: integers only, fixed field order,
    /// events in merged-key order. Load via `chrome://tracing` or
    /// <https://ui.perfetto.dev>.
    pub fn to_perfetto(&self) -> String {
        use std::fmt::Write as _;
        let mut out = String::with_capacity(64 + self.events_recorded() as usize * 96);
        out.push_str("{\"displayTimeUnit\":\"ms\",\"traceEvents\":[");
        let mut first = true;
        for (lane, e) in self.merged_events() {
            if !first {
                out.push(',');
            }
            first = false;
            let name = self.lane_name(lane, e.model);
            let cat = e.kind.category().name();
            match e.kind {
                EventKind::Batch => {
                    let _ = write!(
                        out,
                        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"X\",\"ts\":{},\"dur\":{},\
                         \"pid\":0,\"tid\":{},\"args\":{{\"model\":\"{}\",\"batch\":{},\"pct\":{},\"useful_pct\":{}}}}}",
                        e.kind.name(),
                        cat,
                        e.t,
                        e.b.max(1),
                        lane,
                        name,
                        e.a,
                        e.pct,
                        e.useful
                    );
                }
                _ => {
                    let _ = write!(
                        out,
                        "{{\"name\":\"{}\",\"cat\":\"{}\",\"ph\":\"i\",\"ts\":{},\"pid\":0,\
                         \"tid\":{},\"s\":\"t\",\"args\":{{\"model\":\"{}\",\"a\":{},\"b\":{},\"kseq\":{}}}}}",
                        e.kind.name(),
                        cat,
                        e.t,
                        lane,
                        name,
                        e.a,
                        e.b,
                        e.kseq
                    );
                }
            }
        }
        out.push_str("]}");
        out
    }

    /// Cluster-wide per-window p99 latency (ms); 0 for empty windows.
    pub fn per_window_p99(&self) -> Vec<f64> {
        let n = self.n_windows();
        (0..n)
            .map(|i| {
                let mut h = LogHistogram::default();
                for l in &self.lanes {
                    if let Some(w) = l.windows.get(i) {
                        h.merge(&w.lat);
                    }
                }
                if h.count() == 0 { 0.0 } else { h.quantile(0.99) }
            })
            .collect()
    }

    /// The optional `timeseries` section (`--emit-timeseries`,
    /// `figures::fig17`): merged cluster-wide windows, per-GPU
    /// occupancy, per-model served counts by name, and the control
    /// lane's event tallies. Deterministic (BTreeMap-backed objects).
    pub fn timeseries_json(&self) -> Json {
        let n = self.n_windows();
        let wus = self.cfg.window_us;
        let p99 = self.per_window_p99();
        let mut windows = Vec::with_capacity(n);
        // name → per-window served counts, merged across lanes.
        let mut per_model: std::collections::BTreeMap<String, Vec<u64>> =
            std::collections::BTreeMap::new();
        for i in 0..n {
            let mut arrivals = 0u64;
            let mut served = 0u64;
            let mut slo_miss = 0u64;
            let mut dropped = 0u64;
            let mut depth = 0u64;
            for l in &self.lanes {
                if let Some(w) = l.windows.get(i) {
                    arrivals += w.arrivals;
                    served += w.served;
                    slo_miss += w.slo_miss;
                    dropped += w.dropped;
                    depth += w.queue_depth;
                    for (m, &s) in w.served_by_model.iter().enumerate() {
                        if s > 0 {
                            if let Some(name) = l.names.get(m) {
                                let series =
                                    per_model.entry(name.clone()).or_insert_with(|| vec![0; n]);
                                series[i] += s;
                            }
                        }
                    }
                }
            }
            let cw = self.control.windows.get(i);
            windows.push(Json::obj(vec![
                ("t0_us", Json::from(i as u64 * wus)),
                ("arrivals", Json::from(arrivals)),
                ("served", Json::from(served)),
                ("slo_miss", Json::from(slo_miss)),
                ("dropped", Json::from(dropped)),
                ("queue_depth", Json::from(depth)),
                ("p99_ms", Json::from(p99[i])),
                ("replans", Json::from(cw.map_or(0, |w| w.replans))),
                ("evictions", Json::from(cw.map_or(0, |w| w.evictions))),
                ("cold_loads", Json::from(cw.map_or(0, |w| w.cold_loads))),
                ("scale_zeros", Json::from(cw.map_or(0, |w| w.scale_zeros))),
            ]));
        }
        let per_gpu: Vec<Json> = self
            .lanes
            .iter()
            .enumerate()
            .map(|(g, l)| {
                let util: Vec<f64> = (0..n)
                    .map(|i| {
                        l.windows.get(i).map_or(0.0, |w| w.busy_us as f64 / wus as f64)
                    })
                    .collect();
                let knee: Vec<f64> = (0..n)
                    .map(|i| {
                        l.windows
                            .get(i)
                            .map_or(0.0, |w| w.knee_pct_us as f64 / (100.0 * wus as f64))
                    })
                    .collect();
                let depth: Vec<Json> = (0..n)
                    .map(|i| Json::from(l.windows.get(i).map_or(0, |w| w.queue_depth)))
                    .collect();
                Json::obj(vec![
                    ("gpu", Json::from(g)),
                    ("utilization", Json::arr_f64(&util)),
                    ("knee_load", Json::arr_f64(&knee)),
                    ("queue_depth", Json::Arr(depth)),
                ])
            })
            .collect();
        let warm: Vec<Json> = (0..n)
            .map(|i| {
                let row = self
                    .control
                    .windows
                    .get(i)
                    .map(|w| w.warm_by_gpu.clone())
                    .unwrap_or_default();
                Json::Arr(row.into_iter().map(Json::from).collect())
            })
            .collect();
        let pm: Vec<(String, Json)> = per_model
            .into_iter()
            .map(|(name, series)| {
                (name, Json::Arr(series.into_iter().map(Json::from).collect()))
            })
            .collect();
        Json::obj(vec![
            ("window_us", Json::from(wus)),
            ("n_windows", Json::from(n as u64)),
            ("windows", Json::Arr(windows)),
            ("per_gpu", Json::Arr(per_gpu)),
            ("per_model_served", Json::obj_owned(pm)),
            ("warm_by_gpu", Json::Arr(warm)),
        ])
    }

    /// One-line digest for `--verbose` (never serialized), mirroring
    /// `ExecStats::render`.
    pub fn render(&self) -> String {
        let buckets: usize = self
            .lanes
            .iter()
            .flat_map(|l| l.windows.iter())
            .map(|w| w.lat.n_buckets())
            .sum();
        format!(
            "obs: {} events recorded ({} candidates, {} sampled out), {} windows × {} µs, {} hist buckets",
            self.events_recorded(),
            self.candidates(),
            self.sampled_out(),
            self.n_windows(),
            self.cfg.window_us,
            buckets
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg_all() -> ObsCfg {
        ObsCfg { trace: true, timeseries: true, window_us: 1_000, ..Default::default() }
    }

    #[test]
    fn sampling_is_deterministic_and_thins() {
        let hits = |seed: u64, every: u32| -> Vec<u64> {
            (0..10_000).filter(|&s| keep(seed, 5, s, every)).collect()
        };
        assert_eq!(hits(7, 16), hits(7, 16), "same seed ⇒ same kept set");
        assert_ne!(hits(7, 16), hits(8, 16), "different seed ⇒ different kept set");
        let n = hits(7, 16).len() as f64;
        assert!((n - 625.0).abs() < 200.0, "keep-1-in-16 of 10k ≈ 625, got {n}");
        assert_eq!(hits(7, 1).len(), 10_000, "rate 1 keeps everything");
    }

    #[test]
    fn recorder_off_records_nothing() {
        let mut r = Recorder::off();
        assert!(!r.on());
        r.event(EventKind::Enqueue, 5, 0, 1, 0);
        r.count_arrival(5);
        r.count_completion(9, 0, 1.0, true);
        let o = r.finish(vec!["m".into()]);
        assert!(o.events.is_empty());
        assert!(o.windows.is_empty());
        assert_eq!(o.candidates, 0);
    }

    #[test]
    fn window_boundaries_and_horizon_clamp() {
        let mut r = Recorder::new(cfg_all(), 3_000);
        // Exactly on a boundary → lands in the window it opens.
        r.count_completion(1_000, 0, 2.0, true);
        // Mid-window.
        r.count_completion(1_500, 0, 2.0, false);
        // Horizon-exact completion clamps into the last window.
        r.count_completion(3_000, 0, 2.0, true);
        // Past-horizon drain too.
        r.count_completion(3_456, 0, 2.0, true);
        let o = r.finish(vec!["m".into()]);
        assert_eq!(o.windows.len(), 3);
        assert_eq!(o.windows[0].served, 0, "empty window survives");
        assert_eq!(o.windows[1].served, 2);
        assert_eq!(o.windows[1].slo_miss, 1);
        assert_eq!(o.windows[2].served, 2, "t = horizon and beyond clamp to last");
    }

    #[test]
    fn empty_windows_mid_run_are_materialized() {
        let mut r = Recorder::new(cfg_all(), 5_000);
        r.count_arrival(100);
        r.count_arrival(4_900);
        let o = r.finish(vec![]);
        assert_eq!(o.windows.len(), 5);
        assert_eq!(o.windows[0].arrivals, 1);
        assert!(o.windows[1..4].iter().all(|w| w.arrivals == 0));
        assert_eq!(o.windows[4].arrivals, 1);
    }

    #[test]
    fn queue_depth_samples_window_starts() {
        let mut r = Recorder::new(cfg_all(), 4_000);
        r.count_arrival(100); // depth 0 → 1 (window 0 start sampled at 0)
        r.count_arrival(500); // 1 → 2
        // Mutation exactly on the w1 boundary: sample (depth 2) first.
        r.count_arrival(1_000); // 2 → 3
        r.count_span(2_500, 10, 50, 3); // 3 → 0; samples w2 start at 3
        let o = r.finish(vec![]);
        let depths: Vec<u64> = o.windows.iter().map(|w| w.queue_depth).collect();
        assert_eq!(depths, vec![0, 2, 3, 0]);
    }

    #[test]
    fn span_attribution_splits_across_windows() {
        let mut r = Recorder::new(cfg_all(), 3_000);
        // 1.5 windows of busy at 40% useful: [500, 2000).
        r.count_span(500, 1_500, 40, 1);
        let o = r.finish(vec![]);
        assert_eq!(o.windows[0].busy_us, 500);
        assert_eq!(o.windows[1].busy_us, 1_000);
        assert_eq!(o.windows[2].busy_us, 0);
        assert_eq!(o.windows[0].knee_pct_us, 40 * 500);
        assert_eq!(o.windows[1].knee_pct_us, 40 * 1_000);
    }

    #[test]
    fn merge_key_is_record_order_independent() {
        let cfg = ObsCfg { trace: true, ..Default::default() };
        // Lane A records (complete@150 then enqueue@200); lane A' — the
        // same lane under another exec mode — records them in the
        // opposite buffer order. Merged output must be identical.
        let mut a = Recorder::new(cfg, 1_000);
        a.event(EventKind::Complete, 150, 0, 1, 0);
        a.event(EventKind::Enqueue, 200, 0, 2, 0);
        let mut b = Recorder::new(cfg, 1_000);
        b.event(EventKind::Enqueue, 200, 0, 2, 0);
        b.event(EventKind::Complete, 150, 0, 1, 0);
        let la = vec![a.finish(vec!["m".into()])];
        let ra = ObsReport::collect(cfg, 1_000, la, EngineObs::default()).unwrap();
        let lb = vec![b.finish(vec!["m".into()])];
        let rb = ObsReport::collect(cfg, 1_000, lb, EngineObs::default()).unwrap();
        assert_eq!(ra.to_perfetto(), rb.to_perfetto());
        let kinds: Vec<EventKind> = ra.merged_events().iter().map(|(_, e)| e.kind).collect();
        assert_eq!(kinds, vec![EventKind::Complete, EventKind::Enqueue]);
    }

    #[test]
    fn perfetto_output_is_valid_json() {
        let mut r = Recorder::new(cfg_all(), 2_000);
        r.event(EventKind::Arrive, 10, 0, 7, 0);
        r.span(EventKind::Batch, 20, 0, 4, 300, 50, 40);
        r.event(EventKind::Replan, 1_500, NO_MODEL, 1, 0);
        let o = ObsReport::collect(
            cfg_all(),
            2_000,
            vec![r.finish(vec!["vgg19".into()])],
            EngineObs::default(),
        )
        .unwrap();
        let s = o.to_perfetto();
        let j = Json::parse(&s).expect("perfetto export parses as JSON");
        let evs = j.get("traceEvents").unwrap().as_arr().unwrap();
        assert_eq!(evs.len(), 3);
        assert_eq!(evs[0].req_str("name").unwrap(), "arrive");
        assert_eq!(evs[1].req_str("ph").unwrap(), "X");
        assert_eq!(evs[1].req_u64("dur").unwrap(), 300);
        assert_eq!(evs[2].req_str("cat").unwrap(), "control");
    }

    #[test]
    fn timeseries_json_merges_lanes_by_name() {
        let cfg = cfg_all();
        let mut a = Recorder::new(cfg, 2_000);
        a.count_completion(100, 0, 5.0, true);
        let mut b = Recorder::new(cfg, 2_000);
        b.count_completion(150, 1, 5.0, true);
        let o = ObsReport::collect(
            cfg,
            2_000,
            vec![a.finish(vec!["vgg19".into()]), b.finish(vec!["resnet50".into(), "vgg19".into()])],
            EngineObs::default(),
        )
        .unwrap();
        let ts = o.timeseries_json();
        let pm = ts.get("per_model_served").unwrap();
        let vgg = pm.get("vgg19").unwrap().as_arr().unwrap();
        assert_eq!(vgg[0].as_u64(), Some(2), "same model on two lanes merges");
        assert_eq!(ts.get("n_windows").unwrap().as_u64(), Some(2));
        assert!(o.render().contains("events recorded"));
    }

    #[test]
    fn obscfg_validation() {
        assert!(ObsCfg::default().validate().is_ok());
        assert!(ObsCfg { window_us: 0, ..Default::default() }.validate().is_err());
        assert!(ObsCfg { sample_request: 0, ..Default::default() }.validate().is_err());
        assert!(!ObsCfg::default().enabled());
        assert!(ObsCfg { trace: true, ..Default::default() }.enabled());
    }
}
