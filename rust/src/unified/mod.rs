//! Unified cold-start-aware control plane: drift rebalancing and
//! lifecycle residency as one driver.
//!
//! [`crate::controlplane`] and [`crate::lifecycle`] each run alone: the
//! rebalancer migrates replicas with a flat `migration_cost_ms` and no
//! idea what is warm, while the memory manager faults weights in and out
//! under a placement that never moves. Run against the same fleet those
//! blind spots compound — a replan can move a model *off* the only GPU
//! holding its weights, and eviction thrash never feeds back into the
//! placement at all. D-STACK's premise is that these decisions must be
//! co-designed; DARIS (PAPERS.md) shows oversubscribed spatio-temporal
//! schedulers only hold deadlines when migration/load costs are modeled
//! explicitly. This module is that co-design, one
//! [`EpochDriver`] composing both subsystems:
//!
//! - **Footprint-priced migrations** — each replica added by a replan is
//!   priced by the [`crate::gpu::ReconfigModel::cold_load_ms`] of the
//!   weights actually loaded at its target (parameter sharing included),
//!   accumulated in `AdaptiveStats::cold_migration_ms`; the legacy flat
//!   `migration_ms` stays exact for comparison. An added replica is not
//!   a pending activation with a fixed delay (the adaptive path's model)
//!   but a *cold engine slot*: its first arrival faults the weights in
//!   through the lifecycle machinery, so the modeled price and the paid
//!   price come from the same cost model.
//! - **Residency-aware replanning** — the replan target is solved by
//!   [`crate::cluster::placement::plan_residency_biased`] with
//!   `is_warm` wired to the live per-GPU [`ModelStore`]s: warm GPUs win
//!   the packing scan, so a migration lands where the weights already
//!   sit whenever the knee budget allows (cost zero instead of a cold
//!   load).
//! - **Eviction-pressure replans** — the control tick fires not only on
//!   rate drift but also when the stores evicted at least
//!   `eviction_replan_threshold` residents since the previous tick:
//!   thrash means the assignment no longer matches the popularity
//!   distribution, drift detector or no.
//!
//! The driver keeps the lifecycle path's sparse-execution contract:
//! candidate sets are the victim→replica reachability closure
//! ([`crate::lifecycle::reachability_candidates`]) over the *current*
//! replica hosting (recomputed only at tick barriers, where the sparse
//! core rebuilds its index), and fully-warm spans under backlog-free
//! routing elide stepping barriers exactly as in the standalone
//! lifecycle driver — see DESIGN.md §4.9 for why replan surgery at
//! driver-event barriers preserves the determinism argument.
//!
//! The canonical stress scenario is [`drifting_longtail_workload`]: a
//! long-tail Zipf fleet whose popularity ranking rotates at the horizon
//! midpoint, served under memory pressure — rate drift *and* eviction
//! pressure at once (`figures::fig15`, `dstack unified`,
//! `rust/configs/cluster_unified_drift.json`), sweepable to 64+ GPUs via
//! [`unified_gpus`].

use crate::cluster::exec::{run_epochs_stream, EpochDriver, ExecEngine, Touched};
use crate::cluster::placement::plan_residency_biased;
use crate::cluster::routing::BacklogCache;
use crate::cluster::{
    plan_residency, ClusterReport, ExecOpts, GpuModelShare, GpuReport, GpuSched,
    PlacementPolicy, Replica, Router, RoutingPolicy,
};
use crate::controlplane::{
    placement_delta, AdaptiveCfg, AdaptiveStats, DriftDetector, RateEstimator,
};
use crate::cluster::p99_of;
use crate::faults::{
    pick_hedge_target, queue_est_us, FaultKind, Resilience, ResilienceCfg, SloClass,
};
use crate::gpu::{ms_to_us, us_to_ms, Us};
use crate::lifecycle::{reachability_candidates, LifecycleCfg, LifecycleStats, ModelStore};
use crate::metrics::RunReport;
use crate::obs::{EngineObs, EventKind, ObsCfg, ObsReport, Recorder, NO_MODEL};
use crate::overload::{Overload, OverloadSpec, RejectKind};
use crate::profile::{GpuSpec, ModelProfile};
use crate::sim::{ModelEntry, Sim, SimConfig};
use crate::util::stats::{percentile, LogHistogram};
use crate::workload::{ArrivalStream, Arrivals, MaterializedStream, Request};
use std::collections::{BTreeMap, VecDeque};

/// Unified control-plane configuration (the scenario `"unified"` block —
/// see `docs/CONFIG.md`): the adaptive and lifecycle knobs plus the
/// coupling parameter between them.
#[derive(Debug, Clone)]
pub struct UnifiedCfg {
    /// Estimation / drift-detection / tick cadence knobs. The flat
    /// `migration_cost_ms` is still charged into the legacy
    /// `migration_ms` stat for comparison, but no longer gates when an
    /// added replica becomes routable — cold loads do.
    pub adaptive: AdaptiveCfg,
    /// Memory budgets, eviction policy, scale-to-zero and warm routing.
    pub lifecycle: LifecycleCfg,
    /// Evictions across the cluster within one control interval at
    /// which the tick replans even without rate drift (the memory
    /// manager telling the placement it no longer fits). `0` disables
    /// the pressure trigger.
    pub eviction_replan_threshold: u64,
}

impl Default for UnifiedCfg {
    fn default() -> Self {
        UnifiedCfg {
            adaptive: AdaptiveCfg::default(),
            lifecycle: LifecycleCfg::default(),
            eviction_replan_threshold: 8,
        }
    }
}

impl UnifiedCfg {
    /// Validate both sub-configs; returns a message naming the offending
    /// field.
    pub fn validate(&self) -> Result<(), String> {
        self.adaptive.validate()?;
        self.lifecycle.validate()
    }
}

/// The unified driver: lifecycle residency machinery (stores, cold
/// starts, eviction cascades, scale-to-zero) under a *mutable* replica
/// assignment that the control tick re-solves residency-aware.
struct UnifiedDriver<'a> {
    profiles: &'a [ModelProfile],
    gpus: &'a [GpuSpec],
    placement: PlacementPolicy,
    sched: GpuSched,
    cfg: &'a UnifiedCfg,
    horizon_ms: f64,
    horizon: Us,
    interval: Us,
    window_s: f64,
    /// Per-GPU resident-memory budgets the plans are solved for (MiB).
    budgets: Vec<u64>,
    min_replicas: usize,
    pinned: Vec<bool>,
    /// model → live replicas (engine slots always assigned; warmth is
    /// the store's business). Mutated only at tick barriers.
    replicas: Vec<Vec<Replica>>,
    /// gpu → global model → engine-local slot (`None` = never hosted).
    local_of: Vec<Vec<Option<usize>>>,
    /// gpu → engine-local slot → global model.
    local_map: Vec<Vec<usize>>,
    /// gpu → Σ assigned knee% (may exceed 100: temporal sharing).
    knee_load: Vec<u32>,
    shed_rps: Vec<f64>,
    stores: Vec<ModelStore>,
    /// Victim→replica reachability closure over the current hosting —
    /// recomputed after every rebalance (a tick barrier, where the
    /// sparse core rebuilds its own index).
    cand: Vec<Vec<usize>>,
    /// Routing never reads backlogs — precondition for warm-span
    /// barrier elision.
    free_routing: bool,
    router: Router,
    cache: BacklogCache,
    rejected: Vec<u64>,
    /// (gpu, model) → virtual time its in-flight load completes.
    loading: BTreeMap<(usize, usize), Us>,
    /// (gpu, model) → requests parked until the load completes.
    held: BTreeMap<(usize, usize), Vec<Request>>,
    cold_delays_ms: Vec<f64>,
    lstats: LifecycleStats,
    astats: AdaptiveStats,
    idle_timeout: Option<Us>,
    estimator: RateEstimator,
    detector: DriftDetector,
    planned_rates: Vec<f64>,
    window_counts: Vec<u64>,
    next_tick: Us,
    /// Cluster-wide eviction count at the previous tick (pressure
    /// trigger baseline).
    evictions_at_tick: u64,
    /// Reusable cascade queue (always drained empty between uses).
    scratch: VecDeque<(usize, Request)>,
    /// Fault timeline + SLO-class front door ([`crate::faults`]);
    /// `None` outside fault scenarios.
    res: Option<Resilience>,
    /// Overload-control layer ([`crate::overload`]): retry backoff,
    /// per-engine breakers, brownout variant fallback. Brownout here is
    /// residency-gated — a variant is a candidate only where its
    /// weights are already warm; degradation never triggers a cold
    /// start. `None` when the scenario has no `overload` block.
    ovl: Option<Overload>,
    /// Copied into engines created mid-run by replan surgery.
    obs_cfg: ObsCfg,
    /// Control-lane event recorder (routing + both planes' decisions).
    obs: Recorder,
}

impl UnifiedDriver<'_> {
    /// One request dispatch with warmness-aware routing, cold-start
    /// parking and eviction cascades — the lifecycle dispatch, reading
    /// the driver's *live* replica table instead of a frozen plan.
    fn dispatch(
        &mut self,
        t: Us,
        model: usize,
        req: Request,
        work: &mut VecDeque<(usize, Request)>,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
    ) {
        if self.replicas[model].is_empty() {
            self.rejected[model] += 1;
            if self.obs.on() {
                self.obs.event(EventKind::Reject, req.arrival, model as u32, req.id, 0);
            }
            return;
        }
        // Health filter: downed engines drop out of the candidate set
        // (the clone only happens while some engine is unroutable).
        let filtered: Option<Vec<Replica>> = match self.res.as_ref() {
            Some(res) if res.any_unroutable() => Some(
                self.replicas[model].iter().filter(|r| res.routable(r.gpu)).cloned().collect(),
            ),
            _ => None,
        };
        if filtered.as_ref().is_some_and(|f| f.is_empty()) {
            // Placed, but every hosting engine is down right now.
            self.rejected[model] += 1;
            self.res.as_mut().expect("unroutable without resilience").note_unroutable();
            if self.obs.on() {
                self.obs.event(EventKind::Reject, t, model as u32, req.id, 0);
            }
            return;
        }
        // `dispatch_on` needs `&mut self`, so the unfiltered candidate
        // list is moved out of `replicas[model]` for the call (O(1), no
        // allocation) and restored right after — `dispatch_on` never
        // reads `replicas`.
        let mut taken: Vec<Replica> = Vec::new();
        let reps: &[Replica] = match &filtered {
            Some(f) => f,
            None => {
                taken = std::mem::take(&mut self.replicas[model]);
                &taken
            }
        };
        let cache = &mut self.cache;
        let res = self.res.as_ref();
        let (held, stores, loading) = (&self.held, &self.stores, &self.loading);
        let (lcfg, profiles) = (&self.cfg.lifecycle, self.profiles);
        let pick = self.router.route(model, reps, |rep| {
            let backlog = cache.backlog(engines, rep);
            let parked = held.get(&(rep.gpu, model)).map_or(0, |v| v.len());
            let base = backlog
                .saturating_add(parked)
                .saturating_add(res.map_or(0, |r| r.penalty_items(rep.gpu)));
            if !lcfg.warm_routing || stores[rep.gpu].is_warm(model) {
                return base;
            }
            let remaining_ms = match loading.get(&(rep.gpu, model)) {
                Some(&ready) => us_to_ms(ready.saturating_sub(t)),
                None => lcfg
                    .reconfig
                    .cold_load_ms(profiles[model].load_ms, stores[rep.gpu].n_warm()),
            };
            base.saturating_add((remaining_ms * rep.capacity_rps / 1_000.0).ceil() as usize)
        });
        let (rid, rarr) = (req.id, req.arrival);
        let landed = self.dispatch_on(t, model, req, reps, pick, work, engines, touched);
        if filtered.is_none() {
            self.replicas[model] = taken;
        }
        if landed.is_none() {
            self.rejected[model] += 1;
            if self.obs.on() {
                self.obs.event(EventKind::Reject, rarr, model as u32, rid, 0);
            }
        }
    }

    /// Dispatch on the routed replica, falling back across `reps` in
    /// index order: a warm replica serves immediately, an in-flight
    /// load parks the request, a loadable GPU faults the model in.
    /// Returns the GPU the request landed on, or `None` when every
    /// candidate is crowded out (the caller counts the reject). Shared
    /// by the plain routing path and the overload front door (which
    /// routes over a breaker-filtered candidate set).
    #[allow(clippy::too_many_arguments)]
    fn dispatch_on(
        &mut self,
        t: Us,
        model: usize,
        req: Request,
        reps: &[Replica],
        pick: usize,
        work: &mut VecDeque<(usize, Request)>,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
    ) -> Option<usize> {
        let order = std::iter::once(pick).chain((0..reps.len()).filter(|&i| i != pick));
        for i in order {
            let (g, local) = (reps[i].gpu, reps[i].local);
            if self.stores[g].is_warm(model) {
                self.stores[g].touch(t, model);
                if self.obs.on() {
                    self.obs.event(EventKind::Route, req.arrival, model as u32, req.id, g as u64);
                }
                let mut q = req;
                q.model = local;
                engines[g].as_mut().expect("warm replica on idle GPU").sim.inject(q);
                self.cache.note_inject(g, local);
                touched.mark(g);
                self.lstats.warm_hits += 1;
                return Some(g);
            }
            if let Some(&ready) = self.loading.get(&(g, model)) {
                self.cold_delays_ms.push(us_to_ms(ready.saturating_sub(req.arrival)));
                self.held.entry((g, model)).or_default().push(req);
                self.lstats.cold_delayed += 1;
                return Some(g);
            }
            let Some(victims) = self.stores[g].begin_load(
                t,
                model,
                self.profiles[model].mem_mib,
                self.profiles[model].load_ms,
                self.pinned[model],
            ) else {
                continue; // crowded out here — try the next replica
            };
            let load_ms = self
                .cfg
                .lifecycle
                .reconfig
                .cold_load_ms(self.profiles[model].load_ms, self.stores[g].n_warm());
            if !victims.is_empty() {
                let engine = engines[g].as_mut().expect("cold replica on idle GPU");
                for v in victims {
                    let vl = self.local_of[g][v].expect("evicting unassigned model");
                    if self.obs.on() {
                        self.obs.event(
                            EventKind::Evict,
                            t,
                            v as u32,
                            g as u64,
                            self.profiles[v].mem_mib,
                        );
                        self.obs.count_control(EventKind::Evict, t);
                    }
                    for dr in engine.sim.deactivate_model(vl) {
                        work.push_back((v, dr));
                    }
                    self.cache.invalidate(g, vl);
                }
                engine.rebuild_policy(self.sched);
                touched.mark(g);
            }
            let ready = t + ms_to_us(load_ms).max(1);
            if self.obs.on() {
                self.obs.event(EventKind::ColdLoad, t, model as u32, g as u64, ready - t);
                self.obs.count_control(EventKind::ColdLoad, t);
                self.obs.warm_level(g, t, self.stores[g].n_warm() as u64);
            }
            self.loading.insert((g, model), ready);
            self.cold_delays_ms.push(us_to_ms(ready.saturating_sub(req.arrival)));
            self.held.entry((g, model)).or_default().push(req);
            self.lstats.cold_delayed += 1;
            self.lstats.load_ms_total += load_ms;
            return Some(g);
        }
        None
    }

    /// Best-case completion estimate the overload front door (and its
    /// breakers) reasons about: analytic queue time over backlog +
    /// parked + health penalty, plus any remaining weight upload when
    /// the replica is cold.
    fn admit_est_us(
        &mut self,
        t: Us,
        model: usize,
        rep: &Replica,
        engines: &[Option<ExecEngine>],
    ) -> Us {
        let backlog = self
            .cache
            .backlog(engines, rep)
            .saturating_add(self.held.get(&(rep.gpu, model)).map_or(0, |v| v.len()))
            .saturating_add(self.res.as_ref().map_or(0, |r| r.penalty_items(rep.gpu)));
        let mut est = queue_est_us(backlog, rep.batch, rep.capacity_rps);
        if !self.stores[rep.gpu].is_warm(model) {
            let remaining_ms = match self.loading.get(&(rep.gpu, model)) {
                Some(&ready) => us_to_ms(ready.saturating_sub(t)),
                None => self
                    .cfg
                    .lifecycle
                    .reconfig
                    .cold_load_ms(self.profiles[model].load_ms, self.stores[rep.gpu].n_warm()),
            };
            est = est.saturating_add(ms_to_us(remaining_ms));
        }
        est
    }

    /// The overload front door (armed `ovl` only): family-ordered
    /// admission — the primary first, then its brownout variants — with
    /// per-engine breaker feeding/filtering, resolved through
    /// [`Self::dispatch_on`], a scheduled retry, or a typed terminal
    /// reject. Variants are residency-gated: only replicas whose
    /// weights are currently warm are candidates, so a brownout never
    /// triggers a fallback cold start. `attempt` is 0 for fresh
    /// arrivals and the retry ordinal for re-entries.
    #[allow(clippy::too_many_arguments)]
    fn overload_dispatch(
        &mut self,
        t: Us,
        attempt: u32,
        req: Request,
        work: &mut VecDeque<(usize, Request)>,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
    ) {
        let m = req.model;
        let order = self.ovl.as_ref().expect("overload dispatch without layer").service_order(m);
        let mut cause = RejectKind::Unroutable;
        for (fi, &fm) in order.iter().enumerate() {
            let healthy: Vec<Replica> = self.replicas[fm]
                .iter()
                .filter(|r| self.res.as_ref().is_none_or(|res| res.routable(r.gpu)))
                .filter(|r| fi == 0 || self.stores[r.gpu].is_warm(fm))
                .cloned()
                .collect();
            if healthy.is_empty() {
                continue; // `cause` stays Unroutable for the primary
            }
            // Every healthy replica's estimate feeds its breaker; only
            // breaker-approved replicas stay candidates.
            let mut open: Vec<Replica> = Vec::with_capacity(healthy.len());
            let mut best = Us::MAX;
            for rep in &healthy {
                let est = self.admit_est_us(t, fm, rep, engines);
                let miss = t.saturating_add(est) > req.deadline;
                let ovl = self.ovl.as_mut().expect("checked above");
                ovl.note_estimate(t, rep.gpu, miss);
                if ovl.allows(t, rep.gpu) {
                    if est < best {
                        best = est;
                    }
                    open.push(rep.clone());
                }
            }
            if open.is_empty() {
                if fi == 0 {
                    cause = RejectKind::BreakerOpen;
                }
                continue;
            }
            if t.saturating_add(best) > req.deadline {
                if fi == 0 {
                    cause = RejectKind::Deadline;
                }
                continue;
            }
            // Route among the breaker-approved replicas with the same
            // warmness-aware cost `dispatch` probes.
            let cache = &mut self.cache;
            let res = self.res.as_ref();
            let (held, stores, loading) = (&self.held, &self.stores, &self.loading);
            let (lcfg, profiles) = (&self.cfg.lifecycle, self.profiles);
            let pick = self.router.route(fm, &open, |rep| {
                let backlog = cache.backlog(engines, rep);
                let parked = held.get(&(rep.gpu, fm)).map_or(0, |v| v.len());
                let base = backlog
                    .saturating_add(parked)
                    .saturating_add(res.map_or(0, |r| r.penalty_items(rep.gpu)));
                if !lcfg.warm_routing || stores[rep.gpu].is_warm(fm) {
                    return base;
                }
                let remaining_ms = match loading.get(&(rep.gpu, fm)) {
                    Some(&ready) => us_to_ms(ready.saturating_sub(t)),
                    None => lcfg
                        .reconfig
                        .cold_load_ms(profiles[fm].load_ms, stores[rep.gpu].n_warm()),
                };
                base.saturating_add((remaining_ms * rep.capacity_rps / 1_000.0).ceil() as usize)
            });
            let landed = self.dispatch_on(t, fm, req, &open, pick, work, engines, touched);
            let class = self.res.as_ref().map_or(SloClass::LatencyCritical, |r| r.class(m));
            match landed {
                Some(g) => {
                    let ovl = self.ovl.as_mut().expect("checked above");
                    ovl.note_dispatch(t, g);
                    if fi > 0 {
                        ovl.note_degraded(class);
                    }
                    if attempt > 0 {
                        ovl.note_retry_served();
                    }
                }
                // Crowded out everywhere despite passing admission: the
                // pre-existing untyped reject, kept identical so
                // conservation still holds.
                None => self.rejected[fm] += 1,
            }
            return;
        }
        self.overload_reject(t, attempt, &req, cause);
    }

    /// A request the overload front door could not place anywhere in its
    /// family: schedule a backoff retry if budget remains, else issue
    /// the terminal typed reject (`retry_exhausted` when retries are on,
    /// the original cause otherwise).
    fn overload_reject(&mut self, t: Us, attempt: u32, req: &Request, cause: RejectKind) {
        let m = req.model;
        if self.ovl.as_mut().expect("overload reject without layer").try_schedule_retry(
            t,
            req,
            attempt + 1,
        ) {
            return; // re-enters at its release barrier; not terminal
        }
        self.rejected[m] += 1;
        let class = self.res.as_ref().map_or(SloClass::LatencyCritical, |r| r.class(m));
        let forward = self.ovl.as_mut().expect("checked above").note_terminal(cause, class);
        match forward {
            Some(RejectKind::Deadline) => {
                if let Some(res) = &mut self.res {
                    res.note_deadline_reject(m);
                }
            }
            Some(RejectKind::Unroutable) => {
                if let Some(res) = &mut self.res {
                    res.note_unroutable();
                }
            }
            _ => {}
        }
        if self.obs.on() {
            self.obs.event(EventKind::Reject, t, m as u32, req.id, 0);
        }
    }

    /// True when no arrival can trigger a cold start right now (see the
    /// lifecycle driver's identical argument — warmth is monotone
    /// between driver events, and control ticks *are* driver events, so
    /// replan surgery can never land inside an elided span).
    fn warm_span_ready(&self) -> bool {
        self.replicas.iter().enumerate().all(|(m, reps)| {
            reps.iter().all(|r| {
                self.stores[r.gpu].is_warm(m) || self.loading.contains_key(&(r.gpu, m))
            })
        })
    }

    /// Apply every fault-timeline event due at `t`, then the hedge
    /// sweep if its cadence tick is due (see the lifecycle driver's
    /// identical determinism argument).
    fn apply_faults(&mut self, t: Us, engines: &mut [Option<ExecEngine>], touched: &mut Touched) {
        let due = match self.res.as_mut() {
            Some(r) => r.due_faults(t),
            None => return,
        };
        for e in due {
            match e.kind {
                FaultKind::Down => self.on_down(t, e.gpu, engines, touched),
                FaultKind::Degraded => {
                    if self.obs.on() {
                        self.obs.event(EventKind::EngineDown, t, NO_MODEL, e.gpu as u64, 1);
                    }
                }
                FaultKind::Up => {
                    // ModelStore driver: recovery is on demand — the
                    // engine is routable again immediately, weights
                    // fault back in through the cold-start path.
                    let res = self.res.as_mut().expect("fault event without resilience");
                    if res.restoring(e.gpu) {
                        res.mark_restored(e.gpu, t);
                    }
                    if self.obs.on() {
                        self.obs.event(EventKind::EngineUp, t, NO_MODEL, e.gpu as u64, 0);
                    }
                }
            }
        }
        if self.res.as_ref().is_some_and(|r| r.hedge_due(t)) {
            self.hedge_sweep(t, engines, touched);
        }
    }

    /// Hard engine failure (lifecycle semantics: drain, cancel loads,
    /// crash the store, cascade the orphans). The replica table is NOT
    /// touched — the engine's replicas stay booked but unroutable, so a
    /// later control tick replans around them with full knowledge of
    /// the assignment, and recovery needs no table surgery at all.
    fn on_down(&mut self, t: Us, g: usize, engines: &mut [Option<ExecEngine>], touched: &mut Touched) {
        if self.obs.on() {
            self.obs.event(EventKind::EngineDown, t, NO_MODEL, g as u64, 0);
        }
        let mut orphans: Vec<(usize, Request)> = Vec::new();
        if let Some(engine) = engines[g].as_mut() {
            let mut drained_any = false;
            for (local, &global) in self.local_map[g].iter().enumerate() {
                if !engine.sim.is_active(local) {
                    continue; // tombstone (cold / scaled to zero / migrated off)
                }
                for r in engine.sim.deactivate_model(local) {
                    orphans.push((global, r));
                }
                self.cache.invalidate(g, local);
                drained_any = true;
            }
            if drained_any {
                engine.rebuild_policy(self.sched);
            }
            touched.mark(g);
        }
        let dead_loads: Vec<(usize, usize)> =
            self.loading.keys().filter(|k| k.0 == g).copied().collect();
        for key in dead_loads {
            self.loading.remove(&key);
            for r in self.held.remove(&key).unwrap_or_default() {
                orphans.push((key.1, r));
            }
        }
        self.stores[g].crash();
        if self.obs.on() {
            self.obs.warm_level(g, t, 0);
        }
        let reroute = self.res.as_ref().is_none_or(|r| r.cfg.reroute);
        if reroute {
            let n = orphans.len() as u64;
            let mut work = std::mem::take(&mut self.scratch);
            debug_assert!(work.is_empty());
            for (m, mut r) in orphans {
                r.model = m;
                work.push_back((m, r));
            }
            while let Some((m, q)) = work.pop_front() {
                self.dispatch(t, m, q, &mut work, engines, touched);
            }
            self.scratch = work;
            if let Some(res) = self.res.as_mut() {
                res.note_reroute(n);
            }
        } else {
            for (m, r) in orphans {
                self.rejected[m] += 1;
                if self.obs.on() {
                    self.obs.event(EventKind::Reject, t, m as u32, r.id, 0);
                }
            }
        }
    }

    /// Hedged re-dispatch off degraded engines (lifecycle semantics:
    /// targets must be warm, healthy replicas of the same model).
    fn hedge_sweep(&mut self, t: Us, engines: &mut [Option<ExecEngine>], touched: &mut Touched) {
        for g in 0..engines.len() {
            if !self.res.as_ref().is_some_and(|r| r.degraded(g)) || engines[g].is_none() {
                continue;
            }
            for local in 0..self.local_map[g].len() {
                let global = self.local_map[g][local];
                let res = self.res.as_ref().expect("degraded without resilience");
                let cutoff = t.saturating_sub(res.hedge_threshold_us(global));
                let stuck = engines[g].as_ref().unwrap().sim.queued_before(local, cutoff);
                if stuck == 0 {
                    continue;
                }
                let Some(src_idx) = self.replicas[global].iter().position(|r| r.gpu == g)
                else {
                    continue; // migrated off — queue drains where it sits
                };
                let cache = &mut self.cache;
                let stores = &self.stores;
                let src_rep = &self.replicas[global][src_idx];
                let src_est = queue_est_us(
                    cache.backlog(engines, src_rep).saturating_add(res.penalty_items(g)),
                    src_rep.batch,
                    src_rep.capacity_rps,
                );
                let cands: Vec<(Us, usize)> = self.replicas[global]
                    .iter()
                    .filter(|r| {
                        r.gpu != g && res.routable(r.gpu) && stores[r.gpu].is_warm(global)
                    })
                    .map(|r| {
                        let backlog = cache
                            .backlog(engines, r)
                            .saturating_add(res.penalty_items(r.gpu));
                        (queue_est_us(backlog, r.batch, r.capacity_rps), r.gpu)
                    })
                    .collect();
                match pick_hedge_target((src_est, g), &cands) {
                    None => {
                        // Stuck copy wins: hedge fired, copy cancelled.
                        self.res.as_mut().expect("checked").note_hedges(stuck as u64, 0);
                    }
                    Some(win) => {
                        let target = self.replicas[global]
                            .iter()
                            .find(|r| r.gpu == win)
                            .expect("hedge winner is a replica");
                        let (t_gpu, t_local) = (target.gpu, target.local);
                        let moved =
                            engines[g].as_mut().unwrap().sim.take_queued_before(local, cutoff);
                        let n = moved.len() as u64;
                        for mut r in moved {
                            if self.obs.on() {
                                self.obs.event(
                                    EventKind::Hedge,
                                    t,
                                    global as u32,
                                    r.id,
                                    t_gpu as u64,
                                );
                            }
                            r.model = t_local;
                            engines[t_gpu]
                                .as_mut()
                                .expect("warm hedge target on idle GPU")
                                .sim
                                .inject(r);
                            self.cache.note_inject(t_gpu, t_local);
                        }
                        self.stores[t_gpu].touch(t, global);
                        self.cache.invalidate(g, local);
                        touched.mark(g);
                        touched.mark(t_gpu);
                        self.res.as_mut().expect("checked").note_hedges(n, n);
                        // A won hedge is evidence the source engine is
                        // falling behind — feed its breaker.
                        if let Some(ovl) = &mut self.ovl {
                            ovl.note_hedge_loss(t, g);
                        }
                    }
                }
            }
        }
    }

    /// Scale-to-zero sweep (identical to the lifecycle driver's).
    fn idle_sweep(&mut self, t: Us, engines: &mut [Option<ExecEngine>], touched: &mut Touched) {
        let Some(to) = self.idle_timeout else { return };
        for g in 0..self.stores.len() {
            for m in self.stores[g].idle_candidates(t, to) {
                let local = self.local_of[g][m].expect("resident without a slot");
                let engine = engines[g].as_mut().expect("resident on idle GPU");
                if engine.sim.backlog_items(local) == 0 {
                    let released = self.stores[g].release(m);
                    debug_assert!(released, "idle candidate refused release");
                    let drained = engine.sim.deactivate_model(local);
                    debug_assert!(drained.is_empty(), "empty backlog drained requests");
                    engine.rebuild_policy(self.sched);
                    self.lstats.scale_to_zero += 1;
                    if self.obs.on() {
                        self.obs.event(
                            EventKind::ScaleZero,
                            t,
                            m as u32,
                            g as u64,
                            self.profiles[m].mem_mib,
                        );
                        self.obs.count_control(EventKind::ScaleZero, t);
                        self.obs.warm_level(g, t, self.stores[g].n_warm() as u64);
                    }
                    touched.mark(g);
                } else {
                    self.stores[g].touch(t, m);
                }
            }
        }
    }

    /// Control tick: estimate, detect (drift OR eviction pressure),
    /// re-solve residency-aware, apply the delta with footprint pricing.
    fn control_tick(&mut self, t: Us, engines: &mut [Option<ExecEngine>], touched: &mut Touched) {
        self.next_tick += self.interval;
        self.estimator.observe(&self.window_counts, self.window_s);
        self.window_counts.fill(0);
        let drift = self.detector.tick(self.estimator.rates(), &self.planned_rates);
        let ev_now: u64 = self.stores.iter().map(|s| s.evictions).sum();
        let pressure = self.cfg.eviction_replan_threshold > 0
            && ev_now - self.evictions_at_tick >= self.cfg.eviction_replan_threshold;
        self.evictions_at_tick = ev_now;
        if !(drift || pressure) {
            return;
        }
        self.astats.replans += 1;
        if self.obs.on() {
            self.obs.count_control(EventKind::Replan, t);
        }
        self.planned_rates = self.estimator.rates().to_vec();
        let stores = &self.stores;
        let target = plan_residency_biased(
            self.profiles,
            &self.planned_rates,
            self.gpus,
            self.placement,
            &self.budgets,
            self.min_replicas,
            |g, m| stores[g].is_warm(m),
        );
        let current: Vec<Vec<(usize, u32)>> = self
            .replicas
            .iter()
            .map(|reps| reps.iter().map(|r| (r.gpu, r.pct)).collect())
            .collect();
        let mut delta = placement_delta(&current, &target.placement);
        // Deferred removals: a mid-load replica holds store state the
        // manager cannot release (the upload is in flight, requests are
        // parked behind it) and pinned models keep their residency by
        // contract — both stay until a later tick finds them removable.
        delta
            .remove
            .retain(|&(m, g, _)| !self.pinned[m] && !self.loading.contains_key(&(g, m)));
        if self.obs.on() {
            self.obs.event(
                EventKind::Replan,
                t,
                NO_MODEL,
                delta.add.len() as u64,
                delta.remove.len() as u64,
            );
        }
        if !delta.is_empty() {
            // Tear down removed replicas: release residency, drain and
            // re-dispatch their queues, free the assigned knee budget.
            let mut drained: Vec<(usize, Request)> = Vec::new();
            for &(m, g, pct) in &delta.remove {
                let idx = self.replicas[m]
                    .iter()
                    .position(|r| r.gpu == g)
                    .expect("removing unknown replica");
                let rep = self.replicas[m].remove(idx);
                self.knee_load[g] -= pct;
                if self.stores[g].is_warm(m) {
                    let released = self.stores[g].release(m);
                    debug_assert!(released, "warm unpinned resident refused release");
                    if self.obs.on() {
                        self.obs.warm_level(g, t, self.stores[g].n_warm() as u64);
                    }
                }
                let engine = engines[g].as_mut().expect("replica without engine");
                if engine.sim.is_active(rep.local) {
                    for q in engine.sim.deactivate_model(rep.local) {
                        drained.push((m, q));
                    }
                    engine.rebuild_policy(self.sched);
                    self.cache.invalidate(g, rep.local);
                    touched.mark(g);
                }
                self.astats.replicas_removed += 1;
            }
            // Bring up added replicas as *cold slots*: the engine slot
            // is registered (tombstoned) now, the weights fault in on
            // first arrival. Price the move by the cold load it implies
            // at the target — zero when the planner found a warm GPU.
            for (m, r) in &delta.add {
                let g = r.gpu;
                if engines[g].is_none() {
                    let sim_cfg = SimConfig {
                        gpu: self.gpus[g].clone(),
                        horizon_ms: self.horizon_ms,
                        obs: self.obs_cfg,
                        ..Default::default()
                    };
                    engines[g] = Some(ExecEngine {
                        sim: Sim::new(sim_cfg, Vec::new()),
                        policy: self.sched.build(&[]),
                    });
                }
                let engine = engines[g].as_mut().expect("engine just created");
                let local = match self.local_of[g][*m] {
                    Some(li) => {
                        debug_assert!(!engine.sim.is_active(li), "added over an active slot");
                        li
                    }
                    None => {
                        let entry = ModelEntry {
                            profile: self.profiles[*m].clone(),
                            pct: r.pct,
                            batch: r.batch,
                        };
                        let li = engine.sim.add_model(entry);
                        debug_assert_eq!(li, self.local_map[g].len());
                        self.local_map[g].push(*m);
                        self.local_of[g][*m] = Some(li);
                        let dr = engine.sim.deactivate_model(li);
                        debug_assert!(dr.is_empty(), "fresh slot drained requests");
                        engine.rebuild_policy(self.sched);
                        touched.mark(g);
                        li
                    }
                };
                self.replicas[*m].push(Replica {
                    gpu: g,
                    local,
                    pct: r.pct,
                    batch: r.batch,
                    capacity_rps: r.capacity_rps,
                });
                self.knee_load[g] += r.pct;
                self.astats.replicas_added += 1;
                self.astats.migration_ms += self.cfg.adaptive.migration_cost_ms;
                let cold = if self.stores[g].is_warm(*m) {
                    0.0
                } else {
                    self.cfg
                        .lifecycle
                        .reconfig
                        .cold_load_ms(self.profiles[*m].load_ms, self.stores[g].n_warm())
                };
                *self.astats.cold_migration_ms.get_or_insert(0.0) += cold;
            }
            // The hosting graph changed: recompute the reachability
            // index before anything routes against it. (We are at a
            // driver-event barrier — the sparse core rebuilds its
            // inverted index right after this returns.)
            let mut hosted: Vec<Vec<usize>> = vec![Vec::new(); self.gpus.len()];
            for (m, reps) in self.replicas.iter().enumerate() {
                for r in reps {
                    hosted[r.gpu].push(m);
                }
            }
            self.cand = reachability_candidates(&hosted, self.replicas.len());
            // Re-route drained queues through the full cascade dispatch
            // (cold starts and evictions included — they are priced and
            // counted like any other).
            let mut work = std::mem::take(&mut self.scratch);
            debug_assert!(work.is_empty());
            for (m, q) in drained {
                work.push_back((m, q));
            }
            while let Some((m, q)) = work.pop_front() {
                self.dispatch(t, m, q, &mut work, engines, touched);
            }
            self.scratch = work;
            self.astats.rebalances += 1;
            self.astats.rebalance_times_us.push(t);
        }
        self.shed_rps = target.placement.shed_rps.clone();
    }
}

impl EpochDriver for UnifiedDriver<'_> {
    fn n_models(&self) -> usize {
        self.rejected.len()
    }

    fn candidates_of(&self, model: usize) -> &[usize] {
        &self.cand[model]
    }

    fn elides_barriers(&self) -> bool {
        // Fault timelines, hedge sweeps and admission all read engine
        // state at barriers — never elide while resilience is on. The
        // overload layer's breakers and retries read estimates at
        // barriers too.
        self.free_routing && self.warm_span_ready() && self.res.is_none() && self.ovl.is_none()
    }

    /// Barrier-free routing inside a fully-warm span (the lifecycle
    /// version plus demand counting, which the adaptive contract
    /// requires to be identical on both paths).
    fn route_free(&mut self, t: Us, req: &Request) -> Option<(usize, usize)> {
        let model = req.model;
        self.window_counts[model] += 1;
        if self.obs.on() {
            self.obs.event(EventKind::Arrive, req.arrival, model as u32, req.id, 0);
        }
        let reps: &[Replica] = &self.replicas[model];
        if reps.is_empty() {
            self.rejected[model] += 1;
            if self.obs.on() {
                self.obs.event(EventKind::Reject, req.arrival, model as u32, req.id, 0);
            }
            return None;
        }
        // Backlog-free by contract: the closure is never consulted.
        let pick = self.router.route(model, reps, |_| 0);
        let order = std::iter::once(pick).chain((0..reps.len()).filter(|&i| i != pick));
        for i in order {
            let r = &self.replicas[model][i];
            let (g, local) = (r.gpu, r.local);
            if self.stores[g].is_warm(model) {
                self.stores[g].touch(t, model);
                if self.obs.on() {
                    self.obs.event(EventKind::Route, req.arrival, model as u32, req.id, g as u64);
                }
                self.lstats.warm_hits += 1;
                return Some((g, local));
            }
            if let Some(&ready) = self.loading.get(&(g, model)) {
                self.cold_delays_ms.push(us_to_ms(ready.saturating_sub(req.arrival)));
                self.held.entry((g, model)).or_default().push(req.clone());
                self.lstats.cold_delayed += 1;
                return None;
            }
            debug_assert!(false, "cold start inside an elided warm span");
        }
        self.rejected[model] += 1;
        if self.obs.on() {
            self.obs.event(EventKind::Reject, req.arrival, model as u32, req.id, 0);
        }
        None
    }

    fn next_event(&self) -> Option<Us> {
        let t_load = self.loading.values().min().copied();
        let t_idle = self
            .idle_timeout
            .and_then(|to| self.stores.iter().filter_map(|s| s.next_idle_expiry(to)).min());
        let t_tick = if self.next_tick < self.horizon { Some(self.next_tick) } else { None };
        let t_res = self.res.as_ref().and_then(|r| r.next_event());
        let t_retry = self.ovl.as_ref().and_then(|o| o.next_release());
        [t_load, t_idle, t_tick, t_res, t_retry].into_iter().flatten().min()
    }

    /// Mature weight loads due at t (lifecycle semantics: parked
    /// requests inject with their original arrival times).
    fn pre_arrivals(&mut self, t: Us, engines: &mut [Option<ExecEngine>], touched: &mut Touched) {
        self.cache.reset();
        // Faults first: an engine going down at t cancels its in-flight
        // loads before the maturation sweep below could complete them.
        if self.res.is_some() {
            self.apply_faults(t, engines, touched);
        }
        let due: Vec<(usize, usize)> = self
            .loading
            .iter()
            .filter(|&(_, &ready)| ready <= t)
            .map(|(&k, _)| k)
            .collect();
        for (g, m) in due {
            self.loading.remove(&(g, m));
            self.stores[g].complete_load(t, m);
            if self.obs.on() {
                self.obs.warm_level(g, t, self.stores[g].n_warm() as u64);
            }
            let local = self.local_of[g][m].expect("loaded model without a slot");
            let rep = self.replicas[m]
                .iter()
                .find(|r| r.gpu == g)
                .expect("loaded model without a replica");
            let engine = engines[g].as_mut().expect("load on idle GPU");
            engine.sim.reactivate_model(
                local,
                ModelEntry {
                    profile: self.profiles[m].clone(),
                    pct: rep.pct,
                    batch: rep.batch,
                },
            );
            engine.rebuild_policy(self.sched);
            for mut r in self.held.remove(&(g, m)).unwrap_or_default() {
                self.stores[g].touch(t, m);
                r.model = local;
                engine.sim.inject(r);
            }
            touched.mark(g);
        }
        // Matured retries re-enter the front door after faults and load
        // maturations so they see the same engine state a fresh arrival
        // at t would.
        if self.ovl.is_some() {
            let due = self.ovl.as_mut().expect("checked").due_retries(t);
            let mut work = std::mem::take(&mut self.scratch);
            debug_assert!(work.is_empty());
            for (attempt, req) in due {
                self.overload_dispatch(t, attempt, req, &mut work, engines, touched);
                while let Some((m, q)) = work.pop_front() {
                    self.dispatch(t, m, q, &mut work, engines, touched);
                }
            }
            self.scratch = work;
        }
    }

    /// Route one arrival (demand-counted), draining any eviction
    /// cascade it triggers.
    fn route(
        &mut self,
        t: Us,
        req: Request,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
    ) {
        self.window_counts[req.model] += 1;
        if self.obs.on() {
            self.obs.event(EventKind::Arrive, req.arrival, req.model as u32, req.id, 0);
        }
        // Overload front door supersedes plain admission: family-ordered
        // brownout, breaker filtering, and retry scheduling.
        if self.ovl.is_some() {
            let mut work = std::mem::take(&mut self.scratch);
            debug_assert!(work.is_empty());
            self.overload_dispatch(t, 0, req, &mut work, engines, touched);
            while let Some((m, q)) = work.pop_front() {
                self.dispatch(t, m, q, &mut work, engines, touched);
            }
            self.scratch = work;
            return;
        }
        // Deadline-aware admission (fresh arrivals only): reject
        // outright when even the best-case replica — shortest analytic
        // queue estimate plus any remaining weight upload — cannot meet
        // the request's deadline.
        let admitted = match self.res.as_ref() {
            Some(res) if res.cfg.admission => {
                let m = req.model;
                let cache = &mut self.cache;
                let (held, stores, loading) = (&self.held, &self.stores, &self.loading);
                let (lcfg, profiles) = (&self.cfg.lifecycle, self.profiles);
                let best = self.replicas[m]
                    .iter()
                    .filter(|r| res.routable(r.gpu))
                    .map(|r| {
                        let backlog = cache
                            .backlog(engines, r)
                            .saturating_add(held.get(&(r.gpu, m)).map_or(0, |v| v.len()))
                            .saturating_add(res.penalty_items(r.gpu));
                        let mut est = queue_est_us(backlog, r.batch, r.capacity_rps);
                        if !stores[r.gpu].is_warm(m) {
                            let remaining_ms = match loading.get(&(r.gpu, m)) {
                                Some(&ready) => us_to_ms(ready.saturating_sub(t)),
                                None => lcfg
                                    .reconfig
                                    .cold_load_ms(profiles[m].load_ms, stores[r.gpu].n_warm()),
                            };
                            est = est.saturating_add(ms_to_us(remaining_ms));
                        }
                        est
                    })
                    .min();
                // No routable replica ⇒ fall through to dispatch's
                // unroutable reject.
                match best {
                    Some(best) => t.saturating_add(best) <= req.deadline,
                    None => true,
                }
            }
            _ => true,
        };
        if !admitted {
            let m = req.model;
            self.rejected[m] += 1;
            self.res.as_mut().expect("admission without resilience").note_deadline_reject(m);
            if self.obs.on() {
                self.obs.event(EventKind::Reject, t, m as u32, req.id, 0);
            }
            return;
        }
        let mut work = std::mem::take(&mut self.scratch);
        debug_assert!(work.is_empty());
        work.push_back((req.model, req));
        while let Some((m, q)) = work.pop_front() {
            self.dispatch(t, m, q, &mut work, engines, touched);
        }
        self.scratch = work;
    }

    /// Idle sweep, then the control tick — the tick sees post-sweep
    /// warmth, so a replan never prefers a GPU whose resident just
    /// scaled to zero.
    fn post_arrivals(&mut self, t: Us, engines: &mut [Option<ExecEngine>], touched: &mut Touched) {
        self.idle_sweep(t, engines, touched);
        if t == self.next_tick {
            self.control_tick(t, engines, touched);
        }
    }
}

/// Serve `requests` on `gpus` under the unified control plane:
/// residency plan at t = 0 (solved for `initial_rates` against
/// `cfg.lifecycle`'s memory budgets), lifecycle cold starts / eviction /
/// scale-to-zero throughout, and residency-aware drift- or
/// pressure-triggered rebalancing at `cfg.adaptive`'s tick cadence.
/// Deterministic: a fixed (inputs, seed) tuple always yields the same
/// report — for any thread count and either exec mode.
#[allow(clippy::too_many_arguments)]
pub fn run_unified(
    profiles: &[ModelProfile],
    initial_rates: &[f64],
    gpus: &[GpuSpec],
    placement: PlacementPolicy,
    routing: RoutingPolicy,
    sched: GpuSched,
    cfg: &UnifiedCfg,
    requests: Vec<Request>,
    horizon_ms: f64,
    seed: u64,
) -> ClusterReport {
    run_unified_with(
        profiles,
        initial_rates,
        gpus,
        placement,
        routing,
        sched,
        cfg,
        requests,
        horizon_ms,
        seed,
        ExecOpts::default(),
    )
}

/// [`run_unified`] with explicit execution options (thread budget +
/// barrier mode). Thin adapter over [`run_unified_stream`] via
/// [`MaterializedStream`] — identical report bytes.
#[allow(clippy::too_many_arguments)]
pub fn run_unified_with(
    profiles: &[ModelProfile],
    initial_rates: &[f64],
    gpus: &[GpuSpec],
    placement: PlacementPolicy,
    routing: RoutingPolicy,
    sched: GpuSched,
    cfg: &UnifiedCfg,
    requests: Vec<Request>,
    horizon_ms: f64,
    seed: u64,
    opts: ExecOpts,
) -> ClusterReport {
    let stream = MaterializedStream::new(requests, profiles.len());
    run_unified_stream(
        profiles, initial_rates, gpus, placement, routing, sched, cfg, stream, horizon_ms, seed,
        opts,
    )
}

/// [`run_unified`] pulling arrivals lazily from any [`ArrivalStream`] —
/// drift replans, residency biasing and eviction pressure all observe
/// routed traffic, so only the memory profile changes.
#[allow(clippy::too_many_arguments)]
pub fn run_unified_stream<S: ArrivalStream>(
    profiles: &[ModelProfile],
    initial_rates: &[f64],
    gpus: &[GpuSpec],
    placement: PlacementPolicy,
    routing: RoutingPolicy,
    sched: GpuSched,
    cfg: &UnifiedCfg,
    stream: S,
    horizon_ms: f64,
    seed: u64,
    opts: ExecOpts,
) -> ClusterReport {
    run_unified_stream_faults(
        profiles, initial_rates, gpus, placement, routing, sched, cfg, stream, horizon_ms, seed,
        opts, None,
    )
}

/// [`run_unified_stream`] with an optional fault timeline + SLO-class
/// front door ([`crate::faults`]). Failure semantics follow the
/// lifecycle driver (store crash, on-demand recovery); the replica
/// table survives the fault, so control ticks keep replanning with the
/// full assignment in view.
#[allow(clippy::too_many_arguments)]
pub fn run_unified_stream_faults<S: ArrivalStream>(
    profiles: &[ModelProfile],
    initial_rates: &[f64],
    gpus: &[GpuSpec],
    placement: PlacementPolicy,
    routing: RoutingPolicy,
    sched: GpuSched,
    cfg: &UnifiedCfg,
    stream: S,
    horizon_ms: f64,
    seed: u64,
    opts: ExecOpts,
    faults: Option<&ResilienceCfg>,
) -> ClusterReport {
    run_unified_stream_overload(
        profiles, initial_rates, gpus, placement, routing, sched, cfg, stream, horizon_ms, seed,
        opts, faults, None,
    )
}

/// [`run_unified_stream_faults`] plus the optional overload-control
/// layer ([`crate::overload`]): retry-with-backoff, per-engine circuit
/// breakers, and brownout variant fallback. When `overload` declares
/// variants, `profiles` must already be the expanded list
/// (`expand_profiles`) — variants enter the residency plan as ordinary
/// near-zero-demand entries and are served only where their weights are
/// warm.
#[allow(clippy::too_many_arguments)]
pub fn run_unified_stream_overload<S: ArrivalStream>(
    profiles: &[ModelProfile],
    initial_rates: &[f64],
    gpus: &[GpuSpec],
    placement: PlacementPolicy,
    routing: RoutingPolicy,
    sched: GpuSched,
    cfg: &UnifiedCfg,
    stream: S,
    horizon_ms: f64,
    seed: u64,
    opts: ExecOpts,
    faults: Option<&ResilienceCfg>,
    overload: Option<&OverloadSpec>,
) -> ClusterReport {
    cfg.validate().expect("invalid unified config");
    if let Some(spec) = overload {
        assert_eq!(profiles.len(), spec.map.n_total(), "profiles not expanded for variants");
    }
    let n_models = profiles.len();
    let n_gpus = gpus.len();
    let horizon = ms_to_us(horizon_ms);
    let lcfg = &cfg.lifecycle;
    let budgets = lcfg.budgets(gpus);
    assert!(
        budgets.iter().all(|&b| b > 0),
        "unified memory budget is zero after headroom ({budgets:?} MiB) — \
         lower headroom_mib or raise mem_budget_mib"
    );
    let idle_timeout: Option<Us> = if lcfg.idle_timeout_ms > 0.0 {
        Some(ms_to_us(lcfg.idle_timeout_ms).max(1))
    } else {
        None
    };
    let pinned: Vec<bool> =
        profiles.iter().map(|p| lcfg.pinned.iter().any(|n| n == &p.name)).collect();

    // --- t = 0: unbiased residency plan (nothing is warm yet) --------------
    let plan = plan_residency(
        profiles,
        initial_rates,
        gpus,
        placement,
        &budgets,
        lcfg.min_replicas,
    );

    let mut local_of: Vec<Vec<Option<usize>>> = vec![vec![None; n_models]; n_gpus];
    let mut engines: Vec<Option<ExecEngine>> = (0..n_gpus)
        .map(|g| {
            if plan.placement.hosted[g].is_empty() {
                return None;
            }
            let entries: Vec<ModelEntry> = plan.placement.hosted[g]
                .iter()
                .enumerate()
                .map(|(local, &m)| {
                    local_of[g][m] = Some(local);
                    let rep = plan.placement.replicas[m]
                        .iter()
                        .find(|r| r.gpu == g)
                        .expect("hosted model without a replica entry");
                    debug_assert_eq!(rep.local, local, "plan local indices drifted");
                    ModelEntry { profile: profiles[m].clone(), pct: rep.pct, batch: rep.batch }
                })
                .collect();
            let sim_cfg =
                SimConfig { gpu: gpus[g].clone(), horizon_ms, obs: opts.obs, ..Default::default() };
            let mut sim = Sim::new(sim_cfg, entries);
            for (local, &m) in plan.placement.hosted[g].iter().enumerate() {
                if !plan.resident0[g].contains(&m) {
                    let drained = sim.deactivate_model(local);
                    debug_assert!(drained.is_empty());
                }
            }
            let mask = sim.active_mask();
            let policy = sched.build_masked(&sim.models, &mask);
            Some(ExecEngine { sim, policy })
        })
        .collect();

    let stores: Vec<ModelStore> = (0..n_gpus)
        .map(|g| {
            let mut s = ModelStore::new(plan.mem_budget_mib[g], lcfg.eviction);
            for &m in &plan.resident0[g] {
                let ok = s.preload(0, m, profiles[m].mem_mib, profiles[m].load_ms, pinned[m]);
                assert!(ok, "resident0 oversubscribes gpu {g}'s memory budget");
            }
            s
        })
        .collect();

    let interval = ms_to_us(cfg.adaptive.interval_ms).max(1);
    let mut driver = UnifiedDriver {
        profiles,
        gpus,
        placement,
        sched,
        cfg,
        horizon_ms,
        horizon,
        interval,
        window_s: cfg.adaptive.interval_ms / 1_000.0,
        budgets,
        min_replicas: lcfg.min_replicas,
        pinned,
        replicas: plan.placement.replicas.clone(),
        local_of,
        local_map: plan.placement.hosted.clone(),
        knee_load: plan.placement.knee_load.clone(),
        shed_rps: plan.placement.shed_rps.clone(),
        stores,
        cand: reachability_candidates(&plan.placement.hosted, n_models),
        free_routing: !routing.reads_backlogs(),
        router: Router::new(routing, n_models, seed),
        cache: BacklogCache::default(),
        rejected: vec![0u64; n_models],
        loading: BTreeMap::new(),
        held: BTreeMap::new(),
        cold_delays_ms: Vec::new(),
        lstats: LifecycleStats::default(),
        // The unified path always serializes cold_migration_ms —
        // Some(0.0) until the first priced migration.
        astats: AdaptiveStats { cold_migration_ms: Some(0.0), ..Default::default() },
        idle_timeout,
        estimator: RateEstimator::new(cfg.adaptive.alpha, initial_rates),
        detector: DriftDetector::new(&cfg.adaptive, n_models),
        planned_rates: initial_rates.to_vec(),
        window_counts: vec![0u64; n_models],
        next_tick: interval,
        evictions_at_tick: 0,
        scratch: VecDeque::new(),
        res: {
            // The overload layer routes through the resilience front
            // door's admission estimate; when armed without an explicit
            // fault config, synthesize a minimal admission-only door.
            let synth_cfg;
            let res_cfg = match (faults, overload) {
                (Some(f), _) => Some(f),
                (None, Some(_)) => {
                    synth_cfg = ResilienceCfg {
                        admission: true,
                        hedge: false,
                        ..ResilienceCfg::default()
                    };
                    Some(&synth_cfg)
                }
                (None, None) => None,
            };
            res_cfg.map(|f| {
                Resilience::new(f.clone(), profiles, n_gpus, horizon)
                    .expect("invalid faults config (validate at the config layer)")
            })
        },
        ovl: overload.map(|spec| Overload::new(spec, n_gpus)),
        obs_cfg: opts.obs,
        obs: Recorder::new(opts.obs, horizon),
    };
    // Seed the warm-set timeline with the t = 0 resident sets so the
    // first window reflects the preloaded state, not zero.
    if driver.obs.on() {
        for g in 0..n_gpus {
            let level = driver.stores[g].n_warm() as u64;
            driver.obs.warm_level(g, 0, level);
        }
    }
    let exec_stats = run_epochs_stream(&mut engines, stream, horizon, opts, &mut driver);
    let UnifiedDriver {
        replicas,
        local_map,
        knee_load,
        shed_rps,
        stores,
        mut rejected,
        held,
        cold_delays_ms,
        mut lstats,
        mut astats,
        estimator,
        res,
        mut ovl,
        obs: mut obs_rec,
        ..
    } = driver;
    // Retries still pending at the horizon never got a terminal answer:
    // count them as retry-exhausted rejects so every offered request is
    // accounted.
    if let Some(o) = &mut ovl {
        for (_attempt, req) in o.drain_leftover() {
            rejected[req.model] += 1;
            let class = res.as_ref().map_or(SloClass::LatencyCritical, |r| r.class(req.model));
            o.note_retry_exhausted(class);
        }
    }
    astats.est_rates = estimator.rates().to_vec();
    // Requests still parked behind an immature load never reached an
    // engine; stamp their drops on the control lane at the horizon.
    if obs_rec.on() {
        for ((_, m), reqs) in &held {
            for r in reqs {
                obs_rec.event(EventKind::Drop, horizon, *m as u32, r.id, 0);
                obs_rec.count_drop(horizon);
            }
        }
    }
    let control_obs = obs_rec.finish(profiles.iter().map(|p| p.name.clone()).collect());

    // --- finalize + aggregate ----------------------------------------------
    let reports: Vec<Option<RunReport>> = engines
        .iter_mut()
        .map(|slot| slot.as_mut().map(|e| e.finalize(horizon)))
        .collect();
    let obs_lanes: Vec<EngineObs> = engines
        .iter_mut()
        .map(|slot| slot.as_mut().map(|e| e.sim.take_obs()).unwrap_or_default())
        .collect();
    let obs = ObsReport::collect(opts.obs, horizon, obs_lanes, control_obs);

    let horizon_s = horizon_ms / 1_000.0;
    let split_at = astats.first_rebalance_us();
    let mut throughput = vec![0.0; n_models];
    let mut violations = vec![0.0; n_models];
    let mut served = vec![0u64; n_models];
    let mut served_in_slo = 0u64;
    let mut dropped = vec![0u64; n_models];
    let mut latencies: Vec<Vec<f64>> = vec![Vec::new(); n_models];
    let mut hists: Vec<LogHistogram> = vec![LogHistogram::default(); n_models];
    let mut lat_before: Vec<Vec<f64>> = vec![Vec::new(); n_models];
    let mut lat_after: Vec<Vec<f64>> = vec![Vec::new(); n_models];
    // (completion time, in-SLO) pairs for the degraded-goodput stat —
    // only collected when a fault timeline is active.
    let mut comps: Vec<(Us, bool)> = Vec::new();
    let mut gpu_utilization = Vec::with_capacity(n_gpus);
    let mut per_gpu = Vec::with_capacity(n_gpus);
    for g in 0..n_gpus {
        let (util, shares) = match &reports[g] {
            Some(rep) => {
                let mut shares = Vec::with_capacity(rep.per_model.len());
                for (local, mm) in rep.per_model.iter().enumerate() {
                    let global = local_map[g][local];
                    throughput[global] += mm.served as f64 / horizon_s;
                    violations[global] += mm.slo_violations() as f64 / horizon_s;
                    served[global] += mm.served;
                    served_in_slo += mm.served_in_slo;
                    dropped[global] += mm.dropped;
                    latencies[global].extend_from_slice(&mm.latencies_ms);
                    hists[global].merge(&mm.latency_hist);
                    for (lat, &done) in mm.latencies_ms.iter().zip(&mm.completions_us) {
                        match split_at {
                            Some(cut) if done >= cut => lat_after[global].push(*lat),
                            _ => lat_before[global].push(*lat),
                        }
                        if res.is_some() {
                            comps.push((done, *lat <= profiles[global].slo_ms));
                        }
                    }
                    // Shares list the final *resident* packing only.
                    let engine = engines[g].as_ref().expect("reported engine");
                    if engine.sim.is_active(local) {
                        let entry = &engine.sim.models[local];
                        shares.push(GpuModelShare {
                            model: global,
                            pct: entry.pct,
                            batch: entry.batch,
                            served: mm.served,
                        });
                    }
                }
                (rep.gpu_utilization[0], shares)
            }
            None => (0.0, Vec::new()),
        };
        gpu_utilization.push(util);
        per_gpu.push(GpuReport {
            gpu: gpus[g].name.to_string(),
            knee_load_pct: knee_load[g],
            utilization: util,
            models: shares,
        });
    }
    // Conservation: requests parked behind loads that never matured
    // count as dropped (and as violations), exactly as in lifecycle.
    for ((_, m), reqs) in &held {
        dropped[*m] += reqs.len() as u64;
        violations[*m] += reqs.len() as f64 / horizon_s;
    }
    for m in 0..n_models {
        violations[m] += rejected[m] as f64 / horizon_s;
    }
    astats.p99_before_ms = lat_before.iter().map(|l| percentile(l, 99.0)).collect();
    astats.p99_after_ms = lat_after.iter().map(|l| percentile(l, 99.0)).collect();
    let p99_ms: Vec<f64> = latencies.iter().zip(&hists).map(|(l, h)| p99_of(l, h)).collect();
    let replica_map: Vec<Vec<usize>> = replicas
        .iter()
        .map(|reps| reps.iter().map(|r| r.gpu).collect())
        .collect();
    let admitted: Vec<bool> = replicas.iter().map(|reps| !reps.is_empty()).collect();

    lstats.cold_starts = stores.iter().map(|s| s.loads).sum();
    lstats.evictions = stores.iter().map(|s| s.evictions).sum();
    lstats.mib_loaded = stores.iter().map(|s| s.mib_loaded).sum();
    lstats.cold_start_p99_ms = percentile(&cold_delays_ms, 99.0);
    lstats.goodput_rps = served_in_slo as f64 / horizon_s;
    lstats.peak_resident_mib = stores.iter().map(|s| s.peak_mib()).collect();
    lstats.resident_final = stores.iter().map(|s| s.n_resident() as u64).collect();

    ClusterReport {
        policy: format!(
            "unified+{}+{}+{}{}+{}",
            placement.name(),
            lcfg.eviction.name(),
            if lcfg.warm_routing { "warm-" } else { "" },
            routing.name(),
            sched.name()
        ),
        throughput,
        gpu_utilization,
        violations_per_sec: violations,
        p99_ms,
        served,
        dropped,
        rejected,
        replica_map,
        shed_rps,
        admitted,
        per_gpu,
        adaptive: Some(astats),
        lifecycle: Some(lstats),
        resilience: res.map(|mut r| r.finalize(horizon, comps.into_iter())),
        overload: ovl.map(|o| o.finalize()),
        exec: Some(exec_stats),
        obs,
    }
}

/// The canonical drift + memory-pressure stress workload: a long-tail
/// Zipf(`alpha`) fleet (same clone-the-zoo derivation as
/// [`crate::lifecycle::longtail_workload`]) whose popularity *ranking
/// rotates* at the horizon midpoint — model `i` inherits the rate of
/// model `(i + n/2) mod n`, so the head becomes the tail and the cold
/// tail becomes the hot head. Under a constrained memory budget this
/// exercises every unified mechanism at once: the rotation drives the
/// drift detector, the newly-hot tail faults in cold, and the resulting
/// eviction pressure feeds the pressure trigger.
///
/// Returns (profiles, initial rates, merged request stream).
pub fn drifting_longtail_workload(
    n_models: usize,
    alpha: f64,
    total_rps: f64,
    horizon_ms: f64,
    seed: u64,
) -> (Vec<ModelProfile>, Vec<f64>, Vec<Request>) {
    let base = crate::profile::zoo();
    drifting_longtail_workload_from(&base, n_models, alpha, total_rps, horizon_ms, seed)
}

/// [`drifting_longtail_workload`] over an explicit base model list (the
/// config path cycles the scenario's `models` entries).
pub fn drifting_longtail_workload_from(
    base: &[ModelProfile],
    n_models: usize,
    alpha: f64,
    total_rps: f64,
    horizon_ms: f64,
    seed: u64,
) -> (Vec<ModelProfile>, Vec<f64>, Vec<Request>) {
    use crate::workload::merged_stream;
    let (profiles, r0, specs) =
        drifting_longtail_specs_from(base, n_models, alpha, total_rps, horizon_ms);
    let reqs = merged_stream(&specs, horizon_ms, seed);
    (profiles, r0, reqs)
}

/// [`drifting_longtail_workload`]'s arrival *specs* over the default
/// zoo — the streamed leg of the equivalence matrix builds a
/// [`crate::workload::MergedStream`] from these.
pub fn drifting_longtail_specs(
    n_models: usize,
    alpha: f64,
    total_rps: f64,
    horizon_ms: f64,
) -> (Vec<ModelProfile>, Vec<f64>, Vec<(Arrivals, f64)>) {
    let base = crate::profile::zoo();
    drifting_longtail_specs_from(&base, n_models, alpha, total_rps, horizon_ms)
}

/// [`drifting_longtail_workload_from`] without the materialization
/// step: (profiles, initial rates, per-model `(process, slo_ms)` specs).
pub fn drifting_longtail_specs_from(
    base: &[ModelProfile],
    n_models: usize,
    alpha: f64,
    total_rps: f64,
    horizon_ms: f64,
) -> (Vec<ModelProfile>, Vec<f64>, Vec<(Arrivals, f64)>) {
    assert!(!base.is_empty(), "long-tail fleet needs at least one base model");
    use crate::workload::zipf_rates;
    let profiles: Vec<ModelProfile> = (0..n_models)
        .map(|i| {
            let mut p = base[i % base.len()].clone();
            p.name = crate::lifecycle::fleet_name(&p.name, i);
            p.load_ms = 150.0 + 0.15 * p.mem_mib as f64;
            p
        })
        .collect();
    let r0 = zipf_rates(n_models, alpha, total_rps);
    let mid = horizon_ms / 2.0;
    let r1: Vec<f64> = (0..n_models).map(|i| r0[(i + n_models / 2) % n_models]).collect();
    let specs: Vec<(Arrivals, f64)> = profiles
        .iter()
        .enumerate()
        .map(|(i, p)| (Arrivals::trace(vec![(0.0, r0[i]), (mid, r1[i])]), p.slo_ms))
        .collect();
    (profiles, r0, specs)
}

/// A homogeneous V100 cluster of `n` GPUs — the canonical unified
/// scenario runs on 4, and sweeps to 64+ by just raising `n`.
pub fn unified_gpus(n: usize) -> Vec<GpuSpec> {
    vec![crate::profile::V100.clone(); n]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::{ExecMode, Parallelism};

    /// The canonical stress scenario at unit-test scale: 12 models on
    /// 4 V100s, 3 GiB budgets, popularity rotation at the midpoint.
    fn stress_cfg() -> UnifiedCfg {
        UnifiedCfg {
            adaptive: AdaptiveCfg { interval_ms: 250.0, ..Default::default() },
            lifecycle: LifecycleCfg {
                mem_budget_mib: 3_072,
                min_replicas: 1,
                ..Default::default()
            },
            eviction_replan_threshold: 8,
        }
    }

    fn run_stress(cfg: &UnifiedCfg, routing: RoutingPolicy, opts: ExecOpts) -> ClusterReport {
        let (profiles, rates, reqs) = drifting_longtail_workload(12, 1.1, 500.0, 2_500.0, 11);
        run_unified_with(
            &profiles,
            &rates,
            &unified_gpus(4),
            PlacementPolicy::LoadBalance,
            routing,
            GpuSched::Dstack,
            cfg,
            reqs,
            2_500.0,
            11,
            opts,
        )
    }

    #[test]
    fn drifting_longtail_rotates_popularity() {
        let (profiles, r0, reqs) = drifting_longtail_workload(8, 1.1, 400.0, 1_000.0, 7);
        assert_eq!(profiles.len(), 8);
        assert_eq!(profiles[0].name, "mobilenet_00");
        // Zipf head at t = 0 …
        assert!(r0[0] > r0[7]);
        for w in reqs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        // … and the head's arrivals thin out after the midpoint while
        // the rotated-in model's pick up: count per half.
        let count = |m: usize, lo: f64, hi: f64| {
            reqs.iter()
                .filter(|r| r.model == m && (lo..hi).contains(&(r.arrival as f64 / 1_000.0)))
                .count() as f64
        };
        assert!(
            count(0, 0.0, 500.0) > 2.0 * count(0, 500.0, 1_000.0),
            "head model must cool down after the rotation"
        );
        assert!(
            count(4, 500.0, 1_000.0) > 2.0 * count(4, 0.0, 500.0),
            "rotated-in model must heat up"
        );
    }

    #[test]
    fn unified_run_is_deterministic_and_reports_both_planes() {
        let cfg = stress_cfg();
        let opts = ExecOpts::default();
        let a = run_stress(&cfg, RoutingPolicy::JoinShortestQueue, opts);
        let b = run_stress(&cfg, RoutingPolicy::JoinShortestQueue, opts);
        let (ja, jb) = (a.to_json().to_string_pretty(), b.to_json().to_string_pretty());
        assert_eq!(ja, jb, "same seed ⇒ identical unified report");
        assert!(ja.contains("\"adaptive\""), "control-plane stats attached");
        assert!(ja.contains("\"lifecycle\""), "memory-manager stats attached");
        assert!(ja.contains("\"cold_migration_ms\""), "unified always prices migrations");
        assert!(ja.starts_with("{\n  \"policy\": \"unified+"));
    }

    #[test]
    fn rotation_under_pressure_prices_migrations_by_cold_load() {
        let cfg = stress_cfg();
        let rep = run_stress(&cfg, RoutingPolicy::JoinShortestQueue, ExecOpts::default());
        let astats = rep.adaptive.as_ref().expect("adaptive stats");
        let lstats = rep.lifecycle.as_ref().expect("lifecycle stats");
        assert!(astats.replans > 0, "rotation must trip the drift detector");
        assert!(astats.rebalances > 0, "rotation must move replicas: {astats:?}");
        assert!(astats.replicas_added > 0, "{astats:?}");
        let cold = astats.cold_migration_ms.expect("unified fills cold pricing");
        // Footprint pricing diverges from the flat legacy charge: even a
        // parameter-shared reload of the smallest fleet model costs
        // ≥ 0.6 × 150 ms = 90 ms, vs the 50 ms flat rate per add.
        assert!(
            cold > astats.migration_ms,
            "cold pricing {cold} ms should exceed flat {} ms",
            astats.migration_ms
        );
        assert!(lstats.cold_starts > 0, "the rotated-in tail faults in cold");
        // Conservation still holds through replan surgery.
        let total = rep.served.iter().sum::<u64>()
            + rep.dropped.iter().sum::<u64>()
            + rep.rejected.iter().sum::<u64>();
        assert!(total > 0);
    }

    #[test]
    fn eviction_pressure_alone_triggers_replans() {
        // Detector effectively disabled (absurd fire threshold): any
        // replan must come from the pressure trigger. Tight budgets +
        // long-tail traffic guarantee eviction thrash.
        use crate::lifecycle::longtail_workload;
        let mk = |threshold: u64| UnifiedCfg {
            adaptive: AdaptiveCfg {
                interval_ms: 250.0,
                drift_threshold: 1e12,
                rearm_threshold: 1e9,
                ..Default::default()
            },
            lifecycle: LifecycleCfg {
                mem_budget_mib: 2_048,
                min_replicas: 1,
                ..Default::default()
            },
            eviction_replan_threshold: threshold,
        };
        let (profiles, rates, reqs) = longtail_workload(10, 1.1, 400.0, 2_000.0, 3);
        let run = |cfg: &UnifiedCfg| {
            run_unified(
                &profiles,
                &rates,
                &unified_gpus(2),
                PlacementPolicy::LoadBalance,
                RoutingPolicy::JoinShortestQueue,
                GpuSched::Dstack,
                cfg,
                reqs.clone(),
                2_000.0,
                3,
            )
        };
        let pressured = run(&mk(2));
        let pa = pressured.adaptive.as_ref().unwrap();
        let pl = pressured.lifecycle.as_ref().unwrap();
        assert!(pl.evictions > 0, "2 GiB budgets must thrash");
        assert!(pa.replans > 0, "eviction pressure must fire the tick: {pa:?}");
        let disabled = run(&mk(0));
        let da = disabled.adaptive.as_ref().unwrap();
        assert_eq!(da.replans, 0, "threshold 0 disables the pressure trigger");
    }

    #[test]
    fn unified_sparse_matches_epoch_bytes() {
        let cfg = stress_cfg();
        let run = |mode| {
            run_stress(
                &cfg,
                RoutingPolicy::JoinShortestQueue,
                ExecOpts { threads: Parallelism::Threads(1), mode, ..Default::default() },
            )
        };
        let sparse = run(ExecMode::Sparse).to_json().to_string_pretty();
        let epoch = run(ExecMode::Epoch).to_json().to_string_pretty();
        assert_eq!(sparse, epoch, "replan surgery broke sparse determinism");
    }

    #[test]
    fn warm_rr_fleet_elides_barriers_across_replans() {
        // Ample memory (everything preloads warm) + RR routing: spans
        // between control ticks are fully warm and backlog-free, so the
        // sparse core must elide stepping barriers even while drift
        // replans rewire the placement at tick boundaries.
        let cfg = UnifiedCfg {
            adaptive: AdaptiveCfg { interval_ms: 250.0, ..Default::default() },
            lifecycle: LifecycleCfg {
                mem_budget_mib: 0,
                idle_timeout_ms: 0.0,
                min_replicas: 1,
                ..Default::default()
            },
            eviction_replan_threshold: 8,
        };
        let rep = run_stress(
            &cfg,
            RoutingPolicy::RoundRobin,
            ExecOpts {
                threads: Parallelism::Threads(1),
                mode: ExecMode::Sparse,
                ..Default::default()
            },
        );
        let exec = rep.exec.expect("exec stats attached");
        assert!(exec.barriers_elided > 0, "warm RR spans elided nothing: {exec:?}");
        assert!(exec.arrivals_batched > 0);
    }

    #[test]
    fn config_validation_covers_both_planes() {
        assert!(UnifiedCfg::default().validate().is_ok());
        let bad_adaptive = UnifiedCfg {
            adaptive: AdaptiveCfg { alpha: 0.0, ..Default::default() },
            ..Default::default()
        };
        assert!(bad_adaptive.validate().is_err());
        let bad_lifecycle = UnifiedCfg {
            lifecycle: LifecycleCfg { min_replicas: 0, ..Default::default() },
            ..Default::default()
        };
        assert!(bad_lifecycle.validate().is_err());
    }
}
