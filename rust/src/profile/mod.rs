//! Model and GPU profiles: the measured quantities the paper obtains by
//! NVPROF profiling (§3, §4.4), reconstructed here by calibrating the
//! analytical model (§4.3) to the published operating points (Table 6,
//! §6.2, Fig. 3). All downstream components — the optimizer, the GPU
//! simulator and every scheduler — consume latency exclusively through
//! [`ModelProfile::latency_ms`], so the calibrated analytic surface is
//! the single latency oracle of the system.

use crate::analytic::{calibrate, AnalyticDnn};
use std::collections::BTreeMap;

/// A GPU device type (paper testbeds: V100, P100, T4).
#[derive(Debug, Clone, PartialEq)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Number of streaming multiprocessors.
    pub sms: u32,
    /// Max resident threads per SM (paper uses 2048 for the V100).
    pub threads_per_sm: u32,
    /// Device memory in MiB.
    pub mem_mib: u64,
    /// Arithmetic-intensity threshold (FLOP/byte); kernels above are
    /// compute-bound (§4.1; NVIDIA reports 139.8 for the V100).
    pub aint_threshold: f64,
    /// Relative *per-SM* throughput vs the V100 (clock/architecture);
    /// the SM-count difference is already captured by the analytic
    /// model's S-dependence, so this must not re-count it.
    pub rel_capacity: f64,
}

pub const V100: GpuSpec = GpuSpec {
    name: "V100",
    sms: 80,
    threads_per_sm: 2048,
    mem_mib: 16 * 1024,
    aint_threshold: 139.8,
    rel_capacity: 1.0,
};

pub const P100: GpuSpec = GpuSpec {
    name: "P100",
    sms: 56,
    threads_per_sm: 2048,
    mem_mib: 16 * 1024,
    aint_threshold: 66.0,
    rel_capacity: 0.85,
};

pub const T4: GpuSpec = GpuSpec {
    name: "T4",
    sms: 40,
    threads_per_sm: 1024,
    mem_mib: 16 * 1024,
    aint_threshold: 203.0,
    rel_capacity: 0.85,
};

impl GpuSpec {
    pub fn by_name(name: &str) -> Option<&'static GpuSpec> {
        match name {
            "V100" => Some(&V100),
            "P100" => Some(&P100),
            "T4" => Some(&T4),
            _ => None,
        }
    }

    /// SM count for a GPU percentage (paper: 50% of V100 = 40 SMs).
    pub fn sms_for_pct(&self, pct: u32) -> u32 {
        ((pct.min(100) as f64 / 100.0 * self.sms as f64).round() as u32).max(1)
    }

    /// GPU% needed to run `threads` concurrently (Fig. 5's Y2 axis).
    pub fn pct_for_threads(&self, threads: u64) -> f64 {
        let total = self.sms as u64 * self.threads_per_sm as u64;
        threads as f64 / total as f64 * 100.0
    }
}

/// One representative GPU kernel of a model (Table 2 / Fig. 5 data).
#[derive(Debug, Clone)]
pub struct KernelInfo {
    pub name: &'static str,
    /// Floating point operations per invocation.
    pub gflops: f64,
    /// Bytes moved per invocation (×10⁶).
    pub mbytes: f64,
    /// GPU threads requested.
    pub threads: u64,
    /// Runtime share of one inference (fraction, for Fig. 5 bubbles).
    pub runtime_frac: f64,
    /// Times this kernel runs per inference (`R_i`).
    pub reps: u32,
}

impl KernelInfo {
    /// Arithmetic intensity in FLOP/byte (§4.1).
    pub fn arithmetic_intensity(&self) -> f64 {
        self.gflops * 1e9 / (self.mbytes * 1e6)
    }

    /// Compute- or memory-bound classification against a GPU threshold.
    pub fn is_compute_bound(&self, gpu: &GpuSpec) -> bool {
        self.arithmetic_intensity() >= gpu.aint_threshold
    }
}

/// Everything the framework knows about one servable model.
#[derive(Debug, Clone)]
pub struct ModelProfile {
    pub name: String,
    /// Knee GPU% on the V100 at the profiled batch (Table 6 col 2).
    pub knee_pct: u32,
    /// Application SLO in ms (Table 6 col 3).
    pub slo_ms: f64,
    /// Profiled/optimal batch size (Table 6 col 4).
    pub opt_batch: u32,
    /// Runtime at (knee, opt_batch) in ms (Table 6 col 5).
    pub runtime_ms: f64,
    /// Calibrated analytical latency model.
    pub dnn: AnalyticDnn,
    /// Cold model-load time (framework init + weight upload), ms (§3.2
    /// reports "10s of seconds" for big frameworks; we default 8000).
    pub load_ms: f64,
    /// GPU memory footprint of loaded weights+activations, MiB.
    pub mem_mib: u64,
    /// Representative kernels (may be empty for schedulers-only models).
    pub kernels: Vec<KernelInfo>,
    /// Maximum batch size the model accepts (Eq. 10's MaxBatchSize).
    pub max_batch: u32,
}

impl ModelProfile {
    /// Latency (ms) at `gpu_pct`% of `gpu` with batch `b` — the f_L(p,b)
    /// surface of §5 (fitted there; analytic here).
    pub fn latency_ms_on(&self, gpu: &GpuSpec, gpu_pct: u32, b: u32) -> f64 {
        let sms = gpu.sms_for_pct(gpu_pct);
        self.dnn.latency_ms(sms as f64, b as f64) / gpu.rel_capacity
    }

    /// Latency on the default V100 testbed.
    pub fn latency_ms(&self, gpu_pct: u32, b: u32) -> f64 {
        self.latency_ms_on(&V100, gpu_pct, b)
    }

    /// Knee GPU% on an arbitrary GPU at batch `b`.
    pub fn knee_pct_on(&self, gpu: &GpuSpec, b: u32) -> u32 {
        let sms = self.dnn.knee_sms(b as f64, gpu.sms);
        ((sms as f64 / gpu.sms as f64) * 100.0).ceil() as u32
    }

    /// Throughput (items/s) at an operating point.
    pub fn throughput(&self, gpu_pct: u32, b: u32) -> f64 {
        b as f64 / (self.latency_ms(gpu_pct, b) / 1000.0)
    }
}

fn model(
    name: &str,
    knee_pct: u32,
    slo_ms: f64,
    opt_batch: u32,
    runtime_ms: f64,
    serial_frac: f64,
    mem_mib: u64,
    kernels: Vec<KernelInfo>,
) -> ModelProfile {
    let knee_sms = V100.sms_for_pct(knee_pct);
    let dnn = calibrate(knee_sms, runtime_ms, opt_batch as f64, V100.sms, serial_frac);
    ModelProfile {
        name: name.to_string(),
        knee_pct,
        slo_ms,
        opt_batch,
        runtime_ms,
        dnn,
        load_ms: 8_000.0,
        mem_mib,
        kernels,
        max_batch: 16,
    }
}

/// The paper's Table 6 model zoo, calibrated so that knee%, SLO, batch
/// and runtime match the published values on the V100.
pub fn zoo() -> Vec<ModelProfile> {
    vec![
        model("mobilenet", 20, 25.0, 16, 10.0, 0.45, 600, mobilenet_kernels()),
        model("alexnet", 30, 25.0, 16, 8.0, 0.35, 800, alexnet_kernels()),
        model("bert", 30, 25.0, 16, 9.0, 0.35, 1300, bert_kernels()),
        model("resnet50", 40, 50.0, 16, 28.0, 0.25, 1100, resnet50_kernels()),
        model("vgg19", 50, 100.0, 16, 55.0, 0.15, 2200, vgg19_kernels()),
        model("resnet18", 30, 25.0, 16, 12.0, 0.35, 700, Vec::new()),
        model("inception", 40, 50.0, 16, 25.0, 0.25, 1000, Vec::new()),
        model("resnext50", 50, 100.0, 16, 40.0, 0.15, 1200, Vec::new()),
    ]
}

/// §6.2's three LeNet-style ConvNets (knee-runtime pairs as published).
pub fn convnets() -> Vec<ModelProfile> {
    vec![
        model("convnet1", 30, 50.0, 16, 10.3, 0.35, 200, Vec::new()),
        model("convnet2", 40, 50.0, 16, 14.6, 0.30, 260, Vec::new()),
        model("convnet3", 60, 100.0, 16, 15.4, 0.20, 320, Vec::new()),
    ]
}

/// Fig. 3's light models for the P100/T4 cross-GPU validation.
pub fn light_models() -> Vec<ModelProfile> {
    vec![
        model("squeezenet", 20, 25.0, 16, 7.0, 0.45, 300, Vec::new()),
        model("alexnet", 30, 25.0, 16, 8.0, 0.35, 800, alexnet_kernels()),
        model("resnet50", 40, 50.0, 16, 28.0, 0.25, 1100, resnet50_kernels()),
    ]
}

/// Look up a model by name across all built-in profiles.
pub fn by_name(name: &str) -> Option<ModelProfile> {
    zoo()
        .into_iter()
        .chain(convnets())
        .chain(light_models())
        .chain(std::iter::once(gnmt_profile()))
        .find(|m| m.name == name)
}

/// Registry keyed by name (convenience for config loading).
pub fn registry() -> BTreeMap<String, ModelProfile> {
    let mut map = BTreeMap::new();
    for m in zoo().into_iter().chain(convnets()).chain(light_models()) {
        map.entry(m.name.clone()).or_insert(m);
    }
    map.insert("gnmt".into(), gnmt_profile());
    map
}

/// BERT on 20-word sentences (Fig. 6b): double the tokens roughly
/// doubles the attention work — higher latency, knee moves right
/// (paper: 30% → 40%).
pub fn bert_long() -> ModelProfile {
    model("bert20", 40, 25.0, 16, 15.0, 0.3, 1300, bert_kernels())
}

/// GNMT appears only in Table 2 (memory-bound LSTM kernel).
pub fn gnmt_profile() -> ModelProfile {
    model(
        "gnmt",
        50,
        100.0,
        16,
        60.0,
        0.5,
        1800,
        vec![KernelInfo {
            name: "LSTM",
            gflops: 0.016,
            mbytes: 8.38,
            threads: 65_536,
            runtime_frac: 0.6,
            reps: 8,
        }],
    )
}

// ---- Table 2 kernels ------------------------------------------------------
// GFLOPs and bytes follow the paper's Table 2. Where the printed FLOPs,
// bytes and A.int are mutually inconsistent (Alexnet Conv.2: 0.30 GFLOP /
// 0.22 MB would be 1364 FLOP/B, printed 182; ResNet-50 Conv.2: would be
// 851, printed 393) we keep the printed *A.int* — the quantity the
// classification in §4.1 actually uses — and derive bytes from it.

fn alexnet_kernels() -> Vec<KernelInfo> {
    vec![KernelInfo {
        name: "Conv.2",
        gflops: 0.30,
        mbytes: 0.30e3 / 182.0, // bytes chosen so A.int = 182 (printed)
        threads: 290_400,
        runtime_frac: 0.22,
        reps: 1,
    }]
}

fn resnet50_kernels() -> Vec<KernelInfo> {
    vec![KernelInfo {
        name: "Conv.2",
        gflops: 0.103,
        mbytes: 0.103e3 / 393.0, // A.int = 393 (printed)
        threads: 200_704,
        runtime_frac: 0.05,
        reps: 16,
    }]
}

fn vgg19_kernels() -> Vec<KernelInfo> {
    vec![KernelInfo {
        name: "Conv.11",
        gflops: 3.7,
        mbytes: 9.44, // consistent with printed A.int 391
        threads: 401_408,
        runtime_frac: 0.09,
        reps: 4,
    }]
}

fn bert_kernels() -> Vec<KernelInfo> {
    vec![KernelInfo {
        name: "attention",
        gflops: 0.18,
        mbytes: 1.2,
        threads: 49_152,
        runtime_frac: 0.35,
        reps: 12,
    }]
}

/// Fig. 5: Mobilenet's 11 distinct kernels, 156 executions total.
/// Thread counts and runtime shares are synthesized to match the figure's
/// description: kernels 3, 4 and 6 demand > 100% of the V100
/// (> 163,840 threads) but are short; kernels 7 and 10 run long at < 10%.
fn mobilenet_kernels() -> Vec<KernelInfo> {
    let k = |name, threads, runtime_frac, reps, gflops, mbytes| KernelInfo {
        name,
        threads,
        runtime_frac,
        reps,
        gflops,
        mbytes,
    };
    vec![
        k("conv_s2", 100_352, 0.04, 1, 0.021, 0.30),
        k("dwconv3x3_a", 150_528, 0.06, 4, 0.009, 0.60),
        k("conv1x1_expand_a", 602_112, 0.03, 5, 0.055, 0.25),   // >100% GPU
        k("relu6", 802_816, 0.02, 35, 0.001, 0.80),             // >100% GPU
        k("dwconv3x3_b", 75_264, 0.07, 8, 0.012, 0.45),
        k("conv1x1_expand_b", 301_056, 0.04, 10, 0.060, 0.22),  // >100% GPU
        k("conv1x1_project", 12_544, 0.28, 22, 0.048, 0.18),    // long, <10%
        k("dwconv3x3_c", 25_088, 0.09, 18, 0.014, 0.35),
        k("batchnorm", 50_176, 0.05, 35, 0.002, 0.50),
        k("conv1x1_tail", 6_272, 0.26, 17, 0.052, 0.15),        // long, <10%
        k("global_pool_fc", 2_048, 0.06, 1, 0.003, 0.08),
    ]
}

/// Total kernel executions per inference (Fig. 5 reports 156).
pub fn mobilenet_kernel_executions() -> u32 {
    mobilenet_kernels().iter().map(|k| k.reps).sum()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn table6_operating_points_reproduced() {
        // knee%, SLO, batch and runtime must match the paper's Table 6.
        let want: &[(&str, u32, f64, u32, f64)] = &[
            ("mobilenet", 20, 25.0, 16, 10.0),
            ("alexnet", 30, 25.0, 16, 8.0),
            ("bert", 30, 25.0, 16, 9.0),
            ("resnet50", 40, 50.0, 16, 28.0),
            ("vgg19", 50, 100.0, 16, 55.0),
            ("resnet18", 30, 25.0, 16, 12.0),
            ("inception", 40, 50.0, 16, 25.0),
            ("resnext50", 50, 100.0, 16, 40.0),
        ];
        let zoo = zoo();
        assert_eq!(zoo.len(), want.len());
        for (m, (name, knee, slo, batch, rt)) in zoo.iter().zip(want) {
            assert_eq!(&m.name, name);
            assert_eq!(m.knee_pct, *knee);
            assert_eq!(m.slo_ms, *slo);
            assert_eq!(m.opt_batch, *batch);
            // Calibrated latency at the knee equals the published runtime.
            let lat = m.latency_ms(m.knee_pct, m.opt_batch);
            assert!(
                (lat - rt).abs() / rt < 1e-6,
                "{name}: latency at knee {lat} vs published {rt}"
            );
            // And the analytic knee really is at the published GPU%.
            assert_eq!(m.knee_pct_on(&V100, m.opt_batch), *knee, "{name} knee");
        }
    }

    #[test]
    fn latency_increases_below_knee() {
        for m in zoo() {
            let at_knee = m.latency_ms(m.knee_pct, 16);
            let below = m.latency_ms(m.knee_pct / 2, 16);
            assert!(
                below > at_knee * 1.5,
                "{}: below-knee {below} vs knee {at_knee}",
                m.name
            );
            // Above the knee the improvement is marginal (< 25%).
            let above = m.latency_ms(100, 16);
            assert!(above > at_knee * 0.75, "{}: {above} vs {at_knee}", m.name);
        }
    }

    #[test]
    fn table2_aint_classification() {
        // Compute-bound: alexnet/resnet50/vgg19 conv kernels; memory-bound:
        // GNMT LSTM (A.int ≈ 2 < 139.8).
        let alex = &alexnet_kernels()[0];
        assert!((alex.arithmetic_intensity() - 182.0).abs() < 1.0);
        assert!(alex.is_compute_bound(&V100));
        let r50 = &resnet50_kernels()[0];
        assert!((r50.arithmetic_intensity() - 393.0).abs() < 1.0);
        assert!(r50.is_compute_bound(&V100));
        let vgg = &vgg19_kernels()[0];
        assert!((vgg.arithmetic_intensity() - 391.0).abs() < 3.0);
        assert!(vgg.is_compute_bound(&V100));
        let lstm = &gnmt_profile().kernels[0];
        assert!(lstm.arithmetic_intensity() < 3.0);
        assert!(!lstm.is_compute_bound(&V100));
    }

    #[test]
    fn mobilenet_fig5_shape() {
        let ks = mobilenet_kernels();
        assert_eq!(ks.len(), 11, "11 distinct kernels");
        assert_eq!(mobilenet_kernel_executions(), 156, "156 executions");
        // Runtime fractions sum to ~1.
        let total: f64 = ks.iter().map(|k| k.runtime_frac).sum();
        assert!((total - 1.0).abs() < 1e-9);
        // Some kernels demand >100% GPU; they must be short.
        let over: Vec<_> = ks.iter().filter(|k| V100.pct_for_threads(k.threads) > 100.0).collect();
        assert_eq!(over.len(), 3);
        for k in &over {
            assert!(k.runtime_frac < 0.05, "{} is over-100% but long", k.name);
        }
        // The biggest runtime contributors demand <10% GPU.
        let mut by_rt = ks.clone();
        by_rt.sort_by(|a, b| b.runtime_frac.total_cmp(&a.runtime_frac));
        for k in &by_rt[..2] {
            assert!(V100.pct_for_threads(k.threads) < 10.0, "{}", k.name);
        }
    }

    #[test]
    fn runtime_frac_sort_total_cmp() {
        // Regression for the NaN-unsafe partial_cmp().unwrap() the
        // descending runtime_frac sort used: total_cmp matches
        // partial_cmp on the finite fractions kernel tables hold, and a
        // NaN key (greatest in the total order, so first in a descending
        // sort) orders deterministically instead of panicking.
        let mut by_rt = mobilenet_kernels();
        by_rt.sort_by(|a, b| b.runtime_frac.total_cmp(&a.runtime_frac));
        for w in by_rt.windows(2) {
            assert!(w[0].runtime_frac >= w[1].runtime_frac);
        }
        let mut keys = vec![0.3f64, f64::NAN, 0.5, 0.2];
        keys.sort_by(|a, b| b.total_cmp(a));
        assert!(keys[0].is_nan());
        assert_eq!(&keys[1..], &[0.5, 0.3, 0.2]);
    }

    #[test]
    fn gpu_pct_to_sms() {
        assert_eq!(V100.sms_for_pct(50), 40); // paper's example
        assert_eq!(V100.sms_for_pct(100), 80);
        assert_eq!(V100.sms_for_pct(0), 1); // clamp: at least one SM
        assert_eq!(T4.sms_for_pct(50), 20);
    }

    #[test]
    fn cross_gpu_knee_exists_for_light_models() {
        // Fig. 3: alexnet/squeezenet show a knee on P100 and T4 too.
        for m in light_models() {
            if m.name == "resnet50" {
                continue; // paper: no obvious knee on smaller GPUs
            }
            for gpu in [&P100, &T4] {
                let knee = m.knee_pct_on(gpu, 16);
                assert!(
                    knee < 100,
                    "{} on {} should knee below 100% (got {knee})",
                    m.name,
                    gpu.name
                );
            }
        }
    }

    #[test]
    fn convnet_profiles_match_section_6_2() {
        let cs = convnets();
        let want = [("convnet1", 30, 10.3), ("convnet2", 40, 14.6), ("convnet3", 60, 15.4)];
        for (c, (name, knee, rt)) in cs.iter().zip(want) {
            assert_eq!(c.name, name);
            assert_eq!(c.knee_pct, knee);
            let lat = c.latency_ms(c.knee_pct, 16);
            assert!((lat - rt).abs() / rt < 1e-6);
        }
    }

    #[test]
    fn registry_contains_all() {
        let r = registry();
        for name in
            ["mobilenet", "alexnet", "bert", "resnet50", "vgg19", "convnet1", "squeezenet", "gnmt"]
        {
            assert!(r.contains_key(name), "missing {name}");
        }
        assert!(by_name("vgg19").is_some());
        assert!(by_name("nonexistent").is_none());
    }

    #[test]
    fn throughput_at_knee_matches_ratio() {
        let m = by_name("resnet50").unwrap();
        let t = m.throughput(40, 16);
        // 16 images / 28 ms ≈ 571 img/s.
        assert!((t - 16.0 / 0.028).abs() < 1.0, "{t}");
    }
}
