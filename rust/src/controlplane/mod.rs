//! Adaptive control plane: runtime re-optimization and cluster
//! rebalancing under rate drift.
//!
//! The cluster layer ([`crate::cluster`]) computes a knee-packed
//! placement once at t = 0 and never revisits it — under dynamic-rate
//! traces (the paper's Fig. 11b regime, generalized to a cluster) a load
//! shift strands replicas on the wrong GPUs: the formerly-hot model
//! holds knee budget it no longer needs while the newly-hot model
//! saturates its lone replica. This module closes the loop between
//! observation and allocation with a dataflow of three stages driven by
//! a periodic control tick on the global virtual clock:
//!
//! 1. **[`RateEstimator`]** — a per-model EWMA over per-tick arrival
//!    counts sampled by the cluster driver (every request the router
//!    sees, including admission-rejected ones: the *demand* signal, not
//!    the served rate).
//! 2. **[`DriftDetector`]** — compares estimates against the rates the
//!    current placement was solved for, with hysteresis: a model opens
//!    a *drift episode* when its relative deviation exceeds
//!    `drift_threshold`; an open episode replans every `cooldown_ticks`
//!    until the deviation converges below `rearm_threshold`
//!    (`rearm < drift`), which closes it. Deviations that only wander
//!    into the band between the two thresholds never open an episode —
//!    noisy rates cannot flap the placement, while a step change
//!    triggers a bounded burst of replans until the EWMA settles.
//! 3. **Rebalancer** — on drift, re-solves operating points and packing
//!    by re-running [`crate::cluster::placement::place`] (which derives
//!    each model's fresh knee/batch point per GPU type through
//!    [`crate::cluster::placement::op_point`] — the §5 optimizer at the
//!    knee, the right point when multiplexing, see
//!    [`crate::sim::entries_at_optimum`]) against the *estimated* rates,
//!    then computes an incremental [`RebalanceDelta`] against the live
//!    replica set: replicas to remove and replicas to add. Removals
//!    apply first and additions only become routable after a
//!    `migration_cost_ms` model-load delay, so a GPU's knee budget is
//!    never oversubscribed mid-flight (see [`placement_delta`] and the
//!    budget invariant in [`run_adaptive`]).
//!
//! Replica removal drains the replica's queued requests and re-routes
//! them to the model's surviving replicas (requests keep their original
//! arrival time and deadline — end-to-end latency accounting is
//! unaffected); in-flight batches complete on the old GPU and are
//! counted there. A removed replica's engine slot becomes a *tombstone*
//! that a later re-activation of the same model reuses, so an engine's
//! model table only ever grows to the number of distinct models placed
//! on it.
//!
//! The outcome of an adaptive run is an ordinary
//! [`crate::cluster::ClusterReport`] whose `adaptive` field carries
//! [`AdaptiveStats`]: replan/rebalance counts, migration cost, and
//! per-model p99 before vs after the first applied rebalance — the
//! adaptive-vs-static comparison is a first-class reportable figure
//! (`figures::fig13`, `dstack adaptive`).

use crate::cluster::exec::{run_epochs_stream, EpochDriver, ExecEngine, Touched};
use crate::cluster::routing::BacklogCache;
use crate::cluster::{
    place, ClusterReport, ExecOpts, GpuModelShare, GpuReport, GpuSched, Placement,
    PlacementPolicy, Replica, Router, RoutingPolicy,
};
use crate::cluster::p99_of;
use crate::faults::{
    pick_hedge_target, queue_est_us, FaultKind, Resilience, ResilienceCfg, SloClass,
};
use crate::gpu::{ms_to_us, Us};
use crate::overload::{co_locate_variants, Overload, OverloadSpec, RejectKind};
use crate::metrics::RunReport;
use crate::obs::{EngineObs, EventKind, ObsCfg, ObsReport, Recorder, NO_MODEL};
use crate::profile::{GpuSpec, ModelProfile};
use crate::sim::{ModelEntry, Sim, SimConfig};
use crate::util::json::Json;
use crate::util::stats::{percentile, LogHistogram};
use crate::workload::{ArrivalStream, Arrivals, MaterializedStream, Request};

/// Control-plane configuration (the scenario `"adaptive"` block — see
/// `docs/CONFIG.md`).
#[derive(Debug, Clone)]
pub struct AdaptiveCfg {
    /// Control-tick period (ms of virtual time).
    pub interval_ms: f64,
    /// EWMA smoothing factor in (0, 1]: weight of the newest window.
    pub alpha: f64,
    /// Relative deviation |est − planned| / max(planned, 1) at which a
    /// model enters the drifted state and a replan fires.
    pub drift_threshold: f64,
    /// Deviation below which a drifted model re-arms (must be below
    /// `drift_threshold` — the hysteresis band).
    pub rearm_threshold: f64,
    /// Minimum control ticks between replans.
    pub cooldown_ticks: u32,
    /// Model-load delay before an added replica becomes routable (ms);
    /// the §3.2 reconfiguration cost, charged per migration.
    pub migration_cost_ms: f64,
}

impl Default for AdaptiveCfg {
    fn default() -> Self {
        AdaptiveCfg {
            interval_ms: 500.0,
            alpha: 0.3,
            drift_threshold: 0.3,
            rearm_threshold: 0.15,
            cooldown_ticks: 2,
            migration_cost_ms: 50.0,
        }
    }
}

impl AdaptiveCfg {
    /// Validate ranges; returns a message naming the offending field.
    pub fn validate(&self) -> Result<(), String> {
        let bad = |v: f64| v.is_nan();
        if bad(self.interval_ms) || self.interval_ms <= 0.0 {
            return Err("adaptive.interval_ms must be > 0".into());
        }
        if bad(self.alpha) || self.alpha <= 0.0 || self.alpha > 1.0 {
            return Err("adaptive.alpha must be in (0, 1]".into());
        }
        if bad(self.drift_threshold) || self.drift_threshold <= 0.0 {
            return Err("adaptive.drift_threshold must be > 0".into());
        }
        if bad(self.rearm_threshold)
            || self.rearm_threshold < 0.0
            || self.rearm_threshold >= self.drift_threshold
        {
            return Err("adaptive.rearm_threshold must be in [0, drift_threshold)".into());
        }
        if bad(self.migration_cost_ms) || self.migration_cost_ms < 0.0 {
            return Err("adaptive.migration_cost_ms must be >= 0".into());
        }
        Ok(())
    }
}

/// Per-model EWMA rate estimator over fixed observation windows.
#[derive(Debug, Clone)]
pub struct RateEstimator {
    alpha: f64,
    rates: Vec<f64>,
}

impl RateEstimator {
    /// Seed the estimate with the rates the initial placement was solved
    /// for, so the detector starts from a consistent state.
    pub fn new(alpha: f64, initial_rates: &[f64]) -> RateEstimator {
        RateEstimator { alpha, rates: initial_rates.to_vec() }
    }

    /// Fold one observation window (per-model arrival counts over
    /// `window_s` seconds) into the estimates.
    pub fn observe(&mut self, counts: &[u64], window_s: f64) {
        debug_assert_eq!(counts.len(), self.rates.len());
        debug_assert!(window_s > 0.0);
        for (rate, &c) in self.rates.iter_mut().zip(counts) {
            let measured = c as f64 / window_s;
            *rate = self.alpha * measured + (1.0 - self.alpha) * *rate;
        }
    }

    /// Current per-model rate estimates (req/s).
    pub fn rates(&self) -> &[f64] {
        &self.rates
    }
}

/// Hysteresis drift detector (stage 2 of the module dataflow).
#[derive(Debug, Clone)]
pub struct DriftDetector {
    fire: f64,
    rearm: f64,
    cooldown: u32,
    drifted: Vec<bool>,
    ticks_since_replan: u32,
}

impl DriftDetector {
    pub fn new(cfg: &AdaptiveCfg, n_models: usize) -> DriftDetector {
        DriftDetector {
            fire: cfg.drift_threshold,
            rearm: cfg.rearm_threshold,
            cooldown: cfg.cooldown_ticks,
            drifted: vec![false; n_models],
            // Ready to fire on the very first tick if drift is present.
            ticks_since_replan: cfg.cooldown_ticks,
        }
    }

    /// Relative deviation of an estimate from the planned rate, with an
    /// absolute floor of 1 req/s so silent models waking up register as
    /// infinite-relative drift without dividing by zero.
    pub fn deviation(estimated: f64, planned: f64) -> f64 {
        (estimated - planned).abs() / planned.max(1.0)
    }

    /// Advance one control tick. Returns `true` when a replan should
    /// fire. Hysteresis: a model *opens* a drift episode when its
    /// deviation exceeds the fire threshold, and the episode stays open
    /// — triggering a replan every `cooldown_ticks` — until the
    /// deviation converges below the rearm threshold (replans refresh
    /// the planned rates, so a settled estimate closes the episode
    /// within a tick or two). A deviation that merely wanders into the
    /// band (rearm, fire] without crossing fire never opens an episode,
    /// which is what keeps noisy rates from flapping the placement.
    /// The caller must re-solve the placement against the estimates on
    /// `true` and treat them as the new planned rates.
    pub fn tick(&mut self, estimated: &[f64], planned: &[f64]) -> bool {
        debug_assert_eq!(estimated.len(), planned.len());
        self.ticks_since_replan = self.ticks_since_replan.saturating_add(1);
        for (m, (&est, &pl)) in estimated.iter().zip(planned).enumerate() {
            let d = Self::deviation(est, pl);
            if self.drifted[m] {
                if d < self.rearm {
                    self.drifted[m] = false;
                }
            } else if d > self.fire {
                self.drifted[m] = true;
            }
        }
        let episode_open = self.drifted.iter().any(|&x| x);
        if episode_open && self.ticks_since_replan >= self.cooldown {
            self.ticks_since_replan = 0;
            true
        } else {
            false
        }
    }
}

/// An incremental placement change: replicas to tear down and replicas
/// to bring up. Removals always apply before additions so per-GPU knee
/// budgets stay within 100% throughout the migration.
#[derive(Debug, Clone, Default)]
pub struct RebalanceDelta {
    /// (model, target replica) — `local` is assigned at activation.
    pub add: Vec<(usize, Replica)>,
    /// (model, gpu, freed knee pct).
    pub remove: Vec<(usize, usize, u32)>,
}

impl RebalanceDelta {
    pub fn is_empty(&self) -> bool {
        self.add.is_empty() && self.remove.is_empty()
    }
}

/// Diff the live replica set against a freshly solved target placement.
/// `current[m]` lists (gpu, knee pct) of model `m`'s live (and pending)
/// replicas. Replicas present in both are kept untouched — operating
/// points depend only on (model, GPU type), so a kept replica's point
/// never changes across re-solves. Fully deterministic: models ascending,
/// GPUs in the target's own deterministic order.
pub fn placement_delta(current: &[Vec<(usize, u32)>], target: &Placement) -> RebalanceDelta {
    let mut delta = RebalanceDelta::default();
    for (m, cur) in current.iter().enumerate() {
        let want = &target.replicas[m];
        for &(gpu, pct) in cur {
            if !want.iter().any(|r| r.gpu == gpu) {
                delta.remove.push((m, gpu, pct));
            }
        }
        for r in want {
            if !cur.iter().any(|&(gpu, _)| gpu == r.gpu) {
                delta.add.push((m, r.clone()));
            }
        }
    }
    delta
}

/// Apply a delta to per-GPU knee loads (removals first), returning the
/// load after removals and after additions. Panics if additions would
/// push any GPU past 100% — the rebalancer must never schedule an
/// oversubscribing migration.
pub fn apply_delta_to_knee_load(
    knee_load: &[u32],
    delta: &RebalanceDelta,
) -> (Vec<u32>, Vec<u32>) {
    let mut after_remove = knee_load.to_vec();
    for &(_, gpu, pct) in &delta.remove {
        after_remove[gpu] = after_remove[gpu]
            .checked_sub(pct)
            .expect("removing more knee pct than the GPU holds");
    }
    let mut after_add = after_remove.clone();
    for (m, r) in &delta.add {
        after_add[r.gpu] += r.pct;
        assert!(
            after_add[r.gpu] <= 100,
            "rebalance oversubscribes gpu {} to {}% (adding model {m})",
            r.gpu,
            after_add[r.gpu]
        );
    }
    (after_remove, after_add)
}

/// Control-plane telemetry attached to an adaptive run's
/// [`ClusterReport`].
#[derive(Debug, Clone, Default)]
pub struct AdaptiveStats {
    /// Drift firings (placement re-solves), including no-op ones.
    pub replans: u64,
    /// Replans whose delta actually moved replicas.
    pub rebalances: u64,
    pub replicas_added: u64,
    pub replicas_removed: u64,
    /// Total model-load time charged to migrations (ms) at the legacy
    /// flat `migration_cost_ms` — kept flat-cost exact so old configs
    /// and the adaptive golden shape never move.
    pub migration_ms: f64,
    /// Footprint-aware migration cost (ms): each replica add priced by
    /// the `cold_load_ms` of the weights actually loaded at its target
    /// (parameter sharing included). `None` on the legacy adaptive path
    /// — only the unified control plane fills (and serializes) it, so
    /// adaptive report bytes are unchanged.
    pub cold_migration_ms: Option<f64>,
    /// Virtual times of applied (non-empty) rebalances (µs).
    pub rebalance_times_us: Vec<Us>,
    /// Final EWMA rate estimates (req/s per model).
    pub est_rates: Vec<f64>,
    /// Per-model p99 latency (ms) over completions before the first
    /// applied rebalance (the whole run when none was applied).
    pub p99_before_ms: Vec<f64>,
    /// Per-model p99 latency (ms) over completions at or after the
    /// first applied rebalance (NaN-free: 0 when no samples).
    pub p99_after_ms: Vec<f64>,
}

impl AdaptiveStats {
    pub fn first_rebalance_us(&self) -> Option<Us> {
        self.rebalance_times_us.first().copied()
    }

    /// Deterministic JSON form (embedded in `ClusterReport::to_json`).
    /// `cold_migration_ms` is emitted only when set (unified runs), so
    /// legacy adaptive shapes — and their goldens — stay byte-stable.
    pub fn to_json(&self) -> Json {
        let mut fields = vec![
            ("replans", Json::from(self.replans)),
            ("rebalances", Json::from(self.rebalances)),
            ("replicas_added", Json::from(self.replicas_added)),
            ("replicas_removed", Json::from(self.replicas_removed)),
            ("migration_ms", Json::from(self.migration_ms)),
        ];
        if let Some(cold) = self.cold_migration_ms {
            fields.push(("cold_migration_ms", Json::from(cold)));
        }
        fields.extend([
            (
                "rebalance_times_us",
                Json::Arr(self.rebalance_times_us.iter().map(|&t| Json::from(t)).collect()),
            ),
            ("est_rates", Json::arr_f64(&self.est_rates)),
            ("p99_before_ms", Json::arr_f64(&self.p99_before_ms)),
            ("p99_after_ms", Json::arr_f64(&self.p99_after_ms)),
        ]);
        Json::obj(fields)
    }
}

/// One live (or pending) replica tracked by the driver. A pending
/// replica (`local == None`) becomes routable when its activation event
/// — tracked in the driver's `pending` list with its effective time —
/// matures.
#[derive(Debug, Clone)]
struct LiveRep {
    gpu: usize,
    pct: u32,
    batch: u32,
    capacity_rps: f64,
    /// Engine-local model index once activated.
    local: Option<usize>,
}

/// Activate `rep` (a replica of global `model`) on its GPU's engine,
/// creating the engine on first use, reusing the model's tombstone slot
/// when it served here before, and rebuilding the per-GPU policy from
/// the updated entry table. Fills in `rep.local`.
#[allow(clippy::too_many_arguments)]
fn activate_replica(
    engines: &mut [Option<ExecEngine>],
    local_map: &mut [Vec<usize>],
    profiles: &[ModelProfile],
    gpus: &[GpuSpec],
    horizon_ms: f64,
    obs_cfg: ObsCfg,
    sched: GpuSched,
    model: usize,
    rep: &mut LiveRep,
) {
    let g = rep.gpu;
    if engines[g].is_none() {
        let sim_cfg =
            SimConfig { gpu: gpus[g].clone(), horizon_ms, obs: obs_cfg, ..Default::default() };
        engines[g] = Some(ExecEngine {
            sim: Sim::new(sim_cfg, Vec::new()),
            policy: sched.build(&[]),
        });
    }
    let engine = engines[g].as_mut().expect("engine just created");
    let entry = ModelEntry { profile: profiles[model].clone(), pct: rep.pct, batch: rep.batch };
    let local = match local_map[g].iter().position(|&gm| gm == model) {
        Some(li) => {
            engine.sim.reactivate_model(li, entry);
            li
        }
        None => {
            let li = engine.sim.add_model(entry);
            debug_assert_eq!(li, local_map[g].len());
            local_map[g].push(model);
            li
        }
    };
    rep.local = Some(local);
    engine.rebuild_policy(sched);
}

/// Routable replicas of `model`: live entries whose engine slot is
/// assigned (pending migrations are excluded until they mature).
fn routable_of(live: &[Vec<LiveRep>], model: usize) -> Vec<Replica> {
    live[model]
        .iter()
        .filter(|r| r.local.is_some())
        .map(|r| Replica {
            gpu: r.gpu,
            local: r.local.expect("filtered on local"),
            pct: r.pct,
            batch: r.batch,
            capacity_rps: r.capacity_rps,
        })
        .collect()
}

/// The adaptive driver's barrier work on the cluster execution core
/// ([`crate::cluster::exec`]): mature pending activations before
/// arrivals, route demand-counted arrivals, and run the
/// estimate→detect→rebalance control tick after them.
struct AdaptiveDriver<'a> {
    profiles: &'a [ModelProfile],
    gpus: &'a [GpuSpec],
    placement: PlacementPolicy,
    sched: GpuSched,
    cfg: &'a AdaptiveCfg,
    horizon_ms: f64,
    horizon: Us,
    interval: Us,
    migration_us: Us,
    window_s: f64,
    live: Vec<Vec<LiveRep>>,
    /// Routable view handed to the router: rebuilt whenever `live`
    /// changes.
    routable: Vec<Vec<Replica>>,
    /// model → GPUs with a routable replica (the sparse core's
    /// candidate index), kept in lockstep with `routable`.
    cand: Vec<Vec<usize>>,
    /// gpu → engine-local index → global model index.
    local_map: Vec<Vec<usize>>,
    knee_load: Vec<u32>,
    shed_rps: Vec<f64>,
    estimator: RateEstimator,
    detector: DriftDetector,
    planned_rates: Vec<f64>,
    window_counts: Vec<u64>,
    stats: AdaptiveStats,
    /// (effective_at, model, index into live[model]) of pending adds.
    pending: Vec<(Us, usize, usize)>,
    router: Router,
    cache: BacklogCache,
    rejected: Vec<u64>,
    next_tick: Us,
    /// Fault timeline + front-door state — `None` for plain runs, in
    /// which case every fault hook is pass-through.
    res: Option<Resilience>,
    /// Overload-control layer (retry backoff, breakers, brownout) —
    /// `None` leaves the faults path byte-identical.
    ovl: Option<Overload>,
    /// Observability config copied into engines created mid-run.
    obs_cfg: ObsCfg,
    /// Control-lane recorder: arrive/route/reject + replans.
    obs: Recorder,
}

impl AdaptiveDriver<'_> {
    /// Rebuild `routable[m]` and the candidate index after `live[m]`
    /// changed (activation, rebalance surgery) — both only ever happen
    /// at driver-event barriers, as the sparse core requires.
    fn refresh_routable(&mut self, m: usize) {
        self.routable[m] = routable_of(&self.live, m);
        self.cand[m] = self.routable[m].iter().map(|r| r.gpu).collect();
    }

    /// Route one request of `model` to a replica (JSQ/P2C probe the
    /// live engine backlogs through the per-barrier cache) and inject
    /// it, or count it rejected when the model has no routable replica.
    /// Shared by arrival routing, the re-routing of queues drained from
    /// removed replicas, and (`on_failure`) the failure cascade of a
    /// downed engine. With faults active, unhealthy engines are
    /// filtered out and degraded replicas carry the routing-cost
    /// penalty; `None` leaves the path byte-identical.
    fn route_and_inject(
        &mut self,
        model: usize,
        req: Request,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
        on_failure: bool,
    ) {
        let all = &self.routable[model];
        let filtered: Vec<Replica>;
        let reps: &[Replica] = match &self.res {
            Some(res) if res.any_unroutable() => {
                filtered = all.iter().filter(|r| res.routable(r.gpu)).cloned().collect();
                &filtered
            }
            _ => all,
        };
        if reps.is_empty() {
            self.rejected[model] += 1;
            if let Some(res) = &mut self.res {
                res.note_unroutable();
            }
            if self.obs.on() {
                self.obs.event(EventKind::Reject, req.arrival, model as u32, req.id, 0);
            }
            return;
        }
        let cache = &mut self.cache;
        let res = self.res.as_ref();
        let pick = self.router.route(model, reps, |rep| {
            cache
                .backlog(engines, rep)
                .saturating_add(res.map_or(0, |r| r.penalty_items(rep.gpu)))
        });
        let (rep_gpu, rep_local) = (reps[pick].gpu, reps[pick].local);
        if self.obs.on() {
            self.obs.event(EventKind::Route, req.arrival, model as u32, req.id, rep_gpu as u64);
        }
        let mut q = req;
        q.model = rep_local;
        engines[rep_gpu].as_mut().expect("replica on idle GPU").sim.inject(q);
        self.cache.note_inject(rep_gpu, rep_local);
        touched.mark(rep_gpu);
        if on_failure {
            if let Some(res) = &mut self.res {
                res.note_reroute(1);
            }
        }
    }

    /// The overload front door (armed `ovl` only): family-ordered
    /// admission over the *live routable* replica view — primary first,
    /// then its brownout variants (routable only where the rebalancer's
    /// co-location placed them) — with per-engine breaker
    /// feeding/filtering, resolved to a dispatch, a scheduled retry, or
    /// a typed terminal reject. `attempt` is 0 for fresh arrivals and
    /// the retry ordinal for re-entries.
    fn overload_dispatch(
        &mut self,
        t: Us,
        attempt: u32,
        mut req: Request,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
    ) {
        let m = req.model;
        let order = self.ovl.as_ref().expect("overload dispatch without layer").service_order(m);
        let mut cause = RejectKind::Unroutable;
        for (fi, &fm) in order.iter().enumerate() {
            let healthy: Vec<Replica> = self.routable[fm]
                .iter()
                .filter(|r| self.res.as_ref().is_none_or(|res| res.routable(r.gpu)))
                .cloned()
                .collect();
            if healthy.is_empty() {
                continue; // `cause` stays Unroutable for the primary
            }
            // Every healthy replica's estimate feeds its breaker; only
            // breaker-approved replicas stay candidates.
            let mut open: Vec<Replica> = Vec::with_capacity(healthy.len());
            let mut best = Us::MAX;
            for rep in &healthy {
                let load = self
                    .cache
                    .backlog(engines, rep)
                    .saturating_add(self.res.as_ref().map_or(0, |r| r.penalty_items(rep.gpu)));
                let est = queue_est_us(load, rep.batch, rep.capacity_rps);
                let miss = t.saturating_add(est) > req.deadline;
                let ovl = self.ovl.as_mut().expect("checked above");
                ovl.note_estimate(t, rep.gpu, miss);
                if ovl.allows(t, rep.gpu) {
                    if est < best {
                        best = est;
                    }
                    open.push(rep.clone());
                }
            }
            if open.is_empty() {
                if fi == 0 {
                    cause = RejectKind::BreakerOpen;
                }
                continue;
            }
            if t.saturating_add(best) > req.deadline {
                if fi == 0 {
                    cause = RejectKind::Deadline;
                }
                continue;
            }
            let cache = &mut self.cache;
            let res = self.res.as_ref();
            let pick = self.router.route(fm, &open, |rep| {
                cache
                    .backlog(engines, rep)
                    .saturating_add(res.map_or(0, |r| r.penalty_items(rep.gpu)))
            });
            let (rep_gpu, rep_local) = (open[pick].gpu, open[pick].local);
            if self.obs.on() {
                self.obs.event(EventKind::Route, t, fm as u32, req.id, rep_gpu as u64);
            }
            req.model = rep_local;
            engines[rep_gpu].as_mut().expect("replica on idle GPU").sim.inject(req);
            self.cache.note_inject(rep_gpu, rep_local);
            touched.mark(rep_gpu);
            let class = self.res.as_ref().map_or(SloClass::LatencyCritical, |r| r.class(m));
            let ovl = self.ovl.as_mut().expect("checked above");
            ovl.note_dispatch(t, rep_gpu);
            if fi > 0 {
                ovl.note_degraded(class);
            }
            if attempt > 0 {
                ovl.note_retry_served();
            }
            return;
        }
        self.overload_reject(t, attempt, &req, cause);
    }

    /// A request the overload front door could not place anywhere in its
    /// family: schedule a backoff retry if budget remains, else issue
    /// the terminal typed reject (`retry_exhausted` when retries are on,
    /// the original cause otherwise).
    fn overload_reject(&mut self, t: Us, attempt: u32, req: &Request, cause: RejectKind) {
        let m = req.model;
        if self.ovl.as_mut().expect("overload reject without layer").try_schedule_retry(
            t,
            req,
            attempt + 1,
        ) {
            return; // re-enters at its release barrier; not terminal
        }
        self.rejected[m] += 1;
        let class = self.res.as_ref().map_or(SloClass::LatencyCritical, |r| r.class(m));
        let forward = self.ovl.as_mut().expect("checked above").note_terminal(cause, class);
        match forward {
            Some(RejectKind::Deadline) => {
                if let Some(res) = &mut self.res {
                    res.note_deadline_reject(m);
                }
            }
            Some(RejectKind::Unroutable) => {
                if let Some(res) = &mut self.res {
                    res.note_unroutable();
                }
            }
            _ => {}
        }
        if self.obs.on() {
            self.obs.event(EventKind::Reject, t, m as u32, req.id, 0);
        }
    }

    /// Apply timeline faults, restore maturities and the hedge sweep
    /// due at barrier `t` (all surfaced as driver events, so in sparse
    /// mode every engine is synchronized here).
    fn apply_faults(
        &mut self,
        t: Us,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
    ) {
        let due = self.res.as_mut().expect("faults without resilience").due_faults(t);
        for e in &due {
            match e.kind {
                FaultKind::Down => self.on_down(t, e.gpu, engines, touched),
                FaultKind::Degraded => {
                    if self.obs.on() {
                        self.obs.event(EventKind::EngineDown, t, NO_MODEL, e.gpu as u64, 1);
                    }
                }
                FaultKind::Up => {
                    let res = self.res.as_mut().expect("faults without resilience");
                    if res.restoring(e.gpu) {
                        // Cold recovery: the slowest re-load among the
                        // models a live replica still claims on this
                        // engine gates routability.
                        let cold = self.local_map[e.gpu]
                            .iter()
                            .filter(|&&m| self.live[m].iter().any(|r| r.gpu == e.gpu))
                            .map(|&m| ms_to_us(self.profiles[m].load_ms).max(1))
                            .max()
                            .unwrap_or(1);
                        res.schedule_restore(e.gpu, t + cold);
                    } else if self.obs.on() {
                        self.obs.event(EventKind::EngineUp, t, NO_MODEL, e.gpu as u64, 0);
                    }
                }
            }
        }
        let due = self.res.as_mut().expect("faults without resilience").due_restores(t);
        for g in due {
            self.on_restore(t, g, engines, touched);
        }
        if self.res.as_mut().expect("faults without resilience").hedge_due(t) {
            self.hedge_sweep(t, engines, touched);
        }
    }

    /// Engine `g` failed: drain every active local, cascade-re-route the
    /// drained requests (or reject them in the naive `reroute: false`
    /// baseline), tombstone-rebuild the policy. Live replicas stay in
    /// the book — the engine is simply unroutable until restored, and
    /// the rebalancer keeps reasoning about the same placement.
    fn on_down(
        &mut self,
        t: Us,
        g: usize,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
    ) {
        if self.obs.on() {
            self.obs.event(EventKind::EngineDown, t, NO_MODEL, g as u64, 0);
        }
        let mut drained: Vec<Request> = Vec::new();
        if let Some(eng) = engines[g].as_mut() {
            for local in 0..self.local_map[g].len() {
                if !eng.sim.is_active(local) {
                    continue;
                }
                let global = self.local_map[g][local];
                for mut r in eng.sim.deactivate_model(local) {
                    r.model = global;
                    drained.push(r);
                }
                self.cache.invalidate(g, local);
            }
            eng.rebuild_policy(self.sched);
            touched.mark(g);
        }
        let reroute = self.res.as_ref().is_none_or(|r| r.cfg.reroute);
        for r in drained {
            if reroute {
                let m = r.model;
                self.route_and_inject(m, r, engines, touched, true);
            } else {
                self.rejected[r.model] += 1;
                if self.obs.on() {
                    self.obs.event(EventKind::Reject, t, r.model as u32, r.id, 0);
                }
            }
        }
    }

    /// Engine `g`'s cold re-activation matured: re-activate every local
    /// a live replica still claims (migrated-off tombstones stay
    /// tombstoned) and mark the engine routable.
    fn on_restore(
        &mut self,
        t: Us,
        g: usize,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
    ) {
        if let Some(eng) = engines[g].as_mut() {
            for local in 0..self.local_map[g].len() {
                if eng.sim.is_active(local) {
                    continue;
                }
                let global = self.local_map[g][local];
                if !self.live[global].iter().any(|r| r.gpu == g && r.local == Some(local)) {
                    continue;
                }
                let entry = eng.sim.models[local].clone();
                eng.sim.reactivate_model(local, entry);
            }
            eng.rebuild_policy(self.sched);
            touched.mark(g);
        }
        self.res.as_mut().expect("restore without resilience").mark_restored(g, t);
        if self.obs.on() {
            self.obs.event(EventKind::EngineUp, t, NO_MODEL, g as u64, 0);
        }
    }

    /// Hedged re-dispatch off degraded engines (see
    /// [`crate::faults::pick_hedge_target`] for the analytic
    /// first-completion-wins rule).
    fn hedge_sweep(
        &mut self,
        t: Us,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
    ) {
        for g in 0..engines.len() {
            if !self.res.as_ref().is_some_and(|r| r.degraded(g)) || engines[g].is_none() {
                continue;
            }
            for local in 0..self.local_map[g].len() {
                let global = self.local_map[g][local];
                let res = self.res.as_ref().expect("hedge without resilience");
                let cutoff = t.saturating_sub(res.hedge_threshold_us(global));
                let eng = engines[g].as_ref().expect("checked some");
                if !eng.sim.is_active(local) {
                    continue;
                }
                let stuck = eng.sim.queued_before(local, cutoff) as u64;
                if stuck == 0 {
                    continue;
                }
                let Some(src) = self.routable[global].iter().find(|r| r.gpu == g) else {
                    continue;
                };
                let cache = &mut self.cache;
                let src_est = queue_est_us(
                    cache.backlog(engines, src).saturating_add(res.penalty_items(g)),
                    src.batch,
                    src.capacity_rps,
                );
                let cands: Vec<(Us, usize)> = self.routable[global]
                    .iter()
                    .filter(|r| r.gpu != g && res.routable(r.gpu))
                    .map(|r| {
                        let load =
                            cache.backlog(engines, r).saturating_add(res.penalty_items(r.gpu));
                        (queue_est_us(load, r.batch, r.capacity_rps), r.gpu)
                    })
                    .collect();
                match pick_hedge_target((src_est, g), &cands) {
                    None => {
                        self.res.as_mut().expect("checked").note_hedges(stuck, 0);
                    }
                    Some(win) => {
                        let target = self.routable[global]
                            .iter()
                            .find(|r| r.gpu == win)
                            .expect("winner without replica");
                        let (t_gpu, t_local) = (target.gpu, target.local);
                        let moved = engines[g]
                            .as_mut()
                            .expect("checked some")
                            .sim
                            .take_queued_before(local, cutoff);
                        let n = moved.len() as u64;
                        for mut r in moved {
                            if self.obs.on() {
                                self.obs.event(
                                    EventKind::Hedge,
                                    t,
                                    global as u32,
                                    r.id,
                                    t_gpu as u64,
                                );
                            }
                            r.model = t_local;
                            engines[t_gpu]
                                .as_mut()
                                .expect("routable replica on idle GPU")
                                .sim
                                .inject(r);
                            self.cache.note_inject(t_gpu, t_local);
                        }
                        self.cache.invalidate(g, local);
                        touched.mark(g);
                        touched.mark(t_gpu);
                        self.res.as_mut().expect("checked").note_hedges(n, n);
                        // A hedge fired off this engine: that's a strike
                        // against its breaker.
                        if let Some(ovl) = &mut self.ovl {
                            ovl.note_hedge_loss(t, g);
                        }
                    }
                }
            }
        }
    }
}

impl EpochDriver for AdaptiveDriver<'_> {
    fn n_models(&self) -> usize {
        self.routable.len()
    }

    fn candidates_of(&self, model: usize) -> &[usize] {
        &self.cand[model]
    }

    fn elides_barriers(&self) -> bool {
        // RR decisions are pure router state; arrivals between control
        // ticks then batch into injection rounds. Demand counting
        // (`window_counts`) happens in `route_free`, identically. Fault
        // and overload runs never elide: the front door probes backlogs
        // and ages.
        !self.router.policy().reads_backlogs() && self.res.is_none() && self.ovl.is_none()
    }

    fn route_free(&mut self, _t: Us, req: &Request) -> Option<(usize, usize)> {
        let model = req.model;
        self.window_counts[model] += 1;
        if self.obs.on() {
            self.obs.event(EventKind::Arrive, req.arrival, model as u32, req.id, 0);
        }
        let reps = &self.routable[model];
        if reps.is_empty() {
            self.rejected[model] += 1;
            if self.obs.on() {
                self.obs.event(EventKind::Reject, req.arrival, model as u32, req.id, 0);
            }
            return None;
        }
        // Backlog-free by contract: the closure is never consulted.
        let pick = self.router.route(model, reps, |_| 0);
        let rep = &reps[pick];
        if self.obs.on() {
            self.obs.event(EventKind::Route, req.arrival, model as u32, req.id, rep.gpu as u64);
        }
        Some((rep.gpu, rep.local))
    }

    fn next_event(&self) -> Option<Us> {
        let t_act = self.pending.iter().map(|&(at, _, _)| at).min();
        let t_tick = if self.next_tick < self.horizon { Some(self.next_tick) } else { None };
        let t_res = self.res.as_ref().and_then(|r| r.next_event());
        let t_retry = self.ovl.as_ref().and_then(|o| o.next_release());
        [t_act, t_tick, t_res, t_retry].into_iter().flatten().min()
    }

    /// Mature pending replica activations due at t (faults first: a
    /// replica activating onto an engine that just went down stays
    /// active-but-unroutable until the restore).
    fn pre_arrivals(&mut self, t: Us, engines: &mut [Option<ExecEngine>], touched: &mut Touched) {
        self.cache.reset();
        if self.res.is_some() {
            self.apply_faults(t, engines, touched);
        }
        if self.pending.iter().any(|&(at, _, _)| at <= t) {
            let due: Vec<(Us, usize, usize)> =
                self.pending.iter().copied().filter(|&(at, _, _)| at <= t).collect();
            self.pending.retain(|&(at, _, _)| at > t);
            let mut refreshed = Vec::new();
            for (_, m, idx) in due {
                let mut lr = self.live[m][idx].clone();
                activate_replica(
                    engines,
                    &mut self.local_map,
                    self.profiles,
                    self.gpus,
                    self.horizon_ms,
                    self.obs_cfg,
                    self.sched,
                    m,
                    &mut lr,
                );
                touched.mark(lr.gpu);
                self.live[m][idx] = lr;
                refreshed.push(m);
            }
            for m in refreshed {
                self.refresh_routable(m);
            }
        }
        // Matured backoff retries re-enter the front door after faults
        // and activations so they see the post-barrier replica view.
        if self.ovl.is_some() {
            for (attempt, req) in self.ovl.as_mut().expect("checked").due_retries(t) {
                self.overload_dispatch(t, attempt, req, engines, touched);
            }
        }
    }

    /// Route an arrival (counted into the estimator window whether or
    /// not it is admitted — demand, not service).
    fn route(
        &mut self,
        t: Us,
        req: Request,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
    ) {
        let model = req.model;
        self.window_counts[model] += 1;
        if self.obs.on() {
            self.obs.event(EventKind::Arrive, req.arrival, model as u32, req.id, 0);
        }
        if self.ovl.is_some() {
            // The overload front door subsumes plain admission: family-
            // ordered estimates, breaker filtering, retry scheduling.
            self.overload_dispatch(t, 0, req, engines, touched);
            return;
        }
        if self.res.as_ref().is_some_and(|r| r.cfg.admission) {
            // Deadline-aware admission: best-case estimate across the
            // healthy replicas vs the remaining budget. No healthy
            // replica at all falls through to the unroutable reject.
            let res = self.res.as_ref().expect("checked");
            let cache = &mut self.cache;
            let best = self.routable[model]
                .iter()
                .filter(|rep| res.routable(rep.gpu))
                .map(|rep| {
                    let load =
                        cache.backlog(engines, rep).saturating_add(res.penalty_items(rep.gpu));
                    queue_est_us(load, rep.batch, rep.capacity_rps)
                })
                .min();
            if let Some(best) = best {
                if t.saturating_add(best) > req.deadline {
                    self.rejected[model] += 1;
                    self.res.as_mut().expect("checked").note_deadline_reject(model);
                    if self.obs.on() {
                        self.obs.event(EventKind::Reject, t, model as u32, req.id, 0);
                    }
                    return;
                }
            }
        }
        self.route_and_inject(model, req, engines, touched, false);
    }

    /// Control tick: estimate, detect drift, rebalance.
    fn post_arrivals(&mut self, t: Us, engines: &mut [Option<ExecEngine>], touched: &mut Touched) {
        if t != self.next_tick {
            return;
        }
        self.next_tick += self.interval;
        self.estimator.observe(&self.window_counts, self.window_s);
        self.window_counts.fill(0);
        if !self.detector.tick(self.estimator.rates(), &self.planned_rates) {
            return;
        }
        self.stats.replans += 1;
        self.planned_rates = self.estimator.rates().to_vec();
        // With brownout variants armed, the rebalancer bin-packs the
        // primaries only (variants offer no demand of their own) and
        // then re-derives variant co-location on the new packing.
        let target = match &self.ovl {
            Some(ovl) if ovl.map.n_total() > ovl.map.n_primary => {
                let n_p = ovl.map.n_primary;
                let mut tgt = place(
                    &self.profiles[..n_p],
                    &self.planned_rates[..n_p],
                    self.gpus,
                    self.placement,
                );
                co_locate_variants(&mut tgt, self.profiles, &ovl.map, self.gpus);
                tgt
            }
            _ => place(self.profiles, &self.planned_rates, self.gpus, self.placement),
        };
        if self.obs.on() {
            self.obs.count_control(EventKind::Replan, t);
        }
        let current: Vec<Vec<(usize, u32)>> = self
            .live
            .iter()
            .map(|reps| reps.iter().map(|r| (r.gpu, r.pct)).collect())
            .collect();
        let delta = placement_delta(&current, &target);
        if !delta.is_empty() {
            // Budget invariant: removals-then-additions never pushes a
            // GPU past 100% knee load.
            let (_, after) = apply_delta_to_knee_load(&self.knee_load, &delta);
            // Tear down removed replicas: drain queues, re-route
            // survivors' way (or count as rejected when the model lost
            // its last replica).
            let mut drained: Vec<(usize, Request)> = Vec::new();
            for &(m, gpu, _) in &delta.remove {
                let idx = self.live[m]
                    .iter()
                    .position(|r| r.gpu == gpu)
                    .expect("removing unknown replica");
                let lr = self.live[m].remove(idx);
                if let Some(local) = lr.local {
                    let engine = engines[gpu].as_mut().expect("live replica without engine");
                    // A fault may have drained this local already; a
                    // tombstoned slot has nothing left to hand over.
                    if engine.sim.is_active(local) {
                        for req in engine.sim.deactivate_model(local) {
                            drained.push((m, req));
                        }
                    }
                    engine.rebuild_policy(self.sched);
                    // The drained queue changed this slot's backlog out
                    // of band; drop any memoized probe.
                    self.cache.invalidate(gpu, local);
                    touched.mark(gpu);
                    self.stats.replicas_removed += 1;
                } else {
                    // Still pending: cancel the migration and refund its
                    // accounting — the replica never materialized, so it
                    // is neither an add nor a remove.
                    self.pending.retain(|&(_, pm, pidx)| !(pm == m && pidx == idx));
                    self.stats.replicas_added -= 1;
                    self.stats.migration_ms -= self.cfg.migration_cost_ms;
                }
                // Pending entries index into live[m]; the removal
                // shifted everything behind it down by one.
                for p in self.pending.iter_mut() {
                    if p.1 == m && p.2 > idx {
                        p.2 -= 1;
                    }
                }
            }
            // Bring up added replicas after the migration delay.
            for (m, r) in &delta.add {
                let lr = LiveRep {
                    gpu: r.gpu,
                    pct: r.pct,
                    batch: r.batch,
                    capacity_rps: r.capacity_rps,
                    local: None,
                };
                self.live[*m].push(lr);
                self.pending.push((t + self.migration_us, *m, self.live[*m].len() - 1));
                self.stats.replicas_added += 1;
                self.stats.migration_ms += self.cfg.migration_cost_ms;
            }
            self.knee_load = after;
            for m in 0..self.live.len() {
                self.refresh_routable(m);
            }
            // Re-route drained requests among surviving replicas.
            for (m, req) in drained {
                self.route_and_inject(m, req, engines, touched, false);
            }
            self.stats.rebalances += 1;
            self.stats.rebalance_times_us.push(t);
        }
        if self.obs.on() {
            self.obs.event(
                EventKind::Replan,
                t,
                NO_MODEL,
                delta.add.len() as u64,
                delta.remove.len() as u64,
            );
        }
        self.shed_rps = target.shed_rps.clone();
    }
}

/// Serve `requests` on `gpus` with the adaptive control plane: initial
/// knee-packed placement for `initial_rates`, then per-tick estimation,
/// drift detection and incremental rebalancing as described in the
/// module docs. Deterministic: a fixed (inputs, seed) tuple always
/// yields the same report, including the rebalance schedule — for any
/// thread count.
#[allow(clippy::too_many_arguments)]
pub fn run_adaptive(
    profiles: &[ModelProfile],
    initial_rates: &[f64],
    gpus: &[GpuSpec],
    placement: PlacementPolicy,
    routing: RoutingPolicy,
    sched: GpuSched,
    cfg: &AdaptiveCfg,
    requests: Vec<Request>,
    horizon_ms: f64,
    seed: u64,
) -> ClusterReport {
    run_adaptive_with(
        profiles,
        initial_rates,
        gpus,
        placement,
        routing,
        sched,
        cfg,
        requests,
        horizon_ms,
        seed,
        ExecOpts::default(),
    )
}

/// [`run_adaptive`] with explicit execution options (thread budget +
/// barrier mode). Thin adapter over [`run_adaptive_stream`] via
/// [`MaterializedStream`] — identical report bytes.
#[allow(clippy::too_many_arguments)]
pub fn run_adaptive_with(
    profiles: &[ModelProfile],
    initial_rates: &[f64],
    gpus: &[GpuSpec],
    placement: PlacementPolicy,
    routing: RoutingPolicy,
    sched: GpuSched,
    cfg: &AdaptiveCfg,
    requests: Vec<Request>,
    horizon_ms: f64,
    seed: u64,
    opts: ExecOpts,
) -> ClusterReport {
    let stream = MaterializedStream::new(requests, profiles.len());
    run_adaptive_stream(
        profiles, initial_rates, gpus, placement, routing, sched, cfg, stream, horizon_ms, seed,
        opts,
    )
}

/// [`run_adaptive`] pulling arrivals lazily from any [`ArrivalStream`]
/// — the control plane's demand estimation, drift detection and
/// rebalance schedule are all unchanged (they observe routed requests,
/// not the source container).
#[allow(clippy::too_many_arguments)]
pub fn run_adaptive_stream<S: ArrivalStream>(
    profiles: &[ModelProfile],
    initial_rates: &[f64],
    gpus: &[GpuSpec],
    placement: PlacementPolicy,
    routing: RoutingPolicy,
    sched: GpuSched,
    cfg: &AdaptiveCfg,
    stream: S,
    horizon_ms: f64,
    seed: u64,
    opts: ExecOpts,
) -> ClusterReport {
    run_adaptive_stream_faults(
        profiles, initial_rates, gpus, placement, routing, sched, cfg, stream, horizon_ms, seed,
        opts, None,
    )
}

/// [`run_adaptive_stream`] with an optional fault timeline + SLO-class
/// front door ([`crate::faults`]). `faults: None` is the exact plain
/// path; with a config, the report carries
/// [`crate::cluster::ClusterReport::resilience`].
#[allow(clippy::too_many_arguments)]
pub fn run_adaptive_stream_faults<S: ArrivalStream>(
    profiles: &[ModelProfile],
    initial_rates: &[f64],
    gpus: &[GpuSpec],
    placement: PlacementPolicy,
    routing: RoutingPolicy,
    sched: GpuSched,
    cfg: &AdaptiveCfg,
    stream: S,
    horizon_ms: f64,
    seed: u64,
    opts: ExecOpts,
    faults: Option<&ResilienceCfg>,
) -> ClusterReport {
    run_adaptive_stream_overload(
        profiles, initial_rates, gpus, placement, routing, sched, cfg, stream, horizon_ms, seed,
        opts, faults, None,
    )
}

/// [`run_adaptive_stream_faults`] with the overload-control layer
/// ([`crate::overload`]). `overload: None` is the exact faults path.
/// When armed, `profiles`/`initial_rates` must be the expanded family
/// list (primaries first, then variants at rate 0); placement and
/// every rebalance bin-pack the primaries and co-locate variants onto
/// their primaries' GPUs where headroom allows.
#[allow(clippy::too_many_arguments)]
pub fn run_adaptive_stream_overload<S: ArrivalStream>(
    profiles: &[ModelProfile],
    initial_rates: &[f64],
    gpus: &[GpuSpec],
    placement: PlacementPolicy,
    routing: RoutingPolicy,
    sched: GpuSched,
    cfg: &AdaptiveCfg,
    stream: S,
    horizon_ms: f64,
    seed: u64,
    opts: ExecOpts,
    faults: Option<&ResilienceCfg>,
    overload: Option<&OverloadSpec>,
) -> ClusterReport {
    cfg.validate().expect("invalid adaptive config");
    let n_models = profiles.len();
    let n_gpus = gpus.len();
    let horizon = ms_to_us(horizon_ms);
    let interval = ms_to_us(cfg.interval_ms).max(1);
    let migration_us = ms_to_us(cfg.migration_cost_ms);

    // --- initial placement --------------------------------------------------
    let initial = match overload {
        Some(spec) if spec.map.n_total() > spec.map.n_primary => {
            let n_p = spec.map.n_primary;
            assert_eq!(profiles.len(), spec.map.n_total(), "profiles not expanded for variants");
            let mut pl = place(&profiles[..n_p], &initial_rates[..n_p], gpus, placement);
            co_locate_variants(&mut pl, profiles, &spec.map, gpus);
            pl
        }
        _ => place(profiles, initial_rates, gpus, placement),
    };
    let mut live: Vec<Vec<LiveRep>> = vec![Vec::new(); n_models];

    let mut engines: Vec<Option<ExecEngine>> = (0..n_gpus).map(|_| None).collect();
    let mut local_map: Vec<Vec<usize>> = vec![Vec::new(); n_gpus];

    for (m, reps) in initial.replicas.iter().enumerate() {
        for r in reps {
            let mut lr = LiveRep {
                gpu: r.gpu,
                pct: r.pct,
                batch: r.batch,
                capacity_rps: r.capacity_rps,
                local: None,
            };
            activate_replica(
                &mut engines,
                &mut local_map,
                profiles,
                gpus,
                horizon_ms,
                opts.obs,
                sched,
                m,
                &mut lr,
            );
            live[m].push(lr);
        }
    }

    let routable: Vec<Vec<Replica>> = (0..n_models).map(|m| routable_of(&live, m)).collect();
    let cand: Vec<Vec<usize>> = routable
        .iter()
        .map(|reps| reps.iter().map(|r| r.gpu).collect())
        .collect();
    let mut driver = AdaptiveDriver {
        profiles,
        gpus,
        placement,
        sched,
        cfg,
        horizon_ms,
        horizon,
        interval,
        migration_us,
        window_s: cfg.interval_ms / 1_000.0,
        live,
        routable,
        cand,
        local_map,
        knee_load: initial.knee_load.clone(),
        shed_rps: initial.shed_rps.clone(),
        estimator: RateEstimator::new(cfg.alpha, initial_rates),
        detector: DriftDetector::new(cfg, n_models),
        planned_rates: initial_rates.to_vec(),
        window_counts: vec![0u64; n_models],
        stats: AdaptiveStats::default(),
        pending: Vec::new(),
        router: Router::new(routing, n_models, seed),
        cache: BacklogCache::default(),
        rejected: vec![0u64; n_models],
        next_tick: interval,
        res: {
            // The overload layer routes through the resilience front
            // door's admission estimate; when armed without an explicit
            // fault config, synthesize a minimal admission-only door.
            let synth_cfg;
            let res_cfg = match (faults, overload) {
                (Some(fc), _) => Some(fc),
                (None, Some(_)) => {
                    synth_cfg = ResilienceCfg {
                        admission: true,
                        hedge: false,
                        ..ResilienceCfg::default()
                    };
                    Some(&synth_cfg)
                }
                (None, None) => None,
            };
            res_cfg.map(|fc| {
                Resilience::new(fc.clone(), profiles, n_gpus, horizon)
                    .expect("invalid faults config (validate at the config layer)")
            })
        },
        ovl: overload.map(|spec| Overload::new(spec, n_gpus)),
        obs_cfg: opts.obs,
        obs: Recorder::new(opts.obs, horizon),
    };
    let exec_stats = run_epochs_stream(&mut engines, stream, horizon, opts, &mut driver);

    let AdaptiveDriver {
        live,
        local_map,
        knee_load,
        shed_rps,
        estimator,
        mut stats,
        mut rejected,
        res,
        mut ovl,
        obs: mut obs_rec,
        ..
    } = driver;
    stats.est_rates = estimator.rates().to_vec();
    // Retries still pending at the horizon never got a terminal answer:
    // count them as retry-exhausted rejects so every offered request is
    // accounted.
    if let Some(o) = &mut ovl {
        for (_attempt, req) in o.drain_leftover() {
            rejected[req.model] += 1;
            let class =
                res.as_ref().map_or(SloClass::LatencyCritical, |r| r.class(req.model));
            o.note_retry_exhausted(class);
        }
    }
    let control_obs = obs_rec.finish(profiles.iter().map(|p| p.name.clone()).collect());

    // --- finalize + aggregate ----------------------------------------------
    let reports: Vec<Option<RunReport>> = engines
        .iter_mut()
        .map(|slot| slot.as_mut().map(|e| e.finalize(horizon)))
        .collect();
    let obs_lanes: Vec<EngineObs> = engines
        .iter_mut()
        .map(|slot| slot.as_mut().map(|e| e.sim.take_obs()).unwrap_or_default())
        .collect();
    let obs = ObsReport::collect(opts.obs, horizon, obs_lanes, control_obs);

    let horizon_s = horizon_ms / 1_000.0;
    let split_at = stats.first_rebalance_us();
    let mut throughput = vec![0.0; n_models];
    let mut violations = vec![0.0; n_models];
    let mut served = vec![0u64; n_models];
    let mut dropped = vec![0u64; n_models];
    let mut latencies: Vec<Vec<f64>> = vec![Vec::new(); n_models];
    let mut hists: Vec<LogHistogram> = vec![LogHistogram::default(); n_models];
    let mut lat_before: Vec<Vec<f64>> = vec![Vec::new(); n_models];
    let mut lat_after: Vec<Vec<f64>> = vec![Vec::new(); n_models];
    // Completion instants + SLO outcome for degraded-goodput accounting
    // (gathered only when a fault timeline is attached).
    let mut comps: Vec<(Us, bool)> = Vec::new();
    let mut gpu_utilization = Vec::with_capacity(n_gpus);
    let mut per_gpu = Vec::with_capacity(n_gpus);
    for g in 0..n_gpus {
        let (util, shares) = match &reports[g] {
            Some(rep) => {
                let mut shares = Vec::with_capacity(rep.per_model.len());
                for (local, mm) in rep.per_model.iter().enumerate() {
                    let global = local_map[g][local];
                    throughput[global] += mm.served as f64 / horizon_s;
                    violations[global] += mm.slo_violations() as f64 / horizon_s;
                    served[global] += mm.served;
                    dropped[global] += mm.dropped;
                    latencies[global].extend_from_slice(&mm.latencies_ms);
                    hists[global].merge(&mm.latency_hist);
                    for (lat, &done) in mm.latencies_ms.iter().zip(&mm.completions_us) {
                        match split_at {
                            Some(cut) if done >= cut => lat_after[global].push(*lat),
                            _ => lat_before[global].push(*lat),
                        }
                        if res.is_some() {
                            comps.push((done, *lat <= profiles[global].slo_ms));
                        }
                    }
                    // Shares describe the *final* packing: tombstones
                    // (models migrated off this GPU) contribute their
                    // served counts above but are not listed as current
                    // replicas — keeping per_gpu consistent with
                    // replica_map and knee_load_pct.
                    let engine = engines[g].as_ref().expect("reported engine");
                    if engine.sim.is_active(local) {
                        let entry = &engine.sim.models[local];
                        shares.push(GpuModelShare {
                            model: global,
                            pct: entry.pct,
                            batch: entry.batch,
                            served: mm.served,
                        });
                    }
                }
                (rep.gpu_utilization[0], shares)
            }
            None => (0.0, Vec::new()),
        };
        gpu_utilization.push(util);
        per_gpu.push(GpuReport {
            gpu: gpus[g].name.to_string(),
            knee_load_pct: knee_load[g],
            utilization: util,
            models: shares,
        });
    }
    for m in 0..n_models {
        violations[m] += rejected[m] as f64 / horizon_s;
    }
    stats.p99_before_ms = lat_before.iter().map(|l| percentile(l, 99.0)).collect();
    stats.p99_after_ms = lat_after.iter().map(|l| percentile(l, 99.0)).collect();
    let p99_ms: Vec<f64> =
        latencies.iter().zip(&hists).map(|(l, h)| p99_of(l, h)).collect();
    let replica_map: Vec<Vec<usize>> = live
        .iter()
        .map(|reps| reps.iter().map(|r| r.gpu).collect())
        .collect();
    let admitted: Vec<bool> = live.iter().map(|reps| !reps.is_empty()).collect();

    ClusterReport {
        policy: format!("adaptive+{}+{}+{}", placement.name(), routing.name(), sched.name()),
        throughput,
        gpu_utilization,
        violations_per_sec: violations,
        p99_ms,
        served,
        dropped,
        rejected,
        replica_map,
        shed_rps,
        admitted,
        per_gpu,
        adaptive: Some(stats),
        lifecycle: None,
        resilience: res.map(|mut r| r.finalize(horizon, comps.into_iter())),
        overload: ovl.map(|o| o.finalize()),
        exec: Some(exec_stats),
        obs,
    }
}

/// The canonical drifting-rate cluster workload (the adaptive-vs-static
/// acceptance scenario, `figures::fig13`, `dstack adaptive`, the
/// `bench_adaptive` bench and the golden trace all run this): on a
/// 2×V100 cluster, ResNet-50 and VGG-19 swap hot/cold roles at the
/// horizon midpoint while AlexNet and Mobilenet offer steady load. A
/// static peak-rate placement cannot admit all four (peaks would need
/// both GPUs twice over); each phase individually fits, so tracking the
/// drift is worth an entire GPU's worth of admitted traffic.
///
/// Returns (profiles, initial rates, peak rates, request stream).
pub fn drift_workload(
    horizon_ms: f64,
    seed: u64,
) -> (Vec<ModelProfile>, Vec<f64>, Vec<f64>, Vec<Request>) {
    use crate::workload::merged_stream;
    let (profiles, initial, peak, specs) = drift_specs(horizon_ms);
    let reqs = merged_stream(&specs, horizon_ms, seed);
    (profiles, initial, peak, reqs)
}

/// [`drift_workload`]'s arrival *specs* (profiles, initial rates, peak
/// rates, per-model `(process, slo_ms)` pairs) — feed them to
/// [`crate::workload::MergedStream`] for the lazy, byte-identical
/// streamed leg of the equivalence matrix.
pub fn drift_specs(
    horizon_ms: f64,
) -> (Vec<ModelProfile>, Vec<f64>, Vec<f64>, Vec<(Arrivals, f64)>) {
    use crate::workload::drift_rates;
    let spec = drift_rates(horizon_ms);
    let profiles: Vec<ModelProfile> = spec
        .iter()
        .map(|(n, _)| crate::profile::by_name(n).expect("drift model in zoo"))
        .collect();
    let peak: Vec<f64> = spec
        .iter()
        .map(|(_, tr)| tr.iter().map(|&(_, r)| r).fold(0.0, f64::max))
        .collect();
    let arrivals: Vec<(Arrivals, f64)> = profiles
        .iter()
        .zip(&spec)
        .map(|(p, (_, tr))| (Arrivals::trace(tr.clone()), p.slo_ms))
        .collect();
    let initial: Vec<f64> = arrivals.iter().map(|(a, _)| a.rate_at(0.0)).collect();
    (profiles, initial, peak, arrivals)
}

/// The 2×V100 GPU set [`drift_workload`] is sized for.
pub fn drift_gpus() -> Vec<GpuSpec> {
    vec![crate::profile::V100.clone(), crate::profile::V100.clone()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::{by_name, V100};

    fn cfg() -> AdaptiveCfg {
        AdaptiveCfg::default()
    }

    #[test]
    fn estimator_converges_geometrically() {
        let mut est = RateEstimator::new(0.5, &[100.0]);
        // Windows of 1 s at 300 req/s: estimate halves its distance to
        // the truth every observation.
        est.observe(&[300], 1.0);
        assert!((est.rates()[0] - 200.0).abs() < 1e-9);
        est.observe(&[300], 1.0);
        assert!((est.rates()[0] - 250.0).abs() < 1e-9);
        // Window length scales counts into rates.
        let mut est2 = RateEstimator::new(1.0, &[0.0]);
        est2.observe(&[150], 0.5);
        assert!((est2.rates()[0] - 300.0).abs() < 1e-9);
    }

    #[test]
    fn detector_ignores_noise_inside_the_band() {
        // ±15% noise around the planned rate with a 30% fire threshold:
        // no replan, ever — the flapping guard.
        let mut det = DriftDetector::new(&cfg(), 1);
        let planned = [200.0];
        for i in 0..100 {
            let noisy = 200.0 * (1.0 + 0.15 * if i % 2 == 0 { 1.0 } else { -1.0 });
            assert!(!det.tick(&[noisy], &planned), "fired on noise at tick {i}");
        }
    }

    #[test]
    fn detector_fires_on_step_change_then_settles() {
        let c = cfg();
        let mut det = DriftDetector::new(&c, 1);
        let mut planned = [100.0];
        // Step to 300 req/s: fires on the first tick (cooldown pre-armed).
        assert!(det.tick(&[300.0], &planned));
        planned = [300.0];
        // Settled around the new plan: deviations < rearm ⇒ silence.
        for _ in 0..20 {
            assert!(!det.tick(&[305.0], &planned));
        }
    }

    #[test]
    fn detector_respects_cooldown_and_rearm_band() {
        let c = AdaptiveCfg { cooldown_ticks: 3, ..cfg() };
        let mut det = DriftDetector::new(&c, 1);
        let planned = [100.0];
        assert!(det.tick(&[200.0], &planned), "first fire");
        // Still drifting hard, but inside the cooldown: suppressed.
        assert!(!det.tick(&[220.0], &planned));
        assert!(!det.tick(&[240.0], &planned));
        // Cooldown elapsed and the episode is still open: replans again.
        assert!(det.tick(&[260.0], &planned));
        // After the replan the deviation sits inside the band
        // (rearm..fire): the open episode keeps refining the plan at
        // the cooldown cadence until the estimate converges.
        let planned2 = [260.0];
        assert!(!det.tick(&[310.0], &planned2)); // dev ≈ 0.19, cooldown 1
        assert!(!det.tick(&[310.0], &planned2)); // cooldown 2
        assert!(det.tick(&[310.0], &planned2), "open episode refines");
        // Convergence below rearm closes the episode…
        let planned3 = [310.0];
        assert!(!det.tick(&[320.0], &planned3)); // dev ≈ 0.03 → re-armed
        // …and once closed, band-level deviations (rearm < dev < fire)
        // never re-open it: the anti-flapping guarantee.
        for _ in 0..10 {
            assert!(!det.tick(&[370.0], &planned3)); // dev ≈ 0.19
        }
    }

    #[test]
    fn deviation_has_absolute_floor() {
        assert!(DriftDetector::deviation(10.0, 0.0) > 5.0);
        assert!((DriftDetector::deviation(150.0, 100.0) - 0.5).abs() < 1e-12);
    }

    #[test]
    fn delta_of_identical_placements_is_empty() {
        let profiles = vec![by_name("resnet50").unwrap(), by_name("vgg19").unwrap()];
        let rates = [400.0, 100.0];
        let gpus = [V100.clone(), V100.clone()];
        let p = place(&profiles, &rates, &gpus, PlacementPolicy::FirstFitDecreasing);
        let current: Vec<Vec<(usize, u32)>> = p
            .replicas
            .iter()
            .map(|reps| reps.iter().map(|r| (r.gpu, r.pct)).collect())
            .collect();
        let delta = placement_delta(&current, &p);
        assert!(delta.is_empty(), "{delta:?}");
    }

    #[test]
    fn delta_moves_replicas_when_rates_swap() {
        // The drift scenario's core move: resnet50 hot→cold frees a GPU
        // that vgg19 cold→hot claims.
        let profiles = vec![
            by_name("resnet50").unwrap(),
            by_name("vgg19").unwrap(),
            by_name("alexnet").unwrap(),
            by_name("mobilenet").unwrap(),
        ];
        let gpus = [V100.clone(), V100.clone()];
        let before = place(
            &profiles,
            &[900.0, 100.0, 400.0, 300.0],
            &gpus,
            PlacementPolicy::FirstFitDecreasing,
        );
        let after = place(
            &profiles,
            &[150.0, 450.0, 400.0, 300.0],
            &gpus,
            PlacementPolicy::FirstFitDecreasing,
        );
        let current: Vec<Vec<(usize, u32)>> = before
            .replicas
            .iter()
            .map(|reps| reps.iter().map(|r| (r.gpu, r.pct)).collect())
            .collect();
        let delta = placement_delta(&current, &after);
        assert!(!delta.is_empty());
        assert!(
            delta.remove.iter().any(|&(m, _, _)| m == 0),
            "resnet50 should shrink: {delta:?}"
        );
        assert!(delta.add.iter().any(|&(m, _)| m == 1), "vgg19 should grow: {delta:?}");
        // Budget invariant holds across the migration.
        let (after_remove, after_add) = apply_delta_to_knee_load(&before.knee_load, &delta);
        for g in 0..gpus.len() {
            assert!(after_remove[g] <= 100);
            assert!(after_add[g] <= 100);
            assert_eq!(after_add[g], after.knee_load[g]);
        }
    }

    #[test]
    fn delta_is_deterministic() {
        let profiles = vec![by_name("resnet50").unwrap(), by_name("vgg19").unwrap()];
        let gpus = [V100.clone(), V100.clone()];
        let a = place(&profiles, &[900.0, 100.0], &gpus, PlacementPolicy::FirstFitDecreasing);
        let b = place(&profiles, &[100.0, 500.0], &gpus, PlacementPolicy::FirstFitDecreasing);
        let current: Vec<Vec<(usize, u32)>> = a
            .replicas
            .iter()
            .map(|reps| reps.iter().map(|r| (r.gpu, r.pct)).collect())
            .collect();
        let d1 = placement_delta(&current, &b);
        let d2 = placement_delta(&current, &b);
        assert_eq!(format!("{d1:?}"), format!("{d2:?}"));
    }

    #[test]
    #[should_panic(expected = "oversubscribes")]
    fn oversubscribing_delta_panics() {
        let delta = RebalanceDelta {
            add: vec![(
                0,
                Replica { gpu: 0, local: 0, pct: 60, batch: 16, capacity_rps: 100.0 },
            )],
            remove: Vec::new(),
        };
        apply_delta_to_knee_load(&[70], &delta);
    }

    #[test]
    fn adaptive_stats_shape_is_legacy_unless_cold_priced() {
        // The adaptive golden must never grow a key: cold_migration_ms
        // appears only when the unified path fills it.
        let mut s = AdaptiveStats::default();
        assert!(!s.to_json().to_string_compact().contains("cold_migration_ms"));
        s.cold_migration_ms = Some(123.5);
        let j = s.to_json().to_string_compact();
        assert!(j.contains("\"cold_migration_ms\""), "{j}");
        assert!(j.contains("\"migration_ms\""), "legacy field must survive: {j}");
    }

    #[test]
    fn config_validation_rejects_bad_fields() {
        assert!(AdaptiveCfg::default().validate().is_ok());
        assert!(AdaptiveCfg { interval_ms: 0.0, ..cfg() }.validate().is_err());
        assert!(AdaptiveCfg { alpha: 1.5, ..cfg() }.validate().is_err());
        assert!(AdaptiveCfg { rearm_threshold: 0.5, ..cfg() }.validate().is_err());
        assert!(AdaptiveCfg { migration_cost_ms: -1.0, ..cfg() }.validate().is_err());
    }

    #[test]
    fn drift_workload_shape() {
        let (profiles, initial, peak, reqs) = drift_workload(2_000.0, 7);
        assert_eq!(profiles.len(), 4);
        assert_eq!(initial, vec![900.0, 100.0, 400.0, 300.0]);
        assert_eq!(peak, vec![900.0, 450.0, 400.0, 300.0]);
        assert!(!reqs.is_empty());
        // Sorted stream, all four models present.
        for w in reqs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        for m in 0..4 {
            assert!(reqs.iter().any(|r| r.model == m), "model {m} silent");
        }
    }
}
