//! The paper's analytical DNN-parallelism model (§4.3, Eqs. 1–6).
//!
//! A DNN is modeled as a sequence of `Kmax` kernels whose inherent
//! parallelism decreases linearly from `p·b` (Eq. 1). Each kernel's
//! parallel work executes on `S` SMs in `W_i / max(1, min(S, N_i))`
//! (Eq. 2); serialized overheads (kernel launch `t_np` plus a memory
//! wait `E_m = d_i·S / M`, Eq. 3) accumulate per repetition (Eq. 4);
//! total latency is Eq. 5. The most *efficient* SM count — the paper's
//! "Knee" — is where `1/(E_t²·S)` (the magnitude of Eq. 6) peaks.
//!
//! With the paper's Fig. 4 parameters (`Kmax=50, t_p=40, t_np=10`,
//! `N1 ∈ {20,40,60}`) this module reproduces interior knees at
//! 10/20/30 SMs (paper reports 9/24/31 — same shape; the paper does not
//! publish its `d_i/M` values, see docs/EXPERIMENTS.md F4).

/// Parameters of the analytical DNN (Table 4 notation).
#[derive(Debug, Clone)]
pub struct AnalyticDnn {
    /// Number of distinct kernels (`Kmax`).
    pub kmax: usize,
    /// Inherent parallelism of the first kernel per batch item (`p`).
    pub p: f64,
    /// Time per parallelizable operation (`t_p`), in model time units.
    pub t_p: f64,
    /// Serialized (launch) time per kernel repetition (`t_np`).
    pub t_np: f64,
    /// Repetition count per kernel (`R_i`); empty ⇒ all ones.
    pub reps: Vec<f64>,
    /// Per-kernel data volume over memory bandwidth per SM (`d_i / M`),
    /// in model time units per SM; empty ⇒ all zeros.
    pub d_over_m: Vec<f64>,
    /// Scale factor mapping model time units → milliseconds (calibrated).
    pub ms_per_unit: f64,
    /// Occupancy half-batch `h`: per-SM efficiency at batch `b` is
    /// `b/(b+h)`, normalized to 1 at [`Self::cal_batch`]. Models the
    /// measured sub-linear latency growth with batch (Fig. 4c; at small
    /// batches GPUs cannot hide memory latency, so per-item cost rises).
    /// `0` disables the effect (used for the paper's Fig. 4 synthetic
    /// DNN, which the paper evaluates with ideal per-op efficiency).
    pub occ_half: f64,
    /// Batch size at which occupancy is normalized (profiling batch).
    pub cal_batch: f64,
}

impl AnalyticDnn {
    /// The paper's Fig. 4 synthetic DNN with first-kernel parallelism `n1`.
    pub fn fig4(n1: f64) -> AnalyticDnn {
        AnalyticDnn {
            kmax: 50,
            p: n1,
            t_p: 40.0,
            t_np: 10.0,
            reps: Vec::new(),
            d_over_m: Vec::new(),
            ms_per_unit: 1.0,
            occ_half: 0.0,
            cal_batch: 1.0,
        }
    }

    fn rep(&self, i: usize) -> f64 {
        self.reps.get(i).copied().unwrap_or(1.0)
    }

    fn dm(&self, i: usize) -> f64 {
        self.d_over_m.get(i).copied().unwrap_or(0.0)
    }

    /// Eq. 1 — inherent parallelism of kernel `i` (0-based) at batch `b`.
    pub fn n_i(&self, i: usize, b: f64) -> f64 {
        let first = self.p * b;
        let step = first / self.kmax as f64;
        (first - step * i as f64).max(0.0)
    }

    /// Eq. 5 — total execution time (model units) on `s` SMs at batch `b`.
    ///
    /// Deviation from Eq. 4 as printed: the paper multiplies the entire
    /// serialized term by `b`, which makes batching strictly harmful
    /// (η of Eq. 9 would be maximized at b=1), contradicting the paper's
    /// own measured Fig. 7 where low batch loses efficacy. Physically a
    /// batch is processed by *one* kernel launch per repetition, so the
    /// launch overhead `t_np` is paid per launch, not per item; only the
    /// parallel work (via `N_i = p·b`, Eq. 1) scales with the batch.
    /// See docs/EXPERIMENTS.md §Notes.
    pub fn e_t_units(&self, s: f64, b: f64) -> f64 {
        assert!(s >= 1.0, "at least one SM required");
        let mut parallel = 0.0;
        let mut serial = 0.0;
        for i in 0..self.kmax {
            let n_i = self.n_i(i, b);
            let w_i = n_i * self.t_p; // per-op work × op count
            let e_i = w_i / s.min(n_i).max(1.0); // Eq. 2
            let e_m = self.dm(i) * s; // Eq. 3 (as printed)
            parallel += self.rep(i) * e_i;
            serial += self.rep(i) * (self.t_np + e_m);
        }
        // Occupancy derating (see `occ_half`): per-item parallel cost is
        // inflated at small batches relative to the calibration batch.
        if self.occ_half > 0.0 {
            let occ = |x: f64| x / (x + self.occ_half);
            parallel *= occ(self.cal_batch) / occ(b.max(1.0));
        }
        serial + parallel // Eq. 4 (per-launch, see above) + Eq. 5
    }

    /// Latency in milliseconds on `s` SMs at batch `b`.
    pub fn latency_ms(&self, s: f64, b: f64) -> f64 {
        self.e_t_units(s, b) * self.ms_per_unit
    }

    /// The knee metric `1/(E_t²·S)` (magnitude of Eq. 6): DNN work
    /// processed per unit time per allocated SM, to be maximized.
    pub fn efficiency(&self, s: f64, b: f64) -> f64 {
        let e_t = self.e_t_units(s, b);
        1.0 / (e_t * e_t * s)
    }

    /// Knee in SMs at batch `b`: the SM count in `[1, max_sms]`
    /// maximizing [`Self::efficiency`].
    pub fn knee_sms(&self, b: f64, max_sms: u32) -> u32 {
        let mut best_s = 1;
        let mut best = f64::NEG_INFINITY;
        for s in 1..=max_sms {
            let eff = self.efficiency(s as f64, b);
            if eff > best {
                best = eff;
                best_s = s;
            }
        }
        best_s
    }

    /// Sweep latency over SM counts (Fig. 4a data).
    pub fn latency_curve(&self, b: f64, max_sms: u32) -> Vec<(u32, f64)> {
        (1..=max_sms).map(|s| (s, self.latency_ms(s as f64, b))).collect()
    }

    /// Sweep the knee metric over SM counts (Fig. 4b data).
    pub fn efficiency_curve(&self, b: f64, max_sms: u32) -> Vec<(u32, f64)> {
        (1..=max_sms).map(|s| (s, self.efficiency(s as f64, b))).collect()
    }
}

/// Calibration: fit an [`AnalyticDnn`] to a published operating point.
///
/// Given a target knee (in SMs, at `batch`) and the latency at that knee
/// (ms), search the first-kernel parallelism `p` so the model's knee
/// lands on the target, then set `ms_per_unit` so the latency matches.
/// This inverts the paper's §4.4 workflow: they fit the model to NVPROF
/// measurements; we fit it to the published Table 6 operating points.
pub fn calibrate(
    target_knee_sms: u32,
    target_latency_ms: f64,
    batch: f64,
    max_sms: u32,
    serial_frac: f64,
) -> AnalyticDnn {
    assert!(target_knee_sms >= 1 && target_knee_sms <= max_sms);
    assert!(target_latency_ms > 0.0);
    // t_np relative to t_p controls how early serialization dominates;
    // `serial_frac` lets heavier models carry proportionally less launch
    // overhead (they have larger kernels).
    let template = |p: f64| AnalyticDnn {
        kmax: 50,
        p,
        t_p: 40.0,
        t_np: 40.0 * serial_frac,
        reps: Vec::new(),
        d_over_m: Vec::new(),
        ms_per_unit: 1.0,
        // Occupancy disabled for calibrated profiles: Eq. 2's
        // `max(1, min(S, N_i))` floor already yields the measured
        // sub-linear latency growth with batch (saturated kernels cost
        // t_p per launch regardless of N_i), so per-item cost falls with
        // batching exactly as in Fig. 4c without extra derating.
        occ_half: 0.0,
        cal_batch: batch,
    };
    // The knee grows monotonically with p — bisect.
    let mut lo = 0.05_f64;
    let mut hi = 4096.0_f64;
    for _ in 0..64 {
        let mid = 0.5 * (lo + hi);
        let knee = template(mid).knee_sms(batch, max_sms);
        if knee < target_knee_sms {
            lo = mid;
        } else {
            hi = mid;
        }
    }
    // Fine scan around the bisection point for the exact integer knee.
    let mut dnn = template(hi);
    for mult in [1.0, 1.02, 0.98, 1.05, 0.95, 1.1, 0.9] {
        let cand = template(hi * mult);
        if cand.knee_sms(batch, max_sms) == target_knee_sms {
            dnn = cand;
            break;
        }
    }
    let at_knee = dnn.e_t_units(target_knee_sms as f64, batch);
    dnn.ms_per_unit = target_latency_ms / at_knee;
    dnn
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fig4_interior_knees() {
        // Paper Fig. 4b: N1 = 20/40/60 → knees at 9/24/31 SMs. With the
        // printed parameters and no memory term we land at 10/20/30 —
        // the documented reproduction values (docs/EXPERIMENTS.md F4).
        assert_eq!(AnalyticDnn::fig4(20.0).knee_sms(1.0, 80), 10);
        assert_eq!(AnalyticDnn::fig4(40.0).knee_sms(1.0, 80), 20);
        assert_eq!(AnalyticDnn::fig4(60.0).knee_sms(1.0, 80), 30);
    }

    #[test]
    fn latency_monotone_nonincreasing_without_memory_term() {
        let dnn = AnalyticDnn::fig4(40.0);
        let curve = dnn.latency_curve(1.0, 80);
        for w in curve.windows(2) {
            assert!(w[1].1 <= w[0].1 + 1e-9, "latency increased: {w:?}");
        }
    }

    #[test]
    fn latency_flattens_beyond_parallelism() {
        let dnn = AnalyticDnn::fig4(20.0);
        // Beyond S = N1 no kernel can use extra SMs: latency is constant.
        let l20 = dnn.latency_ms(20.0, 1.0);
        let l80 = dnn.latency_ms(80.0, 1.0);
        assert!((l20 - l80).abs() < 1e-9);
    }

    #[test]
    fn memory_term_creates_latency_minimum() {
        let mut dnn = AnalyticDnn::fig4(40.0);
        dnn.d_over_m = vec![1.0; 50];
        // With Eq. 3 as printed, large S inflates the serialized part.
        let l20 = dnn.latency_ms(20.0, 1.0);
        let l80 = dnn.latency_ms(80.0, 1.0);
        assert!(l80 > l20, "memory term should penalize excess SMs");
    }

    #[test]
    fn batching_increases_latency_and_knee() {
        let dnn = AnalyticDnn::fig4(20.0);
        // §4.4.1 / Fig. 4c-d: latency grows with batch at fixed GPU%, and
        // the efficient operating point moves right with batch size.
        assert!(dnn.latency_ms(16.0, 8.0) > dnn.latency_ms(16.0, 1.0));
        let k1 = dnn.knee_sms(1.0, 80);
        let k8 = dnn.knee_sms(8.0, 80);
        assert!(k8 > k1, "knee should grow with batch: {k1} vs {k8}");
    }

    #[test]
    fn low_sm_penalty_is_superlinear() {
        // Fig. 2's "exponential increase" at low GPU%: going 10→1 SMs
        // costs much more than the flat-region latency delta.
        let dnn = AnalyticDnn::fig4(60.0);
        let l1 = dnn.latency_ms(1.0, 1.0);
        let l10 = dnn.latency_ms(10.0, 1.0);
        assert!(l1 / l10 > 5.0);
    }

    #[test]
    fn calibrate_hits_knee_and_latency() {
        for (knee, lat) in [(16u32, 10.0), (24, 8.0), (32, 28.0), (40, 55.0)] {
            let dnn = calibrate(knee, lat, 16.0, 80, 0.25);
            assert_eq!(dnn.knee_sms(16.0, 80), knee, "knee mismatch for {knee}");
            let got = dnn.latency_ms(knee as f64, 16.0);
            assert!((got - lat).abs() / lat < 1e-9, "latency {got} vs {lat}");
        }
    }

    #[test]
    fn eq1_parallelism_schedule() {
        let dnn = AnalyticDnn::fig4(50.0);
        assert!((dnn.n_i(0, 2.0) - 100.0).abs() < 1e-12);
        // Decreases by p*b/Kmax = 2 per kernel.
        assert!((dnn.n_i(1, 2.0) - 98.0).abs() < 1e-12);
        // Clamped at zero for the tail.
        assert_eq!(dnn.n_i(60, 2.0), 0.0);
    }
}
