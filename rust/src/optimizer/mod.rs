//! Optimal batching and GPU% selection (§5, Eqs. 7–12).
//!
//! Maximizes Efficacy `η = Throughput / (Latency × GPU%)` — equivalently
//! `η = b / (f_L(p,b)² · GPU%)` (Eq. 9) — subject to:
//!
//! - Eq. 10: `1 ≤ b ≤ MaxBatchSize`
//! - Eq. 11: `f_L(p,b) + C ≤ SLO` (batch assembly + inference fit the SLO)
//! - Eq. 12: `f_L(p,b) ≤ SLO/2` (room for the next batch's oldest request)
//!
//! The paper solves this with MATLAB `fmincon` over a fitted `f_L`; we
//! have the calibrated analytic surface and the decision space is small
//! (batch × GPU% grid), so exhaustive search *is* the exact optimum.

use crate::profile::{GpuSpec, ModelProfile, V100};

/// Per-image batch assembly time (§5.1: one 224×224 image arrives every
/// ~481 µs on the 10 Gbps testbed link).
pub const ASSEMBLY_MS_PER_IMAGE: f64 = 0.481;

/// An (batch, GPU%) operating point with its metrics.
#[derive(Debug, Clone, PartialEq)]
pub struct OperatingPoint {
    pub batch: u32,
    pub gpu_pct: u32,
    /// Inference latency f_L(p, b) in ms.
    pub latency_ms: f64,
    /// Batch assembly time C in ms.
    pub assembly_ms: f64,
    /// Throughput in items/s (Eq. 8).
    pub throughput: f64,
    /// Efficacy η (Eq. 7).
    pub efficacy: f64,
    pub feasible: bool,
}

/// Optimizer configuration.
#[derive(Debug, Clone)]
pub struct OptConfig {
    /// SLO for this model (ms). Defaults to the profile's SLO.
    pub slo_ms: Option<f64>,
    /// Per-item assembly time (ms/item).
    pub assembly_ms_per_item: f64,
    /// GPU% granularity of the search grid.
    pub pct_step: u32,
    /// Over-provisioning added when deploying (§5.1: "over-provision the
    /// GPU% by 5-10% while deploying the model in a real system").
    pub deploy_headroom_pct: u32,
}

impl Default for OptConfig {
    fn default() -> Self {
        OptConfig {
            slo_ms: None,
            assembly_ms_per_item: ASSEMBLY_MS_PER_IMAGE,
            pct_step: 5,
            deploy_headroom_pct: 5,
        }
    }
}

/// Evaluate one (batch, GPU%) point on a GPU.
pub fn evaluate(
    m: &ModelProfile,
    gpu: &GpuSpec,
    batch: u32,
    gpu_pct: u32,
    cfg: &OptConfig,
) -> OperatingPoint {
    let slo = cfg.slo_ms.unwrap_or(m.slo_ms);
    let latency_ms = m.latency_ms_on(gpu, gpu_pct, batch);
    let assembly_ms = batch as f64 * cfg.assembly_ms_per_item;
    let throughput = batch as f64 / (latency_ms / 1000.0); // Eq. 8
    let gpu_frac = gpu_pct as f64 / 100.0;
    let efficacy = throughput / (latency_ms * gpu_frac); // Eq. 7
    let feasible = batch >= 1
        && batch <= m.max_batch // Eq. 10
        && latency_ms + assembly_ms <= slo // Eq. 11
        && latency_ms <= slo / 2.0; // Eq. 12
    OperatingPoint { batch, gpu_pct, latency_ms, assembly_ms, throughput, efficacy, feasible }
}

/// The full efficacy surface (Fig. 7 for ResNet-50, Fig. 8 feasibility
/// region for Mobilenet): every grid point with metrics + feasibility.
pub fn surface(m: &ModelProfile, gpu: &GpuSpec, cfg: &OptConfig) -> Vec<OperatingPoint> {
    let mut out = Vec::new();
    for batch in 1..=m.max_batch {
        let mut pct = cfg.pct_step.max(1);
        while pct <= 100 {
            out.push(evaluate(m, gpu, batch, pct, cfg));
            pct += cfg.pct_step.max(1);
        }
    }
    out
}

/// Solve for the deployed operating point, following §5.1's selection
/// rule: pick from the *high-efficacy region* — for each batch size the
/// efficient GPU% is the batch's knee (where η(p) peaks, see
/// [`crate::analytic::AnalyticDnn::knee_sms`]) — the point that maximizes
/// throughput subject to Eqs. 10–12, breaking ties by efficacy.
///
/// When no point satisfies Eq. 12 (the paper's own Table 6 rows for
/// ResNet-50 and VGG-19 violate it: runtime > SLO/2), the constraint is
/// relaxed to Eq. 11 only, mirroring the paper's deployed values.
/// Returns `None` when even Eq. 11 cannot be met.
pub fn optimize(m: &ModelProfile, gpu: &GpuSpec, cfg: &OptConfig) -> Option<OperatingPoint> {
    let slo = cfg.slo_ms.unwrap_or(m.slo_ms);
    let mut cands: Vec<(OperatingPoint, bool)> = Vec::new();
    for batch in 1..=m.max_batch {
        let knee_pct = m.knee_pct_on(gpu, batch);
        let p = evaluate(m, gpu, batch, knee_pct, cfg);
        if p.feasible {
            cands.push((p, true));
        } else if p.latency_ms + p.assembly_ms <= slo {
            cands.push((p, false)); // Eq. 11 holds, Eq. 12 does not
        }
    }
    // Throughput dominates; strictness (Eq. 12) then efficacy break
    // ties. total_cmp per key matches the old tuple partial_cmp on the
    // finite values evaluate() yields, without a NaN panic path (a NaN
    // key compares greatest in the total order, deterministically).
    cands
        .into_iter()
        .max_by(|(a, sa), (b, sb)| {
            a.throughput
                .total_cmp(&b.throughput)
                .then(sa.cmp(sb))
                .then(a.efficacy.total_cmp(&b.efficacy))
        })
        .map(|(p, _)| p)
}

/// The deployed operating point: the optimum with the §5.1 headroom
/// added to GPU% (clamped at 100).
///
/// Use this for *single-model* deployment only. Multiplexed paths — the
/// per-GPU entry tables ([`crate::sim::entries_at_optimum`]), the
/// cluster packer ([`crate::cluster::placement::op_point`]) and the
/// adaptive control plane's re-optimization on top of it — deploy at
/// the bare knee instead: over-provisioned GPU% destroys the
/// spatio-temporal packing (the Table 6 knees 20+30+40+50 admit a
/// feasible session plan; +5% each does not).
pub fn deploy_point(m: &ModelProfile, gpu: &GpuSpec, cfg: &OptConfig) -> Option<OperatingPoint> {
    optimize(m, gpu, cfg).map(|mut p| {
        p.gpu_pct = (p.gpu_pct + cfg.deploy_headroom_pct).min(100);
        p.latency_ms = m.latency_ms_on(gpu, p.gpu_pct, p.batch);
        p.throughput = p.batch as f64 / (p.latency_ms / 1000.0);
        p.efficacy = p.throughput / (p.latency_ms * p.gpu_pct as f64 / 100.0);
        p
    })
}

/// Largest batch that finishes within `budget_ms` at `gpu_pct` — used by
/// the schedulers' opportunistic pass and the adaptive batcher.
pub fn max_batch_within(m: &ModelProfile, gpu: &GpuSpec, gpu_pct: u32, budget_ms: f64) -> u32 {
    let mut best = 0;
    for b in 1..=m.max_batch {
        if m.latency_ms_on(gpu, gpu_pct, b) <= budget_ms {
            best = b;
        } else {
            break; // latency is monotone in b
        }
    }
    best
}

/// Table 6 row: per-model optimal (knee%, batch, runtime) on the V100.
#[derive(Debug, Clone)]
pub struct Table6Row {
    pub model: String,
    pub knee_pct: u32,
    pub slo_ms: f64,
    pub batch: u32,
    pub runtime_ms: f64,
}

/// Regenerate Table 6 from the optimizer (rather than copying the
/// profile fields): for each model, the optimal point's GPU% and batch.
pub fn table6(models: &[ModelProfile]) -> Vec<Table6Row> {
    models
        .iter()
        .map(|m| {
            let cfg = OptConfig::default();
            let opt = optimize(m, &V100, &cfg);
            match opt {
                Some(p) => Table6Row {
                    model: m.name.clone(),
                    knee_pct: p.gpu_pct,
                    slo_ms: m.slo_ms,
                    batch: p.batch,
                    runtime_ms: p.latency_ms,
                },
                None => Table6Row {
                    model: m.name.clone(),
                    knee_pct: m.knee_pct,
                    slo_ms: m.slo_ms,
                    batch: m.opt_batch,
                    runtime_ms: m.runtime_ms,
                },
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::{by_name, zoo};

    #[test]
    fn efficacy_peaks_at_interior_point() {
        // Fig. 7: both very low and very high batch lose efficacy.
        let m = by_name("resnet50").unwrap();
        let cfg = OptConfig { slo_ms: Some(1e9), ..Default::default() }; // unconstrained
        let s = surface(&m, &V100, &cfg);
        let best = s.iter().max_by(|a, b| a.efficacy.total_cmp(&b.efficacy)).unwrap();
        let b1 = s.iter().find(|p| p.batch == 1 && p.gpu_pct == best.gpu_pct).unwrap();
        assert!(best.efficacy > b1.efficacy, "batch 1 should not be optimal");
        assert!(best.gpu_pct < 100, "100% GPU should not be optimal");
    }

    #[test]
    fn optimize_ranking_total_cmp_matches_partial() {
        // The (throughput, strict, efficacy) ranking must pick the same
        // point the old tuple partial_cmp().unwrap() did on the finite
        // candidates real profiles yield; regression for the NaN panic
        // path the unwrap carried.
        for m in zoo() {
            let cfg = OptConfig::default();
            let Some(p) = optimize(&m, &V100, &cfg) else { continue };
            let slo = m.slo_ms;
            let mut cands = Vec::new();
            for batch in 1..=m.max_batch {
                let q = evaluate(&m, &V100, batch, m.knee_pct_on(&V100, batch), &cfg);
                if q.feasible {
                    cands.push((q, true));
                } else if q.latency_ms + q.assembly_ms <= slo {
                    cands.push((q, false));
                }
            }
            let old = cands
                .iter()
                .max_by(|(a, sa), (b, sb)| {
                    (a.throughput, *sa, a.efficacy)
                        .partial_cmp(&(b.throughput, *sb, b.efficacy))
                        .unwrap()
                })
                .unwrap();
            assert_eq!((p.batch, p.gpu_pct), (old.0.batch, old.0.gpu_pct), "{}", m.name);
        }
        // NaN keys order deterministically: greatest in the total order,
        // so a NaN-throughput candidate wins max_by instead of panicking.
        let pick =
            [f64::NAN, 1.0, 2.0].iter().copied().max_by(|a, b| a.total_cmp(b)).unwrap();
        assert!(pick.is_nan());
    }

    #[test]
    fn constraints_respected() {
        let m = by_name("mobilenet").unwrap();
        let cfg = OptConfig::default();
        for p in surface(&m, &V100, &cfg) {
            if p.feasible {
                assert!(p.latency_ms + p.assembly_ms <= m.slo_ms + 1e-9); // Eq. 11
                assert!(p.latency_ms <= m.slo_ms / 2.0 + 1e-9); // Eq. 12
                assert!(p.batch >= 1 && p.batch <= m.max_batch); // Eq. 10
            }
        }
    }

    #[test]
    fn mobilenet_optimum_near_30pct() {
        // §5.1: "It is particularly revealing that Mobilenet has an
        // optimal point close to 30%."
        let m = by_name("mobilenet").unwrap();
        let p = optimize(&m, &V100, &OptConfig::default()).unwrap();
        assert!(
            (20..=40).contains(&p.gpu_pct),
            "mobilenet optimum at {}% not near 30%",
            p.gpu_pct
        );
        assert!(p.feasible);
    }

    #[test]
    fn all_zoo_models_have_feasible_points() {
        for m in zoo() {
            let p = optimize(&m, &V100, &OptConfig::default());
            assert!(p.is_some(), "{} has no feasible operating point", m.name);
        }
    }

    #[test]
    fn deploy_point_adds_headroom() {
        let m = by_name("resnet50").unwrap();
        let cfg = OptConfig::default();
        let opt = optimize(&m, &V100, &cfg).unwrap();
        let dep = deploy_point(&m, &V100, &cfg).unwrap();
        assert_eq!(dep.gpu_pct, (opt.gpu_pct + cfg.deploy_headroom_pct).min(100));
        assert!(dep.latency_ms <= opt.latency_ms + 1e-9, "more GPU can't be slower");
    }

    #[test]
    fn max_batch_within_budget() {
        let m = by_name("alexnet").unwrap();
        // At the knee, the profiled batch-16 runtime is 8 ms.
        let b = max_batch_within(&m, &V100, m.knee_pct, 8.0);
        assert_eq!(b, 16);
        let b_small = max_batch_within(&m, &V100, m.knee_pct, 2.0);
        assert!(b_small < 16);
        assert_eq!(max_batch_within(&m, &V100, m.knee_pct, 0.001), 0);
    }

    #[test]
    fn table6_close_to_published() {
        // The optimizer's GPU% should land within ±15 points of the
        // published knee and pick a large batch for every model.
        let rows = table6(&zoo());
        for (row, m) in rows.iter().zip(zoo()) {
            assert!(
                (row.knee_pct as i64 - m.knee_pct as i64).abs() <= 15,
                "{}: opt {}% vs published {}%",
                row.model,
                row.knee_pct,
                m.knee_pct
            );
            assert!(row.batch >= 8, "{}: batch {} too small", row.model, row.batch);
        }
    }

    #[test]
    fn infeasible_when_slo_impossible() {
        let mut m = by_name("vgg19").unwrap();
        m.slo_ms = 1.0; // nothing fits in 1 ms
        assert!(optimize(&m, &V100, &OptConfig::default()).is_none());
    }
}
