//! Model lifecycle subsystem: per-GPU memory management, cold starts,
//! scale-to-zero, and long-tail (Zipf) model fleets.
//!
//! The paper multiplexes a handful of *resident* DNNs; the systems it
//! benchmarks against (Nexus, Clipper) serve fleets where the working
//! set exceeds GPU memory. In that regime throughput is decided by
//! *what is resident*, not just how residents are scheduled. This
//! module closes that gap with four cooperating mechanisms:
//!
//! 1. **[`ModelStore`]** (`store`) — per-GPU resident-set tracker
//!    against a device-memory budget, with pluggable eviction
//!    (LRU / LFU / cost-aware "load-ms-per-req saved") and pinning.
//!    Cold loads reserve memory for the duration of the weight upload
//!    and are charged through the §3.2 [`crate::gpu::ReconfigModel`]:
//!    parameter sharing (cudaIPC) cuts the transfer to
//!    `shared_load_fraction` whenever another model is already resident.
//! 2. **Scale-to-zero / warm-up** — idle residents release their memory
//!    *and* their knee budget through the existing [`crate::sim::Sim`]
//!    tombstone surgery (`deactivate_model`); a later request faults the
//!    model back in (`reactivate_model`) after the load delay, the same
//!    machinery the adaptive control plane uses for migrations.
//! 3. **Memory-feasible assignment** —
//!    [`crate::cluster::placement::plan_residency`] assigns models to
//!    GPUs by *effective* knee load (knee% × busy fraction, since a
//!    tail model only holds its knee while a batch runs), bounds the
//!    t = 0 resident set by each GPU's memory budget, and rejects
//!    models whose weights can never fit — so no request is ever
//!    admitted for a never-resident model.
//! 4. **Warmness-aware routing** — JSQ/P2C run against a *cost* that
//!    adds, for cold replicas, the items the replica could have served
//!    during its remaining load time. Warm replicas win ties; a cold
//!    dispatch is taken only when the warm queues are long enough to
//!    amortize the load, and then pays the §3.2 load delay before its
//!    requests are injected.
//!
//! The outcome is an ordinary [`ClusterReport`] whose `lifecycle` field
//! carries [`LifecycleStats`] (cold starts, evictions, bytes loaded,
//! cold-start delay p99, goodput) — serialized only for lifecycle runs
//! so static/adaptive golden shapes are unchanged. The canonical
//! scenario is [`longtail_workload`]: N models with Zipf(α) popularity
//! over GPUs whose combined memory holds fewer than half of them
//! (`rust/configs/cluster_longtail_zipf.json`, `dstack lifecycle`,
//! `figures::fig14`, `benches/bench_lifecycle.rs`).

pub mod store;

pub use store::{EvictionPolicy, ModelStore};

use crate::cluster::exec::{run_epochs_stream, EpochDriver, ExecEngine, Touched};
use crate::cluster::routing::BacklogCache;
use crate::cluster::{
    ClusterReport, ExecOpts, GpuModelShare, GpuReport, GpuSched, Replica, ResidencyPlan,
    Router, RoutingPolicy,
};
use crate::cluster::p99_of;
use crate::faults::{
    pick_hedge_target, queue_est_us, FaultKind, Resilience, ResilienceCfg, SloClass,
};
use crate::gpu::{ms_to_us, us_to_ms, ReconfigModel, Us};
use crate::overload::{Overload, OverloadSpec, RejectKind};
use crate::metrics::RunReport;
use crate::obs::{EngineObs, EventKind, ObsReport, Recorder, NO_MODEL};
use crate::profile::{GpuSpec, ModelProfile};
use crate::sim::{ModelEntry, Sim, SimConfig};
use crate::util::json::Json;
use crate::util::stats::{percentile, LogHistogram};
use crate::workload::{ArrivalStream, Arrivals, MaterializedStream, Request};
use std::collections::{BTreeMap, VecDeque};

/// Lifecycle configuration (the scenario `"lifecycle"` block — see
/// `docs/CONFIG.md`).
#[derive(Debug, Clone)]
pub struct LifecycleCfg {
    /// Victim selection under memory pressure.
    pub eviction: EvictionPolicy,
    /// Per-GPU resident-memory budget (MiB). `0` ⇒ the device's full
    /// `GpuSpec::mem_mib`.
    pub mem_budget_mib: u64,
    /// Reserved headroom subtracted from the budget (activations,
    /// fragmentation), MiB.
    pub headroom_mib: u64,
    /// Idle time after which a warm model scales to zero (releases
    /// memory and knee budget). `0` disables scale-to-zero.
    pub idle_timeout_ms: f64,
    /// Fold cold-start penalties into the routing cost (JSQ/P2C
    /// tie-break toward warm replicas). `false` = warm-oblivious
    /// routing: queues only, cold starts land wherever backlog is
    /// shortest.
    pub warm_routing: bool,
    /// Minimum replicas per admitted model (availability / routing
    /// choice), capped at the number of memory-feasible GPUs.
    pub min_replicas: usize,
    /// Profile names whose residents are never evicted or scaled to
    /// zero.
    pub pinned: Vec<String>,
    /// §3.2 reconfiguration cost model (parameter sharing discount on
    /// cold loads).
    pub reconfig: ReconfigModel,
}

impl Default for LifecycleCfg {
    fn default() -> Self {
        LifecycleCfg {
            eviction: EvictionPolicy::Lru,
            mem_budget_mib: 0,
            headroom_mib: 0,
            idle_timeout_ms: 2_000.0,
            warm_routing: true,
            min_replicas: 2,
            pinned: Vec::new(),
            reconfig: ReconfigModel::default(),
        }
    }
}

impl LifecycleCfg {
    /// Validate ranges; returns a message naming the offending field.
    pub fn validate(&self) -> Result<(), String> {
        if self.idle_timeout_ms.is_nan() || self.idle_timeout_ms < 0.0 {
            return Err("lifecycle.idle_timeout_ms must be >= 0".into());
        }
        if self.min_replicas == 0 {
            return Err("lifecycle.min_replicas must be >= 1".into());
        }
        if self.mem_budget_mib > 0 && self.headroom_mib >= self.mem_budget_mib {
            return Err("lifecycle.headroom_mib must be < mem_budget_mib".into());
        }
        Ok(())
    }

    /// Resident-memory budget for one device (MiB).
    pub fn budget_for(&self, gpu: &GpuSpec) -> u64 {
        let cap = if self.mem_budget_mib > 0 {
            self.mem_budget_mib.min(gpu.mem_mib)
        } else {
            gpu.mem_mib
        };
        cap.saturating_sub(self.headroom_mib)
    }

    /// Per-GPU budgets for a cluster.
    pub fn budgets(&self, gpus: &[GpuSpec]) -> Vec<u64> {
        gpus.iter().map(|g| self.budget_for(g)).collect()
    }
}

/// Memory-manager telemetry attached to a lifecycle run's
/// [`ClusterReport`].
#[derive(Debug, Clone, Default)]
pub struct LifecycleStats {
    /// On-demand model loads triggered by routing a cold request.
    pub cold_starts: u64,
    /// Requests dispatched to an already-warm replica.
    pub warm_hits: u64,
    /// Park events behind a model load: a request re-parked after an
    /// eviction drained its queue counts once per park.
    pub cold_delayed: u64,
    /// Residents evicted under memory pressure.
    pub evictions: u64,
    /// Idle residents released by the scale-to-zero sweep.
    pub scale_to_zero: u64,
    /// Total weight traffic of on-demand loads (MiB).
    pub mib_loaded: u64,
    /// Total model-load time charged (ms).
    pub load_ms_total: f64,
    /// p99 of the arrival→warm delay over park events (ms); includes
    /// parks whose request was still waiting at the horizon.
    pub cold_start_p99_ms: f64,
    /// Served-within-SLO requests per second, cluster-wide.
    pub goodput_rps: f64,
    /// Per-GPU high-water mark of resident memory (MiB).
    pub peak_resident_mib: Vec<u64>,
    /// Per-GPU resident-model count at the horizon.
    pub resident_final: Vec<u64>,
}

impl LifecycleStats {
    /// Deterministic JSON form (embedded in `ClusterReport::to_json`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("cold_starts", Json::from(self.cold_starts)),
            ("warm_hits", Json::from(self.warm_hits)),
            ("cold_delayed", Json::from(self.cold_delayed)),
            ("evictions", Json::from(self.evictions)),
            ("scale_to_zero", Json::from(self.scale_to_zero)),
            ("mib_loaded", Json::from(self.mib_loaded)),
            ("load_ms_total", Json::from(self.load_ms_total)),
            ("cold_start_p99_ms", Json::from(self.cold_start_p99_ms)),
            ("goodput_rps", Json::from(self.goodput_rps)),
            (
                "peak_resident_mib",
                Json::Arr(self.peak_resident_mib.iter().map(|&v| Json::from(v)).collect()),
            ),
            (
                "resident_final",
                Json::Arr(self.resident_final.iter().map(|&v| Json::from(v)).collect()),
            ),
        ])
    }
}

/// Name of fleet entry `i` cloned from `base` — the single source of
/// the `{base}_{:02}` scheme shared by [`longtail_workload_from`], the
/// CLI's report rows and the config layer's `pinned` validation.
pub fn fleet_name(base: &str, i: usize) -> String {
    format!("{base}_{i:02}")
}

/// The canonical long-tail fleet: `n_models` clones of the Table 6 zoo
/// (round-robin, suffixed `_00..`) with Zipf(`alpha`) popularity summing
/// to `total_rps`. Cold-load times are re-derived from the weight
/// footprint (`150 ms + 0.15 ms/MiB` — a warm serving framework
/// streaming weights, not the §3.2 tens-of-seconds full framework init;
/// parameter sharing discounts this further at load time). Returns
/// (profiles, rates, merged request stream).
pub fn longtail_workload(
    n_models: usize,
    alpha: f64,
    total_rps: f64,
    horizon_ms: f64,
    seed: u64,
) -> (Vec<ModelProfile>, Vec<f64>, Vec<Request>) {
    let base = crate::profile::zoo();
    longtail_workload_from(&base, n_models, alpha, total_rps, horizon_ms, seed)
}

/// [`longtail_workload`] over an explicit base model list (the config
/// path cycles the scenario's `models` entries).
pub fn longtail_workload_from(
    base: &[ModelProfile],
    n_models: usize,
    alpha: f64,
    total_rps: f64,
    horizon_ms: f64,
    seed: u64,
) -> (Vec<ModelProfile>, Vec<f64>, Vec<Request>) {
    use crate::workload::merged_stream;
    let (profiles, rates, specs) = longtail_specs_from(base, n_models, alpha, total_rps);
    let reqs = merged_stream(&specs, horizon_ms, seed);
    (profiles, rates, reqs)
}

/// [`longtail_workload`]'s arrival *specs* over the default zoo — the
/// lazy-stream leg of the equivalence matrix builds a
/// [`crate::workload::MergedStream`] from these.
pub fn longtail_specs(
    n_models: usize,
    alpha: f64,
    total_rps: f64,
) -> (Vec<ModelProfile>, Vec<f64>, Vec<(Arrivals, f64)>) {
    let base = crate::profile::zoo();
    longtail_specs_from(&base, n_models, alpha, total_rps)
}

/// [`longtail_workload_from`] without the materialization step:
/// (profiles, rates, per-model `(process, slo_ms)` specs).
pub fn longtail_specs_from(
    base: &[ModelProfile],
    n_models: usize,
    alpha: f64,
    total_rps: f64,
) -> (Vec<ModelProfile>, Vec<f64>, Vec<(Arrivals, f64)>) {
    assert!(!base.is_empty(), "long-tail fleet needs at least one base model");
    use crate::workload::zipf_rates;
    let profiles: Vec<ModelProfile> = (0..n_models)
        .map(|i| {
            let mut p = base[i % base.len()].clone();
            p.name = fleet_name(&p.name, i);
            p.load_ms = 150.0 + 0.15 * p.mem_mib as f64;
            p
        })
        .collect();
    let rates = zipf_rates(n_models, alpha, total_rps);
    let specs: Vec<(Arrivals, f64)> = profiles
        .iter()
        .zip(&rates)
        .map(|(p, &r)| (Arrivals::Poisson { rate: r }, p.slo_ms))
        .collect();
    (profiles, rates, specs)
}

/// Victim→replica reachability closure over a static hosting table:
/// for each model, the full set of engines an arrival of that model can
/// read or write — its own replicas, plus (transitively) the replicas
/// of every model an eviction cascade starting there can drain.
///
/// A cold start on GPU `g` may evict any model hosted on `g`; the
/// victim's queue is then re-dispatched against the *victim's* replica
/// set, which may trigger further evictions there. The closure of that
/// relation is exactly the connected component of the bipartite
/// model↔GPU hosting graph, so every model's candidate set is its
/// component's (sorted) GPU list. Because the lifecycle hosting table
/// is fixed at plan time, the index is computed once up front — this is
/// what lets the sparse execution core sync a component instead of the
/// whole cluster (the old "conservatively all engines" answer forced it
/// back to the epoch loop).
///
/// Models hosted nowhere get an empty set: their arrivals reject
/// without synchronizing any engine.
pub fn reachability_candidates(hosted: &[Vec<usize>], n_models: usize) -> Vec<Vec<usize>> {
    let n_gpus = hosted.len();
    let mut gpus_of: Vec<Vec<usize>> = vec![Vec::new(); n_models];
    for (g, ms) in hosted.iter().enumerate() {
        for &m in ms {
            gpus_of[m].push(g);
        }
    }
    let mut comp_of_gpu = vec![usize::MAX; n_gpus];
    let mut seen_model = vec![false; n_models];
    let mut components: Vec<Vec<usize>> = Vec::new();
    for g0 in 0..n_gpus {
        if comp_of_gpu[g0] != usize::MAX || hosted[g0].is_empty() {
            continue;
        }
        let c = components.len();
        let mut members = Vec::new();
        let mut stack = vec![g0];
        comp_of_gpu[g0] = c;
        while let Some(g) = stack.pop() {
            members.push(g);
            for &m in &hosted[g] {
                if seen_model[m] {
                    continue;
                }
                seen_model[m] = true;
                for &g2 in &gpus_of[m] {
                    if comp_of_gpu[g2] == usize::MAX {
                        comp_of_gpu[g2] = c;
                        stack.push(g2);
                    }
                }
            }
        }
        members.sort_unstable();
        components.push(members);
    }
    (0..n_models)
        .map(|m| match gpus_of[m].first() {
            Some(&g) => components[comp_of_gpu[g]].clone(),
            None => Vec::new(),
        })
        .collect()
}

/// The lifecycle driver's barrier work on the cluster execution core
/// ([`crate::cluster::exec`]): mature weight loads before arrivals,
/// dispatch arrivals (with warmness-aware routing, cold-start parking
/// and eviction cascades), and sweep idle residents to zero after them.
struct LifecycleDriver<'a> {
    profiles: &'a [ModelProfile],
    plan: &'a ResidencyPlan,
    /// Per-model victim→replica reachability closure
    /// ([`reachability_candidates`]): the bounded candidate sets that
    /// keep lifecycle runs on the sparse path instead of degrading to
    /// the epoch loop.
    cand: Vec<Vec<usize>>,
    /// Routing never reads backlogs (round-robin / static splits) —
    /// precondition for eliding barriers over fully-warm spans.
    free_routing: bool,
    cfg: &'a LifecycleCfg,
    sched: GpuSched,
    pinned: Vec<bool>,
    /// gpu → global model → engine-local slot.
    local_of: Vec<Vec<Option<usize>>>,
    stores: Vec<ModelStore>,
    router: Router,
    cache: BacklogCache,
    rejected: Vec<u64>,
    /// (gpu, model) → virtual time its in-flight load completes.
    loading: BTreeMap<(usize, usize), Us>,
    /// (gpu, model) → requests parked until the load completes.
    held: BTreeMap<(usize, usize), Vec<Request>>,
    cold_delays_ms: Vec<f64>,
    stats: LifecycleStats,
    idle_timeout: Option<Us>,
    /// Reusable cascade queue for [`Self::dispatch`] (always drained
    /// empty between requests; hoisted so the routing hot path does not
    /// allocate per request).
    scratch: VecDeque<(usize, Request)>,
    /// Fault timeline + SLO-class front door ([`crate::faults`]);
    /// `None` outside fault scenarios (zero overhead, golden shapes
    /// untouched).
    res: Option<Resilience>,
    /// Overload-control layer (retry backoff, breakers, brownout) —
    /// `None` leaves the faults path byte-identical. Brownout here is
    /// residency-gated: variants serve only where their weights are
    /// already warm (the front door never cold-starts a fallback).
    ovl: Option<Overload>,
    /// Control-lane recorder: arrive/route/reject plus
    /// eviction/cold-load/scale-to-zero events and warm-set levels.
    obs: Recorder,
}

impl LifecycleDriver<'_> {
    /// One request dispatch, shared by arrivals and eviction re-routes.
    /// Victim queues drained by an eviction are appended to `work` so
    /// cascades stay iterative (loading residents are unevictable,
    /// which bounds the cascade by the resident count).
    fn dispatch(
        &mut self,
        t: Us,
        model: usize,
        req: Request,
        work: &mut VecDeque<(usize, Request)>,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
    ) {
        let plan = self.plan;
        let all: &[Replica] = &plan.placement.replicas[model];
        if all.is_empty() {
            self.rejected[model] += 1;
            if self.obs.on() {
                self.obs.event(EventKind::Reject, req.arrival, model as u32, req.id, 0);
            }
            return;
        }
        // Health filter: downed engines drop out of the candidate set.
        // The clone only happens while some engine is unroutable — the
        // no-fault hot path stays allocation-free.
        let filtered: Vec<Replica>;
        let reps: &[Replica] = match self.res.as_ref() {
            Some(res) if res.any_unroutable() => {
                filtered = all.iter().filter(|r| res.routable(r.gpu)).cloned().collect();
                &filtered
            }
            _ => all,
        };
        if reps.is_empty() {
            // Placed, but every hosting engine is down right now.
            self.rejected[model] += 1;
            self.res.as_mut().expect("unroutable without resilience").note_unroutable();
            if self.obs.on() {
                self.obs.event(EventKind::Reject, t, model as u32, req.id, 0);
            }
            return;
        }
        let cache = &mut self.cache;
        let res = self.res.as_ref();
        let (held, stores, loading) = (&self.held, &self.stores, &self.loading);
        let (cfg, profiles) = (self.cfg, self.profiles);
        let pick = self.router.route(model, reps, |rep| {
            let backlog = cache.backlog(engines, rep);
            let parked = held.get(&(rep.gpu, model)).map_or(0, |v| v.len());
            let base = backlog
                .saturating_add(parked)
                .saturating_add(res.map_or(0, |r| r.penalty_items(rep.gpu)));
            if !cfg.warm_routing || stores[rep.gpu].is_warm(model) {
                return base;
            }
            // Cold cost: the items this replica could have served while
            // the (remaining) weight upload streams in.
            let remaining_ms = match loading.get(&(rep.gpu, model)) {
                Some(&ready) => us_to_ms(ready.saturating_sub(t)),
                // Pre-route estimate: the post-eviction sharing set is
                // unknowable here, so assume today's warm residents.
                None => cfg
                    .reconfig
                    .cold_load_ms(profiles[model].load_ms, stores[rep.gpu].n_warm()),
            };
            base.saturating_add((remaining_ms * rep.capacity_rps / 1_000.0).ceil() as usize)
        });
        if self.dispatch_on(t, model, req, reps, pick, work, engines, touched).is_none() {
            self.rejected[model] += 1;
        }
    }

    /// Dispatch on the routed replica, falling back across `reps` in
    /// index order when a GPU cannot start a load right now (pinned or
    /// mid-load residents crowd its budget): a warm replica serves
    /// immediately, an in-flight load parks the request, a loadable GPU
    /// faults the model in. Returns the GPU the request landed on, or
    /// `None` when the model has no path to residency anywhere (the
    /// caller counts the reject). Shared by the plain routing path and
    /// the overload front door (which routes over a breaker-filtered
    /// candidate set).
    #[allow(clippy::too_many_arguments)]
    fn dispatch_on(
        &mut self,
        t: Us,
        model: usize,
        req: Request,
        reps: &[Replica],
        pick: usize,
        work: &mut VecDeque<(usize, Request)>,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
    ) -> Option<usize> {
        let order = std::iter::once(pick).chain((0..reps.len()).filter(|&i| i != pick));
        for i in order {
            let r = &reps[i];
            let g = r.gpu;
            if self.stores[g].is_warm(model) {
                self.stores[g].touch(t, model);
                if self.obs.on() {
                    self.obs.event(EventKind::Route, req.arrival, model as u32, req.id, g as u64);
                }
                let mut q = req;
                q.model = r.local;
                engines[g].as_mut().expect("warm replica on idle GPU").sim.inject(q);
                self.cache.note_inject(g, r.local);
                touched.mark(g);
                self.stats.warm_hits += 1;
                return Some(g);
            }
            if let Some(&ready) = self.loading.get(&(g, model)) {
                self.cold_delays_ms.push(us_to_ms(ready.saturating_sub(req.arrival)));
                self.held.entry((g, model)).or_default().push(req);
                self.stats.cold_delayed += 1;
                return Some(g);
            }
            // Cold start: reserve memory now (evicting if needed), park
            // the request until the weights have streamed in.
            let Some(victims) = self.stores[g].begin_load(
                t,
                model,
                self.profiles[model].mem_mib,
                self.profiles[model].load_ms,
                self.pinned[model],
            ) else {
                continue; // crowded out here — try the next replica
            };
            // Charge the upload against the *post-eviction* sharing set:
            // only warm survivors can share parameters during the load
            // (the loading model itself is excluded by n_warm).
            let load_ms = self
                .cfg
                .reconfig
                .cold_load_ms(self.profiles[model].load_ms, self.stores[g].n_warm());
            if !victims.is_empty() {
                let engine = engines[g].as_mut().expect("cold replica on idle GPU");
                for v in victims {
                    let vl = self.local_of[g][v].expect("evicting unassigned model");
                    if self.obs.on() {
                        self.obs.event(
                            EventKind::Evict,
                            t,
                            v as u32,
                            g as u64,
                            self.profiles[v].mem_mib,
                        );
                        self.obs.count_control(EventKind::Evict, t);
                    }
                    for dr in engine.sim.deactivate_model(vl) {
                        work.push_back((v, dr));
                    }
                    // The drained victim queue changed this slot's
                    // backlog out of band; drop any memoized probe.
                    self.cache.invalidate(g, vl);
                }
                // The mask changed (victims tombstoned); the loading
                // model itself stays inactive until complete_load
                // rebuilds again.
                engine.rebuild_policy(self.sched);
                touched.mark(g);
            }
            let ready = t + ms_to_us(load_ms).max(1);
            if self.obs.on() {
                self.obs.event(EventKind::ColdLoad, t, model as u32, g as u64, ready - t);
                self.obs.count_control(EventKind::ColdLoad, t);
                self.obs.warm_level(g, t, self.stores[g].n_warm() as u64);
            }
            self.loading.insert((g, model), ready);
            self.cold_delays_ms.push(us_to_ms(ready.saturating_sub(req.arrival)));
            self.held.entry((g, model)).or_default().push(req);
            self.stats.cold_delayed += 1;
            self.stats.load_ms_total += load_ms;
            return Some(g);
        }
        None
    }

    /// Best-case completion estimate the overload front door (and its
    /// breakers) reasons about: analytic queue time over backlog +
    /// parked + health penalty, plus any remaining weight upload when
    /// the replica is cold — the same quantity the plain admission
    /// check computes.
    fn admit_est_us(
        &mut self,
        t: Us,
        model: usize,
        rep: &Replica,
        engines: &[Option<ExecEngine>],
    ) -> Us {
        let backlog = self
            .cache
            .backlog(engines, rep)
            .saturating_add(self.held.get(&(rep.gpu, model)).map_or(0, |v| v.len()))
            .saturating_add(self.res.as_ref().map_or(0, |r| r.penalty_items(rep.gpu)));
        let mut est = queue_est_us(backlog, rep.batch, rep.capacity_rps);
        if !self.stores[rep.gpu].is_warm(model) {
            let remaining_ms = match self.loading.get(&(rep.gpu, model)) {
                Some(&ready) => us_to_ms(ready.saturating_sub(t)),
                None => self
                    .cfg
                    .reconfig
                    .cold_load_ms(self.profiles[model].load_ms, self.stores[rep.gpu].n_warm()),
            };
            est = est.saturating_add(ms_to_us(remaining_ms));
        }
        est
    }

    /// The overload front door (armed `ovl` only): family-ordered
    /// admission — the primary first, then its brownout variants — with
    /// per-engine breaker feeding/filtering, resolved through
    /// [`Self::dispatch_on`] (warm-serve / park / cold-start for the
    /// primary), a scheduled retry, or a typed terminal reject.
    /// Variants are residency-gated: only replicas whose weights are
    /// already warm are candidates, so a brownout never triggers a
    /// fallback cold start. `attempt` is 0 for fresh arrivals and the
    /// retry ordinal for re-entries.
    #[allow(clippy::too_many_arguments)]
    fn overload_dispatch(
        &mut self,
        t: Us,
        attempt: u32,
        req: Request,
        work: &mut VecDeque<(usize, Request)>,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
    ) {
        let m = req.model;
        let order = self.ovl.as_ref().expect("overload dispatch without layer").service_order(m);
        let mut cause = RejectKind::Unroutable;
        for (fi, &fm) in order.iter().enumerate() {
            let healthy: Vec<Replica> = self.plan.placement.replicas[fm]
                .iter()
                .filter(|r| self.res.as_ref().is_none_or(|res| res.routable(r.gpu)))
                .filter(|r| fi == 0 || self.stores[r.gpu].is_warm(fm))
                .cloned()
                .collect();
            if healthy.is_empty() {
                continue; // `cause` stays Unroutable for the primary
            }
            // Every healthy replica's estimate feeds its breaker; only
            // breaker-approved replicas stay candidates.
            let mut open: Vec<Replica> = Vec::with_capacity(healthy.len());
            let mut best = Us::MAX;
            for rep in &healthy {
                let est = self.admit_est_us(t, fm, rep, engines);
                let miss = t.saturating_add(est) > req.deadline;
                let ovl = self.ovl.as_mut().expect("checked above");
                ovl.note_estimate(t, rep.gpu, miss);
                if ovl.allows(t, rep.gpu) {
                    if est < best {
                        best = est;
                    }
                    open.push(rep.clone());
                }
            }
            if open.is_empty() {
                if fi == 0 {
                    cause = RejectKind::BreakerOpen;
                }
                continue;
            }
            if t.saturating_add(best) > req.deadline {
                if fi == 0 {
                    cause = RejectKind::Deadline;
                }
                continue;
            }
            // Route among the breaker-approved replicas with the same
            // warmness-aware cost `dispatch` probes.
            let cache = &mut self.cache;
            let res = self.res.as_ref();
            let (held, stores, loading) = (&self.held, &self.stores, &self.loading);
            let (cfg, profiles) = (self.cfg, self.profiles);
            let pick = self.router.route(fm, &open, |rep| {
                let backlog = cache.backlog(engines, rep);
                let parked = held.get(&(rep.gpu, fm)).map_or(0, |v| v.len());
                let base = backlog
                    .saturating_add(parked)
                    .saturating_add(res.map_or(0, |r| r.penalty_items(rep.gpu)));
                if !cfg.warm_routing || stores[rep.gpu].is_warm(fm) {
                    return base;
                }
                let remaining_ms = match loading.get(&(rep.gpu, fm)) {
                    Some(&ready) => us_to_ms(ready.saturating_sub(t)),
                    None => cfg
                        .reconfig
                        .cold_load_ms(profiles[fm].load_ms, stores[rep.gpu].n_warm()),
                };
                base.saturating_add((remaining_ms * rep.capacity_rps / 1_000.0).ceil() as usize)
            });
            let landed = self.dispatch_on(t, fm, req, &open, pick, work, engines, touched);
            let class = self.res.as_ref().map_or(SloClass::LatencyCritical, |r| r.class(m));
            match landed {
                Some(g) => {
                    let ovl = self.ovl.as_mut().expect("checked above");
                    ovl.note_dispatch(t, g);
                    if fi > 0 {
                        ovl.note_degraded(class);
                    }
                    if attempt > 0 {
                        ovl.note_retry_served();
                    }
                }
                // Crowded out everywhere despite passing admission: the
                // pre-existing untyped lifecycle reject (no residency
                // path), kept identical so conservation still holds.
                None => self.rejected[fm] += 1,
            }
            return;
        }
        self.overload_reject(t, attempt, &req, cause);
    }

    /// A request the overload front door could not place anywhere in its
    /// family: schedule a backoff retry if budget remains, else issue
    /// the terminal typed reject (`retry_exhausted` when retries are on,
    /// the original cause otherwise).
    fn overload_reject(&mut self, t: Us, attempt: u32, req: &Request, cause: RejectKind) {
        let m = req.model;
        if self.ovl.as_mut().expect("overload reject without layer").try_schedule_retry(
            t,
            req,
            attempt + 1,
        ) {
            return; // re-enters at its release barrier; not terminal
        }
        self.rejected[m] += 1;
        let class = self.res.as_ref().map_or(SloClass::LatencyCritical, |r| r.class(m));
        let forward = self.ovl.as_mut().expect("checked above").note_terminal(cause, class);
        match forward {
            Some(RejectKind::Deadline) => {
                if let Some(res) = &mut self.res {
                    res.note_deadline_reject(m);
                }
            }
            Some(RejectKind::Unroutable) => {
                if let Some(res) = &mut self.res {
                    res.note_unroutable();
                }
            }
            _ => {}
        }
        if self.obs.on() {
            self.obs.event(EventKind::Reject, t, m as u32, req.id, 0);
        }
    }

impl LifecycleDriver<'_> {
    /// True when no arrival can trigger a cold start right now: every
    /// replica of every admitted model is warm or already mid-load.
    /// Warm hits only touch driver state + inject; parks only touch
    /// driver state; and nothing inside a span can turn a warm replica
    /// cold (evictions need cold starts, scale-to-zero and load
    /// maturities are driver events that end the span) — so under
    /// backlog-free routing a whole such span is elidable.
    fn warm_span_ready(&self) -> bool {
        self.plan.placement.replicas.iter().enumerate().all(|(m, reps)| {
            reps.iter().all(|r| {
                self.stores[r.gpu].is_warm(m) || self.loading.contains_key(&(r.gpu, m))
            })
        })
    }

    /// Apply every fault-timeline event due at `t`, then run the hedge
    /// sweep if its cadence tick is due. Called at the head of every
    /// barrier — driver events surface the timeline's instants, so the
    /// schedule lands on the same virtual-time barriers regardless of
    /// exec mode or thread count.
    fn apply_faults(&mut self, t: Us, engines: &mut [Option<ExecEngine>], touched: &mut Touched) {
        let due = match self.res.as_mut() {
            Some(r) => r.due_faults(t),
            None => return,
        };
        for e in due {
            match e.kind {
                FaultKind::Down => self.on_down(t, e.gpu, engines, touched),
                FaultKind::Degraded => {
                    if self.obs.on() {
                        self.obs.event(EventKind::EngineDown, t, NO_MODEL, e.gpu as u64, 1);
                    }
                }
                FaultKind::Up => {
                    // ModelStore drivers recover *on demand*: the engine
                    // is routable again immediately, and every model
                    // faults back in through the ordinary cold-start
                    // path — the same §3.2 cost model the eager-restore
                    // drivers charge up front, paid lazily per model.
                    let res = self.res.as_mut().expect("fault event without resilience");
                    if res.restoring(e.gpu) {
                        res.mark_restored(e.gpu, t);
                    }
                    if self.obs.on() {
                        self.obs.event(EventKind::EngineUp, t, NO_MODEL, e.gpu as u64, 0);
                    }
                }
            }
        }
        if self.res.as_ref().is_some_and(|r| r.hedge_due(t)) {
            self.hedge_sweep(t, engines, touched);
        }
    }

    /// Hard engine failure: the serving process and its device memory
    /// are gone. Drain every active slot, cancel in-flight weight
    /// uploads (their parked requests join the drained queues), wipe
    /// the store, and cascade the orphans through the ordinary
    /// dispatch path — they may fault their models in elsewhere. With
    /// rerouting disabled (the naive baseline) the orphans are plain
    /// rejects instead.
    fn on_down(&mut self, t: Us, g: usize, engines: &mut [Option<ExecEngine>], touched: &mut Touched) {
        if self.obs.on() {
            self.obs.event(EventKind::EngineDown, t, NO_MODEL, g as u64, 0);
        }
        let mut orphans: Vec<(usize, Request)> = Vec::new();
        if let Some(engine) = engines[g].as_mut() {
            let mut drained_any = false;
            for (local, &global) in self.plan.placement.hosted[g].iter().enumerate() {
                if !engine.sim.is_active(local) {
                    continue; // tombstone (cold / scaled to zero) — nothing queued
                }
                for r in engine.sim.deactivate_model(local) {
                    orphans.push((global, r));
                }
                self.cache.invalidate(g, local);
                drained_any = true;
            }
            if drained_any {
                engine.rebuild_policy(self.sched);
            }
            touched.mark(g);
        }
        let dead_loads: Vec<(usize, usize)> =
            self.loading.keys().filter(|k| k.0 == g).copied().collect();
        for key in dead_loads {
            self.loading.remove(&key);
            for r in self.held.remove(&key).unwrap_or_default() {
                orphans.push((key.1, r));
            }
        }
        self.stores[g].crash();
        if self.obs.on() {
            self.obs.warm_level(g, t, 0);
        }
        let reroute = self.res.as_ref().is_none_or(|r| r.cfg.reroute);
        if reroute {
            let n = orphans.len() as u64;
            let mut work = std::mem::take(&mut self.scratch);
            debug_assert!(work.is_empty());
            for (m, mut r) in orphans {
                r.model = m;
                work.push_back((m, r));
            }
            while let Some((m, q)) = work.pop_front() {
                self.dispatch(t, m, q, &mut work, engines, touched);
            }
            self.scratch = work;
            if let Some(res) = self.res.as_mut() {
                res.note_reroute(n);
            }
        } else {
            for (m, r) in orphans {
                self.rejected[m] += 1;
                if self.obs.on() {
                    self.obs.event(EventKind::Reject, t, m as u32, r.id, 0);
                }
            }
        }
    }

    /// Hedged re-dispatch off degraded engines: requests queued past
    /// their SLO class's threshold move to the analytically best *warm*,
    /// healthy peer replica when its estimate strictly beats the source
    /// (ties to the lower engine index — [`pick_hedge_target`]). The
    /// sim is work-conserving, so moving the stuck queue prefix *is*
    /// first-completion-wins with the losing copy cancelled eagerly.
    fn hedge_sweep(&mut self, t: Us, engines: &mut [Option<ExecEngine>], touched: &mut Touched) {
        for g in 0..engines.len() {
            if !self.res.as_ref().is_some_and(|r| r.degraded(g)) || engines[g].is_none() {
                continue;
            }
            for (local, &global) in self.plan.placement.hosted[g].iter().enumerate() {
                let res = self.res.as_ref().expect("degraded without resilience");
                let cutoff = t.saturating_sub(res.hedge_threshold_us(global));
                let stuck = engines[g].as_ref().unwrap().sim.queued_before(local, cutoff);
                if stuck == 0 {
                    continue;
                }
                let Some(src_rep) =
                    self.plan.placement.replicas[global].iter().find(|r| r.gpu == g)
                else {
                    continue;
                };
                let cache = &mut self.cache;
                let stores = &self.stores;
                let src_est = queue_est_us(
                    cache.backlog(engines, src_rep).saturating_add(res.penalty_items(g)),
                    src_rep.batch,
                    src_rep.capacity_rps,
                );
                let cands: Vec<(Us, usize)> = self.plan.placement.replicas[global]
                    .iter()
                    .filter(|r| {
                        r.gpu != g && res.routable(r.gpu) && stores[r.gpu].is_warm(global)
                    })
                    .map(|r| {
                        let backlog = cache
                            .backlog(engines, r)
                            .saturating_add(res.penalty_items(r.gpu));
                        (queue_est_us(backlog, r.batch, r.capacity_rps), r.gpu)
                    })
                    .collect();
                match pick_hedge_target((src_est, g), &cands) {
                    None => {
                        // Stuck copy wins: hedge fired, copy cancelled.
                        self.res.as_mut().expect("checked").note_hedges(stuck as u64, 0);
                    }
                    Some(win) => {
                        let target = self.plan.placement.replicas[global]
                            .iter()
                            .find(|r| r.gpu == win)
                            .expect("hedge winner is a replica");
                        let (t_gpu, t_local) = (target.gpu, target.local);
                        let moved =
                            engines[g].as_mut().unwrap().sim.take_queued_before(local, cutoff);
                        let n = moved.len() as u64;
                        for mut r in moved {
                            if self.obs.on() {
                                self.obs.event(
                                    EventKind::Hedge,
                                    t,
                                    global as u32,
                                    r.id,
                                    t_gpu as u64,
                                );
                            }
                            r.model = t_local;
                            engines[t_gpu]
                                .as_mut()
                                .expect("warm hedge target on idle GPU")
                                .sim
                                .inject(r);
                            self.cache.note_inject(t_gpu, t_local);
                        }
                        self.stores[t_gpu].touch(t, global);
                        self.cache.invalidate(g, local);
                        touched.mark(g);
                        touched.mark(t_gpu);
                        self.res.as_mut().expect("checked").note_hedges(n, n);
                        // A hedge fired off this engine: that's a strike
                        // against its breaker.
                        if let Some(ovl) = &mut self.ovl {
                            ovl.note_hedge_loss(t, g);
                        }
                    }
                }
            }
        }
    }
}

impl EpochDriver for LifecycleDriver<'_> {
    fn n_models(&self) -> usize {
        self.rejected.len()
    }

    fn candidates_of(&self, model: usize) -> &[usize] {
        &self.cand[model]
    }

    fn elides_barriers(&self) -> bool {
        // Fault timelines, hedge sweeps, admission and the overload
        // front door all read engine state at barriers — never elide
        // while resilience or overload control is on.
        self.free_routing && self.warm_span_ready() && self.res.is_none() && self.ovl.is_none()
    }

    /// Barrier-free routing inside a fully-warm span: reproduces
    /// [`Self::dispatch`]'s decision and driver-state mutations (RR
    /// cursor, store touch, warm/park counters) without touching any
    /// engine. Cold starts cannot occur here — [`Self::elides_barriers`]
    /// only admits spans where every replica is warm or mid-load.
    fn route_free(&mut self, t: Us, req: &Request) -> Option<(usize, usize)> {
        let model = req.model;
        if self.obs.on() {
            self.obs.event(EventKind::Arrive, req.arrival, model as u32, req.id, 0);
        }
        let reps: &[Replica] = &self.plan.placement.replicas[model];
        if reps.is_empty() {
            self.rejected[model] += 1;
            if self.obs.on() {
                self.obs.event(EventKind::Reject, req.arrival, model as u32, req.id, 0);
            }
            return None;
        }
        // Backlog-free policies never call the cost closure.
        let pick = self.router.route(model, reps, |_| 0);
        let order = std::iter::once(pick).chain((0..reps.len()).filter(|&i| i != pick));
        for i in order {
            let r = &reps[i];
            let g = r.gpu;
            if self.stores[g].is_warm(model) {
                self.stores[g].touch(t, model);
                if self.obs.on() {
                    self.obs.event(EventKind::Route, req.arrival, model as u32, req.id, g as u64);
                }
                self.stats.warm_hits += 1;
                return Some((g, r.local));
            }
            if let Some(&ready) = self.loading.get(&(g, model)) {
                self.cold_delays_ms.push(us_to_ms(ready.saturating_sub(req.arrival)));
                self.held.entry((g, model)).or_default().push(req.clone());
                self.stats.cold_delayed += 1;
                return None;
            }
            debug_assert!(false, "cold start inside an elided warm span");
        }
        self.rejected[model] += 1;
        if self.obs.on() {
            self.obs.event(EventKind::Reject, req.arrival, model as u32, req.id, 0);
        }
        None
    }

    fn next_event(&self) -> Option<Us> {
        let t_load = self.loading.values().min().copied();
        let t_idle = self
            .idle_timeout
            .and_then(|to| self.stores.iter().filter_map(|s| s.next_idle_expiry(to)).min());
        let t_res = self.res.as_ref().and_then(|r| r.next_event());
        let t_retry = self.ovl.as_ref().and_then(|o| o.next_release());
        [t_load, t_idle, t_res, t_retry].into_iter().flatten().min()
    }

    /// Mature loads due at t: the model becomes warm, its tombstone
    /// slot reactivates, parked requests inject with their original
    /// arrival times (cold delay shows up as end-to-end latency).
    fn pre_arrivals(&mut self, t: Us, engines: &mut [Option<ExecEngine>], touched: &mut Touched) {
        self.cache.reset();
        // Faults first: an engine going down at t cancels its in-flight
        // loads before the maturation sweep below could complete them.
        if self.res.is_some() {
            self.apply_faults(t, engines, touched);
        }
        let due: Vec<(usize, usize)> = self
            .loading
            .iter()
            .filter(|&(_, &ready)| ready <= t)
            .map(|(&k, _)| k)
            .collect();
        for (g, m) in due {
            self.loading.remove(&(g, m));
            self.stores[g].complete_load(t, m);
            if self.obs.on() {
                self.obs.warm_level(g, t, self.stores[g].n_warm() as u64);
            }
            let local = self.local_of[g][m].expect("loaded model without a slot");
            let rep = self.plan.placement.replicas[m]
                .iter()
                .find(|r| r.gpu == g)
                .expect("loaded model without a replica");
            let engine = engines[g].as_mut().expect("load on idle GPU");
            engine.sim.reactivate_model(
                local,
                ModelEntry {
                    profile: self.profiles[m].clone(),
                    pct: rep.pct,
                    batch: rep.batch,
                },
            );
            engine.rebuild_policy(self.sched);
            for mut r in self.held.remove(&(g, m)).unwrap_or_default() {
                self.stores[g].touch(t, m);
                r.model = local;
                engine.sim.inject(r);
            }
            touched.mark(g);
        }
        // Matured backoff retries re-enter the front door after faults
        // and load maturities so they see the post-barrier warm sets.
        if self.ovl.is_some() {
            let mut work = std::mem::take(&mut self.scratch);
            debug_assert!(work.is_empty());
            for (attempt, req) in self.ovl.as_mut().expect("checked").due_retries(t) {
                self.overload_dispatch(t, attempt, req, &mut work, engines, touched);
                while let Some((m, q)) = work.pop_front() {
                    self.dispatch(t, m, q, &mut work, engines, touched);
                }
            }
            self.scratch = work;
        }
    }

    /// Route one arrival, draining any eviction cascade it triggers.
    fn route(
        &mut self,
        t: Us,
        req: Request,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
    ) {
        if self.obs.on() {
            self.obs.event(EventKind::Arrive, req.arrival, req.model as u32, req.id, 0);
        }
        if self.ovl.is_some() {
            // The overload front door subsumes plain admission: family-
            // ordered estimates, breaker filtering, retry scheduling.
            // Victim queues drained by an eviction cascade re-route
            // through the ordinary dispatch path (sunk work).
            let mut work = std::mem::take(&mut self.scratch);
            debug_assert!(work.is_empty());
            self.overload_dispatch(t, 0, req, &mut work, engines, touched);
            while let Some((m, q)) = work.pop_front() {
                self.dispatch(t, m, q, &mut work, engines, touched);
            }
            self.scratch = work;
            return;
        }
        // Deadline-aware admission (fresh arrivals only — cascade
        // re-routes inside `dispatch` already carry sunk work): reject
        // outright when even the best-case replica — shortest analytic
        // queue estimate plus any remaining weight upload — cannot meet
        // the request's deadline.
        let admitted = match self.res.as_ref() {
            Some(res) if res.cfg.admission => {
                let m = req.model;
                let cache = &mut self.cache;
                let (held, stores, loading) = (&self.held, &self.stores, &self.loading);
                let (cfg, profiles) = (self.cfg, self.profiles);
                let best = self.plan.placement.replicas[m]
                    .iter()
                    .filter(|r| res.routable(r.gpu))
                    .map(|r| {
                        let backlog = cache
                            .backlog(engines, r)
                            .saturating_add(held.get(&(r.gpu, m)).map_or(0, |v| v.len()))
                            .saturating_add(res.penalty_items(r.gpu));
                        let mut est = queue_est_us(backlog, r.batch, r.capacity_rps);
                        if !stores[r.gpu].is_warm(m) {
                            let remaining_ms = match loading.get(&(r.gpu, m)) {
                                Some(&ready) => us_to_ms(ready.saturating_sub(t)),
                                None => cfg
                                    .reconfig
                                    .cold_load_ms(profiles[m].load_ms, stores[r.gpu].n_warm()),
                            };
                            est = est.saturating_add(ms_to_us(remaining_ms));
                        }
                        est
                    })
                    .min();
                // No routable replica ⇒ fall through to dispatch's
                // unroutable reject (counted there, not as a deadline
                // miss).
                match best {
                    Some(best) => t.saturating_add(best) <= req.deadline,
                    None => true,
                }
            }
            _ => true,
        };
        if !admitted {
            let m = req.model;
            self.rejected[m] += 1;
            self.res.as_mut().expect("admission without resilience").note_deadline_reject(m);
            if self.obs.on() {
                self.obs.event(EventKind::Reject, t, m as u32, req.id, 0);
            }
            return;
        }
        let mut work = std::mem::take(&mut self.scratch);
        debug_assert!(work.is_empty());
        work.push_back((req.model, req));
        while let Some((m, q)) = work.pop_front() {
            self.dispatch(t, m, q, &mut work, engines, touched);
        }
        // Hand the (empty) queue back so its capacity is reused.
        self.scratch = work;
    }

    /// Scale-to-zero sweep: idle warm residents with an empty backlog
    /// release memory and knee budget; residents that are idle by the
    /// clock but still draining are re-armed (they are in use, not
    /// idle).
    fn post_arrivals(&mut self, t: Us, engines: &mut [Option<ExecEngine>], touched: &mut Touched) {
        let Some(to) = self.idle_timeout else { return };
        for g in 0..self.stores.len() {
            for m in self.stores[g].idle_candidates(t, to) {
                let local = self.local_of[g][m].expect("resident without a slot");
                let engine = engines[g].as_mut().expect("resident on idle GPU");
                if engine.sim.backlog_items(local) == 0 {
                    let released = self.stores[g].release(m);
                    debug_assert!(released, "idle candidate refused release");
                    let drained = engine.sim.deactivate_model(local);
                    debug_assert!(drained.is_empty(), "empty backlog drained requests");
                    engine.rebuild_policy(self.sched);
                    self.stats.scale_to_zero += 1;
                    if self.obs.on() {
                        self.obs.event(
                            EventKind::ScaleZero,
                            t,
                            m as u32,
                            g as u64,
                            self.profiles[m].mem_mib,
                        );
                        self.obs.count_control(EventKind::ScaleZero, t);
                        self.obs.warm_level(g, t, self.stores[g].n_warm() as u64);
                    }
                    touched.mark(g);
                } else {
                    self.stores[g].touch(t, m);
                }
            }
        }
    }
}

/// Serve `requests` on `gpus` under the lifecycle memory manager:
/// `plan` assigns models and the t = 0 resident sets; everything beyond
/// the resident sets is faulted in on demand (evicting per
/// `cfg.eviction`), idles out per `cfg.idle_timeout_ms`, and routes per
/// `routing` with warmness-aware costs when `cfg.warm_routing`.
/// Deterministic: a fixed (inputs, seed) tuple always yields the same
/// report, including the load/eviction schedule — for any thread count.
#[allow(clippy::too_many_arguments)]
pub fn run_lifecycle(
    profiles: &[ModelProfile],
    gpus: &[GpuSpec],
    plan: &ResidencyPlan,
    routing: RoutingPolicy,
    sched: GpuSched,
    cfg: &LifecycleCfg,
    requests: Vec<Request>,
    horizon_ms: f64,
    seed: u64,
) -> ClusterReport {
    run_lifecycle_with(
        profiles,
        gpus,
        plan,
        routing,
        sched,
        cfg,
        requests,
        horizon_ms,
        seed,
        ExecOpts::default(),
    )
}

/// [`run_lifecycle`] with explicit execution options (thread budget +
/// barrier mode). Thin adapter over [`run_lifecycle_stream`] via
/// [`MaterializedStream`] — identical report bytes.
#[allow(clippy::too_many_arguments)]
pub fn run_lifecycle_with(
    profiles: &[ModelProfile],
    gpus: &[GpuSpec],
    plan: &ResidencyPlan,
    routing: RoutingPolicy,
    sched: GpuSched,
    cfg: &LifecycleCfg,
    requests: Vec<Request>,
    horizon_ms: f64,
    seed: u64,
    opts: ExecOpts,
) -> ClusterReport {
    let stream = MaterializedStream::new(requests, profiles.len());
    run_lifecycle_stream(
        profiles, gpus, plan, routing, sched, cfg, stream, horizon_ms, seed, opts,
    )
}

/// [`run_lifecycle`] pulling arrivals lazily from any [`ArrivalStream`]
/// — faults, evictions and idle expiries are driven by routed requests
/// and driver events, so laziness changes nothing but memory.
#[allow(clippy::too_many_arguments)]
pub fn run_lifecycle_stream<S: ArrivalStream>(
    profiles: &[ModelProfile],
    gpus: &[GpuSpec],
    plan: &ResidencyPlan,
    routing: RoutingPolicy,
    sched: GpuSched,
    cfg: &LifecycleCfg,
    stream: S,
    horizon_ms: f64,
    seed: u64,
    opts: ExecOpts,
) -> ClusterReport {
    run_lifecycle_stream_faults(
        profiles, gpus, plan, routing, sched, cfg, stream, horizon_ms, seed, opts, None,
    )
}

/// [`run_lifecycle_stream`] with an optional fault timeline + SLO-class
/// front door ([`crate::faults`]): engine failures crash the store
/// (weights are gone), drain queues into the eviction-cascade
/// re-dispatch path, and recover *on demand* — the restored engine
/// comes back empty and every model faults back in through the
/// ordinary cold-start machinery, paying the same §3.2 load cost the
/// eager-restore drivers charge up front.
#[allow(clippy::too_many_arguments)]
pub fn run_lifecycle_stream_faults<S: ArrivalStream>(
    profiles: &[ModelProfile],
    gpus: &[GpuSpec],
    plan: &ResidencyPlan,
    routing: RoutingPolicy,
    sched: GpuSched,
    cfg: &LifecycleCfg,
    stream: S,
    horizon_ms: f64,
    seed: u64,
    opts: ExecOpts,
    faults: Option<&ResilienceCfg>,
) -> ClusterReport {
    run_lifecycle_stream_overload(
        profiles, gpus, plan, routing, sched, cfg, stream, horizon_ms, seed, opts, faults, None,
    )
}

/// [`run_lifecycle_stream_faults`] with the overload-control layer
/// ([`crate::overload`]). `overload: None` is the exact faults path.
/// When armed with brownout variants, `profiles` and `plan` must
/// already cover the expanded family list — variants are ordinary
/// residency-managed entries (plan, stores, idle-out) that the front
/// door falls back to only where they are currently warm.
#[allow(clippy::too_many_arguments)]
pub fn run_lifecycle_stream_overload<S: ArrivalStream>(
    profiles: &[ModelProfile],
    gpus: &[GpuSpec],
    plan: &ResidencyPlan,
    routing: RoutingPolicy,
    sched: GpuSched,
    cfg: &LifecycleCfg,
    stream: S,
    horizon_ms: f64,
    seed: u64,
    opts: ExecOpts,
    faults: Option<&ResilienceCfg>,
    overload: Option<&OverloadSpec>,
) -> ClusterReport {
    cfg.validate().expect("invalid lifecycle config");
    let n_models = profiles.len();
    let n_gpus = gpus.len();
    assert_eq!(plan.placement.n_gpus(), n_gpus, "plan built for a different cluster");
    if let Some(spec) = overload {
        assert_eq!(n_models, spec.map.n_total(), "profiles not expanded for variants");
    }
    let horizon = ms_to_us(horizon_ms);
    let idle_timeout: Option<Us> = if cfg.idle_timeout_ms > 0.0 {
        Some(ms_to_us(cfg.idle_timeout_ms).max(1))
    } else {
        None
    };
    let pinned: Vec<bool> =
        profiles.iter().map(|p| cfg.pinned.iter().any(|n| n == &p.name)).collect();

    // --- engines, stores, index maps ---------------------------------------
    let mut local_of: Vec<Vec<Option<usize>>> = vec![vec![None; n_models]; n_gpus];
    let mut engines: Vec<Option<ExecEngine>> = (0..n_gpus)
        .map(|g| {
            if plan.placement.hosted[g].is_empty() {
                return None;
            }
            let entries: Vec<ModelEntry> = plan.placement.hosted[g]
                .iter()
                .enumerate()
                .map(|(local, &m)| {
                    local_of[g][m] = Some(local);
                    let rep = plan.placement.replicas[m]
                        .iter()
                        .find(|r| r.gpu == g)
                        .expect("hosted model without a replica entry");
                    debug_assert_eq!(rep.local, local, "plan local indices drifted");
                    ModelEntry { profile: profiles[m].clone(), pct: rep.pct, batch: rep.batch }
                })
                .collect();
            let sim_cfg =
                SimConfig { gpu: gpus[g].clone(), horizon_ms, obs: opts.obs, ..Default::default() };
            let mut sim = Sim::new(sim_cfg, entries);
            // Everything outside the t = 0 resident set starts as a
            // tombstone: no knee budget, no traffic until faulted in.
            for (local, &m) in plan.placement.hosted[g].iter().enumerate() {
                if !plan.resident0[g].contains(&m) {
                    let drained = sim.deactivate_model(local);
                    debug_assert!(drained.is_empty());
                }
            }
            let mask = sim.active_mask();
            let policy = sched.build_masked(&sim.models, &mask);
            Some(ExecEngine { sim, policy })
        })
        .collect();

    let stores: Vec<ModelStore> = (0..n_gpus)
        .map(|g| {
            let mut s = ModelStore::new(plan.mem_budget_mib[g], cfg.eviction);
            for &m in &plan.resident0[g] {
                let ok = s.preload(0, m, profiles[m].mem_mib, profiles[m].load_ms, pinned[m]);
                assert!(ok, "resident0 oversubscribes gpu {g}'s memory budget");
            }
            s
        })
        .collect();

    let mut driver = LifecycleDriver {
        profiles,
        plan,
        cand: reachability_candidates(&plan.placement.hosted, n_models),
        free_routing: !routing.reads_backlogs(),
        cfg,
        sched,
        pinned,
        local_of,
        stores,
        router: Router::new(routing, n_models, seed),
        cache: BacklogCache::default(),
        rejected: vec![0u64; n_models],
        loading: BTreeMap::new(),
        held: BTreeMap::new(),
        cold_delays_ms: Vec::new(),
        stats: LifecycleStats::default(),
        idle_timeout,
        scratch: VecDeque::new(),
        res: {
            // The overload layer routes through the resilience front
            // door's admission estimate; when armed without an explicit
            // fault config, synthesize a minimal admission-only door.
            let synth_cfg;
            let res_cfg = match (faults, overload) {
                (Some(f), _) => Some(f),
                (None, Some(_)) => {
                    synth_cfg = ResilienceCfg {
                        admission: true,
                        hedge: false,
                        ..ResilienceCfg::default()
                    };
                    Some(&synth_cfg)
                }
                (None, None) => None,
            };
            res_cfg.map(|f| {
                Resilience::new(f.clone(), profiles, n_gpus, horizon)
                    .expect("invalid faults config (validate at the config layer)")
            })
        },
        ovl: overload.map(|spec| Overload::new(spec, n_gpus)),
        obs: Recorder::new(opts.obs, horizon),
    };
    // Seed the warm-set timeline with the t = 0 resident sets so the
    // first window reflects the preloaded state, not zero.
    if driver.obs.on() {
        for g in 0..n_gpus {
            let level = driver.stores[g].n_warm() as u64;
            driver.obs.warm_level(g, 0, level);
        }
    }
    let exec_stats = run_epochs_stream(&mut engines, stream, horizon, opts, &mut driver);
    let LifecycleDriver {
        stores,
        mut rejected,
        held,
        cold_delays_ms,
        mut stats,
        res,
        mut ovl,
        obs: mut obs_rec,
        ..
    } = driver;
    // Retries still pending at the horizon never got a terminal answer:
    // count them as retry-exhausted rejects so every offered request is
    // accounted.
    if let Some(o) = &mut ovl {
        for (_attempt, req) in o.drain_leftover() {
            rejected[req.model] += 1;
            let class =
                res.as_ref().map_or(SloClass::LatencyCritical, |r| r.class(req.model));
            o.note_retry_exhausted(class);
        }
    }
    // Requests still parked behind an immature load never reached an
    // engine; stamp their drops on the control lane at the horizon.
    if obs_rec.on() {
        for ((_, m), reqs) in &held {
            for r in reqs {
                obs_rec.event(EventKind::Drop, horizon, *m as u32, r.id, 0);
                obs_rec.count_drop(horizon);
            }
        }
    }
    let control_obs = obs_rec.finish(profiles.iter().map(|p| p.name.clone()).collect());

    // --- finalize + aggregate ----------------------------------------------
    let reports: Vec<Option<RunReport>> = engines
        .iter_mut()
        .map(|slot| slot.as_mut().map(|e| e.finalize(horizon)))
        .collect();
    let obs_lanes: Vec<EngineObs> = engines
        .iter_mut()
        .map(|slot| slot.as_mut().map(|e| e.sim.take_obs()).unwrap_or_default())
        .collect();
    let obs = ObsReport::collect(opts.obs, horizon, obs_lanes, control_obs);

    let horizon_s = horizon_ms / 1_000.0;
    let mut throughput = vec![0.0; n_models];
    let mut violations = vec![0.0; n_models];
    let mut served = vec![0u64; n_models];
    let mut served_in_slo = 0u64;
    let mut dropped = vec![0u64; n_models];
    let mut latencies: Vec<Vec<f64>> = vec![Vec::new(); n_models];
    let mut hists: Vec<LogHistogram> = vec![LogHistogram::default(); n_models];
    let mut gpu_utilization = Vec::with_capacity(n_gpus);
    let mut per_gpu = Vec::with_capacity(n_gpus);
    // (completion time, in-SLO) pairs for the degraded-goodput stat —
    // only collected when a fault timeline is active.
    let mut comps: Vec<(Us, bool)> = Vec::new();
    for g in 0..n_gpus {
        let (util, shares) = match &reports[g] {
            Some(rep) => {
                let mut shares = Vec::with_capacity(rep.per_model.len());
                for (local, mm) in rep.per_model.iter().enumerate() {
                    let global = plan.placement.hosted[g][local];
                    throughput[global] += mm.served as f64 / horizon_s;
                    violations[global] += mm.slo_violations() as f64 / horizon_s;
                    served[global] += mm.served;
                    served_in_slo += mm.served_in_slo;
                    if res.is_some() {
                        for (lat, &done) in mm.latencies_ms.iter().zip(&mm.completions_us) {
                            comps.push((done, *lat <= profiles[global].slo_ms));
                        }
                    }
                    dropped[global] += mm.dropped;
                    latencies[global].extend_from_slice(&mm.latencies_ms);
                    hists[global].merge(&mm.latency_hist);
                    // Shares list the final resident set only, keeping
                    // per_gpu consistent with what the GPU holds at the
                    // horizon.
                    let engine = engines[g].as_ref().expect("reported engine");
                    if engine.sim.is_active(local) {
                        let entry = &engine.sim.models[local];
                        shares.push(GpuModelShare {
                            model: global,
                            pct: entry.pct,
                            batch: entry.batch,
                            served: mm.served,
                        });
                    }
                }
                (rep.gpu_utilization[0], shares)
            }
            None => (0.0, Vec::new()),
        };
        gpu_utilization.push(util);
        per_gpu.push(GpuReport {
            gpu: gpus[g].name.to_string(),
            knee_load_pct: plan.placement.knee_load[g],
            utilization: util,
            models: shares,
        });
    }
    // Requests still parked behind a load that never matured inside the
    // horizon were never served — count them as dropped so conservation
    // (served + dropped + rejected = offered) holds.
    for ((_, m), reqs) in &held {
        dropped[*m] += reqs.len() as u64;
        violations[*m] += reqs.len() as f64 / horizon_s;
    }
    for m in 0..n_models {
        violations[m] += rejected[m] as f64 / horizon_s;
    }
    let p99_ms: Vec<f64> = latencies.iter().zip(&hists).map(|(l, h)| p99_of(l, h)).collect();
    let replica_map: Vec<Vec<usize>> = plan
        .placement
        .replicas
        .iter()
        .map(|reps| reps.iter().map(|r| r.gpu).collect())
        .collect();

    // Load/eviction counters live in the stores (single source of
    // truth); the stats block just aggregates them.
    stats.cold_starts = stores.iter().map(|s| s.loads).sum();
    stats.evictions = stores.iter().map(|s| s.evictions).sum();
    stats.mib_loaded = stores.iter().map(|s| s.mib_loaded).sum();
    stats.cold_start_p99_ms = percentile(&cold_delays_ms, 99.0);
    stats.goodput_rps = served_in_slo as f64 / horizon_s;
    stats.peak_resident_mib = stores.iter().map(|s| s.peak_mib()).collect();
    stats.resident_final = stores.iter().map(|s| s.n_resident() as u64).collect();

    ClusterReport {
        policy: format!(
            "lifecycle+{}+{}{}+{}",
            cfg.eviction.name(),
            if cfg.warm_routing { "warm-" } else { "" },
            routing.name(),
            sched.name()
        ),
        throughput,
        gpu_utilization,
        violations_per_sec: violations,
        p99_ms,
        served,
        dropped,
        rejected,
        replica_map,
        shed_rps: plan.placement.shed_rps.clone(),
        admitted: plan.placement.admitted.clone(),
        per_gpu,
        adaptive: None,
        lifecycle: Some(stats),
        resilience: res.map(|mut r| r.finalize(horizon, comps.into_iter())),
        overload: ovl.map(|o| o.finalize()),
        exec: Some(exec_stats),
        obs,
    }
}

/// Plan + serve in one call: [`crate::cluster::plan_residency`] against
/// `cfg`'s memory budgets, then [`run_lifecycle`].
#[allow(clippy::too_many_arguments)]
pub fn serve_longtail(
    profiles: &[ModelProfile],
    offered_rps: &[f64],
    gpus: &[GpuSpec],
    placement: crate::cluster::PlacementPolicy,
    routing: RoutingPolicy,
    sched: GpuSched,
    cfg: &LifecycleCfg,
    requests: Vec<Request>,
    horizon_ms: f64,
    seed: u64,
) -> ClusterReport {
    serve_longtail_with(
        profiles,
        offered_rps,
        gpus,
        placement,
        routing,
        sched,
        cfg,
        requests,
        horizon_ms,
        seed,
        ExecOpts::default(),
    )
}

/// [`serve_longtail`] with explicit execution options.
#[allow(clippy::too_many_arguments)]
pub fn serve_longtail_with(
    profiles: &[ModelProfile],
    offered_rps: &[f64],
    gpus: &[GpuSpec],
    placement: crate::cluster::PlacementPolicy,
    routing: RoutingPolicy,
    sched: GpuSched,
    cfg: &LifecycleCfg,
    requests: Vec<Request>,
    horizon_ms: f64,
    seed: u64,
    opts: ExecOpts,
) -> ClusterReport {
    let stream = MaterializedStream::new(requests, profiles.len());
    serve_longtail_stream(
        profiles, offered_rps, gpus, placement, routing, sched, cfg, stream, horizon_ms, seed,
        opts,
    )
}

/// [`serve_longtail`] pulling arrivals lazily from any
/// [`ArrivalStream`]: residency planning + the streamed lifecycle run.
#[allow(clippy::too_many_arguments)]
pub fn serve_longtail_stream<S: ArrivalStream>(
    profiles: &[ModelProfile],
    offered_rps: &[f64],
    gpus: &[GpuSpec],
    placement: crate::cluster::PlacementPolicy,
    routing: RoutingPolicy,
    sched: GpuSched,
    cfg: &LifecycleCfg,
    stream: S,
    horizon_ms: f64,
    seed: u64,
    opts: ExecOpts,
) -> ClusterReport {
    serve_longtail_stream_faults(
        profiles, offered_rps, gpus, placement, routing, sched, cfg, stream, horizon_ms, seed,
        opts, None,
    )
}

/// [`serve_longtail_stream`] with an optional fault timeline
/// ([`run_lifecycle_stream_faults`]).
#[allow(clippy::too_many_arguments)]
pub fn serve_longtail_stream_faults<S: ArrivalStream>(
    profiles: &[ModelProfile],
    offered_rps: &[f64],
    gpus: &[GpuSpec],
    placement: crate::cluster::PlacementPolicy,
    routing: RoutingPolicy,
    sched: GpuSched,
    cfg: &LifecycleCfg,
    stream: S,
    horizon_ms: f64,
    seed: u64,
    opts: ExecOpts,
    faults: Option<&ResilienceCfg>,
) -> ClusterReport {
    let budgets = cfg.budgets(gpus);
    assert!(
        budgets.iter().all(|&b| b > 0),
        "lifecycle memory budget is zero after headroom ({budgets:?} MiB) — \
         lower headroom_mib or raise mem_budget_mib"
    );
    let plan = crate::cluster::plan_residency(
        profiles,
        offered_rps,
        gpus,
        placement,
        &budgets,
        cfg.min_replicas,
    );
    run_lifecycle_stream_faults(
        profiles, gpus, &plan, routing, sched, cfg, stream, horizon_ms, seed, opts, faults,
    )
}

/// [`serve_longtail_stream_faults`] with the overload-control layer:
/// residency planning over the full expanded family list (variants are
/// ordinary entries with zero offered demand, so they never displace a
/// primary's residency claim), then the overload-armed lifecycle run.
#[allow(clippy::too_many_arguments)]
pub fn serve_longtail_stream_overload<S: ArrivalStream>(
    profiles: &[ModelProfile],
    offered_rps: &[f64],
    gpus: &[GpuSpec],
    placement: crate::cluster::PlacementPolicy,
    routing: RoutingPolicy,
    sched: GpuSched,
    cfg: &LifecycleCfg,
    stream: S,
    horizon_ms: f64,
    seed: u64,
    opts: ExecOpts,
    faults: Option<&ResilienceCfg>,
    overload: Option<&OverloadSpec>,
) -> ClusterReport {
    let budgets = cfg.budgets(gpus);
    assert!(
        budgets.iter().all(|&b| b > 0),
        "lifecycle memory budget is zero after headroom ({budgets:?} MiB) — \
         lower headroom_mib or raise mem_budget_mib"
    );
    let plan = crate::cluster::plan_residency(
        profiles,
        offered_rps,
        gpus,
        placement,
        &budgets,
        cfg.min_replicas,
    );
    run_lifecycle_stream_overload(
        profiles, gpus, &plan, routing, sched, cfg, stream, horizon_ms, seed, opts, faults,
        overload,
    )
}

/// The 2×V100 cluster the canonical long-tail scenario is sized for.
pub fn longtail_gpus() -> Vec<GpuSpec> {
    vec![crate::profile::V100.clone(), crate::profile::V100.clone()]
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::PlacementPolicy;

    fn small_cfg() -> LifecycleCfg {
        LifecycleCfg { mem_budget_mib: 3_072, ..Default::default() }
    }

    fn run(
        n: usize,
        total_rps: f64,
        horizon_ms: f64,
        seed: u64,
        cfg: &LifecycleCfg,
    ) -> ClusterReport {
        let (profiles, rates, reqs) = longtail_workload(n, 1.1, total_rps, horizon_ms, seed);
        serve_longtail(
            &profiles,
            &rates,
            &longtail_gpus(),
            PlacementPolicy::LoadBalance,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            cfg,
            reqs,
            horizon_ms,
            seed,
        )
    }

    #[test]
    fn longtail_workload_shape() {
        let (profiles, rates, reqs) = longtail_workload(12, 1.1, 400.0, 1_000.0, 7);
        assert_eq!(profiles.len(), 12);
        assert_eq!(rates.len(), 12);
        assert!(!reqs.is_empty());
        // Distinct names, cycled bases, footprint-derived load times.
        assert_eq!(profiles[0].name, "mobilenet_00");
        assert_eq!(profiles[8].name, "mobilenet_08");
        for p in &profiles {
            assert!(p.load_ms < 1_000.0, "{}: load {} ms", p.name, p.load_ms);
            assert!(p.load_ms >= 150.0);
        }
        for w in reqs.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
    }

    #[test]
    fn lifecycle_run_is_deterministic() {
        let cfg = small_cfg();
        let a = run(8, 300.0, 1_200.0, 11, &cfg).to_json().to_string_compact();
        let b = run(8, 300.0, 1_200.0, 11, &cfg).to_json().to_string_compact();
        assert_eq!(a, b, "same seed ⇒ identical lifecycle report");
        assert!(a.contains("\"lifecycle\""));
    }

    #[test]
    fn memory_pressure_causes_cold_starts_and_evictions() {
        let cfg = LifecycleCfg { mem_budget_mib: 2_048, ..Default::default() };
        let rep = run(10, 400.0, 2_000.0, 3, &cfg);
        let stats = rep.lifecycle.as_ref().expect("lifecycle stats attached");
        assert!(stats.cold_starts > 0, "tail must fault in");
        assert!(stats.evictions > 0, "2 GiB budget must thrash");
        assert!(stats.mib_loaded > 0);
        assert!(stats.warm_hits > 0, "the head stays warm");
        for (g, &peak) in stats.peak_resident_mib.iter().enumerate() {
            assert!(peak <= 2_048, "gpu {g} resident peak {peak} MiB > budget");
        }
        assert!(rep.total_throughput() > 0.0);
    }

    #[test]
    fn idle_models_scale_to_zero() {
        // Plenty of memory (no eviction pressure) but a short idle
        // timeout: the tail must be released at least once.
        let cfg = LifecycleCfg {
            mem_budget_mib: 0,
            idle_timeout_ms: 300.0,
            ..Default::default()
        };
        let rep = run(10, 150.0, 2_000.0, 5, &cfg);
        let stats = rep.lifecycle.as_ref().unwrap();
        assert!(stats.scale_to_zero > 0, "idle tail models must release memory");
        assert_eq!(stats.evictions, 0, "no memory pressure ⇒ no evictions");
    }

    #[test]
    fn disabled_idle_timeout_never_scales_to_zero() {
        let cfg = LifecycleCfg {
            mem_budget_mib: 0,
            idle_timeout_ms: 0.0,
            ..Default::default()
        };
        let rep = run(6, 150.0, 1_000.0, 9, &cfg);
        let stats = rep.lifecycle.as_ref().unwrap();
        assert_eq!(stats.scale_to_zero, 0);
    }

    #[test]
    fn cold_delays_cost_latency_not_correctness() {
        let cfg = small_cfg();
        let rep = run(10, 300.0, 2_000.0, 13, &cfg);
        let stats = rep.lifecycle.as_ref().unwrap();
        assert!(stats.cold_delayed > 0);
        // A cold-delayed request waits at least the smallest weight
        // upload (≥ ~150 ms even with parameter sharing).
        assert!(
            stats.cold_start_p99_ms > 100.0,
            "cold-start p99 {} ms implausibly small",
            stats.cold_start_p99_ms
        );
        // Goodput is bounded by throughput.
        assert!(stats.goodput_rps <= rep.total_throughput() + 1e-9);
        assert!(stats.goodput_rps > 0.0);
    }

    #[test]
    fn config_validation_rejects_bad_fields() {
        assert!(LifecycleCfg::default().validate().is_ok());
        assert!(LifecycleCfg { idle_timeout_ms: -1.0, ..Default::default() }
            .validate()
            .is_err());
        assert!(LifecycleCfg { min_replicas: 0, ..Default::default() }.validate().is_err());
        assert!(LifecycleCfg { mem_budget_mib: 100, headroom_mib: 100, ..Default::default() }
            .validate()
            .is_err());
    }

    #[test]
    fn reachability_candidates_follow_cohosting_components() {
        // g0 hosts {m0, m1}, g1 hosts {m1}, g2 hosts {m2}; m3 nowhere.
        // An arrival of m0 can evict m1 on g0, whose queue re-routes to
        // g1 — so m0's candidate set must include g1 despite m0 having
        // no replica there. m2 is isolated; m3 rejects engine-free.
        let hosted = vec![vec![0, 1], vec![1], vec![2]];
        let cand = reachability_candidates(&hosted, 4);
        assert_eq!(cand[0], vec![0, 1]);
        assert_eq!(cand[1], vec![0, 1]);
        assert_eq!(cand[2], vec![2]);
        assert!(cand[3].is_empty());
    }

    #[test]
    fn reachability_closure_is_transitive() {
        // Chain g0{0,1} g1{1,2} g2{2,3}: a cascade starting at m0 can
        // reach g2 through two eviction hops, so the whole chain is one
        // component; g3{4} stays separate (bounded — NOT all engines).
        let hosted = vec![vec![0, 1], vec![1, 2], vec![2, 3], vec![4]];
        let cand = reachability_candidates(&hosted, 5);
        for m in 0..4 {
            assert_eq!(cand[m], vec![0, 1, 2], "model {m}");
        }
        assert_eq!(cand[4], vec![3]);
    }

    #[test]
    fn sparse_candidates_contain_eviction_cascades() {
        // Memory-pressured sparse run: the exec core's debug asserts
        // check every engine a cascade touches sits inside the arriving
        // model's candidate set; byte-identity with epoch mode pins the
        // behavior (the old all-engines answer silently fell back to
        // the epoch loop, making this vacuous).
        use crate::cluster::{ExecMode, Parallelism};
        let cfg = LifecycleCfg { mem_budget_mib: 2_048, ..Default::default() };
        let (profiles, rates, reqs) = longtail_workload(10, 1.1, 400.0, 1_500.0, 3);
        let run = |mode| {
            serve_longtail_with(
                &profiles,
                &rates,
                &longtail_gpus(),
                PlacementPolicy::LoadBalance,
                RoutingPolicy::JoinShortestQueue,
                GpuSched::Dstack,
                &cfg,
                reqs.clone(),
                1_500.0,
                3,
                ExecOpts { threads: Parallelism::Threads(1), mode, ..Default::default() },
            )
        };
        let sparse = run(ExecMode::Sparse);
        let stats = sparse.lifecycle.as_ref().unwrap();
        assert!(stats.evictions > 0, "pressure scenario must actually cascade");
        let epoch = run(ExecMode::Epoch);
        assert_eq!(
            sparse.to_json().to_string_pretty(),
            epoch.to_json().to_string_pretty(),
            "bounded candidate sets changed lifecycle results"
        );
    }

    #[test]
    fn warm_rr_spans_elide_barriers() {
        // Ample memory + round-robin routing: once the fleet is warm no
        // arrival can cold-start, so the driver's warm-span elision must
        // engage on the sparse path (this is the lifecycle analogue of
        // the static RR elision test in parallel_exec.rs).
        use crate::cluster::{ExecMode, Parallelism};
        let cfg = LifecycleCfg {
            mem_budget_mib: 0,
            idle_timeout_ms: 0.0,
            ..Default::default()
        };
        let (profiles, rates, reqs) = longtail_workload(8, 1.1, 300.0, 1_500.0, 9);
        let rep = serve_longtail_with(
            &profiles,
            &rates,
            &longtail_gpus(),
            PlacementPolicy::LoadBalance,
            RoutingPolicy::RoundRobin,
            GpuSched::Dstack,
            &cfg,
            reqs,
            1_500.0,
            9,
            ExecOpts {
                threads: Parallelism::Threads(1),
                mode: ExecMode::Sparse,
                ..Default::default()
            },
        );
        let exec = rep.exec.expect("exec stats attached");
        assert!(exec.barriers_elided > 0, "warm RR span elided nothing: {exec:?}");
        assert!(exec.arrivals_batched > 0);
    }

    #[test]
    fn budgets_respect_device_memory_and_headroom() {
        let cfg = LifecycleCfg {
            mem_budget_mib: 4_096,
            headroom_mib: 512,
            ..Default::default()
        };
        let v100 = crate::profile::V100.clone();
        assert_eq!(cfg.budget_for(&v100), 3_584);
        let unbounded = LifecycleCfg::default();
        assert_eq!(unbounded.budget_for(&v100), v100.mem_mib);
    }
}
