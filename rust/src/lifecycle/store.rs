//! Per-GPU model store: the resident set tracked against device memory.
//!
//! The store is pure bookkeeping — it never touches the engine. The
//! lifecycle driver ([`crate::lifecycle::run_lifecycle`]) consults it on
//! every dispatch (warm or cold?), charges cold loads through it
//! (reserving memory for the duration of the weight upload), and applies
//! its eviction verdicts to the per-GPU [`crate::sim::Sim`] via the
//! tombstone surgery (`deactivate_model`/`reactivate_model`).
//!
//! Invariants (checked in debug builds, property-tested in
//! `rust/tests/lifecycle_cluster.rs`):
//! - `used_mib` always equals the sum of resident footprints;
//! - `used_mib <= capacity_mib` after every operation;
//! - pinned and mid-load residents are never chosen as victims.

use crate::gpu::Us;

/// Which resident to sacrifice under memory pressure.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum EvictionPolicy {
    /// Least-recently-used: evict the resident with the oldest
    /// `last_used` timestamp.
    Lru,
    /// Least-frequently-used: fewest dispatches since load (ties broken
    /// by recency).
    Lfu,
    /// Cost-aware: evict the resident whose retention saves the fewest
    /// load-milliseconds per unit time — `load_ms × hits / age`, i.e.
    /// cheap-to-reload rarely-hit models go first even if recently
    /// touched.
    CostAware,
}

impl EvictionPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            EvictionPolicy::Lru => "lru",
            EvictionPolicy::Lfu => "lfu",
            EvictionPolicy::CostAware => "cost",
        }
    }

    pub fn parse(s: &str) -> Result<EvictionPolicy, String> {
        Ok(match s {
            "lru" => EvictionPolicy::Lru,
            "lfu" => EvictionPolicy::Lfu,
            "cost" | "cost_aware" => EvictionPolicy::CostAware,
            other => return Err(format!("unknown eviction policy '{other}'")),
        })
    }

    pub fn all() -> &'static [EvictionPolicy] {
        &[EvictionPolicy::Lru, EvictionPolicy::Lfu, EvictionPolicy::CostAware]
    }
}

/// One model currently holding device memory.
#[derive(Debug, Clone)]
pub struct ResidentEntry {
    /// Global model index.
    pub model: usize,
    /// Weight footprint held (MiB).
    pub mem_mib: u64,
    /// Full (unshared) reload cost, for cost-aware scoring (ms).
    pub load_ms: f64,
    /// When the model became (or started becoming) resident.
    pub loaded_at: Us,
    /// Last dispatch that touched this model.
    pub last_used: Us,
    /// Dispatches since load.
    pub hits: u64,
    /// Pinned residents are never evicted or scaled to zero.
    pub pinned: bool,
    /// Mid-load: memory is reserved but the model is not yet warm.
    /// Loading residents are never eviction victims.
    pub loading: bool,
}

/// Resident-set tracker for one GPU.
#[derive(Debug, Clone)]
pub struct ModelStore {
    policy: EvictionPolicy,
    capacity_mib: u64,
    used_mib: u64,
    peak_mib: u64,
    residents: Vec<ResidentEntry>,
    /// Victims removed under memory pressure (scale-to-zero not counted).
    pub evictions: u64,
    /// On-demand loads charged (t = 0 preloads not counted).
    pub loads: u64,
    /// Total weight traffic of on-demand loads (MiB).
    pub mib_loaded: u64,
}

impl ModelStore {
    pub fn new(capacity_mib: u64, policy: EvictionPolicy) -> ModelStore {
        ModelStore {
            policy,
            capacity_mib,
            used_mib: 0,
            peak_mib: 0,
            residents: Vec::new(),
            evictions: 0,
            loads: 0,
            mib_loaded: 0,
        }
    }

    pub fn policy(&self) -> EvictionPolicy {
        self.policy
    }

    pub fn capacity_mib(&self) -> u64 {
        self.capacity_mib
    }

    pub fn used_mib(&self) -> u64 {
        self.used_mib
    }

    pub fn free_mib(&self) -> u64 {
        self.capacity_mib - self.used_mib
    }

    /// High-water mark of `used_mib` over the store's lifetime.
    pub fn peak_mib(&self) -> u64 {
        self.peak_mib
    }

    pub fn n_resident(&self) -> usize {
        self.residents.len()
    }

    /// Residents whose weights are fully loaded — the models a new cold
    /// load can share parameters with (§3.2 cudaIPC).
    pub fn n_warm(&self) -> usize {
        self.residents.iter().filter(|r| !r.loading).count()
    }

    pub fn residents(&self) -> &[ResidentEntry] {
        &self.residents
    }

    fn find(&self, model: usize) -> Option<usize> {
        self.residents.iter().position(|r| r.model == model)
    }

    /// Resident at all (warm or mid-load)?
    pub fn is_resident(&self, model: usize) -> bool {
        self.find(model).is_some()
    }

    /// Resident *and* finished loading — dispatchable without delay.
    pub fn is_warm(&self, model: usize) -> bool {
        self.find(model).is_some_and(|i| !self.residents[i].loading)
    }

    /// Record a dispatch of `model` (recency + frequency signals).
    pub fn touch(&mut self, now: Us, model: usize) {
        if let Some(i) = self.find(model) {
            let r = &mut self.residents[i];
            r.last_used = r.last_used.max(now);
            r.hits += 1;
        }
    }

    fn insert(&mut self, entry: ResidentEntry) {
        debug_assert!(self.find(entry.model).is_none(), "double-resident model");
        self.used_mib += entry.mem_mib;
        self.peak_mib = self.peak_mib.max(self.used_mib);
        self.residents.push(entry);
        self.debug_check();
    }

    /// Seed a model at t = 0 (placement preload). Warm immediately, no
    /// load counters charged. Returns false (state unchanged) when the
    /// footprint does not fit the remaining capacity.
    pub fn preload(
        &mut self,
        now: Us,
        model: usize,
        mem_mib: u64,
        load_ms: f64,
        pinned: bool,
    ) -> bool {
        if self.used_mib + mem_mib > self.capacity_mib {
            return false;
        }
        self.insert(ResidentEntry {
            model,
            mem_mib,
            load_ms,
            loaded_at: now,
            last_used: now,
            hits: 0,
            pinned,
            loading: false,
        });
        true
    }

    /// Cost-aware eviction score: the load-milliseconds this resident
    /// saves per unit time if kept (`load_ms × hit rate`). Smaller means
    /// cheaper to lose — evicted first. Deterministic: float scores
    /// compare via `total_cmp`, ties resolve by model index.
    fn retention_value(now: Us, r: &ResidentEntry) -> f64 {
        let age_ms = (now.saturating_sub(r.loaded_at) as f64 / 1_000.0).max(1.0);
        r.load_ms * r.hits as f64 / age_ms
    }

    /// Start an on-demand load of `model`, evicting victims per policy
    /// until the footprint fits. Memory is reserved immediately (the
    /// weights stream in over the load delay); the caller marks the
    /// model warm with [`Self::complete_load`]. Returns the evicted
    /// model indices in eviction order, or `None` — with the store
    /// unchanged — when even evicting every unpinned, non-loading
    /// resident cannot make room.
    pub fn begin_load(
        &mut self,
        now: Us,
        model: usize,
        mem_mib: u64,
        load_ms: f64,
        pinned: bool,
    ) -> Option<Vec<usize>> {
        debug_assert!(self.find(model).is_none(), "begin_load of resident model {model}");
        // Plan the victim set without mutating: candidates in eviction
        // order, shortest prefix that frees enough memory.
        let mut candidates: Vec<usize> = (0..self.residents.len())
            .filter(|&i| !self.residents[i].pinned && !self.residents[i].loading)
            .collect();
        match self.policy {
            EvictionPolicy::Lru => candidates.sort_by_key(|&i| {
                let r = &self.residents[i];
                (r.last_used, r.model)
            }),
            EvictionPolicy::Lfu => candidates.sort_by_key(|&i| {
                let r = &self.residents[i];
                (r.hits, r.last_used, r.model)
            }),
            EvictionPolicy::CostAware => candidates.sort_by(|&a, &b| {
                let (ra, rb) = (&self.residents[a], &self.residents[b]);
                Self::retention_value(now, ra)
                    .total_cmp(&Self::retention_value(now, rb))
                    .then(ra.model.cmp(&rb.model))
            }),
        }
        let mut freed = 0u64;
        let mut take = 0usize;
        while self.used_mib - freed + mem_mib > self.capacity_mib {
            if take == candidates.len() {
                return None; // cannot fit even after evicting everything evictable
            }
            freed += self.residents[candidates[take]].mem_mib;
            take += 1;
        }
        let mut victims: Vec<usize> =
            candidates[..take].iter().map(|&i| self.residents[i].model).collect();
        // Remove by model id (indices shift as we remove).
        for &v in &victims {
            let i = self.find(v).expect("victim resident");
            self.used_mib -= self.residents[i].mem_mib;
            self.residents.remove(i);
            self.evictions += 1;
        }
        self.insert(ResidentEntry {
            model,
            mem_mib,
            load_ms,
            loaded_at: now,
            last_used: now,
            hits: 0,
            pinned,
            loading: true,
        });
        self.loads += 1;
        self.mib_loaded += mem_mib;
        victims.shrink_to_fit();
        Some(victims)
    }

    /// Mark a mid-load model warm (the weight upload finished).
    pub fn complete_load(&mut self, now: Us, model: usize) {
        let i = self.find(model).expect("completing load of non-resident model");
        let r = &mut self.residents[i];
        debug_assert!(r.loading, "complete_load of warm model {model}");
        r.loading = false;
        r.last_used = r.last_used.max(now);
    }

    /// Release a warm resident (scale-to-zero). Not counted as an
    /// eviction. Returns false for non-resident, pinned or mid-load
    /// models (state unchanged).
    pub fn release(&mut self, model: usize) -> bool {
        let Some(i) = self.find(model) else { return false };
        if self.residents[i].pinned || self.residents[i].loading {
            return false;
        }
        self.used_mib -= self.residents[i].mem_mib;
        self.residents.remove(i);
        self.debug_check();
        true
    }

    /// Wipe the store after an engine failure: every resident — pinned
    /// and mid-load included — is dropped and its memory freed. Returns
    /// the dropped model indices in model order. Not counted as
    /// evictions (the weights were lost, not sacrificed); load/traffic
    /// counters are preserved so report totals still reflect the work
    /// actually done before the crash.
    pub fn crash(&mut self) -> Vec<usize> {
        let mut dropped: Vec<usize> = self.residents.iter().map(|r| r.model).collect();
        dropped.sort_unstable();
        self.used_mib = 0;
        self.residents.clear();
        self.debug_check();
        dropped
    }

    /// Warm, unpinned residents idle since before `now − timeout`, in
    /// model order.
    pub fn idle_candidates(&self, now: Us, timeout: Us) -> Vec<usize> {
        let mut out: Vec<usize> = self
            .residents
            .iter()
            .filter(|r| !r.pinned && !r.loading && r.last_used + timeout <= now)
            .map(|r| r.model)
            .collect();
        out.sort_unstable();
        out
    }

    /// Earliest future instant at which some warm, unpinned resident
    /// becomes idle-expired (assuming no further touches).
    pub fn next_idle_expiry(&self, timeout: Us) -> Option<Us> {
        self.residents
            .iter()
            .filter(|r| !r.pinned && !r.loading)
            .map(|r| r.last_used + timeout)
            .min()
    }

    fn debug_check(&self) {
        debug_assert_eq!(
            self.used_mib,
            self.residents.iter().map(|r| r.mem_mib).sum::<u64>(),
            "resident memory accounting drifted"
        );
        debug_assert!(self.used_mib <= self.capacity_mib, "store over capacity");
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn store(cap: u64, policy: EvictionPolicy) -> ModelStore {
        ModelStore::new(cap, policy)
    }

    #[test]
    fn preload_respects_capacity() {
        let mut s = store(2_000, EvictionPolicy::Lru);
        assert!(s.preload(0, 0, 1_200, 300.0, false));
        assert!(!s.preload(0, 1, 900, 300.0, false), "over capacity");
        assert_eq!(s.used_mib(), 1_200);
        assert_eq!(s.n_resident(), 1);
        assert!(s.is_warm(0));
        assert_eq!(s.loads, 0, "preloads are free");
    }

    #[test]
    fn lru_evicts_oldest() {
        let mut s = store(3_000, EvictionPolicy::Lru);
        s.preload(0, 0, 1_000, 300.0, false);
        s.preload(0, 1, 1_000, 300.0, false);
        s.preload(0, 2, 1_000, 300.0, false);
        s.touch(10, 0);
        s.touch(20, 2); // model 1 is now the coldest
        let victims = s.begin_load(30, 3, 1_500, 400.0, false).unwrap();
        assert_eq!(victims, vec![1, 0], "oldest-first until it fits");
        assert!(s.is_resident(3) && !s.is_warm(3), "loading, not yet warm");
        s.complete_load(40, 3);
        assert!(s.is_warm(3));
        assert_eq!(s.evictions, 2);
        assert_eq!(s.mib_loaded, 1_500);
    }

    #[test]
    fn lfu_evicts_fewest_hits() {
        let mut s = store(2_000, EvictionPolicy::Lfu);
        s.preload(0, 0, 1_000, 300.0, false);
        s.preload(0, 1, 1_000, 300.0, false);
        for t in 0..5 {
            s.touch(t, 1);
        }
        s.touch(100, 0); // recent but rarely used
        let victims = s.begin_load(200, 2, 1_000, 300.0, false).unwrap();
        assert_eq!(victims, vec![0], "LFU ignores recency");
    }

    #[test]
    fn cost_aware_keeps_expensive_hot_models() {
        let mut s = store(2_000, EvictionPolicy::CostAware);
        // Model 0: expensive reload, frequently hit. Model 1: cheap
        // reload, same recency.
        s.preload(0, 0, 1_000, 2_000.0, false);
        s.preload(0, 1, 1_000, 100.0, false);
        for t in 1..20 {
            s.touch(t, 0);
            s.touch(t, 1);
        }
        let victims = s.begin_load(1_000, 2, 1_000, 300.0, false).unwrap();
        assert_eq!(victims, vec![1], "cheap-to-reload goes first");
    }

    #[test]
    fn pinned_and_loading_are_never_victims() {
        let mut s = store(2_500, EvictionPolicy::Lru);
        s.preload(0, 0, 1_000, 300.0, true); // pinned
        let v = s.begin_load(10, 1, 1_000, 300.0, false).unwrap();
        assert!(v.is_empty());
        // Model 1 is mid-load: the only possible victim is none.
        assert!(s.begin_load(20, 2, 1_000, 300.0, false).is_none(), "nothing evictable");
        assert_eq!(s.n_resident(), 2, "failed load leaves the store unchanged");
        assert_eq!(s.used_mib(), 2_000);
        // Once warm, model 1 becomes evictable.
        s.complete_load(30, 1);
        let v = s.begin_load(40, 2, 1_000, 300.0, false).unwrap();
        assert_eq!(v, vec![1]);
    }

    #[test]
    fn release_frees_memory_but_counts_separately() {
        let mut s = store(2_000, EvictionPolicy::Lru);
        s.preload(0, 0, 800, 300.0, false);
        assert!(s.release(0));
        assert_eq!(s.used_mib(), 0);
        assert_eq!(s.evictions, 0, "scale-to-zero is not an eviction");
        assert!(!s.release(0), "double release is a no-op");
        // Pinned models cannot be scaled to zero.
        s.preload(0, 1, 800, 300.0, true);
        assert!(!s.release(1));
    }

    #[test]
    fn idle_candidates_and_expiry() {
        let mut s = store(4_000, EvictionPolicy::Lru);
        s.preload(0, 0, 1_000, 300.0, false);
        s.preload(0, 1, 1_000, 300.0, false);
        s.preload(0, 2, 1_000, 300.0, true); // pinned never idles out
        s.touch(5_000, 1);
        assert_eq!(s.idle_candidates(10_000, 8_000), vec![0]);
        assert_eq!(s.next_idle_expiry(8_000), Some(8_000));
        assert_eq!(s.idle_candidates(14_000, 8_000), vec![0, 1]);
    }

    #[test]
    fn peak_tracks_high_water_mark() {
        let mut s = store(3_000, EvictionPolicy::Lru);
        s.preload(0, 0, 1_000, 300.0, false);
        s.preload(0, 1, 1_500, 300.0, false);
        assert_eq!(s.peak_mib(), 2_500);
        s.release(1);
        assert_eq!(s.used_mib(), 1_000);
        assert_eq!(s.peak_mib(), 2_500, "peak is monotone");
    }

    #[test]
    fn crash_wipes_everything_including_pinned_and_loading() {
        let mut s = store(4_000, EvictionPolicy::Lru);
        s.preload(0, 0, 1_000, 300.0, true); // pinned
        s.preload(0, 1, 1_000, 300.0, false);
        s.begin_load(10, 2, 1_000, 300.0, false).unwrap(); // mid-load
        assert_eq!(s.crash(), vec![0, 1, 2]);
        assert_eq!(s.n_resident(), 0);
        assert_eq!(s.used_mib(), 0);
        assert_eq!(s.evictions, 0, "a crash is not an eviction");
        assert_eq!(s.loads, 1, "load counters survive the crash");
        // The store is immediately usable again.
        assert!(s.preload(20, 0, 1_000, 300.0, true));
    }

    #[test]
    fn parse_and_names_roundtrip() {
        for p in EvictionPolicy::all() {
            assert_eq!(EvictionPolicy::parse(p.name()).unwrap(), *p);
        }
        assert!(EvictionPolicy::parse("fifo").is_err());
    }
}
