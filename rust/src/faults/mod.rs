//! Fault injection and the resilient front door (DESIGN.md §4.12).
//!
//! Production fleets lose engines mid-flight; D-STACK's evaluation
//! assumes they never do. This module closes that gap with three
//! cooperating pieces, all deterministic on the virtual clock:
//!
//! 1. **Fault timeline** — `engine_down` / `engine_up` /
//!    `engine_degraded` events, scripted via the `"faults"` config block
//!    or generated from seeded exponential MTBF/MTTR processes
//!    ([`ResilienceCfg::mtbf_ms`]). The timeline is built and validated
//!    once up front; every driver surfaces the next fault as a *driver
//!    event* through [`crate::cluster::exec::EpochDriver::next_event`],
//!    so in sparse mode each fault is a global barrier — the same
//!    mechanism that already makes control ticks and load maturities
//!    mode-invariant (DESIGN.md §4.7).
//! 2. **Failure semantics** — a downed engine drains: its queued
//!    requests cascade-re-route through the existing tombstone-surgery
//!    path ([`crate::sim::Sim::deactivate_model`]) and are counted in
//!    [`ResilienceStats::rerouted_on_failure`]; recovery re-activates
//!    the engine *cold*, charging `cold_load_ms` for every re-resident
//!    model (drivers with a [`crate::lifecycle::ModelStore`] reload on
//!    demand instead, which charges the same cost model). A *degraded*
//!    engine keeps serving but is deprioritized by a routing-cost
//!    penalty ([`ResilienceCfg::degraded_penalty_items`]) and becomes
//!    hedge-eligible.
//! 3. **Front door** — requests carry a per-model SLO class
//!    (`latency_critical` vs cold-start-tolerant `bulk`,
//!    [`SloClass`]); deadline-aware admission rejects a request whose
//!    remaining budget cannot cover the best-case queue+batch(+cold)
//!    estimate across its routable replicas; and a periodic hedge sweep
//!    ([`ResilienceCfg::hedge_check_ms`], armed only while an engine is
//!    degraded) speculatively re-dispatches requests stuck past their
//!    class threshold on a degraded engine to the next-best replica.
//!    First-completion-wins is decided analytically — both completion
//!    estimates are computable in virtual time — with ties broken by
//!    engine index ([`pick_hedge_target`]); the loser's copy is
//!    cancelled eagerly, so no request is ever double-served.
//!
//! The shared [`Resilience`] helper is *embedded* in each driver
//! (`res: Option<Resilience>`), not a wrapper driver: fault application
//! and hedging need each driver's own routing/cascade machinery. When
//! it is `None`, every fault hook is dead code and report bytes are
//! untouched ([`ResilienceStats`] serializes only for fault runs).

use crate::gpu::{ms_to_us, Us};
use crate::profile::ModelProfile;
use crate::util::json::Json;
use crate::util::rng::Pcg32;
use std::collections::BTreeMap;

/// What happened to an engine at a timeline point.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum FaultKind {
    /// Engine fails: drains, queued requests re-route, unroutable until
    /// the matching `Up` (plus its cold re-activation) completes.
    Down,
    /// Engine recovers — cold: re-resident models pay `cold_load_ms`.
    Up,
    /// Engine keeps serving at full speed in virtual time but is
    /// deprioritized by routing and eligible for hedged re-dispatch
    /// (the "doomed/slow replica" the hedge exists for).
    Degraded,
}

impl FaultKind {
    pub fn name(&self) -> &'static str {
        match self {
            FaultKind::Down => "engine_down",
            FaultKind::Up => "engine_up",
            FaultKind::Degraded => "engine_degraded",
        }
    }

    /// Parse a config-file kind name.
    pub fn from_name(s: &str) -> Option<FaultKind> {
        match s {
            "engine_down" | "down" => Some(FaultKind::Down),
            "engine_up" | "up" => Some(FaultKind::Up),
            "engine_degraded" | "degraded" => Some(FaultKind::Degraded),
            _ => None,
        }
    }
}

/// One scripted or generated fault-timeline entry.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct FaultEvent {
    /// Virtual time (µs). Must be > 0 — the timeline exists before the
    /// run starts, and driver events must be strictly future.
    pub t: Us,
    pub gpu: usize,
    pub kind: FaultKind,
}

/// Per-model SLO class carried by the front door.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum SloClass {
    /// Default: tight hedge threshold, strict deadline admission.
    LatencyCritical,
    /// Cold-start-tolerant batch traffic: wide hedge threshold.
    Bulk,
}

impl SloClass {
    pub fn name(&self) -> &'static str {
        match self {
            SloClass::LatencyCritical => "latency_critical",
            SloClass::Bulk => "bulk",
        }
    }
}

/// Fault-injection + front-door configuration (the scenario `"faults"`
/// block — see `docs/CONFIG.md`).
#[derive(Debug, Clone, PartialEq)]
pub struct ResilienceCfg {
    /// Scripted timeline entries (merged with any generated ones).
    pub events: Vec<FaultEvent>,
    /// Mean time between failures per engine (ms); `0` disables the
    /// generated down/up process (scripted events still apply).
    pub mtbf_ms: f64,
    /// Mean time to repair per engine (ms); used when `mtbf_ms > 0`.
    pub mttr_ms: f64,
    /// Seed of the MTBF/MTTR exponential processes (one independent
    /// Pcg32 stream per GPU).
    pub seed: u64,
    /// Profile names served as [`SloClass::Bulk`]. A name matches
    /// exactly, or as the base of a `{name}_{NN}` fleet clone
    /// ([`crate::lifecycle::fleet_name`]).
    pub bulk_models: Vec<String>,
    /// Deadline-aware admission: reject on arrival when the remaining
    /// deadline budget cannot cover the best-case service estimate.
    pub admission: bool,
    /// Re-route a downed engine's drained queue through the driver's
    /// dispatch path. `false` = the naive baseline: drained requests
    /// are rejected (counted, conservation holds).
    pub reroute: bool,
    /// Enable the hedged re-dispatch sweep on degraded engines.
    pub hedge: bool,
    /// Hedge sweep cadence (ms) while any engine is degraded.
    pub hedge_check_ms: f64,
    /// Stuck-age threshold for `latency_critical` requests (ms).
    pub hedge_critical_ms: f64,
    /// Stuck-age threshold for `bulk` requests (ms).
    pub hedge_bulk_ms: f64,
    /// Queue-items-equivalent cost added to a degraded replica in the
    /// routing/hedge cost comparison (JSQ/P2C deprioritization; RR
    /// ignores costs by design).
    pub degraded_penalty_items: usize,
}

impl Default for ResilienceCfg {
    fn default() -> Self {
        ResilienceCfg {
            events: Vec::new(),
            mtbf_ms: 0.0,
            mttr_ms: 500.0,
            seed: 0,
            bulk_models: Vec::new(),
            admission: false,
            reroute: true,
            hedge: true,
            hedge_check_ms: 50.0,
            hedge_critical_ms: 20.0,
            hedge_bulk_ms: 200.0,
            degraded_penalty_items: 64,
        }
    }
}

impl ResilienceCfg {
    /// Validate ranges; returns a message naming the offending field.
    pub fn validate(&self) -> Result<(), String> {
        if self.mtbf_ms < 0.0 || self.mtbf_ms.is_nan() {
            return Err("faults.mtbf_ms must be >= 0".into());
        }
        if self.mtbf_ms > 0.0 && (self.mttr_ms <= 0.0 || self.mttr_ms.is_nan()) {
            return Err("faults.mttr_ms must be > 0 when mtbf_ms > 0".into());
        }
        if self.hedge_check_ms <= 0.0 || self.hedge_check_ms.is_nan() {
            return Err("faults.hedge_check_ms must be > 0".into());
        }
        if self.hedge_critical_ms < 0.0 || self.hedge_bulk_ms < 0.0 {
            return Err("faults.hedge thresholds must be >= 0".into());
        }
        for e in &self.events {
            if e.t == 0 {
                return Err("faults.events times must be > 0".into());
            }
        }
        Ok(())
    }

    /// True when this config actually injects or changes anything — the
    /// gate for attaching [`ResilienceStats`] to a report.
    pub fn active(&self) -> bool {
        !self.events.is_empty()
            || self.mtbf_ms > 0.0
            || !self.bulk_models.is_empty()
            || self.admission
    }
}

/// Engine health as the drivers see it.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Health {
    Up,
    /// Serving but deprioritized and hedge-eligible.
    Degraded,
    /// Drained; unroutable.
    Down,
    /// Recovery announced (`Up` event seen) but the cold re-activation
    /// has not matured yet; still unroutable.
    Restoring,
}

/// Front-door telemetry attached to a fault run's
/// [`crate::cluster::ClusterReport`] (`resilience` block, serialized
/// only when a `"faults"` config is active).
#[derive(Debug, Clone, Default)]
pub struct ResilienceStats {
    /// Timeline entries applied (down + up + degraded).
    pub fault_events: u64,
    /// `engine_down` events applied.
    pub engine_downs: u64,
    /// Requests drained from a downed engine and successfully
    /// re-dispatched elsewhere.
    pub rerouted_on_failure: u64,
    /// Stuck requests for which a hedge was fired (speculative
    /// re-dispatch attempted).
    pub hedges_fired: u64,
    /// Hedges whose re-dispatched copy won first-completion (the
    /// request actually moved; the stuck copy was cancelled).
    pub hedges_won: u64,
    /// Deadline-admission rejects of `latency_critical` requests.
    pub deadline_rejects_critical: u64,
    /// Deadline-admission rejects of `bulk` requests.
    pub deadline_rejects_bulk: u64,
    /// Requests rejected because every replica of their model was
    /// down/draining (the zero-routable guard).
    pub unroutable_rejects: u64,
    /// Served-within-SLO throughput during cluster-unhealthy windows
    /// (any engine not fully up), req/s over those windows.
    pub degraded_goodput_rps: f64,
    /// Engine-uptime integral: 100 × (1 − Σ downtime / (engines ×
    /// horizon)). Degraded time counts as up; restore time as down.
    pub availability_pct: f64,
}

impl ResilienceStats {
    /// Deterministic JSON form (embedded in `ClusterReport::to_json`).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("fault_events", Json::from(self.fault_events)),
            ("engine_downs", Json::from(self.engine_downs)),
            ("rerouted_on_failure", Json::from(self.rerouted_on_failure)),
            ("hedges_fired", Json::from(self.hedges_fired)),
            ("hedges_won", Json::from(self.hedges_won)),
            ("deadline_rejects_critical", Json::from(self.deadline_rejects_critical)),
            ("deadline_rejects_bulk", Json::from(self.deadline_rejects_bulk)),
            ("unroutable_rejects", Json::from(self.unroutable_rejects)),
            ("degraded_goodput_rps", Json::from(self.degraded_goodput_rps)),
            ("availability_pct", Json::from(self.availability_pct)),
        ])
    }
}

/// Build the merged, sorted timeline (scripted events + the generated
/// MTBF/MTTR process) and validate per-engine alternation: `Down` only
/// from `Up`/`Degraded`, `Degraded` only from `Up`, `Up` only from
/// `Down`/`Degraded`. Rejects out-of-range GPU indices.
pub fn build_timeline(
    cfg: &ResilienceCfg,
    n_gpus: usize,
    horizon: Us,
) -> Result<Vec<FaultEvent>, String> {
    cfg.validate()?;
    let mut timeline = cfg.events.clone();
    if cfg.mtbf_ms > 0.0 {
        for g in 0..n_gpus {
            // One independent stream per engine: fleet size changes do
            // not reshuffle other engines' fault histories.
            let mut rng = Pcg32::new(cfg.seed, 0xFA17 + g as u64);
            let mut t: Us = 0;
            loop {
                t += exp_us(&mut rng, cfg.mtbf_ms);
                if t >= horizon {
                    break;
                }
                timeline.push(FaultEvent { t, gpu: g, kind: FaultKind::Down });
                t += exp_us(&mut rng, cfg.mttr_ms);
                if t >= horizon {
                    break; // stays down through the horizon
                }
                timeline.push(FaultEvent { t, gpu: g, kind: FaultKind::Up });
            }
        }
    }
    timeline.sort_by_key(|e| (e.t, e.gpu, e.kind));
    // Alternation check: replay the health machine per engine.
    let mut state = vec![Health::Up; n_gpus];
    for e in &timeline {
        if e.gpu >= n_gpus {
            return Err(format!(
                "faults.events: gpu {} out of range (cluster has {n_gpus})",
                e.gpu
            ));
        }
        let s = state[e.gpu];
        let ok = match e.kind {
            FaultKind::Down => matches!(s, Health::Up | Health::Degraded),
            FaultKind::Degraded => s == Health::Up,
            FaultKind::Up => matches!(s, Health::Down | Health::Degraded),
        };
        if !ok {
            return Err(format!(
                "faults.events: {} on gpu {} at t = {} µs while engine is {s:?}",
                e.kind.name(),
                e.gpu,
                e.t
            ));
        }
        state[e.gpu] = match e.kind {
            FaultKind::Down => Health::Down,
            FaultKind::Degraded => Health::Degraded,
            FaultKind::Up => Health::Up,
        };
    }
    Ok(timeline)
}

/// Exponential inter-event gap in µs with the given mean (ms), floored
/// at 1 µs so consecutive events never collapse onto one instant.
fn exp_us(rng: &mut Pcg32, mean_ms: f64) -> Us {
    let u = 1.0 - rng.f64(); // (0, 1]: ln never sees 0
    ms_to_us(-mean_ms * u.ln()).max(1)
}

/// First-completion-wins: among `candidates` (each a `(est_us, gpu)`
/// completion estimate for the hedged copy), return the GPU of the
/// strict lexicographic minimum *iff* it beats the stuck copy's
/// `source` estimate — ties broken by lower engine index, so the
/// decision is total and deterministic. `None` = the stuck copy wins;
/// the hedge is cancelled and the request stays put.
pub fn pick_hedge_target(source: (Us, usize), candidates: &[(Us, usize)]) -> Option<usize> {
    let best = candidates.iter().min()?;
    if *best < source {
        Some(best.1)
    } else {
        None
    }
}

/// Best-case service estimate (µs) for one replica: the queue ahead
/// plus one full batch, at the replica's calibrated capacity. The
/// admission check and the hedge comparison both build on this.
pub fn queue_est_us(backlog_items: usize, batch: u32, capacity_rps: f64) -> Us {
    if capacity_rps <= 0.0 {
        return Us::MAX / 4;
    }
    (((backlog_items as f64 + batch as f64) / capacity_rps) * 1e6).ceil() as Us
}

/// Served-in-SLO rate over the cluster-unhealthy windows: completions
/// `(t_done, in_slo)` falling inside any window, divided by the total
/// window duration. `0` when no window opened.
pub fn degraded_goodput_rps(
    windows: &[(Us, Us)],
    completions: impl Iterator<Item = (Us, bool)>,
) -> f64 {
    let total_us: Us = windows.iter().map(|(a, b)| b.saturating_sub(*a)).sum();
    if total_us == 0 {
        return 0.0;
    }
    let mut served = 0u64;
    for (t, in_slo) in completions {
        if in_slo && windows.iter().any(|&(a, b)| t >= a && t < b) {
            served += 1;
        }
    }
    served as f64 / (total_us as f64 / 1e6)
}

/// The per-run fault/front-door state machine every driver embeds as
/// `res: Option<Resilience>`. All mutation happens at driver-event
/// barriers (fault application, restore maturation, hedge cadence), so
/// the sparse execution core's global sync at driver events keeps the
/// whole layer byte-identical across exec modes and thread counts.
#[derive(Debug)]
pub struct Resilience {
    pub cfg: ResilienceCfg,
    timeline: Vec<FaultEvent>,
    cursor: usize,
    health: Vec<Health>,
    /// Per-model bulk class (resolved once against profile names).
    bulk: Vec<bool>,
    /// gpu → virtual time its cold re-activation matures.
    restore_at: BTreeMap<usize, Us>,
    /// Next hedge sweep; armed only while an engine is degraded.
    next_hedge: Option<Us>,
    down_since: Vec<Option<Us>>,
    downtime_us: Vec<Us>,
    /// Open cluster-unhealthy window start (any engine not `Up`).
    unhealthy_since: Option<Us>,
    /// Closed cluster-unhealthy windows, in order.
    pub unhealthy_windows: Vec<(Us, Us)>,
    pub stats: ResilienceStats,
}

impl Resilience {
    /// Build the runtime: timeline (validated), per-model class table,
    /// all engines healthy.
    pub fn new(
        cfg: ResilienceCfg,
        profiles: &[ModelProfile],
        n_gpus: usize,
        horizon: Us,
    ) -> Result<Resilience, String> {
        let timeline = build_timeline(&cfg, n_gpus, horizon)?;
        let bulk = profiles.iter().map(|p| is_bulk_name(&cfg.bulk_models, &p.name)).collect();
        Ok(Resilience {
            cfg,
            timeline,
            cursor: 0,
            health: vec![Health::Up; n_gpus],
            bulk,
            restore_at: BTreeMap::new(),
            next_hedge: None,
            down_since: vec![None; n_gpus],
            downtime_us: vec![0; n_gpus],
            unhealthy_since: None,
            unhealthy_windows: Vec::new(),
            stats: ResilienceStats::default(),
        })
    }

    pub fn class(&self, model: usize) -> SloClass {
        if self.bulk.get(model).copied().unwrap_or(false) {
            SloClass::Bulk
        } else {
            SloClass::LatencyCritical
        }
    }

    /// Stuck-age threshold (µs) for `model`'s class.
    pub fn hedge_threshold_us(&self, model: usize) -> Us {
        let ms = match self.class(model) {
            SloClass::LatencyCritical => self.cfg.hedge_critical_ms,
            SloClass::Bulk => self.cfg.hedge_bulk_ms,
        };
        ms_to_us(ms).max(1)
    }

    pub fn health(&self, g: usize) -> Health {
        self.health[g]
    }

    /// Can the router send traffic to engine `g` right now?
    pub fn routable(&self, g: usize) -> bool {
        matches!(self.health[g], Health::Up | Health::Degraded)
    }

    pub fn degraded(&self, g: usize) -> bool {
        self.health[g] == Health::Degraded
    }

    /// True while engine `g` awaits its cold re-activation — the
    /// driver's cue (after [`Self::due_faults`] returned an `Up` event)
    /// that a restore must be scheduled; a `Degraded` engine recovers in
    /// place and never enters this state.
    pub fn restoring(&self, g: usize) -> bool {
        self.health[g] == Health::Restoring
    }

    /// Any engine currently unroutable? (Gates the replica-filter
    /// allocation on the routing hot path.)
    pub fn any_unroutable(&self) -> bool {
        self.health.iter().any(|h| matches!(h, Health::Down | Health::Restoring))
    }

    pub fn any_degraded(&self) -> bool {
        self.health.iter().any(|&h| h == Health::Degraded)
    }

    /// Degraded-replica cost penalty in queue-items units.
    pub fn penalty_items(&self, g: usize) -> usize {
        if self.degraded(g) {
            self.cfg.degraded_penalty_items
        } else {
            0
        }
    }

    /// Earliest pending fault / restore / hedge time — merged into the
    /// embedding driver's `next_event`.
    pub fn next_event(&self) -> Option<Us> {
        let t_fault = self.timeline.get(self.cursor).map(|e| e.t);
        let t_restore = self.restore_at.values().min().copied();
        [t_fault, t_restore, self.next_hedge].into_iter().flatten().min()
    }

    /// Pop timeline entries due at `t`, applying health transitions and
    /// availability accounting. The caller (a driver, at its barrier)
    /// performs the engine-side effects per returned event: drain on
    /// `Down`, schedule/perform the cold re-activation on `Up`.
    pub fn due_faults(&mut self, t: Us) -> Vec<FaultEvent> {
        let mut due = Vec::new();
        while let Some(&e) = self.timeline.get(self.cursor) {
            if e.t > t {
                break;
            }
            self.cursor += 1;
            self.stats.fault_events += 1;
            match e.kind {
                FaultKind::Down => {
                    self.stats.engine_downs += 1;
                    self.health[e.gpu] = Health::Down;
                    self.down_since[e.gpu].get_or_insert(e.t);
                    self.restore_at.remove(&e.gpu); // re-failed mid-restore
                    self.open_window(e.t);
                }
                FaultKind::Degraded => {
                    self.health[e.gpu] = Health::Degraded;
                    self.open_window(e.t);
                }
                FaultKind::Up => {
                    if self.health[e.gpu] == Health::Degraded {
                        // Recovery in place: nothing was drained, no
                        // cold re-activation owed.
                        self.health[e.gpu] = Health::Up;
                        self.close_window_if_healthy(e.t);
                    } else {
                        // Unroutable until the driver's restore matures;
                        // the driver either schedules one or marks
                        // restored now ([`Self::restoring`] tells it
                        // which case this is).
                        self.health[e.gpu] = Health::Restoring;
                    }
                }
            }
            due.push(e);
        }
        self.rearm_hedge(t);
        due
    }

    /// Register the cold re-activation of engine `g` maturing at `at`.
    pub fn schedule_restore(&mut self, g: usize, at: Us) {
        debug_assert_eq!(self.health[g], Health::Restoring);
        self.restore_at.insert(g, at);
    }

    /// Restores due at `t` (the embedding driver re-activates the
    /// engine's models, then calls [`Self::mark_restored`]).
    pub fn due_restores(&mut self, t: Us) -> Vec<usize> {
        let due: Vec<usize> =
            self.restore_at.iter().filter(|&(_, &at)| at <= t).map(|(&g, _)| g).collect();
        for g in &due {
            self.restore_at.remove(g);
        }
        due
    }

    /// Engine `g` is fully back: routable, downtime closed.
    pub fn mark_restored(&mut self, g: usize, t: Us) {
        self.health[g] = Health::Up;
        if let Some(since) = self.down_since[g].take() {
            self.downtime_us[g] += t.saturating_sub(since);
        }
        self.close_window_if_healthy(t);
        self.rearm_hedge(t);
    }

    /// Is a hedge sweep due at `t`? Advances the cadence when it fires;
    /// disarms when no engine is degraded anymore.
    pub fn hedge_due(&mut self, t: Us) -> bool {
        if !self.cfg.hedge || !self.any_degraded() {
            self.next_hedge = None;
            return false;
        }
        match self.next_hedge {
            Some(h) if h <= t => {
                self.next_hedge = Some(t + ms_to_us(self.cfg.hedge_check_ms).max(1));
                true
            }
            _ => false,
        }
    }

    fn rearm_hedge(&mut self, t: Us) {
        if self.cfg.hedge && self.any_degraded() {
            if self.next_hedge.is_none() {
                self.next_hedge = Some(t + ms_to_us(self.cfg.hedge_check_ms).max(1));
            }
        } else {
            self.next_hedge = None;
        }
    }

    fn open_window(&mut self, t: Us) {
        self.unhealthy_since.get_or_insert(t);
    }

    fn close_window_if_healthy(&mut self, t: Us) {
        if self.health.iter().all(|&h| h == Health::Up) {
            if let Some(since) = self.unhealthy_since.take() {
                if t > since {
                    self.unhealthy_windows.push((since, t));
                }
            }
        }
    }

    pub fn note_reroute(&mut self, n: u64) {
        self.stats.rerouted_on_failure += n;
    }

    pub fn note_unroutable(&mut self) {
        self.stats.unroutable_rejects += 1;
    }

    pub fn note_deadline_reject(&mut self, model: usize) {
        match self.class(model) {
            SloClass::LatencyCritical => self.stats.deadline_rejects_critical += 1,
            SloClass::Bulk => self.stats.deadline_rejects_bulk += 1,
        }
    }

    pub fn note_hedges(&mut self, fired: u64, won: u64) {
        self.stats.hedges_fired += fired;
        self.stats.hedges_won += won;
    }

    /// Close open windows/downtime at the horizon and fill the derived
    /// stats. `completions` feeds the degraded-window goodput.
    pub fn finalize(
        &mut self,
        horizon: Us,
        completions: impl Iterator<Item = (Us, bool)>,
    ) -> ResilienceStats {
        for g in 0..self.health.len() {
            if let Some(since) = self.down_since[g].take() {
                self.downtime_us[g] += horizon.saturating_sub(since);
            }
        }
        if let Some(since) = self.unhealthy_since.take() {
            if horizon > since {
                self.unhealthy_windows.push((since, horizon));
            }
        }
        let total_down: Us = self.downtime_us.iter().sum();
        let span = self.health.len() as f64 * horizon as f64;
        self.stats.availability_pct =
            if span > 0.0 { 100.0 * (1.0 - total_down as f64 / span) } else { 100.0 };
        self.stats.degraded_goodput_rps =
            degraded_goodput_rps(&self.unhealthy_windows, completions);
        self.stats.clone()
    }
}

/// Does `name` belong to the bulk class? Matches an entry exactly or as
/// the base of a `{entry}_{NN}` fleet clone.
fn is_bulk_name(bulk_models: &[String], name: &str) -> bool {
    bulk_models.iter().any(|b| {
        name == b
            || name
                .strip_prefix(b.as_str())
                .and_then(|rest| rest.strip_prefix('_'))
                .is_some_and(|d| !d.is_empty() && d.chars().all(|c| c.is_ascii_digit()))
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn profiles(names: &[&str]) -> Vec<ModelProfile> {
        names
            .iter()
            .map(|n| {
                let mut p = crate::profile::zoo()[0].clone();
                p.name = (*n).to_string();
                p
            })
            .collect()
    }

    fn ev(t: Us, gpu: usize, kind: FaultKind) -> FaultEvent {
        FaultEvent { t, gpu, kind }
    }

    #[test]
    fn timeline_sorts_and_validates_alternation() {
        let cfg = ResilienceCfg {
            events: vec![
                ev(500_000, 1, FaultKind::Up),
                ev(100_000, 1, FaultKind::Down),
                ev(200_000, 0, FaultKind::Degraded),
            ],
            ..Default::default()
        };
        let tl = build_timeline(&cfg, 2, 1_000_000).expect("valid alternation");
        assert_eq!(tl.len(), 3);
        assert_eq!(tl[0].t, 100_000);
        assert_eq!(tl[2].kind, FaultKind::Up);
        // Up without a preceding Down rejects.
        let bad = ResilienceCfg {
            events: vec![ev(100, 0, FaultKind::Up)],
            ..Default::default()
        };
        assert!(build_timeline(&bad, 2, 1_000_000).is_err());
        // Double-down rejects.
        let bad2 = ResilienceCfg {
            events: vec![ev(100, 0, FaultKind::Down), ev(200, 0, FaultKind::Down)],
            ..Default::default()
        };
        assert!(build_timeline(&bad2, 2, 1_000_000).is_err());
        // Out-of-range GPU rejects.
        let bad3 = ResilienceCfg {
            events: vec![ev(100, 5, FaultKind::Down)],
            ..Default::default()
        };
        assert!(build_timeline(&bad3, 2, 1_000_000).is_err());
        // t = 0 rejects (driver events must be strictly future).
        let bad4 = ResilienceCfg {
            events: vec![ev(0, 0, FaultKind::Down)],
            ..Default::default()
        };
        assert!(build_timeline(&bad4, 2, 1_000_000).is_err());
    }

    #[test]
    fn mtbf_generation_is_seeded_and_alternates() {
        let cfg = ResilienceCfg { mtbf_ms: 300.0, mttr_ms: 100.0, seed: 9, ..Default::default() };
        let a = build_timeline(&cfg, 3, ms_to_us(5_000.0)).unwrap();
        let b = build_timeline(&cfg, 3, ms_to_us(5_000.0)).unwrap();
        assert_eq!(a, b, "same seed ⇒ same generated timeline");
        assert!(!a.is_empty(), "5 s at 300 ms MTBF must generate failures");
        let other = ResilienceCfg { seed: 10, ..cfg.clone() };
        assert_ne!(a, build_timeline(&other, 3, ms_to_us(5_000.0)).unwrap());
        // Per-GPU independence: dropping to 2 GPUs leaves gpu 0/1
        // histories untouched.
        let two = build_timeline(&cfg, 2, ms_to_us(5_000.0)).unwrap();
        let first_two: Vec<FaultEvent> = a.iter().filter(|e| e.gpu < 2).copied().collect();
        assert_eq!(two, first_two);
    }

    #[test]
    fn class_resolution_matches_fleet_clones() {
        let cfg = ResilienceCfg {
            bulk_models: vec!["resnet50".into()],
            ..Default::default()
        };
        let ps = profiles(&["mobilenet", "resnet50", "resnet50_07", "resnet50x"]);
        let r = Resilience::new(cfg, &ps, 1, 1_000).unwrap();
        assert_eq!(r.class(0), SloClass::LatencyCritical);
        assert_eq!(r.class(1), SloClass::Bulk);
        assert_eq!(r.class(2), SloClass::Bulk, "fleet clone inherits the base class");
        assert_eq!(r.class(3), SloClass::LatencyCritical, "prefix without _NN is distinct");
        assert!(r.hedge_threshold_us(1) > r.hedge_threshold_us(0));
    }

    #[test]
    fn hedge_target_ties_break_by_engine_index() {
        // Strictly better estimate wins.
        assert_eq!(pick_hedge_target((1_000, 2), &[(900, 3)]), Some(3));
        // Equal estimate: lower engine index wins.
        assert_eq!(pick_hedge_target((1_000, 2), &[(1_000, 1)]), Some(1));
        assert_eq!(pick_hedge_target((1_000, 2), &[(1_000, 3)]), None);
        // Among targets, min (est, gpu) is chosen.
        assert_eq!(
            pick_hedge_target((1_000, 0), &[(900, 3), (900, 1), (950, 2)]),
            Some(1)
        );
        assert_eq!(pick_hedge_target((100, 0), &[]), None);
    }

    #[test]
    fn health_machine_counts_downtime_and_windows() {
        let cfg = ResilienceCfg {
            events: vec![
                ev(100, 0, FaultKind::Down),
                ev(300, 0, FaultKind::Up),
                ev(600, 1, FaultKind::Degraded),
            ],
            ..Default::default()
        };
        let ps = profiles(&["m"]);
        let mut r = Resilience::new(cfg, &ps, 2, 1_000).unwrap();
        assert_eq!(r.next_event(), Some(100));
        let due = r.due_faults(100);
        assert_eq!(due.len(), 1);
        assert!(!r.routable(0));
        assert!(r.any_unroutable());
        let due = r.due_faults(300);
        assert_eq!(due[0].kind, FaultKind::Up);
        assert_eq!(r.health(0), Health::Restoring);
        assert!(!r.routable(0), "restoring engines stay unroutable");
        r.schedule_restore(0, 450);
        assert_eq!(r.next_event(), Some(450));
        assert_eq!(r.due_restores(450), vec![0]);
        r.mark_restored(0, 450);
        assert!(r.routable(0));
        // Degraded at 600: routable but penalized, hedge armed.
        r.due_faults(600);
        assert!(r.routable(1));
        assert!(r.degraded(1));
        assert!(r.penalty_items(1) > 0);
        assert_eq!(r.penalty_items(0), 0);
        assert!(r.next_event().is_some(), "hedge cadence armed");
        assert!(!r.hedge_due(600), "first sweep is one cadence after arming");
        let h = r.next_event().unwrap();
        assert!(r.hedge_due(h));
        let stats = r.finalize(1_000, std::iter::empty());
        assert_eq!(stats.fault_events, 3);
        assert_eq!(stats.engine_downs, 1);
        // Downtime: gpu 0 down 100→450 of a 2 × 1000 span.
        let expect = 100.0 * (1.0 - 350.0 / 2_000.0);
        assert!((stats.availability_pct - expect).abs() < 1e-9, "{}", stats.availability_pct);
        // Unhealthy windows: [100, 450) then [600, 1000).
        assert_eq!(r.unhealthy_windows, vec![(100, 450), (600, 1_000)]);
    }

    #[test]
    fn degraded_engine_recovers_in_place() {
        let cfg = ResilienceCfg {
            events: vec![ev(100, 0, FaultKind::Degraded), ev(400, 0, FaultKind::Up)],
            ..Default::default()
        };
        let ps = profiles(&["m"]);
        let mut r = Resilience::new(cfg, &ps, 1, 1_000).unwrap();
        r.due_faults(100);
        assert!(r.degraded(0));
        let due = r.due_faults(400);
        assert_eq!(due[0].kind, FaultKind::Up);
        assert!(!r.restoring(0), "degraded recovery owes no cold restore");
        assert!(r.routable(0));
        let stats = r.finalize(1_000, std::iter::empty());
        assert!((stats.availability_pct - 100.0).abs() < 1e-9, "degraded counts as up");
        assert_eq!(r.unhealthy_windows, vec![(100, 400)]);
    }

    #[test]
    fn degraded_goodput_counts_in_window_slo_completions() {
        let windows = vec![(100, 200), (400, 500)];
        // 2 in-window in-SLO, 1 in-window miss, 1 out-of-window.
        let comps = vec![(150, true), (450, true), (120, false), (300, true)];
        let g = degraded_goodput_rps(&windows, comps.into_iter());
        // 2 served over 200 µs = 10⁴ req/s.
        assert!((g - 10_000.0).abs() < 1e-6, "{g}");
        assert_eq!(degraded_goodput_rps(&[], std::iter::empty()), 0.0);
    }

    #[test]
    fn queue_estimates_scale_with_backlog() {
        assert!(queue_est_us(10, 4, 100.0) > queue_est_us(2, 4, 100.0));
        assert_eq!(queue_est_us(6, 4, 100.0), 100_000);
        assert!(queue_est_us(1, 1, 0.0) > 1_000_000_000, "zero capacity ⇒ effectively never");
    }

    #[test]
    fn cfg_validation_and_activity() {
        assert!(ResilienceCfg::default().validate().is_ok());
        assert!(!ResilienceCfg::default().active());
        assert!(ResilienceCfg { mtbf_ms: -1.0, ..Default::default() }.validate().is_err());
        assert!(ResilienceCfg { mtbf_ms: 100.0, mttr_ms: 0.0, ..Default::default() }
            .validate()
            .is_err());
        assert!(ResilienceCfg { hedge_check_ms: 0.0, ..Default::default() }.validate().is_err());
        assert!(ResilienceCfg { mtbf_ms: 100.0, ..Default::default() }.active());
        assert!(ResilienceCfg { admission: true, ..Default::default() }.active());
        assert!(
            ResilienceCfg { bulk_models: vec!["x".into()], ..Default::default() }.active()
        );
    }
}
