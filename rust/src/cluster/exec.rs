//! Shared cluster execution core: deterministic engine stepping with
//! bulk-synchronous *or* sparse barriers (the wall-clock backbone of
//! every multi-GPU driver — see DESIGN.md §4.7–4.8).
//!
//! The three cluster drivers ([`crate::cluster::run_placement`],
//! [`crate::controlplane::run_adaptive`],
//! [`crate::lifecycle::run_lifecycle`]) used to carry one hand-rolled
//! copy each of the same global-clock loop, stepping every per-GPU
//! engine on a single thread. The key structural fact they all share:
//! per-GPU execution is *independent between global interaction points*.
//! Only three things ever need a cluster-wide view:
//!
//! 1. **routing** — a request is dispatched against the live backlog of
//!    every candidate replica at its arrival instant;
//! 2. **control ticks** — the adaptive plane samples demand and may
//!    rebalance replicas across engines;
//! 3. **lifecycle events** — load maturities, pending replica
//!    activations and idle expiries mutate engine model tables.
//!
//! Everything else an engine does (batch completions, policy timers,
//! dispatch rounds) touches only its own state.
//!
//! # Epoch mode ([`ExecMode::Epoch`])
//!
//! The PR 4 loop: advance the cluster in global epochs — compute the
//! next barrier time (next arrival, control tick, or lifecycle event),
//! run the driver's serial barrier work at it, then fan the per-engine
//! stepping out to a worker pool and let each engine replay its own
//! internal event sequence up to the *next* barrier, in parallel. Every
//! engine synchronizes at every barrier, so an un-quantized arrival
//! stream degenerates to one epoch per request and the per-epoch
//! full-slice engine scan makes coordination O(GPUs × requests).
//!
//! # Sparse mode ([`ExecMode::Sparse`], the default)
//!
//! An arrival only needs the engines that host replicas of the arriving
//! model — the *candidate set*, exposed by the driver through
//! [`EpochDriver::candidates`]. Every other engine is irrelevant to the
//! barrier: nothing reads or writes it, so it may keep running ahead to
//! its *own* next relevant barrier. The core maintains
//!
//! - a per-model → candidate-engine index (inverted into engine →
//!   hosted models, rebuilt only when a driver event may have changed
//!   the topology), and
//! - a per-engine `safe_until` frontier: the earliest instant the
//!   engine can matter again — the next arrival of a model it hosts,
//!   the next driver event (conservatively: any driver event may touch
//!   any engine), or the horizon —
//!
//! kept in a min-heap keyed on each engine's frontier. Selecting the
//! engines that must synchronize at a barrier is then O(k log G) for k
//! candidates instead of the epoch loop's O(G) full-slice scan, and an
//! engine whose hosted models stay silent for a hundred arrivals is
//! advanced once, not re-scanned a hundred times — the big win for
//! un-quantized long-tail Zipf streams.
//!
//! For routing policies that never read backlogs (round-robin / static
//! splits, [`crate::cluster::routing::RoutingPolicy::reads_backlogs`]),
//! the stepping barrier is elided entirely: every arrival strictly
//! before the next driver event is routed serially through the pure
//! decision hook [`EpochDriver::route_free`] and delivered as a
//! *timestamped injection*; each engine then replays its events and its
//! injections interleaved in time order — the same per-engine call
//! sequence, with zero intervening barriers and one fat parallel round
//! per span.
//!
//! # Determinism
//!
//! Neither thread count nor `exec_mode` is allowed to change results,
//! byte for byte:
//!
//! - A [`crate::sim::Sim`]'s trajectory is a pure function of its
//!   (step-time, injection) call sequence. Both modes produce the exact
//!   sequence of the original serial loop for every engine: internal
//!   events replay at their own timestamps in order, injections land
//!   at their arrival instants before the step at that instant.
//! - All cross-engine reads (backlog probes, rebalance surgery, idle
//!   sweeps) happen in serial phases, when every engine that can be
//!   read has processed exactly its events *strictly before* the
//!   barrier. In sparse mode only candidate engines are forced to the
//!   arrival instant before the backlog probe — sufficient because a
//!   probe of model *m* only ever reads engines hosting *m*, which are
//!   candidates by construction.
//! - The frontier invariant makes run-ahead safe: an engine hosting
//!   model *m* has `safe_until` ≤ the next arrival of *m* (arrival
//!   times only ever pop from the per-model queues, never appear
//!   earlier), so no engine can ever have run past a barrier that needs
//!   it. Driver events conservatively bound *every* frontier; a driver
//!   may therefore only create a new event at a barrier, with a time
//!   strictly in the future — which all three drivers satisfy (debug
//!   asserts enforce both directions).
//!
//! Hence a fixed (placement, routing, seed, stream) tuple yields an
//! identical `ClusterReport` JSON for any `threads` × `exec_mode`
//! combination — the property `rust/tests/parallel_exec.rs` locks in
//! for all three drivers.
//!
//! # Fault barriers ([`crate::faults`])
//!
//! Fault-injection timelines ride the same machinery with no new core
//! hooks: the resilience layer surfaces its next scheduled instant
//! (fault event, restore maturity, or hedge-sweep tick) through
//! [`EpochDriver::next_event`], so every fault lands on a *driver-event
//! barrier* — a serial phase where all engines have synchronized.
//! Drain/re-route surgery, cold restores and hedged queue moves are
//! therefore ordinary barrier work, covered by the determinism argument
//! above verbatim: the timeline is fixed virtual-time data, the barrier
//! set it induces is identical for every `threads` × `exec_mode`
//! combination, and drivers with an active fault timeline report
//! `elides_barriers() == false` so no arrival span can skip the
//! stepping barrier a hedge sweep or admission probe needs. Byte
//! identity for fault scenarios is locked in by the ninth
//! `rust/tests/parallel_exec.rs` scenario and `rust/tests/resilience.rs`.
//!
//! # Worker pool
//!
//! No dependencies are reachable in the build image, so the pool is
//! plain `std`: scoped threads ([`std::thread::scope`]) that live for
//! the whole run, fed batches of [`WorkItem`]s over
//! [`std::sync::mpsc`] channels. Engines *move* into a batch and move
//! back when the worker returns it (ownership ping-pong), which keeps
//! the pool 100% safe code — no shared-mutability cells, no unsafe.
//! Rounds with fewer than `FANOUT_MIN` busy engines are stepped inline
//! on the driver thread, and `threads = 1` skips spawning entirely.

use crate::gpu::Us;
use crate::metrics::RunReport;
use crate::sim::{Policy, Sim};
use crate::util::json::Json;
use crate::workload::{ArrivalStream, MaterializedStream, Request};
use std::cmp::Reverse;
use std::collections::BinaryHeap;
use std::sync::mpsc::{channel, Receiver, Sender};

/// Engine-stepping thread budget for a cluster run — the `parallelism`
/// scenario knob and the CLI `--threads` flag (docs/CONFIG.md).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Parallelism {
    /// One stepping lane per available core (the default).
    #[default]
    Auto,
    /// Exactly `n` lanes; `1` is the legacy serial path.
    Threads(usize),
}

impl Parallelism {
    /// Parse the config/CLI spelling: `"auto"` or an integer ≥ 1.
    pub fn parse(s: &str) -> Result<Parallelism, String> {
        if s == "auto" {
            return Ok(Parallelism::Auto);
        }
        match s.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(Parallelism::Threads(n)),
            _ => Err(format!("parallelism must be \"auto\" or an integer >= 1, got '{s}'")),
        }
    }

    /// Number of stepping lanes this run may use (≥ 1).
    pub fn resolve(self) -> usize {
        match self {
            Parallelism::Auto => {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            }
            Parallelism::Threads(n) => n.max(1),
        }
    }

    /// Canonical config spelling (`"auto"` or the number).
    pub fn label(self) -> String {
        match self {
            Parallelism::Auto => "auto".to_string(),
            Parallelism::Threads(n) => n.to_string(),
        }
    }
}

/// Barrier discipline of the execution core — the `exec_mode` scenario
/// knob and the CLI `--exec-mode` flag (docs/CONFIG.md). Mode never
/// changes results, only wall-clock; sparse is the default and epoch is
/// kept in-tree so the equivalence stays testable.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum ExecMode {
    /// PR 4 bulk-synchronous loop: every engine barriers at every
    /// global arrival/driver event.
    Epoch,
    /// Per-engine relevant-arrival lookahead + routing-aware barrier
    /// elision (the default).
    #[default]
    Sparse,
}

impl ExecMode {
    /// Parse the config/CLI spelling.
    pub fn parse(s: &str) -> Result<ExecMode, String> {
        match s {
            "epoch" => Ok(ExecMode::Epoch),
            "sparse" => Ok(ExecMode::Sparse),
            other => Err(format!("exec_mode must be \"epoch\" or \"sparse\", got '{other}'")),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            ExecMode::Epoch => "epoch",
            ExecMode::Sparse => "sparse",
        }
    }
}

/// Execution-core options every cluster driver accepts (the `_with`
/// run variants): stepping thread budget plus barrier discipline.
/// Neither field changes results — see the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ExecOpts {
    pub threads: Parallelism,
    pub mode: ExecMode,
    /// Observability knobs (`crate::obs`). Like `threads`/`mode`, never
    /// changes report bytes: traces and time-series ride the report
    /// out-of-band (`ClusterReport::obs`) and are exported separately.
    pub obs: crate::obs::ObsCfg,
}

impl ExecOpts {
    pub fn new(threads: Parallelism, mode: ExecMode) -> ExecOpts {
        ExecOpts { threads, mode, obs: crate::obs::ObsCfg::default() }
    }

    /// Default mode with an explicit thread budget.
    pub fn with_threads(threads: Parallelism) -> ExecOpts {
        ExecOpts { threads, ..Default::default() }
    }
}

/// Out-of-band execution telemetry attached to a
/// [`crate::cluster::ClusterReport`] (its `exec` field). Deliberately
/// **never serialized** into the report JSON: `exec_mode` and thread
/// count must not change the report bytes, and these counters do.
/// Surfaced by `dstack … --verbose` and recorded by
/// `benches/bench_parallel.rs` into `BENCH_parallel.json`.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub struct ExecStats {
    pub mode: ExecMode,
    /// Serial barrier rounds run (epoch-mode epochs, sparse-mode
    /// barriers + elision rounds).
    pub epochs: u64,
    /// Arrival instants folded into batched injection rounds instead of
    /// getting their own stepping barrier (sparse mode, backlog-free
    /// routing only).
    pub barriers_elided: u64,
    /// Arrivals routed through batched injection rounds.
    pub arrivals_batched: u64,
    /// Longest run-ahead window granted to an engine past a barrier
    /// before its next forced resync (µs).
    pub max_lookahead_us: Us,
    /// Requests pulled from the arrival stream over the whole run.
    pub requests_streamed: u64,
    /// Peak requests simultaneously held by the arrival source plus the
    /// current routing round — the peak-RSS proxy `bench_streaming`
    /// asserts stays O(backlog) for lazy streams (the materialized
    /// adapters report ≈ the full stream length here).
    pub peak_in_flight: u64,
}

impl ExecStats {
    fn new(mode: ExecMode) -> ExecStats {
        ExecStats { mode, ..Default::default() }
    }

    fn note_lookahead(&mut self, d: Us) {
        self.max_lookahead_us = self.max_lookahead_us.max(d);
    }

    fn note_in_flight(&mut self, n: u64) {
        self.peak_in_flight = self.peak_in_flight.max(n);
    }

    /// Fraction of would-be barriers the sparse core elided:
    /// `elided / (elided + serial rounds)`. 0 in epoch mode.
    pub fn elision_ratio(&self) -> f64 {
        let total = self.barriers_elided + self.epochs;
        if total == 0 {
            0.0
        } else {
            self.barriers_elided as f64 / total as f64
        }
    }

    /// JSON form for bench summaries (NOT part of `ClusterReport` JSON).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("mode", Json::from(self.mode.label())),
            ("epochs", Json::from(self.epochs)),
            ("barriers_elided", Json::from(self.barriers_elided)),
            ("arrivals_batched", Json::from(self.arrivals_batched)),
            ("max_lookahead_us", Json::from(self.max_lookahead_us)),
            ("requests_streamed", Json::from(self.requests_streamed)),
            ("peak_in_flight", Json::from(self.peak_in_flight)),
        ])
    }

    /// One-line human form for `--verbose` CLI output.
    pub fn render(&self) -> String {
        format!(
            "exec core: mode={} serial_rounds={} barriers_elided={} ({:.0}%) \
             arrivals_batched={} max_lookahead={:.1} ms streamed={} peak_in_flight={}",
            self.mode.label(),
            self.epochs,
            self.barriers_elided,
            self.elision_ratio() * 100.0,
            self.arrivals_batched,
            self.max_lookahead_us as f64 / 1_000.0,
            self.requests_streamed,
            self.peak_in_flight
        )
    }
}

/// Engines a driver marked at a barrier (injections, tombstone
/// surgery). List-backed so clearing is O(marked), not O(GPUs) — the
/// epoch loop used to refill a full bool slice at every barrier.
pub(crate) struct Touched {
    flags: Vec<bool>,
    list: Vec<usize>,
}

impl Touched {
    pub(crate) fn new(n: usize) -> Touched {
        Touched { flags: vec![false; n], list: Vec::with_capacity(n) }
    }

    /// Mark engine `g` as mutated at the current barrier.
    pub(crate) fn mark(&mut self, g: usize) {
        if !self.flags[g] {
            self.flags[g] = true;
            self.list.push(g);
        }
    }

    pub(crate) fn is(&self, g: usize) -> bool {
        self.flags[g]
    }

    pub(crate) fn list(&self) -> &[usize] {
        &self.list
    }

    pub(crate) fn clear(&mut self) {
        for &g in &self.list {
            self.flags[g] = false;
        }
        self.list.clear();
    }
}

/// One per-GPU engine: a [`Sim`] plus the policy driving it. Shared by
/// all cluster drivers; the control plane and the memory manager
/// additionally rebuild the policy after tombstone surgery
/// ([`Self::rebuild_policy`]).
pub(crate) struct ExecEngine {
    pub(crate) sim: Sim,
    pub(crate) policy: Box<dyn Policy>,
}

impl ExecEngine {
    fn step(&mut self, t: Us, horizon: Us) {
        self.sim.step_to(t, self.policy.as_mut(), horizon);
    }

    /// One engine's share of a round: finish the barrier time (when it
    /// was touched by routing/surgery or has an event due there), then
    /// replay its internal events strictly before `drain_to` — each at
    /// its own timestamp, exactly as the serial global loop stepped it.
    fn advance(&mut self, step_now: bool, now: Us, drain_to: Us, horizon: Us) {
        if step_now {
            self.step(now, horizon);
        }
        while let Some(w) = self.sim.next_event_time() {
            if w >= drain_to {
                break;
            }
            self.step(w, horizon);
        }
    }

    /// Elided-barrier replay: interleave internal events with
    /// timestamped injections (nondecreasing arrival order) — replay
    /// events strictly before each arrival instant, inject everything
    /// due at it, step at it — then drain remaining events before
    /// `drain_to`. This is exactly the call sequence [`Sim::run`] (and
    /// hence the barrier-per-arrival loops) produces.
    fn advance_injecting(&mut self, inj: Vec<(Us, Request)>, drain_to: Us, horizon: Us) {
        debug_assert!(inj.windows(2).all(|w| w[0].0 <= w[1].0), "injections out of order");
        let mut it = inj.into_iter().peekable();
        while let Some(&(a, _)) = it.peek() {
            while let Some(w) = self.sim.next_event_time() {
                if w >= a {
                    break;
                }
                self.step(w, horizon);
            }
            while it.peek().is_some_and(|&(t, _)| t == a) {
                let (_, r) = it.next().expect("peeked");
                self.sim.inject(r);
            }
            self.step(a, horizon);
        }
        while let Some(w) = self.sim.next_event_time() {
            if w >= drain_to {
                break;
            }
            self.step(w, horizon);
        }
    }

    /// Rebuild the per-GPU policy from the engine's current entry table,
    /// masking tombstones so retired models hold no plan capacity,
    /// slices or shares.
    pub(crate) fn rebuild_policy(&mut self, sched: super::GpuSched) {
        let mask = self.sim.active_mask();
        self.policy = sched.build_masked(&self.sim.models, &mask);
    }

    /// Horizon wrap-up under the engine's own policy name.
    pub(crate) fn finalize(&mut self, horizon: Us) -> RunReport {
        let name = self.policy.name();
        self.sim.finalize(name, horizon)
    }
}

/// Driver-specific half of a barrier: everything that needs the global
/// view, executed serially. The core supplies the arrival stream and
/// the engine stepping; the driver supplies barrier times of its own
/// (ticks, load maturities, …), the routing/topology hooks, and the
/// barrier work.
///
/// # Contract (what makes sparse barriers safe)
///
/// - [`Self::candidates`] must cover every engine [`Self::route`] can
///   read or write for that request — including fallback replicas and
///   any engine an eviction/re-route cascade may reach. A driver whose
///   cascades are unbounded (the lifecycle memory manager) declares
///   *all* engines and degrades gracefully to epoch behavior.
/// - Topology (the candidate index) may only change at barriers where
///   [`Self::next_event`] was due.
/// - A new driver event may only be created at a barrier, with a time
///   strictly greater than that barrier, and only if `next_event()` at
///   every earlier barrier was no later than the creating barrier (true
///   for periodic ticks and for maturities spawned by ticks/loads).
/// - When [`Self::elides_barriers`] is true, `pre_arrivals` /
///   `post_arrivals` must be no-ops at barriers without a due driver
///   event, and [`Self::route_free`] must reproduce [`Self::route`]'s
///   driver-state mutations exactly while never touching an engine.
pub(crate) trait EpochDriver {
    /// Number of global models (the candidate-index domain).
    fn n_models(&self) -> usize;

    /// Earliest pending driver event (control tick, pending activation,
    /// load maturity, idle expiry). `None` when only arrivals remain.
    fn next_event(&self) -> Option<Us>;

    /// Engines hosting a routable replica of `model` — the engines an
    /// arrival of that model synchronizes in sparse mode. An empty
    /// slice means arrivals of the model are rejected without touching
    /// any engine.
    fn candidates_of(&self, model: usize) -> &[usize];

    /// Candidate engines of one arriving request (the sparse core's
    /// per-arrival hook; defaults to the model-level index).
    fn candidates(&self, req: &Request) -> &[usize] {
        self.candidates_of(req.model)
    }

    /// True when routing decisions never read engine state (round-robin
    /// / static splits): the sparse core may then elide stepping
    /// barriers and batch arrivals through [`Self::route_free`].
    fn elides_barriers(&self) -> bool {
        false
    }

    /// Pure routing decision for the elided path: admission + replica
    /// choice with all driver-side bookkeeping (demand counters, reject
    /// counts), returning the destination `(gpu, engine-local model)`
    /// or `None` when rejected. Must not touch any engine.
    fn route_free(&mut self, _t: Us, _req: &Request) -> Option<(usize, usize)> {
        unreachable!("driver did not declare barrier-free routing")
    }

    /// Barrier work before arrivals are routed (mature loads/activations
    /// due at `t`). Mark engines whose tables changed in `touched`.
    fn pre_arrivals(
        &mut self,
        _t: Us,
        _engines: &mut [Option<ExecEngine>],
        _touched: &mut Touched,
    ) {
    }

    /// Route one arrival at `t` (reads live backlogs, injects, marks
    /// `touched`). Requests arrive owned: injection moves them.
    fn route(
        &mut self,
        t: Us,
        req: Request,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
    );

    /// Barrier work after arrivals (control ticks, idle sweeps).
    fn post_arrivals(
        &mut self,
        _t: Us,
        _engines: &mut [Option<ExecEngine>],
        _touched: &mut Touched,
    ) {
    }
}

/// One engine's share of a stepping round, shipped by value to a
/// worker: the engine moves in, is advanced, and moves back.
struct WorkItem {
    /// Engine slot index.
    g: usize,
    engine: ExecEngine,
    /// Step at the round's barrier instant first (the engine was
    /// injected into or mutated there).
    step_now: bool,
    /// Replay internal events strictly before this instant (per-item:
    /// sparse engines run ahead to their *own* frontier).
    drain_to: Us,
    /// Timestamped injections for the elided-barrier path (empty
    /// otherwise).
    inj: Vec<(Us, Request)>,
}

impl WorkItem {
    fn run(&mut self, now: Us, horizon: Us) {
        if self.inj.is_empty() {
            self.engine.advance(self.step_now, now, self.drain_to, horizon);
        } else {
            let inj = std::mem::take(&mut self.inj);
            self.engine.advance_injecting(inj, self.drain_to, horizon);
        }
    }
}

struct Batch {
    items: Vec<WorkItem>,
    now: Us,
    horizon: Us,
}

struct Worker {
    cmd: Sender<Batch>,
    ret: Receiver<Batch>,
}

struct Pool {
    workers: Vec<Worker>,
}

/// Below this many busy engines a round is stepped inline: the fan-out
/// overhead (one channel round-trip per worker) only pays for itself
/// when several engines have real work between barriers.
const FANOUT_MIN: usize = 4;

/// Run a round of work items: inline on the driver thread when small,
/// round-robined over the pool's lanes when fat. Engines return to
/// their slots either way. `items` is caller-owned scratch, drained
/// here so its capacity is reused across rounds — un-quantized streams
/// barrier at every arrival, so this would otherwise allocate per
/// request.
fn run_items(
    pool: &mut Option<&mut Pool>,
    engines: &mut [Option<ExecEngine>],
    items: &mut Vec<WorkItem>,
    now: Us,
    horizon: Us,
) {
    if items.is_empty() {
        return;
    }
    match pool {
        Some(pool) if items.len() >= FANOUT_MIN => {
            let lanes = pool.workers.len() + 1;
            let mut batches: Vec<Vec<WorkItem>> = (0..lanes).map(|_| Vec::new()).collect();
            for (i, item) in items.drain(..).enumerate() {
                batches[i % lanes].push(item);
            }
            let mut mine = batches.swap_remove(0);
            let mut sent: Vec<usize> = Vec::new();
            for (wi, items) in batches.into_iter().enumerate() {
                if items.is_empty() {
                    continue;
                }
                pool.workers[wi]
                    .cmd
                    .send(Batch { items, now, horizon })
                    .expect("exec worker hung up");
                sent.push(wi);
            }
            for item in mine.iter_mut() {
                item.run(now, horizon);
            }
            for item in mine {
                engines[item.g] = Some(item.engine);
            }
            for wi in sent {
                let b = pool.workers[wi].ret.recv().expect("exec worker died");
                for item in b.items {
                    engines[item.g] = Some(item.engine);
                }
            }
        }
        _ => {
            for mut item in items.drain(..) {
                item.run(now, horizon);
                engines[item.g] = Some(item.engine);
            }
        }
    }
}

/// Drive `engines` over a materialized `requests` vector — the legacy
/// entry point, now a thin adapter over [`run_epochs_stream`] via
/// [`MaterializedStream`] (which preserves the exact pre-streaming
/// bookkeeping, so report bytes are unchanged).
pub(crate) fn run_epochs<D: EpochDriver>(
    engines: &mut [Option<ExecEngine>],
    requests: Vec<Request>,
    horizon: Us,
    opts: ExecOpts,
    driver: &mut D,
) -> ExecStats {
    let n_models = driver.n_models();
    run_epochs_stream(engines, MaterializedStream::new(requests, n_models), horizon, opts, driver)
}

/// Drive `engines` over the arrivals pulled lazily from `stream` to
/// `horizon` under `driver`. The stream is owned: every injection
/// *moves* a request — no full-stream clone anywhere on the path, and
/// memory stays O(stream backlog) for lazy sources. Returns the run's
/// [`ExecStats`].
pub(crate) fn run_epochs_stream<D: EpochDriver, S: ArrivalStream>(
    engines: &mut [Option<ExecEngine>],
    mut stream: S,
    horizon: Us,
    opts: ExecOpts,
    driver: &mut D,
) -> ExecStats {
    // More lanes than engines can never help: each engine is stepped by
    // exactly one lane per round. Capping here also bounds the spawn
    // count for arbitrary user-supplied `--threads` values. Clusters
    // too small to ever clear the fan-out threshold skip the pool
    // entirely — no spawns, no channels, pure serial path.
    let lanes = opts.threads.resolve().min(engines.len());
    let mut stats = ExecStats::new(opts.mode);
    if lanes <= 1 || engines.len() < FANOUT_MIN {
        match opts.mode {
            ExecMode::Epoch => {
                epoch_loop(engines, &mut stream, horizon, driver, None, &mut stats)
            }
            ExecMode::Sparse => {
                sparse_loop(engines, &mut stream, horizon, driver, None, &mut stats)
            }
        }
        return stats;
    }
    std::thread::scope(|s| {
        // `lanes - 1` workers; the driver thread is the remaining lane.
        let mut workers = Vec::with_capacity(lanes - 1);
        for _ in 0..lanes - 1 {
            let (cmd_tx, cmd_rx) = channel::<Batch>();
            let (ret_tx, ret_rx) = channel::<Batch>();
            s.spawn(move || {
                while let Ok(mut b) = cmd_rx.recv() {
                    for item in b.items.iter_mut() {
                        item.run(b.now, b.horizon);
                    }
                    if ret_tx.send(b).is_err() {
                        break;
                    }
                }
            });
            workers.push(Worker { cmd: cmd_tx, ret: ret_rx });
        }
        let mut pool = Pool { workers };
        match opts.mode {
            ExecMode::Epoch => {
                epoch_loop(engines, &mut stream, horizon, driver, Some(&mut pool), &mut stats)
            }
            ExecMode::Sparse => {
                sparse_loop(engines, &mut stream, horizon, driver, Some(&mut pool), &mut stats)
            }
        }
        // Dropping the pool's senders ends the workers; the scope joins.
    });
    stats
}

/// Tail drain shared by both loops: no barriers remain, but engines may
/// still hold events inside the horizon (the serial loops processed
/// exactly those).
fn drain_tail(
    engines: &mut [Option<ExecEngine>],
    horizon: Us,
    pool: &mut Option<&mut Pool>,
) {
    let mut items = Vec::new();
    for (g, slot) in engines.iter_mut().enumerate() {
        let Some(e) = slot.as_ref() else { continue };
        if e.sim.next_event_time().is_some_and(|w| w < horizon) {
            items.push(WorkItem {
                g,
                engine: slot.take().expect("checked some"),
                step_now: false,
                drain_to: horizon,
                inj: Vec::new(),
            });
        }
    }
    run_items(pool, engines, &mut items, 0, horizon);
}

/// The PR 4 bulk-synchronous loop: every engine barriers at every
/// global arrival / driver event.
fn epoch_loop<D: EpochDriver, S: ArrivalStream>(
    engines: &mut [Option<ExecEngine>],
    stream: &mut S,
    horizon: Us,
    driver: &mut D,
    mut pool: Option<&mut Pool>,
    stats: &mut ExecStats,
) {
    let mut touched = Touched::new(engines.len());
    // Reused round scratch (capacity bounded by the engine count).
    let mut items: Vec<WorkItem> = Vec::with_capacity(engines.len());
    loop {
        stats.note_in_flight(stream.buffered() as u64);
        let t_arr = stream.peek_time();
        let t_drv = driver.next_event();
        let Some(t) = [t_arr, t_drv].into_iter().flatten().min() else { break };
        if t >= horizon {
            break;
        }
        touched.clear();
        driver.pre_arrivals(t, engines, &mut touched);
        while stream.peek_time().is_some_and(|a| a <= t) {
            let r = stream.next_request().expect("peeked");
            stats.requests_streamed += 1;
            driver.route(t, r, engines, &mut touched);
        }
        driver.post_arrivals(t, engines, &mut touched);
        stats.epochs += 1;
        // The next barrier is known now — arrivals and driver events
        // only change during serial phases — so engines can run ahead
        // to it without any cross-engine coordination.
        let drain_to = [stream.peek_time(), driver.next_event()]
            .into_iter()
            .flatten()
            .min()
            .unwrap_or(horizon)
            .min(horizon);
        stats.note_lookahead(drain_to.saturating_sub(t));
        for (g, slot) in engines.iter_mut().enumerate() {
            let Some(e) = slot.as_ref() else { continue };
            let w = e.sim.next_event_time();
            let step_now = touched.is(g) || w.is_some_and(|w| w <= t);
            if step_now || w.is_some_and(|w| w < drain_to) {
                items.push(WorkItem {
                    g,
                    engine: slot.take().expect("checked some"),
                    step_now,
                    drain_to,
                    inj: Vec::new(),
                });
            }
        }
        run_items(&mut pool, engines, &mut items, t, horizon);
    }
    drain_tail(engines, horizon, &mut pool);
}

/// An engine's next relevant barrier: the earliest pending arrival of a
/// model it hosts (per [`ArrivalStream::peek_model`] — exact for
/// materialized/merged streams, conservatively the global head for
/// trace replays), the next driver event (conservative — any driver
/// event may touch any engine), or the horizon. Conservative peeks
/// shrink the run-ahead window but never the call sequence, so results
/// stay byte-identical (stream module docs).
fn safe_until<S: ArrivalStream>(
    hosted: &[usize],
    stream: &S,
    t_drv: Option<Us>,
    horizon: Us,
) -> Us {
    let mut f = t_drv.unwrap_or(horizon).min(horizon);
    for &m in hosted {
        if let Some(a) = stream.peek_model(m) {
            f = f.min(a);
        }
    }
    f
}

/// Invert the driver's model → candidate-engine index into engine →
/// hosted models. Only called at topology-change points (start, driver
/// events).
fn rebuild_hosted<D: EpochDriver + ?Sized>(
    hosted: &mut [Vec<usize>],
    driver: &D,
    n_models: usize,
) {
    for h in hosted.iter_mut() {
        h.clear();
    }
    for m in 0..n_models {
        for &g in driver.candidates_of(m) {
            hosted[g].push(m);
        }
    }
}

/// Cap on arrivals popped from the stream per elided round. Without it
/// a driver-event-free span would pull the *entire* stream into the
/// per-engine injection vectors — O(total requests) memory, defeating
/// the lazy stream. When the cap cuts a span short, the round drains
/// only to the next pending arrival, which preserves each engine's
/// (step-time, injection) call sequence exactly: events strictly before
/// that arrival replay identically whether the span was split or not,
/// and same-instant arrivals are never split across rounds.
const ELIDE_CHUNK: usize = 1024;

/// Sparse-barrier loop: candidate-set sync at arrivals, global sync at
/// driver events, frontier-heap work selection, and barrier elision for
/// backlog-free routing. See the module docs for the determinism
/// argument.
fn sparse_loop<D: EpochDriver, S: ArrivalStream>(
    engines: &mut [Option<ExecEngine>],
    stream: &mut S,
    horizon: Us,
    driver: &mut D,
    mut pool: Option<&mut Pool>,
    stats: &mut ExecStats,
) {
    let n_g = engines.len();
    let n_models = driver.n_models();
    // Degenerate candidate index: a driver that declares *every* engine
    // a candidate of every model (the lifecycle memory manager, whose
    // eviction cascades can reach any engine; legacy all-models-on-all-
    // GPUs layouts under JSQ) makes every arrival a global barrier —
    // sparse bookkeeping would only add frontier/heap overhead on top
    // of epoch behavior. Run the epoch loop directly; it is the same
    // call sequence (byte-identity is mode-independent anyway).
    // Backlog-free routing still benefits from elision, so it stays on
    // the sparse path.
    if !driver.elides_barriers()
        && n_g > 0
        && (0..n_models).all(|m| driver.candidates_of(m).len() == n_g)
    {
        return epoch_loop(engines, stream, horizon, driver, pool, stats);
    }
    // Frontiers are computed from the stream's per-model peeks. Arrival
    // times only ever pop from the stream, never appear earlier, so a
    // frontier computed earlier can never exceed a model's next arrival
    // — the invariant that makes run-ahead safe.
    let mut hosted: Vec<Vec<usize>> = vec![Vec::new(); n_g];
    rebuild_hosted(&mut hosted, driver, n_models);
    // `frontier[g]` is authoritative; the heap holds (frontier, g)
    // entries with lazy deletion (an entry is stale when it no longer
    // matches `frontier[g]`). Frontiers are monotone per engine, so
    // stale entries always pop before the live one.
    let mut frontier: Vec<Us> = vec![0; n_g];
    let mut heap: BinaryHeap<Reverse<(Us, usize)>> = BinaryHeap::with_capacity(n_g * 2);
    {
        let t_drv = driver.next_event();
        for g in 0..n_g {
            frontier[g] = safe_until(&hosted[g], stream, t_drv, horizon);
            heap.push(Reverse((frontier[g], g)));
        }
    }
    let mut touched = Touched::new(n_g);
    let mut sync: Vec<usize> = Vec::with_capacity(n_g);
    let mut inj: Vec<Vec<(Us, Request)>> = vec![Vec::new(); n_g];
    // Reused round scratch (capacity bounded by the engine count).
    let mut items: Vec<WorkItem> = Vec::with_capacity(n_g);

    loop {
        let t_arr = stream.peek_time();
        let t_drv = driver.next_event();
        let Some(t) = [t_arr, t_drv].into_iter().flatten().min() else { break };
        if t >= horizon {
            break;
        }
        let drv_due = t_drv == Some(t);

        if !drv_due && driver.elides_barriers() {
            // ---- elided span [t, span_end): no driver event inside,
            // routing reads no engine state, so every arrival becomes a
            // timestamped injection and the span is one fat round —
            // chunked to ELIDE_CHUNK arrivals so a lazy stream is never
            // materialized wholesale (same-instant arrivals always stay
            // in one chunk: splitting an instant would split its
            // inject-all-then-step call group).
            let span_end = t_drv.unwrap_or(horizon).min(horizon);
            let mut last = None;
            let mut popped: usize = 0;
            while stream.peek_time().is_some_and(|a| {
                a < span_end && (popped < ELIDE_CHUNK || last == Some(a))
            }) {
                let r = stream.next_request().expect("peeked");
                stats.requests_streamed += 1;
                popped += 1;
                if last != Some(r.arrival) {
                    stats.barriers_elided += 1;
                    last = Some(r.arrival);
                }
                stats.arrivals_batched += 1;
                if let Some((g, local)) = driver.route_free(r.arrival, &r) {
                    let mut q = r;
                    q.model = local;
                    inj[g].push((q.arrival, q));
                }
            }
            // Chunk-limited rounds drain only to the next pending
            // arrival; the next loop iteration opens a fresh elided
            // round there, replaying the identical call sequence.
            let round_end = match stream.peek_time() {
                Some(a) if a < span_end => a,
                _ => span_end,
            };
            stats.note_in_flight(stream.buffered() as u64 + popped as u64);
            stats.epochs += 1;
            stats.note_lookahead(round_end - t);
            for (g, slot) in engines.iter_mut().enumerate() {
                let Some(e) = slot.as_ref() else { continue };
                if !inj[g].is_empty() || e.sim.next_event_time().is_some_and(|w| w < round_end)
                {
                    items.push(WorkItem {
                        g,
                        engine: slot.take().expect("checked some"),
                        step_now: false,
                        drain_to: round_end,
                        inj: std::mem::take(&mut inj[g]),
                    });
                }
            }
            debug_assert!(
                inj.iter().all(|v| v.is_empty()),
                "elided injections routed to an engine-less slot"
            );
            run_items(&mut pool, engines, &mut items, t, horizon);
            // Every engine advanced to round_end: restart the frontier
            // bookkeeping from a clean heap.
            heap.clear();
            let t_next = driver.next_event();
            for g in 0..n_g {
                frontier[g] = safe_until(&hosted[g], stream, t_next, horizon);
                heap.push(Reverse((frontier[g], g)));
            }
            continue;
        }

        // ---- regular sparse barrier at t ----
        stats.note_in_flight(stream.buffered() as u64);
        // Engines whose frontier expired must reach the barrier: the
        // candidates of every model arriving at t (by the frontier
        // invariant), plus — at driver events — everyone.
        sync.clear();
        if drv_due {
            heap.clear();
            sync.extend((0..n_g).filter(|&g| engines[g].is_some()));
        } else {
            while let Some(&Reverse((f, g))) = heap.peek() {
                if f > t {
                    break;
                }
                heap.pop();
                if frontier[g] == f {
                    sync.push(g);
                }
            }
        }
        // Catch-up: replay events strictly before t, so serial-phase
        // reads see exactly the pre-barrier state (same as epoch mode).
        for &g in &sync {
            let Some(e) = engines[g].as_ref() else { continue };
            debug_assert!(e.sim.now() <= t, "engine {g} ran ahead of barrier {t}");
            if e.sim.next_event_time().is_some_and(|w| w < t) {
                items.push(WorkItem {
                    g,
                    engine: engines[g].take().expect("checked some"),
                    step_now: false,
                    drain_to: t,
                    inj: Vec::new(),
                });
            }
        }
        run_items(&mut pool, engines, &mut items, t, horizon);

        touched.clear();
        driver.pre_arrivals(t, engines, &mut touched);
        while stream.peek_time().is_some_and(|a| a <= t) {
            let r = stream.next_request().expect("peeked");
            stats.requests_streamed += 1;
            debug_assert!(
                driver.candidates(&r).iter().all(|&g| frontier[g] <= t),
                "candidate engine not synchronized at its model's arrival"
            );
            driver.route(t, r, engines, &mut touched);
        }
        driver.post_arrivals(t, engines, &mut touched);
        stats.epochs += 1;
        if drv_due {
            // Topology may only change at driver-event barriers.
            rebuild_hosted(&mut hosted, driver, n_models);
        }

        // Advance: synced + touched engines get a fresh frontier and
        // run ahead to it. At driver events re-collect from the slots —
        // the serial phase may have created engines (pending replica
        // activations). At arrival barriers touched ⊆ sync: a driver
        // can only have mutated candidates of the arriving models.
        let t_next = driver.next_event();
        debug_assert!(t_next.map_or(true, |d| d > t), "driver event not consumed at {t}");
        if drv_due {
            sync.clear();
            sync.extend((0..n_g).filter(|&g| engines[g].is_some()));
        } else {
            debug_assert!(
                touched.list().iter().all(|&g| sync.contains(&g)),
                "driver touched an engine outside the arrival's candidate set"
            );
        }
        for &g in &sync {
            let Some(e) = engines[g].as_ref() else { continue };
            frontier[g] = safe_until(&hosted[g], stream, t_next, horizon);
            debug_assert!(frontier[g] >= t);
            stats.note_lookahead(frontier[g] - t);
            heap.push(Reverse((frontier[g], g)));
            let step_now = touched.is(g);
            if step_now || e.sim.next_event_time().is_some_and(|w| w < frontier[g]) {
                items.push(WorkItem {
                    g,
                    engine: engines[g].take().expect("checked some"),
                    step_now,
                    drain_to: frontier[g],
                    inj: Vec::new(),
                });
            }
        }
        run_items(&mut pool, engines, &mut items, t, horizon);
    }
    drain_tail(engines, horizon, &mut pool);
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::cluster::GpuSched;
    use crate::profile::by_name;
    use crate::sim::{entries_at_optimum, SimConfig};

    #[test]
    fn parallelism_parses_and_resolves() {
        assert_eq!(Parallelism::parse("auto"), Ok(Parallelism::Auto));
        assert_eq!(Parallelism::parse("1"), Ok(Parallelism::Threads(1)));
        assert_eq!(Parallelism::parse("8"), Ok(Parallelism::Threads(8)));
        assert!(Parallelism::parse("0").is_err());
        assert!(Parallelism::parse("-2").is_err());
        assert!(Parallelism::parse("fast").is_err());
        assert_eq!(Parallelism::Threads(3).resolve(), 3);
        assert!(Parallelism::Auto.resolve() >= 1);
        assert_eq!(Parallelism::Auto.label(), "auto");
        assert_eq!(Parallelism::Threads(4).label(), "4");
        assert_eq!(Parallelism::default(), Parallelism::Auto);
    }

    #[test]
    fn exec_mode_parses_and_defaults_sparse() {
        assert_eq!(ExecMode::parse("epoch"), Ok(ExecMode::Epoch));
        assert_eq!(ExecMode::parse("sparse"), Ok(ExecMode::Sparse));
        assert!(ExecMode::parse("fast").is_err());
        assert_eq!(ExecMode::default(), ExecMode::Sparse);
        assert_eq!(ExecMode::Epoch.label(), "epoch");
        assert_eq!(ExecOpts::default().mode, ExecMode::Sparse);
        assert_eq!(ExecOpts::default().threads, Parallelism::Auto);
        assert_eq!(ExecOpts::with_threads(Parallelism::Threads(2)).mode, ExecMode::Sparse);
    }

    #[test]
    fn exec_stats_ratio_and_json() {
        let mut s = ExecStats::new(ExecMode::Sparse);
        assert_eq!(s.elision_ratio(), 0.0);
        s.epochs = 25;
        s.barriers_elided = 75;
        s.arrivals_batched = 90;
        s.note_lookahead(1_500);
        s.note_lookahead(300);
        assert!((s.elision_ratio() - 0.75).abs() < 1e-12);
        assert_eq!(s.max_lookahead_us, 1_500);
        s.note_in_flight(40);
        s.note_in_flight(12);
        assert_eq!(s.peak_in_flight, 40);
        let j = s.to_json().to_string_compact();
        assert!(j.contains("\"mode\":\"sparse\""), "{j}");
        assert!(j.contains("\"barriers_elided\":75"), "{j}");
        assert!(j.contains("\"peak_in_flight\":40"), "{j}");
        assert!(s.render().contains("75%"), "{}", s.render());
    }

    #[test]
    fn touched_marks_dedups_and_clears_cheaply() {
        let mut t = Touched::new(4);
        t.mark(2);
        t.mark(2);
        t.mark(0);
        assert!(t.is(2) && t.is(0) && !t.is(1));
        assert_eq!(t.list(), &[2, 0]);
        t.clear();
        assert!(!t.is(2) && !t.is(0));
        assert!(t.list().is_empty());
    }

    #[test]
    fn exec_engine_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<ExecEngine>();
        assert_send::<Batch>();
    }

    // ---- candidate-index / frontier edge cases on a minimal driver ----

    /// Two-engine driver: model 0 → engine 0, model 1 → engine 1,
    /// model 2 → no replicas (always rejected). One optional surgery
    /// event mid-stream tombstones engine 1's model and re-routes its
    /// queue to engine 0 — a driver event that changes topology.
    struct MiniDriver {
        cand: Vec<Vec<usize>>,
        rejected: Vec<u64>,
        surgery_at: Option<Us>,
    }

    impl EpochDriver for MiniDriver {
        fn n_models(&self) -> usize {
            self.cand.len()
        }

        fn next_event(&self) -> Option<Us> {
            self.surgery_at
        }

        fn candidates_of(&self, model: usize) -> &[usize] {
            &self.cand[model]
        }

        fn route(
            &mut self,
            _t: Us,
            mut req: Request,
            engines: &mut [Option<ExecEngine>],
            touched: &mut Touched,
        ) {
            let m = req.model;
            let Some(&g) = self.cand[m].first() else {
                self.rejected[m] += 1;
                return;
            };
            req.model = 0; // every engine hosts exactly one local model
            engines[g].as_mut().expect("candidate engine").sim.inject(req);
            touched.mark(g);
        }

        fn post_arrivals(
            &mut self,
            t: Us,
            engines: &mut [Option<ExecEngine>],
            touched: &mut Touched,
        ) {
            if self.surgery_at != Some(t) {
                return;
            }
            self.surgery_at = None;
            // Tombstone engine 1's model; re-route its queue to engine 0.
            let drained = engines[1].as_mut().expect("engine 1").sim.deactivate_model(0);
            touched.mark(1);
            self.cand[1] = vec![0];
            for mut r in drained {
                r.model = 0;
                engines[0].as_mut().expect("engine 0").sim.inject(r);
                touched.mark(0);
            }
        }
    }

    fn mini_cluster() -> Vec<Option<ExecEngine>> {
        let profiles = vec![by_name("alexnet").unwrap()];
        (0..2)
            .map(|_| {
                let entries = entries_at_optimum(&profiles);
                let policy = GpuSched::Dstack.build(&entries);
                let sim = Sim::new(
                    SimConfig { horizon_ms: 100.0, ..Default::default() },
                    entries,
                );
                Some(ExecEngine { sim, policy })
            })
            .collect()
    }

    fn mini_stream() -> Vec<Request> {
        // Interleaved arrivals of all three models, several per instant.
        let mut reqs = Vec::new();
        let mut id = 0;
        for k in 0..40u64 {
            let t = 317 * k;
            for m in 0..3usize {
                if (k + m as u64) % 2 == 0 {
                    reqs.push(Request { id, model: m, arrival: t, deadline: t + 50_000 });
                    id += 1;
                }
            }
        }
        reqs
    }

    fn mini_run(mode: ExecMode, surgery: bool) -> (Vec<String>, Vec<u64>) {
        let mut engines = mini_cluster();
        let mut driver = MiniDriver {
            cand: vec![vec![0], vec![1], Vec::new()],
            rejected: vec![0; 3],
            surgery_at: surgery.then_some(6_000),
        };
        let horizon = 100_000;
        let stats = run_epochs(
            &mut engines,
            mini_stream(),
            horizon,
            ExecOpts { threads: Parallelism::Threads(1), mode, ..Default::default() },
            &mut driver,
        );
        assert_eq!(
            stats.requests_streamed,
            mini_stream().len() as u64,
            "every request must be pulled from the stream"
        );
        assert!(stats.peak_in_flight > 0);
        let reports: Vec<String> = engines
            .iter_mut()
            .map(|e| {
                let r = e.as_mut().unwrap().finalize(horizon);
                format!("{:?} {:?}", r.per_model[0].served, r.per_model[0].latencies_ms)
            })
            .collect();
        (reports, driver.rejected)
    }

    #[test]
    fn zero_replica_models_reject_identically_across_modes() {
        let (re, rj_e) = mini_run(ExecMode::Epoch, false);
        let (rs, rj_s) = mini_run(ExecMode::Sparse, false);
        assert_eq!(re, rs, "per-engine outcomes diverged");
        assert_eq!(rj_e, rj_s);
        assert!(rj_e[2] > 0, "model without replicas must reject");
        assert_eq!(rj_e[0], 0);
    }

    #[test]
    fn mid_stream_surgery_is_identical_across_modes() {
        let (re, rj_e) = mini_run(ExecMode::Epoch, true);
        let (rs, rj_s) = mini_run(ExecMode::Sparse, true);
        assert_eq!(re, rs, "surgery outcomes diverged between epoch and sparse");
        assert_eq!(rj_e, rj_s);
    }

    #[test]
    fn safe_until_takes_earliest_relevant_arrival() {
        let reqs = vec![
            Request { id: 0, model: 2, arrival: 400, deadline: 10_400 },
            Request { id: 1, model: 0, arrival: 900, deadline: 10_900 },
        ];
        let s = MaterializedStream::new(reqs, 3);
        // Hosts models 0 and 1 (1 has no pending arrivals).
        assert_eq!(safe_until(&[0, 1], &s, None, 10_000), 900);
        // A driver event before the arrival wins.
        assert_eq!(safe_until(&[0, 1], &s, Some(600), 10_000), 600);
        // Hosting nothing pending ⇒ horizon (or the driver event).
        assert_eq!(safe_until(&[1], &s, None, 10_000), 10_000);
        // Model 2 is not hosted here, so its earlier arrival is ignored.
        assert_eq!(safe_until(&[0], &s, None, 10_000), 900);
    }
}
