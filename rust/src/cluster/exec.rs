//! Shared cluster execution core: bulk-synchronous engine stepping with
//! deterministic barriers (the wall-clock backbone of every multi-GPU
//! driver — see DESIGN.md §4.7).
//!
//! The three cluster drivers ([`crate::cluster::run_placement`],
//! [`crate::controlplane::run_adaptive`],
//! [`crate::lifecycle::run_lifecycle`]) used to carry one hand-rolled
//! copy each of the same global-clock loop, stepping every per-GPU
//! engine on a single thread. The key structural fact they all share:
//! per-GPU execution is *independent between global interaction points*.
//! Only three things ever need a cluster-wide view:
//!
//! 1. **routing** — a request is dispatched against the live backlog of
//!    every candidate replica at its arrival instant;
//! 2. **control ticks** — the adaptive plane samples demand and may
//!    rebalance replicas across engines;
//! 3. **lifecycle events** — load maturities, pending replica
//!    activations and idle expiries mutate engine model tables.
//!
//! Everything else an engine does (batch completions, policy timers,
//! dispatch rounds) touches only its own state. So the core advances the
//! cluster in *epochs*: compute the next global barrier time (next
//! arrival, control tick, or lifecycle event), run the driver's serial
//! barrier work at it — which routes arrivals against engine backlogs
//! exactly as the serial loops did — then fan the per-engine stepping
//! out to a worker pool and let each engine replay its own internal
//! event sequence up to the *next* barrier, in parallel.
//!
//! # Determinism
//!
//! Thread count is not allowed to change results, byte for byte:
//!
//! - Barrier times depend only on the request stream and driver state,
//!   never on which thread stepped an engine.
//! - All cross-engine reads (backlog probes, rebalance surgery, idle
//!   sweeps) happen in the serial barrier phase, when every engine has
//!   processed exactly its events *strictly before* the barrier — the
//!   same state the serial loop exposed, because in that loop every
//!   engine-internal event was itself a global minimum and engines were
//!   stepped at their own event times.
//! - Between barriers each engine steps at its own event times in
//!   order, one [`Sim::step_to`] per event, exactly the call sequence
//!   the serial loop produced. Engines never share mutable state, so
//!   partitioning them over threads is pure scheduling.
//!
//! Hence a fixed (placement, routing, seed, stream) tuple yields an
//! identical `ClusterReport` JSON for `threads = 1` and `threads = N` —
//! the property `rust/tests/parallel_exec.rs` locks in for all three
//! drivers.
//!
//! # Worker pool
//!
//! No dependencies are reachable in the build image, so the pool is
//! plain `std`: scoped threads ([`std::thread::scope`]) that live for
//! the whole run, fed per-epoch batches over [`std::sync::mpsc`]
//! channels. Engines *move* into a batch and move back when the worker
//! returns it (ownership ping-pong), which keeps the pool 100% safe
//! code — no shared-mutability cells, no unsafe partitioning. Epochs
//! with fewer than `FANOUT_MIN` busy engines are stepped inline on
//! the driver thread: for small clusters the pool is pure bypass, and
//! `threads = 1` skips spawning entirely (the legacy serial path).

use crate::gpu::Us;
use crate::metrics::RunReport;
use crate::sim::{Policy, Sim};
use crate::workload::Request;
use std::collections::VecDeque;
use std::sync::mpsc::{channel, Receiver, Sender};

/// Engine-stepping thread budget for a cluster run — the `parallelism`
/// scenario knob and the CLI `--threads` flag (docs/CONFIG.md).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum Parallelism {
    /// One stepping lane per available core (the default).
    #[default]
    Auto,
    /// Exactly `n` lanes; `1` is the legacy serial path.
    Threads(usize),
}

impl Parallelism {
    /// Parse the config/CLI spelling: `"auto"` or an integer ≥ 1.
    pub fn parse(s: &str) -> Result<Parallelism, String> {
        if s == "auto" {
            return Ok(Parallelism::Auto);
        }
        match s.parse::<usize>() {
            Ok(n) if n >= 1 => Ok(Parallelism::Threads(n)),
            _ => Err(format!("parallelism must be \"auto\" or an integer >= 1, got '{s}'")),
        }
    }

    /// Number of stepping lanes this run may use (≥ 1).
    pub fn resolve(self) -> usize {
        match self {
            Parallelism::Auto => {
                std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
            }
            Parallelism::Threads(n) => n.max(1),
        }
    }

    /// Canonical config spelling (`"auto"` or the number).
    pub fn label(self) -> String {
        match self {
            Parallelism::Auto => "auto".to_string(),
            Parallelism::Threads(n) => n.to_string(),
        }
    }
}

/// One per-GPU engine: a [`Sim`] plus the policy driving it. Shared by
/// all cluster drivers; the control plane and the memory manager
/// additionally rebuild the policy after tombstone surgery
/// ([`Self::rebuild_policy`]).
pub(crate) struct ExecEngine {
    pub(crate) sim: Sim,
    pub(crate) policy: Box<dyn Policy>,
}

impl ExecEngine {
    fn step(&mut self, t: Us, horizon: Us) {
        self.sim.step_to(t, self.policy.as_mut(), horizon);
    }

    /// One engine's share of an epoch: finish the barrier time (when it
    /// was touched by routing/surgery or has an event due there), then
    /// replay its internal events strictly before the next barrier —
    /// each at its own timestamp, exactly as the serial global loop
    /// stepped it.
    fn advance(&mut self, step_now: bool, now: Us, drain_to: Us, horizon: Us) {
        if step_now {
            self.step(now, horizon);
        }
        while let Some(w) = self.sim.next_event_time() {
            if w >= drain_to {
                break;
            }
            self.step(w, horizon);
        }
    }

    /// Rebuild the per-GPU policy from the engine's current entry table,
    /// masking tombstones so retired models hold no plan capacity,
    /// slices or shares.
    pub(crate) fn rebuild_policy(&mut self, sched: super::GpuSched) {
        let mask = self.sim.active_mask();
        self.policy = sched.build_masked(&self.sim.models, &mask);
    }

    /// Horizon wrap-up under the engine's own policy name.
    pub(crate) fn finalize(&mut self, horizon: Us) -> RunReport {
        let name = self.policy.name();
        self.sim.finalize(name, horizon)
    }
}

/// Driver-specific half of an epoch: everything that needs the global
/// view, executed serially at each barrier. The core supplies the
/// arrival stream and the engine stepping; the driver supplies barrier
/// times of its own (ticks, load maturities, …) and the barrier work.
pub(crate) trait EpochDriver {
    /// Earliest pending driver event (control tick, pending activation,
    /// load maturity, idle expiry). `None` when only arrivals remain.
    fn next_event(&self) -> Option<Us>;

    /// Barrier work before arrivals are routed (mature loads/activations
    /// due at `t`). Mark engines whose tables changed in `touched`.
    fn pre_arrivals(
        &mut self,
        _t: Us,
        _engines: &mut [Option<ExecEngine>],
        _touched: &mut [bool],
    ) {
    }

    /// Route one arrival at `t` (reads live backlogs, injects, marks
    /// `touched`). Requests arrive owned: injection moves them.
    fn route(
        &mut self,
        t: Us,
        req: Request,
        engines: &mut [Option<ExecEngine>],
        touched: &mut [bool],
    );

    /// Barrier work after arrivals (control ticks, idle sweeps).
    fn post_arrivals(
        &mut self,
        _t: Us,
        _engines: &mut [Option<ExecEngine>],
        _touched: &mut [bool],
    ) {
    }
}

/// One epoch's worth of engine stepping shipped to a worker: the
/// engines move in, are advanced, and move back.
struct Batch {
    /// (engine slot, engine, step-at-barrier?).
    items: Vec<(usize, ExecEngine, bool)>,
    now: Us,
    drain_to: Us,
    horizon: Us,
}

struct Worker {
    cmd: Sender<Batch>,
    ret: Receiver<Batch>,
}

struct Pool {
    workers: Vec<Worker>,
}

/// Below this many busy engines an epoch is stepped inline: the fan-out
/// overhead (one channel round-trip per worker) only pays for itself
/// when several engines have real work between barriers.
const FANOUT_MIN: usize = 4;

/// Drive `engines` over `requests` to `horizon` under `driver`,
/// advancing in bulk-synchronous epochs with up to `threads` stepping
/// lanes. The stream is cloned once into a work queue up front so every
/// injection *moves* a request instead of cloning it.
pub(crate) fn run_epochs<D: EpochDriver>(
    engines: &mut [Option<ExecEngine>],
    requests: &[Request],
    horizon: Us,
    threads: Parallelism,
    driver: &mut D,
) {
    // More lanes than engines can never help: each engine is stepped by
    // exactly one lane per epoch. Capping here also bounds the spawn
    // count for arbitrary user-supplied `--threads` values. Clusters
    // too small to ever clear the fan-out threshold skip the pool
    // entirely — no spawns, no channels, pure serial path.
    let lanes = threads.resolve().min(engines.len());
    let mut queue: VecDeque<Request> = requests.to_vec().into();
    if lanes <= 1 || engines.len() < FANOUT_MIN {
        epoch_loop(engines, &mut queue, horizon, driver, None);
        return;
    }
    std::thread::scope(|s| {
        // `lanes - 1` workers; the driver thread is the remaining lane.
        let mut workers = Vec::with_capacity(lanes - 1);
        for _ in 0..lanes - 1 {
            let (cmd_tx, cmd_rx) = channel::<Batch>();
            let (ret_tx, ret_rx) = channel::<Batch>();
            s.spawn(move || {
                while let Ok(mut b) = cmd_rx.recv() {
                    for (_, e, step_now) in b.items.iter_mut() {
                        e.advance(*step_now, b.now, b.drain_to, b.horizon);
                    }
                    if ret_tx.send(b).is_err() {
                        break;
                    }
                }
            });
            workers.push(Worker { cmd: cmd_tx, ret: ret_rx });
        }
        let mut pool = Pool { workers };
        epoch_loop(engines, &mut queue, horizon, driver, Some(&mut pool));
        // Dropping the pool's senders ends the workers; the scope joins.
    });
}

fn epoch_loop<D: EpochDriver>(
    engines: &mut [Option<ExecEngine>],
    queue: &mut VecDeque<Request>,
    horizon: Us,
    driver: &mut D,
    mut pool: Option<&mut Pool>,
) {
    let mut touched = vec![false; engines.len()];
    // Scratch for advance_phase, reused across epochs (capacity is
    // bounded by the engine count; un-quantized streams barrier at
    // every arrival, so this would otherwise allocate per request).
    let mut work: Vec<(usize, bool)> = Vec::with_capacity(engines.len());
    loop {
        let t_arr = queue.front().map(|r| r.arrival);
        let t_drv = driver.next_event();
        let Some(t) = [t_arr, t_drv].into_iter().flatten().min() else { break };
        if t >= horizon {
            break;
        }
        touched.fill(false);
        driver.pre_arrivals(t, engines, &mut touched);
        while queue.front().is_some_and(|r| r.arrival <= t) {
            let r = queue.pop_front().expect("checked front");
            driver.route(t, r, engines, &mut touched);
        }
        driver.post_arrivals(t, engines, &mut touched);
        // The next barrier is known now — arrivals and driver events
        // only change during serial phases — so engines can run ahead
        // to it without any cross-engine coordination.
        let drain_to = [queue.front().map(|r| r.arrival), driver.next_event()]
            .into_iter()
            .flatten()
            .min()
            .unwrap_or(horizon)
            .min(horizon);
        advance_phase(engines, &touched, &mut work, t, drain_to, horizon, pool.as_deref_mut());
    }
    // Tail drain: no barriers remain, but engines may still hold events
    // inside the horizon (the serial loops processed exactly those).
    touched.fill(false);
    advance_phase(engines, &touched, &mut work, 0, horizon, horizon, pool.as_deref_mut());
}

/// Step every engine with work in `[now, drain_to)`, fanning out to the
/// pool when enough of them are busy. `work` is caller-owned scratch.
#[allow(clippy::too_many_arguments)]
fn advance_phase(
    engines: &mut [Option<ExecEngine>],
    touched: &[bool],
    work: &mut Vec<(usize, bool)>,
    now: Us,
    drain_to: Us,
    horizon: Us,
    pool: Option<&mut Pool>,
) {
    work.clear();
    for (g, slot) in engines.iter().enumerate() {
        let Some(e) = slot.as_ref() else { continue };
        let w = e.sim.next_event_time();
        let step_now = touched[g] || w.is_some_and(|w| w <= now);
        if step_now || w.is_some_and(|w| w < drain_to) {
            work.push((g, step_now));
        }
    }
    match pool {
        Some(pool) if work.len() >= FANOUT_MIN => {
            fan_out(pool, engines, work, now, drain_to, horizon);
        }
        _ => {
            for &(g, step_now) in work.iter() {
                engines[g]
                    .as_mut()
                    .expect("busy engine vanished")
                    .advance(step_now, now, drain_to, horizon);
            }
        }
    }
}

fn fan_out(
    pool: &mut Pool,
    engines: &mut [Option<ExecEngine>],
    work: &[(usize, bool)],
    now: Us,
    drain_to: Us,
    horizon: Us,
) {
    let lanes = pool.workers.len() + 1;
    let mut batches: Vec<Vec<(usize, ExecEngine, bool)>> =
        (0..lanes).map(|_| Vec::new()).collect();
    for (i, &(g, step_now)) in work.iter().enumerate() {
        let e = engines[g].take().expect("busy engine vanished");
        batches[i % lanes].push((g, e, step_now));
    }
    let mut mine = batches.swap_remove(0);
    let mut sent: Vec<usize> = Vec::new();
    for (wi, items) in batches.into_iter().enumerate() {
        if items.is_empty() {
            continue;
        }
        pool.workers[wi]
            .cmd
            .send(Batch { items, now, drain_to, horizon })
            .expect("exec worker hung up");
        sent.push(wi);
    }
    for (_, e, step_now) in mine.iter_mut() {
        e.advance(*step_now, now, drain_to, horizon);
    }
    for (g, e, _) in mine {
        engines[g] = Some(e);
    }
    for wi in sent {
        let b = pool.workers[wi].ret.recv().expect("exec worker died");
        for (g, e, _) in b.items {
            engines[g] = Some(e);
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parallelism_parses_and_resolves() {
        assert_eq!(Parallelism::parse("auto"), Ok(Parallelism::Auto));
        assert_eq!(Parallelism::parse("1"), Ok(Parallelism::Threads(1)));
        assert_eq!(Parallelism::parse("8"), Ok(Parallelism::Threads(8)));
        assert!(Parallelism::parse("0").is_err());
        assert!(Parallelism::parse("-2").is_err());
        assert!(Parallelism::parse("fast").is_err());
        assert_eq!(Parallelism::Threads(3).resolve(), 3);
        assert!(Parallelism::Auto.resolve() >= 1);
        assert_eq!(Parallelism::Auto.label(), "auto");
        assert_eq!(Parallelism::Threads(4).label(), "4");
        assert_eq!(Parallelism::default(), Parallelism::Auto);
    }

    #[test]
    fn exec_engine_is_send() {
        fn assert_send<T: Send>() {}
        assert_send::<ExecEngine>();
        assert_send::<Batch>();
    }
}
