//! Load-aware request routing across a model's replicas.
//!
//! Replaces the old up-front round-robin stream split: the cluster
//! driver routes each request *at its arrival instant*, so load-aware
//! policies can react to the actual queue state of every replica. All
//! three policies are deterministic under a fixed seed, which keeps
//! whole-cluster runs bit-reproducible.
//!
//! The [`Router`] is deliberately stateless about *which* replicas
//! exist: callers pass the current replica slice on every call, so the
//! adaptive control plane ([`crate::controlplane`]) can grow and shrink
//! a model's replica set mid-run — round-robin cursors simply wrap
//! modulo the new length, and the load-aware policies sample whatever
//! backlogs the live set exposes.
//!
//! The `backlog` closure is a *cost*, not literally a queue length:
//! the lifecycle driver ([`crate::lifecycle`]) implements
//! warmness-aware routing by folding a cold-start penalty (the items a
//! replica could have served during its remaining model-load time) into
//! the closure, which makes JSQ/P2C tie-break toward warm replicas with
//! no router changes.

use super::exec::ExecEngine;
use super::placement::Replica;
use crate::util::rng::Pcg32;
use std::collections::HashMap;

/// Replica-selection discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutingPolicy {
    /// Cycle through replicas per model (the paper's §7.1 stream split,
    /// now applied online).
    RoundRobin,
    /// Join-shortest-queue on items queued + in flight at each replica.
    JoinShortestQueue,
    /// Power-of-two-choices: sample two distinct replicas, take the
    /// shorter queue — near-JSQ balance at O(1) state inspection.
    PowerOfTwoChoices,
}

impl RoutingPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            RoutingPolicy::RoundRobin => "rr",
            RoutingPolicy::JoinShortestQueue => "jsq",
            RoutingPolicy::PowerOfTwoChoices => "p2c",
        }
    }

    pub fn parse(s: &str) -> Result<RoutingPolicy, String> {
        Ok(match s {
            "rr" | "round_robin" => RoutingPolicy::RoundRobin,
            "jsq" | "join_shortest_queue" => RoutingPolicy::JoinShortestQueue,
            "p2c" | "power_of_two" | "power_of_two_choices" => RoutingPolicy::PowerOfTwoChoices,
            other => return Err(format!("unknown routing policy '{other}'")),
        })
    }

    pub fn all() -> &'static [RoutingPolicy] {
        &[
            RoutingPolicy::RoundRobin,
            RoutingPolicy::JoinShortestQueue,
            RoutingPolicy::PowerOfTwoChoices,
        ]
    }

    /// Does picking a replica read live engine backlogs? Round-robin
    /// never does — its decisions are pure router state — which is what
    /// lets the sparse execution core ([`crate::cluster::exec`]) elide
    /// stepping barriers and batch whole arrival spans into one
    /// injection round for RR-routed streams.
    pub fn reads_backlogs(&self) -> bool {
        !matches!(self, RoutingPolicy::RoundRobin)
    }
}

/// Per-run router state (round-robin counters, P2C sampling stream).
pub struct Router {
    policy: RoutingPolicy,
    rr: Vec<usize>,
    rng: Pcg32,
}

impl Router {
    pub fn new(policy: RoutingPolicy, n_models: usize, seed: u64) -> Router {
        Router { policy, rr: vec![0; n_models], rng: Pcg32::new(seed, 0x70C) }
    }

    pub fn policy(&self) -> RoutingPolicy {
        self.policy
    }

    /// Pick the index (into `replicas`) that the next request of `model`
    /// goes to. `backlog` reports items queued + in flight at a replica;
    /// ties always resolve to the lowest replica index (determinism).
    pub fn route(
        &mut self,
        model: usize,
        replicas: &[Replica],
        mut backlog: impl FnMut(&Replica) -> usize,
    ) -> usize {
        assert!(!replicas.is_empty(), "routing model {model} with no replicas");
        match self.policy {
            RoutingPolicy::RoundRobin => {
                let i = self.rr[model] % replicas.len();
                self.rr[model] += 1;
                i
            }
            RoutingPolicy::JoinShortestQueue => (0..replicas.len())
                .min_by_key(|&i| (backlog(&replicas[i]), i))
                .expect("non-empty replicas"),
            RoutingPolicy::PowerOfTwoChoices => {
                let n = replicas.len();
                if n == 1 {
                    return 0;
                }
                let a = self.rng.usize_below(n);
                let mut b = self.rng.usize_below(n - 1);
                if b >= a {
                    b += 1;
                }
                let (qa, qb) = (backlog(&replicas[a]), backlog(&replicas[b]));
                if qb < qa || (qb == qa && b < a) {
                    b
                } else {
                    a
                }
            }
        }
    }
}

/// Memoizes [`crate::sim::Sim::backlog_items`] probes within one
/// routing round (one barrier of the execution core). JSQ and P2C probe
/// the same engines once per candidate per request, and a barrier can
/// route dozens of requests; the probe walks the engine's running set,
/// so re-probing is the routing hot path. Within a round a replica's
/// backlog only changes through this module's own actions — an
/// injection adds one item ([`Self::note_inject`]), tombstone surgery
/// drains a queue ([`Self::invalidate`]) — so the memo can be kept
/// exactly in sync with the live value and the cached round is
/// byte-identical to a re-probing one.
#[derive(Default)]
pub(crate) struct BacklogCache {
    /// (gpu, engine-local model) → items queued + in flight.
    map: HashMap<(usize, usize), usize>,
}

impl BacklogCache {
    /// Start a new routing round (call at every barrier).
    pub(crate) fn reset(&mut self) {
        self.map.clear();
    }

    /// The replica's backlog: cached, or probed from the live engine on
    /// first use. Idle GPUs report `usize::MAX` (never preferred), as
    /// the uncached probes did.
    pub(crate) fn backlog(&mut self, engines: &[Option<ExecEngine>], rep: &Replica) -> usize {
        *self.map.entry((rep.gpu, rep.local)).or_insert_with(|| {
            engines[rep.gpu].as_ref().map_or(usize::MAX, |e| e.sim.backlog_items(rep.local))
        })
    }

    /// Keep a cached entry in sync with an injection into that replica.
    pub(crate) fn note_inject(&mut self, gpu: usize, local: usize) {
        if let Some(v) = self.map.get_mut(&(gpu, local)) {
            *v = v.saturating_add(1);
        }
    }

    /// Drop a cached entry whose queue was mutated out of band
    /// (eviction / rebalance surgery drained it): the next probe
    /// re-reads the live engine.
    pub(crate) fn invalidate(&mut self, gpu: usize, local: usize) {
        self.map.remove(&(gpu, local));
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn replicas(n: usize) -> Vec<Replica> {
        (0..n)
            .map(|g| Replica { gpu: g, local: 0, pct: 40, batch: 16, capacity_rps: 100.0 })
            .collect()
    }

    #[test]
    fn backlog_cache_stays_in_sync_with_live_engine() {
        use crate::profile::by_name;
        use crate::sim::{entries_at_optimum, Sim, SimConfig};
        use crate::workload::Request;
        let entries = entries_at_optimum(&[by_name("alexnet").unwrap()]);
        let policy = super::super::GpuSched::Dstack.build(&entries);
        let sim = Sim::new(SimConfig::default(), entries);
        let mut engines = vec![Some(ExecEngine { sim, policy }), None];
        let rep = Replica { gpu: 0, local: 0, pct: 40, batch: 16, capacity_rps: 100.0 };
        let mut cache = BacklogCache::default();
        assert_eq!(cache.backlog(&engines, &rep), 0);
        // Injection keeps the memo equal to the live probe.
        engines[0]
            .as_mut()
            .unwrap()
            .sim
            .inject(Request { id: 0, model: 0, arrival: 0, deadline: 1_000 });
        cache.note_inject(0, 0);
        assert_eq!(cache.backlog(&engines, &rep), 1);
        assert_eq!(engines[0].as_ref().unwrap().sim.backlog_items(0), 1);
        // Invalidation and reset both fall back to a fresh probe.
        cache.invalidate(0, 0);
        assert_eq!(cache.backlog(&engines, &rep), 1);
        cache.reset();
        assert_eq!(cache.backlog(&engines, &rep), 1);
        // Idle GPUs are never preferred.
        let idle = Replica { gpu: 1, local: 0, pct: 40, batch: 16, capacity_rps: 100.0 };
        assert_eq!(cache.backlog(&engines, &idle), usize::MAX);
    }

    #[test]
    fn only_round_robin_is_backlog_free() {
        assert!(!RoutingPolicy::RoundRobin.reads_backlogs());
        assert!(RoutingPolicy::JoinShortestQueue.reads_backlogs());
        assert!(RoutingPolicy::PowerOfTwoChoices.reads_backlogs());
    }

    #[test]
    fn round_robin_cycles_per_model() {
        let reps = replicas(3);
        let mut r = Router::new(RoutingPolicy::RoundRobin, 2, 1);
        let picks: Vec<usize> = (0..6).map(|_| r.route(0, &reps, |_| 0)).collect();
        assert_eq!(picks, vec![0, 1, 2, 0, 1, 2]);
        // Model 1 has its own counter.
        assert_eq!(r.route(1, &reps, |_| 0), 0);
    }

    #[test]
    fn jsq_takes_shortest_with_stable_ties() {
        let reps = replicas(3);
        let mut r = Router::new(RoutingPolicy::JoinShortestQueue, 1, 1);
        let loads = [5usize, 2, 9];
        assert_eq!(r.route(0, &reps, |rep| loads[rep.gpu]), 1);
        // All-equal backlog → lowest index.
        assert_eq!(r.route(0, &reps, |_| 4), 0);
    }

    #[test]
    fn p2c_prefers_lighter_of_its_pair_and_is_deterministic() {
        let reps = replicas(4);
        let loads = [0usize, 100, 100, 100];
        let run = |seed: u64| -> Vec<usize> {
            let mut r = Router::new(RoutingPolicy::PowerOfTwoChoices, 1, seed);
            (0..64).map(|_| r.route(0, &reps, |rep| loads[rep.gpu])).collect()
        };
        let a = run(9);
        assert_eq!(a, run(9), "same seed, same choices");
        // Whenever replica 0 is in the sampled pair it must win; it is
        // sampled in a pair with probability 1/2 per request.
        let zero = a.iter().filter(|&&p| p == 0).count();
        assert!(zero > 16, "p2c barely found the idle replica: {zero}/64");
        // Single replica short-circuits.
        let one = replicas(1);
        let mut r = Router::new(RoutingPolicy::PowerOfTwoChoices, 1, 3);
        assert_eq!(r.route(0, &one, |_| 42), 0);
    }
}
