//! Multi-GPU cluster serving: placement, load-aware routing, admission
//! control (§7.1, Fig. 12, generalized).
//!
//! The paper evaluates a 4×T4 cluster with three fixed layouts and an
//! up-front round-robin stream split. This module turns that into a real
//! cluster subsystem (DESIGN.md §4):
//!
//! - [`placement`] bin-packs models onto (possibly heterogeneous) GPUs
//!   by their per-GPU-type knee GPU%, replicating hot models and
//!   rejecting what the cluster cannot host;
//! - [`routing`] dispatches each request to a replica at its arrival
//!   instant — round-robin, join-shortest-queue or power-of-two-choices
//!   — against the live backlog of every per-GPU engine;
//! - [`run_placement`] drives one [`crate::sim::Sim`] engine per GPU in
//!   a single global virtual clock, feeding them *routed* requests
//!   instead of pre-split streams, and aggregates a [`ClusterReport`]
//!   with per-GPU packing, per-model replica map, reject/shed counts and
//!   p99 latency per model;
//! - [`exec`] is the execution core all three cluster drivers (this
//!   module, [`crate::controlplane`], [`crate::lifecycle`]) run on:
//!   bulk-synchronous epochs whose barriers are the routing/control
//!   instants, with per-GPU engine stepping fanned out to a worker pool
//!   ([`Parallelism`], the `--threads` flag) — byte-identical results
//!   for any thread count.
//!
//! The paper's fixed scenarios ([`ClusterPolicy`]) are retained as thin
//! layouts over the same engine: every GPU runs an independent scheduler
//! instance (per-GPU D-STACK schedulers, cluster-level placement), and
//! with round-robin routing the arrival-order splits are identical to
//! the old up-front split.
//!
//! Placement here is solved once, at t = 0. The adaptive control plane
//! ([`crate::controlplane`]) layers runtime re-optimization on top:
//! it re-runs [`placement::place`] against EWMA rate estimates when a
//! drift detector fires and migrates replicas incrementally, reusing
//! this module's engine/routing machinery unchanged.

pub mod exec;
pub mod placement;
pub mod routing;

pub use exec::{ExecMode, ExecOpts, ExecStats, Parallelism};
pub use placement::{
    op_point, place, plan_residency, plan_residency_biased, Placement, PlacementPolicy, Replica,
    ResidencyPlan,
};
pub use routing::{Router, RoutingPolicy};

use crate::faults::{
    pick_hedge_target, queue_est_us, FaultKind, Resilience, ResilienceCfg, ResilienceStats,
    SloClass,
};
use crate::gpu::{ms_to_us, Us};
use crate::overload::{co_locate_variants, Overload, OverloadSpec, OverloadStats, RejectKind};
use crate::metrics::RunReport;
use crate::obs::{EngineObs, EventKind, ObsReport, Recorder, NO_MODEL};
use crate::profile::{GpuSpec, ModelProfile};
use crate::sched::{dstack::Dstack, gslice::Gslice, temporal::Temporal, triton::Triton};
use crate::sim::{ModelEntry, Policy, Sim, SimConfig};
use crate::util::json::Json;
use crate::util::stats::{percentile, LogHistogram};
use crate::workload::{ArrivalStream, Arrivals, MaterializedStream, Request};
use exec::{run_epochs_stream, EpochDriver, ExecEngine, Touched};
use routing::BacklogCache;

/// Which scheduler runs on each GPU of the cluster.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum GpuSched {
    Dstack,
    Temporal,
    Triton,
    Gslice,
}

impl GpuSched {
    pub fn name(&self) -> &'static str {
        match self {
            GpuSched::Dstack => "dstack",
            GpuSched::Temporal => "temporal",
            GpuSched::Triton => "triton",
            GpuSched::Gslice => "gslice",
        }
    }

    pub fn parse(s: &str) -> Result<GpuSched, String> {
        Ok(match s {
            "dstack" => GpuSched::Dstack,
            "temporal" => GpuSched::Temporal,
            "triton" => GpuSched::Triton,
            "gslice" => GpuSched::Gslice,
            other => return Err(format!("unknown per-GPU scheduler '{other}'")),
        })
    }

    /// Instantiate the per-GPU policy over an engine's entry table.
    /// `active` masks control-plane tombstones (see
    /// [`crate::controlplane`]); static paths pass all-true.
    pub(crate) fn build_masked(
        &self,
        entries: &[ModelEntry],
        active: &[bool],
    ) -> Box<dyn Policy> {
        match self {
            GpuSched::Dstack => Box::new(Dstack::from_entries(entries)),
            GpuSched::Temporal => Box::new(Temporal::from_entries(entries)),
            GpuSched::Triton => Box::new(Triton::from_entries(entries)),
            GpuSched::Gslice => Box::new(Gslice::from_entries_masked(entries, active)),
        }
    }

    pub(crate) fn build(&self, entries: &[ModelEntry]) -> Box<dyn Policy> {
        self.build_masked(entries, &vec![true; entries.len()])
    }
}

/// Legacy cluster-level strategy (the paper's three Fig. 12 scenarios).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterPolicy {
    /// One GPU per model, dynamic batching at 100% GPU (a dedicated
    /// serving instance per model — the paper's first scenario).
    Exclusive,
    /// Every model on every GPU, temporal sharing.
    TemporalAll,
    /// Every model on every GPU, D-STACK.
    DstackAll,
}

/// Per-model share of one GPU's packing (reported, not prescriptive).
#[derive(Debug, Clone)]
pub struct GpuModelShare {
    /// Global model index.
    pub model: usize,
    /// Deployed GPU% of this replica.
    pub pct: u32,
    /// Deployed batch size.
    pub batch: u32,
    /// Requests this replica served.
    pub served: u64,
}

/// One GPU's slice of the cluster report.
#[derive(Debug, Clone)]
pub struct GpuReport {
    /// GPU type name (e.g. "V100").
    pub gpu: String,
    /// Σ placed knee GPU% on this device.
    pub knee_load_pct: u32,
    /// Mean utilization over the horizon, 0..1.
    pub utilization: f64,
    pub models: Vec<GpuModelShare>,
}

/// Aggregated cluster run: cluster-wide per-model outcomes plus the
/// packing that produced them.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub policy: String,
    /// Per-model served requests/s across the cluster.
    pub throughput: Vec<f64>,
    /// Per-GPU utilization.
    pub gpu_utilization: Vec<f64>,
    /// Per-model SLO violations/s across the cluster (late + unserved +
    /// admission-rejected).
    pub violations_per_sec: Vec<f64>,
    /// Per-model p99 end-to-end latency (ms) over all replicas.
    pub p99_ms: Vec<f64>,
    /// Per-model served / still-queued-at-horizon / admission-rejected
    /// request counts. Conservation: served + dropped + rejected equals
    /// the offered stream per model.
    pub served: Vec<u64>,
    pub dropped: Vec<u64>,
    pub rejected: Vec<u64>,
    /// model → GPUs hosting a replica.
    pub replica_map: Vec<Vec<usize>>,
    /// Offered rate the placement could not cover (req/s per model).
    pub shed_rps: Vec<f64>,
    pub admitted: Vec<bool>,
    pub per_gpu: Vec<GpuReport>,
    /// Control-plane telemetry — `Some` only for adaptive runs
    /// ([`crate::controlplane::run_adaptive`]); static reports serialize
    /// without the field, so their golden JSON is unchanged.
    pub adaptive: Option<crate::controlplane::AdaptiveStats>,
    /// Memory-manager telemetry — `Some` only for lifecycle runs
    /// ([`crate::lifecycle::run_lifecycle`]); serialized only when
    /// present, so static and adaptive golden shapes are unchanged.
    pub lifecycle: Option<crate::lifecycle::LifecycleStats>,
    /// Fault-injection / front-door telemetry ([`crate::faults`]) —
    /// `Some` only when a `"faults"` config is active; serialized only
    /// when present, so every pre-existing golden shape is unchanged.
    pub resilience: Option<ResilienceStats>,
    /// Overload-control telemetry ([`crate::overload`]: retries,
    /// breakers, brownout) — `Some` only when an `"overload"` config is
    /// active; serialized only when present, so every pre-existing
    /// report and golden byte is unchanged.
    pub overload: Option<OverloadStats>,
    /// Execution-core telemetry (barriers run/elided, lookahead).
    /// **Never serialized** by [`Self::to_json`]: `exec_mode` and
    /// thread count must not change report bytes. Surfaced by
    /// `dstack … --verbose` and by `benches/bench_parallel.rs`.
    pub exec: Option<ExecStats>,
    /// Observability payload (event trace + windowed time-series) —
    /// `Some` only when `ExecOpts::obs` enables recording. Like `exec`,
    /// **never serialized** by [`Self::to_json`]: traces and series are
    /// exported out-of-band (`--emit-trace` / `--emit-timeseries`,
    /// `figures::fig17`), so report and golden bytes are unchanged.
    pub obs: Option<ObsReport>,
}

impl ClusterReport {
    pub fn total_throughput(&self) -> f64 {
        self.throughput.iter().sum()
    }

    pub fn mean_utilization(&self) -> f64 {
        self.gpu_utilization.iter().sum::<f64>() / self.gpu_utilization.len().max(1) as f64
    }

    /// Deterministic JSON form (golden-trace tests, tooling).
    pub fn to_json(&self) -> Json {
        let per_gpu: Vec<Json> = self
            .per_gpu
            .iter()
            .map(|g| {
                let models: Vec<Json> = g
                    .models
                    .iter()
                    .map(|s| {
                        Json::obj(vec![
                            ("model", Json::from(s.model)),
                            ("pct", Json::from(s.pct)),
                            ("batch", Json::from(s.batch)),
                            ("served", Json::from(s.served)),
                        ])
                    })
                    .collect();
                Json::obj(vec![
                    ("gpu", Json::from(g.gpu.as_str())),
                    ("knee_load_pct", Json::from(g.knee_load_pct)),
                    ("utilization", Json::from(g.utilization)),
                    ("models", Json::Arr(models)),
                ])
            })
            .collect();
        let replica_map: Vec<Json> = self
            .replica_map
            .iter()
            .map(|gpus| Json::Arr(gpus.iter().map(|&g| Json::from(g)).collect()))
            .collect();
        let mut pairs = vec![
            ("policy", Json::from(self.policy.as_str())),
            ("throughput", Json::arr_f64(&self.throughput)),
            ("gpu_utilization", Json::arr_f64(&self.gpu_utilization)),
            ("violations_per_sec", Json::arr_f64(&self.violations_per_sec)),
            ("p99_ms", Json::arr_f64(&self.p99_ms)),
            ("served", Json::Arr(self.served.iter().map(|&v| Json::from(v)).collect())),
            ("dropped", Json::Arr(self.dropped.iter().map(|&v| Json::from(v)).collect())),
            ("rejected", Json::Arr(self.rejected.iter().map(|&v| Json::from(v)).collect())),
            ("replica_map", Json::Arr(replica_map)),
            ("shed_rps", Json::arr_f64(&self.shed_rps)),
            (
                "admitted",
                Json::Arr(self.admitted.iter().map(|&b| Json::from(b)).collect()),
            ),
            ("per_gpu", Json::Arr(per_gpu)),
        ];
        if let Some(stats) = &self.adaptive {
            pairs.push(("adaptive", stats.to_json()));
        }
        if let Some(stats) = &self.lifecycle {
            pairs.push(("lifecycle", stats.to_json()));
        }
        if let Some(stats) = &self.resilience {
            pairs.push(("resilience", stats.to_json()));
        }
        if let Some(stats) = &self.overload {
            pairs.push(("overload", stats.to_json()));
        }
        Json::obj(pairs)
    }
}

/// The seeded Fig. 12 cluster workload (profiles, offered rates, merged
/// request stream) — the one workload every cluster experiment, bench
/// and acceptance comparison runs, built from
/// [`crate::workload::fig12_rates`] so the mix lives in one place.
pub fn fig12_workload(
    horizon_ms: f64,
    seed: u64,
) -> (Vec<ModelProfile>, Vec<f64>, Vec<Request>) {
    use crate::workload::merged_stream;
    let (profiles, rates, specs) = fig12_specs();
    let reqs = merged_stream(&specs, horizon_ms, seed);
    (profiles, rates, reqs)
}

/// The Fig. 12 workload's arrival *specs* (profiles, offered rates,
/// per-model `(process, slo_ms)` pairs) — what
/// [`crate::workload::MergedStream`] turns into a lazy stream; the
/// streamed leg of the equivalence matrix and `bench_streaming` build
/// from these so the mix stays byte-identical to [`fig12_workload`].
pub fn fig12_specs() -> (Vec<ModelProfile>, Vec<f64>, Vec<(Arrivals, f64)>) {
    use crate::workload::fig12_rates;
    let spec = fig12_rates();
    let profiles: Vec<ModelProfile> = spec
        .iter()
        .map(|(n, _)| crate::profile::by_name(n).expect("fig12 model in zoo"))
        .collect();
    let rates: Vec<f64> = spec.iter().map(|&(_, r)| r).collect();
    let specs: Vec<(Arrivals, f64)> = profiles
        .iter()
        .zip(&rates)
        .map(|(p, &r)| (Arrivals::Poisson { rate: r }, p.slo_ms))
        .collect();
    (profiles, rates, specs)
}

/// Operating points recomputed for a cluster's GPU type (knees differ
/// between V100 and T4 — §7.1).
pub fn entries_for_gpu(profiles: &[ModelProfile], gpu: &GpuSpec) -> Vec<ModelEntry> {
    profiles
        .iter()
        .map(|p| {
            let (pct, batch, _) = op_point(p, gpu);
            ModelEntry { profile: p.clone(), pct, batch }
        })
        .collect()
}

/// The static driver's barrier work: admission, routing, injection.
/// Placement never changes mid-run, so without fault injection there
/// are no driver events and no pre/post barrier phases — every barrier
/// is an arrival instant, the candidate index is fixed (`cand[m]` =
/// GPUs hosting a replica of `m`), and RR-routed runs elide stepping
/// barriers entirely. With a fault timeline attached
/// ([`crate::faults::Resilience`]), fault applications, restore
/// maturities and hedge sweeps become driver events — global barriers
/// in sparse mode — and barrier elision is off (the front door probes
/// backlogs and queue ages).
struct PlacementDriver<'a> {
    pl: &'a Placement,
    /// Global profile table (cold `load_ms` for failure recovery).
    profiles: &'a [ModelProfile],
    sched: GpuSched,
    /// model → hosting GPUs (the sparse core's candidate index).
    cand: Vec<Vec<usize>>,
    router: Router,
    cache: BacklogCache,
    rejected: Vec<u64>,
    /// Fault timeline + front-door state — `None` for plain runs, in
    /// which case every hook below is pass-through.
    res: Option<Resilience>,
    /// Overload layer (retry/breaker/brownout) — `None` keeps the
    /// dispatch path byte-identical to the pre-overload code.
    ovl: Option<Overload>,
    /// Control-lane recorder: arrive/route/reject, by global model.
    obs: Recorder,
}

impl PlacementDriver<'_> {
    /// Admission + health filter + routing + injection for one request
    /// (`req.model` is global). `rerouted` marks failure-cascade
    /// re-dispatches: they skip deadline admission (admitted once
    /// already) and count into `rerouted_on_failure` on success.
    fn dispatch_one(
        &mut self,
        t: Us,
        mut req: Request,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
        rerouted: bool,
    ) {
        let m = req.model;
        let all = &self.pl.replicas[m];
        // The filtered clone is only built while an engine is actually
        // unroutable; the no-fault path routes the shared slice as
        // before (zero allocation, identical picks and bytes).
        let filtered: Vec<Replica>;
        let reps: &[Replica] = match &self.res {
            Some(res) if res.any_unroutable() => {
                filtered = all.iter().filter(|r| res.routable(r.gpu)).cloned().collect();
                &filtered
            }
            _ => all,
        };
        if reps.is_empty() {
            // Zero-routable window: every replica down/draining. Typed
            // reject instead of a silent hold-until-horizon drop.
            self.rejected[m] += 1;
            if let Some(res) = &mut self.res {
                res.note_unroutable();
            }
            if self.obs.on() {
                self.obs.event(EventKind::Reject, t, m as u32, req.id, 0);
            }
            return;
        }
        let cache = &mut self.cache;
        let res = self.res.as_ref();
        if !rerouted && res.is_some_and(|r| r.cfg.admission) {
            // Deadline-aware admission: best-case queue+batch estimate
            // across the routable replicas vs the remaining budget.
            let best = reps
                .iter()
                .map(|rep| {
                    let load = cache
                        .backlog(engines, rep)
                        .saturating_add(res.map_or(0, |r| r.penalty_items(rep.gpu)));
                    queue_est_us(load, rep.batch, rep.capacity_rps)
                })
                .min()
                .unwrap_or(Us::MAX);
            if t.saturating_add(best) > req.deadline {
                self.rejected[m] += 1;
                if let Some(res) = &mut self.res {
                    res.note_deadline_reject(m);
                }
                if self.obs.on() {
                    self.obs.event(EventKind::Reject, t, m as u32, req.id, 0);
                }
                return;
            }
        }
        let res = self.res.as_ref();
        let cache = &mut self.cache;
        let pick = self.router.route(m, reps, |rep| {
            cache
                .backlog(engines, rep)
                .saturating_add(res.map_or(0, |r| r.penalty_items(rep.gpu)))
        });
        let (rep_gpu, rep_local) = (reps[pick].gpu, reps[pick].local);
        if self.obs.on() {
            let at = if rerouted { t } else { req.arrival };
            self.obs.event(EventKind::Route, at, m as u32, req.id, rep_gpu as u64);
        }
        req.model = rep_local;
        engines[rep_gpu].as_mut().expect("replica on idle GPU").sim.inject(req);
        self.cache.note_inject(rep_gpu, rep_local);
        touched.mark(rep_gpu);
        if rerouted {
            if let Some(res) = &mut self.res {
                res.note_reroute(1);
            }
        }
    }

    /// The overload front door (armed `ovl` only): family-ordered
    /// admission — the primary first, then its brownout variants — with
    /// per-engine breaker feeding/filtering, resolved to a dispatch, a
    /// scheduled retry, or a typed terminal reject. `attempt` is 0 for
    /// fresh arrivals and the retry ordinal for re-entries.
    fn overload_dispatch(
        &mut self,
        t: Us,
        attempt: u32,
        mut req: Request,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
    ) {
        let m = req.model;
        let order = self.ovl.as_ref().expect("overload dispatch without layer").service_order(m);
        let mut cause = RejectKind::Unroutable;
        for (fi, &fm) in order.iter().enumerate() {
            let healthy: Vec<Replica> = self.pl.replicas[fm]
                .iter()
                .filter(|r| self.res.as_ref().is_none_or(|res| res.routable(r.gpu)))
                .cloned()
                .collect();
            if healthy.is_empty() {
                continue; // `cause` stays Unroutable for the primary
            }
            // Every healthy replica's estimate feeds its breaker; only
            // breaker-approved replicas stay candidates.
            let mut open: Vec<Replica> = Vec::with_capacity(healthy.len());
            let mut best = Us::MAX;
            for rep in &healthy {
                let load = self
                    .cache
                    .backlog(engines, rep)
                    .saturating_add(self.res.as_ref().map_or(0, |r| r.penalty_items(rep.gpu)));
                let est = queue_est_us(load, rep.batch, rep.capacity_rps);
                let miss = t.saturating_add(est) > req.deadline;
                let ovl = self.ovl.as_mut().expect("checked above");
                ovl.note_estimate(t, rep.gpu, miss);
                if ovl.allows(t, rep.gpu) {
                    if est < best {
                        best = est;
                    }
                    open.push(rep.clone());
                }
            }
            if open.is_empty() {
                if fi == 0 {
                    cause = RejectKind::BreakerOpen;
                }
                continue;
            }
            if t.saturating_add(best) > req.deadline {
                if fi == 0 {
                    cause = RejectKind::Deadline;
                }
                continue;
            }
            let cache = &mut self.cache;
            let res = self.res.as_ref();
            let pick = self.router.route(fm, &open, |rep| {
                cache
                    .backlog(engines, rep)
                    .saturating_add(res.map_or(0, |r| r.penalty_items(rep.gpu)))
            });
            let (rep_gpu, rep_local) = (open[pick].gpu, open[pick].local);
            if self.obs.on() {
                self.obs.event(EventKind::Route, t, fm as u32, req.id, rep_gpu as u64);
            }
            req.model = rep_local;
            engines[rep_gpu].as_mut().expect("replica on idle GPU").sim.inject(req);
            self.cache.note_inject(rep_gpu, rep_local);
            touched.mark(rep_gpu);
            let class = self.res.as_ref().map_or(SloClass::LatencyCritical, |r| r.class(m));
            let ovl = self.ovl.as_mut().expect("checked above");
            ovl.note_dispatch(t, rep_gpu);
            if fi > 0 {
                ovl.note_degraded(class);
            }
            if attempt > 0 {
                ovl.note_retry_served();
            }
            return;
        }
        self.overload_reject(t, attempt, &req, cause);
    }

    /// A request the overload front door could not place anywhere in its
    /// family: schedule a backoff retry if budget remains, else issue
    /// the terminal typed reject (`retry_exhausted` when retries are on,
    /// the original cause otherwise).
    fn overload_reject(&mut self, t: Us, attempt: u32, req: &Request, cause: RejectKind) {
        let m = req.model;
        if self.ovl.as_mut().expect("overload reject without layer").try_schedule_retry(
            t,
            req,
            attempt + 1,
        ) {
            return; // re-enters at its release barrier; not terminal
        }
        self.rejected[m] += 1;
        let class = self.res.as_ref().map_or(SloClass::LatencyCritical, |r| r.class(m));
        let forward = self.ovl.as_mut().expect("checked above").note_terminal(cause, class);
        match forward {
            Some(RejectKind::Deadline) => {
                if let Some(res) = &mut self.res {
                    res.note_deadline_reject(m);
                }
            }
            Some(RejectKind::Unroutable) => {
                if let Some(res) = &mut self.res {
                    res.note_unroutable();
                }
            }
            _ => {}
        }
        if self.obs.on() {
            self.obs.event(EventKind::Reject, t, m as u32, req.id, 0);
        }
    }

    /// Apply timeline faults, restore maturities and the hedge sweep
    /// due at barrier `t`. All three are driver events
    /// ([`Resilience::next_event`]), so in sparse mode every engine is
    /// synchronized here — cross-engine drains and moves are safe and
    /// mode-invariant.
    fn apply_faults(
        &mut self,
        t: Us,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
    ) {
        let due = self.res.as_mut().expect("faults without resilience").due_faults(t);
        for e in &due {
            match e.kind {
                FaultKind::Down => self.on_down(t, e.gpu, engines, touched),
                FaultKind::Degraded => {
                    if self.obs.on() {
                        self.obs.event(EventKind::EngineDown, t, NO_MODEL, e.gpu as u64, 1);
                    }
                }
                FaultKind::Up => {
                    // Recovery from a hard down is cold: every hosted
                    // model re-loads its weights; the engine is routable
                    // again only when the slowest load matures. Degraded
                    // engines recover in place (nothing drained) and
                    // need no restore.
                    let res = self.res.as_mut().expect("faults without resilience");
                    if res.restoring(e.gpu) {
                        let cold = self.pl.hosted[e.gpu]
                            .iter()
                            .map(|&m| ms_to_us(self.profiles[m].load_ms).max(1))
                            .max()
                            .unwrap_or(1);
                        res.schedule_restore(e.gpu, t + cold);
                    } else if self.obs.on() {
                        self.obs.event(EventKind::EngineUp, t, NO_MODEL, e.gpu as u64, 0);
                    }
                }
            }
        }
        let due = self.res.as_mut().expect("faults without resilience").due_restores(t);
        for g in due {
            self.on_restore(t, g, engines, touched);
        }
        if self.res.as_mut().expect("faults without resilience").hedge_due(t) {
            self.hedge_sweep(t, engines, touched);
        }
    }

    /// Engine `g` failed: drain every active local queue, re-route the
    /// drained requests through the normal dispatch path (the health
    /// filter excludes `g` now), rebuild the policy over the tombstoned
    /// table. With `reroute` off (the naive baseline), drained requests
    /// are typed rejects instead — conservation holds either way.
    fn on_down(
        &mut self,
        t: Us,
        g: usize,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
    ) {
        if self.obs.on() {
            self.obs.event(EventKind::EngineDown, t, NO_MODEL, g as u64, 0);
        }
        let mut drained: Vec<Request> = Vec::new();
        if let Some(eng) = engines[g].as_mut() {
            for (local, &global) in self.pl.hosted[g].iter().enumerate() {
                if !eng.sim.is_active(local) {
                    continue;
                }
                for mut r in eng.sim.deactivate_model(local) {
                    r.model = global;
                    drained.push(r);
                }
                self.cache.invalidate(g, local);
            }
            eng.rebuild_policy(self.sched);
            touched.mark(g);
        }
        let reroute = self.res.as_ref().is_none_or(|r| r.cfg.reroute);
        for r in drained {
            if reroute {
                self.dispatch_one(t, r, engines, touched, true);
            } else {
                self.rejected[r.model] += 1;
                if self.obs.on() {
                    self.obs.event(EventKind::Reject, t, r.model as u32, r.id, 0);
                }
            }
        }
    }

    /// Engine `g`'s cold re-activation matured: re-activate every
    /// hosted model at its original operating point and mark the
    /// engine routable.
    fn on_restore(
        &mut self,
        t: Us,
        g: usize,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
    ) {
        if let Some(eng) = engines[g].as_mut() {
            for local in 0..eng.sim.models.len() {
                if !eng.sim.is_active(local) {
                    let entry = eng.sim.models[local].clone();
                    eng.sim.reactivate_model(local, entry);
                }
            }
            eng.rebuild_policy(self.sched);
            touched.mark(g);
        }
        self.res.as_mut().expect("restore without resilience").mark_restored(g, t);
        if self.obs.on() {
            self.obs.event(EventKind::EngineUp, t, NO_MODEL, g as u64, 0);
        }
    }

    /// Hedged re-dispatch: for each degraded engine, move requests
    /// stuck past their class threshold to the analytically-best other
    /// replica — first-completion-wins with ties broken by engine index
    /// ([`pick_hedge_target`]); when the stuck copy wins, nothing
    /// moves (the hedge copy is the one cancelled).
    fn hedge_sweep(
        &mut self,
        t: Us,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
    ) {
        for g in 0..engines.len() {
            if !self.res.as_ref().is_some_and(|r| r.degraded(g)) || engines[g].is_none() {
                continue;
            }
            for (local, &global) in self.pl.hosted[g].iter().enumerate() {
                let res = self.res.as_ref().expect("hedge without resilience");
                let cutoff = t.saturating_sub(res.hedge_threshold_us(global));
                let eng = engines[g].as_ref().expect("checked some");
                if !eng.sim.is_active(local) {
                    continue;
                }
                let stuck = eng.sim.queued_before(local, cutoff) as u64;
                if stuck == 0 {
                    continue;
                }
                let src = self.pl.replicas[global]
                    .iter()
                    .find(|r| r.gpu == g)
                    .expect("hosted model without replica");
                let cache = &mut self.cache;
                let src_est = queue_est_us(
                    cache.backlog(engines, src).saturating_add(res.penalty_items(g)),
                    src.batch,
                    src.capacity_rps,
                );
                let cands: Vec<(Us, usize)> = self.pl.replicas[global]
                    .iter()
                    .filter(|r| r.gpu != g && res.routable(r.gpu))
                    .map(|r| {
                        let load =
                            cache.backlog(engines, r).saturating_add(res.penalty_items(r.gpu));
                        (queue_est_us(load, r.batch, r.capacity_rps), r.gpu)
                    })
                    .collect();
                match pick_hedge_target((src_est, g), &cands) {
                    None => {
                        // Stuck copy wins: hedge fired, copy cancelled.
                        self.res.as_mut().expect("checked").note_hedges(stuck, 0);
                    }
                    Some(win) => {
                        let target = self.pl.replicas[global]
                            .iter()
                            .find(|r| r.gpu == win)
                            .expect("winner without replica");
                        let (t_gpu, t_local) = (target.gpu, target.local);
                        let moved = engines[g]
                            .as_mut()
                            .expect("checked some")
                            .sim
                            .take_queued_before(local, cutoff);
                        let n = moved.len() as u64;
                        for mut r in moved {
                            if self.obs.on() {
                                self.obs.event(
                                    EventKind::Hedge,
                                    t,
                                    global as u32,
                                    r.id,
                                    t_gpu as u64,
                                );
                            }
                            r.model = t_local;
                            engines[t_gpu]
                                .as_mut()
                                .expect("routable replica on idle GPU")
                                .sim
                                .inject(r);
                            self.cache.note_inject(t_gpu, t_local);
                        }
                        self.cache.invalidate(g, local);
                        touched.mark(g);
                        touched.mark(t_gpu);
                        self.res.as_mut().expect("checked").note_hedges(n, n);
                        // The losing engine's breaker sees the hedge loss.
                        if let Some(ovl) = &mut self.ovl {
                            ovl.note_hedge_loss(t, g);
                        }
                    }
                }
            }
        }
    }
}

impl EpochDriver for PlacementDriver<'_> {
    fn n_models(&self) -> usize {
        self.rejected.len()
    }

    fn next_event(&self) -> Option<Us> {
        let res = self.res.as_ref().and_then(|r| r.next_event());
        let ovl = self.ovl.as_ref().and_then(|o| o.next_release());
        match (res, ovl) {
            (Some(a), Some(b)) => Some(a.min(b)),
            (a, None) => a,
            (None, b) => b,
        }
    }

    fn candidates_of(&self, model: usize) -> &[usize] {
        &self.cand[model]
    }

    fn elides_barriers(&self) -> bool {
        !self.router.policy().reads_backlogs() && self.res.is_none() && self.ovl.is_none()
    }

    fn route_free(&mut self, _t: Us, req: &Request) -> Option<(usize, usize)> {
        if self.obs.on() {
            self.obs.event(EventKind::Arrive, req.arrival, req.model as u32, req.id, 0);
        }
        if !self.pl.admitted[req.model] {
            self.rejected[req.model] += 1;
            if self.obs.on() {
                self.obs.event(EventKind::Reject, req.arrival, req.model as u32, req.id, 0);
            }
            return None;
        }
        let reps = &self.pl.replicas[req.model];
        // Backlog-free by contract: the closure is never consulted.
        let pick = self.router.route(req.model, reps, |_| 0);
        let rep = &reps[pick];
        if self.obs.on() {
            self.obs.event(EventKind::Route, req.arrival, req.model as u32, req.id, rep.gpu as u64);
        }
        Some((rep.gpu, rep.local))
    }

    fn pre_arrivals(
        &mut self,
        t: Us,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
    ) {
        self.cache.reset();
        if self.res.is_some() {
            self.apply_faults(t, engines, touched);
        }
        if self.ovl.is_some() {
            // Matured backoff retries re-enter through the front door in
            // deterministic (release, schedule) order.
            for (attempt, req) in self.ovl.as_mut().expect("checked").due_retries(t) {
                self.overload_dispatch(t, attempt, req, engines, touched);
            }
        }
    }

    fn route(
        &mut self,
        t: Us,
        req: Request,
        engines: &mut [Option<ExecEngine>],
        touched: &mut Touched,
    ) {
        if self.obs.on() {
            self.obs.event(EventKind::Arrive, req.arrival, req.model as u32, req.id, 0);
        }
        if !self.pl.admitted[req.model] {
            self.rejected[req.model] += 1;
            if self.obs.on() {
                self.obs.event(EventKind::Reject, req.arrival, req.model as u32, req.id, 0);
            }
            return;
        }
        if self.ovl.is_some() {
            self.overload_dispatch(t, 0, req, engines, touched);
            return;
        }
        self.dispatch_one(t, req, engines, touched, false);
    }
}

/// Drive one engine per GPU over `requests` under `placement`, routing
/// each request at its arrival instant, with the default
/// ([`ExecOpts::default`]) execution options. The stream is owned:
/// injections move requests, no full-stream clone is made.
/// Deterministic: a fixed (placement, routing, seed, stream) tuple
/// always yields the same [`ClusterReport`] — for *any* thread count
/// and either `exec_mode` (see [`exec`]).
#[allow(clippy::too_many_arguments)]
pub fn run_placement(
    profiles: &[ModelProfile],
    gpus: &[GpuSpec],
    pl: &Placement,
    requests: Vec<Request>,
    horizon_ms: f64,
    routing: RoutingPolicy,
    sched: GpuSched,
    seed: u64,
    label: &str,
) -> ClusterReport {
    run_placement_with(
        profiles,
        gpus,
        pl,
        requests,
        horizon_ms,
        routing,
        sched,
        seed,
        label,
        ExecOpts::default(),
    )
}

/// [`run_placement`] with explicit execution options (thread budget +
/// barrier mode). Thin adapter over [`run_placement_stream`]: the
/// vector becomes a [`MaterializedStream`], preserving the exact
/// pre-streaming call sequence (and hence report bytes).
#[allow(clippy::too_many_arguments)]
pub fn run_placement_with(
    profiles: &[ModelProfile],
    gpus: &[GpuSpec],
    pl: &Placement,
    requests: Vec<Request>,
    horizon_ms: f64,
    routing: RoutingPolicy,
    sched: GpuSched,
    seed: u64,
    label: &str,
    opts: ExecOpts,
) -> ClusterReport {
    let stream = MaterializedStream::new(requests, profiles.len());
    run_placement_stream(
        profiles, gpus, pl, stream, horizon_ms, routing, sched, seed, label, opts,
    )
}

/// [`run_placement`] pulling arrivals lazily from any
/// [`ArrivalStream`] — memory stays O(stream backlog) instead of
/// O(total requests). Byte-identical to the materialized path for the
/// same arrival sequence (`tests/parallel_exec.rs`).
#[allow(clippy::too_many_arguments)]
pub fn run_placement_stream<S: ArrivalStream>(
    profiles: &[ModelProfile],
    gpus: &[GpuSpec],
    pl: &Placement,
    stream: S,
    horizon_ms: f64,
    routing: RoutingPolicy,
    sched: GpuSched,
    seed: u64,
    label: &str,
    opts: ExecOpts,
) -> ClusterReport {
    run_placement_stream_faults(
        profiles, gpus, pl, stream, horizon_ms, routing, sched, seed, label, opts, None,
    )
}

/// [`run_placement_stream`] with an optional fault timeline + SLO-class
/// front door ([`crate::faults`]). With `faults: None` this is the
/// exact plain path (no allocation, no behavior change); with a config,
/// engine down/up/degraded events play out as driver-event barriers and
/// the report carries [`ClusterReport::resilience`].
#[allow(clippy::too_many_arguments)]
pub fn run_placement_stream_faults<S: ArrivalStream>(
    profiles: &[ModelProfile],
    gpus: &[GpuSpec],
    pl: &Placement,
    stream: S,
    horizon_ms: f64,
    routing: RoutingPolicy,
    sched: GpuSched,
    seed: u64,
    label: &str,
    opts: ExecOpts,
    faults: Option<&ResilienceCfg>,
) -> ClusterReport {
    run_placement_stream_overload(
        profiles, gpus, pl, stream, horizon_ms, routing, sched, seed, label, opts, faults, None,
    )
}

/// [`run_placement_stream_faults`] with the overload-control layer
/// ([`crate::overload`]: backoff retries, per-engine circuit breakers,
/// brownout variant fallback). With `overload: None` this is the exact
/// faults path; when armed, the overload layer implies deadline-aware
/// admission (a default front door is synthesized if no fault config is
/// given), retry releases become driver events, and the report carries
/// [`ClusterReport::overload`].
#[allow(clippy::too_many_arguments)]
pub fn run_placement_stream_overload<S: ArrivalStream>(
    profiles: &[ModelProfile],
    gpus: &[GpuSpec],
    pl: &Placement,
    stream: S,
    horizon_ms: f64,
    routing: RoutingPolicy,
    sched: GpuSched,
    seed: u64,
    label: &str,
    opts: ExecOpts,
    faults: Option<&ResilienceCfg>,
    overload: Option<&OverloadSpec>,
) -> ClusterReport {
    assert_eq!(pl.n_gpus(), gpus.len(), "placement built for a different cluster");
    let n_models = profiles.len();
    let n_gpus = gpus.len();
    let horizon = ms_to_us(horizon_ms);

    // One engine per GPU that hosts anything; empty GPUs stay idle.
    let mut engines: Vec<Option<ExecEngine>> = (0..n_gpus)
        .map(|g| {
            if pl.hosted[g].is_empty() {
                return None;
            }
            let entries: Vec<ModelEntry> = pl.hosted[g]
                .iter()
                .map(|&m| {
                    let rep = pl.replicas[m]
                        .iter()
                        .find(|r| r.gpu == g)
                        .expect("hosted model without a replica entry");
                    ModelEntry { profile: profiles[m].clone(), pct: rep.pct, batch: rep.batch }
                })
                .collect();
            let policy = sched.build(&entries);
            let cfg =
                SimConfig { gpu: gpus[g].clone(), horizon_ms, obs: opts.obs, ..Default::default() };
            Some(ExecEngine { sim: Sim::new(cfg, entries), policy })
        })
        .collect();

    let cand: Vec<Vec<usize>> = pl
        .replicas
        .iter()
        .map(|reps| reps.iter().map(|r| r.gpu).collect())
        .collect();
    // The overload layer routes through the resilience front door's
    // admission estimate; when armed without an explicit fault config,
    // synthesize a minimal admission-only door (no faults, no hedging).
    let synth_cfg;
    let res_cfg = match (faults, overload) {
        (Some(cfg), _) => Some(cfg),
        (None, Some(_)) => {
            synth_cfg =
                ResilienceCfg { admission: true, hedge: false, ..ResilienceCfg::default() };
            Some(&synth_cfg)
        }
        (None, None) => None,
    };
    let res = res_cfg.map(|cfg| {
        Resilience::new(cfg.clone(), profiles, n_gpus, horizon)
            .expect("invalid faults config (validate at the config layer)")
    });
    let ovl = overload.map(|spec| Overload::new(spec, n_gpus));
    let mut driver = PlacementDriver {
        pl,
        profiles,
        sched,
        cand,
        router: Router::new(routing, n_models, seed),
        cache: BacklogCache::default(),
        rejected: vec![0u64; n_models],
        res,
        ovl,
        obs: Recorder::new(opts.obs, horizon),
    };
    let exec_stats = run_epochs_stream(&mut engines, stream, horizon, opts, &mut driver);
    let control_obs = driver.obs.finish(profiles.iter().map(|p| p.name.clone()).collect());
    let mut rejected = driver.rejected;
    let res = driver.res;
    let mut ovl = driver.ovl;
    // Retries still pending at the horizon never got a terminal answer:
    // count them as retry-exhausted rejects so every offered request is
    // accounted (served + dropped + typed rejects == offered).
    if let Some(o) = &mut ovl {
        for (_attempt, req) in o.drain_leftover() {
            rejected[req.model] += 1;
            let class =
                res.as_ref().map_or(SloClass::LatencyCritical, |r| r.class(req.model));
            o.note_retry_exhausted(class);
        }
    }

    let reports: Vec<Option<RunReport>> = engines
        .iter_mut()
        .map(|slot| slot.as_mut().map(|e| e.finalize(horizon)))
        .collect();
    // Engine observability is drained after finalize so horizon drops
    // and drained completions are included; idle GPUs get empty lanes.
    let obs_lanes: Vec<EngineObs> = engines
        .iter_mut()
        .map(|slot| slot.as_mut().map(|e| e.sim.take_obs()).unwrap_or_default())
        .collect();
    let obs = ObsReport::collect(opts.obs, horizon, obs_lanes, control_obs);

    // Aggregate per global model index.
    let horizon_s = horizon_ms / 1_000.0;
    let mut throughput = vec![0.0; n_models];
    let mut violations = vec![0.0; n_models];
    let mut served = vec![0u64; n_models];
    let mut dropped = vec![0u64; n_models];
    let mut latencies: Vec<Vec<f64>> = vec![Vec::new(); n_models];
    let mut hists: Vec<LogHistogram> = vec![LogHistogram::default(); n_models];
    let mut gpu_utilization = Vec::with_capacity(n_gpus);
    let mut per_gpu = Vec::with_capacity(n_gpus);
    // Completion instants + SLO outcome, fed to the degraded-goodput
    // accounting (only gathered when a fault timeline is attached;
    // empty when `exact_latencies` is off — goodput then reads 0).
    let mut comps: Vec<(Us, bool)> = Vec::new();
    for g in 0..n_gpus {
        let (util, shares) = match &reports[g] {
            Some(rep) => {
                let mut shares = Vec::with_capacity(rep.per_model.len());
                for (local, mm) in rep.per_model.iter().enumerate() {
                    let global = pl.hosted[g][local];
                    throughput[global] += mm.served as f64 / horizon_s;
                    violations[global] += mm.slo_violations() as f64 / horizon_s;
                    served[global] += mm.served;
                    dropped[global] += mm.dropped;
                    latencies[global].extend_from_slice(&mm.latencies_ms);
                    hists[global].merge(&mm.latency_hist);
                    if res.is_some() {
                        let slo = profiles[global].slo_ms;
                        for (lat, &done) in mm.latencies_ms.iter().zip(&mm.completions_us) {
                            comps.push((done, *lat <= slo));
                        }
                    }
                    let r = pl.replicas[global]
                        .iter()
                        .find(|r| r.gpu == g)
                        .expect("share without replica");
                    shares.push(GpuModelShare {
                        model: global,
                        pct: r.pct,
                        batch: r.batch,
                        served: mm.served,
                    });
                }
                (rep.gpu_utilization[0], shares)
            }
            None => (0.0, Vec::new()),
        };
        gpu_utilization.push(util);
        per_gpu.push(GpuReport {
            gpu: gpus[g].name.to_string(),
            knee_load_pct: pl.knee_load[g],
            utilization: util,
            models: shares,
        });
    }
    for m in 0..n_models {
        violations[m] += rejected[m] as f64 / horizon_s;
    }
    let p99_ms: Vec<f64> =
        latencies.iter().zip(&hists).map(|(l, h)| p99_of(l, h)).collect();
    let replica_map: Vec<Vec<usize>> = pl
        .replicas
        .iter()
        .map(|reps| reps.iter().map(|r| r.gpu).collect())
        .collect();

    ClusterReport {
        policy: label.to_string(),
        throughput,
        gpu_utilization,
        violations_per_sec: violations,
        p99_ms,
        served,
        dropped,
        rejected,
        replica_map,
        shed_rps: pl.shed_rps.clone(),
        admitted: pl.admitted.clone(),
        per_gpu,
        adaptive: None,
        lifecycle: None,
        resilience: res.map(|mut r| r.finalize(horizon, comps.into_iter())),
        overload: ovl.map(|o| o.finalize()),
        exec: Some(exec_stats),
        obs,
    }
}

/// Per-model p99 for cluster aggregation: exact percentile over the
/// gathered latency vectors when present, falling back to the merged
/// bounded histogram when `observability.exact_latencies` is off (the
/// vectors are then empty by design).
pub(crate) fn p99_of(lat: &[f64], hist: &LogHistogram) -> f64 {
    if lat.is_empty() && hist.count() > 0 {
        return hist.quantile(0.99);
    }
    percentile(lat, 99.0)
}

/// Placement + routing + simulation in one call: bin-pack `profiles`
/// (with their offered rates) onto `gpus`, then serve `requests`.
#[allow(clippy::too_many_arguments)]
pub fn serve_cluster(
    profiles: &[ModelProfile],
    offered_rps: &[f64],
    gpus: &[GpuSpec],
    placement: PlacementPolicy,
    routing: RoutingPolicy,
    sched: GpuSched,
    requests: Vec<Request>,
    horizon_ms: f64,
    seed: u64,
) -> ClusterReport {
    serve_cluster_with(
        profiles,
        offered_rps,
        gpus,
        placement,
        routing,
        sched,
        requests,
        horizon_ms,
        seed,
        ExecOpts::default(),
    )
}

/// [`serve_cluster`] with explicit execution options.
#[allow(clippy::too_many_arguments)]
pub fn serve_cluster_with(
    profiles: &[ModelProfile],
    offered_rps: &[f64],
    gpus: &[GpuSpec],
    placement: PlacementPolicy,
    routing: RoutingPolicy,
    sched: GpuSched,
    requests: Vec<Request>,
    horizon_ms: f64,
    seed: u64,
    opts: ExecOpts,
) -> ClusterReport {
    let stream = MaterializedStream::new(requests, profiles.len());
    serve_cluster_stream(
        profiles, offered_rps, gpus, placement, routing, sched, stream, horizon_ms, seed, opts,
    )
}

/// [`serve_cluster`] pulling arrivals lazily from any [`ArrivalStream`]
/// (a [`crate::workload::MergedStream`] over generator specs, or a
/// [`crate::workload::TraceStream`] replaying a production log).
#[allow(clippy::too_many_arguments)]
pub fn serve_cluster_stream<S: ArrivalStream>(
    profiles: &[ModelProfile],
    offered_rps: &[f64],
    gpus: &[GpuSpec],
    placement: PlacementPolicy,
    routing: RoutingPolicy,
    sched: GpuSched,
    stream: S,
    horizon_ms: f64,
    seed: u64,
    opts: ExecOpts,
) -> ClusterReport {
    serve_cluster_stream_faults(
        profiles, offered_rps, gpus, placement, routing, sched, stream, horizon_ms, seed, opts,
        None,
    )
}

/// [`serve_cluster_stream`] with an optional fault timeline + SLO-class
/// front door (see [`run_placement_stream_faults`]).
#[allow(clippy::too_many_arguments)]
pub fn serve_cluster_stream_faults<S: ArrivalStream>(
    profiles: &[ModelProfile],
    offered_rps: &[f64],
    gpus: &[GpuSpec],
    placement: PlacementPolicy,
    routing: RoutingPolicy,
    sched: GpuSched,
    stream: S,
    horizon_ms: f64,
    seed: u64,
    opts: ExecOpts,
    faults: Option<&ResilienceCfg>,
) -> ClusterReport {
    let pl = place(profiles, offered_rps, gpus, placement);
    let label = format!("{}+{}+{}", placement.name(), routing.name(), sched.name());
    run_placement_stream_faults(
        profiles, gpus, &pl, stream, horizon_ms, routing, sched, seed, &label, opts, faults,
    )
}

/// [`serve_cluster_stream_faults`] with the overload-control layer.
/// `profiles` must already be the expanded family list (primaries
/// first, then brownout variants, per [`crate::overload::expand_profiles`])
/// and `offered_rps` covers the full expanded list with variant rates
/// at 0. Placement bin-packs the primaries only; variants are then
/// co-located onto their primaries' GPUs where knee headroom and memory
/// allow ([`co_locate_variants`]), so a brownout never displaces a
/// primary replica.
#[allow(clippy::too_many_arguments)]
pub fn serve_cluster_stream_overload<S: ArrivalStream>(
    profiles: &[ModelProfile],
    offered_rps: &[f64],
    gpus: &[GpuSpec],
    placement: PlacementPolicy,
    routing: RoutingPolicy,
    sched: GpuSched,
    stream: S,
    horizon_ms: f64,
    seed: u64,
    opts: ExecOpts,
    faults: Option<&ResilienceCfg>,
    overload: Option<&OverloadSpec>,
) -> ClusterReport {
    let pl = match overload {
        Some(spec) if spec.map.n_total() > spec.map.n_primary => {
            let n_p = spec.map.n_primary;
            assert_eq!(profiles.len(), spec.map.n_total(), "profiles not expanded for variants");
            let mut pl = place(&profiles[..n_p], &offered_rps[..n_p], gpus, placement);
            co_locate_variants(&mut pl, profiles, &spec.map, gpus);
            pl
        }
        _ => place(profiles, offered_rps, gpus, placement),
    };
    let label = format!("{}+{}+{}", placement.name(), routing.name(), sched.name());
    run_placement_stream_overload(
        profiles, gpus, &pl, stream, horizon_ms, routing, sched, seed, &label, opts, faults,
        overload,
    )
}

/// Run a legacy fixed-layout cluster experiment: `profiles` over
/// `n_gpus` of type `gpu` under one of the paper's three scenarios.
/// Implemented on the placement/routing engine with round-robin
/// dispatch, which reproduces the old up-front stream split exactly.
pub fn run_cluster(
    profiles: &[ModelProfile],
    gpu: &GpuSpec,
    n_gpus: usize,
    requests: Vec<Request>,
    horizon_ms: f64,
    policy: ClusterPolicy,
) -> ClusterReport {
    let n_models = profiles.len();
    // One op_point per model on this (homogeneous) GPU type — the same
    // source entries_for_gpu uses, capacity included.
    let ops: Vec<(u32, u32, f64)> = profiles.iter().map(|p| op_point(p, gpu)).collect();

    let hosted: Vec<Vec<usize>> = match policy {
        ClusterPolicy::Exclusive => {
            assert!(
                n_gpus >= n_models,
                "exclusive placement needs one GPU per model ({n_models} > {n_gpus})"
            );
            (0..n_gpus).map(|g| if g < n_models { vec![g] } else { Vec::new() }).collect()
        }
        _ => (0..n_gpus).map(|_| (0..n_models).collect()).collect(),
    };
    let pl = Placement::fixed(n_models, hosted, |_g, m| ops[m]);
    let sched = match policy {
        ClusterPolicy::Exclusive => GpuSched::Triton,
        ClusterPolicy::TemporalAll => GpuSched::Temporal,
        ClusterPolicy::DstackAll => GpuSched::Dstack,
    };
    let gpus = vec![gpu.clone(); n_gpus];
    run_placement(
        profiles,
        &gpus,
        &pl,
        requests,
        horizon_ms,
        RoutingPolicy::RoundRobin,
        sched,
        0,
        &format!("{policy:?}"),
    )
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::{by_name, T4, V100};
    use crate::workload::{merged_stream, Arrivals};

    /// The Fig. 12 regime (see [`fig12_workload`]): the heavy models'
    /// demand exceeds what one dedicated T4 can serve, while the light
    /// models leave their dedicated GPUs mostly idle — D-STACK
    /// consolidates and reassigns that idle capacity.
    fn fig12_setup(horizon_ms: f64) -> (Vec<ModelProfile>, Vec<f64>, Vec<Request>) {
        fig12_workload(horizon_ms, 77)
    }

    #[test]
    fn knees_differ_on_t4() {
        let profiles = vec![by_name("mobilenet").unwrap(), by_name("vgg19").unwrap()];
        let v100 = entries_for_gpu(&profiles, &V100);
        let t4 = entries_for_gpu(&profiles, &T4);
        // The T4 has half the SMs; a model's knee GPU% is higher there.
        assert!(t4[0].pct >= v100[0].pct, "{} vs {}", t4[0].pct, v100[0].pct);
    }

    #[test]
    fn dstack_cluster_beats_temporal_and_exclusive() {
        // Fig. 12: D-STACK ≥ 1.6× temporal / exclusive on the 4×T4
        // cluster; temporal ≈ exclusive.
        let (profiles, _rates, reqs) = fig12_setup(4_000.0);
        let excl =
            run_cluster(&profiles, &T4, 4, reqs.clone(), 4_000.0, ClusterPolicy::Exclusive);
        let temp =
            run_cluster(&profiles, &T4, 4, reqs.clone(), 4_000.0, ClusterPolicy::TemporalAll);
        let dstk = run_cluster(&profiles, &T4, 4, reqs, 4_000.0, ClusterPolicy::DstackAll);
        let (e, t, d) =
            (excl.total_throughput(), temp.total_throughput(), dstk.total_throughput());
        assert!(d > 1.1 * t, "dstack {d} vs temporal {t}");
        assert!(d > 1.3 * e, "dstack {d} vs exclusive {e}");
        // The overloaded ResNet-50 gains the most from consolidation.
        assert!(
            dstk.throughput[2] > 1.3 * excl.throughput[2],
            "resnet50: dstack {} vs exclusive {}",
            dstk.throughput[2],
            excl.throughput[2]
        );
        assert!(
            dstk.throughput[3] > 1.5 * excl.throughput[3],
            "vgg19: dstack {} vs exclusive {}",
            dstk.throughput[3],
            excl.throughput[3]
        );
    }

    #[test]
    fn exclusive_strands_capacity_on_light_model_gpus() {
        // The under-utilization mechanism behind Fig. 12: the dedicated
        // GPUs of light models sit mostly idle while the heavy models'
        // GPUs drop requests.
        let (profiles, _rates, reqs) = fig12_setup(3_000.0);
        let excl = run_cluster(&profiles, &T4, 4, reqs, 3_000.0, ClusterPolicy::Exclusive);
        // GPU 0 hosts mobilenet (light, 150/s): mostly idle.
        assert!(
            excl.gpu_utilization[0] < 0.6,
            "mobilenet GPU util {}",
            excl.gpu_utilization[0]
        );
        // GPU 3 hosts vgg19 (450/s ≫ its ~250/s capacity): saturated and
        // violating SLOs.
        assert!(excl.gpu_utilization[3] > 0.9);
        assert!(excl.violations_per_sec[3] > 100.0);
    }

    #[test]
    #[should_panic(expected = "exclusive placement")]
    fn exclusive_requires_enough_gpus() {
        let (profiles, _rates, reqs) = fig12_setup(500.0);
        run_cluster(&profiles, &T4, 2, reqs, 500.0, ClusterPolicy::Exclusive);
    }

    #[test]
    fn placed_cluster_conserves_requests() {
        let (profiles, rates, reqs) = fig12_setup(2_000.0);
        let rep = serve_cluster(
            &profiles,
            &rates,
            &[V100.clone(), T4.clone(), T4.clone()],
            PlacementPolicy::FirstFitDecreasing,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            reqs.clone(),
            2_000.0,
            7,
        );
        let mut offered = vec![0u64; profiles.len()];
        for r in &reqs {
            offered[r.model] += 1;
        }
        for m in 0..profiles.len() {
            assert_eq!(
                rep.served[m] + rep.dropped[m] + rep.rejected[m],
                offered[m],
                "model {m}: conservation"
            );
        }
        // This cluster admits everything in the Fig. 12 regime.
        assert!(rep.admitted.iter().all(|&a| a), "{:?}", rep.admitted);
        assert!(rep.total_throughput() > 0.0);
    }

    #[test]
    fn jsq_beats_round_robin_on_heterogeneous_cluster() {
        // With one fast and one slow GPU hosting the same hot model,
        // blind round-robin overloads the slow replica while JSQ shifts
        // traffic to wherever queues drain faster: p99 must not regress
        // and throughput must at least match.
        let profiles = vec![by_name("resnet50").unwrap()];
        let rates = [900.0];
        let specs = vec![(Arrivals::Poisson { rate: 900.0 }, profiles[0].slo_ms)];
        let reqs = merged_stream(&specs, 3_000.0, 13);
        let gpus = [V100.clone(), T4.clone()];
        let run = |routing| {
            serve_cluster(
                &profiles,
                &rates,
                &gpus,
                PlacementPolicy::FirstFitDecreasing,
                routing,
                GpuSched::Dstack,
                reqs.clone(),
                3_000.0,
                3,
            )
        };
        let rr = run(RoutingPolicy::RoundRobin);
        let jsq = run(RoutingPolicy::JoinShortestQueue);
        assert!(
            jsq.total_throughput() >= 0.98 * rr.total_throughput(),
            "jsq {} vs rr {}",
            jsq.total_throughput(),
            rr.total_throughput()
        );
        assert!(
            jsq.violations_per_sec[0] <= rr.violations_per_sec[0] + 1.0,
            "jsq viol {} vs rr {}",
            jsq.violations_per_sec[0],
            rr.violations_per_sec[0]
        );
    }

    #[test]
    fn rejected_models_are_counted_not_lost() {
        // A single T4 cannot admit the whole heavy mix; rejected models'
        // requests show up in `rejected` and in violations/s.
        let (profiles, rates, reqs) = fig12_setup(1_500.0);
        let rep = serve_cluster(
            &profiles,
            &rates,
            &[T4.clone()],
            PlacementPolicy::FirstFitDecreasing,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            reqs,
            1_500.0,
            1,
        );
        let n_rejected_models = rep.admitted.iter().filter(|&&a| !a).count();
        assert!(n_rejected_models >= 1, "one T4 cannot host all of Fig. 12");
        for m in 0..profiles.len() {
            if !rep.admitted[m] {
                assert!(rep.rejected[m] > 0);
                assert_eq!(rep.served[m], 0);
                assert!(rep.violations_per_sec[m] > 0.0);
                assert!(rep.replica_map[m].is_empty());
            }
        }
    }

    #[test]
    fn cluster_report_json_is_deterministic() {
        let (profiles, rates, reqs) = fig12_setup(1_000.0);
        let gpus = [V100.clone(), T4.clone(), T4.clone()];
        let run = || {
            serve_cluster(
                &profiles,
                &rates,
                &gpus,
                PlacementPolicy::LoadBalance,
                RoutingPolicy::PowerOfTwoChoices,
                GpuSched::Dstack,
                reqs.clone(),
                1_000.0,
                21,
            )
        };
        let a = run().to_json().to_string_pretty();
        let b = run().to_json().to_string_pretty();
        assert_eq!(a, b, "same seed ⇒ identical ClusterReport");
        assert!(a.contains("\"replica_map\""));
    }
}

#[cfg(test)]
mod debug_cluster {
    use super::*;
    use super::tests_helpers::*;

    #[test]
    #[ignore]
    fn debug_fig12() {
        let (profiles, reqs) = setup(6_000.0);
        for pol in [ClusterPolicy::Exclusive, ClusterPolicy::TemporalAll, ClusterPolicy::DstackAll] {
            let r = run_cluster(&profiles, &crate::profile::T4, 4, reqs.clone(), 6_000.0, pol);
            eprintln!("{:?}: total={:.0} per-model={:?} utils={:?} viol={:?}",
                pol, r.total_throughput(),
                r.throughput.iter().map(|t| t.round()).collect::<Vec<_>>(),
                r.gpu_utilization.iter().map(|u| (u*100.0).round()).collect::<Vec<_>>(),
                r.violations_per_sec.iter().map(|v| v.round()).collect::<Vec<_>>());
        }
    }
}

#[cfg(test)]
mod tests_helpers {
    use super::*;
    pub fn setup(horizon_ms: f64) -> (Vec<ModelProfile>, Vec<Request>) {
        let (profiles, _rates, reqs) = fig12_workload(horizon_ms, 77);
        (profiles, reqs)
    }
}
