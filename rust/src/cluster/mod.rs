//! Multi-GPU cluster scheduling (§7.1, Fig. 12).
//!
//! The paper evaluates a 4×T4 cluster three ways: (1) one GPU dedicated
//! per model ("exclusive"), (2) all models on every GPU with temporal
//! sharing, (3) all models on every GPU under D-STACK. Request streams
//! are split round-robin across the GPUs hosting each model; every GPU
//! runs an independent scheduler instance (the paper's design: per-GPU
//! D-STACK schedulers, cluster-level placement).

use crate::metrics::RunReport;
use crate::profile::{GpuSpec, ModelProfile};
use crate::sched::{dstack::Dstack, temporal::Temporal, triton::Triton};
use crate::sim::{ModelEntry, Policy, Sim, SimConfig};
use crate::workload::Request;

/// Cluster-level placement / scheduling strategy.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ClusterPolicy {
    /// One GPU per model, dynamic batching at 100% GPU (a dedicated
    /// serving instance per model — the paper's first scenario).
    Exclusive,
    /// Every model on every GPU, temporal sharing.
    TemporalAll,
    /// Every model on every GPU, D-STACK.
    DstackAll,
}

/// Aggregated cluster run.
#[derive(Debug, Clone)]
pub struct ClusterReport {
    pub policy: String,
    /// Per-model served requests/s across the cluster.
    pub throughput: Vec<f64>,
    /// Per-GPU utilization.
    pub gpu_utilization: Vec<f64>,
    /// Per-model SLO violations/s across the cluster.
    pub violations_per_sec: Vec<f64>,
}

impl ClusterReport {
    pub fn total_throughput(&self) -> f64 {
        self.throughput.iter().sum()
    }

    pub fn mean_utilization(&self) -> f64 {
        self.gpu_utilization.iter().sum::<f64>() / self.gpu_utilization.len().max(1) as f64
    }
}

/// Operating points recomputed for the cluster's GPU type (knees differ
/// between V100 and T4 — §7.1).
pub fn entries_for_gpu(profiles: &[ModelProfile], gpu: &GpuSpec) -> Vec<ModelEntry> {
    use crate::optimizer::{optimize, OptConfig};
    profiles
        .iter()
        .map(|p| {
            let cfg = OptConfig::default();
            match optimize(p, gpu, &cfg) {
                Some(op) => ModelEntry { profile: p.clone(), pct: op.gpu_pct, batch: op.batch },
                None => ModelEntry {
                    profile: p.clone(),
                    pct: p.knee_pct_on(gpu, p.opt_batch),
                    batch: p.opt_batch,
                },
            }
        })
        .collect()
}

/// Split a request stream round-robin (per model) across `n` GPUs,
/// remapping each request's model index to the hosting GPU's local index.
fn split_stream(
    requests: &[Request],
    n_gpus: usize,
    hosted: impl Fn(usize) -> Vec<(usize, usize)>, // model -> [(gpu, local_idx)]
) -> Vec<Vec<Request>> {
    let mut out: Vec<Vec<Request>> = vec![Vec::new(); n_gpus];
    let mut rr: Vec<usize> = vec![0; 64];
    for r in requests {
        let hosts = hosted(r.model);
        let pick = rr[r.model] % hosts.len();
        rr[r.model] += 1;
        let (gpu, local) = hosts[pick];
        let mut req = r.clone();
        req.model = local;
        out[gpu].push(req);
    }
    out
}

/// Run the cluster experiment: `profiles` over `n_gpus` of type `gpu`,
/// with a merged request stream (model indices into `profiles`).
pub fn run_cluster(
    profiles: &[ModelProfile],
    gpu: &GpuSpec,
    n_gpus: usize,
    requests: &[Request],
    horizon_ms: f64,
    policy: ClusterPolicy,
) -> ClusterReport {
    let entries = entries_for_gpu(profiles, gpu);
    let n_models = profiles.len();

    // Per-GPU model hosting.
    let hosted: Box<dyn Fn(usize) -> Vec<(usize, usize)>> = match policy {
        ClusterPolicy::Exclusive => {
            assert!(
                n_gpus >= n_models,
                "exclusive placement needs one GPU per model ({n_models} > {n_gpus})"
            );
            Box::new(move |m| vec![(m, 0)])
        }
        _ => Box::new(move |m| (0..n_gpus).map(|g| (g, m)).collect()),
    };
    let streams = split_stream(requests, n_gpus, hosted);

    let mut reports: Vec<(usize, RunReport)> = Vec::new();
    for (g, stream) in streams.iter().enumerate() {
        let gpu_entries: Vec<ModelEntry> = match policy {
            ClusterPolicy::Exclusive => {
                if g >= n_models {
                    continue;
                }
                vec![entries[g].clone()]
            }
            _ => entries.clone(),
        };
        let mut pol: Box<dyn Policy> = match policy {
            ClusterPolicy::Exclusive => Box::new(Triton::from_entries(&gpu_entries)),
            ClusterPolicy::TemporalAll => Box::new(Temporal::from_entries(&gpu_entries)),
            ClusterPolicy::DstackAll => Box::new(Dstack::from_entries(&gpu_entries)),
        };
        let cfg = SimConfig { gpu: gpu.clone(), horizon_ms, ..Default::default() };
        let mut sim = Sim::new(cfg, gpu_entries);
        reports.push((g, sim.run(pol.as_mut(), stream)));
    }

    // Aggregate per global model index.
    let horizon_s = horizon_ms / 1_000.0;
    let mut throughput = vec![0.0; n_models];
    let mut violations = vec![0.0; n_models];
    let mut utils = Vec::new();
    for (g, rep) in &reports {
        utils.push(rep.gpu_utilization[0]);
        for (local, m) in rep.per_model.iter().enumerate() {
            let global = match policy {
                ClusterPolicy::Exclusive => *g,
                _ => local,
            };
            throughput[global] += m.served as f64 / horizon_s;
            violations[global] += m.slo_violations() as f64 / horizon_s;
        }
    }
    ClusterReport {
        policy: format!("{policy:?}"),
        throughput,
        gpu_utilization: utils,
        violations_per_sec: violations,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::{by_name, T4};
    use crate::workload::{merged_stream, Arrivals};

    fn fig12_setup(horizon_ms: f64) -> (Vec<ModelProfile>, Vec<Request>) {
        let names = ["mobilenet", "alexnet", "resnet50", "vgg19"];
        let profiles: Vec<_> = names.iter().map(|n| by_name(n).unwrap()).collect();
        // Asymmetric demand (the Fig. 12 regime): the heavy models'
        // demand exceeds what one dedicated T4 can serve, while the
        // light models leave their dedicated GPUs mostly idle — D-STACK
        // consolidates and reassigns that idle capacity.
        let rates = [150.0, 150.0, 900.0, 450.0];
        let specs: Vec<_> = profiles
            .iter()
            .zip(rates)
            .map(|(p, r)| (Arrivals::Poisson { rate: r }, p.slo_ms))
            .collect();
        let reqs = merged_stream(&specs, horizon_ms, 77);
        (profiles, reqs)
    }

    #[test]
    fn knees_differ_on_t4() {
        let profiles = vec![by_name("mobilenet").unwrap(), by_name("vgg19").unwrap()];
        let v100 = entries_for_gpu(&profiles, &crate::profile::V100);
        let t4 = entries_for_gpu(&profiles, &T4);
        // The T4 has half the SMs; a model's knee GPU% is higher there.
        assert!(t4[0].pct >= v100[0].pct, "{} vs {}", t4[0].pct, v100[0].pct);
    }

    #[test]
    fn dstack_cluster_beats_temporal_and_exclusive() {
        // Fig. 12: D-STACK ≥ 1.6× temporal / exclusive on the 4×T4
        // cluster; temporal ≈ exclusive.
        let (profiles, reqs) = fig12_setup(4_000.0);
        let excl = run_cluster(&profiles, &T4, 4, &reqs, 4_000.0, ClusterPolicy::Exclusive);
        let temp = run_cluster(&profiles, &T4, 4, &reqs, 4_000.0, ClusterPolicy::TemporalAll);
        let dstk = run_cluster(&profiles, &T4, 4, &reqs, 4_000.0, ClusterPolicy::DstackAll);
        let (e, t, d) =
            (excl.total_throughput(), temp.total_throughput(), dstk.total_throughput());
        assert!(d > 1.1 * t, "dstack {d} vs temporal {t}");
        assert!(d > 1.3 * e, "dstack {d} vs exclusive {e}");
        // The overloaded ResNet-50 gains the most from consolidation.
        assert!(
            dstk.throughput[2] > 1.3 * excl.throughput[2],
            "resnet50: dstack {} vs exclusive {}",
            dstk.throughput[2],
            excl.throughput[2]
        );
        assert!(
            dstk.throughput[3] > 1.5 * excl.throughput[3],
            "vgg19: dstack {} vs exclusive {}",
            dstk.throughput[3],
            excl.throughput[3]
        );
    }

    #[test]
    fn exclusive_strands_capacity_on_light_model_gpus() {
        // The under-utilization mechanism behind Fig. 12: the dedicated
        // GPUs of light models sit mostly idle while the heavy models'
        // GPUs drop requests.
        let (profiles, reqs) = fig12_setup(3_000.0);
        let excl = run_cluster(&profiles, &T4, 4, &reqs, 3_000.0, ClusterPolicy::Exclusive);
        // GPU 0 hosts mobilenet (light, 300/s): mostly idle.
        assert!(
            excl.gpu_utilization[0] < 0.6,
            "mobilenet GPU util {}",
            excl.gpu_utilization[0]
        );
        // GPU 3 hosts vgg19 (450/s ≫ its ~250/s capacity): saturated and
        // violating SLOs.
        assert!(excl.gpu_utilization[3] > 0.9);
        assert!(excl.violations_per_sec[3] > 100.0);
    }

    #[test]
    fn stream_split_preserves_requests() {
        let (_profiles, reqs) = fig12_setup(1_000.0);
        let n = reqs.len();
        let streams = split_stream(&reqs, 4, |m| (0..4).map(|g| (g, m)).collect());
        let total: usize = streams.iter().map(|s| s.len()).sum();
        assert_eq!(total, n);
        // Round-robin keeps streams roughly balanced.
        let c0 = streams[0].len() as i64;
        for s in &streams[1..] {
            assert!((s.len() as i64 - c0).abs() <= 4, "{} vs {c0}", s.len());
        }
    }
}

#[cfg(test)]
mod debug_cluster {
    use super::*;
    use super::tests_helpers::*;

    #[test]
    #[ignore]
    fn debug_fig12() {
        let (profiles, reqs) = setup(6_000.0);
        for pol in [ClusterPolicy::Exclusive, ClusterPolicy::TemporalAll, ClusterPolicy::DstackAll] {
            let r = run_cluster(&profiles, &crate::profile::T4, 4, &reqs, 6_000.0, pol);
            eprintln!("{:?}: total={:.0} per-model={:?} utils={:?} viol={:?}",
                pol, r.total_throughput(),
                r.throughput.iter().map(|t| t.round()).collect::<Vec<_>>(),
                r.gpu_utilization.iter().map(|u| (u*100.0).round()).collect::<Vec<_>>(),
                r.violations_per_sec.iter().map(|v| v.round()).collect::<Vec<_>>());
        }
    }
}

#[cfg(test)]
mod tests_helpers {
    use super::*;
    use crate::profile::by_name;
    use crate::workload::{merged_stream, Arrivals};
    pub fn setup(horizon_ms: f64) -> (Vec<ModelProfile>, Vec<Request>) {
        let names = ["mobilenet", "alexnet", "resnet50", "vgg19"];
        let profiles: Vec<_> = names.iter().map(|n| by_name(n).unwrap()).collect();
        let rates = [150.0, 150.0, 900.0, 450.0];
        let specs: Vec<_> = profiles.iter().zip(rates)
            .map(|(p, r)| (Arrivals::Poisson { rate: r }, p.slo_ms)).collect();
        let reqs = merged_stream(&specs, horizon_ms, 77);
        (profiles, reqs)
    }
}
