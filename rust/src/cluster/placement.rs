//! Cluster placement: bin-packing models onto GPUs by knee GPU%.
//!
//! The knee GPU% from the §4 analytic model is exactly the "item size" a
//! cluster-level packer needs: a GPU can host any set of models whose
//! knee allocations sum to ≤ 100% without destroying the per-GPU
//! spatio-temporal packing (§6.1). This module right-sizes every model
//! per GPU *type* (knees differ between V100 and T4 — §7.1, Fig. 3),
//! bin-packs replicas under that budget, replicates hot models whose
//! offered rate exceeds one replica's service capacity, and rejects
//! models the remaining cluster capacity cannot host at all (admission
//! control). Two packing disciplines are provided: classic
//! first-fit-decreasing and a load-balancing variant that spreads knee
//! load across GPUs (Jain et al.'s space-time packing and Nabavinejad et
//! al.'s batching-vs-multi-tenancy tradeoff both reduce to this
//! placement decision).
//!
//! [`place`] is a pure function of (profiles, rates, GPUs, policy) —
//! fully deterministic and cheap enough to re-solve online. The static
//! cluster path calls it once at t = 0; the adaptive control plane
//! ([`crate::controlplane`]) calls it again whenever its drift detector
//! fires, against *estimated* rates, and diffs the result into an
//! incremental migration. Because [`op_point`] depends only on (model,
//! GPU type), replicas shared between two solutions keep their
//! operating point — a rebalance only ever adds or removes replicas.

use crate::optimizer::{optimize, OptConfig};
use crate::profile::{GpuSpec, ModelProfile};

/// Queueing headroom when sizing replica counts: replicate until placed
/// service capacity covers `HEADROOM ×` the offered rate, so open-loop
/// bursts do not immediately push a just-barely-sized model into SLO
/// violations.
pub const CAPACITY_HEADROOM: f64 = 1.15;

/// Packing discipline for [`place`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PlacementPolicy {
    /// Classic first-fit-decreasing on knee GPU%: biggest models first,
    /// each replica onto the first GPU with enough residual budget.
    FirstFitDecreasing,
    /// Worst-fit variant: each replica onto the GPU with the *most*
    /// residual budget, spreading knee load evenly.
    LoadBalance,
}

impl PlacementPolicy {
    pub fn name(&self) -> &'static str {
        match self {
            PlacementPolicy::FirstFitDecreasing => "ffd",
            PlacementPolicy::LoadBalance => "lb",
        }
    }

    pub fn parse(s: &str) -> Result<PlacementPolicy, String> {
        Ok(match s {
            "ffd" | "first_fit" | "first_fit_decreasing" => PlacementPolicy::FirstFitDecreasing,
            "lb" | "load_balance" | "worst_fit" => PlacementPolicy::LoadBalance,
            other => return Err(format!("unknown placement policy '{other}'")),
        })
    }

    pub fn all() -> &'static [PlacementPolicy] {
        &[PlacementPolicy::FirstFitDecreasing, PlacementPolicy::LoadBalance]
    }
}

/// One deployed copy of a model on one GPU.
#[derive(Debug, Clone)]
pub struct Replica {
    /// Cluster GPU index.
    pub gpu: usize,
    /// Local model index inside that GPU's engine.
    pub local: usize,
    /// Deployed GPU% (the knee-derived operating point on that GPU type).
    pub pct: u32,
    /// Deployed batch size.
    pub batch: u32,
    /// Max sustained service rate of this replica (req/s) at its
    /// operating point: batch / f_L(pct, batch).
    pub capacity_rps: f64,
}

/// The outcome of placing a model set on a cluster.
#[derive(Debug, Clone)]
pub struct Placement {
    /// gpu → global model indices hosted there, in local-index order.
    pub hosted: Vec<Vec<usize>>,
    /// model → its replicas (empty ⇔ rejected by admission control).
    pub replicas: Vec<Vec<Replica>>,
    /// model → admitted (≥ 1 replica placed)?
    pub admitted: Vec<bool>,
    /// model → offered rate (req/s, with headroom) the placed capacity
    /// could *not* cover; > 0 means the model runs degraded ("shed").
    pub shed_rps: Vec<f64>,
    /// gpu → Σ placed knee GPU% (≤ 100 for bin-packed placements; fixed
    /// legacy layouts may exceed it and rely on temporal sharing).
    pub knee_load: Vec<u32>,
}

impl Placement {
    pub fn n_gpus(&self) -> usize {
        self.hosted.len()
    }

    /// Total placed service capacity for `model` (req/s).
    pub fn capacity_rps(&self, model: usize) -> f64 {
        self.replicas[model].iter().map(|r| r.capacity_rps).sum()
    }

    /// Build a placement from an explicit gpu → models layout (the
    /// paper's fixed Fig. 12 scenarios). `op(gpu, model)` supplies the
    /// deployed (pct, batch, capacity_rps) for each copy.
    pub fn fixed(
        n_models: usize,
        hosted: Vec<Vec<usize>>,
        mut op: impl FnMut(usize, usize) -> (u32, u32, f64),
    ) -> Placement {
        let mut replicas: Vec<Vec<Replica>> = vec![Vec::new(); n_models];
        let mut knee_load = vec![0u32; hosted.len()];
        for (gpu, models) in hosted.iter().enumerate() {
            for (local, &m) in models.iter().enumerate() {
                assert!(m < n_models, "fixed placement references model {m} of {n_models}");
                let (pct, batch, capacity_rps) = op(gpu, m);
                knee_load[gpu] += pct;
                replicas[m].push(Replica { gpu, local, pct, batch, capacity_rps });
            }
        }
        let admitted: Vec<bool> = replicas.iter().map(|r| !r.is_empty()).collect();
        Placement {
            hosted,
            replicas,
            admitted,
            shed_rps: vec![0.0; n_models],
            knee_load,
        }
    }
}

/// The knee operating point of `m` on GPU type `gpu`: deployed GPU%,
/// batch, and the replica's max service rate there.
pub fn op_point(m: &ModelProfile, gpu: &GpuSpec) -> (u32, u32, f64) {
    let cfg = OptConfig::default();
    let (pct, batch) = match optimize(m, gpu, &cfg) {
        Some(op) => (op.gpu_pct, op.batch),
        None => (m.knee_pct_on(gpu, m.opt_batch), m.opt_batch),
    };
    let pct = pct.clamp(1, 100);
    let latency_ms = m.latency_ms_on(gpu, pct, batch);
    let capacity = batch as f64 / (latency_ms / 1_000.0);
    (pct, batch, capacity)
}

/// Bin-pack `profiles` (with offered rates in req/s) onto `gpus`.
///
/// Models are processed in decreasing knee-size order (ties broken by
/// offered rate, then name, then index — fully deterministic). Each
/// model receives replicas — at most one per GPU — until the placed
/// capacity covers [`CAPACITY_HEADROOM`] × its offered rate or no GPU
/// has residual knee budget *and* residual weight memory
/// (`GpuSpec::mem_mib`) for it: a statically placed replica pins its
/// weights for the whole run, so device memory is a hard second
/// capacity dimension next to knee GPU%. A model with zero replicas is
/// *rejected* (admission control); partially covered models record the
/// uncovered remainder in `shed_rps`.
pub fn place(
    profiles: &[ModelProfile],
    offered_rps: &[f64],
    gpus: &[GpuSpec],
    policy: PlacementPolicy,
) -> Placement {
    assert_eq!(
        profiles.len(),
        offered_rps.len(),
        "one offered rate per model required"
    );
    let n_models = profiles.len();
    let n_gpus = gpus.len();
    // Operating point of every model on every cluster GPU (types may
    // repeat; recomputation per index keeps the lookup trivial).
    let ops: Vec<Vec<(u32, u32, f64)>> = profiles
        .iter()
        .map(|m| gpus.iter().map(|g| op_point(m, g)).collect())
        .collect();

    // Decreasing knee size; the "size" of a model is the largest knee%
    // it demands on any GPU type present (the binding constraint).
    let size = |m: usize| ops[m].iter().map(|o| o.0).max().unwrap_or(0);
    let mut order: Vec<usize> = (0..n_models).collect();
    order.sort_by(|&a, &b| {
        size(b)
            .cmp(&size(a))
            .then(offered_rps[b].total_cmp(&offered_rps[a]))
            .then(profiles[a].name.cmp(&profiles[b].name))
            .then(a.cmp(&b))
    });

    let mut free = vec![100u32; n_gpus];
    // Hard second capacity dimension: a replica holds its model's weight
    // memory for the whole run on the static path, so a GPU can only
    // host what fits `GpuSpec::mem_mib`. (Time-shared memory is the
    // lifecycle subsystem's job — see [`plan_residency`].)
    let mut free_mem: Vec<u64> = gpus.iter().map(|g| g.mem_mib).collect();
    let mut hosted: Vec<Vec<usize>> = vec![Vec::new(); n_gpus];
    let mut replicas: Vec<Vec<Replica>> = vec![Vec::new(); n_models];
    let mut shed = vec![0.0f64; n_models];

    for &m in &order {
        let mut remaining = offered_rps[m] * CAPACITY_HEADROOM;
        loop {
            let pick = {
                let fits = (0..n_gpus).filter(|&g| {
                    free[g] >= ops[m][g].0
                        && free_mem[g] >= profiles[m].mem_mib
                        && !hosted[g].contains(&m)
                });
                match policy {
                    PlacementPolicy::FirstFitDecreasing => fits.min(),
                    // Most residual budget; ties to the lowest index.
                    PlacementPolicy::LoadBalance => {
                        fits.max_by_key(|&g| (free[g], std::cmp::Reverse(g)))
                    }
                }
            };
            let Some(g) = pick else { break };
            let (pct, batch, capacity_rps) = ops[m][g];
            let local = hosted[g].len();
            hosted[g].push(m);
            free[g] -= pct;
            free_mem[g] -= profiles[m].mem_mib;
            replicas[m].push(Replica { gpu: g, local, pct, batch, capacity_rps });
            remaining -= capacity_rps;
            if remaining <= 0.0 {
                break;
            }
        }
        shed[m] = remaining.max(0.0);
    }

    let admitted: Vec<bool> = replicas.iter().map(|r| !r.is_empty()).collect();
    let knee_load: Vec<u32> = free.iter().map(|f| 100 - f).collect();
    Placement { hosted, replicas, admitted, shed_rps: shed, knee_load }
}

/// A placement for model fleets whose working set exceeds GPU memory:
/// the assignment says which GPUs *may* serve each model (replicas are
/// engine slots, possibly tombstoned), while `resident0` says whose
/// weights are actually preloaded at t = 0 within each GPU's memory
/// budget. Everything else time-shares memory through the lifecycle
/// [`crate::lifecycle::ModelStore`] (cold loads + eviction).
#[derive(Debug, Clone)]
pub struct ResidencyPlan {
    /// The assignment (admission, replicas, engine layout). `knee_load`
    /// here is the *sum of assigned knees* and may exceed 100 — assigned
    /// models time-share the GPU temporally; the per-GPU scheduler never
    /// runs more than 100% concurrently.
    pub placement: Placement,
    /// gpu → global models preloaded (warm) at t = 0, hottest first,
    /// greedily filled within `mem_budget_mib`.
    pub resident0: Vec<Vec<usize>>,
    /// Per-GPU resident-memory budget the plan was solved for (MiB).
    pub mem_budget_mib: Vec<u64>,
}

/// Assign a (possibly memory-oversubscribed) model fleet to `gpus` for
/// lifecycle-managed serving.
///
/// Unlike [`place`], the packed quantity is *effective* knee load —
/// knee GPU% × the fraction of time the model is actually busy
/// (`offered × `[`CAPACITY_HEADROOM`]` / capacity`, capped at 1) — since
/// a long-tail model only holds its knee while a batch runs. Models are
/// assigned hottest-first; each receives up to
/// `min_replicas.min(feasible GPUs)` replicas (availability / routing
/// choice — best-effort: later models get fewer when earlier ones have
/// exhausted the effective knee budget) and more while placed capacity
/// still trails headroomed demand. A GPU is feasible for a model only
/// if the model's weights
/// fit its memory budget at all (otherwise the replica could never be
/// made resident). Models with zero feasible replicas are rejected.
///
/// The initial resident set greedily preloads each GPU's assigned
/// models, hottest first, until the memory budget is exhausted — the
/// long tail starts cold and is faulted in on demand.
pub fn plan_residency(
    profiles: &[ModelProfile],
    offered_rps: &[f64],
    gpus: &[GpuSpec],
    policy: PlacementPolicy,
    mem_budget_mib: &[u64],
    min_replicas: usize,
) -> ResidencyPlan {
    plan_residency_biased(
        profiles,
        offered_rps,
        gpus,
        policy,
        mem_budget_mib,
        min_replicas,
        |_, _| false,
    )
}

/// [`plan_residency`] with a residency bias: `is_warm(gpu, model)`
/// reports whether the model's weights are *currently* loaded on that
/// GPU, and the packer prefers warm targets so a mid-flight replan
/// (the unified control plane's drift/eviction-pressure replans) moves
/// replicas onto GPUs where the weights already sit — a warm replica
/// costs zero `cold_load_ms`, a cold one pays the full footprint.
///
/// The bias is a *preference*, not a constraint: FFD tie-breaks its
/// first-fit scan warm-before-cold (then lowest index), LoadBalance
/// picks warm GPUs first and only then falls back to most-residual
/// budget. With a constant-`false` predicate the selection collapses to
/// the unbiased packer exactly, which is how [`plan_residency`] keeps
/// its historical (golden-covered) output byte-identical.
pub fn plan_residency_biased(
    profiles: &[ModelProfile],
    offered_rps: &[f64],
    gpus: &[GpuSpec],
    policy: PlacementPolicy,
    mem_budget_mib: &[u64],
    min_replicas: usize,
    is_warm: impl Fn(usize, usize) -> bool,
) -> ResidencyPlan {
    assert_eq!(profiles.len(), offered_rps.len(), "one offered rate per model required");
    assert_eq!(gpus.len(), mem_budget_mib.len(), "one memory budget per GPU required");
    assert!(min_replicas >= 1, "min_replicas must be >= 1");
    let n_models = profiles.len();
    let n_gpus = gpus.len();
    let ops: Vec<Vec<(u32, u32, f64)>> = profiles
        .iter()
        .map(|m| gpus.iter().map(|g| op_point(m, g)).collect())
        .collect();
    // Effective knee load of one replica of model m on gpu g.
    let eff = |m: usize, g: usize| -> f64 {
        let (pct, _, cap) = ops[m][g];
        let busy = (offered_rps[m] * CAPACITY_HEADROOM / cap.max(1e-9)).min(1.0);
        pct as f64 * busy
    };

    // Hottest first (ties by name, then index — deterministic). One
    // comparator for both the assignment order and the resident0
    // preload order, so the two can never desynchronize.
    let hotter = |a: &usize, b: &usize| {
        offered_rps[*b]
            .total_cmp(&offered_rps[*a])
            .then(profiles[*a].name.cmp(&profiles[*b].name))
            .then(a.cmp(b))
    };
    let mut order: Vec<usize> = (0..n_models).collect();
    order.sort_by(hotter);

    let mut free_eff = vec![100.0f64; n_gpus];
    let mut hosted: Vec<Vec<usize>> = vec![Vec::new(); n_gpus];
    let mut replicas: Vec<Vec<Replica>> = vec![Vec::new(); n_models];
    let mut shed = vec![0.0f64; n_models];

    for &m in &order {
        let feasible_gpus =
            (0..n_gpus).filter(|&g| profiles[m].mem_mib <= mem_budget_mib[g]).count();
        let want = min_replicas.min(feasible_gpus);
        let mut remaining = offered_rps[m] * CAPACITY_HEADROOM;
        let mut placed = 0usize;
        loop {
            if placed >= want && remaining <= 0.0 {
                break;
            }
            let pick = {
                let fits = (0..n_gpus).filter(|&g| {
                    profiles[m].mem_mib <= mem_budget_mib[g]
                        && free_eff[g] >= eff(m, g)
                        && !hosted[g].contains(&m)
                });
                // Residency bias: warm GPUs sort strictly before cold
                // ones under both disciplines; with no warm GPU the
                // selection is identical to the unbiased packer.
                match policy {
                    PlacementPolicy::FirstFitDecreasing => {
                        fits.min_by_key(|&g| (!is_warm(g, m), g))
                    }
                    PlacementPolicy::LoadBalance => fits.max_by(|&a, &b| {
                        is_warm(a, m)
                            .cmp(&is_warm(b, m))
                            .then(free_eff[a].total_cmp(&free_eff[b]))
                            .then(b.cmp(&a)) // ties to the lowest index
                    }),
                }
            };
            let Some(g) = pick else { break };
            let (pct, batch, capacity_rps) = ops[m][g];
            let local = hosted[g].len();
            hosted[g].push(m);
            free_eff[g] -= eff(m, g);
            replicas[m].push(Replica { gpu: g, local, pct, batch, capacity_rps });
            remaining -= capacity_rps;
            placed += 1;
        }
        shed[m] = remaining.max(0.0);
    }

    // Σ assigned knee% per GPU (> 100 is fine: temporal sharing).
    let mut knee_load = vec![0u32; n_gpus];
    for (g, models) in hosted.iter().enumerate() {
        for &m in models {
            knee_load[g] += ops[m][g].0;
        }
    }
    // Preload hottest-first within each GPU's budget.
    let mut resident0: Vec<Vec<usize>> = vec![Vec::new(); n_gpus];
    for g in 0..n_gpus {
        let mut by_heat = hosted[g].clone();
        by_heat.sort_by(hotter);
        let mut used = 0u64;
        for m in by_heat {
            if used + profiles[m].mem_mib <= mem_budget_mib[g] {
                used += profiles[m].mem_mib;
                resident0[g].push(m);
            }
        }
    }

    let admitted: Vec<bool> = replicas.iter().map(|r| !r.is_empty()).collect();
    ResidencyPlan {
        placement: Placement { hosted, replicas, admitted, shed_rps: shed, knee_load },
        resident0,
        mem_budget_mib: mem_budget_mib.to_vec(),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::{by_name, T4, V100};

    fn models(names: &[&str]) -> Vec<ModelProfile> {
        names.iter().map(|n| by_name(n).unwrap()).collect()
    }

    #[test]
    fn knee_budget_never_oversubscribed() {
        let ms = models(&["mobilenet", "alexnet", "resnet50", "vgg19"]);
        let rates = [150.0, 150.0, 900.0, 450.0];
        for &pol in PlacementPolicy::all() {
            for gpus in [vec![T4.clone(); 4], vec![V100.clone(), V100.clone(), T4.clone(), T4.clone()]] {
                let p = place(&ms, &rates, &gpus, pol);
                for (g, load) in p.knee_load.iter().enumerate() {
                    assert!(*load <= 100, "{pol:?}: gpu {g} packed to {load}%");
                }
            }
        }
    }

    #[test]
    fn hot_models_get_replicated() {
        // ResNet-50 at 900 req/s needs more than one replica's capacity
        // on either GPU type.
        let ms = models(&["mobilenet", "alexnet", "resnet50", "vgg19"]);
        let rates = [150.0, 150.0, 900.0, 450.0];
        let p = place(
            &ms,
            &rates,
            &[V100.clone(), V100.clone(), T4.clone(), T4.clone()],
            PlacementPolicy::FirstFitDecreasing,
        );
        let r50 = 2; // index in `ms`
        assert!(p.replicas[r50].len() >= 2, "resnet50 replicas: {}", p.replicas[r50].len());
        assert!(p.admitted.iter().all(|&a| a), "everything fits this cluster");
        // Replica capacity actually covers the (headroomed) demand.
        assert!(p.capacity_rps(r50) >= 900.0, "{}", p.capacity_rps(r50));
        assert!(p.shed_rps[r50] == 0.0);
    }

    #[test]
    fn admission_rejects_when_cluster_full() {
        // One T4 cannot host the whole heavy zoo: something is rejected
        // or shed, and rejected models have no replicas.
        let ms = models(&["vgg19", "resnext50", "resnet50", "inception", "mobilenet"]);
        let rates = [400.0; 5];
        let p = place(&ms, &rates, &[T4.clone()], PlacementPolicy::FirstFitDecreasing);
        let placed_pct: u32 = p.knee_load[0];
        assert!(placed_pct <= 100);
        let rejected: Vec<usize> =
            (0..ms.len()).filter(|&m| !p.admitted[m]).collect();
        let shed: f64 = p.shed_rps.iter().sum();
        assert!(
            !rejected.is_empty() || shed > 0.0,
            "five heavy models at 400/s cannot fully fit one T4"
        );
        for &m in &rejected {
            assert!(p.replicas[m].is_empty());
        }
    }

    #[test]
    fn load_balance_spreads_vs_ffd_packs() {
        // Two light models on two GPUs: FFD stacks both onto GPU 0,
        // load-balancing puts one on each.
        let ms = models(&["mobilenet", "alexnet"]);
        let rates = [50.0, 50.0];
        let gpus = [V100.clone(), V100.clone()];
        let ffd = place(&ms, &rates, &gpus, PlacementPolicy::FirstFitDecreasing);
        let lb = place(&ms, &rates, &gpus, PlacementPolicy::LoadBalance);
        assert_eq!(ffd.knee_load[1], 0, "ffd leaves gpu 1 empty: {:?}", ffd.knee_load);
        assert!(lb.knee_load[0] > 0 && lb.knee_load[1] > 0, "{:?}", lb.knee_load);
    }

    #[test]
    fn heterogeneous_op_points_differ() {
        let m = by_name("vgg19").unwrap();
        let (pct_v, _, cap_v) = op_point(&m, &V100);
        let (pct_t, _, cap_t) = op_point(&m, &T4);
        assert!(pct_t > pct_v, "T4 knee% {pct_t} vs V100 {pct_v}");
        assert!(cap_v > cap_t, "V100 capacity {cap_v} vs T4 {cap_t}");
    }

    #[test]
    fn memory_is_a_hard_placement_dimension() {
        // Plenty of knee budget, almost no memory: only what fits the
        // small device's RAM may be placed there.
        let small = GpuSpec { mem_mib: 1_500, ..V100 };
        let ms = models(&["mobilenet", "vgg19"]); // 600 + 2200 MiB
        let rates = [50.0, 50.0];
        let p = place(&ms, &rates, &[small], PlacementPolicy::FirstFitDecreasing);
        assert!(p.admitted[0], "mobilenet (600 MiB) fits");
        assert!(!p.admitted[1], "vgg19 (2200 MiB) cannot fit 1.5 GiB");
        // With enough memory the same knee budget admits both.
        let p2 = place(&ms, &rates, &[V100.clone()], PlacementPolicy::FirstFitDecreasing);
        assert!(p2.admitted.iter().all(|&a| a));
    }

    #[test]
    fn residency_plan_timeshares_memory() {
        // 6 models × ~1-2 GiB against a 3 GiB budget per GPU: all are
        // admitted (assigned), but only a prefix is resident at t = 0.
        let ms = models(&["mobilenet", "alexnet", "resnet50", "vgg19", "inception", "resnet18"]);
        let rates = [200.0, 100.0, 50.0, 25.0, 12.0, 6.0];
        let gpus = [V100.clone(), V100.clone()];
        let budgets = [3_000u64, 3_000];
        let plan = plan_residency(
            &ms,
            &rates,
            &gpus,
            PlacementPolicy::LoadBalance,
            &budgets,
            2,
        );
        assert!(plan.placement.admitted.iter().all(|&a| a), "everything is assignable");
        for (m, reps) in plan.placement.replicas.iter().enumerate() {
            assert!(reps.len() >= 2, "model {m} should get 2 replicas for routing choice");
        }
        // The resident sets respect the budget and cover < all models.
        let total_mem: u64 = ms.iter().map(|p| p.mem_mib).sum();
        assert!(total_mem * 2 > budgets[0] + budgets[1], "working set oversubscribes memory");
        for g in 0..2 {
            let used: u64 =
                plan.resident0[g].iter().map(|&m| ms[m].mem_mib).sum();
            assert!(used <= budgets[g], "gpu {g} preloads {used} > {}", budgets[g]);
            assert!(!plan.resident0[g].is_empty(), "gpu {g} starts fully cold");
            assert!(
                plan.resident0[g].len() < plan.placement.hosted[g].len(),
                "gpu {g}: everything resident — not a time-sharing regime"
            );
            // Preloads are a subset of the assignment.
            for m in &plan.resident0[g] {
                assert!(plan.placement.hosted[g].contains(m));
            }
        }
        // Hottest model is warm somewhere at t = 0.
        assert!(plan.resident0.iter().any(|r| r.contains(&0)), "hottest model starts cold");
    }

    #[test]
    fn residency_plan_rejects_memory_infeasible_models() {
        // A model bigger than every GPU's budget can never become
        // resident — it must be rejected, not assigned.
        let ms = models(&["mobilenet", "vgg19"]);
        let rates = [50.0, 50.0];
        let gpus = [V100.clone()];
        let plan = plan_residency(
            &ms,
            &rates,
            &gpus,
            PlacementPolicy::FirstFitDecreasing,
            &[1_000],
            1,
        );
        assert!(plan.placement.admitted[0]);
        assert!(!plan.placement.admitted[1], "vgg19 can never fit a 1 GiB budget");
        assert!(plan.placement.replicas[1].is_empty());
    }

    #[test]
    fn residency_bias_prefers_warm_targets() {
        // Two identical GPUs, one light model wanting a single replica:
        // the unbiased packer (FFD) picks GPU 0; telling the packer the
        // weights are warm on GPU 1 flips the choice — and the
        // constant-false predicate reproduces plan_residency exactly.
        let ms = models(&["mobilenet"]);
        let rates = [50.0];
        let gpus = [V100.clone(), V100.clone()];
        let budgets = [8_000u64, 8_000];
        for &pol in PlacementPolicy::all() {
            let cold = plan_residency(&ms, &rates, &gpus, pol, &budgets, 1);
            let same =
                plan_residency_biased(&ms, &rates, &gpus, pol, &budgets, 1, |_, _| false);
            assert_eq!(
                format!("{:?}", cold.placement.hosted),
                format!("{:?}", same.placement.hosted),
                "{pol:?}: false predicate must not change the plan"
            );
            let warm =
                plan_residency_biased(&ms, &rates, &gpus, pol, &budgets, 1, |g, _| g == 1);
            assert_eq!(
                warm.placement.replicas[0][0].gpu, 1,
                "{pol:?}: warm GPU 1 should win the placement"
            );
        }
    }

    #[test]
    fn replica_bookkeeping_consistent() {
        let ms = models(&["mobilenet", "alexnet", "resnet50", "vgg19"]);
        let rates = [150.0, 150.0, 900.0, 450.0];
        for &pol in PlacementPolicy::all() {
            let p = place(&ms, &rates, &[T4.clone(); 4], pol);
            for (m, reps) in p.replicas.iter().enumerate() {
                for r in reps {
                    assert_eq!(p.hosted[r.gpu][r.local], m, "{pol:?}: hosted/replica mismatch");
                }
                // At most one replica of a model per GPU.
                let mut gpus_used: Vec<usize> = reps.iter().map(|r| r.gpu).collect();
                gpus_used.sort_unstable();
                gpus_used.dedup();
                assert_eq!(gpus_used.len(), reps.len(), "{pol:?}: duplicate replica on a gpu");
            }
        }
    }
}
