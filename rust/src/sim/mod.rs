//! Discrete-event serving simulator.
//!
//! Drives a scheduling [`Policy`] against a pre-generated open-loop
//! request stream in virtual time on a [`GpuSim`]: the engine advances
//! between arrivals, batch completions and policy-requested timer
//! wakeups; after every event it repeatedly asks the policy for launch
//! decisions until quiescence. All paper-scale experiments (Tables 1/3,
//! Figs. 9–12) run through this engine with calibrated latency profiles.

use crate::gpu::{ms_to_us, GpuSim, Us};
use crate::metrics::{ModelMetrics, RunReport};
use crate::obs::{EngineObs, EventKind, ObsCfg, Recorder};
use crate::profile::{GpuSpec, ModelProfile};
use crate::workload::Request;
use std::collections::{BTreeSet, BinaryHeap, VecDeque};

/// A model admitted to the system, with its deployed operating point
/// (from the §5 optimizer, or policy-specific).
#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub profile: ModelProfile,
    /// Deployed GPU% (knee + headroom for D-STACK/GSLICE; ignored by
    /// temporal policies which always use 100%).
    pub pct: u32,
    /// Deployed batch size from the optimizer.
    pub batch: u32,
}

/// A launch decision returned by a policy.
#[derive(Debug, Clone)]
pub struct Launch {
    pub model: usize,
    pub batch: u32,
    pub pct: u32,
    /// Override the duration (ms). Policies that model interference
    /// (default-MPS Fixed-Batch) or add switching overheads use this;
    /// `None` uses the profile's f_L(pct, batch).
    pub latency_ms_override: Option<f64>,
}

/// Read-only view of simulator state handed to policies.
pub struct SimView<'a> {
    pub now: Us,
    pub horizon_us: Us,
    pub queues: &'a [VecDeque<Request>],
    pub gpu: &'a GpuSim,
    pub models: &'a [ModelEntry],
    /// Per-model liveness (control-plane reconfiguration): inactive
    /// models are tombstones — they receive no traffic and must not be
    /// given planned capacity or time slices.
    pub active: &'a [bool],
}

impl<'a> SimView<'a> {
    pub fn queue_len(&self, model: usize) -> usize {
        self.queues[model].len()
    }

    /// Is `model` currently serving (not a reconfiguration tombstone)?
    pub fn is_active(&self, model: usize) -> bool {
        self.active[model]
    }

    /// Earliest-deadline request currently queued for `model` (queues
    /// are FIFO in arrival order, so this is the head).
    pub fn oldest_deadline(&self, model: usize) -> Option<Us> {
        self.queues[model].front().map(|r| r.deadline)
    }

    /// Remaining ms until the oldest queued request's deadline.
    pub fn deadline_budget_ms(&self, model: usize) -> Option<f64> {
        self.oldest_deadline(model)
            .map(|d| if d > self.now { (d - self.now) as f64 / 1_000.0 } else { 0.0 })
    }
}

/// Scheduling policy interface. Implementations live in [`crate::sched`].
///
/// `Send` is a supertrait: the cluster execution core
/// (`cluster::exec`) fans per-GPU engines — each a [`Sim`] plus its
/// boxed policy — out to a worker pool between barriers, so policies
/// must be movable across threads. All implementations are plain owned
/// data; `rust/tests/parallel_exec.rs` pins the bound for each one.
pub trait Policy: Send {
    fn name(&self) -> String;

    /// Return launches to perform *now*. Called repeatedly after every
    /// event until it returns an empty vector. The engine validates each
    /// launch (queue occupancy, GPU capacity) and performs it.
    fn dispatch(&mut self, view: &SimView) -> Vec<Launch>;

    /// Next virtual time this policy wants a wakeup (slice boundaries,
    /// session starts). Queried after each quiescent dispatch round.
    fn next_wakeup(&mut self, _view: &SimView) -> Option<Us> {
        None
    }

    /// Notification that a batch of `model` completed.
    fn on_complete(&mut self, _model: usize, _now: Us) {}
}

/// Simulator configuration.
#[derive(Debug, Clone)]
pub struct SimConfig {
    pub gpu: GpuSpec,
    pub horizon_ms: f64,
    /// Record a Gantt log (Fig. 9 visualizations).
    pub gantt: bool,
    /// Shed requests whose deadline has passed before service started.
    /// Default *false*: the paper's systems serve late requests and count
    /// them as SLO violations ("requests that violate the SLO"), with
    /// "unserved" only those still queued when the run ends.
    pub drop_expired: bool,
    /// Allow aggregate GPU% > 100 (uncontrolled default MPS baseline).
    pub allow_oversub: bool,
    /// Observability: event tracing, windowed time-series, and the
    /// exact-vs-histogram latency switch (see [`crate::obs`]). The
    /// default records nothing and keeps the exact vectors — byte-
    /// identical behavior to a pre-observability build.
    pub obs: ObsCfg,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            gpu: crate::profile::V100.clone(),
            horizon_ms: 10_000.0,
            gantt: false,
            drop_expired: false,
            allow_oversub: false,
            obs: ObsCfg::default(),
        }
    }
}

#[derive(Debug)]
struct Completion {
    t: Us,
    seq: u64,
    inst: u64,
    model: usize,
    reqs: Vec<Request>,
}

impl PartialEq for Completion {
    fn eq(&self, o: &Self) -> bool {
        (self.t, self.seq) == (o.t, o.seq)
    }
}
impl Eq for Completion {}
impl PartialOrd for Completion {
    fn partial_cmp(&self, o: &Self) -> Option<std::cmp::Ordering> {
        Some(self.cmp(o))
    }
}
impl Ord for Completion {
    fn cmp(&self, o: &Self) -> std::cmp::Ordering {
        // Reversed for min-heap behavior inside BinaryHeap.
        (o.t, o.seq).cmp(&(self.t, self.seq))
    }
}

/// The simulator itself.
pub struct Sim {
    pub cfg: SimConfig,
    pub models: Vec<ModelEntry>,
    pub gpu: GpuSim,
    queues: Vec<VecDeque<Request>>,
    metrics: Vec<ModelMetrics>,
    /// Per-model liveness under runtime reconfiguration: a deactivated
    /// model keeps its slot (stable indices for metrics, queues and the
    /// policy view) but receives no new traffic — see
    /// [`Self::deactivate_model`].
    active: Vec<bool>,
    completions: BinaryHeap<Completion>,
    timers: BTreeSet<Us>,
    seq: u64,
    now: Us,
    last_completion: Us,
    /// This engine's observability lane (see [`crate::obs`]): records
    /// enqueue/complete/drop events and occupancy spans at the engine's
    /// own state-mutation points — whose sequence is a pure function of
    /// the scenario, so traces are exec-mode- and thread-invariant.
    obs: Recorder,
}

impl Sim {
    pub fn new(cfg: SimConfig, models: Vec<ModelEntry>) -> Sim {
        let n = models.len();
        let mut gpu = GpuSim::new(cfg.gpu.clone(), n, cfg.gantt);
        gpu.allow_oversub = cfg.allow_oversub;
        let metrics = models
            .iter()
            .map(|m| ModelMetrics { name: m.profile.name.clone(), ..Default::default() })
            .collect();
        let obs = Recorder::new(cfg.obs, ms_to_us(cfg.horizon_ms));
        Sim {
            cfg,
            models,
            gpu,
            queues: vec![VecDeque::new(); n],
            metrics,
            active: vec![true; n],
            completions: BinaryHeap::new(),
            timers: BTreeSet::new(),
            seq: 0,
            now: 0,
            last_completion: 0,
            obs,
        }
    }

    /// Hand over this engine's finished observability lane (events,
    /// windows, model-name table). Drivers call this once, after
    /// [`Self::finalize`].
    pub fn take_obs(&mut self) -> EngineObs {
        let names = self.metrics.iter().map(|m| m.name.clone()).collect();
        self.obs.finish(names)
    }

    /// Record one completed request into metrics + observability — the
    /// single code path `step_to` and `finalize` share, so both stamp
    /// identical events at the completion's own virtual time.
    fn note_completion(&mut self, t: Us, model: usize, r: &Request) {
        let exact = self.cfg.obs.exact_latencies;
        let lat_ms = (t - r.arrival) as f64 / 1_000.0;
        let in_slo = t <= r.deadline;
        let m = &mut self.metrics[model];
        m.served += 1;
        if in_slo {
            m.served_in_slo += 1;
        }
        if exact {
            m.latencies_ms.push(lat_ms);
            m.completions_us.push(t);
        } else {
            m.latency_hist.push(lat_ms);
        }
        if self.obs.on() {
            self.obs.event(EventKind::Complete, t, model as u32, r.id, t - r.arrival);
            self.obs.count_completion(t, model, lat_ms, in_slo);
        }
    }

    /// Append a model at runtime (cluster rebalancing): fresh local slot
    /// at the end of the table, empty queue, zeroed metrics. Returns the
    /// new local index. To bring back a retired model, use
    /// [`Self::reactivate_model`] on its tombstone instead — metrics
    /// then keep accumulating for the same logical model.
    pub fn add_model(&mut self, entry: ModelEntry) -> usize {
        let i = self.models.len();
        self.metrics
            .push(ModelMetrics { name: entry.profile.name.clone(), ..Default::default() });
        self.models.push(entry);
        self.queues.push(VecDeque::new());
        self.active.push(true);
        self.gpu.grow_models(self.models.len());
        i
    }

    /// Re-activate a retired model in place, with a (possibly updated)
    /// operating point. The slot must be a tombstone left by
    /// [`Self::deactivate_model`] for the same model.
    pub fn reactivate_model(&mut self, local: usize, entry: ModelEntry) {
        assert!(!self.active[local], "reactivating an active model {local}");
        debug_assert_eq!(
            self.models[local].profile.name, entry.profile.name,
            "tombstone holds a different model"
        );
        self.models[local] = entry;
        self.active[local] = true;
    }

    /// Retire a model at runtime: it keeps its slot (indices stay stable
    /// for the policy and for in-flight completions, which still finish
    /// and are counted here) but its queued requests are handed back to
    /// the caller for re-routing. The caller must stop injecting for
    /// this local index until a matching [`Self::reactivate_model`].
    pub fn deactivate_model(&mut self, local: usize) -> Vec<Request> {
        debug_assert!(self.active[local], "deactivating an inactive model {local}");
        self.active[local] = false;
        self.queues[local].drain(..).collect()
    }

    /// Requests queued for `local` that arrived at or before `cutoff` —
    /// the "stuck past the hedge threshold" count the resilience sweep
    /// probes before deciding whether to pull anything
    /// ([`crate::faults`]). Queues are FIFO by arrival, so this is a
    /// prefix count.
    pub fn queued_before(&self, local: usize, cutoff: Us) -> usize {
        self.queues[local].iter().take_while(|r| r.arrival <= cutoff).count()
    }

    /// Remove and return the queued prefix that arrived at or before
    /// `cutoff`, oldest first. The hedged-dispatch path uses this to
    /// move stuck requests off a degraded engine once a strictly better
    /// replica is known — pulling only after the target is chosen keeps
    /// the FIFO-by-arrival queue invariant (re-injecting into the same
    /// queue would reorder it). In-flight batches are untouched.
    pub fn take_queued_before(&mut self, local: usize, cutoff: Us) -> Vec<Request> {
        let mut out = Vec::new();
        while self.queues[local].front().is_some_and(|r| r.arrival <= cutoff) {
            out.push(self.queues[local].pop_front().unwrap());
        }
        out
    }

    /// Is the local model currently accepting traffic?
    pub fn is_active(&self, local: usize) -> bool {
        self.active[local]
    }

    /// Per-slot liveness snapshot — what cluster control planes mask
    /// policy rebuilds on after tombstone surgery (see
    /// [`crate::controlplane`] and [`crate::lifecycle`]).
    pub fn active_mask(&self) -> Vec<bool> {
        self.active.clone()
    }

    /// Current virtual time (µs).
    pub fn now(&self) -> Us {
        self.now
    }

    /// Earliest pending *internal* event — batch completion or policy
    /// timer. Arrivals are the caller's concern ([`Self::inject`]); a
    /// cluster-level driver uses this to interleave several engines in
    /// one global virtual clock.
    pub fn next_event_time(&self) -> Option<Us> {
        let t_comp = self.completions.peek().map(|c| c.t);
        let t_timer = self.timers.first().copied();
        [t_comp, t_timer].into_iter().flatten().min()
    }

    /// Enqueue a request (its `model` field indexes this engine's local
    /// model table). Routed cluster traffic and `run`'s own stream
    /// arrivals both enter through here.
    pub fn inject(&mut self, r: Request) {
        debug_assert!(r.model < self.queues.len(), "inject: unknown local model {}", r.model);
        if self.obs.on() {
            self.obs.event(EventKind::Enqueue, r.arrival, r.model as u32, r.id, 0);
            self.obs.count_arrival(r.arrival);
        }
        self.queues[r.model].push_back(r);
    }

    /// Requests queued plus items currently in flight for `model` — the
    /// load signal a cluster router (JSQ / power-of-two) samples.
    pub fn backlog_items(&self, model: usize) -> usize {
        let in_flight: usize = self
            .gpu
            .running()
            .iter()
            .filter(|r| r.model == model)
            .map(|r| r.batch as usize)
            .sum();
        self.queues[model].len() + in_flight
    }

    /// Advance virtual time to `t` (≥ now): process completions and
    /// timers due by `t`, shed expired requests if configured, then run
    /// the policy to quiescence. The caller injects any arrivals at `t`
    /// *before* this call so the dispatch round sees them — the same
    /// ordering `run` has always used.
    pub fn step_to(&mut self, t: Us, policy: &mut dyn Policy, horizon: Us) {
        debug_assert!(t >= self.now, "step_to going backwards: {t} < {}", self.now);
        self.now = t;
        while self.completions.peek().is_some_and(|c| c.t <= t) {
            let c = self.completions.pop().unwrap();
            self.gpu.complete(t, c.inst);
            self.last_completion = self.last_completion.max(c.t);
            for r in &c.reqs {
                self.note_completion(t, c.model, r);
            }
            policy.on_complete(c.model, t);
        }
        while self.timers.first().is_some_and(|&w| w <= t) {
            self.timers.pop_first();
        }
        self.prune_expired();
        self.dispatch_until_quiescent(policy, horizon);
    }

    /// Horizon wrap-up: drain batches still in flight (they started
    /// before the horizon; count them at their true completion time so
    /// request conservation holds: served + dropped = offered), drop
    /// anything still queued, and emit the report.
    pub fn finalize(&mut self, policy_name: String, horizon: Us) -> RunReport {
        self.now = horizon;
        while let Some(c) = self.completions.pop() {
            self.last_completion = self.last_completion.max(c.t);
            for r in &c.reqs {
                self.note_completion(c.t, c.model, r);
            }
        }
        // Anything still queued at the horizon was never served.
        for q in 0..self.queues.len() {
            self.metrics[q].dropped += self.queues[q].len() as u64;
            if self.obs.on() {
                while let Some(r) = self.queues[q].pop_front() {
                    self.obs.event(EventKind::Drop, horizon, q as u32, r.id, 0);
                    self.obs.count_drop(horizon);
                }
            }
            self.queues[q].clear();
        }
        let util = self.gpu.utilization(horizon);
        RunReport {
            policy: policy_name,
            horizon_us: horizon,
            per_model: self.metrics.clone(),
            gpu_utilization: vec![util],
            busy_ms: self.gpu.busy_ms(),
            last_completion_us: self.last_completion,
        }
    }

    /// Run `policy` over the (time-sorted) request stream; returns the
    /// run report at the horizon. Implemented on the incremental
    /// primitives above — single-GPU behavior is unchanged.
    pub fn run(&mut self, policy: &mut dyn Policy, requests: &[Request]) -> RunReport {
        let horizon = ms_to_us(self.cfg.horizon_ms);
        let mut cursor = 0usize;
        debug_assert!(requests.windows(2).all(|w| w[0].arrival <= w[1].arrival));

        loop {
            let t_arr = requests.get(cursor).map(|r| r.arrival);
            let t_next = [t_arr, self.next_event_time()].into_iter().flatten().min();
            let Some(t) = t_next else { break };
            if t >= horizon {
                break;
            }
            while requests.get(cursor).is_some_and(|r| r.arrival <= t) {
                self.inject(requests[cursor].clone());
                cursor += 1;
            }
            self.step_to(t, policy, horizon);
        }

        self.finalize(policy.name(), horizon)
    }

    fn prune_expired(&mut self) {
        if !self.cfg.drop_expired {
            return;
        }
        let now = self.now;
        for (i, q) in self.queues.iter_mut().enumerate() {
            while q.front().is_some_and(|r| r.deadline < now) {
                let r = q.pop_front().unwrap();
                self.metrics[i].dropped += 1;
                if self.obs.on() {
                    self.obs.event(EventKind::Drop, now, i as u32, r.id, 0);
                    self.obs.count_drop(now);
                }
            }
        }
    }

    fn dispatch_until_quiescent(&mut self, policy: &mut dyn Policy, horizon: Us) {
        loop {
            let view = SimView {
                now: self.now,
                horizon_us: horizon,
                queues: &self.queues,
                gpu: &self.gpu,
                models: &self.models,
                active: &self.active,
            };
            let launches = policy.dispatch(&view);
            if launches.is_empty() {
                break;
            }
            for l in launches {
                self.do_launch(l);
            }
        }
        // Ask for a wakeup after quiescence.
        let view = SimView {
            now: self.now,
            horizon_us: horizon,
            queues: &self.queues,
            gpu: &self.gpu,
            models: &self.models,
            active: &self.active,
        };
        if let Some(w) = policy.next_wakeup(&view) {
            if w > self.now && w < horizon {
                self.timers.insert(w);
            }
        }
    }

    fn do_launch(&mut self, l: Launch) {
        let entry = &self.models[l.model];
        let avail = self.queues[l.model].len() as u32;
        assert!(l.batch >= 1, "empty launch for model {}", l.model);
        assert!(
            l.batch <= avail,
            "policy launched batch {} with only {avail} queued (model {})",
            l.batch,
            l.model
        );
        let reqs: Vec<Request> =
            (0..l.batch).map(|_| self.queues[l.model].pop_front().unwrap()).collect();
        let lat_ms = l
            .latency_ms_override
            .unwrap_or_else(|| entry.profile.latency_ms_on(&self.gpu.spec, l.pct, l.batch));
        let dur = ms_to_us(lat_ms).max(1);
        // Useful SM fraction: beyond the model's knee at this batch the
        // extra SMs idle (the paper computes utilization via Knee%).
        let useful = l.pct.min(entry.profile.knee_pct_on(&self.gpu.spec, l.batch));
        let inst = self.gpu.launch_useful(self.now, l.model, l.batch, l.pct, useful, dur);
        if self.obs.on() {
            let (model, batch) = (l.model as u32, l.batch as u64);
            self.obs.span(EventKind::Batch, self.now, model, batch, dur, l.pct, useful);
            self.obs.count_span(self.now, dur, useful, l.batch);
        }
        let m = &mut self.metrics[l.model];
        m.batches += 1;
        m.batch_items += l.batch as u64;
        self.seq += 1;
        self.completions.push(Completion {
            t: self.now + dur,
            seq: self.seq,
            inst,
            model: l.model,
            reqs,
        });
    }
}

/// Convenience: build [`ModelEntry`]s at each profile's optimizer point.
///
/// Uses the *knee* operating point (no §5.1 deploy headroom): when
/// multiplexing, over-provisioned GPU% destroys the spatio-temporal
/// packing (the Table 6 knees 20+30+40+50 admit a feasible session plan;
/// +5% each does not). The headroom rule is for single-model deployment
/// — use [`crate::optimizer::deploy_point`] there.
pub fn entries_at_optimum(profiles: &[ModelProfile]) -> Vec<ModelEntry> {
    use crate::optimizer::{optimize, OptConfig};
    profiles
        .iter()
        .map(|p| {
            let cfg = OptConfig::default();
            match optimize(p, &crate::profile::V100, &cfg) {
                Some(op) => ModelEntry { profile: p.clone(), pct: op.gpu_pct, batch: op.batch },
                None => ModelEntry { profile: p.clone(), pct: p.knee_pct, batch: p.opt_batch },
            }
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::by_name;
    use crate::workload::{merged_stream, Arrivals};

    /// Greedy test policy: run any queued model at its deployed point
    /// whenever capacity allows.
    struct Greedy;

    impl Policy for Greedy {
        fn name(&self) -> String {
            "greedy".into()
        }

        fn dispatch(&mut self, v: &SimView) -> Vec<Launch> {
            for (i, e) in v.models.iter().enumerate() {
                let queued = v.queue_len(i) as u32;
                if queued == 0 || v.gpu.n_running_of(i) > 0 {
                    continue;
                }
                if v.gpu.free_pct() >= e.pct {
                    let b = queued.min(e.batch);
                    return vec![Launch {
                        model: i,
                        batch: b,
                        pct: e.pct,
                        latency_ms_override: None,
                    }];
                }
            }
            Vec::new()
        }
    }

    fn setup(names: &[&str], rate: f64, horizon_ms: f64, seed: u64) -> (Sim, Vec<Request>) {
        let profiles: Vec<_> = names.iter().map(|n| by_name(n).unwrap()).collect();
        let entries = entries_at_optimum(&profiles);
        let specs: Vec<_> = profiles
            .iter()
            .map(|p| (Arrivals::Poisson { rate }, p.slo_ms))
            .collect();
        let reqs = merged_stream(&specs, horizon_ms, seed);
        let cfg = SimConfig { horizon_ms, ..Default::default() };
        (Sim::new(cfg, entries), reqs)
    }

    #[test]
    fn serves_requests_and_accounts() {
        let (mut sim, reqs) = setup(&["alexnet", "mobilenet"], 200.0, 2_000.0, 11);
        let total = reqs.len() as u64;
        let mut pol = Greedy;
        let rep = sim.run(&mut pol, &reqs);
        let served: u64 = rep.per_model.iter().map(|m| m.served).sum();
        let dropped: u64 = rep.per_model.iter().map(|m| m.dropped).sum();
        // Conservation: every request is served or dropped (none lost).
        assert_eq!(served + dropped, total);
        assert!(served > 0);
        // Alexnet at 200/s with batch≈16 @8ms is easily sustainable.
        assert!(
            rep.per_model[0].served as f64 / total as f64 > 0.3,
            "{:?}",
            rep.per_model.iter().map(|m| m.served).collect::<Vec<_>>()
        );
    }

    #[test]
    fn deterministic_under_seed() {
        let (mut s1, r1) = setup(&["alexnet", "resnet50"], 150.0, 1_500.0, 5);
        let (mut s2, r2) = setup(&["alexnet", "resnet50"], 150.0, 1_500.0, 5);
        let a = s1.run(&mut Greedy, &r1);
        let b = s2.run(&mut Greedy, &r2);
        assert_eq!(a.per_model[0].served, b.per_model[0].served);
        assert_eq!(a.per_model[1].latencies_ms, b.per_model[1].latencies_ms);
        assert_eq!(a.busy_ms, b.busy_ms);
    }

    #[test]
    fn utilization_positive_and_bounded() {
        let (mut sim, reqs) = setup(&["resnet50", "vgg19"], 300.0, 2_000.0, 9);
        let rep = sim.run(&mut Greedy, &reqs);
        let u = rep.gpu_utilization[0];
        assert!(u > 0.05 && u <= 1.0, "{u}");
    }

    #[test]
    fn expired_requests_are_dropped_not_served() {
        // Overload with shedding enabled: vgg19 at 2000/s cannot keep
        // up; the queue must shed expired requests.
        let profiles = vec![crate::profile::by_name("vgg19").unwrap()];
        let entries = entries_at_optimum(&profiles);
        let specs = vec![(Arrivals::Poisson { rate: 2_000.0 }, profiles[0].slo_ms)];
        let reqs = merged_stream(&specs, 2_000.0, 3);
        let cfg = SimConfig { horizon_ms: 2_000.0, drop_expired: true, ..Default::default() };
        let mut sim = Sim::new(cfg, entries);
        let rep = sim.run(&mut Greedy, &reqs);
        assert!(rep.per_model[0].dropped > 0, "overload must shed requests");
        // Served-late is impossible when expired requests are dropped
        // before launch and in-flight batches were feasible at launch.
        let m = &rep.per_model[0];
        assert!(m.served > 0);
    }

    #[test]
    fn backlog_counts_queued_and_in_flight() {
        let (mut sim, reqs) = setup(&["alexnet"], 300.0, 1_000.0, 12);
        assert_eq!(sim.backlog_items(0), 0);
        let horizon = ms_to_us(1_000.0);
        let mut pol = Greedy;
        // Feed the first few arrivals by hand through the incremental API.
        let n = reqs.len().min(8);
        for r in &reqs[..n] {
            sim.inject(r.clone());
        }
        let t0 = reqs[n - 1].arrival;
        assert_eq!(sim.backlog_items(0), n, "all queued before any dispatch");
        sim.step_to(t0, &mut pol, horizon);
        // Greedy launched one batch: items moved from queue to in-flight,
        // but the backlog (queued + in flight) is conserved.
        assert!(sim.gpu.n_running_of(0) > 0);
        assert_eq!(sim.backlog_items(0), n);
    }

    #[test]
    fn incremental_stepping_matches_run() {
        // Driving the engine event-by-event from outside (the cluster
        // driver's pattern) must reproduce `run` exactly.
        let (mut s1, reqs) = setup(&["alexnet", "resnet50"], 250.0, 1_200.0, 21);
        let a = s1.run(&mut Greedy, &reqs);

        let (mut s2, _) = setup(&["alexnet", "resnet50"], 250.0, 1_200.0, 21);
        let horizon = ms_to_us(1_200.0);
        let mut pol = Greedy;
        let mut cursor = 0usize;
        loop {
            let t_arr = reqs.get(cursor).map(|r| r.arrival);
            let Some(t) = [t_arr, s2.next_event_time()].into_iter().flatten().min() else {
                break;
            };
            if t >= horizon {
                break;
            }
            while reqs.get(cursor).is_some_and(|r| r.arrival <= t) {
                s2.inject(reqs[cursor].clone());
                cursor += 1;
            }
            s2.step_to(t, &mut pol, horizon);
        }
        let b = s2.finalize("greedy".into(), horizon);
        for (x, y) in a.per_model.iter().zip(&b.per_model) {
            assert_eq!(x.served, y.served);
            assert_eq!(x.dropped, y.dropped);
            assert_eq!(x.latencies_ms, y.latencies_ms);
        }
        assert_eq!(a.busy_ms, b.busy_ms);
        assert_eq!(a.gpu_utilization, b.gpu_utilization);
    }

    #[test]
    fn runtime_activate_deactivate_models() {
        let (mut sim, reqs) = setup(&["alexnet"], 200.0, 1_000.0, 8);
        let n = reqs.len().min(4);
        for r in &reqs[..n] {
            sim.inject(r.clone());
        }
        assert!(sim.is_active(0));
        // Retirement hands the queued requests back for re-routing.
        let drained = sim.deactivate_model(0);
        assert_eq!(drained.len(), n);
        assert!(!sim.is_active(0));
        assert_eq!(sim.backlog_items(0), 0);
        // Re-activating the same model reuses the tombstone slot…
        let e = entries_at_optimum(&[by_name("alexnet").unwrap()]).remove(0);
        sim.reactivate_model(0, e);
        assert!(sim.is_active(0));
        // …while a different model appends a fresh slot.
        let e2 = entries_at_optimum(&[by_name("resnet50").unwrap()]).remove(0);
        assert_eq!(sim.add_model(e2), 1);
        assert_eq!(sim.models.len(), 2);
        assert!(sim.is_active(1));
    }

    #[test]
    fn take_queued_before_pulls_the_stuck_prefix() {
        let (mut sim, reqs) = setup(&["alexnet"], 200.0, 1_000.0, 8);
        let n = reqs.len().min(6);
        for r in &reqs[..n] {
            sim.inject(r.clone());
        }
        // Cut between the 3rd and 4th arrival: exactly 3 are "stuck".
        let cutoff = reqs[2].arrival;
        assert!(reqs[3].arrival > cutoff, "seed must not collide arrivals");
        assert_eq!(sim.queued_before(0, cutoff), 3);
        let pulled = sim.take_queued_before(0, cutoff);
        assert_eq!(pulled.len(), 3);
        assert!(pulled.windows(2).all(|w| w[0].arrival <= w[1].arrival), "oldest first");
        // The remainder is untouched and still FIFO.
        assert_eq!(sim.backlog_items(0), n - 3);
        assert_eq!(sim.queued_before(0, cutoff), 0);
        assert_eq!(sim.take_queued_before(0, cutoff), Vec::new());
    }

    #[test]
    fn completion_times_parallel_latencies() {
        let (mut sim, reqs) = setup(&["alexnet", "mobilenet"], 200.0, 1_000.0, 6);
        let rep = sim.run(&mut Greedy, &reqs);
        for m in &rep.per_model {
            assert_eq!(m.latencies_ms.len(), m.completions_us.len());
            for (lat, &done) in m.latencies_ms.iter().zip(&m.completions_us) {
                assert!(*lat >= 0.0);
                assert!(done <= ms_to_us(1_000.0) + ms_to_us(200.0), "completion {done}");
            }
        }
    }

    #[test]
    fn latencies_include_queue_wait() {
        let (mut sim, reqs) = setup(&["resnet50"], 400.0, 2_000.0, 4);
        let rep = sim.run(&mut Greedy, &reqs);
        let s = rep.per_model[0].latency_summary();
        // Inference alone at the deploy point is ≥ ~15 ms; queueing adds.
        assert!(s.mean > 5.0, "mean {}", s.mean);
        assert!(s.max >= s.mean);
    }
}
