//! Streaming trace loader: replay production-style request logs
//! (Azure-Functions-shaped columns — timestamp, model, count) through
//! the [`ArrivalStream`] interface without holding the trace in memory.
//!
//! Two on-disk formats, picked by file extension:
//!
//! - **CSV** (`.csv`): a header line naming `timestamp_ms` (or
//!   `timestamp`), `model` and `count` columns (any order, extra
//!   columns ignored), then one record per line.
//! - **JSON lines** (`.jsonl` / `.ndjson` / `.json`): one object per
//!   line with the same fields; `count` defaults to 1 when absent.
//!
//! A record `(t, model, count)` expands to `count` requests arriving at
//! `t` ms (per-minute/per-bucket counts are the shape real serving
//! traces come in); `model` is a model name from the spec or a numeric
//! model index. Records at or past the horizon are dropped.
//!
//! # Sort-or-reject policy
//!
//! Streaming replay requires nondecreasing timestamps. Under
//! [`UnsortedPolicy::Reject`] (the default) an out-of-order record is a
//! load error naming the offending line; under [`UnsortedPolicy::Sort`]
//! the trace is materialized, stably sorted by timestamp and replayed
//! from memory — a convenience for small, shuffled logs that
//! deliberately gives up the O(backlog) memory bound.
//!
//! [`TraceStream::open`] validates the *entire* file up front (format,
//! model names, ordering) in one O(1)-memory pass, so a lazily replayed
//! trace can never fail mid-run; the second pass then streams records
//! one line at a time. Malformed rows, truncated files and unknown
//! models are `Err`s with line numbers — never panics.

use super::stream::{ArrivalStream, MaterializedStream};
use super::Request;
use crate::gpu::{ms_to_us, Us};
use crate::util::json::Json;
use std::io::{BufRead, BufReader};
use std::path::Path;

/// What a replayed trace maps onto: the model-index domain (name →
/// index via position), per-model SLOs, the replay horizon and the
/// ordering policy.
#[derive(Debug, Clone)]
pub struct TraceSpec {
    /// `(name, slo_ms)` per model index — the trace's `model` column
    /// resolves against the names (or indexes this list directly).
    pub models: Vec<(String, f64)>,
    /// Records arriving at or past this are dropped.
    pub horizon_ms: f64,
    pub policy: UnsortedPolicy,
}

/// How to handle out-of-order timestamps — see the module docs.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum UnsortedPolicy {
    /// Fail the load with the offending line (keeps replay streaming).
    #[default]
    Reject,
    /// Materialize, stable-sort by timestamp, replay from memory.
    Sort,
}

impl UnsortedPolicy {
    /// Parse the config/CLI spelling.
    pub fn parse(s: &str) -> Result<UnsortedPolicy, String> {
        match s {
            "reject" => Ok(UnsortedPolicy::Reject),
            "sort" => Ok(UnsortedPolicy::Sort),
            other => Err(format!("on_unsorted must be \"reject\" or \"sort\", got '{other}'")),
        }
    }

    pub fn label(self) -> &'static str {
        match self {
            UnsortedPolicy::Reject => "reject",
            UnsortedPolicy::Sort => "sort",
        }
    }
}

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
enum TraceFormat {
    Csv,
    Jsonl,
}

fn format_of(path: &Path) -> Result<TraceFormat, String> {
    match path.extension().and_then(|e| e.to_str()) {
        Some("csv") => Ok(TraceFormat::Csv),
        Some("jsonl") | Some("ndjson") | Some("json") => Ok(TraceFormat::Jsonl),
        _ => Err(format!(
            "{}: unknown trace format (expected .csv, .jsonl, .ndjson or .json)",
            path.display()
        )),
    }
}

/// Resolved CSV column indices (header order is free).
#[derive(Debug, Clone, Copy)]
struct CsvCols {
    t: usize,
    model: usize,
    count: usize,
}

/// One parsed trace record before expansion.
type Record = (f64, usize, u64); // (t_ms, model index, count)

/// Line-by-line record reader shared by the validation and replay
/// passes. O(1) memory: one line buffer, no record retained.
struct RecordReader {
    reader: BufReader<std::fs::File>,
    format: TraceFormat,
    cols: Option<CsvCols>,
    names: Vec<String>,
    path: String,
    lineno: usize,
    buf: String,
}

impl RecordReader {
    fn open(path: &Path, spec: &TraceSpec) -> Result<RecordReader, String> {
        let format = format_of(path)?;
        let file = std::fs::File::open(path)
            .map_err(|e| format!("{}: cannot open trace: {e}", path.display()))?;
        Ok(RecordReader {
            reader: BufReader::new(file),
            format,
            cols: None,
            names: spec.models.iter().map(|(n, _)| n.clone()).collect(),
            path: path.display().to_string(),
            lineno: 0,
            buf: String::new(),
        })
    }

    fn err(&self, msg: impl std::fmt::Display) -> String {
        format!("{}:{}: {msg}", self.path, self.lineno)
    }

    fn resolve_model(&self, field: &str) -> Result<usize, String> {
        // Numeric fields index the spec's model list directly; anything
        // else must be a known model name.
        if let Ok(idx) = field.parse::<usize>() {
            if idx < self.names.len() {
                return Ok(idx);
            }
            return Err(self.err(format!(
                "model index {idx} out of range (spec has {} models)",
                self.names.len()
            )));
        }
        self.names.iter().position(|n| n == field).ok_or_else(|| {
            self.err(format!("unknown model '{field}' (known: {})", self.names.join(", ")))
        })
    }

    fn parse_header(&mut self, line: &str) -> Result<CsvCols, String> {
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        let find = |cands: &[&str]| {
            fields.iter().position(|f| cands.iter().any(|c| f.eq_ignore_ascii_case(c)))
        };
        let t = find(&["timestamp_ms", "timestamp"]);
        let model = find(&["model"]);
        let count = find(&["count"]);
        match (t, model, count) {
            (Some(t), Some(model), Some(count)) => Ok(CsvCols { t, model, count }),
            _ => Err(self.err(format!(
                "CSV header must name timestamp_ms (or timestamp), model and count \
                 columns, got '{line}'"
            ))),
        }
    }

    fn parse_csv(&self, line: &str, cols: CsvCols) -> Result<Record, String> {
        let fields: Vec<&str> = line.split(',').map(str::trim).collect();
        let need = cols.t.max(cols.model).max(cols.count) + 1;
        if fields.len() < need {
            return Err(
                self.err(format!("expected at least {need} CSV fields, got {}", fields.len()))
            );
        }
        let t: f64 = fields[cols.t]
            .parse()
            .map_err(|_| self.err(format!("bad timestamp '{}'", fields[cols.t])))?;
        if !t.is_finite() || t < 0.0 {
            return Err(self.err(format!("timestamp must be finite and >= 0, got {t}")));
        }
        let model = self.resolve_model(fields[cols.model])?;
        let count: u64 = fields[cols.count]
            .parse()
            .map_err(|_| self.err(format!("bad count '{}'", fields[cols.count])))?;
        Ok((t, model, count))
    }

    fn parse_jsonl(&self, line: &str) -> Result<Record, String> {
        let j = Json::parse(line).map_err(|e| self.err(format!("bad JSON record: {e}")))?;
        let t = j
            .get("timestamp_ms")
            .or_else(|| j.get("timestamp"))
            .and_then(|v| v.as_f64())
            .ok_or_else(|| self.err("record is missing a numeric timestamp_ms"))?;
        if !t.is_finite() || t < 0.0 {
            return Err(self.err(format!("timestamp must be finite and >= 0, got {t}")));
        }
        let mv = j.get("model").ok_or_else(|| self.err("record is missing 'model'"))?;
        let model = if let Some(name) = mv.as_str() {
            self.resolve_model(name)?
        } else if let Some(idx) = mv.as_u64() {
            self.resolve_model(&idx.to_string())?
        } else {
            return Err(self.err("'model' must be a name or a model index"));
        };
        let count = match j.get("count") {
            None => 1,
            Some(c) => c
                .as_u64()
                .ok_or_else(|| self.err("'count' must be a non-negative integer"))?,
        };
        Ok((t, model, count))
    }

    /// Next record, skipping blank lines (and the CSV header).
    fn next_record(&mut self) -> Result<Option<Record>, String> {
        loop {
            self.buf.clear();
            self.lineno += 1;
            let n = self
                .reader
                .read_line(&mut self.buf)
                .map_err(|e| format!("{}:{}: read error: {e}", self.path, self.lineno))?;
            if n == 0 {
                return Ok(None);
            }
            let line = self.buf.trim().to_string();
            if line.is_empty() {
                continue;
            }
            match self.format {
                TraceFormat::Csv => {
                    let Some(cols) = self.cols else {
                        self.cols = Some(self.parse_header(&line)?);
                        continue;
                    };
                    return self.parse_csv(&line, cols).map(Some);
                }
                TraceFormat::Jsonl => return self.parse_jsonl(&line).map(Some),
            }
        }
    }
}

/// A trace file replayed as an [`ArrivalStream`]. Under the default
/// reject policy replay is lazy — memory is O(1) in the trace length
/// (one line + the current record's remaining count) — and
/// [`ArrivalStream::peek_model`] falls back to the conservative global
/// head (safe per the stream contract; a log line does not reveal
/// per-model lookahead). Under the sort policy the stream is backed by
/// a sorted [`MaterializedStream`].
pub struct TraceStream {
    inner: TraceInner,
    /// Expanded requests inside the horizon (from the validation pass).
    total: u64,
}

enum TraceInner {
    Lazy {
        reader: RecordReader,
        slo_us: Vec<Us>,
        horizon_ms: f64,
        /// Current record mid-expansion: (arrival, model, remaining).
        cur: Option<(Us, usize, u64)>,
        next_id: u64,
        done: bool,
    },
    Sorted(MaterializedStream),
}

impl TraceStream {
    /// Open and fully validate `path` against `spec`; see the module
    /// docs for formats, policies and error behavior.
    pub fn open(path: &Path, spec: &TraceSpec) -> Result<TraceStream, String> {
        assert!(!spec.models.is_empty(), "trace spec needs at least one model");
        let slo_us: Vec<Us> = spec.models.iter().map(|&(_, slo)| ms_to_us(slo)).collect();
        match spec.policy {
            UnsortedPolicy::Reject => {
                // Pass 1: validate every line (format, models, ordering)
                // so lazy replay can never fail mid-run.
                let mut v = RecordReader::open(path, spec)?;
                let mut prev = f64::NEG_INFINITY;
                let mut total = 0u64;
                while let Some((t, _, count)) = v.next_record()? {
                    if t < prev {
                        return Err(v.err(format!(
                            "timestamps out of order ({t} ms after {prev} ms) — \
                             sort the trace or load it with the \"sort\" policy"
                        )));
                    }
                    prev = t;
                    if t < spec.horizon_ms {
                        total += count;
                    }
                }
                // Pass 2: the replay reader.
                let reader = RecordReader::open(path, spec)?;
                let mut s = TraceStream {
                    inner: TraceInner::Lazy {
                        reader,
                        slo_us,
                        horizon_ms: spec.horizon_ms,
                        cur: None,
                        next_id: 0,
                        done: false,
                    },
                    total,
                };
                s.advance_if_empty();
                Ok(s)
            }
            UnsortedPolicy::Sort => {
                let mut v = RecordReader::open(path, spec)?;
                let mut recs: Vec<Record> = Vec::new();
                while let Some(rec) = v.next_record()? {
                    if rec.0 < spec.horizon_ms && rec.2 > 0 {
                        recs.push(rec);
                    }
                }
                recs.sort_by(|a, b| a.0.total_cmp(&b.0));
                let mut reqs = Vec::new();
                let mut next_id = 0u64;
                for (t, m, count) in recs {
                    let arrival = ms_to_us(t);
                    for _ in 0..count {
                        reqs.push(Request {
                            id: next_id,
                            model: m,
                            arrival,
                            deadline: arrival + slo_us[m],
                        });
                        next_id += 1;
                    }
                }
                let total = reqs.len() as u64;
                Ok(TraceStream {
                    inner: TraceInner::Sorted(MaterializedStream::new(reqs, spec.models.len())),
                    total,
                })
            }
        }
    }

    /// Requests the replay will deliver (counted during validation).
    pub fn total_requests(&self) -> u64 {
        self.total
    }

    /// Pull records until one expands inside the horizon (lazy path).
    fn advance_if_empty(&mut self) {
        let TraceInner::Lazy { reader, cur, horizon_ms, done, .. } = &mut self.inner else {
            return;
        };
        if *done || cur.is_some() {
            return;
        }
        loop {
            match reader.next_record() {
                Ok(Some((t, m, count))) => {
                    if t >= *horizon_ms {
                        // Ordering was validated: everything after is
                        // at or past the horizon too.
                        *done = true;
                        return;
                    }
                    if count == 0 {
                        continue;
                    }
                    *cur = Some((ms_to_us(t), m, count));
                    return;
                }
                Ok(None) => {
                    *done = true;
                    return;
                }
                Err(e) => {
                    // The validation pass proved the file clean; only a
                    // mid-run rewrite of the file can land here.
                    debug_assert!(false, "validated trace failed on replay: {e}");
                    *done = true;
                    return;
                }
            }
        }
    }
}

impl ArrivalStream for TraceStream {
    fn peek_time(&self) -> Option<Us> {
        match &self.inner {
            TraceInner::Lazy { cur, .. } => cur.map(|(a, _, _)| a),
            TraceInner::Sorted(s) => s.peek_time(),
        }
    }

    fn peek_model(&self, model: usize) -> Option<Us> {
        match &self.inner {
            // Conservative: the global head is a valid lower bound for
            // every model with arrivals remaining, and a log file gives
            // no cheap per-model lookahead. Never returns None while
            // the stream has records left — the contract's safe side.
            TraceInner::Lazy { .. } => self.peek_time(),
            TraceInner::Sorted(s) => s.peek_model(model),
        }
    }

    fn next_request(&mut self) -> Option<Request> {
        match &mut self.inner {
            TraceInner::Lazy { cur, slo_us, next_id, .. } => {
                let (arrival, m, remaining) = (*cur)?;
                let r = Request {
                    id: *next_id,
                    model: m,
                    arrival,
                    deadline: arrival + slo_us[m],
                };
                *next_id += 1;
                *cur = (remaining > 1).then_some((arrival, m, remaining - 1));
                self.advance_if_empty();
                Some(r)
            }
            TraceInner::Sorted(s) => s.next_request(),
        }
    }

    fn buffered(&self) -> usize {
        match &self.inner {
            TraceInner::Lazy { cur, .. } => cur.map(|(_, _, n)| n as usize).unwrap_or(0),
            TraceInner::Sorted(s) => s.buffered(),
        }
    }
}

/// Materialize a trace into a request vector — the eager adapter tests
/// and small-scale callers use ([`TraceStream::open`] + collect).
pub fn load_trace(path: &Path, spec: &TraceSpec) -> Result<Vec<Request>, String> {
    let mut s = TraceStream::open(path, spec)?;
    let mut out = Vec::with_capacity(s.total_requests() as usize);
    while let Some(r) = s.next_request() {
        out.push(r);
    }
    Ok(out)
}
