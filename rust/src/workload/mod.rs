//! Workload generation (§7's request streams).
//!
//! The paper drives its testbed with MoonGen at ~1920 images/s over
//! 10 GbE, splitting the stream across multiplexed models in (inverse)
//! proportion to their SLOs, and also evaluates dynamically varying
//! rates (Fig. 11b). This module produces the equivalent open-loop
//! request streams in virtual time.
//!
//! Streams come in two shapes:
//!
//! - **Materialized** (`Vec<Request>`): [`merged_stream`] collects every
//!   arrival up front — fine for test-scale horizons, O(total) memory.
//! - **Lazy** ([`stream::ArrivalStream`]): [`stream::MergedStream`]
//!   k-way-merges per-model [`ArrivalIter`]s on demand, and
//!   [`trace::TraceStream`] replays request logs line by line — both
//!   O(backlog) memory, which is what lets the execution core serve a
//!   day of production traffic (10⁷–10⁸ requests) without holding the
//!   stream in memory. The two shapes are byte-identical by
//!   construction: `merged_stream` *is* `MergedStream` collected.

use crate::gpu::{ms_to_us, Us};
use crate::util::rng::Pcg32;

pub mod stream;
pub mod trace;

pub use stream::{ArrivalStream, MaterializedStream, MergedStream};
pub use trace::{load_trace, TraceSpec, TraceStream, UnsortedPolicy};

/// One inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub model: usize,
    pub arrival: Us,
    /// Absolute deadline (arrival + SLO).
    pub deadline: Us,
}

/// Arrival process for a single model's stream.
#[derive(Debug, Clone)]
pub enum Arrivals {
    /// Exponential (Poisson) inter-arrivals at `rate` req/s.
    Poisson { rate: f64 },
    /// Uniformly jittered inter-arrivals: mean `1/rate`, multiplied by
    /// U(1−jitter, 1+jitter) (§6.3's "random, uniformly distributed
    /// inter-arrival delay").
    Uniform { rate: f64, jitter: f64 },
    /// Piecewise-constant rates: (start_ms, rate) segments, used for the
    /// dynamic-rate experiment (Fig. 11b).
    Trace { segments: Vec<(f64, f64)> },
    /// 2-state Markov-modulated Poisson process: Poisson arrivals at
    /// `rate_low` / `rate_high` req/s, dwelling exponentially with the
    /// given mean in each state (starting low at t = 0). The bursty
    /// arrival shape serving systems are actually evaluated on
    /// (cf. SGPRS / Nexus trace studies in PAPERS.md).
    Mmpp { rate_low: f64, rate_high: f64, dwell_low_ms: f64, dwell_high_ms: f64 },
    /// Diurnal sine wave: instantaneous rate
    /// `max(0, base + amplitude·sin(2π(t/period + phase)))` req/s,
    /// generated exactly by Lewis–Shedler thinning at
    /// `base + |amplitude|`.
    Diurnal { base: f64, amplitude: f64, period_ms: f64, phase: f64 },
    /// Flash crowd: steady `base` req/s except a multiplicative spike —
    /// `base·mult` over `[spike_start_ms, spike_start_ms + spike_ms)`.
    /// Sugar for the equivalent piecewise-constant [`Arrivals::Trace`].
    Flash { base: f64, mult: f64, spike_start_ms: f64, spike_ms: f64 },
}

impl Arrivals {
    /// Trace constructor that sorts segments by start time up front, so
    /// every later lookup is a binary search over a sorted slice. The
    /// sort is stable: among segments sharing a start time, the one
    /// listed last wins — the same semantics the old linear scan had.
    pub fn trace(segments: Vec<(f64, f64)>) -> Arrivals {
        Arrivals::Trace { segments: Self::normalize_segments(&segments) }
    }

    fn normalize_segments(segments: &[(f64, f64)]) -> Vec<(f64, f64)> {
        let mut s = segments.to_vec();
        s.sort_by(|a, b| a.0.total_cmp(&b.0));
        s
    }

    /// Rate of the last segment with `start <= t_ms`; 0 before the first
    /// segment. `segments` must be sorted by start time.
    fn rate_from_sorted(segments: &[(f64, f64)], t_ms: f64) -> f64 {
        let idx = segments.partition_point(|&(start, _)| start <= t_ms);
        if idx == 0 {
            0.0
        } else {
            segments[idx - 1].1
        }
    }

    /// Start of the first segment strictly after `t_ms` (sorted input) —
    /// lets the generator skip idle spans in one jump.
    fn next_start_after(segments: &[(f64, f64)], t_ms: f64) -> Option<f64> {
        let idx = segments.partition_point(|&(start, _)| start <= t_ms);
        segments.get(idx).map(|&(start, _)| start)
    }

    /// Offered rate (req/s) at virtual time `t_ms`: the constant rate
    /// for Poisson/Uniform processes, the covering segment's rate for a
    /// trace (0 before the first segment). The single source of truth
    /// for "rate at time t" — scenario sizing (`Scenario::initial_rates`)
    /// and the control plane's drift workload both resolve t = 0
    /// through here.
    pub fn rate_at(&self, t_ms: f64) -> f64 {
        match self {
            Arrivals::Poisson { rate } | Arrivals::Uniform { rate, .. } => *rate,
            // Public enum fields mean a `Trace` may be built unsorted;
            // `iter` normalizes once per stream, this path stays
            // correct (if slower) for ad-hoc callers.
            Arrivals::Trace { segments } => {
                Self::rate_from_sorted(&Self::normalize_segments(segments), t_ms)
            }
            // The modulation state is random, so "rate at t" can only
            // mean the stationary mean — which is exactly what placement
            // sizing and `offered_rates` want from it.
            Arrivals::Mmpp { rate_low, rate_high, dwell_low_ms, dwell_high_ms } => {
                (rate_low * dwell_low_ms + rate_high * dwell_high_ms)
                    / (dwell_low_ms + dwell_high_ms)
            }
            Arrivals::Diurnal { base, amplitude, period_ms, phase } => {
                let w = std::f64::consts::TAU * (t_ms / period_ms + phase);
                (base + amplitude * w.sin()).max(0.0)
            }
            Arrivals::Flash { base, mult, spike_start_ms, spike_ms } => {
                if t_ms >= *spike_start_ms && t_ms < spike_start_ms + spike_ms {
                    base * mult
                } else {
                    *base
                }
            }
        }
    }

    /// Peak offered rate over the whole horizon — what placement sizing
    /// should provision for when the process is not constant.
    pub fn peak_rate(&self) -> f64 {
        match self {
            Arrivals::Poisson { rate } | Arrivals::Uniform { rate, .. } => *rate,
            Arrivals::Trace { segments } => {
                segments.iter().map(|&(_, r)| r).fold(0.0, f64::max)
            }
            Arrivals::Mmpp { rate_low, rate_high, .. } => rate_low.max(*rate_high),
            Arrivals::Diurnal { base, amplitude, .. } => (base + amplitude.abs()).max(0.0),
            Arrivals::Flash { base, mult, .. } => base.max(base * mult),
        }
    }

    /// Lazy arrival iterator over `[0, horizon_ms)` for `model` with the
    /// model's SLO. Yields [`Request`]s with `id = 0` — the consumer
    /// (merge/collect layer) assigns ids. [`Arrivals::generate`] is this
    /// iterator collected, draw for draw: both paths consume the RNG in
    /// the identical sequence, which is what makes lazy and materialized
    /// streams byte-identical.
    pub fn iter(&self, model: usize, slo_ms: f64, horizon_ms: f64, rng: Pcg32) -> ArrivalIter {
        ArrivalIter::new(self.clone(), model, slo_ms, horizon_ms, rng)
    }

    /// Generate arrivals over `[0, horizon_ms)` for `model` with the
    /// model's SLO; ids are assigned by the caller via `next_id`.
    /// Implemented as [`Arrivals::iter`] collected (the legacy adapter
    /// over the streaming path).
    pub fn generate(
        &self,
        model: usize,
        slo_ms: f64,
        horizon_ms: f64,
        rng: &mut Pcg32,
        next_id: &mut u64,
    ) -> Vec<Request> {
        let mut it = self.iter(model, slo_ms, horizon_ms, rng.clone());
        let mut out = Vec::new();
        for mut r in it.by_ref() {
            r.id = *next_id;
            *next_id += 1;
            out.push(r);
        }
        // Hand the advanced RNG state back so callers that reuse the
        // generator across streams see exactly the draws of the old
        // eager loop.
        *rng = it.into_rng();
        out
    }
}

/// Lazy per-model arrival stepper — see [`Arrivals::iter`]. Holds the
/// process, the (pre-sorted) piecewise segments where applicable, and
/// the RNG; `next` performs exactly the draws the eager generator made
/// per emitted request.
#[derive(Debug, Clone)]
pub struct ArrivalIter {
    process: Arrivals,
    /// Pre-normalized segments for `Trace` (and the lowered `Flash`
    /// piecewise form), so the hot loop only binary-searches.
    sorted: Option<Vec<(f64, f64)>>,
    model: usize,
    slo_us: Us,
    horizon_ms: f64,
    rng: Pcg32,
    t_ms: f64,
    done: bool,
    /// MMPP modulation state: currently in the high-rate phase?
    high: bool,
    /// MMPP: absolute time the current dwell expires.
    switch_ms: f64,
}

impl ArrivalIter {
    fn new(process: Arrivals, model: usize, slo_ms: f64, horizon_ms: f64, mut rng: Pcg32) -> Self {
        let sorted = match &process {
            Arrivals::Trace { segments } => Some(Arrivals::normalize_segments(segments)),
            Arrivals::Flash { base, mult, spike_start_ms, spike_ms } => {
                assert!(*spike_ms >= 0.0 && *spike_start_ms >= 0.0, "flash spike must be in [0,∞)");
                Some(Arrivals::normalize_segments(&[
                    (0.0, *base),
                    (*spike_start_ms, base * mult),
                    (spike_start_ms + spike_ms, *base),
                ]))
            }
            _ => None,
        };
        let mut high = false;
        let mut switch_ms = f64::INFINITY;
        if let Arrivals::Mmpp { rate_low, rate_high, dwell_low_ms, dwell_high_ms } = &process {
            assert!(
                *dwell_low_ms > 0.0 && *dwell_high_ms > 0.0,
                "mmpp dwell times must be > 0 (got {dwell_low_ms} / {dwell_high_ms} ms)"
            );
            assert!(*rate_low >= 0.0 && *rate_high >= 0.0, "mmpp rates must be >= 0");
            high = false;
            switch_ms = dwell_low_ms * rng.exp(1.0);
        }
        if let Arrivals::Diurnal { base, period_ms, .. } = &process {
            assert!(*period_ms > 0.0, "diurnal period must be > 0 (got {period_ms} ms)");
            assert!(*base >= 0.0, "diurnal base rate must be >= 0 (got {base})");
        }
        ArrivalIter {
            process,
            sorted,
            model,
            slo_us: ms_to_us(slo_ms),
            horizon_ms,
            rng,
            t_ms: 0.0,
            done: false,
            high,
            switch_ms,
        }
    }

    /// Consume the iterator, returning the advanced RNG (the legacy
    /// `generate` adapter writes it back into the caller's generator).
    pub fn into_rng(self) -> Pcg32 {
        self.rng
    }

    fn emit(&self) -> Request {
        let arrival = ms_to_us(self.t_ms);
        Request { id: 0, model: self.model, arrival, deadline: arrival + self.slo_us }
    }

    /// Poisson / Uniform / piecewise-constant (Trace, Flash) arrivals —
    /// the exact loop of the pre-streaming eager generator.
    fn next_piecewise(&mut self) -> Option<Request> {
        loop {
            let rate = match &self.sorted {
                Some(segs) => Arrivals::rate_from_sorted(segs, self.t_ms),
                None => self.process.rate_at(self.t_ms),
            };
            if rate <= 0.0 {
                // Idle span: jump straight to the next segment start (a
                // constant-rate process at rate 0 stays silent forever).
                let next = self
                    .sorted
                    .as_ref()
                    .and_then(|segs| Arrivals::next_start_after(segs, self.t_ms));
                let Some(next) = next else {
                    self.done = true;
                    return None;
                };
                self.t_ms = next;
                if self.t_ms >= self.horizon_ms {
                    self.done = true;
                    return None;
                }
                continue;
            }
            let gap_ms = match &self.process {
                Arrivals::Uniform { jitter, .. } => {
                    let mean = 1_000.0 / rate;
                    mean * self.rng.f64_range(1.0 - jitter, 1.0 + jitter)
                }
                _ => self.rng.exp(rate) * 1_000.0,
            };
            self.t_ms += gap_ms;
            if self.t_ms >= self.horizon_ms {
                self.done = true;
                return None;
            }
            return Some(self.emit());
        }
    }

    /// 2-state MMPP: exponential gaps at the phase rate; a gap that
    /// crosses the dwell boundary is discarded and redrawn at the new
    /// phase's rate — valid because exponentials are memoryless.
    fn next_mmpp(&mut self) -> Option<Request> {
        let &Arrivals::Mmpp { rate_low, rate_high, dwell_low_ms, dwell_high_ms } = &self.process
        else {
            unreachable!("next_mmpp on a non-mmpp process")
        };
        loop {
            let rate = if self.high { rate_high } else { rate_low };
            if rate > 0.0 {
                let gap_ms = self.rng.exp(rate) * 1_000.0;
                if self.t_ms + gap_ms < self.switch_ms {
                    self.t_ms += gap_ms;
                    if self.t_ms >= self.horizon_ms {
                        self.done = true;
                        return None;
                    }
                    return Some(self.emit());
                }
            }
            // Dwell expired (or the phase is silent): jump to the
            // switch and draw the next dwell.
            self.t_ms = self.switch_ms;
            if self.t_ms >= self.horizon_ms {
                self.done = true;
                return None;
            }
            self.high = !self.high;
            let dwell = if self.high { dwell_high_ms } else { dwell_low_ms };
            self.switch_ms = self.t_ms + dwell * self.rng.exp(1.0);
        }
    }

    /// Diurnal sine: Lewis–Shedler thinning against the envelope rate
    /// `base + |amplitude|` (an exact, not approximate, sampler for an
    /// inhomogeneous Poisson process).
    fn next_diurnal(&mut self) -> Option<Request> {
        let &Arrivals::Diurnal { base, amplitude, .. } = &self.process else {
            unreachable!("next_diurnal on a non-diurnal process")
        };
        let rate_max = base + amplitude.abs();
        if rate_max <= 0.0 {
            self.done = true;
            return None;
        }
        loop {
            self.t_ms += self.rng.exp(rate_max) * 1_000.0;
            if self.t_ms >= self.horizon_ms {
                self.done = true;
                return None;
            }
            let r = self.process.rate_at(self.t_ms);
            if self.rng.f64() * rate_max < r {
                return Some(self.emit());
            }
        }
    }
}

impl Iterator for ArrivalIter {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        if self.done {
            return None;
        }
        match &self.process {
            Arrivals::Mmpp { .. } => self.next_mmpp(),
            Arrivals::Diurnal { .. } => self.next_diurnal(),
            _ => self.next_piecewise(),
        }
    }
}

/// Canonical bursty rendition of a flat per-model rate: the named
/// generator shaped so its *mean* offered rate stays `rate` (MMPP:
/// 0.5×/2× rates with 400/200 ms dwells — stationary mean exactly
/// `rate`) or its base does (diurnal: ±0.8×`rate` over half the
/// horizon; flash: a 6× spike over 10% of the horizon starting at
/// 40%). The CLI's `--workload` flag and the streaming figure both
/// resolve through here so they stress the same shapes.
pub fn bursty_arrivals(kind: &str, rate: f64, horizon_ms: f64) -> Result<Arrivals, String> {
    Ok(match kind {
        "poisson" => Arrivals::Poisson { rate },
        "mmpp" => Arrivals::Mmpp {
            rate_low: 0.5 * rate,
            rate_high: 2.0 * rate,
            dwell_low_ms: 400.0,
            dwell_high_ms: 200.0,
        },
        "diurnal" => Arrivals::Diurnal {
            base: rate,
            amplitude: 0.8 * rate,
            period_ms: horizon_ms / 2.0,
            phase: 0.0,
        },
        "flash" => Arrivals::Flash {
            base: rate,
            mult: 6.0,
            spike_start_ms: 0.4 * horizon_ms,
            spike_ms: 0.1 * horizon_ms,
        },
        other => {
            return Err(format!(
                "unknown workload kind '{other}' (expected poisson|mmpp|diurnal|flash)"
            ))
        }
    })
}

/// Split an aggregate request rate across models inversely proportional
/// to their SLOs (§7: with 1920 req/s over {25,25,50,100} ms SLOs the
/// paper assigns 700/700/320/160 req/s).
pub fn slo_proportional_rates(total_rate: f64, slos_ms: &[f64]) -> Vec<f64> {
    let weights: Vec<f64> = slos_ms.iter().map(|s| 1.0 / s).collect();
    let sum: f64 = weights.iter().sum();
    weights.iter().map(|w| total_rate * w / sum).collect()
}

/// Build a merged, time-sorted request stream for a set of models:
/// [`stream::MergedStream`] collected. The lazy merge and this eager
/// adapter share one implementation, so a driver fed the stream and a
/// driver fed the collected `Vec` see the identical request sequence —
/// ids included (assigned in merge order, ties broken by model index).
pub fn merged_stream(
    specs: &[(Arrivals, f64)], // (process, slo_ms) per model index
    horizon_ms: f64,
    seed: u64,
) -> Vec<Request> {
    MergedStream::new(specs, horizon_ms, seed).collect()
}

/// The Fig. 12 cluster workload: the 4-model mix with asymmetric demand
/// (heavy models oversubscribe a dedicated T4, light models strand
/// capacity). Single source of truth for every cluster experiment,
/// bench and test that claims to run "the same seeded workload".
pub fn fig12_rates() -> Vec<(&'static str, f64)> {
    vec![
        ("mobilenet", 150.0),
        ("alexnet", 150.0),
        ("resnet50", 900.0),
        ("vgg19", 450.0),
    ]
}

/// The drifting-rate cluster workload behind the adaptive-vs-static
/// comparison (`controlplane`, `figures::fig13`): ResNet-50 and VGG-19
/// swap hot/cold roles at the horizon midpoint (piecewise-constant
/// traces), AlexNet and Mobilenet offer steady background load. Peak
/// rates are deliberately *not* simultaneous: a placement solved for the
/// per-model peaks cannot admit all four models on the 2×V100 cluster
/// this mix is sized for, while each phase individually fits.
/// Returns (model name, (start_ms, rate) trace) per model.
pub fn drift_rates(horizon_ms: f64) -> Vec<(&'static str, Vec<(f64, f64)>)> {
    let mid = horizon_ms / 2.0;
    vec![
        ("resnet50", vec![(0.0, 900.0), (mid, 150.0)]),
        ("vgg19", vec![(0.0, 100.0), (mid, 450.0)]),
        ("alexnet", vec![(0.0, 400.0)]),
        ("mobilenet", vec![(0.0, 300.0)]),
    ]
}

/// Zipf-distributed per-model rates for long-tail model fleets
/// (Nexus/Clipper's serving regime, opened by the lifecycle subsystem):
/// model `i` (0-based popularity rank) offers
/// `total_rps · (i+1)^−alpha / Σ_j (j+1)^−alpha` req/s. `alpha = 0`
/// degenerates to a uniform split; `alpha ≈ 1.1` gives the classic
/// head-heavy tail where the top model draws ~30% of all traffic and
/// the tail trickles.
pub fn zipf_rates(n_models: usize, alpha: f64, total_rps: f64) -> Vec<f64> {
    assert!(n_models > 0, "zipf_rates needs at least one model");
    assert!(alpha >= 0.0 && alpha.is_finite(), "alpha must be finite and >= 0");
    assert!(total_rps >= 0.0, "total_rps must be >= 0");
    let weights: Vec<f64> = (1..=n_models).map(|i| (i as f64).powf(-alpha)).collect();
    let sum: f64 = weights.iter().sum();
    weights.into_iter().map(|w| total_rps * w / sum).collect()
}

/// The paper's Fig. 11a request-rate assignments for the C-2/3/4/7 mixes.
/// Returns (model name, rate req/s) pairs.
pub fn fig11a_rates(mix: &str) -> Vec<(&'static str, f64)> {
    match mix {
        "C-2" => vec![("resnet50", 320.0), ("vgg19", 160.0)],
        "C-3" => vec![("resnet50", 320.0), ("vgg19", 160.0), ("bert", 700.0)],
        "C-4" => vec![
            ("resnet50", 320.0),
            ("vgg19", 160.0),
            ("bert", 700.0),
            ("mobilenet", 700.0),
        ],
        "C-7" => vec![
            ("alexnet", 440.0),
            ("mobilenet", 440.0),
            ("resnet18", 440.0),
            ("resnet50", 220.0),
            ("inception", 220.0),
            ("resnext50", 80.0),
            ("vgg19", 80.0),
        ],
        other => panic!("unknown mix {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_approximation() {
        let arr = Arrivals::Poisson { rate: 500.0 };
        let mut rng = Pcg32::seeded(1);
        let mut id = 0;
        let reqs = arr.generate(0, 25.0, 10_000.0, &mut rng, &mut id);
        // 500/s over 10 s → ~5000 requests.
        assert!((reqs.len() as f64 - 5_000.0).abs() < 250.0, "{}", reqs.len());
        // Deadlines are arrival + SLO.
        for r in &reqs {
            assert_eq!(r.deadline, r.arrival + 25_000);
        }
    }

    #[test]
    fn uniform_jitter_bounds() {
        let arr = Arrivals::Uniform { rate: 100.0, jitter: 0.5 };
        let mut rng = Pcg32::seeded(2);
        let mut id = 0;
        let reqs = arr.generate(0, 50.0, 5_000.0, &mut rng, &mut id);
        assert!(!reqs.is_empty());
        for w in reqs.windows(2) {
            let gap = (w[1].arrival - w[0].arrival) as f64 / 1000.0;
            assert!(gap >= 5.0 - 1e-3 && gap <= 15.0 + 1e-3, "gap {gap} ms");
        }
    }

    #[test]
    fn trace_changes_rate() {
        // 1000/s for the first second, then silence.
        let arr = Arrivals::Trace { segments: vec![(0.0, 1000.0), (1000.0, 0.0)] };
        let mut rng = Pcg32::seeded(3);
        let mut id = 0;
        let reqs = arr.generate(0, 25.0, 3_000.0, &mut rng, &mut id);
        let before: usize = reqs.iter().filter(|r| r.arrival < 1_000_000).count();
        let after = reqs.len() - before;
        assert!(before > 800, "{before}");
        // At most one spillover event whose gap straddles the boundary.
        assert!(after <= 1, "arrivals after the trace goes silent: {after}");
    }

    #[test]
    fn unsorted_trace_equals_sorted() {
        // The generator must not care about segment declaration order:
        // identical seed + identical (sorted) rate function ⇒ identical
        // stream, whether the caller sorted or not.
        let sorted = Arrivals::trace(vec![(0.0, 400.0), (500.0, 900.0), (1500.0, 100.0)]);
        let unsorted =
            Arrivals::Trace { segments: vec![(1500.0, 100.0), (0.0, 400.0), (500.0, 900.0)] };
        let gen = |a: &Arrivals| {
            let mut rng = Pcg32::seeded(11);
            let mut id = 0;
            a.generate(0, 25.0, 2_500.0, &mut rng, &mut id)
        };
        assert_eq!(gen(&sorted), gen(&unsorted));
        assert!(!gen(&sorted).is_empty());
    }

    #[test]
    fn trace_segment_boundaries() {
        // A segment's rate applies from exactly its start time; before
        // the first segment the rate is zero; equal start times resolve
        // to the last-listed segment (stable sort).
        let a = Arrivals::Trace { segments: vec![(1_000.0, 800.0)] };
        assert_eq!(a.rate_at(999.999), 0.0);
        assert_eq!(a.rate_at(1_000.0), 800.0);
        assert_eq!(a.rate_at(5_000.0), 800.0);
        let dup = Arrivals::Trace { segments: vec![(0.0, 100.0), (0.0, 300.0)] };
        assert_eq!(dup.rate_at(0.0), 300.0, "last-listed duplicate start wins");

        // Generation respects the leading idle span: no arrival before
        // the first live segment.
        let mut rng = Pcg32::seeded(5);
        let mut id = 0;
        let reqs = a.generate(0, 25.0, 3_000.0, &mut rng, &mut id);
        assert!(!reqs.is_empty());
        assert!(
            reqs.iter().all(|r| r.arrival >= 1_000_000),
            "arrival before the trace goes live: {:?}",
            reqs.first()
        );
    }

    #[test]
    fn trace_with_interior_idle_gap_resumes() {
        // live 0-500 ms, silent 500-2000 ms, live again after.
        let a = Arrivals::trace(vec![(0.0, 1_000.0), (500.0, 0.0), (2_000.0, 1_000.0)]);
        let mut rng = Pcg32::seeded(9);
        let mut id = 0;
        let reqs = a.generate(0, 25.0, 3_000.0, &mut rng, &mut id);
        let in_gap = reqs
            .iter()
            .filter(|r| r.arrival > 510_000 && r.arrival < 2_000_000)
            .count();
        // At most the single spillover event whose gap straddles 500 ms.
        assert!(in_gap <= 1, "{in_gap} arrivals inside the silent span");
        let resumed = reqs.iter().filter(|r| r.arrival >= 2_000_000).count();
        assert!(resumed > 500, "trace did not resume: {resumed}");
    }

    #[test]
    fn slo_split_matches_paper() {
        // §7: 1920 req/s over SLOs {25,25,50,100} → 698/698/349/175.
        let rates = slo_proportional_rates(1920.0, &[25.0, 25.0, 50.0, 100.0]);
        assert!((rates[0] - 698.0).abs() < 1.0, "{rates:?}");
        assert!((rates[1] - 698.0).abs() < 1.0);
        assert!((rates[2] - 349.0).abs() < 1.0);
        assert!((rates[3] - 174.5).abs() < 1.0);
        let sum: f64 = rates.iter().sum();
        assert!((sum - 1920.0).abs() < 1e-9);
    }

    #[test]
    fn merged_stream_sorted_and_deterministic() {
        let specs = vec![
            (Arrivals::Poisson { rate: 300.0 }, 25.0),
            (Arrivals::Poisson { rate: 100.0 }, 50.0),
        ];
        let a = merged_stream(&specs, 2_000.0, 7);
        let b = merged_stream(&specs, 2_000.0, 7);
        assert_eq!(a, b, "same seed, same stream");
        for w in a.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        let c = merged_stream(&specs, 2_000.0, 8);
        assert_ne!(a, c, "different seed, different stream");
    }

    #[test]
    fn zipf_rates_shape() {
        let r = zipf_rates(24, 1.1, 600.0);
        assert_eq!(r.len(), 24);
        assert!((r.iter().sum::<f64>() - 600.0).abs() < 1e-9, "rates sum to the total");
        for w in r.windows(2) {
            assert!(w[0] > w[1], "popularity must strictly decrease");
        }
        // Head-heavy: rank 0 draws > 25% of traffic at alpha = 1.1.
        assert!(r[0] > 150.0, "head rate {}", r[0]);
        assert!(r[23] < 10.0, "tail rate {}", r[23]);
        // alpha = 0 → uniform split.
        let u = zipf_rates(4, 0.0, 100.0);
        for v in u {
            assert!((v - 25.0).abs() < 1e-9);
        }
    }

    #[test]
    fn fig11a_mixes() {
        assert_eq!(fig11a_rates("C-2").len(), 2);
        assert_eq!(fig11a_rates("C-3").len(), 3);
        assert_eq!(fig11a_rates("C-4").len(), 4);
        assert_eq!(fig11a_rates("C-7").len(), 7);
        let total: f64 = fig11a_rates("C-7").iter().map(|(_, r)| r).sum();
        assert!((total - 1920.0).abs() < 1.0);
    }
}
