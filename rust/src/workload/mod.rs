//! Workload generation (§7's request streams).
//!
//! The paper drives its testbed with MoonGen at ~1920 images/s over
//! 10 GbE, splitting the stream across multiplexed models in (inverse)
//! proportion to their SLOs, and also evaluates dynamically varying
//! rates (Fig. 11b). This module produces the equivalent open-loop
//! request streams in virtual time.

use crate::gpu::{ms_to_us, Us};
use crate::util::rng::Pcg32;

/// One inference request.
#[derive(Debug, Clone, PartialEq)]
pub struct Request {
    pub id: u64,
    pub model: usize,
    pub arrival: Us,
    /// Absolute deadline (arrival + SLO).
    pub deadline: Us,
}

/// Arrival process for a single model's stream.
#[derive(Debug, Clone)]
pub enum Arrivals {
    /// Exponential (Poisson) inter-arrivals at `rate` req/s.
    Poisson { rate: f64 },
    /// Uniformly jittered inter-arrivals: mean `1/rate`, multiplied by
    /// U(1−jitter, 1+jitter) (§6.3's "random, uniformly distributed
    /// inter-arrival delay").
    Uniform { rate: f64, jitter: f64 },
    /// Piecewise-constant rates: (start_ms, rate) segments, used for the
    /// dynamic-rate experiment (Fig. 11b).
    Trace { segments: Vec<(f64, f64)> },
}

impl Arrivals {
    fn rate_at(&self, t_ms: f64) -> f64 {
        match self {
            Arrivals::Poisson { rate } | Arrivals::Uniform { rate, .. } => *rate,
            Arrivals::Trace { segments } => {
                let mut r = 0.0;
                for (start, rate) in segments {
                    if t_ms >= *start {
                        r = *rate;
                    }
                }
                r
            }
        }
    }

    /// Generate arrivals over `[0, horizon_ms)` for `model` with the
    /// model's SLO; ids are assigned by the caller via `next_id`.
    pub fn generate(
        &self,
        model: usize,
        slo_ms: f64,
        horizon_ms: f64,
        rng: &mut Pcg32,
        next_id: &mut u64,
    ) -> Vec<Request> {
        let mut out = Vec::new();
        let mut t_ms = 0.0;
        loop {
            let rate = self.rate_at(t_ms);
            let gap_ms = if rate <= 0.0 {
                // Idle segment: jump forward 1 ms looking for a live one.
                t_ms += 1.0;
                if t_ms >= horizon_ms {
                    break;
                }
                continue;
            } else {
                match self {
                    Arrivals::Poisson { .. } | Arrivals::Trace { .. } => {
                        rng.exp(rate) * 1_000.0
                    }
                    Arrivals::Uniform { jitter, .. } => {
                        let mean = 1_000.0 / rate;
                        mean * rng.f64_range(1.0 - jitter, 1.0 + jitter)
                    }
                }
            };
            t_ms += gap_ms;
            if t_ms >= horizon_ms {
                break;
            }
            let arrival = ms_to_us(t_ms);
            out.push(Request {
                id: *next_id,
                model,
                arrival,
                deadline: arrival + ms_to_us(slo_ms),
            });
            *next_id += 1;
        }
        out
    }
}

/// Split an aggregate request rate across models inversely proportional
/// to their SLOs (§7: with 1920 req/s over {25,25,50,100} ms SLOs the
/// paper assigns 700/700/320/160 req/s).
pub fn slo_proportional_rates(total_rate: f64, slos_ms: &[f64]) -> Vec<f64> {
    let weights: Vec<f64> = slos_ms.iter().map(|s| 1.0 / s).collect();
    let sum: f64 = weights.iter().sum();
    weights.iter().map(|w| total_rate * w / sum).collect()
}

/// Build a merged, time-sorted request stream for a set of models.
pub fn merged_stream(
    specs: &[(Arrivals, f64)], // (process, slo_ms) per model index
    horizon_ms: f64,
    seed: u64,
) -> Vec<Request> {
    let mut all = Vec::new();
    let mut next_id = 0u64;
    for (model, (arr, slo)) in specs.iter().enumerate() {
        // Independent stream per model for reproducibility under reorder.
        let mut rng = Pcg32::new(seed, model as u64 + 1);
        all.extend(arr.generate(model, *slo, horizon_ms, &mut rng, &mut next_id));
    }
    all.sort_by_key(|r| (r.arrival, r.id));
    all
}

/// The paper's Fig. 11a request-rate assignments for the C-2/3/4/7 mixes.
/// Returns (model name, rate req/s) pairs.
pub fn fig11a_rates(mix: &str) -> Vec<(&'static str, f64)> {
    match mix {
        "C-2" => vec![("resnet50", 320.0), ("vgg19", 160.0)],
        "C-3" => vec![("resnet50", 320.0), ("vgg19", 160.0), ("bert", 700.0)],
        "C-4" => vec![
            ("resnet50", 320.0),
            ("vgg19", 160.0),
            ("bert", 700.0),
            ("mobilenet", 700.0),
        ],
        "C-7" => vec![
            ("alexnet", 440.0),
            ("mobilenet", 440.0),
            ("resnet18", 440.0),
            ("resnet50", 220.0),
            ("inception", 220.0),
            ("resnext50", 80.0),
            ("vgg19", 80.0),
        ],
        other => panic!("unknown mix {other}"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn poisson_rate_approximation() {
        let arr = Arrivals::Poisson { rate: 500.0 };
        let mut rng = Pcg32::seeded(1);
        let mut id = 0;
        let reqs = arr.generate(0, 25.0, 10_000.0, &mut rng, &mut id);
        // 500/s over 10 s → ~5000 requests.
        assert!((reqs.len() as f64 - 5_000.0).abs() < 250.0, "{}", reqs.len());
        // Deadlines are arrival + SLO.
        for r in &reqs {
            assert_eq!(r.deadline, r.arrival + 25_000);
        }
    }

    #[test]
    fn uniform_jitter_bounds() {
        let arr = Arrivals::Uniform { rate: 100.0, jitter: 0.5 };
        let mut rng = Pcg32::seeded(2);
        let mut id = 0;
        let reqs = arr.generate(0, 50.0, 5_000.0, &mut rng, &mut id);
        assert!(!reqs.is_empty());
        for w in reqs.windows(2) {
            let gap = (w[1].arrival - w[0].arrival) as f64 / 1000.0;
            assert!(gap >= 5.0 - 1e-3 && gap <= 15.0 + 1e-3, "gap {gap} ms");
        }
    }

    #[test]
    fn trace_changes_rate() {
        // 1000/s for the first second, then silence.
        let arr = Arrivals::Trace { segments: vec![(0.0, 1000.0), (1000.0, 0.0)] };
        let mut rng = Pcg32::seeded(3);
        let mut id = 0;
        let reqs = arr.generate(0, 25.0, 3_000.0, &mut rng, &mut id);
        let before: usize = reqs.iter().filter(|r| r.arrival < 1_000_000).count();
        let after = reqs.len() - before;
        assert!(before > 800, "{before}");
        // At most one spillover event whose gap straddles the boundary.
        assert!(after <= 1, "arrivals after the trace goes silent: {after}");
    }

    #[test]
    fn slo_split_matches_paper() {
        // §7: 1920 req/s over SLOs {25,25,50,100} → 698/698/349/175.
        let rates = slo_proportional_rates(1920.0, &[25.0, 25.0, 50.0, 100.0]);
        assert!((rates[0] - 698.0).abs() < 1.0, "{rates:?}");
        assert!((rates[1] - 698.0).abs() < 1.0);
        assert!((rates[2] - 349.0).abs() < 1.0);
        assert!((rates[3] - 174.5).abs() < 1.0);
        let sum: f64 = rates.iter().sum();
        assert!((sum - 1920.0).abs() < 1e-9);
    }

    #[test]
    fn merged_stream_sorted_and_deterministic() {
        let specs = vec![
            (Arrivals::Poisson { rate: 300.0 }, 25.0),
            (Arrivals::Poisson { rate: 100.0 }, 50.0),
        ];
        let a = merged_stream(&specs, 2_000.0, 7);
        let b = merged_stream(&specs, 2_000.0, 7);
        assert_eq!(a, b, "same seed, same stream");
        for w in a.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        let c = merged_stream(&specs, 2_000.0, 8);
        assert_ne!(a, c, "different seed, different stream");
    }

    #[test]
    fn fig11a_mixes() {
        assert_eq!(fig11a_rates("C-2").len(), 2);
        assert_eq!(fig11a_rates("C-3").len(), 3);
        assert_eq!(fig11a_rates("C-4").len(), 4);
        assert_eq!(fig11a_rates("C-7").len(), 7);
        let total: f64 = fig11a_rates("C-7").iter().map(|(_, r)| r).sum();
        assert!((total - 1920.0).abs() < 1.0);
    }
}
