//! Lazy arrival streams — the O(backlog)-memory request sources the
//! execution core pulls from (DESIGN.md §4.10).
//!
//! Every cluster driver used to take the whole request stream as an
//! upfront `Vec<Request>`, capping a run at what fits in host memory.
//! The [`ArrivalStream`] trait replaces that with a peekable, ordered
//! pull interface; [`crate::cluster::exec`] consumes it directly, and
//! the `Vec`-taking driver signatures survive as thin adapters over
//! [`MaterializedStream`].
//!
//! # Contract
//!
//! Implementations must yield requests in nondecreasing `arrival`
//! order, and [`ArrivalStream::peek_model`] must obey the *frontier
//! invariant* the sparse execution core's run-ahead depends on:
//!
//! - the returned time must never exceed the model's true next arrival
//!   time in the remaining stream (a conservative *earlier* bound —
//!   e.g. the global head, [`ArrivalStream::peek_time`] — is always
//!   safe: engines merely synchronize more often);
//! - `None` may only be returned when **no** arrivals of the model
//!   remain (`None` while arrivals remain would let an engine run past
//!   a barrier that needs it).
//!
//! Conservative peeking never changes results, only scheduling
//! granularity: a `Sim`'s trajectory is a pure function of its
//! (step-time, injection) call sequence, and frontiers only decide how
//! far an engine runs *ahead* between barriers, never which barriers it
//! observes. That is why a byte-identity test over
//! {materialized, streamed} × {epoch, sparse} × threads can (and does)
//! pass — `rust/tests/parallel_exec.rs`.

use super::{ArrivalIter, Arrivals, Request};
use crate::gpu::Us;
use crate::util::rng::Pcg32;
use std::cmp::Reverse;
use std::collections::{BinaryHeap, VecDeque};

/// Ordered, peekable source of arrivals — what [`crate::cluster::exec`]
/// drives engines from. See the module docs for the peeking contract.
pub trait ArrivalStream {
    /// Arrival time of the globally next request, if any remain.
    fn peek_time(&self) -> Option<Us>;

    /// Lower bound on `model`'s next arrival time; `None` only when no
    /// arrivals of the model remain. Returning [`Self::peek_time`] is
    /// always a safe (conservative) fallback.
    fn peek_model(&self, model: usize) -> Option<Us>;

    /// Pop the globally next request (ties broken by model index).
    fn next_request(&mut self) -> Option<Request>;

    /// Requests currently buffered in memory by the source — the
    /// peak-RSS proxy `bench_streaming` tracks. O(models) for the lazy
    /// sources, O(remaining) for [`MaterializedStream`].
    fn buffered(&self) -> usize;
}

/// Lazy k-way merge of per-model [`ArrivalIter`]s: one buffered head
/// per model, a min-heap on `(arrival, model)`, ids assigned in merge
/// order. Memory is O(models) regardless of stream length.
///
/// Seeding matches [`super::merged_stream`] exactly — model `m` draws
/// from `Pcg32::new(seed, m + 1)` — and the `(arrival, model)` heap
/// order reproduces the materialized path's `(arrival, id)` sort (ids
/// used to be assigned in per-model blocks, so sorting by id *was*
/// sorting by model index at equal arrivals). `merged_stream` is this
/// stream collected.
pub struct MergedStream {
    sources: Vec<ArrivalIter>,
    /// Per-model lookahead head (`id` unassigned until popped).
    heads: Vec<Option<Request>>,
    /// One live entry per model with a pending head.
    heap: BinaryHeap<Reverse<(Us, usize)>>,
    next_id: u64,
    buffered: usize,
}

impl MergedStream {
    /// Merge the per-model processes in `specs` (`(process, slo_ms)` per
    /// model index) over `[0, horizon_ms)`.
    pub fn new(specs: &[(Arrivals, f64)], horizon_ms: f64, seed: u64) -> MergedStream {
        let mut sources = Vec::with_capacity(specs.len());
        let mut heads = Vec::with_capacity(specs.len());
        let mut heap = BinaryHeap::with_capacity(specs.len());
        let mut buffered = 0;
        for (model, (arr, slo)) in specs.iter().enumerate() {
            // Independent stream per model for reproducibility under
            // reorder — the same seeding as the materialized path.
            let mut it = arr.iter(model, *slo, horizon_ms, Pcg32::new(seed, model as u64 + 1));
            let head = it.next();
            if let Some(r) = &head {
                heap.push(Reverse((r.arrival, model)));
                buffered += 1;
            }
            sources.push(it);
            heads.push(head);
        }
        MergedStream { sources, heads, heap, next_id: 0, buffered }
    }

    /// Number of per-model sources (the stream's model-index domain).
    pub fn n_models(&self) -> usize {
        self.sources.len()
    }
}

impl ArrivalStream for MergedStream {
    fn peek_time(&self) -> Option<Us> {
        self.heap.peek().map(|&Reverse((a, _))| a)
    }

    fn peek_model(&self, model: usize) -> Option<Us> {
        self.heads.get(model).and_then(|h| h.as_ref().map(|r| r.arrival))
    }

    fn next_request(&mut self) -> Option<Request> {
        let Reverse((_, m)) = self.heap.pop()?;
        let mut r = self.heads[m].take().expect("heap entry without a buffered head");
        r.id = self.next_id;
        self.next_id += 1;
        match self.sources[m].next() {
            Some(n) => {
                self.heap.push(Reverse((n.arrival, m)));
                self.heads[m] = Some(n);
            }
            None => self.buffered -= 1,
        }
        Some(r)
    }

    fn buffered(&self) -> usize {
        self.buffered
    }
}

impl Iterator for MergedStream {
    type Item = Request;

    fn next(&mut self) -> Option<Request> {
        self.next_request()
    }
}

/// `Vec<Request>` adapter: the legacy materialized path expressed as a
/// stream, with exact per-model peeking. This is what the `Vec`-taking
/// driver signatures wrap their input in, so the pre-streaming call
/// sequence (and hence every report byte) is preserved.
pub struct MaterializedStream {
    queue: VecDeque<Request>,
    /// Per-model pending arrival times, popped in lockstep with
    /// `queue` — times only ever pop, so an earlier-computed frontier
    /// can never exceed a model's next arrival.
    times: Vec<VecDeque<Us>>,
}

impl MaterializedStream {
    /// Wrap an arrival-sorted request vector; `n_models` is the global
    /// model-index domain (every `Request::model` must be below it).
    pub fn new(requests: Vec<Request>, n_models: usize) -> MaterializedStream {
        debug_assert!(
            requests.windows(2).all(|w| w[0].arrival <= w[1].arrival),
            "request stream must be sorted by arrival time"
        );
        let mut times = vec![VecDeque::new(); n_models];
        for r in &requests {
            times[r.model].push_back(r.arrival);
        }
        MaterializedStream { queue: requests.into(), times }
    }
}

impl ArrivalStream for MaterializedStream {
    fn peek_time(&self) -> Option<Us> {
        self.queue.front().map(|r| r.arrival)
    }

    fn peek_model(&self, model: usize) -> Option<Us> {
        self.times.get(model).and_then(|q| q.front().copied())
    }

    fn next_request(&mut self) -> Option<Request> {
        let r = self.queue.pop_front()?;
        let t = self.times[r.model].pop_front();
        debug_assert_eq!(t, Some(r.arrival), "per-model times out of lockstep");
        Some(r)
    }

    fn buffered(&self) -> usize {
        self.queue.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::workload::merged_stream;

    fn specs() -> Vec<(Arrivals, f64)> {
        vec![
            (Arrivals::Poisson { rate: 300.0 }, 25.0),
            (Arrivals::Uniform { rate: 120.0, jitter: 0.4 }, 50.0),
            (Arrivals::trace(vec![(0.0, 200.0), (800.0, 50.0)]), 100.0),
        ]
    }

    #[test]
    fn merged_stream_is_lazy_merge_collected() {
        let eager = merged_stream(&specs(), 1_500.0, 42);
        let lazy: Vec<Request> = MergedStream::new(&specs(), 1_500.0, 42).collect();
        assert_eq!(eager, lazy, "eager adapter must equal the lazy merge, ids included");
        assert!(eager.len() > 300, "stream too small to be meaningful: {}", eager.len());
        for w in eager.windows(2) {
            assert!(w[0].arrival <= w[1].arrival);
        }
        // Merge-order ids are dense and sequential.
        for (i, r) in eager.iter().enumerate() {
            assert_eq!(r.id, i as u64);
        }
    }

    #[test]
    fn merged_peeks_are_exact_and_buffer_is_o_models() {
        let mut s = MergedStream::new(&specs(), 1_000.0, 9);
        assert_eq!(s.n_models(), 3);
        assert!(s.buffered() <= 3, "lazy merge buffers one head per model");
        let mut n = 0u64;
        loop {
            // The global head must equal the min over per-model heads,
            // and what pops next must match both.
            let per_model = (0..3).filter_map(|m| s.peek_model(m)).min();
            let head = s.peek_time();
            assert_eq!(head, per_model, "global head must equal the min per-model head");
            let Some(r) = s.next_request() else { break };
            assert_eq!(Some(r.arrival), head, "pop disagreed with peek");
            assert!(s.peek_time().map_or(true, |t| t >= r.arrival), "order violated");
            assert!(s.buffered() <= 3);
            n += 1;
        }
        assert!(n > 100, "{n}");
        assert!((0..3).all(|m| s.peek_model(m).is_none()));
    }

    #[test]
    fn materialized_stream_round_trips() {
        let reqs = merged_stream(&specs(), 800.0, 5);
        let total = reqs.len();
        let mut s = MaterializedStream::new(reqs.clone(), 3);
        assert_eq!(s.buffered(), total);
        let mut out = Vec::new();
        while let Some(r) = s.next_request() {
            out.push(r);
        }
        assert_eq!(out, reqs);
        assert_eq!(s.buffered(), 0);
        assert!(s.peek_time().is_none());
        assert!(s.peek_model(2).is_none());
    }

    #[test]
    fn materialized_peek_model_is_exact() {
        let reqs = vec![
            Request { id: 0, model: 1, arrival: 100, deadline: 1_100 },
            Request { id: 1, model: 0, arrival: 250, deadline: 1_250 },
            Request { id: 2, model: 1, arrival: 400, deadline: 1_400 },
        ];
        let mut s = MaterializedStream::new(reqs, 2);
        assert_eq!(s.peek_time(), Some(100));
        assert_eq!(s.peek_model(0), Some(250));
        assert_eq!(s.peek_model(1), Some(100));
        s.next_request();
        assert_eq!(s.peek_model(1), Some(400));
        s.next_request();
        assert_eq!(s.peek_model(0), None);
        assert_eq!(s.peek_model(1), Some(400));
    }
}
