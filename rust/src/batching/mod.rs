//! Batch-assembly policies.
//!
//! Three batching disciplines appear in the paper's comparisons (§7):
//! *fixed* batching (always the max batch — the FB baseline), *adaptive*
//! batching (Clipper/Nexus-style: take what's queued, capped by what
//! fits the latency budget — used by GSLICE and the temporal baseline),
//! and the *optimal* batch from the §5 optimization (used by D-STACK).
//!
//! The optimal batch is a property of a replica's deployed operating
//! point: it is chosen per (model, GPU type) by the §5 optimizer and
//! carried in [`crate::sim::ModelEntry::batch`]. When the adaptive
//! control plane ([`crate::controlplane`]) migrates a replica across
//! GPU types, the receiving engine's entry therefore arrives with a
//! freshly derived batch for that device — no batching state survives a
//! migration.

use crate::optimizer;
use crate::profile::{GpuSpec, ModelProfile};

/// Batching discipline.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum BatchPolicy {
    /// Always wait for / take the model's max batch (FB baseline).
    Fixed,
    /// Take min(queued, max_batch), additionally capped so inference
    /// fits the remaining latency budget (Clipper/Nexus adaptive).
    Adaptive,
    /// The §5 optimizer's batch, capped by queue occupancy.
    Optimal,
}

/// Decide a batch size.
///
/// * `queued` — requests currently waiting for this model.
/// * `opt_batch` — the model's optimizer-derived batch.
/// * `budget_ms` — remaining time before the oldest request's deadline
///   (or the slice end, whichever is smaller); `None` = unconstrained.
/// * `gpu_pct` — allocation the batch would run at.
pub fn choose_batch(
    policy: BatchPolicy,
    m: &ModelProfile,
    gpu: &GpuSpec,
    queued: usize,
    opt_batch: u32,
    gpu_pct: u32,
    budget_ms: Option<f64>,
) -> u32 {
    let queued = queued as u32;
    if queued == 0 {
        return 0;
    }
    match policy {
        BatchPolicy::Fixed => {
            // FB waits for a full batch; partial queues produce nothing.
            if queued >= m.max_batch {
                m.max_batch
            } else {
                0
            }
        }
        BatchPolicy::Adaptive => {
            let want = queued.min(m.max_batch);
            match budget_ms {
                Some(budget) => {
                    let fit = optimizer::max_batch_within(m, gpu, gpu_pct, budget);
                    want.min(fit)
                }
                None => want,
            }
        }
        BatchPolicy::Optimal => {
            let want = queued.min(opt_batch).min(m.max_batch);
            match budget_ms {
                Some(budget) => {
                    let fit = optimizer::max_batch_within(m, gpu, gpu_pct, budget);
                    want.min(fit)
                }
                None => want,
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::{by_name, V100};

    #[test]
    fn fixed_waits_for_full_batch() {
        let m = by_name("alexnet").unwrap();
        assert_eq!(choose_batch(BatchPolicy::Fixed, &m, &V100, 10, 16, 30, None), 0);
        assert_eq!(choose_batch(BatchPolicy::Fixed, &m, &V100, 16, 16, 30, None), 16);
        assert_eq!(choose_batch(BatchPolicy::Fixed, &m, &V100, 40, 16, 30, None), 16);
    }

    #[test]
    fn adaptive_takes_whats_queued() {
        let m = by_name("alexnet").unwrap();
        assert_eq!(choose_batch(BatchPolicy::Adaptive, &m, &V100, 5, 16, 30, None), 5);
        assert_eq!(choose_batch(BatchPolicy::Adaptive, &m, &V100, 99, 16, 30, None), 16);
        assert_eq!(choose_batch(BatchPolicy::Adaptive, &m, &V100, 0, 16, 30, None), 0);
    }

    #[test]
    fn adaptive_respects_budget() {
        let m = by_name("alexnet").unwrap();
        // A budget between the batch-1 and batch-16 latencies forces a
        // partial batch.
        let budget =
            0.5 * (m.latency_ms(m.knee_pct, 1) + m.latency_ms(m.knee_pct, 16));
        let b = choose_batch(BatchPolicy::Adaptive, &m, &V100, 16, 16, m.knee_pct, Some(budget));
        assert!(b > 0 && b < 16, "{b} (budget {budget})");
        // Impossible budget → no launch.
        assert_eq!(
            choose_batch(BatchPolicy::Adaptive, &m, &V100, 16, 16, m.knee_pct, Some(0.001)),
            0
        );
    }

    #[test]
    fn optimal_caps_at_opt_batch() {
        let m = by_name("vgg19").unwrap();
        assert_eq!(choose_batch(BatchPolicy::Optimal, &m, &V100, 99, 8, 50, None), 8);
        assert_eq!(choose_batch(BatchPolicy::Optimal, &m, &V100, 3, 8, 50, None), 3);
    }
}
