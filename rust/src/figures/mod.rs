//! Regeneration of every table and figure in the paper's evaluation
//! (the DESIGN.md §5 per-experiment index). Each generator returns a
//! [`FigData`] (header + rows) that the CLI renders as an ASCII table
//! and writes as CSV under `results/`.

use crate::analytic::AnalyticDnn;
use crate::config::{build_policy, PolicyKind};
use crate::gpu::us_to_ms;
use crate::metrics::RunReport;
use crate::optimizer::{self, OptConfig};
use crate::profile::{self, by_name, GpuSpec, ModelProfile, P100, T4, V100};
use crate::sim::{entries_at_optimum, ModelEntry, Sim, SimConfig};
use crate::workload::{fig11a_rates, merged_stream, slo_proportional_rates, Arrivals};
use std::path::Path;

/// One regenerated table/figure dataset.
pub struct FigData {
    pub name: String,
    pub title: String,
    pub header: Vec<String>,
    pub rows: Vec<Vec<String>>,
}

impl FigData {
    fn new(name: &str, title: &str, header: &[&str]) -> FigData {
        FigData {
            name: name.into(),
            title: title.into(),
            header: header.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    fn push(&mut self, row: Vec<String>) {
        self.rows.push(row);
    }

    pub fn render(&self) -> String {
        let hdr: Vec<&str> = self.header.iter().map(|s| s.as_str()).collect();
        format!("# {} — {}\n{}", self.name, self.title, crate::util::ascii_table(&hdr, &self.rows))
    }

    pub fn write_csv(&self, dir: &Path) -> std::io::Result<()> {
        let hdr: Vec<&str> = self.header.iter().map(|s| s.as_str()).collect();
        crate::util::write_file(
            &dir.join(format!("{}.csv", self.name)),
            &crate::util::to_csv(&hdr, &self.rows),
        )
    }
}

fn f(v: f64) -> String {
    format!("{v:.2}")
}

fn run_mix(
    names: &[&str],
    rates: &[f64],
    policy: PolicyKind,
    horizon_ms: f64,
    seed: u64,
) -> RunReport {
    let profiles: Vec<ModelProfile> = names.iter().map(|n| by_name(n).unwrap()).collect();
    let entries: Vec<ModelEntry> = entries_at_optimum(&profiles);
    let specs: Vec<_> = profiles
        .iter()
        .zip(rates)
        .map(|(p, &r)| (Arrivals::Poisson { rate: r }, p.slo_ms))
        .collect();
    let reqs = merged_stream(&specs, horizon_ms, seed);
    let mut pol = build_policy(policy, &entries);
    let cfg = SimConfig {
        horizon_ms,
        allow_oversub: policy == PolicyKind::FixedBatch,
        ..Default::default()
    };
    let mut sim = Sim::new(cfg, entries);
    sim.run(pol.as_mut(), &reqs)
}

// ---------------------------------------------------------------------------
// Tables
// ---------------------------------------------------------------------------

/// Table 1: Triton vs D-STACK completing 10 000 images per model
/// (4 models on one V100) — task completion time.
pub fn table1() -> FigData {
    let names = ["alexnet", "mobilenet", "resnet50", "vgg19"];
    let mut out = FigData::new(
        "table1",
        "task completion: 4 models x 10k images (s)",
        &["policy", "completion_s", "reduction_vs_triton_%"],
    );
    // 10k images per model arrive over the first 5 s (open loop at
    // 2000/s each); deadline pressure removed (completion-time metric).
    let profiles: Vec<ModelProfile> = names
        .iter()
        .map(|n| {
            let mut p = by_name(n).unwrap();
            p.slo_ms = 1e7; // no deadline: measure completion
            p
        })
        .collect();
    let entries = entries_at_optimum(&profiles);
    let specs: Vec<_> =
        profiles.iter().map(|p| (Arrivals::Poisson { rate: 2_000.0 }, p.slo_ms)).collect();
    // 5 s of arrivals ≈ 10k per model; long horizon to drain.
    let reqs = merged_stream(&specs, 5_000.0, 10);
    let mut completions = Vec::new();
    for kind in [PolicyKind::Triton, PolicyKind::Dstack] {
        let mut pol = build_policy(kind, &entries);
        let mut sim =
            Sim::new(SimConfig { horizon_ms: 300_000.0, ..Default::default() }, entries.clone());
        let rep = sim.run(pol.as_mut(), &reqs);
        completions.push((kind.name(), us_to_ms(rep.last_completion_us) / 1_000.0));
    }
    let triton = completions[0].1;
    for (name, secs) in completions {
        out.push(vec![
            name.to_string(),
            f(secs),
            f((1.0 - secs / triton) * 100.0),
        ]);
    }
    out
}

/// Table 2: compute- vs memory-bound kernels by arithmetic intensity.
pub fn table2() -> FigData {
    let mut out = FigData::new(
        "table2",
        "arithmetic intensity classification (V100 threshold 139.8 FLOP/B)",
        &["model", "kernel", "gflops", "mbytes", "arith_intensity", "limit"],
    );
    let models = ["alexnet", "resnet50", "vgg19", "gnmt"];
    for name in models {
        let m = by_name(name).unwrap();
        for k in &m.kernels {
            out.push(vec![
                name.to_string(),
                k.name.to_string(),
                format!("{:.3}", k.gflops),
                format!("{:.2}", k.mbytes),
                format!("{:.0}", k.arithmetic_intensity()),
                if k.is_compute_bound(&V100) { "Compute" } else { "Memory" }.to_string(),
            ]);
        }
    }
    out
}

/// Table 3: p99 *service* (inference) latency in isolation vs 5-way
/// multiplexed at the knee. The paper measures < 3% delta on real
/// hardware because CSS maintains SM isolation; in the simulator SM
/// isolation holds by construction, so this regenerates the same
/// conclusion from the Gantt-recorded batch service times.
pub fn table3() -> FigData {
    let mut out = FigData::new(
        "table3",
        "p99 service latency (ms) of knee-allocated batches: isolation vs 5-way multiplexed",
        &["model", "knee_%", "isolation_p99", "multiplexed_p99", "delta_%"],
    );
    let names = ["mobilenet", "resnet18", "bert", "resnet50", "vgg19"];

    // Collect per-launch service durations for launches at the model's
    // knee allocation.
    // Compare like with like: same allocation AND same batch size.
    // Collect durations bucketed by batch at the knee allocation.
    fn service_by_batch(
        sim: &Sim,
        model: usize,
        knee: u32,
    ) -> std::collections::BTreeMap<u32, Vec<f64>> {
        let mut map: std::collections::BTreeMap<u32, Vec<f64>> = Default::default();
        for e in sim.gpu.gantt.as_ref().unwrap() {
            if e.model == model && e.pct == knee {
                map.entry(e.batch).or_default().push(us_to_ms(e.end - e.start));
            }
        }
        map
    }

    let run = |names: &[&str], rates: &[f64], kind: PolicyKind| -> Sim {
        let profiles: Vec<ModelProfile> = names.iter().map(|n| by_name(n).unwrap()).collect();
        let entries = entries_at_optimum(&profiles);
        let specs: Vec<_> = profiles
            .iter()
            .zip(rates)
            .map(|(p, &r)| (Arrivals::Poisson { rate: r }, p.slo_ms))
            .collect();
        let reqs = merged_stream(&specs, 5_000.0, 3);
        let mut pol = build_policy(kind, &entries);
        let mut sim = Sim::new(
            SimConfig { horizon_ms: 5_000.0, gantt: true, ..Default::default() },
            entries,
        );
        sim.run(pol.as_mut(), &reqs);
        sim
    };

    let multi = run(&names, &[200.0; 5], PolicyKind::Dstack);
    for (i, n) in names.iter().enumerate() {
        let m = by_name(n).unwrap();
        let iso = run(&[n], &[200.0], PolicyKind::Dstack);
        let iso_b = service_by_batch(&iso, 0, m.knee_pct);
        let mul_b = service_by_batch(&multi, i, m.knee_pct);
        // Largest batch size with enough samples in BOTH runs.
        let bucket = iso_b
            .keys()
            .rev()
            .find(|b| iso_b[b].len() >= 5 && mul_b.get(b).is_some_and(|v| v.len() >= 5))
            .copied();
        let (iso_p99, mul_p99) = match bucket {
            Some(b) => (
                crate::util::stats::percentile(&iso_b[&b], 99.0),
                crate::util::stats::percentile(&mul_b[&b], 99.0),
            ),
            None => (m.latency_ms(m.knee_pct, 16), m.latency_ms(m.knee_pct, 16)),
        };
        let delta = if iso_p99 > 0.0 { (mul_p99 - iso_p99) / iso_p99 * 100.0 } else { 0.0 };
        out.push(vec![
            n.to_string(),
            format!("{}", m.knee_pct),
            f(iso_p99),
            f(mul_p99),
            f(delta),
        ]);
    }
    out
}

/// Table 6: per-model optimal operating points from the §5 optimizer.
pub fn table6() -> FigData {
    let mut out = FigData::new(
        "table6",
        "optimizer-derived operating points (V100)",
        &["model", "knee_%", "slo_ms", "batch", "runtime_ms"],
    );
    for row in optimizer::table6(&profile::zoo()) {
        out.push(vec![
            row.model,
            format!("{}", row.knee_pct),
            format!("{:.0}", row.slo_ms),
            format!("{}", row.batch),
            f(row.runtime_ms),
        ]);
    }
    out
}

// ---------------------------------------------------------------------------
// Figures
// ---------------------------------------------------------------------------

/// Fig. 2: V100 inference latency vs GPU% at batch 16.
pub fn fig2() -> FigData {
    let mut out = FigData::new(
        "fig2",
        "V100 latency (ms) vs GPU% (batch=16)",
        &["gpu_pct", "mobilenet", "alexnet", "bert", "resnet18", "resnet50", "inception", "vgg19"],
    );
    let models = ["mobilenet", "alexnet", "bert", "resnet18", "resnet50", "inception", "vgg19"];
    let profiles: Vec<ModelProfile> = models.iter().map(|m| by_name(m).unwrap()).collect();
    for pct in (10..=100).step_by(10) {
        let mut row = vec![pct.to_string()];
        for p in &profiles {
            row.push(f(p.latency_ms(pct, 16)));
        }
        out.push(row);
    }
    out
}

/// Fig. 3: latency vs GPU% on P100 and T4 for light models.
pub fn fig3() -> FigData {
    let mut out = FigData::new(
        "fig3",
        "P100/T4 latency (ms) vs GPU% (batch=16)",
        &["gpu_pct", "A-P100", "A-T4", "Sq-P100", "Sq-T4", "R-P100", "R-T4"],
    );
    let models = ["alexnet", "squeezenet", "resnet50"];
    let gpus: [&GpuSpec; 2] = [&P100, &T4];
    for pct in (10..=100).step_by(10) {
        let mut row = vec![pct.to_string()];
        for name in models {
            let m = profile::light_models().into_iter().find(|p| p.name == name).unwrap();
            for gpu in gpus {
                row.push(f(m.latency_ms_on(gpu, pct, 16)));
            }
        }
        out.push(row);
    }
    out
}

/// Fig. 4a/b: the analytic DNN's latency and knee-metric curves.
pub fn fig4ab() -> FigData {
    let mut out = FigData::new(
        "fig4ab",
        "analytic model: latency + efficiency vs SMs (N1=20/40/60)",
        &["sms", "lat_n20", "lat_n40", "lat_n60", "eff_n20", "eff_n40", "eff_n60"],
    );
    let dnns = [AnalyticDnn::fig4(20.0), AnalyticDnn::fig4(40.0), AnalyticDnn::fig4(60.0)];
    for s in 1..=80u32 {
        let mut row = vec![s.to_string()];
        for d in &dnns {
            row.push(f(d.latency_ms(s as f64, 1.0)));
        }
        for d in &dnns {
            row.push(format!("{:.3e}", d.efficiency(s as f64, 1.0)));
        }
        out.push(row);
    }
    out
}

/// Fig. 4c/d: mobilenet latency + knee metric vs GPU% across batches.
pub fn fig4cd() -> FigData {
    let mut out = FigData::new(
        "fig4cd",
        "mobilenet latency (ms) and knee GPU% vs batch",
        &["gpu_pct", "lat_b1", "lat_b2", "lat_b4", "lat_b8", "knee_pct_of_batch"],
    );
    let m = by_name("mobilenet").unwrap();
    for pct in (10..=100).step_by(10) {
        let knee_note = match pct {
            10 => m.knee_pct_on(&V100, 1).to_string(),
            20 => m.knee_pct_on(&V100, 2).to_string(),
            30 => m.knee_pct_on(&V100, 4).to_string(),
            40 => m.knee_pct_on(&V100, 8).to_string(),
            _ => String::new(),
        };
        out.push(vec![
            pct.to_string(),
            f(m.latency_ms(pct, 1)),
            f(m.latency_ms(pct, 2)),
            f(m.latency_ms(pct, 4)),
            f(m.latency_ms(pct, 8)),
            knee_note,
        ]);
    }
    out
}

/// Fig. 5: Mobilenet per-kernel thread counts, GPU% demand and runtime.
pub fn fig5() -> FigData {
    let mut out = FigData::new(
        "fig5",
        "mobilenet kernels: threads, GPU% demand, runtime share",
        &["kernel", "threads", "gpu_pct_demand", "runtime_frac", "reps"],
    );
    let m = by_name("mobilenet").unwrap();
    for k in &m.kernels {
        out.push(vec![
            k.name.to_string(),
            k.threads.to_string(),
            f(V100.pct_for_threads(k.threads)),
            format!("{:.3}", k.runtime_frac),
            k.reps.to_string(),
        ]);
    }
    out
}

/// Fig. 6: knee metric (Eq. 6) per model; BERT at 10 and 20 words.
pub fn fig6() -> FigData {
    let mut out = FigData::new(
        "fig6",
        "knee metric vs GPU% (batch 16); bert at 10/20 words",
        &["gpu_pct", "mobilenet", "resnet18", "resnet50", "vgg19", "bert10", "bert20"],
    );
    let ms: Vec<ModelProfile> =
        ["mobilenet", "resnet18", "resnet50", "vgg19"].iter().map(|m| by_name(m).unwrap()).collect();
    let bert10 = by_name("bert").unwrap();
    // 20-word sentences: double the work → knee moves right (paper: 30→40%).
    let bert20 = crate::profile::bert_long();
    for pct in (5..=100).step_by(5) {
        let sms = V100.sms_for_pct(pct) as f64;
        let mut row = vec![pct.to_string()];
        for m in ms.iter().chain([&bert10, &bert20]) {
            row.push(format!("{:.3e}", m.dnn.efficiency(sms, 16.0)));
        }
        out.push(row);
    }
    out
}

/// Fig. 7: ResNet-50 efficacy surface over (batch, GPU%).
pub fn fig7() -> FigData {
    let mut out = FigData::new(
        "fig7",
        "resnet50 efficacy (Eq. 7) over batch x GPU%",
        &["batch", "pct10", "pct20", "pct30", "pct40", "pct50", "pct70", "pct100"],
    );
    let m = by_name("resnet50").unwrap();
    let cfg = OptConfig { slo_ms: Some(1e9), ..Default::default() };
    for b in [1u32, 2, 4, 8, 12, 16] {
        let mut row = vec![b.to_string()];
        for pct in [10u32, 20, 30, 40, 50, 70, 100] {
            let p = optimizer::evaluate(&m, &V100, b, pct, &cfg);
            row.push(f(p.efficacy));
        }
        out.push(row);
    }
    out
}

/// Fig. 8: Mobilenet feasibility region + optimal point (SLO 50 ms).
pub fn fig8() -> FigData {
    let mut out = FigData::new(
        "fig8",
        "mobilenet feasibility (SLO=50ms): rows batch, cols GPU%; *=feasible",
        &["batch", "p10", "p20", "p30", "p40", "p50", "p70", "p100", "efficacy_at_knee"],
    );
    let mut m = by_name("mobilenet").unwrap();
    m.slo_ms = 50.0;
    let cfg = OptConfig::default();
    for b in [1u32, 2, 4, 8, 12, 16] {
        let mut row = vec![b.to_string()];
        for pct in [10u32, 20, 30, 40, 50, 70, 100] {
            let p = optimizer::evaluate(&m, &V100, b, pct, &cfg);
            row.push(if p.feasible { format!("*{:.1}", p.efficacy) } else { "-".into() });
        }
        let knee = m.knee_pct_on(&V100, b);
        let p = optimizer::evaluate(&m, &V100, b, knee, &cfg);
        row.push(f(p.efficacy));
        out.push(row);
    }
    let opt = optimizer::optimize(&m, &V100, &cfg).unwrap();
    out.push(vec![
        format!("OPT: batch {} @ {}%", opt.batch, opt.gpu_pct),
        f(opt.latency_ms),
        f(opt.throughput),
        f(opt.efficacy),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
        String::new(),
    ]);
    out
}

/// Fig. 9a-c: schedule utilization for temporal, plain spatio-temporal
/// and full D-STACK on the alexnet/resnet50/vgg19 session.
pub fn fig9abc() -> FigData {
    let mut out = FigData::new(
        "fig9abc",
        "scheduling of {alexnet,resnet50,vgg19}: mean GPU utilization",
        &["policy", "util_%", "thpt_req_s", "viol_frac"],
    );
    let names = ["alexnet", "resnet50", "vgg19"];
    let rates = slo_proportional_rates(1_400.0, &[25.0, 50.0, 100.0]);
    for kind in [PolicyKind::Temporal, PolicyKind::SpatioTemporalOnly, PolicyKind::Dstack] {
        let rep = run_mix(&names, &rates, kind, 10_000.0, 9);
        out.push(vec![
            kind.name().to_string(),
            f(rep.mean_utilization() * 100.0),
            f(rep.total_throughput()),
            format!("{:.3}", rep.violation_fraction()),
        ]);
    }
    out
}

/// Fig. 9d: ideal vs D-STACK vs GSLICE vs temporal on ConvNet-1/2/3.
pub fn fig9d() -> FigData {
    let mut out = FigData::new(
        "fig9d",
        "convnet1-3 saturated: utilization and throughput vs ideal",
        &["policy", "util_%", "thpt_img_s", "thpt_vs_ideal_%"],
    );
    let profiles = profile::convnets();
    let entries = entries_at_optimum(&profiles);
    let specs: Vec<_> =
        profiles.iter().map(|p| (Arrivals::Poisson { rate: 2_000.0 }, p.slo_ms)).collect();
    let reqs = merged_stream(&specs, 5_000.0, 11);
    let ideal = crate::sched::ideal::run_ideal(&profiles, &V100, 16, 5_000.0, 100);
    let ideal_thpt: f64 = ideal.throughput.iter().sum();
    for kind in [PolicyKind::Temporal, PolicyKind::Gslice, PolicyKind::Dstack] {
        let mut pol = build_policy(kind, &entries);
        let mut sim =
            Sim::new(SimConfig { horizon_ms: 5_000.0, ..Default::default() }, entries.clone());
        let rep = sim.run(pol.as_mut(), &reqs);
        out.push(vec![
            kind.name().to_string(),
            f(rep.mean_utilization() * 100.0),
            f(rep.total_throughput() * 16.0 / 16.0),
            f(rep.total_throughput() / ideal_thpt * 100.0),
        ]);
    }
    out.push(vec![
        "ideal".into(),
        f(ideal.utilization * 100.0),
        f(ideal_thpt),
        f(100.0),
    ]);
    out
}

/// Fig. 10: throughput and GPU runtime per model across schedulers.
pub fn fig10() -> FigData {
    let mut out = FigData::new(
        "fig10",
        "per-model throughput (req/s) / GPU runtime (s) over 10 s",
        &["policy", "alexnet", "mobilenet", "resnet50", "vgg19", "fairness_jain"],
    );
    let names = ["alexnet", "mobilenet", "resnet50", "vgg19"];
    let rates = slo_proportional_rates(1_900.0, &[25.0, 25.0, 50.0, 100.0]);
    for kind in
        [PolicyKind::Temporal, PolicyKind::MaxThroughput, PolicyKind::MaxMin, PolicyKind::Dstack]
    {
        let rep = run_mix(&names, &rates, kind, 10_000.0, 5);
        let t = rep.throughput();
        out.push(vec![
            format!("{} thpt", kind.name()),
            f(t[0]),
            f(t[1]),
            f(t[2]),
            f(t[3]),
            format!("{:.3}", rep.runtime_fairness()),
        ]);
        out.push(vec![
            format!("{} runtime_s", kind.name()),
            f(rep.busy_ms[0] / 1_000.0),
            f(rep.busy_ms[1] / 1_000.0),
            f(rep.busy_ms[2] / 1_000.0),
            f(rep.busy_ms[3] / 1_000.0),
            String::new(),
        ]);
    }
    out
}

/// Fig. 11a: throughput + SLO violations for C-2/3/4/7 mixes across
/// FB / temporal / Triton / GSLICE / D-STACK.
pub fn fig11a() -> FigData {
    let mut out = FigData::new(
        "fig11a",
        "multiplexing mixes: total throughput (req/s) and violations/s",
        &["mix", "policy", "thpt", "viol_per_s", "viol_frac", "util_%"],
    );
    for mix in ["C-2", "C-3", "C-4", "C-7"] {
        let spec = fig11a_rates(mix);
        let names: Vec<&str> = spec.iter().map(|(n, _)| *n).collect();
        let rates: Vec<f64> = spec.iter().map(|(_, r)| *r).collect();
        for kind in [
            PolicyKind::FixedBatch,
            PolicyKind::Temporal,
            PolicyKind::Triton,
            PolicyKind::Gslice,
            PolicyKind::Dstack,
        ] {
            let rep = run_mix(&names, &rates, kind, 10_000.0, 21);
            out.push(vec![
                mix.to_string(),
                kind.name().to_string(),
                f(rep.total_throughput()),
                f(rep.total_violations_per_sec()),
                format!("{:.3}", rep.violation_fraction()),
                f(rep.mean_utilization() * 100.0),
            ]);
        }
    }
    out
}

/// Fig. 11b: D-STACK under dynamically varying rates (5 phases).
pub fn fig11b() -> FigData {
    let mut out = FigData::new(
        "fig11b",
        "dynamic rates: per-phase served req/s under D-STACK",
        &["phase", "alexnet", "mobilenet", "resnet50", "vgg19", "util_%"],
    );
    let names = ["alexnet", "mobilenet", "resnet50", "vgg19"];
    let profiles: Vec<ModelProfile> = names.iter().map(|n| by_name(n).unwrap()).collect();
    let entries = entries_at_optimum(&profiles);
    let phase_ms = 2_000.0;
    let base = [700.0, 700.0, 320.0, 160.0];
    let mut specs = Vec::new();
    for (i, p) in profiles.iter().enumerate() {
        let mut segments = vec![(0.0, base[i])];
        for k in 1..5usize {
            let rate = if k - 1 == i { base[i] * 0.3 } else { base[i] };
            segments.push((k as f64 * phase_ms, rate));
        }
        specs.push((Arrivals::trace(segments), p.slo_ms));
    }
    let reqs = merged_stream(&specs, 5.0 * phase_ms, 3);
    let mut pol = build_policy(PolicyKind::Dstack, &entries);
    let mut sim = Sim::new(
        SimConfig { horizon_ms: 5.0 * phase_ms, gantt: true, ..Default::default() },
        entries,
    );
    let _rep = sim.run(pol.as_mut(), &reqs);
    let gantt = sim.gpu.gantt.as_ref().unwrap();
    for k in 0..5u64 {
        let lo = k * 2_000_000;
        let hi = lo + 2_000_000;
        let mut items = [0f64; 4];
        let mut busy = 0f64;
        for e in gantt.iter().filter(|e| e.start >= lo && e.start < hi) {
            items[e.model] += 1.0;
            busy += e.pct as f64 * (e.end.min(hi) - e.start) as f64;
        }
        out.push(vec![
            format!("T{k}"),
            f(items[0]),
            f(items[1]),
            f(items[2]),
            f(items[3]),
            f(busy / (100.0 * 2_000_000.0) * 100.0),
        ]);
    }
    out
}

/// Fig. 12: the 4×T4 cluster — the paper's three fixed layouts, then the
/// same workload re-expressed as placement scenarios on the cluster
/// engine (knee-packed placement + load-aware routing, §7.1 extended),
/// including a heterogeneous 2×V100 + 2×T4 variant.
pub fn fig12() -> FigData {
    use crate::cluster::{
        run_cluster, serve_cluster, ClusterPolicy, GpuSched, PlacementPolicy, RoutingPolicy,
    };
    let mut out = FigData::new(
        "fig12",
        "cluster throughput (req/s): fixed layouts vs placement engine",
        &["policy", "total", "mobilenet", "alexnet", "resnet50", "vgg19", "util_%"],
    );
    let horizon_ms = 8_000.0;
    let (profiles, rates, reqs) = crate::cluster::fig12_workload(horizon_ms, 77);
    let mut push = |label: String, r: &crate::cluster::ClusterReport| {
        out.push(vec![
            label,
            f(r.total_throughput()),
            f(r.throughput[0]),
            f(r.throughput[1]),
            f(r.throughput[2]),
            f(r.throughput[3]),
            f(r.mean_utilization() * 100.0),
        ]);
    };
    for pol in [ClusterPolicy::Exclusive, ClusterPolicy::TemporalAll, ClusterPolicy::DstackAll] {
        let r = run_cluster(&profiles, &T4, 4, reqs.clone(), horizon_ms, pol);
        push(r.policy.clone(), &r);
    }
    let t4x4 = vec![T4.clone(); 4];
    let hetero = vec![V100.clone(), V100.clone(), T4.clone(), T4.clone()];
    let placed: [(&str, &[GpuSpec], PlacementPolicy, RoutingPolicy); 4] = [
        ("ffd+rr 4xT4", &t4x4, PlacementPolicy::FirstFitDecreasing, RoutingPolicy::RoundRobin),
        (
            "ffd+jsq 4xT4",
            &t4x4,
            PlacementPolicy::FirstFitDecreasing,
            RoutingPolicy::JoinShortestQueue,
        ),
        ("lb+p2c 4xT4", &t4x4, PlacementPolicy::LoadBalance, RoutingPolicy::PowerOfTwoChoices),
        (
            "ffd+jsq 2xV100+2xT4",
            &hetero,
            PlacementPolicy::FirstFitDecreasing,
            RoutingPolicy::JoinShortestQueue,
        ),
    ];
    for (label, gpus, placement, routing) in placed {
        let r = serve_cluster(
            &profiles,
            &rates,
            gpus,
            placement,
            routing,
            GpuSched::Dstack,
            reqs.clone(),
            horizon_ms,
            77,
        );
        push(label.to_string(), &r);
    }
    out
}

/// Fig. 13 (beyond the paper): adaptive control plane vs static
/// placement on the drifting-rate cluster workload
/// ([`crate::workload::drift_rates`], 2×V100). Static solves the
/// knee packing once — for the per-model peak rates, which never occur
/// simultaneously — and strands two models at admission; the adaptive
/// plane places for the live estimates and migrates replicas when the
/// drift detector fires.
pub fn fig13() -> FigData {
    use crate::cluster::{serve_cluster, GpuSched, PlacementPolicy, RoutingPolicy};
    use crate::controlplane::{drift_gpus, drift_workload, run_adaptive, AdaptiveCfg};
    let mut out = FigData::new(
        "fig13",
        "adaptive vs static under rate drift (req/s, drifting 2xV100 workload)",
        &["policy", "total", "resnet50", "vgg19", "alexnet", "mobilenet", "viol_per_s", "rebalances"],
    );
    let horizon_ms = 6_000.0;
    let seed = 77;
    let (profiles, initial, peak, reqs) = drift_workload(horizon_ms, seed);
    let gpus = drift_gpus();
    let mut push = |label: &str, r: &crate::cluster::ClusterReport| {
        out.push(vec![
            label.to_string(),
            f(r.total_throughput()),
            f(r.throughput[0]),
            f(r.throughput[1]),
            f(r.throughput[2]),
            f(r.throughput[3]),
            f(r.violations_per_sec.iter().sum::<f64>()),
            r.adaptive.as_ref().map_or(0, |a| a.rebalances).to_string(),
        ]);
    };
    let run_static = |rates: &[f64]| {
        serve_cluster(
            &profiles,
            rates,
            &gpus,
            PlacementPolicy::FirstFitDecreasing,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            reqs.clone(),
            horizon_ms,
            seed,
        )
    };
    push("static (peak rates)", &run_static(&peak));
    push("static (t=0 rates)", &run_static(&initial));
    let cfg = AdaptiveCfg { interval_ms: 250.0, ..Default::default() };
    let adap = run_adaptive(
        &profiles,
        &initial,
        &gpus,
        PlacementPolicy::FirstFitDecreasing,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        &cfg,
        reqs,
        horizon_ms,
        seed,
    );
    push("adaptive", &adap);
    out
}

/// Fig. 14 (beyond the paper): long-tail serving under the lifecycle
/// memory manager — cold-start p99 and goodput vs eviction policy and
/// memory headroom. A 24-model Zipf(1.1) fleet (~26 GiB of weights)
/// serves on 2×V100 whose resident budget is swept from thrash-prone
/// to roomy; each eviction policy replays the identical request stream.
pub fn fig14() -> FigData {
    use crate::cluster::{GpuSched, PlacementPolicy, RoutingPolicy};
    use crate::lifecycle::{
        longtail_gpus, longtail_workload, serve_longtail, EvictionPolicy, LifecycleCfg,
    };
    let mut out = FigData::new(
        "fig14",
        "long-tail lifecycle: goodput + cold-start p99 vs eviction policy x memory budget",
        &[
            "eviction",
            "budget_mib",
            "goodput_rps",
            "total_rps",
            "cold_p99_ms",
            "cold_starts",
            "evictions",
            "viol_per_s",
        ],
    );
    let horizon_ms = 3_000.0;
    let seed = 77;
    let (profiles, rates, reqs) = longtail_workload(24, 1.1, 600.0, horizon_ms, seed);
    let gpus = longtail_gpus();
    for &policy in EvictionPolicy::all() {
        for budget in [3_072u64, 4_096, 6_144] {
            let cfg = LifecycleCfg {
                eviction: policy,
                mem_budget_mib: budget,
                ..Default::default()
            };
            let rep = serve_longtail(
                &profiles,
                &rates,
                &gpus,
                PlacementPolicy::LoadBalance,
                RoutingPolicy::JoinShortestQueue,
                GpuSched::Dstack,
                &cfg,
                reqs.clone(),
                horizon_ms,
                seed,
            );
            let stats = rep.lifecycle.as_ref().expect("lifecycle stats");
            out.push(vec![
                policy.name().to_string(),
                budget.to_string(),
                f(stats.goodput_rps),
                f(rep.total_throughput()),
                f(stats.cold_start_p99_ms),
                stats.cold_starts.to_string(),
                stats.evictions.to_string(),
                f(rep.violations_per_sec.iter().sum::<f64>()),
            ]);
        }
    }
    out
}

/// Fig. 15 (beyond the paper): the unified control plane vs the naive
/// composition of its halves. A 24-model Zipf(1.1) fleet whose
/// popularity ranking rotates mid-stream serves on 4×V100 under two
/// memory budgets; "naive" runs the lifecycle manager alone on the
/// frozen t=0 residency plan (no replanning — the drift detector and
/// the memory manager never talk), while "unified" reprices replica
/// moves by the cold-load footprint actually paid and replans on both
/// rate drift and eviction pressure.
pub fn fig15() -> FigData {
    use crate::cluster::{GpuSched, PlacementPolicy, RoutingPolicy};
    use crate::lifecycle::{serve_longtail, LifecycleCfg};
    use crate::unified::{drifting_longtail_workload, run_unified, unified_gpus, UnifiedCfg};
    let mut out = FigData::new(
        "fig15",
        "unified control plane vs naive composition under drift + memory pressure (4xV100)",
        &[
            "policy",
            "budget_mib",
            "goodput_rps",
            "total_rps",
            "cold_p99_ms",
            "cold_starts",
            "evictions",
            "replans",
            "cold_mig_ms",
            "viol_per_s",
        ],
    );
    let horizon_ms = 6_000.0;
    let seed = 42;
    let (profiles, rates, reqs) = drifting_longtail_workload(24, 1.1, 600.0, horizon_ms, seed);
    let gpus = unified_gpus(4);
    let mut push = |label: &str, budget: u64, rep: &crate::cluster::ClusterReport| {
        let stats = rep.lifecycle.as_ref().expect("lifecycle stats");
        out.push(vec![
            label.to_string(),
            budget.to_string(),
            f(stats.goodput_rps),
            f(rep.total_throughput()),
            f(stats.cold_start_p99_ms),
            stats.cold_starts.to_string(),
            stats.evictions.to_string(),
            rep.adaptive.as_ref().map_or(0, |a| a.replans).to_string(),
            f(rep.adaptive.as_ref().and_then(|a| a.cold_migration_ms).unwrap_or(0.0)),
            f(rep.violations_per_sec.iter().sum::<f64>()),
        ]);
    };
    for budget in [4_096u64, 8_192] {
        let lcfg = LifecycleCfg { mem_budget_mib: budget, min_replicas: 1, ..Default::default() };
        let naive = serve_longtail(
            &profiles,
            &rates,
            &gpus,
            PlacementPolicy::LoadBalance,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            &lcfg,
            reqs.clone(),
            horizon_ms,
            seed,
        );
        push("naive (frozen t=0 plan)", budget, &naive);
        let ucfg = UnifiedCfg { lifecycle: lcfg, ..Default::default() };
        let unified = run_unified(
            &profiles,
            &rates,
            &gpus,
            PlacementPolicy::LoadBalance,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            &ucfg,
            reqs.clone(),
            horizon_ms,
            seed,
        );
        push("unified", budget, &unified);
    }
    out
}

/// Fig. 16 (beyond the paper): throughput and SLO misses when the same
/// mean offered load arrives bursty instead of Poisson — the Fig. 12
/// model mix on 4×T4 under each canonical arrival shape
/// ([`crate::workload::bursty_arrivals`]): MMPP burst trains, a
/// diurnal sine, and a 6× flash crowd. Arrivals stream lazily through
/// the execution core; the last two columns are the streaming
/// telemetry (total requests pulled, max buffered in flight) showing
/// the run never materializes the workload.
pub fn fig_streaming() -> FigData {
    use crate::cluster::{
        fig12_specs, serve_cluster_stream, ExecOpts, GpuSched, PlacementPolicy, RoutingPolicy,
    };
    use crate::workload::{bursty_arrivals, MergedStream};
    let mut out = FigData::new(
        "fig16",
        "throughput + SLO misses under bursty arrival streams (fig12 mix, 4xT4)",
        &[
            "workload",
            "total_rps",
            "viol_per_s",
            "shed_rps",
            "requests_streamed",
            "peak_in_flight",
        ],
    );
    let horizon_ms = 4_000.0;
    let seed = 42;
    let (profiles, rates, _) = fig12_specs();
    let gpus: Vec<GpuSpec> = (0..4).map(|_| T4.clone()).collect();
    for kind in ["poisson", "mmpp", "diurnal", "flash"] {
        let specs: Vec<_> = profiles
            .iter()
            .zip(&rates)
            .map(|(p, &r)| (bursty_arrivals(kind, r, horizon_ms).expect("known kind"), p.slo_ms))
            .collect();
        let stream = MergedStream::new(&specs, horizon_ms, seed);
        let rep = serve_cluster_stream(
            &profiles,
            &rates,
            &gpus,
            PlacementPolicy::FirstFitDecreasing,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            stream,
            horizon_ms,
            seed,
            ExecOpts::default(),
        );
        let x = rep.exec.as_ref().expect("cluster runs attach exec stats");
        out.push(vec![
            kind.to_string(),
            f(rep.total_throughput()),
            f(rep.violations_per_sec.iter().sum::<f64>()),
            f(rep.shed_rps.iter().sum::<f64>()),
            x.requests_streamed.to_string(),
            x.peak_in_flight.to_string(),
        ]);
    }
    out
}

/// Fig. 17 (beyond the paper): the unified drift *timeline* — the
/// fig15 stress scenario rerun with the deterministic event recorder
/// on, rendered as one row per virtual-time window: cluster p99 and
/// mean utilization next to the control plane's replan / eviction /
/// cold-load / scale-to-zero markers and the warm-set size. The
/// popularity rotation at the midpoint shows up as a p99 spike, a
/// burst of cold loads + evictions, then a replan restoring goodput.
pub fn fig17() -> FigData {
    fig17_with_artifacts().0
}

/// [`fig17`] plus the raw observability artifacts of the same run —
/// the Perfetto trace JSON and the windowed time-series JSON — so CI
/// uploads them without a second simulation.
pub fn fig17_with_artifacts() -> (FigData, String, String) {
    use crate::cluster::{ExecOpts, GpuSched, PlacementPolicy, RoutingPolicy};
    use crate::lifecycle::LifecycleCfg;
    use crate::obs::ObsCfg;
    use crate::unified::{drifting_longtail_workload, run_unified_with, unified_gpus, UnifiedCfg};
    let horizon_ms = 6_000.0;
    let seed = 42;
    let (profiles, rates, reqs) = drifting_longtail_workload(24, 1.1, 600.0, horizon_ms, seed);
    let gpus = unified_gpus(4);
    let ucfg = UnifiedCfg {
        lifecycle: LifecycleCfg { mem_budget_mib: 4_096, min_replicas: 1, ..Default::default() },
        ..Default::default()
    };
    let opts = ExecOpts {
        obs: ObsCfg { trace: true, timeseries: true, ..Default::default() },
        ..Default::default()
    };
    let rep = run_unified_with(
        &profiles,
        &rates,
        &gpus,
        PlacementPolicy::LoadBalance,
        RoutingPolicy::JoinShortestQueue,
        GpuSched::Dstack,
        &ucfg,
        reqs,
        horizon_ms,
        seed,
        opts,
    );
    let obs = rep.obs.as_ref().expect("recorder was enabled");
    let mut out = FigData::new(
        "fig17",
        "unified drift timeline: windowed p99/util + replan/eviction markers (4xV100)",
        &[
            "t0_ms",
            "arrivals",
            "served",
            "slo_miss",
            "p99_ms",
            "mean_util",
            "warm_models",
            "replans",
            "evictions",
            "cold_loads",
            "scale_zeros",
        ],
    );
    let n = obs.n_windows();
    let p99 = obs.per_window_p99();
    let wus = obs.cfg.window_us;
    for i in 0..n {
        let (mut arrivals, mut served, mut slo_miss, mut busy) = (0u64, 0u64, 0u64, 0u64);
        for l in &obs.lanes {
            if let Some(w) = l.windows.get(i) {
                arrivals += w.arrivals;
                served += w.served;
                slo_miss += w.slo_miss;
                busy += w.busy_us;
            }
        }
        let util = busy as f64 / (obs.lanes.len().max(1) as f64 * wus as f64);
        let cw = obs.control.windows.get(i);
        out.push(vec![
            (i as u64 * wus / 1_000).to_string(),
            arrivals.to_string(),
            served.to_string(),
            slo_miss.to_string(),
            f(p99[i]),
            f(util),
            cw.map_or(0, |w| w.warm_by_gpu.iter().sum::<u64>()).to_string(),
            cw.map_or(0, |w| w.replans).to_string(),
            cw.map_or(0, |w| w.evictions).to_string(),
            cw.map_or(0, |w| w.cold_loads).to_string(),
            cw.map_or(0, |w| w.scale_zeros).to_string(),
        ]);
    }
    let trace = obs.to_perfetto();
    let series = obs.timeseries_json().to_string_pretty();
    (out, trace, series)
}

/// Fig. 18 (beyond the paper): the resilience timeline through an
/// engine-failure cycle — the canonical 24-model long-tail fleet on
/// 2×V100 with a scripted degrade→down→up timeline on GPU 1, served
/// twice: once behind the resilient front door (cascade re-route of
/// the drained queue + hedged re-dispatch off the degraded engine)
/// and once naive (drained requests rejected, no hedging). One row
/// per virtual-time window: goodput (served-in-SLO) and p99 for each
/// variant side by side, plus how many engines were down. The outage
/// window shows the hedged run holding goodput while the naive run
/// sheds its share; recovery converges both.
pub fn fig18() -> FigData {
    use crate::cluster::{ExecOpts, GpuSched, PlacementPolicy, RoutingPolicy};
    use crate::faults::{FaultEvent, FaultKind, ResilienceCfg};
    use crate::gpu::ms_to_us;
    use crate::lifecycle::{
        longtail_gpus, longtail_workload, serve_longtail_stream_faults, LifecycleCfg,
    };
    use crate::obs::ObsCfg;
    use crate::workload::MaterializedStream;
    let horizon_ms = 6_000.0;
    let seed = 42;
    let (down_ms, up_ms) = (2_500.0, 4_000.0);
    let (profiles, rates, reqs) = longtail_workload(24, 1.1, 600.0, horizon_ms, seed);
    let gpus = longtail_gpus();
    let lcfg = LifecycleCfg { mem_budget_mib: 4_096, ..Default::default() };
    let events = vec![
        FaultEvent { t: ms_to_us(1_500.0), gpu: 1, kind: FaultKind::Degraded },
        FaultEvent { t: ms_to_us(down_ms), gpu: 1, kind: FaultKind::Down },
        FaultEvent { t: ms_to_us(up_ms), gpu: 1, kind: FaultKind::Up },
    ];
    let opts = ExecOpts {
        obs: ObsCfg { timeseries: true, ..Default::default() },
        ..Default::default()
    };
    let run = |fcfg: &ResilienceCfg| {
        serve_longtail_stream_faults(
            &profiles,
            &rates,
            &gpus,
            PlacementPolicy::LoadBalance,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            &lcfg,
            MaterializedStream::new(reqs.clone(), profiles.len()),
            horizon_ms,
            seed,
            opts,
            Some(fcfg),
        )
    };
    let hedged = run(&ResilienceCfg { events: events.clone(), ..Default::default() });
    let naive = run(&ResilienceCfg {
        events,
        reroute: false,
        hedge: false,
        ..Default::default()
    });
    // Per-window goodput (served − SLO misses), p99 and miss count.
    let summarize = |rep: &crate::cluster::ClusterReport| {
        let obs = rep.obs.as_ref().expect("recorder was enabled");
        let p99 = obs.per_window_p99();
        (0..obs.n_windows())
            .map(|i| {
                let (mut served, mut miss) = (0u64, 0u64);
                for l in &obs.lanes {
                    if let Some(w) = l.windows.get(i) {
                        served += w.served;
                        miss += w.slo_miss;
                    }
                }
                (served.saturating_sub(miss), p99[i], miss)
            })
            .collect::<Vec<_>>()
    };
    let (h, n) = (summarize(&hedged), summarize(&naive));
    let wus = hedged.obs.as_ref().expect("recorder was enabled").cfg.window_us;
    let mut out = FigData::new(
        "fig18",
        "engine-failure timeline: goodput + p99, hedged front door vs naive (24 models, 2xV100)",
        &[
            "t0_ms",
            "goodput_hedged",
            "goodput_naive",
            "p99_hedged_ms",
            "p99_naive_ms",
            "miss_hedged",
            "miss_naive",
            "engines_down",
        ],
    );
    for i in 0..h.len().min(n.len()) {
        let t0 = i as crate::gpu::Us * wus;
        let engines_down =
            u64::from(t0 >= ms_to_us(down_ms) && t0 < ms_to_us(up_ms));
        out.push(vec![
            (t0 / 1_000).to_string(),
            h[i].0.to_string(),
            n[i].0.to_string(),
            f(h[i].1),
            f(n[i].1),
            h[i].2.to_string(),
            n[i].2.to_string(),
            engines_down.to_string(),
        ]);
    }
    out
}

/// Fig. 19 (beyond the paper): brownout under a flash crowd — a
/// 4-model mix on 2×V100 + T4 where resnet50's arrival rate spikes 5×
/// for two seconds mid-run, served three ways behind the same
/// admission front door: **brownout** (retries + breakers + degraded
/// int8 variants co-resident with their primaries), **retry-only**
/// (same knobs, variants disabled), and **shed-only** (no overload
/// layer — over-deadline arrivals are rejected outright). One row per
/// virtual-time window: goodput (served − SLO misses) and p99 for each
/// leg. `degraded_share_pct` is the brownout run's *run-level* share of
/// served requests that landed on a degraded variant (the recorder
/// aggregates windows per GPU, not per model, so the share has no
/// per-window split); `spike` marks the flash window.
pub fn fig19() -> FigData {
    use crate::cluster::{
        serve_cluster_stream_overload, ExecOpts, GpuSched, PlacementPolicy, RoutingPolicy,
    };
    use crate::faults::ResilienceCfg;
    use crate::gpu::ms_to_us;
    use crate::obs::ObsCfg;
    use crate::overload::{expand_profiles, OverloadCfg, OverloadSpec, VariantMap, VariantSpec};
    use crate::profile::GpuSpec;
    use crate::workload::{Arrivals, MergedStream};
    let horizon_ms = 8_000.0;
    let seed = 42;
    let (spike_start_ms, spike_ms) = (3_000.0, 2_000.0);
    let base: Vec<crate::profile::ModelProfile> = ["resnet50", "vgg19", "mobilenet", "alexnet"]
        .iter()
        .map(|n| crate::profile::by_name(n).expect("zoo model"))
        .collect();
    let arrivals = [
        Arrivals::Flash { base: 300.0, mult: 5.0, spike_start_ms, spike_ms },
        Arrivals::Poisson { rate: 160.0 },
        Arrivals::Poisson { rate: 400.0 },
        Arrivals::Poisson { rate: 300.0 },
    ];
    let specs: Vec<_> =
        arrivals.iter().cloned().zip(base.iter()).map(|(a, p)| (a, p.slo_ms)).collect();
    let decls = vec![
        (
            0,
            VariantSpec {
                name: "resnet50_int8".into(),
                knee_pct: 20,
                latency_scale: 0.5,
                mem_mib: 400,
            },
        ),
        (
            1,
            VariantSpec {
                name: "vgg19_int8".into(),
                knee_pct: 30,
                latency_scale: 0.55,
                mem_mib: 600,
            },
        ),
    ];
    let (expanded, map) = expand_profiles(&base, &decls).expect("valid variants");
    let gpus: Vec<GpuSpec> = ["V100", "V100", "T4"]
        .iter()
        .map(|n| GpuSpec::by_name(n).expect("known gpu").clone())
        .collect();
    let fcfg = ResilienceCfg {
        admission: true,
        hedge: false,
        bulk_models: vec!["vgg19".into()],
        ..Default::default()
    };
    let ocfg = OverloadCfg { breaker_k: 8, ..Default::default() };
    let opts = ExecOpts {
        obs: ObsCfg { timeseries: true, ..Default::default() },
        ..Default::default()
    };
    let run = |profiles: &[crate::profile::ModelProfile], ovl: Option<&OverloadSpec>| {
        let mut rates = arrivals.iter().map(|a| a.peak_rate()).collect::<Vec<_>>();
        rates.resize(profiles.len(), 0.0);
        serve_cluster_stream_overload(
            profiles,
            &rates,
            &gpus,
            PlacementPolicy::LoadBalance,
            RoutingPolicy::JoinShortestQueue,
            GpuSched::Dstack,
            MergedStream::new(&specs, horizon_ms, seed),
            horizon_ms,
            seed,
            opts,
            Some(&fcfg),
            ovl,
        )
    };
    let brownout_spec = OverloadSpec { cfg: ocfg.clone(), map };
    let retry_spec = OverloadSpec {
        cfg: OverloadCfg { brownout: false, ..ocfg },
        map: VariantMap::trivial(base.len()),
    };
    let brownout = run(&expanded, Some(&brownout_spec));
    let retry = run(&base, Some(&retry_spec));
    let shed = run(&base, None);
    let summarize = |rep: &crate::cluster::ClusterReport| {
        let obs = rep.obs.as_ref().expect("recorder was enabled");
        let p99 = obs.per_window_p99();
        (0..obs.n_windows())
            .map(|i| {
                let (mut served, mut miss) = (0u64, 0u64);
                for l in &obs.lanes {
                    if let Some(w) = l.windows.get(i) {
                        served += w.served;
                        miss += w.slo_miss;
                    }
                }
                (served.saturating_sub(miss), p99[i])
            })
            .collect::<Vec<_>>()
    };
    let (b, r, s) = (summarize(&brownout), summarize(&retry), summarize(&shed));
    let o = brownout.overload.as_ref().expect("overload layer was armed");
    let degraded = o.degraded_served_critical + o.degraded_served_bulk;
    let served_total: u64 = brownout.served.iter().sum();
    let share = 100.0 * degraded as f64 / served_total.max(1) as f64;
    let wus = brownout.obs.as_ref().expect("recorder was enabled").cfg.window_us;
    let mut out = FigData::new(
        "fig19",
        "flash-crowd overload: goodput + p99, brownout vs shed-only vs retry-only (2xV100+T4)",
        &[
            "t0_ms",
            "goodput_brownout",
            "goodput_shed",
            "goodput_retry",
            "p99_brownout_ms",
            "p99_shed_ms",
            "p99_retry_ms",
            "degraded_share_pct",
            "spike",
        ],
    );
    let rows = b.len().min(r.len()).min(s.len());
    for i in 0..rows {
        let t0 = i as crate::gpu::Us * wus;
        let spike = u64::from(
            t0 >= ms_to_us(spike_start_ms) && t0 < ms_to_us(spike_start_ms + spike_ms),
        );
        out.push(vec![
            (t0 / 1_000).to_string(),
            b[i].0.to_string(),
            s[i].0.to_string(),
            r[i].0.to_string(),
            f(b[i].1),
            f(s[i].1),
            f(r[i].1),
            f(share),
            spike.to_string(),
        ]);
    }
    out
}

/// All generators, keyed for the CLI (`--fig 2`, `--table 1`, `all`).
pub fn generate(which: &str) -> Vec<FigData> {
    match which {
        "table1" | "t1" => vec![table1()],
        "table2" | "t2" => vec![table2()],
        "table3" | "t3" => vec![table3()],
        "table6" | "t6" => vec![table6()],
        "2" => vec![fig2()],
        "3" => vec![fig3()],
        "4" => vec![fig4ab(), fig4cd()],
        "5" => vec![fig5()],
        "6" => vec![fig6()],
        "7" => vec![fig7()],
        "8" => vec![fig8()],
        "9" => vec![fig9abc(), fig9d()],
        "10" => vec![fig10()],
        "11" => vec![fig11a(), fig11b()],
        "12" => vec![fig12()],
        "13" | "adaptive" => vec![fig13()],
        "14" | "lifecycle" => vec![fig14()],
        "15" | "unified" => vec![fig15()],
        "16" | "streaming" => vec![fig_streaming()],
        "17" | "obs" | "timeline" => vec![fig17()],
        "18" | "resilience" | "failure" => vec![fig18()],
        "19" | "overload" | "brownout" => vec![fig19()],
        "tables" => vec![table1(), table2(), table3(), table6()],
        "ablation" => vec![ablation()],
        "all" => {
            let mut v = vec![
                fig2(),
                fig3(),
                fig4ab(),
                fig4cd(),
                fig5(),
                fig6(),
                fig7(),
                fig8(),
                fig9abc(),
                fig9d(),
                fig10(),
                fig11a(),
                fig11b(),
                fig12(),
                fig13(),
                fig14(),
                fig15(),
                fig_streaming(),
                fig17(),
                fig18(),
                fig19(),
            ];
            v.extend([table1(), table2(), table3(), table6()]);
            v
        }
        other => panic!("unknown figure/table id '{other}'"),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn quick_generators_produce_rows() {
        for d in [table2(), table6(), fig2(), fig3(), fig4ab(), fig4cd(), fig5(), fig6(), fig7(),
            fig8()]
        {
            assert!(!d.rows.is_empty(), "{} empty", d.name);
            assert!(!d.render().is_empty());
            // All rows have ≤ header width.
            for r in &d.rows {
                assert!(r.len() <= d.header.len() + 1, "{}: ragged row", d.name);
            }
        }
    }

    #[test]
    fn fig2_shows_knee_flattening() {
        let d = fig2();
        // Mobilenet (col 1): latency at 20% ≈ latency at 100% (flat
        // beyond knee), but latency at 10% is much higher.
        let lat = |row: usize, col: usize| d.rows[row][col].parse::<f64>().unwrap();
        let l10 = lat(0, 1);
        let l20 = lat(1, 1);
        let l100 = lat(9, 1);
        assert!(l10 > 1.3 * l20, "{l10} vs {l20}");
        assert!((l20 - l100) / l100 < 0.25);
    }

    #[test]
    fn table2_classifies_gnmt_memory_bound() {
        let d = table2();
        let gnmt = d.rows.iter().find(|r| r[0] == "gnmt").unwrap();
        assert_eq!(gnmt[5], "Memory");
        let vgg = d.rows.iter().find(|r| r[0] == "vgg19").unwrap();
        assert_eq!(vgg[5], "Compute");
    }
}

// ---------------------------------------------------------------------------
// Extensions beyond the paper: ablations + schedule visualization.
// ---------------------------------------------------------------------------

/// Ablation of D-STACK's design choices (DESIGN.md §5 "ablation benches"):
/// each row disables or varies one mechanism on the C-4 workload.
pub fn ablation() -> FigData {
    use crate::sched::dstack::{Dstack, DstackCfg};
    let mut out = FigData::new(
        "ablation",
        "D-STACK ablations on C-4 @ 1400 req/s (10 s)",
        &["variant", "thpt_req_s", "viol_frac", "util_%", "fairness"],
    );
    let names = ["mobilenet", "alexnet", "resnet50", "vgg19"];
    let profiles: Vec<ModelProfile> = names.iter().map(|n| by_name(n).unwrap()).collect();
    let entries = entries_at_optimum(&profiles);
    let slos: Vec<f64> = profiles.iter().map(|p| p.slo_ms).collect();
    let rates = slo_proportional_rates(1_400.0, &slos);
    let specs: Vec<_> = profiles
        .iter()
        .zip(&rates)
        .map(|(p, &r)| (Arrivals::Poisson { rate: r }, p.slo_ms))
        .collect();
    let reqs = merged_stream(&specs, 10_000.0, 13);

    let variants: Vec<(&str, DstackCfg)> = vec![
        ("full (default)", DstackCfg::default()),
        (
            "no opportunistic pass",
            DstackCfg { opportunistic: false, ..Default::default() },
        ),
        (
            "no GPU% degradation",
            DstackCfg { degrade_levels: vec![1.0], ..Default::default() },
        ),
        (
            "scoreboard window 1",
            DstackCfg { scoreboard_window: 1, ..Default::default() },
        ),
        (
            "urgency factor 1.0",
            DstackCfg { urgency_factor: 1.0, ..Default::default() },
        ),
        (
            "urgency factor 4.0",
            DstackCfg { urgency_factor: 4.0, ..Default::default() },
        ),
    ];
    for (label, cfg) in variants {
        let mut pol = Dstack::with_cfg(&entries, cfg);
        let mut sim =
            Sim::new(SimConfig { horizon_ms: 10_000.0, ..Default::default() }, entries.clone());
        let rep = sim.run(&mut pol, &reqs);
        out.push(vec![
            label.to_string(),
            f(rep.total_throughput()),
            format!("{:.3}", rep.violation_fraction()),
            f(rep.mean_utilization() * 100.0),
            format!("{:.3}", rep.runtime_fairness()),
        ]);
    }
    out
}

/// ASCII Gantt chart of one session window (Fig. 9a–c visualization):
/// rows are models, columns are time buckets, cell = GPU% tens digit.
pub fn render_gantt(
    gantt: &[crate::gpu::GanttEntry],
    n_models: usize,
    names: &[String],
    t0: crate::gpu::Us,
    t1: crate::gpu::Us,
    cols: usize,
) -> String {
    let mut grid = vec![vec![b' '; cols]; n_models];
    let span = (t1 - t0).max(1);
    for e in gantt.iter().filter(|e| e.end > t0 && e.start < t1) {
        let c0 = ((e.start.max(t0) - t0) as usize * cols) / span as usize;
        let c1 = (((e.end.min(t1) - t0) as usize * cols) / span as usize).max(c0 + 1);
        let ch = b'0' + ((e.pct / 10).min(9) as u8);
        for c in c0..c1.min(cols) {
            grid[e.model][c] = ch;
        }
    }
    let mut out = String::new();
    let width = names.iter().map(|n| n.len()).max().unwrap_or(8);
    for (m, row) in grid.iter().enumerate() {
        out.push_str(&format!("{:>width$} |", names[m], width = width));
        out.push_str(std::str::from_utf8(row).unwrap());
        out.push_str("|\n");
    }
    out.push_str(&format!(
        "{:>width$}  {}..{} ms (cell = GPU% / 10)\n",
        "",
        t0 / 1_000,
        t1 / 1_000,
        width = width
    ));
    out
}

/// Fig. 9a–c as ASCII Gantt charts (one session of the 3-model mix per
/// scheduler), written to `results/fig9_gantt.txt` by the CLI.
pub fn fig9_gantt_text() -> String {
    let names = ["alexnet", "resnet50", "vgg19"];
    let profiles: Vec<ModelProfile> = names.iter().map(|n| by_name(n).unwrap()).collect();
    let rates = slo_proportional_rates(1_400.0, &[25.0, 50.0, 100.0]);
    let mut out = String::new();
    for kind in [PolicyKind::Temporal, PolicyKind::SpatioTemporalOnly, PolicyKind::Dstack] {
        let entries = entries_at_optimum(&profiles);
        let specs: Vec<_> = profiles
            .iter()
            .zip(&rates)
            .map(|(p, &r)| (Arrivals::Poisson { rate: r }, p.slo_ms))
            .collect();
        let reqs = merged_stream(&specs, 1_000.0, 9);
        let mut pol = build_policy(kind, &entries);
        let mut sim = Sim::new(
            SimConfig { horizon_ms: 1_000.0, gantt: true, ..Default::default() },
            entries,
        );
        sim.run(pol.as_mut(), &reqs);
        out.push_str(&format!("== {} (session 300-500 ms) ==\n", kind.name()));
        let model_names: Vec<String> = names.iter().map(|s| s.to_string()).collect();
        out.push_str(&render_gantt(
            sim.gpu.gantt.as_ref().unwrap(),
            3,
            &model_names,
            300_000,
            500_000,
            100,
        ));
        out.push('\n');
    }
    out
}

#[cfg(test)]
mod ext_tests {
    use super::*;

    #[test]
    fn ablation_full_beats_no_opportunistic() {
        let d = ablation();
        let get = |label: &str, col: usize| -> f64 {
            d.rows.iter().find(|r| r[0] == label).unwrap()[col].parse().unwrap()
        };
        // The opportunistic pass is load-bearing: disabling it must cost
        // throughput or violations (Fig. 9b vs 9c).
        let full_thpt = get("full (default)", 1);
        let noop_thpt = get("no opportunistic pass", 1);
        let full_viol = get("full (default)", 2);
        let noop_viol = get("no opportunistic pass", 2);
        assert!(
            full_thpt > noop_thpt || full_viol < noop_viol,
            "opportunistic pass shows no benefit: thpt {full_thpt} vs {noop_thpt}, viol {full_viol} vs {noop_viol}"
        );
    }

    #[test]
    fn gantt_renderer_shapes() {
        use crate::gpu::GanttEntry;
        let g = vec![
            GanttEntry { model: 0, pct: 30, batch: 16, start: 0, end: 50_000 },
            GanttEntry { model: 1, pct: 50, batch: 16, start: 25_000, end: 100_000 },
        ];
        let txt = render_gantt(&g, 2, &["a".into(), "b".into()], 0, 100_000, 40);
        assert!(txt.contains('3') && txt.contains('5'));
        let lines: Vec<&str> = txt.lines().collect();
        assert_eq!(lines.len(), 3);
        // Model a occupies the first half only.
        let a_line = lines[0];
        assert!(a_line[..a_line.len() / 2].contains('3'));
    }

    #[test]
    fn fig9_gantt_text_renders_all_three() {
        let t = fig9_gantt_text();
        assert!(t.contains("temporal") && t.contains("spatio_temporal") && t.contains("dstack"));
    }
}
