//! Serving metrics: per-model throughput, latency distributions, SLO
//! violations (paper's definition: violating requests + unserved
//! requests, §7), GPU runtime share and utilization, plus Jain fairness.

use crate::gpu::{us_to_ms, Us};
use crate::util::json::Json;
use crate::util::stats::{jain_fairness, LogHistogram, Summary};

/// Per-model counters collected during a run.
#[derive(Debug, Clone, Default)]
pub struct ModelMetrics {
    pub name: String,
    /// Requests that completed (any latency).
    pub served: u64,
    /// Served requests that finished within their SLO.
    pub served_in_slo: u64,
    /// Requests dropped (deadline passed before service started).
    pub dropped: u64,
    /// End-to-end latencies (ms) of served requests.
    pub latencies_ms: Vec<f64>,
    /// Completion virtual times (µs), parallel to `latencies_ms` — lets
    /// the adaptive control plane split latency distributions around
    /// rebalance events. Not serialized (see [`Self::to_json`]).
    pub completions_us: Vec<Us>,
    /// Batches executed.
    pub batches: u64,
    /// Sum of batch sizes (for mean batch size).
    pub batch_items: u64,
    /// Bounded-memory latency distribution (~1% relative error). Only
    /// maintained when the exact vectors are disabled
    /// (`observability.exact_latencies = false`); then it is the source
    /// of [`Self::latency_summary`] quantiles, keeping a 10⁷-request
    /// run's memory flat. Never serialized.
    pub latency_hist: LogHistogram,
}

impl ModelMetrics {
    /// Paper §7: SLO violations = late completions + unserved requests.
    pub fn slo_violations(&self) -> u64 {
        (self.served - self.served_in_slo) + self.dropped
    }

    pub fn offered(&self) -> u64 {
        self.served + self.dropped
    }

    pub fn mean_batch(&self) -> f64 {
        if self.batches == 0 {
            0.0
        } else {
            self.batch_items as f64 / self.batches as f64
        }
    }

    pub fn latency_summary(&self) -> Summary {
        if self.latencies_ms.is_empty() && self.latency_hist.count() > 0 {
            return self.latency_hist.summary();
        }
        Summary::from_samples(&self.latencies_ms)
    }

    /// Deterministic JSON form: counters plus a latency summary (the raw
    /// latency vector is deliberately omitted — golden files stay small
    /// and reviewable).
    pub fn to_json(&self) -> Json {
        let s = self.latency_summary();
        Json::obj(vec![
            ("name", Json::from(self.name.as_str())),
            ("served", Json::from(self.served)),
            ("served_in_slo", Json::from(self.served_in_slo)),
            ("dropped", Json::from(self.dropped)),
            ("batches", Json::from(self.batches)),
            ("batch_items", Json::from(self.batch_items)),
            (
                "latency_ms",
                Json::obj(vec![
                    ("mean", Json::from(s.mean)),
                    ("p50", Json::from(s.p50)),
                    ("p99", Json::from(s.p99)),
                    ("max", Json::from(s.max)),
                ]),
            ),
        ])
    }
}

/// Full run report.
#[derive(Debug, Clone)]
pub struct RunReport {
    pub policy: String,
    pub horizon_us: Us,
    pub per_model: Vec<ModelMetrics>,
    /// Mean GPU utilization over the horizon, 0..1 (per GPU).
    pub gpu_utilization: Vec<f64>,
    /// Per-model GPU busy wall-clock ms (summed over GPUs).
    pub busy_ms: Vec<f64>,
    /// Virtual time of the last batch completion (µs) — task-completion
    /// metric for Table 1.
    pub last_completion_us: Us,
}

impl RunReport {
    pub fn horizon_s(&self) -> f64 {
        us_to_ms(self.horizon_us) / 1_000.0
    }

    /// Per-model throughput in served requests/s. A zero-length horizon
    /// offers no time to serve anything — rates are zero, not Inf/NaN.
    pub fn throughput(&self) -> Vec<f64> {
        if self.horizon_us == 0 {
            return vec![0.0; self.per_model.len()];
        }
        let s = self.horizon_s();
        self.per_model.iter().map(|m| m.served as f64 / s).collect()
    }

    pub fn total_throughput(&self) -> f64 {
        self.throughput().iter().sum()
    }

    /// Per-model SLO violations per second (zero-horizon guard as in
    /// [`Self::throughput`]).
    pub fn violations_per_sec(&self) -> Vec<f64> {
        if self.horizon_us == 0 {
            return vec![0.0; self.per_model.len()];
        }
        let s = self.horizon_s();
        self.per_model.iter().map(|m| m.slo_violations() as f64 / s).collect()
    }

    pub fn total_violations_per_sec(&self) -> f64 {
        self.violations_per_sec().iter().sum()
    }

    /// Fraction of all offered requests that violated their SLO.
    pub fn violation_fraction(&self) -> f64 {
        let offered: u64 = self.per_model.iter().map(|m| m.offered()).sum();
        if offered == 0 {
            return 0.0;
        }
        let viol: u64 = self.per_model.iter().map(|m| m.slo_violations()).sum();
        viol as f64 / offered as f64
    }

    /// Mean utilization across GPUs.
    pub fn mean_utilization(&self) -> f64 {
        if self.gpu_utilization.is_empty() {
            return 0.0;
        }
        self.gpu_utilization.iter().sum::<f64>() / self.gpu_utilization.len() as f64
    }

    /// Jain fairness over per-model GPU busy time (Fig. 10b discussion).
    pub fn runtime_fairness(&self) -> f64 {
        jain_fairness(&self.busy_ms)
    }

    /// Deterministic JSON form (golden-trace regression tests, tooling).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("policy", Json::from(self.policy.as_str())),
            ("horizon_us", Json::from(self.horizon_us)),
            ("per_model", Json::Arr(self.per_model.iter().map(|m| m.to_json()).collect())),
            ("gpu_utilization", Json::arr_f64(&self.gpu_utilization)),
            ("busy_ms", Json::arr_f64(&self.busy_ms)),
            ("last_completion_us", Json::from(self.last_completion_us)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn mm(served: u64, in_slo: u64, dropped: u64) -> ModelMetrics {
        ModelMetrics {
            name: "m".into(),
            served,
            served_in_slo: in_slo,
            dropped,
            latencies_ms: vec![10.0; served as usize],
            completions_us: vec![1_000; served as usize],
            batches: served / 4,
            batch_items: served,
            ..Default::default()
        }
    }

    #[test]
    fn violations_counts_late_and_unserved() {
        let m = mm(100, 90, 20);
        assert_eq!(m.slo_violations(), 30);
        assert_eq!(m.offered(), 120);
    }

    #[test]
    fn report_rates() {
        let r = RunReport {
            policy: "test".into(),
            horizon_us: 10_000_000, // 10 s
            per_model: vec![mm(1000, 950, 50), mm(500, 500, 0)],
            gpu_utilization: vec![0.8],
            busy_ms: vec![4_000.0, 4_000.0],
            last_completion_us: 9_999_000,
        };
        assert!((r.horizon_s() - 10.0).abs() < 1e-12);
        assert_eq!(r.throughput(), vec![100.0, 50.0]);
        assert!((r.total_throughput() - 150.0).abs() < 1e-12);
        assert_eq!(r.violations_per_sec(), vec![10.0, 0.0]);
        assert!((r.violation_fraction() - 100.0 / 1550.0).abs() < 1e-12);
        assert!((r.runtime_fairness() - 1.0).abs() < 1e-12);
        assert!((r.mean_utilization() - 0.8).abs() < 1e-12);
    }

    #[test]
    fn report_json_roundtrips_and_omits_raw_latencies() {
        let r = RunReport {
            policy: "dstack".into(),
            horizon_us: 2_000_000,
            per_model: vec![mm(100, 95, 5)],
            gpu_utilization: vec![0.7],
            busy_ms: vec![1_400.0],
            last_completion_us: 1_999_000,
        };
        let j = r.to_json();
        let parsed = Json::parse(&j.to_string_pretty()).unwrap();
        assert_eq!(parsed, j, "serialized report reparses identically");
        assert_eq!(parsed.req_str("policy").unwrap(), "dstack");
        let pm = &parsed.get("per_model").unwrap().as_arr().unwrap()[0];
        assert_eq!(pm.req_u64("served").unwrap(), 100);
        assert!(pm.get("latencies_ms").is_none(), "raw vector must not be serialized");
        assert!(pm.get("latency_ms").unwrap().get("p99").is_some());
    }

    #[test]
    fn mean_batch_size() {
        let m = mm(100, 100, 0);
        assert!((m.mean_batch() - 4.0).abs() < 1e-12);
        assert_eq!(ModelMetrics::default().mean_batch(), 0.0);
    }

    #[test]
    fn zero_horizon_rates_are_zero_not_inf() {
        // Regression: horizon_us == 0 used to divide by zero, leaking
        // Inf (and NaN for 0/0) into throughput and violations/s.
        let r = RunReport {
            policy: "test".into(),
            horizon_us: 0,
            per_model: vec![mm(10, 8, 2), mm(0, 0, 0)],
            gpu_utilization: vec![0.0],
            busy_ms: vec![0.0, 0.0],
            last_completion_us: 0,
        };
        assert_eq!(r.throughput(), vec![0.0, 0.0]);
        assert_eq!(r.violations_per_sec(), vec![0.0, 0.0]);
        assert_eq!(r.total_throughput(), 0.0);
        assert_eq!(r.total_violations_per_sec(), 0.0);
        assert!(r.throughput().iter().all(|v| v.is_finite()));
    }

    #[test]
    fn latency_summary_falls_back_to_histogram() {
        // With exact vectors disabled, quantiles come from the bounded
        // histogram instead of collapsing to zero.
        let mut m = ModelMetrics { name: "m".into(), served: 3, ..Default::default() };
        for x in [10.0, 20.0, 30.0] {
            m.latency_hist.push(x);
        }
        let s = m.latency_summary();
        assert_eq!(s.count, 3);
        assert!((s.mean - 20.0).abs() < 1e-9);
        assert!(s.p99 >= 29.0 && s.p99 <= 30.0, "p99 {}", s.p99);
        // Exact vector present → exact path wins, as before.
        m.latencies_ms = vec![1.0, 2.0, 3.0];
        assert_eq!(m.latency_summary().max, 3.0);
        // Serialized form carries the histogram-backed summary but
        // never the histogram itself.
        m.latencies_ms.clear();
        let j = m.to_json();
        assert!(j.get("latency_hist").is_none());
        assert!(j.get("latency_ms").unwrap().get("p99").is_some());
    }
}
