//! Scenario configuration: JSON files describing a serving experiment
//! (models, arrival rates, scheduler, GPU, horizon), loadable from the
//! `dstack` CLI. This is the "real config system" of the framework —
//! every experiment in docs/EXPERIMENTS.md can be expressed as a scenario.

use crate::profile::{self, GpuSpec, ModelProfile};
use crate::util::json::Json;
use std::path::Path;

/// Scheduling policy selector.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum PolicyKind {
    Dstack,
    SpatioTemporalOnly,
    Temporal,
    FixedBatch,
    Gslice,
    Triton,
    MaxThroughput,
    MaxMin,
}

impl PolicyKind {
    pub fn parse(s: &str) -> Result<PolicyKind, String> {
        Ok(match s {
            "dstack" => PolicyKind::Dstack,
            "spatio_temporal" => PolicyKind::SpatioTemporalOnly,
            "temporal" => PolicyKind::Temporal,
            "fixed_batch" | "fb" | "mps" => PolicyKind::FixedBatch,
            "gslice" => PolicyKind::Gslice,
            "triton" => PolicyKind::Triton,
            "max_throughput" => PolicyKind::MaxThroughput,
            "max_min" => PolicyKind::MaxMin,
            other => return Err(format!("unknown policy '{other}'")),
        })
    }

    pub fn all() -> &'static [PolicyKind] {
        &[
            PolicyKind::Dstack,
            PolicyKind::SpatioTemporalOnly,
            PolicyKind::Temporal,
            PolicyKind::FixedBatch,
            PolicyKind::Gslice,
            PolicyKind::Triton,
            PolicyKind::MaxThroughput,
            PolicyKind::MaxMin,
        ]
    }

    pub fn name(&self) -> &'static str {
        match self {
            PolicyKind::Dstack => "dstack",
            PolicyKind::SpatioTemporalOnly => "spatio_temporal",
            PolicyKind::Temporal => "temporal",
            PolicyKind::FixedBatch => "fixed_batch",
            PolicyKind::Gslice => "gslice",
            PolicyKind::Triton => "triton",
            PolicyKind::MaxThroughput => "max_throughput",
            PolicyKind::MaxMin => "max_min",
        }
    }
}

/// Cluster block of a scenario: heterogeneous GPU set plus placement
/// and routing policies for the knee-packing cluster engine
/// ([`crate::cluster::serve_cluster`]). Present ⇒ the scenario runs on
/// the cluster path instead of a single GPU.
#[derive(Debug, Clone)]
pub struct ClusterCfg {
    pub gpus: Vec<&'static GpuSpec>,
    pub placement: crate::cluster::PlacementPolicy,
    pub routing: crate::cluster::RoutingPolicy,
}

/// One model's workload in a scenario.
#[derive(Debug, Clone)]
pub struct ModelSpec {
    pub name: String,
    /// Mean request rate (req/s). `0` with a non-empty trace uses the trace.
    pub rate: f64,
    /// Optional piecewise-constant rate trace: (start_ms, rate).
    pub trace: Vec<(f64, f64)>,
    /// Optional SLO override (ms); default = profile SLO.
    pub slo_ms: Option<f64>,
    /// Optional explicit arrival process (an `"arrivals"` block with
    /// `"kind": "poisson"|"uniform"|"mmpp"|"diurnal"|"flash"`). Takes
    /// precedence over `rate`/`trace`/`poisson`; placement sizing uses
    /// its [`crate::workload::Arrivals::peak_rate`].
    pub arrivals: Option<crate::workload::Arrivals>,
    /// Optional degraded brownout variants (a `"variants"` array) the
    /// overload layer may serve when the primary cannot meet its
    /// deadline — see [`crate::overload::VariantSpec`]. Requires an
    /// `"overload"` block; incompatible with generated `lifecycle`
    /// fleets.
    pub variants: Vec<crate::overload::VariantSpec>,
}

/// Trace-replay block of a scenario (`"workload": {"trace": {...}}`):
/// arrivals come from a recorded request log streamed through
/// [`crate::workload::TraceStream`] instead of synthetic generators.
/// Requires a `cluster` block (replay runs on the streaming execution
/// core) and is incompatible with `lifecycle`/`unified` fleets, whose
/// model names are generated rather than declared.
#[derive(Debug, Clone)]
pub struct TraceReplay {
    /// CSV or JSON-lines request log; a relative path is resolved
    /// against the scenario file's directory by [`Scenario::from_file`].
    pub path: std::path::PathBuf,
    /// Out-of-order timestamp policy (`"reject"` default | `"sort"`).
    pub on_unsorted: crate::workload::UnsortedPolicy,
}

/// Lifecycle block of a scenario: a long-tail Zipf fleet served under
/// the memory manager (requires `cluster`). The scenario's `models`
/// list becomes the *base* zoo, cycled out to `n_models` distinct
/// fleet entries; per-model `rate`s are ignored on this path (rates
/// come from the Zipf split of `total_rps`).
#[derive(Debug, Clone)]
pub struct LifecycleScenario {
    /// Fleet size (≫ what fits resident memory, typically).
    pub n_models: usize,
    /// Zipf popularity exponent (0 = uniform).
    pub alpha: f64,
    /// Aggregate offered rate across the fleet (req/s).
    pub total_rps: f64,
    /// Memory-manager knobs — see [`crate::lifecycle::LifecycleCfg`].
    pub cfg: crate::lifecycle::LifecycleCfg,
}

/// Unified block of a scenario: the lifecycle fleet served under the
/// merged control plane (requires `cluster` AND `lifecycle`; an
/// `adaptive` block is optional and defaults). The fleet itself —
/// `n_models`, `alpha`, `total_rps`, memory knobs — comes from the
/// `lifecycle` block; this block only adds what the composition needs.
#[derive(Debug, Clone)]
pub struct UnifiedScenario {
    /// Rotate the fleet's popularity ranking at the horizon midpoint
    /// (the canonical drift + pressure stress, see
    /// [`crate::unified::drifting_longtail_workload`]); `false` serves
    /// steady Zipf rates (pressure-only regime).
    pub drift: bool,
    /// Cluster-wide evictions per control interval that force a replan
    /// without drift; `0` disables the pressure trigger.
    pub eviction_replan_threshold: u64,
}

/// A full serving scenario.
#[derive(Debug, Clone)]
pub struct Scenario {
    pub name: String,
    pub gpu: &'static GpuSpec,
    pub n_gpus: usize,
    pub policy: PolicyKind,
    pub horizon_ms: f64,
    pub seed: u64,
    pub models: Vec<ModelSpec>,
    /// Poisson (true) or uniform-jitter arrivals.
    pub poisson: bool,
    /// Engine-stepping thread budget for cluster paths (`"auto"` or an
    /// integer ≥ 1; `1` = serial). Thread count never changes results —
    /// see [`crate::cluster::exec`].
    pub parallelism: crate::cluster::Parallelism,
    /// Barrier discipline of the execution core (`"sparse"` default |
    /// `"epoch"`). Mode never changes results, only wall-clock — see
    /// [`crate::cluster::exec`]; the CLI `--exec-mode` flag overrides.
    pub exec_mode: crate::cluster::ExecMode,
    /// Optional cluster block — see [`ClusterCfg`].
    pub cluster: Option<ClusterCfg>,
    /// Optional adaptive control-plane block (requires `cluster`) —
    /// the scenario runs through [`crate::controlplane::run_adaptive`].
    pub adaptive: Option<crate::controlplane::AdaptiveCfg>,
    /// Optional lifecycle block (requires `cluster`) — the scenario
    /// runs through [`crate::lifecycle::run_lifecycle`].
    pub lifecycle: Option<LifecycleScenario>,
    /// Optional unified block (requires `cluster` + `lifecycle`) — the
    /// scenario runs through [`crate::unified::run_unified`], composing
    /// the lifecycle fleet with the (optional) `adaptive` knobs.
    pub unified: Option<UnifiedScenario>,
    /// Optional trace-replay block — see [`TraceReplay`]. Present ⇒
    /// arrivals stream from the recorded log (per-model `rate`s are
    /// still used for placement sizing).
    pub workload: Option<TraceReplay>,
    /// Optional fault-injection + front-door block (requires `cluster`)
    /// — see [`crate::faults::ResilienceCfg`] and docs/CONFIG.md. The
    /// timeline is validated at load; the report gains a `resilience`
    /// block only when this is present.
    pub faults: Option<crate::faults::ResilienceCfg>,
    /// Optional overload-control block (requires `cluster`) — retry
    /// backoff, per-engine circuit breakers, brownout variant fallback;
    /// see [`crate::overload::OverloadCfg`] and docs/CONFIG.md. The
    /// report gains an `overload` block only when this is present.
    pub overload: Option<crate::overload::OverloadCfg>,
    /// Observability knobs (the `"observability"` block — see
    /// `docs/CONFIG.md` and [`crate::obs`]). Default-off: no tracing,
    /// no time-series, exact latency vectors — report bytes unchanged.
    pub obs: crate::obs::ObsCfg,
}

/// Parse a per-model `"arrivals"` generator block.
fn parse_arrivals(aj: &Json) -> Result<crate::workload::Arrivals, String> {
    use crate::workload::Arrivals;
    let nonneg = |key: &str, v: f64| -> Result<f64, String> {
        if v.is_finite() && v >= 0.0 {
            Ok(v)
        } else {
            Err(format!("arrivals.{key} must be finite and >= 0 (got {v})"))
        }
    };
    let positive = |key: &str, v: f64| -> Result<f64, String> {
        if v.is_finite() && v > 0.0 {
            Ok(v)
        } else {
            Err(format!("arrivals.{key} must be finite and > 0 (got {v})"))
        }
    };
    Ok(match aj.req_str("kind")? {
        "poisson" => Arrivals::Poisson { rate: nonneg("rate", aj.req_f64("rate")?)? },
        "uniform" => {
            let jitter = aj.opt_f64("jitter", 0.5);
            if !(0.0..=1.0).contains(&jitter) {
                return Err(format!("arrivals.jitter must be in [0, 1] (got {jitter})"));
            }
            Arrivals::Uniform { rate: nonneg("rate", aj.req_f64("rate")?)?, jitter }
        }
        "mmpp" => Arrivals::Mmpp {
            rate_low: nonneg("rate_low", aj.req_f64("rate_low")?)?,
            rate_high: nonneg("rate_high", aj.req_f64("rate_high")?)?,
            dwell_low_ms: positive("dwell_low_ms", aj.opt_f64("dwell_low_ms", 500.0))?,
            dwell_high_ms: positive("dwell_high_ms", aj.opt_f64("dwell_high_ms", 500.0))?,
        },
        "diurnal" => Arrivals::Diurnal {
            base: nonneg("base", aj.req_f64("base")?)?,
            amplitude: {
                let a = aj.opt_f64("amplitude", 0.0);
                if !a.is_finite() {
                    return Err(format!("arrivals.amplitude must be finite (got {a})"));
                }
                a
            },
            period_ms: positive("period_ms", aj.req_f64("period_ms")?)?,
            phase: {
                let p = aj.opt_f64("phase", 0.0);
                if !p.is_finite() {
                    return Err(format!("arrivals.phase must be finite (got {p})"));
                }
                p
            },
        },
        "flash" => Arrivals::Flash {
            base: nonneg("base", aj.req_f64("base")?)?,
            mult: nonneg("mult", aj.opt_f64("mult", 1.0))?,
            spike_start_ms: nonneg("spike_start_ms", aj.req_f64("spike_start_ms")?)?,
            spike_ms: nonneg("spike_ms", aj.req_f64("spike_ms")?)?,
        },
        other => {
            return Err(format!(
                "unknown arrivals kind '{other}' (expected poisson|uniform|mmpp|diurnal|flash)"
            ))
        }
    })
}

/// Serialize an arrival process back to its `"arrivals"` block form.
fn arrivals_to_json(a: &crate::workload::Arrivals) -> Json {
    use crate::workload::Arrivals;
    match a {
        Arrivals::Poisson { rate } => Json::obj(vec![
            ("kind", Json::from("poisson")),
            ("rate", Json::from(*rate)),
        ]),
        Arrivals::Uniform { rate, jitter } => Json::obj(vec![
            ("kind", Json::from("uniform")),
            ("rate", Json::from(*rate)),
            ("jitter", Json::from(*jitter)),
        ]),
        // A `Trace` process round-trips through the model's `trace`
        // field, not an arrivals block; emitting one here keeps
        // to_json total for hand-built scenarios.
        Arrivals::Trace { segments } => Json::obj(vec![
            ("kind", Json::from("poisson")),
            ("rate", Json::from(segments.iter().map(|&(_, r)| r).fold(0.0, f64::max))),
        ]),
        Arrivals::Mmpp { rate_low, rate_high, dwell_low_ms, dwell_high_ms } => Json::obj(vec![
            ("kind", Json::from("mmpp")),
            ("rate_low", Json::from(*rate_low)),
            ("rate_high", Json::from(*rate_high)),
            ("dwell_low_ms", Json::from(*dwell_low_ms)),
            ("dwell_high_ms", Json::from(*dwell_high_ms)),
        ]),
        Arrivals::Diurnal { base, amplitude, period_ms, phase } => Json::obj(vec![
            ("kind", Json::from("diurnal")),
            ("base", Json::from(*base)),
            ("amplitude", Json::from(*amplitude)),
            ("period_ms", Json::from(*period_ms)),
            ("phase", Json::from(*phase)),
        ]),
        Arrivals::Flash { base, mult, spike_start_ms, spike_ms } => Json::obj(vec![
            ("kind", Json::from("flash")),
            ("base", Json::from(*base)),
            ("mult", Json::from(*mult)),
            ("spike_start_ms", Json::from(*spike_start_ms)),
            ("spike_ms", Json::from(*spike_ms)),
        ]),
    }
}

impl Scenario {
    /// Parse from JSON text. See `configs/` for examples.
    pub fn from_json(text: &str) -> Result<Scenario, String> {
        let j = Json::parse(text).map_err(|e| e.to_string())?;
        let gpu_name = j.opt_str("gpu", "V100");
        let gpu = GpuSpec::by_name(gpu_name).ok_or(format!("unknown gpu '{gpu_name}'"))?;
        let policy = PolicyKind::parse(j.opt_str("policy", "dstack"))?;
        let models_j = j.req("models")?.as_arr().ok_or("'models' must be an array")?;
        if models_j.is_empty() {
            return Err("scenario needs at least one model".into());
        }
        let mut models = Vec::new();
        for mj in models_j {
            let name = mj.req_str("name")?.to_string();
            if profile::by_name(&name).is_none() {
                return Err(format!("unknown model '{name}'"));
            }
            let trace = match mj.get("trace") {
                Some(Json::Arr(segs)) => {
                    let mut t = Vec::new();
                    for s in segs {
                        let arr = s.as_arr().ok_or("trace segments must be [start_ms, rate]")?;
                        if arr.len() != 2 {
                            return Err("trace segments must be [start_ms, rate]".into());
                        }
                        t.push((
                            arr[0].as_f64().ok_or("trace start must be a number")?,
                            arr[1].as_f64().ok_or("trace rate must be a number")?,
                        ));
                    }
                    t
                }
                _ => Vec::new(),
            };
            let arrivals = match mj.get("arrivals") {
                Some(aj) => Some(parse_arrivals(aj)?),
                None => None,
            };
            let variants = match mj.get("variants") {
                Some(Json::Arr(vs)) => {
                    let mut out = Vec::new();
                    for vj in vs {
                        let v = crate::overload::VariantSpec {
                            name: vj.req_str("name")?.to_string(),
                            knee_pct: vj.req_u64("knee_pct")? as u32,
                            latency_scale: vj.req_f64("latency_scale")?,
                            mem_mib: vj.req_u64("mem_mib")?,
                        };
                        v.validate().map_err(|e| format!("model '{name}': {e}"))?;
                        out.push(v);
                    }
                    out
                }
                Some(_) => return Err("'variants' must be an array".into()),
                None => Vec::new(),
            };
            models.push(ModelSpec {
                name,
                rate: mj.opt_f64("rate", 0.0),
                trace,
                slo_ms: mj.get("slo_ms").and_then(Json::as_f64),
                arrivals,
                variants,
            });
        }
        let cluster = match j.get("cluster") {
            Some(cj) => {
                let names = cj
                    .req("gpus")
                    .map_err(|e| e.to_string())?
                    .as_arr()
                    .ok_or("'cluster.gpus' must be an array of GPU names")?;
                let mut gpus = Vec::new();
                for gj in names {
                    let n = gj.as_str().ok_or("'cluster.gpus' entries must be strings")?;
                    gpus.push(GpuSpec::by_name(n).ok_or(format!("unknown gpu '{n}'"))?);
                }
                if gpus.is_empty() {
                    return Err("'cluster.gpus' needs at least one GPU".into());
                }
                Some(ClusterCfg {
                    gpus,
                    placement: crate::cluster::PlacementPolicy::parse(
                        cj.opt_str("placement", "ffd"),
                    )?,
                    routing: crate::cluster::RoutingPolicy::parse(cj.opt_str("routing", "jsq"))?,
                })
            }
            None => None,
        };
        let adaptive = match j.get("adaptive") {
            Some(aj) => {
                if cluster.is_none() {
                    return Err("'adaptive' requires a 'cluster' block".into());
                }
                let d = crate::controlplane::AdaptiveCfg::default();
                let cfg = crate::controlplane::AdaptiveCfg {
                    interval_ms: aj.opt_f64("interval_ms", d.interval_ms),
                    alpha: aj.opt_f64("alpha", d.alpha),
                    drift_threshold: aj.opt_f64("drift_threshold", d.drift_threshold),
                    rearm_threshold: aj.opt_f64("rearm_threshold", d.rearm_threshold),
                    cooldown_ticks: aj.opt_u64("cooldown_ticks", d.cooldown_ticks as u64)
                        as u32,
                    migration_cost_ms: aj.opt_f64("migration_cost_ms", d.migration_cost_ms),
                };
                cfg.validate()?;
                Some(cfg)
            }
            None => None,
        };
        let lifecycle = match j.get("lifecycle") {
            Some(lj) => {
                if cluster.is_none() {
                    return Err("'lifecycle' requires a 'cluster' block".into());
                }
                let d = crate::lifecycle::LifecycleCfg::default();
                let pinned = match lj.get("pinned") {
                    Some(Json::Arr(names)) => {
                        let mut out = Vec::new();
                        for n in names {
                            out.push(
                                n.as_str()
                                    .ok_or("'lifecycle.pinned' entries must be strings")?
                                    .to_string(),
                            );
                        }
                        out
                    }
                    _ => Vec::new(),
                };
                let cfg = crate::lifecycle::LifecycleCfg {
                    eviction: crate::lifecycle::EvictionPolicy::parse(
                        lj.opt_str("eviction", d.eviction.name()),
                    )?,
                    mem_budget_mib: lj.opt_u64("mem_budget_mib", d.mem_budget_mib),
                    headroom_mib: lj.opt_u64("headroom_mib", d.headroom_mib),
                    idle_timeout_ms: lj.opt_f64("idle_timeout_ms", d.idle_timeout_ms),
                    warm_routing: lj.opt_bool("warm_routing", d.warm_routing),
                    min_replicas: lj.opt_u64("min_replicas", d.min_replicas as u64) as usize,
                    pinned,
                    reconfig: d.reconfig,
                };
                cfg.validate()?;
                // validate() cannot see the devices; check here that the
                // headroom leaves resident memory on every cluster GPU.
                let cl = cluster.as_ref().expect("checked above");
                if let Some(g) = cl.gpus.iter().find(|g| cfg.budget_for(g) == 0) {
                    return Err(format!(
                        "lifecycle.headroom_mib leaves no resident memory on {} \
                         ({} MiB device)",
                        g.name, g.mem_mib
                    ));
                }
                let alpha = lj.opt_f64("alpha", 1.1);
                if !alpha.is_finite() || alpha < 0.0 {
                    return Err("lifecycle.alpha must be finite and >= 0".into());
                }
                let n_models = lj.opt_u64("n_models", 24) as usize;
                if n_models == 0 {
                    return Err("lifecycle.n_models must be >= 1".into());
                }
                let total_rps = lj.opt_f64("total_rps", 600.0);
                if !total_rps.is_finite() || total_rps < 0.0 {
                    return Err("lifecycle.total_rps must be finite and >= 0".into());
                }
                // Pinning refers to generated *fleet* names
                // (`mobilenet_00`, …), not base-zoo names — a typo here
                // would otherwise silently pin nothing.
                for p in &cfg.pinned {
                    let known = (0..n_models).any(|i| {
                        crate::lifecycle::fleet_name(&models[i % models.len()].name, i) == *p
                    });
                    if !known {
                        return Err(format!(
                            "lifecycle.pinned entry '{p}' names no fleet entry (expected \
                             e.g. '{}')",
                            crate::lifecycle::fleet_name(&models[0].name, 0)
                        ));
                    }
                }
                Some(LifecycleScenario { n_models, alpha, total_rps, cfg })
            }
            None => None,
        };
        let unified = match j.get("unified") {
            Some(uj) => {
                if lifecycle.is_none() {
                    return Err(
                        "'unified' requires a 'lifecycle' block (the fleet definition)".into(),
                    );
                }
                let cfg = crate::unified::UnifiedCfg::default();
                Some(UnifiedScenario {
                    drift: uj.opt_bool("drift", true),
                    eviction_replan_threshold: uj
                        .opt_u64("eviction_replan_threshold", cfg.eviction_replan_threshold),
                })
            }
            None => None,
        };
        let workload = match j.get("workload") {
            Some(wj) => {
                let tj = wj.req("trace")?;
                if cluster.is_none() {
                    return Err("'workload.trace' requires a 'cluster' block \
                                (replay runs on the streaming cluster core)"
                        .into());
                }
                if lifecycle.is_some() {
                    return Err("'workload.trace' is incompatible with a 'lifecycle' block \
                                (fleet model names are generated, a trace cannot \
                                 address them)"
                        .into());
                }
                Some(TraceReplay {
                    path: std::path::PathBuf::from(tj.req_str("path")?),
                    on_unsorted: crate::workload::UnsortedPolicy::parse(
                        tj.opt_str("on_unsorted", "reject"),
                    )?,
                })
            }
            None => None,
        };
        let horizon_ms = j.opt_f64("horizon_ms", 10_000.0);
        let faults = match j.get("faults") {
            Some(fj) => {
                let cl = match &cluster {
                    Some(c) => c,
                    None => {
                        return Err("'faults' requires a 'cluster' block \
                                    (fault injection acts on cluster engines)"
                            .into())
                    }
                };
                let d = crate::faults::ResilienceCfg::default();
                let mut events = Vec::new();
                if let Some(ev) = fj.get("events") {
                    let evs = ev.as_arr().ok_or("'faults.events' must be an array")?;
                    for ej in evs {
                        let t_ms = ej.req_f64("t_ms")?;
                        if !t_ms.is_finite() || t_ms <= 0.0 {
                            return Err(format!(
                                "faults.events t_ms must be finite and > 0 (got {t_ms})"
                            ));
                        }
                        let kind = ej.req_str("kind")?;
                        let kind = crate::faults::FaultKind::from_name(kind).ok_or(format!(
                            "unknown fault kind '{kind}' (expected \
                             engine_down|engine_up|engine_degraded)"
                        ))?;
                        events.push(crate::faults::FaultEvent {
                            t: crate::gpu::ms_to_us(t_ms).max(1),
                            gpu: ej.req_u64("gpu")? as usize,
                            kind,
                        });
                    }
                }
                let bulk_models = match fj.get("bulk_models") {
                    Some(Json::Arr(names)) => {
                        let mut out = Vec::new();
                        for n in names {
                            out.push(
                                n.as_str()
                                    .ok_or("'faults.bulk_models' entries must be strings")?
                                    .to_string(),
                            );
                        }
                        out
                    }
                    _ => Vec::new(),
                };
                let cfg = crate::faults::ResilienceCfg {
                    events,
                    mtbf_ms: fj.opt_f64("mtbf_ms", d.mtbf_ms),
                    mttr_ms: fj.opt_f64("mttr_ms", d.mttr_ms),
                    seed: fj.opt_u64("seed", d.seed),
                    bulk_models,
                    admission: fj.opt_bool("admission", d.admission),
                    reroute: fj.opt_bool("reroute", d.reroute),
                    hedge: fj.opt_bool("hedge", d.hedge),
                    hedge_check_ms: fj.opt_f64("hedge_check_ms", d.hedge_check_ms),
                    hedge_critical_ms: fj.opt_f64("hedge_critical_ms", d.hedge_critical_ms),
                    hedge_bulk_ms: fj.opt_f64("hedge_bulk_ms", d.hedge_bulk_ms),
                    degraded_penalty_items: fj
                        .opt_u64("degraded_penalty_items", d.degraded_penalty_items as u64)
                        as usize,
                };
                // Build the full timeline (scripted + generated) here so
                // a bad block fails at load, not mid-run: per-engine
                // alternation, GPU indices in range, times > 0.
                crate::faults::build_timeline(
                    &cfg,
                    cl.gpus.len(),
                    crate::gpu::ms_to_us(horizon_ms),
                )?;
                Some(cfg)
            }
            None => None,
        };
        let overload = match j.get("overload") {
            Some(oj) => {
                if cluster.is_none() {
                    return Err("'overload' requires a 'cluster' block \
                                (the overload layer fronts cluster engines)"
                        .into());
                }
                let d = crate::overload::OverloadCfg::default();
                let cfg = crate::overload::OverloadCfg {
                    max_retries: oj.opt_u64("max_retries", d.max_retries as u64) as u32,
                    backoff_base_ms: oj.opt_f64("backoff_base_ms", d.backoff_base_ms),
                    backoff_cap_ms: oj.opt_f64("backoff_cap_ms", d.backoff_cap_ms),
                    breaker_k: oj.opt_u64("breaker_k", d.breaker_k as u64) as u32,
                    breaker_window_ms: oj.opt_f64("breaker_window_ms", d.breaker_window_ms),
                    breaker_cooldown_ms: oj
                        .opt_f64("breaker_cooldown_ms", d.breaker_cooldown_ms),
                    brownout: oj.opt_bool("brownout", d.brownout),
                };
                cfg.validate()?;
                Some(cfg)
            }
            None => None,
        };
        if models.iter().any(|m| !m.variants.is_empty()) {
            if overload.is_none() {
                return Err("model 'variants' require an 'overload' block \
                            (variants are served by the brownout fallback)"
                    .into());
            }
            if lifecycle.is_some() {
                return Err("model 'variants' are incompatible with a 'lifecycle' fleet \
                            (fleet entries are generated from the base zoo; declare \
                             variants on static/adaptive cluster scenarios)"
                    .into());
            }
        }
        let parallelism = match j.get("parallelism") {
            None => crate::cluster::Parallelism::Auto,
            Some(v) => match (v.as_str(), v.as_u64()) {
                (Some(s), _) => crate::cluster::Parallelism::parse(s)?,
                (None, Some(n)) if n >= 1 => {
                    crate::cluster::Parallelism::Threads(n as usize)
                }
                _ => {
                    return Err(
                        "'parallelism' must be \"auto\" or an integer >= 1".into()
                    )
                }
            },
        };
        let exec_mode = match j.get("exec_mode") {
            None => crate::cluster::ExecMode::default(),
            Some(v) => match v.as_str() {
                Some(s) => crate::cluster::ExecMode::parse(s)?,
                None => {
                    return Err("'exec_mode' must be \"epoch\" or \"sparse\"".into())
                }
            },
        };
        let obs = match j.get("observability") {
            Some(oj) => {
                let d = crate::obs::ObsCfg::default();
                let window_ms = oj.opt_f64("window_ms", crate::gpu::us_to_ms(d.window_us));
                if !(window_ms.is_finite() && window_ms > 0.0) {
                    return Err(format!(
                        "observability.window_ms must be finite and > 0 (got {window_ms})"
                    ));
                }
                let mut o = crate::obs::ObsCfg {
                    trace: oj.opt_bool("trace", d.trace),
                    timeseries: oj.opt_bool("timeseries", d.timeseries),
                    window_us: crate::gpu::ms_to_us(window_ms).max(1),
                    sampling_seed: oj.opt_u64("seed", d.sampling_seed),
                    exact_latencies: oj.opt_bool("exact_latencies", d.exact_latencies),
                    ..d
                };
                if let Some(sj) = oj.get("sample") {
                    o.sample_request = sj.opt_u64("request", d.sample_request as u64) as u32;
                    o.sample_gpu = sj.opt_u64("gpu", d.sample_gpu as u64) as u32;
                    o.sample_control = sj.opt_u64("control", d.sample_control as u64) as u32;
                }
                o.validate()?;
                o
            }
            None => crate::obs::ObsCfg::default(),
        };
        let sc = Scenario {
            name: j.opt_str("name", "scenario").to_string(),
            gpu,
            n_gpus: j.opt_u64("n_gpus", 1) as usize,
            policy,
            horizon_ms,
            seed: j.opt_u64("seed", 42),
            models,
            poisson: j.opt_bool("poisson", true),
            parallelism,
            exec_mode,
            cluster,
            adaptive,
            lifecycle,
            unified,
            workload,
            faults,
            overload,
            obs,
        };
        // Expansion validates variant-name uniqueness against the model
        // list — run it here so a bad block fails at load, not mid-run.
        sc.overload_expanded()?;
        Ok(sc)
    }

    pub fn from_file(path: &Path) -> Result<Scenario, String> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        let mut sc = Scenario::from_json(&text)?;
        // A relative trace path means "next to the scenario file", so
        // shipped configs work from any working directory.
        if let Some(w) = &mut sc.workload {
            if w.path.is_relative() {
                if let Some(dir) = path.parent() {
                    w.path = dir.join(&w.path);
                }
            }
        }
        Ok(sc)
    }

    /// Serialize back to JSON (round-trip support for tooling).
    pub fn to_json(&self) -> Json {
        let models: Vec<Json> = self
            .models
            .iter()
            .map(|m| {
                let mut pairs = vec![
                    ("name", Json::from(m.name.as_str())),
                    ("rate", Json::from(m.rate)),
                ];
                if !m.trace.is_empty() {
                    pairs.push((
                        "trace",
                        Json::Arr(
                            m.trace
                                .iter()
                                .map(|(s, r)| Json::Arr(vec![Json::Num(*s), Json::Num(*r)]))
                                .collect(),
                        ),
                    ));
                }
                if let Some(slo) = m.slo_ms {
                    pairs.push(("slo_ms", Json::from(slo)));
                }
                if !m.variants.is_empty() {
                    pairs.push((
                        "variants",
                        Json::Arr(
                            m.variants
                                .iter()
                                .map(|v| {
                                    Json::obj(vec![
                                        ("name", Json::from(v.name.as_str())),
                                        ("knee_pct", Json::from(v.knee_pct as u64)),
                                        ("latency_scale", Json::from(v.latency_scale)),
                                        ("mem_mib", Json::from(v.mem_mib)),
                                    ])
                                })
                                .collect(),
                        ),
                    ));
                }
                if let Some(a) = &m.arrivals {
                    pairs.push(("arrivals", arrivals_to_json(a)));
                }
                Json::obj(pairs)
            })
            .collect();
        let mut pairs = vec![
            ("name", Json::from(self.name.as_str())),
            ("gpu", Json::from(self.gpu.name)),
            ("n_gpus", Json::from(self.n_gpus as u64)),
            ("policy", Json::from(self.policy.name())),
            ("horizon_ms", Json::from(self.horizon_ms)),
            ("seed", Json::from(self.seed)),
            ("poisson", Json::from(self.poisson)),
            ("parallelism", Json::from(self.parallelism.label().as_str())),
            ("exec_mode", Json::from(self.exec_mode.label())),
            ("models", Json::Arr(models)),
        ];
        if let Some(c) = &self.cluster {
            pairs.push((
                "cluster",
                Json::obj(vec![
                    (
                        "gpus",
                        Json::Arr(c.gpus.iter().map(|g| Json::from(g.name)).collect()),
                    ),
                    ("placement", Json::from(c.placement.name())),
                    ("routing", Json::from(c.routing.name())),
                ]),
            ));
        }
        if let Some(a) = &self.adaptive {
            pairs.push((
                "adaptive",
                Json::obj(vec![
                    ("interval_ms", Json::from(a.interval_ms)),
                    ("alpha", Json::from(a.alpha)),
                    ("drift_threshold", Json::from(a.drift_threshold)),
                    ("rearm_threshold", Json::from(a.rearm_threshold)),
                    ("cooldown_ticks", Json::from(a.cooldown_ticks)),
                    ("migration_cost_ms", Json::from(a.migration_cost_ms)),
                ]),
            ));
        }
        if let Some(l) = &self.lifecycle {
            pairs.push((
                "lifecycle",
                Json::obj(vec![
                    ("n_models", Json::from(l.n_models)),
                    ("alpha", Json::from(l.alpha)),
                    ("total_rps", Json::from(l.total_rps)),
                    ("eviction", Json::from(l.cfg.eviction.name())),
                    ("mem_budget_mib", Json::from(l.cfg.mem_budget_mib)),
                    ("headroom_mib", Json::from(l.cfg.headroom_mib)),
                    ("idle_timeout_ms", Json::from(l.cfg.idle_timeout_ms)),
                    ("warm_routing", Json::from(l.cfg.warm_routing)),
                    ("min_replicas", Json::from(l.cfg.min_replicas)),
                    (
                        "pinned",
                        Json::Arr(l.cfg.pinned.iter().map(|n| Json::from(n.as_str())).collect()),
                    ),
                ]),
            ));
        }
        if let Some(u) = &self.unified {
            pairs.push((
                "unified",
                Json::obj(vec![
                    ("drift", Json::from(u.drift)),
                    (
                        "eviction_replan_threshold",
                        Json::from(u.eviction_replan_threshold),
                    ),
                ]),
            ));
        }
        if let Some(w) = &self.workload {
            pairs.push((
                "workload",
                Json::obj(vec![(
                    "trace",
                    Json::obj(vec![
                        ("path", Json::from(w.path.display().to_string().as_str())),
                        ("on_unsorted", Json::from(w.on_unsorted.label())),
                    ]),
                )]),
            ));
        }
        if let Some(f) = &self.faults {
            pairs.push((
                "faults",
                Json::obj(vec![
                    (
                        "events",
                        Json::Arr(
                            f.events
                                .iter()
                                .map(|e| {
                                    Json::obj(vec![
                                        ("t_ms", Json::from(crate::gpu::us_to_ms(e.t))),
                                        ("gpu", Json::from(e.gpu as u64)),
                                        ("kind", Json::from(e.kind.name())),
                                    ])
                                })
                                .collect(),
                        ),
                    ),
                    ("mtbf_ms", Json::from(f.mtbf_ms)),
                    ("mttr_ms", Json::from(f.mttr_ms)),
                    ("seed", Json::from(f.seed)),
                    (
                        "bulk_models",
                        Json::Arr(f.bulk_models.iter().map(|n| Json::from(n.as_str())).collect()),
                    ),
                    ("admission", Json::from(f.admission)),
                    ("reroute", Json::from(f.reroute)),
                    ("hedge", Json::from(f.hedge)),
                    ("hedge_check_ms", Json::from(f.hedge_check_ms)),
                    ("hedge_critical_ms", Json::from(f.hedge_critical_ms)),
                    ("hedge_bulk_ms", Json::from(f.hedge_bulk_ms)),
                    ("degraded_penalty_items", Json::from(f.degraded_penalty_items as u64)),
                ]),
            ));
        }
        if let Some(o) = &self.overload {
            pairs.push((
                "overload",
                Json::obj(vec![
                    ("max_retries", Json::from(o.max_retries as u64)),
                    ("backoff_base_ms", Json::from(o.backoff_base_ms)),
                    ("backoff_cap_ms", Json::from(o.backoff_cap_ms)),
                    ("breaker_k", Json::from(o.breaker_k as u64)),
                    ("breaker_window_ms", Json::from(o.breaker_window_ms)),
                    ("breaker_cooldown_ms", Json::from(o.breaker_cooldown_ms)),
                    ("brownout", Json::from(o.brownout)),
                ]),
            ));
        }
        if self.obs != crate::obs::ObsCfg::default() {
            pairs.push((
                "observability",
                Json::obj(vec![
                    ("trace", Json::from(self.obs.trace)),
                    ("timeseries", Json::from(self.obs.timeseries)),
                    ("window_ms", Json::from(crate::gpu::us_to_ms(self.obs.window_us))),
                    (
                        "sample",
                        Json::obj(vec![
                            ("request", Json::from(self.obs.sample_request as u64)),
                            ("gpu", Json::from(self.obs.sample_gpu as u64)),
                            ("control", Json::from(self.obs.sample_control as u64)),
                        ]),
                    ),
                    ("seed", Json::from(self.obs.sampling_seed)),
                    ("exact_latencies", Json::from(self.obs.exact_latencies)),
                ]),
            ));
        }
        Json::obj(pairs)
    }

    /// Resolve model profiles (with SLO overrides applied).
    pub fn profiles(&self) -> Vec<ModelProfile> {
        self.models
            .iter()
            .map(|m| {
                let mut p = profile::by_name(&m.name).expect("validated at parse");
                if let Some(slo) = m.slo_ms {
                    p.slo_ms = slo;
                }
                p
            })
            .collect()
    }

    /// Build the arrival processes for each model. An explicit
    /// `arrivals` generator block wins over `trace`/`rate`/`poisson`.
    pub fn arrivals(&self) -> Vec<crate::workload::Arrivals> {
        use crate::workload::Arrivals;
        self.models
            .iter()
            .map(|m| {
                if let Some(a) = &m.arrivals {
                    a.clone()
                } else if !m.trace.is_empty() {
                    Arrivals::trace(m.trace.clone())
                } else if self.poisson {
                    Arrivals::Poisson { rate: m.rate }
                } else {
                    Arrivals::Uniform { rate: m.rate, jitter: 0.5 }
                }
            })
            .collect()
    }

    /// Offered rate per model (req/s) for placement sizing: the peak
    /// rate of the model's arrival process — the flat rate, the peak
    /// segment rate of a trace, or the peak of a generator block
    /// (place for the peak). Trace replay has no generator to ask, so
    /// the declared per-model `rate`s size the placement there.
    pub fn offered_rates(&self) -> Vec<f64> {
        if self.workload.is_some() {
            return self.models.iter().map(|m| m.rate).collect();
        }
        self.arrivals().iter().map(|a| a.peak_rate()).collect()
    }

    /// Offered rate per model at t = 0 — what the adaptive control plane
    /// solves the *initial* placement for (the static cluster path uses
    /// [`Self::offered_rates`], i.e. the peak, instead). For a trace
    /// this is the rate of the segment covering t = 0 (0 when the trace
    /// starts later), resolved through
    /// [`crate::workload::Arrivals::rate_at`].
    pub fn initial_rates(&self) -> Vec<f64> {
        self.arrivals().iter().map(|a| a.rate_at(0.0)).collect()
    }

    /// The overload layer's expanded inputs for the declared-model
    /// cluster paths (static/adaptive/trace): the profile list with
    /// brownout variants appended after the primaries, and the
    /// [`crate::overload::OverloadSpec`] binding the knobs to the
    /// variant map. `Ok(None)` without an `"overload"` block; errors on
    /// an invalid variant set (duplicate names, unknown primaries).
    /// With `brownout: false` the variant declarations are inert — the
    /// map stays trivial and no variant profiles are added. Lifecycle/
    /// unified scenario paths build their own trivial map over the
    /// generated fleet instead (variants are rejected there at parse).
    pub fn overload_expanded(
        &self,
    ) -> Result<Option<(Vec<ModelProfile>, crate::overload::OverloadSpec)>, String> {
        let Some(cfg) = &self.overload else { return Ok(None) };
        let base = self.profiles();
        let decls: Vec<(usize, crate::overload::VariantSpec)> = if cfg.brownout {
            self.models
                .iter()
                .enumerate()
                .flat_map(|(i, m)| m.variants.iter().cloned().map(move |v| (i, v)))
                .collect()
        } else {
            Vec::new()
        };
        let (profiles, map) = crate::overload::expand_profiles(&base, &decls)?;
        Ok(Some((profiles, crate::overload::OverloadSpec { cfg: cfg.clone(), map })))
    }

    /// Execution-core options for the cluster path: the scenario's
    /// thread budget + barrier mode in the form the drivers take.
    pub fn exec_opts(&self) -> crate::cluster::ExecOpts {
        crate::cluster::ExecOpts { threads: self.parallelism, mode: self.exec_mode, obs: self.obs }
    }

    /// Per-GPU scheduler for the cluster path, derived from the
    /// scenario's policy (cluster engines run one scheduler per GPU).
    pub fn gpu_sched(&self) -> crate::cluster::GpuSched {
        use crate::cluster::GpuSched;
        match self.policy {
            PolicyKind::Temporal => GpuSched::Temporal,
            PolicyKind::Triton | PolicyKind::FixedBatch => GpuSched::Triton,
            PolicyKind::Gslice => GpuSched::Gslice,
            _ => GpuSched::Dstack,
        }
    }
}

/// Instantiate the scenario's policy over model entries.
pub fn build_policy(
    kind: PolicyKind,
    entries: &[crate::sim::ModelEntry],
) -> Box<dyn crate::sim::Policy> {
    use crate::sched::*;
    match kind {
        PolicyKind::Dstack => Box::new(dstack::Dstack::from_entries(entries)),
        PolicyKind::SpatioTemporalOnly => Box::new(dstack::Dstack::with_cfg(
            entries,
            dstack::DstackCfg { opportunistic: false, ..Default::default() },
        )),
        PolicyKind::Temporal => Box::new(temporal::Temporal::from_entries(entries)),
        PolicyKind::FixedBatch => Box::new(fixed_batch::FixedBatch::new()),
        PolicyKind::Gslice => Box::new(gslice::Gslice::from_entries(entries)),
        PolicyKind::Triton => Box::new(triton::Triton::from_entries(entries)),
        PolicyKind::MaxThroughput => Box::new(max_throughput::MaxThroughput::from_entries(entries)),
        PolicyKind::MaxMin => Box::new(max_min::MaxMin::from_entries(entries)),
    }
}

/// Run a single-GPU scenario end to end and return the report.
pub fn run_scenario(sc: &Scenario) -> crate::metrics::RunReport {
    use crate::sim::{Sim, SimConfig};
    use crate::workload::merged_stream;
    let profiles = sc.profiles();
    let entries = crate::cluster::entries_for_gpu(&profiles, sc.gpu);
    let arrivals = sc.arrivals();
    let specs: Vec<_> = arrivals
        .into_iter()
        .zip(profiles.iter())
        .map(|(a, p)| (a, p.slo_ms))
        .collect();
    let reqs = merged_stream(&specs, sc.horizon_ms, sc.seed);
    let mut policy = build_policy(sc.policy, &entries);
    let cfg = SimConfig {
        gpu: sc.gpu.clone(),
        horizon_ms: sc.horizon_ms,
        allow_oversub: sc.policy == PolicyKind::FixedBatch,
        ..Default::default()
    };
    let mut sim = Sim::new(cfg, entries);
    sim.run(policy.as_mut(), &reqs)
}

/// Run a scenario's cluster block end to end: knee-packed placement over
/// the configured GPU set, load-aware routing, one engine per GPU.
/// Panics if the scenario has no `cluster` block — callers branch on
/// [`Scenario::cluster`].
pub fn run_cluster_scenario(sc: &Scenario) -> crate::cluster::ClusterReport {
    use crate::workload::MergedStream;
    if sc.workload.is_some() {
        return run_trace_scenario(sc).expect("trace replay failed");
    }
    let cl = sc.cluster.as_ref().expect("scenario has no cluster block");
    // Variants (if any) append to the profile list with zero planned
    // rate — brownout serves them on co-located spare capacity, the
    // placement never sizes for them. Arrivals only target primaries.
    let (profiles, mut rates, ovl) = match sc.overload_expanded().expect("validated at parse") {
        Some((profiles, spec)) => (profiles, sc.offered_rates(), Some(spec)),
        None => (sc.profiles(), sc.offered_rates(), None),
    };
    rates.resize(profiles.len(), 0.0);
    let arrivals = sc.arrivals();
    let specs: Vec<_> = arrivals
        .into_iter()
        .zip(profiles.iter())
        .map(|(a, p)| (a, p.slo_ms))
        .collect();
    // Arrivals flow lazily: generators → k-way merge → execution core,
    // never materialized (byte-identical to the collected path).
    let stream = MergedStream::new(&specs, sc.horizon_ms, sc.seed);
    let gpus: Vec<GpuSpec> = cl.gpus.iter().map(|g| (*g).clone()).collect();
    crate::cluster::serve_cluster_stream_overload(
        &profiles,
        &rates,
        &gpus,
        cl.placement,
        cl.routing,
        sc.gpu_sched(),
        stream,
        sc.horizon_ms,
        sc.seed,
        sc.exec_opts(),
        sc.faults.as_ref(),
        ovl.as_ref(),
    )
}

/// The [`crate::workload::TraceSpec`] a scenario's models induce: the
/// trace's `model` column resolves against the declared model names
/// (SLO overrides applied). Panics without a `workload` block.
pub fn trace_spec(sc: &Scenario) -> crate::workload::TraceSpec {
    let w = sc.workload.as_ref().expect("scenario has no workload.trace block");
    crate::workload::TraceSpec {
        models: sc.profiles().iter().map(|p| (p.name.clone(), p.slo_ms)).collect(),
        horizon_ms: sc.horizon_ms,
        policy: w.on_unsorted,
    }
}

/// Run a scenario's trace-replay workload: the recorded log streams
/// through [`crate::workload::TraceStream`] into the cluster engine
/// (static placement, or the adaptive control plane when an
/// `adaptive` block is present). Errors on unreadable/malformed/
/// out-of-order traces instead of panicking — trace files are user
/// input that only exists at run time.
pub fn run_trace_scenario(sc: &Scenario) -> Result<crate::cluster::ClusterReport, String> {
    let cl = sc.cluster.as_ref().expect("scenario has no cluster block");
    let w = sc.workload.as_ref().expect("scenario has no workload.trace block");
    // The trace addresses declared (primary) names; brownout variants
    // append after them so recorded indices are unchanged.
    let (profiles, ovl) = match sc.overload_expanded().expect("validated at parse") {
        Some((profiles, spec)) => (profiles, Some(spec)),
        None => (sc.profiles(), None),
    };
    let spec = trace_spec(sc);
    let stream = crate::workload::TraceStream::open(&w.path, &spec)?;
    let gpus: Vec<GpuSpec> = cl.gpus.iter().map(|g| (*g).clone()).collect();
    Ok(if sc.adaptive.is_some() {
        let adaptive = sc.adaptive.clone().unwrap_or_default();
        let mut rates = sc.initial_rates();
        rates.resize(profiles.len(), 0.0);
        crate::controlplane::run_adaptive_stream_overload(
            &profiles,
            &rates,
            &gpus,
            cl.placement,
            cl.routing,
            sc.gpu_sched(),
            &adaptive,
            stream,
            sc.horizon_ms,
            sc.seed,
            sc.exec_opts(),
            sc.faults.as_ref(),
            ovl.as_ref(),
        )
    } else {
        let mut rates = sc.offered_rates();
        rates.resize(profiles.len(), 0.0);
        crate::cluster::serve_cluster_stream_overload(
            &profiles,
            &rates,
            &gpus,
            cl.placement,
            cl.routing,
            sc.gpu_sched(),
            stream,
            sc.horizon_ms,
            sc.seed,
            sc.exec_opts(),
            sc.faults.as_ref(),
            ovl.as_ref(),
        )
    })
}

/// Run a scenario's cluster block through the adaptive control plane:
/// initial placement for the t = 0 rates, then periodic re-optimization
/// and rebalancing as rates drift. Panics without `cluster`; uses the
/// default [`crate::controlplane::AdaptiveCfg`] when the scenario has no
/// `adaptive` block.
pub fn run_adaptive_scenario(sc: &Scenario) -> crate::cluster::ClusterReport {
    use crate::workload::MergedStream;
    if sc.workload.is_some() {
        return run_trace_scenario(sc).expect("trace replay failed");
    }
    let cl = sc.cluster.as_ref().expect("scenario has no cluster block");
    let adaptive = sc.adaptive.clone().unwrap_or_default();
    let (profiles, mut initial, ovl) = match sc.overload_expanded().expect("validated at parse") {
        Some((profiles, spec)) => (profiles, sc.initial_rates(), Some(spec)),
        None => (sc.profiles(), sc.initial_rates(), None),
    };
    initial.resize(profiles.len(), 0.0);
    let arrivals = sc.arrivals();
    let specs: Vec<_> = arrivals
        .into_iter()
        .zip(profiles.iter())
        .map(|(a, p)| (a, p.slo_ms))
        .collect();
    let stream = MergedStream::new(&specs, sc.horizon_ms, sc.seed);
    let gpus: Vec<GpuSpec> = cl.gpus.iter().map(|g| (*g).clone()).collect();
    crate::controlplane::run_adaptive_stream_overload(
        &profiles,
        &initial,
        &gpus,
        cl.placement,
        cl.routing,
        sc.gpu_sched(),
        &adaptive,
        stream,
        sc.horizon_ms,
        sc.seed,
        sc.exec_opts(),
        sc.faults.as_ref(),
        ovl.as_ref(),
    )
}

/// Run a scenario's lifecycle block: build the long-tail Zipf fleet by
/// cycling the scenario's `models` as base profiles, assign it with
/// [`crate::cluster::plan_residency`] against the configured memory
/// budgets, and serve it through the memory manager. Panics without
/// `cluster`/`lifecycle` blocks — callers branch on the options.
pub fn run_lifecycle_scenario(sc: &Scenario) -> crate::cluster::ClusterReport {
    let cl = sc.cluster.as_ref().expect("scenario has no cluster block");
    let lc = sc.lifecycle.as_ref().expect("scenario has no lifecycle block");
    let base = sc.profiles();
    let (profiles, rates, reqs) = crate::lifecycle::longtail_workload_from(
        &base,
        lc.n_models,
        lc.alpha,
        lc.total_rps,
        sc.horizon_ms,
        sc.seed,
    );
    let gpus: Vec<GpuSpec> = cl.gpus.iter().map(|g| (*g).clone()).collect();
    let stream = crate::workload::MaterializedStream::new(reqs, profiles.len());
    // Variants are rejected on lifecycle scenarios at parse; the
    // overload knobs (retry/breaker) still apply over a trivial map.
    let ovl = sc.overload.as_ref().map(|cfg| crate::overload::OverloadSpec {
        cfg: cfg.clone(),
        map: crate::overload::VariantMap::trivial(profiles.len()),
    });
    crate::lifecycle::serve_longtail_stream_overload(
        &profiles,
        &rates,
        &gpus,
        cl.placement,
        cl.routing,
        sc.gpu_sched(),
        &lc.cfg,
        stream,
        sc.horizon_ms,
        sc.seed,
        sc.exec_opts(),
        sc.faults.as_ref(),
        ovl.as_ref(),
    )
}

/// Run a scenario's unified block: the lifecycle fleet (drifting or
/// steady per `unified.drift`) served under the merged cold-start-aware
/// control plane — residency-priced replans on drift or eviction
/// pressure. Panics without `cluster`/`lifecycle`/`unified` blocks;
/// the `adaptive` block is optional (defaults apply).
pub fn run_unified_scenario(sc: &Scenario) -> crate::cluster::ClusterReport {
    let cl = sc.cluster.as_ref().expect("scenario has no cluster block");
    let lc = sc.lifecycle.as_ref().expect("scenario has no lifecycle block");
    let un = sc.unified.as_ref().expect("scenario has no unified block");
    let ucfg = crate::unified::UnifiedCfg {
        adaptive: sc.adaptive.clone().unwrap_or_default(),
        lifecycle: lc.cfg.clone(),
        eviction_replan_threshold: un.eviction_replan_threshold,
    };
    let base = sc.profiles();
    let (profiles, rates, reqs) = if un.drift {
        crate::unified::drifting_longtail_workload_from(
            &base,
            lc.n_models,
            lc.alpha,
            lc.total_rps,
            sc.horizon_ms,
            sc.seed,
        )
    } else {
        crate::lifecycle::longtail_workload_from(
            &base,
            lc.n_models,
            lc.alpha,
            lc.total_rps,
            sc.horizon_ms,
            sc.seed,
        )
    };
    let gpus: Vec<GpuSpec> = cl.gpus.iter().map(|g| (*g).clone()).collect();
    let stream = crate::workload::MaterializedStream::new(reqs, profiles.len());
    // As on the lifecycle path: trivial variant map over the generated
    // fleet, retry/breaker knobs still apply.
    let ovl = sc.overload.as_ref().map(|cfg| crate::overload::OverloadSpec {
        cfg: cfg.clone(),
        map: crate::overload::VariantMap::trivial(profiles.len()),
    });
    crate::unified::run_unified_stream_overload(
        &profiles,
        &rates,
        &gpus,
        cl.placement,
        cl.routing,
        sc.gpu_sched(),
        &ucfg,
        stream,
        sc.horizon_ms,
        sc.seed,
        sc.exec_opts(),
        sc.faults.as_ref(),
        ovl.as_ref(),
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    const EXAMPLE: &str = r#"{
        "name": "c4",
        "gpu": "V100",
        "policy": "dstack",
        "horizon_ms": 1000,
        "seed": 7,
        "models": [
            {"name": "mobilenet", "rate": 700},
            {"name": "alexnet", "rate": 700},
            {"name": "resnet50", "rate": 320},
            {"name": "vgg19", "rate": 160, "slo_ms": 120}
        ]
    }"#;

    #[test]
    fn parses_example() {
        let sc = Scenario::from_json(EXAMPLE).unwrap();
        assert_eq!(sc.name, "c4");
        assert_eq!(sc.models.len(), 4);
        assert_eq!(sc.policy, PolicyKind::Dstack);
        assert_eq!(sc.models[3].slo_ms, Some(120.0));
        let profiles = sc.profiles();
        assert_eq!(profiles[3].slo_ms, 120.0, "SLO override applied");
    }

    #[test]
    fn rejects_bad_configs() {
        assert!(Scenario::from_json("{}").is_err());
        assert!(Scenario::from_json(r#"{"models": []}"#).is_err());
        assert!(Scenario::from_json(r#"{"models": [{"name": "nope", "rate": 1}]}"#).is_err());
        assert!(
            Scenario::from_json(r#"{"policy": "magic", "models": [{"name": "alexnet"}]}"#)
                .is_err()
        );
    }

    #[test]
    fn roundtrips_via_json() {
        let sc = Scenario::from_json(EXAMPLE).unwrap();
        let text = sc.to_json().to_string_pretty();
        let sc2 = Scenario::from_json(&text).unwrap();
        assert_eq!(sc2.models.len(), sc.models.len());
        assert_eq!(sc2.policy, sc.policy);
        assert_eq!(sc2.seed, sc.seed);
    }

    #[test]
    fn runs_scenario_end_to_end() {
        let mut sc = Scenario::from_json(EXAMPLE).unwrap();
        sc.horizon_ms = 500.0;
        let rep = run_scenario(&sc);
        assert_eq!(rep.per_model.len(), 4);
        assert!(rep.total_throughput() > 0.0);
    }

    const CLUSTER_EXAMPLE: &str = r#"{
        "name": "hetero",
        "policy": "dstack",
        "horizon_ms": 600,
        "seed": 3,
        "cluster": {"gpus": ["V100", "T4"], "placement": "ffd", "routing": "jsq"},
        "models": [
            {"name": "mobilenet", "rate": 150},
            {"name": "resnet50", "rate": 500}
        ]
    }"#;

    #[test]
    fn cluster_block_parses_and_runs() {
        let sc = Scenario::from_json(CLUSTER_EXAMPLE).unwrap();
        let cl = sc.cluster.as_ref().expect("cluster block parsed");
        assert_eq!(cl.gpus.len(), 2);
        assert_eq!(cl.gpus[0].name, "V100");
        assert_eq!(cl.placement, crate::cluster::PlacementPolicy::FirstFitDecreasing);
        assert_eq!(cl.routing, crate::cluster::RoutingPolicy::JoinShortestQueue);
        let rep = run_cluster_scenario(&sc);
        assert_eq!(rep.throughput.len(), 2);
        assert!(rep.total_throughput() > 0.0);
        assert_eq!(rep.gpu_utilization.len(), 2);
    }

    #[test]
    fn cluster_block_roundtrips_and_validates() {
        let sc = Scenario::from_json(CLUSTER_EXAMPLE).unwrap();
        let text = sc.to_json().to_string_pretty();
        let sc2 = Scenario::from_json(&text).unwrap();
        let (a, b) = (sc.cluster.unwrap(), sc2.cluster.unwrap());
        assert_eq!(a.gpus.len(), b.gpus.len());
        assert_eq!(a.placement, b.placement);
        assert_eq!(a.routing, b.routing);
        // Bad cluster blocks are rejected with a useful error.
        for bad in [
            r#"{"cluster": {"gpus": []}, "models": [{"name": "alexnet", "rate": 1}]}"#,
            r#"{"cluster": {"gpus": ["H100"]}, "models": [{"name": "alexnet", "rate": 1}]}"#,
            r#"{"cluster": {"gpus": ["T4"], "routing": "magic"}, "models": [{"name": "alexnet", "rate": 1}]}"#,
        ] {
            assert!(Scenario::from_json(bad).is_err(), "{bad}");
        }
    }

    const ADAPTIVE_EXAMPLE: &str = r#"{
        "name": "adaptive_mini",
        "policy": "dstack",
        "horizon_ms": 1000,
        "seed": 5,
        "cluster": {"gpus": ["V100", "V100"], "placement": "ffd", "routing": "jsq"},
        "adaptive": {"interval_ms": 250, "alpha": 0.4, "drift_threshold": 0.3,
                     "rearm_threshold": 0.1, "cooldown_ticks": 1, "migration_cost_ms": 20},
        "models": [
            {"name": "resnet50", "rate": 0, "trace": [[0, 500], [500, 100]]},
            {"name": "alexnet", "rate": 200}
        ]
    }"#;

    #[test]
    fn adaptive_block_parses_roundtrips_and_runs() {
        let sc = Scenario::from_json(ADAPTIVE_EXAMPLE).unwrap();
        let a = sc.adaptive.as_ref().expect("adaptive block parsed");
        assert_eq!(a.interval_ms, 250.0);
        assert_eq!(a.cooldown_ticks, 1);
        let text = sc.to_json().to_string_pretty();
        let sc2 = Scenario::from_json(&text).unwrap();
        let b = sc2.adaptive.as_ref().unwrap();
        assert_eq!(a.alpha, b.alpha);
        assert_eq!(a.migration_cost_ms, b.migration_cost_ms);
        let rep = run_adaptive_scenario(&sc);
        assert!(rep.adaptive.is_some(), "adaptive stats attached");
        assert!(rep.total_throughput() > 0.0);
    }

    #[test]
    fn adaptive_requires_cluster_and_valid_fields() {
        let no_cluster = r#"{"adaptive": {}, "models": [{"name": "alexnet", "rate": 1}]}"#;
        assert!(Scenario::from_json(no_cluster).is_err());
        let bad_alpha = r#"{
            "cluster": {"gpus": ["V100"]},
            "adaptive": {"alpha": 2.0},
            "models": [{"name": "alexnet", "rate": 1}]
        }"#;
        assert!(Scenario::from_json(bad_alpha).is_err());
        let bad_band = r#"{
            "cluster": {"gpus": ["V100"]},
            "adaptive": {"drift_threshold": 0.2, "rearm_threshold": 0.4},
            "models": [{"name": "alexnet", "rate": 1}]
        }"#;
        assert!(Scenario::from_json(bad_band).is_err());
    }

    const LIFECYCLE_EXAMPLE: &str = r#"{
        "name": "longtail_mini",
        "policy": "dstack",
        "horizon_ms": 800,
        "seed": 9,
        "cluster": {"gpus": ["V100", "V100"], "placement": "lb", "routing": "jsq"},
        "lifecycle": {"n_models": 8, "alpha": 1.1, "total_rps": 250,
                      "eviction": "lru", "mem_budget_mib": 3072,
                      "idle_timeout_ms": 1000, "warm_routing": true,
                      "min_replicas": 2, "pinned": ["mobilenet_00"]},
        "models": [
            {"name": "mobilenet"},
            {"name": "alexnet"},
            {"name": "resnet50"}
        ]
    }"#;

    #[test]
    fn lifecycle_block_parses_roundtrips_and_runs() {
        let sc = Scenario::from_json(LIFECYCLE_EXAMPLE).unwrap();
        let l = sc.lifecycle.as_ref().expect("lifecycle block parsed");
        assert_eq!(l.n_models, 8);
        assert_eq!(l.cfg.mem_budget_mib, 3072);
        assert_eq!(l.cfg.eviction, crate::lifecycle::EvictionPolicy::Lru);
        assert_eq!(l.cfg.pinned, vec!["mobilenet_00".to_string()]);
        let text = sc.to_json().to_string_pretty();
        let sc2 = Scenario::from_json(&text).unwrap();
        let l2 = sc2.lifecycle.as_ref().unwrap();
        assert_eq!(l.n_models, l2.n_models);
        assert_eq!(l.alpha, l2.alpha);
        assert_eq!(l.total_rps, l2.total_rps);
        assert_eq!(l.cfg.warm_routing, l2.cfg.warm_routing);
        assert_eq!(l.cfg.min_replicas, l2.cfg.min_replicas);
        assert_eq!(l.cfg.pinned, l2.cfg.pinned);
        let rep = run_lifecycle_scenario(&sc);
        assert!(rep.lifecycle.is_some(), "lifecycle stats attached");
        assert_eq!(rep.throughput.len(), 8, "fleet size, not base-list size");
        assert!(rep.total_throughput() > 0.0);
    }

    #[test]
    fn lifecycle_requires_cluster_and_valid_fields() {
        let no_cluster = r#"{"lifecycle": {}, "models": [{"name": "alexnet", "rate": 1}]}"#;
        assert!(Scenario::from_json(no_cluster).is_err());
        for bad in [
            r#"{"cluster": {"gpus": ["V100"]}, "lifecycle": {"eviction": "magic"},
                "models": [{"name": "alexnet"}]}"#,
            r#"{"cluster": {"gpus": ["V100"]}, "lifecycle": {"n_models": 0},
                "models": [{"name": "alexnet"}]}"#,
            r#"{"cluster": {"gpus": ["V100"]}, "lifecycle": {"alpha": -1},
                "models": [{"name": "alexnet"}]}"#,
            r#"{"cluster": {"gpus": ["V100"]}, "lifecycle": {"alpha": 1e999},
                "models": [{"name": "alexnet"}]}"#,
            r#"{"cluster": {"gpus": ["V100"]}, "lifecycle": {"total_rps": 1e999},
                "models": [{"name": "alexnet"}]}"#,
            r#"{"cluster": {"gpus": ["V100"]}, "lifecycle": {"min_replicas": 0},
                "models": [{"name": "alexnet"}]}"#,
            r#"{"cluster": {"gpus": ["V100"]}, "lifecycle": {"headroom_mib": 20000},
                "models": [{"name": "alexnet"}]}"#,
            r#"{"cluster": {"gpus": ["V100"]}, "lifecycle": {"pinned": ["mobilenet"]},
                "models": [{"name": "alexnet"}]}"#,
        ] {
            assert!(Scenario::from_json(bad).is_err(), "{bad}");
        }
    }

    const UNIFIED_EXAMPLE: &str = r#"{
        "name": "unified_mini",
        "policy": "dstack",
        "horizon_ms": 900,
        "seed": 5,
        "cluster": {"gpus": ["V100", "V100"], "placement": "lb", "routing": "jsq"},
        "adaptive": {"interval_ms": 250},
        "lifecycle": {"n_models": 8, "alpha": 1.1, "total_rps": 250,
                      "mem_budget_mib": 3072, "min_replicas": 1},
        "unified": {"drift": true, "eviction_replan_threshold": 4},
        "models": [
            {"name": "mobilenet"},
            {"name": "alexnet"},
            {"name": "resnet50"}
        ]
    }"#;

    #[test]
    fn unified_block_parses_roundtrips_and_runs() {
        let sc = Scenario::from_json(UNIFIED_EXAMPLE).unwrap();
        let u = sc.unified.as_ref().expect("unified block parsed");
        assert!(u.drift);
        assert_eq!(u.eviction_replan_threshold, 4);
        let text = sc.to_json().to_string_pretty();
        let sc2 = Scenario::from_json(&text).unwrap();
        let u2 = sc2.unified.as_ref().unwrap();
        assert_eq!(u.drift, u2.drift);
        assert_eq!(u.eviction_replan_threshold, u2.eviction_replan_threshold);
        let rep = run_unified_scenario(&sc);
        assert!(rep.adaptive.is_some(), "control-plane stats attached");
        assert!(rep.lifecycle.is_some(), "memory-manager stats attached");
        assert!(
            rep.adaptive.as_ref().unwrap().cold_migration_ms.is_some(),
            "unified path prices migrations"
        );
        assert_eq!(rep.throughput.len(), 8);
        assert!(rep.total_throughput() > 0.0);
    }

    #[test]
    fn unified_requires_lifecycle_and_defaults_apply() {
        // No lifecycle block → the fleet is undefined → reject.
        let no_lifecycle = r#"{
            "cluster": {"gpus": ["V100"]}, "unified": {},
            "models": [{"name": "alexnet", "rate": 1}]}"#;
        assert!(Scenario::from_json(no_lifecycle).is_err());
        // Empty unified block inherits defaults (drift on, threshold 8).
        let minimal = r#"{
            "cluster": {"gpus": ["V100"]},
            "lifecycle": {"n_models": 4, "total_rps": 50},
            "unified": {},
            "models": [{"name": "alexnet"}]}"#;
        let sc = Scenario::from_json(minimal).unwrap();
        let u = sc.unified.as_ref().unwrap();
        assert!(u.drift);
        assert_eq!(
            u.eviction_replan_threshold,
            crate::unified::UnifiedCfg::default().eviction_replan_threshold
        );
    }

    #[test]
    fn parallelism_parses_validates_and_roundtrips() {
        use crate::cluster::Parallelism;
        // Default is auto.
        let sc = Scenario::from_json(EXAMPLE).unwrap();
        assert_eq!(sc.parallelism, Parallelism::Auto);
        // Accepted spellings: "auto", a JSON integer, a numeric string.
        let with = |v: &str| {
            Scenario::from_json(&format!(
                r#"{{"parallelism": {v}, "models": [{{"name": "alexnet", "rate": 1}}]}}"#
            ))
        };
        assert_eq!(with("\"auto\"").unwrap().parallelism, Parallelism::Auto);
        assert_eq!(with("4").unwrap().parallelism, Parallelism::Threads(4));
        assert_eq!(with("\"2\"").unwrap().parallelism, Parallelism::Threads(2));
        assert_eq!(with("1").unwrap().parallelism, Parallelism::Threads(1));
        // Rejected: zero, negatives, fractions, junk.
        for bad in ["0", "-1", "2.5", "\"fast\"", "true"] {
            assert!(with(bad).is_err(), "{bad}");
        }
        // Round-trips through to_json.
        let mut sc = Scenario::from_json(CLUSTER_EXAMPLE).unwrap();
        sc.parallelism = Parallelism::Threads(3);
        let sc2 = Scenario::from_json(&sc.to_json().to_string_pretty()).unwrap();
        assert_eq!(sc2.parallelism, Parallelism::Threads(3));
        sc.parallelism = Parallelism::Auto;
        let sc3 = Scenario::from_json(&sc.to_json().to_string_pretty()).unwrap();
        assert_eq!(sc3.parallelism, Parallelism::Auto);
    }

    #[test]
    fn exec_mode_parses_validates_and_roundtrips() {
        use crate::cluster::{ExecMode, Parallelism};
        // Default is sparse.
        let sc = Scenario::from_json(EXAMPLE).unwrap();
        assert_eq!(sc.exec_mode, ExecMode::Sparse);
        let with = |v: &str| {
            Scenario::from_json(&format!(
                r#"{{"exec_mode": {v}, "models": [{{"name": "alexnet", "rate": 1}}]}}"#
            ))
        };
        assert_eq!(with("\"epoch\"").unwrap().exec_mode, ExecMode::Epoch);
        assert_eq!(with("\"sparse\"").unwrap().exec_mode, ExecMode::Sparse);
        for bad in ["\"fast\"", "1", "true"] {
            assert!(with(bad).is_err(), "{bad}");
        }
        // Round-trips through to_json, and exec_opts carries both knobs.
        let mut sc = Scenario::from_json(CLUSTER_EXAMPLE).unwrap();
        sc.exec_mode = ExecMode::Epoch;
        sc.parallelism = Parallelism::Threads(2);
        let sc2 = Scenario::from_json(&sc.to_json().to_string_pretty()).unwrap();
        assert_eq!(sc2.exec_mode, ExecMode::Epoch);
        let opts = sc2.exec_opts();
        assert_eq!(opts.mode, ExecMode::Epoch);
        assert_eq!(opts.threads, Parallelism::Threads(2));
    }

    #[test]
    fn observability_block_parses_validates_and_roundtrips() {
        // Absent block ⇒ defaults (off, exact vectors) and no block in
        // the serialized form — goldens stay byte-stable.
        let sc = Scenario::from_json(EXAMPLE).unwrap();
        assert_eq!(sc.obs, crate::obs::ObsCfg::default());
        assert!(!sc.to_json().to_string_pretty().contains("observability"));
        let with = |block: &str| {
            Scenario::from_json(&format!(
                r#"{{"observability": {block}, "models": [{{"name": "alexnet", "rate": 1}}]}}"#
            ))
        };
        let sc = with(
            r#"{"trace": true, "timeseries": true, "window_ms": 250,
                "sample": {"request": 8, "gpu": 2}, "seed": 9,
                "exact_latencies": false}"#,
        )
        .unwrap();
        assert!(sc.obs.trace && sc.obs.timeseries);
        assert_eq!(sc.obs.window_us, 250_000);
        assert_eq!(sc.obs.sample_request, 8);
        assert_eq!(sc.obs.sample_gpu, 2);
        assert_eq!(sc.obs.sample_control, 1);
        assert_eq!(sc.obs.sampling_seed, 9);
        assert!(!sc.obs.exact_latencies);
        assert_eq!(sc.exec_opts().obs, sc.obs);
        // Round-trips through to_json.
        let sc2 = Scenario::from_json(&sc.to_json().to_string_pretty()).unwrap();
        assert_eq!(sc2.obs, sc.obs);
        // Invalid knobs are rejected with a field-naming message.
        assert!(with(r#"{"window_ms": 0}"#).is_err());
        assert!(with(r#"{"sample": {"request": 0}}"#).is_err());
    }

    #[test]
    fn initial_rates_use_t0_segment() {
        let sc = Scenario::from_json(
            r#"{"models": [
                {"name": "alexnet", "rate": 0, "trace": [[500, 900], [0, 100], [1000, 300]]},
                {"name": "mobilenet", "rate": 250},
                {"name": "vgg19", "rate": 0, "trace": [[200, 80]]}
            ]}"#,
        )
        .unwrap();
        // Unsorted trace: the segment covering t=0 wins; a trace that
        // starts later offers 0 at t=0.
        assert_eq!(sc.initial_rates(), vec![100.0, 250.0, 0.0]);
        assert_eq!(sc.offered_rates(), vec![900.0, 250.0, 80.0]);
    }

    #[test]
    fn offered_rates_use_trace_peak() {
        let sc = Scenario::from_json(
            r#"{"models": [
                {"name": "alexnet", "rate": 0, "trace": [[0, 100], [500, 900], [1000, 300]]},
                {"name": "mobilenet", "rate": 250}
            ]}"#,
        )
        .unwrap();
        assert_eq!(sc.offered_rates(), vec![900.0, 250.0]);
    }

    #[test]
    fn arrivals_blocks_parse_validate_and_roundtrip() {
        use crate::workload::Arrivals;
        let sc = Scenario::from_json(
            r#"{"horizon_ms": 1000, "models": [
                {"name": "mobilenet", "rate": 100, "arrivals":
                    {"kind": "mmpp", "rate_low": 50, "rate_high": 200,
                     "dwell_low_ms": 400, "dwell_high_ms": 200}},
                {"name": "alexnet", "arrivals":
                    {"kind": "diurnal", "base": 100, "amplitude": 80, "period_ms": 500}},
                {"name": "resnet50", "arrivals":
                    {"kind": "flash", "base": 50, "mult": 6,
                     "spike_start_ms": 400, "spike_ms": 100}}
            ]}"#,
        )
        .unwrap();
        let arr = sc.arrivals();
        assert!(matches!(arr[0], Arrivals::Mmpp { rate_low: 50.0, rate_high: 200.0, .. }));
        assert!(matches!(arr[1], Arrivals::Diurnal { base: 100.0, .. }));
        assert!(matches!(arr[2], Arrivals::Flash { mult: 6.0, .. }));
        // Placement sizes for the generator peaks, not the `rate` field.
        assert_eq!(sc.offered_rates(), vec![200.0, 180.0, 300.0]);
        // t = 0 rates: MMPP reports its stationary mean.
        let init = sc.initial_rates();
        assert!((init[0] - 100.0).abs() < 1e-9, "{init:?}");
        // Round-trips through to_json.
        let sc2 = Scenario::from_json(&sc.to_json().to_string_pretty()).unwrap();
        assert_eq!(sc2.offered_rates(), sc.offered_rates());
        assert!(matches!(sc2.arrivals()[0], Arrivals::Mmpp { .. }));
        // Bad generator blocks are rejected with an error, not a panic.
        let with = |block: &str| {
            Scenario::from_json(&format!(
                r#"{{"models": [{{"name": "alexnet", "arrivals": {block}}}]}}"#
            ))
        };
        for bad in [
            r#"{"kind": "magic"}"#,
            r#"{"kind": "poisson"}"#,
            r#"{"kind": "poisson", "rate": -1}"#,
            r#"{"kind": "mmpp", "rate_low": 1, "rate_high": 2, "dwell_low_ms": 0}"#,
            r#"{"kind": "diurnal", "base": 10, "period_ms": 0}"#,
            r#"{"kind": "uniform", "rate": 10, "jitter": 1.5}"#,
            r#"{"kind": "flash", "base": 10, "mult": 2}"#,
        ] {
            assert!(with(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn workload_trace_block_parses_and_validates() {
        let good = r#"{
            "cluster": {"gpus": ["V100"]},
            "workload": {"trace": {"path": "t.csv", "on_unsorted": "sort"}},
            "models": [{"name": "alexnet", "rate": 100}]}"#;
        let sc = Scenario::from_json(good).unwrap();
        let w = sc.workload.as_ref().expect("workload block parsed");
        assert_eq!(w.path, std::path::PathBuf::from("t.csv"));
        assert_eq!(w.on_unsorted, crate::workload::UnsortedPolicy::Sort);
        // Round-trips (default policy too).
        let sc2 = Scenario::from_json(&sc.to_json().to_string_pretty()).unwrap();
        assert_eq!(sc2.workload.as_ref().unwrap().on_unsorted, w.on_unsorted);
        // Trace replay sizes placement from the declared rates.
        assert_eq!(sc.offered_rates(), vec![100.0]);
        for bad in [
            // No cluster block.
            r#"{"workload": {"trace": {"path": "t.csv"}},
                "models": [{"name": "alexnet", "rate": 1}]}"#,
            // Lifecycle fleets have generated names — incompatible.
            r#"{"cluster": {"gpus": ["V100"]},
                "lifecycle": {"n_models": 4, "total_rps": 50},
                "workload": {"trace": {"path": "t.csv"}},
                "models": [{"name": "alexnet"}]}"#,
            // Unknown policy / missing path.
            r#"{"cluster": {"gpus": ["V100"]},
                "workload": {"trace": {"path": "t.csv", "on_unsorted": "magic"}},
                "models": [{"name": "alexnet", "rate": 1}]}"#,
            r#"{"cluster": {"gpus": ["V100"]},
                "workload": {"trace": {}},
                "models": [{"name": "alexnet", "rate": 1}]}"#,
        ] {
            assert!(Scenario::from_json(bad).is_err(), "{bad}");
        }
    }

    #[test]
    fn trace_replay_runs_end_to_end() {
        // from_file resolves the trace next to the scenario file.
        let dir = std::env::temp_dir().join("dstack_cfg_trace_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("t.csv"),
            "timestamp_ms,model,count\n0,mobilenet,2\n5,resnet50,1\n",
        )
        .unwrap();
        let cfg = r#"{
            "name": "replay",
            "horizon_ms": 600,
            "cluster": {"gpus": ["V100"]},
            "workload": {"trace": {"path": "t.csv"}},
            "models": [
                {"name": "mobilenet", "rate": 150},
                {"name": "resnet50", "rate": 100}
            ]}"#;
        std::fs::write(dir.join("sc.json"), cfg).unwrap();
        let sc = Scenario::from_file(&dir.join("sc.json")).unwrap();
        assert_eq!(sc.workload.as_ref().unwrap().path, dir.join("t.csv"));
        let rep = run_trace_scenario(&sc).unwrap();
        assert_eq!(rep.served.iter().sum::<u64>(), 3, "all trace requests served");
        // run_cluster_scenario takes the same path when a workload
        // block is present.
        let rep2 = run_cluster_scenario(&sc);
        assert_eq!(rep.to_json().to_string_compact(), rep2.to_json().to_string_compact());
        // A missing trace file is an Err, not a panic.
        let mut missing = sc.clone();
        missing.workload.as_mut().unwrap().path = dir.join("nope.csv");
        assert!(run_trace_scenario(&missing).is_err());
    }

    const FAULTS_EXAMPLE: &str = r#"{
        "name": "failure_mini",
        "policy": "dstack",
        "horizon_ms": 800,
        "seed": 11,
        "cluster": {"gpus": ["V100", "V100"], "placement": "ffd", "routing": "jsq"},
        "faults": {
            "events": [
                {"t_ms": 200, "gpu": 1, "kind": "engine_degraded"},
                {"t_ms": 300, "gpu": 1, "kind": "engine_down"},
                {"t_ms": 500, "gpu": 1, "kind": "engine_up"}
            ],
            "bulk_models": ["resnet50"],
            "admission": true,
            "hedge_critical_ms": 10
        },
        "models": [
            {"name": "mobilenet", "rate": 150},
            {"name": "resnet50", "rate": 120}
        ]
    }"#;

    #[test]
    fn faults_block_parses_roundtrips_and_runs() {
        use crate::faults::FaultKind;
        let sc = Scenario::from_json(FAULTS_EXAMPLE).unwrap();
        let f = sc.faults.as_ref().expect("faults block parsed");
        assert_eq!(f.events.len(), 3);
        assert_eq!(f.events[0].t, 200_000, "t_ms converts to µs");
        assert_eq!(f.events[1].kind, FaultKind::Down);
        assert!(f.admission);
        assert_eq!(f.bulk_models, vec!["resnet50".to_string()]);
        assert_eq!(f.hedge_critical_ms, 10.0);
        let text = sc.to_json().to_string_pretty();
        let sc2 = Scenario::from_json(&text).unwrap();
        assert_eq!(sc2.faults.as_ref().unwrap(), f, "faults block round-trips");
        let rep = run_cluster_scenario(&sc);
        let r = rep.resilience.as_ref().expect("resilience stats attached");
        assert_eq!(r.fault_events, 3);
        assert_eq!(r.engine_downs, 1);
        assert!(rep.total_throughput() > 0.0);
        assert!(
            rep.to_json().to_string_compact().contains("\"resilience\""),
            "fault runs serialize the resilience block"
        );
        // No faults block ⇒ no resilience field, no serialized block.
        let plain = Scenario::from_json(CLUSTER_EXAMPLE).unwrap();
        assert!(plain.faults.is_none());
        assert!(!plain.to_json().to_string_pretty().contains("faults"));
        let rep = run_cluster_scenario(&plain);
        assert!(rep.resilience.is_none());
        assert!(!rep.to_json().to_string_compact().contains("\"resilience\""));
    }

    #[test]
    fn faults_block_requires_cluster_and_valid_timeline() {
        for bad in [
            // No cluster block.
            r#"{"faults": {}, "models": [{"name": "alexnet", "rate": 1}]}"#,
            // GPU index out of range for the declared cluster.
            r#"{"cluster": {"gpus": ["V100"]},
                "faults": {"events": [{"t_ms": 100, "gpu": 3, "kind": "down"}]},
                "models": [{"name": "alexnet", "rate": 1}]}"#,
            // Up without a preceding down/degraded.
            r#"{"cluster": {"gpus": ["V100"]},
                "faults": {"events": [{"t_ms": 100, "gpu": 0, "kind": "engine_up"}]},
                "models": [{"name": "alexnet", "rate": 1}]}"#,
            // Unknown kind / non-positive time / bad knobs.
            r#"{"cluster": {"gpus": ["V100"]},
                "faults": {"events": [{"t_ms": 100, "gpu": 0, "kind": "explode"}]},
                "models": [{"name": "alexnet", "rate": 1}]}"#,
            r#"{"cluster": {"gpus": ["V100"]},
                "faults": {"events": [{"t_ms": 0, "gpu": 0, "kind": "down"}]},
                "models": [{"name": "alexnet", "rate": 1}]}"#,
            r#"{"cluster": {"gpus": ["V100"]},
                "faults": {"mtbf_ms": 100, "mttr_ms": 0},
                "models": [{"name": "alexnet", "rate": 1}]}"#,
            r#"{"cluster": {"gpus": ["V100"]},
                "faults": {"hedge_check_ms": 0},
                "models": [{"name": "alexnet", "rate": 1}]}"#,
        ] {
            assert!(Scenario::from_json(bad).is_err(), "{bad}");
        }
        // Faults compose with every cluster-family block.
        let lc = r#"{
            "cluster": {"gpus": ["V100", "V100"]},
            "lifecycle": {"n_models": 6, "total_rps": 120, "mem_budget_mib": 3072},
            "faults": {"events": [{"t_ms": 200, "gpu": 1, "kind": "down"},
                                   {"t_ms": 400, "gpu": 1, "kind": "up"}]},
            "horizon_ms": 700,
            "models": [{"name": "mobilenet"}, {"name": "alexnet"}]}"#;
        let sc = Scenario::from_json(lc).unwrap();
        let rep = run_lifecycle_scenario(&sc);
        assert!(rep.lifecycle.is_some());
        assert!(rep.resilience.is_some(), "lifecycle path attaches resilience stats");
        assert!(rep.resilience.as_ref().unwrap().engine_downs == 1);
    }

    #[test]
    fn all_policies_instantiable_and_runnable() {
        for kind in PolicyKind::all() {
            let mut sc = Scenario::from_json(EXAMPLE).unwrap();
            sc.policy = *kind;
            sc.horizon_ms = 300.0;
            let rep = run_scenario(&sc);
            assert_eq!(rep.per_model.len(), 4, "{kind:?}");
        }
    }
}
