//! Simulated GPU device: SM-partitioned spatial multiplexing with MPS
//! semantics (§2, §3.2).
//!
//! The simulator tracks, in virtual time, which model instances occupy
//! which fraction of the GPU (CUDA-MPS `ACTIVE_THREAD_PERCENTAGE`-style
//! caps with SM isolation), the utilization integral, an optional Gantt
//! log (Fig. 9), and the §3.2 dynamic-reconfiguration mechanics:
//! changing a model's GPU% spins up a standby process whose load is
//! masked by the active instance (parameter sharing via cudaIPC cuts the
//! transient memory copy by ~40%), leaving only a ~100 µs idle gap.

use crate::profile::GpuSpec;

/// Virtual time in microseconds.
pub type Us = u64;

pub const US_PER_MS: f64 = 1_000.0;

pub fn ms_to_us(ms: f64) -> Us {
    (ms * US_PER_MS).round().max(0.0) as Us
}

pub fn us_to_ms(us: Us) -> f64 {
    us as f64 / US_PER_MS
}

/// Reconfiguration cost model (§3.2 / paper contribution ii).
#[derive(Debug, Clone)]
pub struct ReconfigModel {
    /// GPU idle gap when a standby takes over (paper: < 100 µs).
    pub takeover_gap_us: Us,
    /// Fraction of weight memory the standby re-loads when parameter
    /// sharing (cudaIPC) is enabled (paper: sharing saves up to 40%).
    pub shared_load_fraction: f64,
    /// Whether parameter sharing is enabled.
    pub param_sharing: bool,
}

impl Default for ReconfigModel {
    fn default() -> Self {
        ReconfigModel { takeover_gap_us: 100, shared_load_fraction: 0.6, param_sharing: true }
    }
}

impl ReconfigModel {
    /// Effective cold-start load (ms) for a model joining a device that
    /// already hosts `n_resident` other models: with parameter sharing
    /// (cudaIPC, §3.2) the standby process re-reads only
    /// `shared_load_fraction` of the weights. Shared by
    /// [`GpuSim::configure`] and the lifecycle memory manager
    /// ([`crate::lifecycle`]) so both charge cold starts identically.
    pub fn cold_load_ms(&self, load_ms: f64, n_resident: usize) -> f64 {
        if self.param_sharing && n_resident > 0 {
            load_ms * self.shared_load_fraction
        } else {
            load_ms
        }
    }
}

/// One resident instance of a model on the simulated GPU.
#[derive(Debug, Clone)]
pub struct Resident {
    pub model: usize,
    /// GPU% this instance was started with (immutable per process —
    /// CUDA MPS fixes the thread percentage at process start).
    pub pct: u32,
    /// Weight memory held, MiB.
    pub mem_mib: u64,
}

/// A batch currently executing.
#[derive(Debug, Clone)]
pub struct Running {
    pub id: u64,
    pub model: usize,
    pub batch: u32,
    pub pct: u32,
    /// SMs the model can actually exploit (min(pct, knee at this
    /// batch)); utilization integrates this, capacity books `pct`.
    /// §6.1: "We compute GPU utilization by using Knee% for each model."
    pub useful_pct: u32,
    pub start: Us,
    pub end: Us,
}

/// Gantt entry for schedule visualizations (Fig. 9a–c).
#[derive(Debug, Clone, PartialEq)]
pub struct GanttEntry {
    pub model: usize,
    pub pct: u32,
    pub batch: u32,
    pub start: Us,
    pub end: Us,
}

/// The simulated device.
#[derive(Debug)]
pub struct GpuSim {
    pub spec: GpuSpec,
    pub reconfig: ReconfigModel,
    running: Vec<Running>,
    residents: Vec<Resident>,
    next_id: u64,
    /// If true, aggregate GPU% may exceed 100 (uncontrolled default MPS,
    /// used by the Fixed-Batch baseline). Controlled policies keep it
    /// false so oversubscription panics (an invariant violation).
    pub allow_oversub: bool,
    // Utilization accounting: ∫ pct dt, advanced lazily.
    last_advance: Us,
    util_integral_pct_us: f64,
    /// Per-model busy integral (pct·µs) for runtime-share metrics.
    busy_pct_us: Vec<f64>,
    /// Per-model wall-clock busy time (µs, counted at any pct).
    busy_us: Vec<Us>,
    /// Idle time injected by reconfiguration gaps (µs).
    pub reconfig_idle_us: Us,
    pub gantt: Option<Vec<GanttEntry>>,
}

impl GpuSim {
    pub fn new(spec: GpuSpec, n_models: usize, gantt: bool) -> GpuSim {
        GpuSim {
            spec,
            reconfig: ReconfigModel::default(),
            running: Vec::new(),
            residents: Vec::new(),
            next_id: 0,
            allow_oversub: false,
            last_advance: 0,
            util_integral_pct_us: 0.0,
            busy_pct_us: vec![0.0; n_models],
            busy_us: vec![0; n_models],
            reconfig_idle_us: 0,
            gantt: if gantt { Some(Vec::new()) } else { None },
        }
    }

    /// Grow the per-model accounting vectors to `n` models (runtime
    /// model activation on a live cluster engine — new slots start with
    /// zero busy time). Shrinking is not supported: indices are stable.
    pub fn grow_models(&mut self, n: usize) {
        if self.busy_pct_us.len() < n {
            self.busy_pct_us.resize(n, 0.0);
            self.busy_us.resize(n, 0);
        }
    }

    /// Aggregate GPU% currently booked.
    pub fn used_pct(&self) -> u32 {
        self.running.iter().map(|r| r.pct).sum()
    }

    pub fn free_pct(&self) -> u32 {
        100u32.saturating_sub(self.used_pct())
    }

    pub fn running(&self) -> &[Running] {
        &self.running
    }

    pub fn n_running(&self) -> usize {
        self.running.len()
    }

    pub fn n_running_of(&self, model: usize) -> usize {
        self.running.iter().filter(|r| r.model == model).count()
    }

    /// Advance the utilization integral to `now`.
    fn advance(&mut self, now: Us) {
        debug_assert!(now >= self.last_advance, "time went backwards");
        let dt = (now - self.last_advance) as f64;
        if dt > 0.0 {
            let useful: u32 = self.running.iter().map(|r| r.useful_pct).sum();
            self.util_integral_pct_us += useful.min(100) as f64 * dt;
            for r in &self.running {
                self.busy_pct_us[r.model] += r.useful_pct as f64 * dt;
            }
            self.last_advance = now;
        }
    }

    /// Start a batch occupying `pct`% for `[now, now+dur_us)`, of which
    /// `useful_pct` is productive (see [`Running::useful_pct`]).
    /// Returns the instance id whose completion the caller must schedule.
    pub fn launch_useful(
        &mut self,
        now: Us,
        model: usize,
        batch: u32,
        pct: u32,
        useful_pct: u32,
        dur_us: Us,
    ) -> u64 {
        self.advance(now);
        assert!(pct >= 1 && pct <= 100, "pct out of range: {pct}");
        if !self.allow_oversub {
            assert!(
                self.used_pct() + pct <= 100,
                "GPU oversubscribed: {} + {pct} > 100 (model {model})",
                self.used_pct()
            );
        }
        let id = self.next_id;
        self.next_id += 1;
        let end = now + dur_us;
        let useful_pct = useful_pct.min(pct);
        self.running.push(Running { id, model, batch, pct, useful_pct, start: now, end });
        self.busy_us[model] += dur_us;
        if let Some(g) = self.gantt.as_mut() {
            g.push(GanttEntry { model, pct, batch, start: now, end });
        }
        id
    }

    /// [`Self::launch_useful`] with the whole allocation productive.
    pub fn launch(&mut self, now: Us, model: usize, batch: u32, pct: u32, dur_us: Us) -> u64 {
        self.launch_useful(now, model, batch, pct, pct, dur_us)
    }

    /// Complete (remove) a running instance.
    pub fn complete(&mut self, now: Us, id: u64) -> Running {
        self.advance(now);
        let idx = self
            .running
            .iter()
            .position(|r| r.id == id)
            .unwrap_or_else(|| panic!("completing unknown instance {id}"));
        self.running.swap_remove(idx)
    }

    /// §3.2 — make a model resident at a GPU%, or change its allocation.
    ///
    /// Returns the virtual time when the (re)configured instance is ready
    /// to serve. With an existing resident the standby load is fully
    /// masked (the old instance keeps serving) and only the takeover gap
    /// is charged as idle; a cold start pays the full (or shared) load.
    pub fn configure(&mut self, now: Us, model: usize, pct: u32, load_ms: f64, mem_mib: u64) -> Us {
        self.advance(now);
        let existing = self.residents.iter().position(|r| r.model == model);
        match existing {
            Some(i) => {
                if self.residents[i].pct == pct {
                    return now; // already configured
                }
                // Overlapped active-standby reload: masked load, tiny gap.
                self.residents[i].pct = pct;
                self.reconfig_idle_us += self.reconfig.takeover_gap_us;
                now + self.reconfig.takeover_gap_us
            }
            None => {
                let eff_ms = self.reconfig.cold_load_ms(load_ms, self.residents.len());
                self.residents.push(Resident { model, pct, mem_mib });
                now + ms_to_us(eff_ms)
            }
        }
    }

    pub fn resident_pct(&self, model: usize) -> Option<u32> {
        self.residents.iter().find(|r| r.model == model).map(|r| r.pct)
    }

    /// Total resident weight memory (MiB) — oversubscription of device
    /// memory is a hard failure, as on the real device.
    pub fn resident_mem_mib(&self) -> u64 {
        self.residents.iter().map(|r| r.mem_mib).sum()
    }

    /// Mean GPU utilization in `[0, horizon_us]` as a fraction of 0..1.
    pub fn utilization(&mut self, horizon_us: Us) -> f64 {
        self.advance(horizon_us);
        if horizon_us == 0 {
            return 0.0;
        }
        self.util_integral_pct_us / (100.0 * horizon_us as f64)
    }

    /// Per-model GPU wall-clock busy time in ms (Fig. 10b).
    pub fn busy_ms(&self) -> Vec<f64> {
        self.busy_us.iter().map(|&us| us_to_ms(us)).collect()
    }

    /// Per-model share of the pct·time integral.
    pub fn busy_share(&self) -> Vec<f64> {
        let total: f64 = self.busy_pct_us.iter().sum();
        if total == 0.0 {
            return vec![0.0; self.busy_pct_us.len()];
        }
        self.busy_pct_us.iter().map(|v| v / total).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::V100;

    fn gpu() -> GpuSim {
        GpuSim::new(V100.clone(), 3, true)
    }

    #[test]
    fn capacity_accounting() {
        let mut g = gpu();
        assert_eq!(g.free_pct(), 100);
        let a = g.launch(0, 0, 16, 40, 1_000);
        let _b = g.launch(0, 1, 16, 60, 2_000);
        assert_eq!(g.free_pct(), 0);
        g.complete(1_000, a);
        assert_eq!(g.free_pct(), 40);
        assert_eq!(g.n_running(), 1);
        assert_eq!(g.n_running_of(1), 1);
    }

    #[test]
    #[should_panic(expected = "oversubscribed")]
    fn oversubscription_panics_when_controlled() {
        let mut g = gpu();
        g.launch(0, 0, 16, 60, 1_000);
        g.launch(0, 1, 16, 50, 1_000);
    }

    #[test]
    fn oversubscription_allowed_for_default_mps() {
        let mut g = gpu();
        g.allow_oversub = true;
        g.launch(0, 0, 16, 80, 1_000);
        g.launch(0, 1, 16, 80, 1_000);
        assert_eq!(g.used_pct(), 160);
        assert_eq!(g.free_pct(), 0);
    }

    #[test]
    fn utilization_integral() {
        let mut g = gpu();
        // 50% busy for half the horizon → 25% utilization.
        let id = g.launch(0, 0, 16, 50, 5_000);
        g.complete(5_000, id);
        let u = g.utilization(10_000);
        assert!((u - 0.25).abs() < 1e-9, "{u}");
    }

    #[test]
    fn utilization_clamps_oversub_at_100() {
        let mut g = gpu();
        g.allow_oversub = true;
        let a = g.launch(0, 0, 16, 80, 10_000);
        let b = g.launch(0, 1, 16, 80, 10_000);
        g.complete(10_000, a);
        g.complete(10_000, b);
        let u = g.utilization(10_000);
        assert!((u - 1.0).abs() < 1e-9, "{u}");
    }

    #[test]
    fn busy_time_per_model() {
        let mut g = gpu();
        let a = g.launch(0, 0, 16, 40, 2_000);
        let b = g.launch(0, 2, 16, 30, 4_000);
        g.complete(2_000, a);
        g.complete(4_000, b);
        let busy = g.busy_ms();
        assert!((busy[0] - 2.0).abs() < 1e-9);
        assert!((busy[1] - 0.0).abs() < 1e-9);
        assert!((busy[2] - 4.0).abs() < 1e-9);
        let share = g.busy_share();
        let expect0 = (40.0 * 2000.0) / (40.0 * 2000.0 + 30.0 * 4000.0);
        assert!((share[0] - expect0).abs() < 1e-9);
    }

    #[test]
    fn reconfig_masks_load_for_resident_models() {
        let mut g = gpu();
        // Cold start pays the load (first model: no sharing possible).
        let ready = g.configure(0, 0, 50, 8_000.0, 1_000);
        assert_eq!(ready, ms_to_us(8_000.0));
        // Re-allocation is near-instant: only the takeover gap.
        let ready2 = g.configure(ready, 0, 25, 8_000.0, 1_000);
        assert_eq!(ready2, ready + g.reconfig.takeover_gap_us);
        assert_eq!(g.resident_pct(0), Some(25));
        assert_eq!(g.reconfig_idle_us, 100);
        // Same pct → no-op.
        assert_eq!(g.configure(ready2, 0, 25, 8_000.0, 1_000), ready2);
    }

    #[test]
    fn param_sharing_reduces_cold_load_of_second_model() {
        let mut g = gpu();
        g.configure(0, 0, 50, 8_000.0, 1_000);
        // Second model cold-loads with cudaIPC weight sharing: 60%.
        let ready = g.configure(0, 1, 30, 10_000.0, 800);
        assert_eq!(ready, ms_to_us(6_000.0));
        assert_eq!(g.resident_mem_mib(), 1_800);
    }

    #[test]
    fn gantt_records_launches() {
        let mut g = gpu();
        let id = g.launch(100, 1, 8, 40, 900);
        g.complete(1_000, id);
        let gantt = g.gantt.as_ref().unwrap();
        assert_eq!(gantt.len(), 1);
        assert_eq!(
            gantt[0],
            GanttEntry { model: 1, pct: 40, batch: 8, start: 100, end: 1_000 }
        );
    }
}
