//! Real-time serving coordinator (the end-to-end path).
//!
//! Wires the full stack together in *wall-clock* time: a workload
//! generator thread produces open-loop requests; the dispatcher owns the
//! PJRT [`crate::runtime::Runtime`], batches queued requests per model
//! (largest available AOT batch that the queue fills, padding the final
//! partial batch), and schedules models with a real-time variant of
//! D-STACK's dynamic pass (deadline-pressure EDF + scoreboard fairness +
//! optimal batching) or a Triton-style FCFS baseline.
//!
//! NOTE (DESIGN.md §1): on the CPU PJRT backend batches execute one at a
//! time, so the *spatial* dimension of D-STACK is exercised in the
//! virtual-time simulator; this coordinator demonstrates the serving
//! plumbing — admission, batching, deadline scheduling, real inference,
//! real latencies — on genuine model executables.

use crate::runtime::Runtime;
use crate::util::rng::Pcg32;
use crate::util::stats::Summary;
use anyhow::Result;
use std::collections::VecDeque;
use std::sync::mpsc;
use std::time::{Duration, Instant};

/// One model admitted to the server.
#[derive(Debug, Clone)]
pub struct ServeModel {
    /// Artifact name (e.g. "alexnet_mini").
    pub name: String,
    /// Mean request rate (req/s), Poisson arrivals.
    pub rate: f64,
    /// SLO in milliseconds.
    pub slo_ms: f64,
}

/// Scheduling discipline for the real-time dispatcher.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ServePolicy {
    /// D-STACK-style: deadline-pressure EDF first, then scoreboard-fair
    /// full-batch launches.
    DstackRt,
    /// Triton-style FCFS on the oldest queued request.
    Fifo,
}

#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub models: Vec<ServeModel>,
    pub policy: ServePolicy,
    pub duration: Duration,
    pub seed: u64,
}

#[derive(Debug, Clone)]
struct Req {
    arrival: Instant,
    deadline: Instant,
    /// Which synthetic payload to use (deterministic per request).
    payload_seed: u64,
}

/// Per-model serving stats.
#[derive(Debug, Clone)]
pub struct ServeModelReport {
    pub name: String,
    pub offered: u64,
    pub served: u64,
    pub in_slo: u64,
    pub batches: u64,
    pub latency: Summary,
}

#[derive(Debug, Clone)]
pub struct ServeReport {
    pub policy: &'static str,
    pub wall_s: f64,
    pub per_model: Vec<ServeModelReport>,
}

impl ServeReport {
    pub fn total_throughput(&self) -> f64 {
        self.per_model.iter().map(|m| m.served as f64).sum::<f64>() / self.wall_s
    }

    pub fn violation_fraction(&self) -> f64 {
        let offered: u64 = self.per_model.iter().map(|m| m.offered).sum();
        if offered == 0 {
            return 0.0;
        }
        let viol: u64 =
            self.per_model.iter().map(|m| (m.served - m.in_slo) + (m.offered - m.served)).sum();
        viol as f64 / offered as f64
    }

    pub fn render(&self) -> String {
        let mut rows = Vec::new();
        for m in &self.per_model {
            rows.push(vec![
                m.name.clone(),
                format!("{}", m.offered),
                format!("{}", m.served),
                format!("{}", m.in_slo),
                format!("{}", m.batches),
                format!("{:.1}", m.latency.p50),
                format!("{:.1}", m.latency.p99),
                format!("{:.0}", m.served as f64 / self.wall_s),
            ]);
        }
        crate::util::ascii_table(
            &["model", "offered", "served", "in_slo", "batches", "p50_ms", "p99_ms", "req/s"],
            &rows,
        )
    }
}

/// Estimated per-batch latency, learned online (EMA over measurements).
struct LatEst {
    /// ms per (model_idx, batch_bucket) — buckets follow manifest batches.
    est: Vec<std::collections::BTreeMap<u32, f64>>,
}

impl LatEst {
    fn get(&self, model: usize, batch: u32) -> f64 {
        self.est[model].get(&batch).copied().unwrap_or(5.0)
    }

    fn update(&mut self, model: usize, batch: u32, ms: f64) {
        let e = self.est[model].entry(batch).or_insert(ms);
        *e = 0.7 * *e + 0.3 * ms;
    }
}

/// The serving engine. Owns the PJRT runtime; see module docs.
pub struct Coordinator {
    rt: Runtime,
}

impl Coordinator {
    pub fn new(rt: Runtime) -> Coordinator {
        Coordinator { rt }
    }

    /// Run the workload to completion and report.
    pub fn serve(&mut self, cfg: &ServeConfig) -> Result<ServeReport> {
        let n = cfg.models.len();
        // Preload all batch variants; measure cold latencies via selfcheck.
        let mut batches_of: Vec<Vec<u32>> = Vec::with_capacity(n);
        for m in &cfg.models {
            let bs = self.rt.manifest.batches(&m.name);
            anyhow::ensure!(!bs.is_empty(), "no artifacts for {}", m.name);
            for &b in &bs {
                self.rt.load(&m.name, b)?;
            }
            batches_of.push(bs);
        }

        // Warm the latency estimator: profile each (model, batch) once
        // BEFORE the workload clock starts (the §3 offline profiling
        // step — warm-up must not eat into request deadlines).
        let mut est = LatEst { est: vec![Default::default(); n] };
        for (i, m) in cfg.models.iter().enumerate() {
            for &b in &batches_of[i] {
                let loaded = self.rt.get(&m.name, b).expect("preloaded");
                let x = crate::runtime::iota_input(&loaded.artifact.input_shape);
                loaded.infer(&x)?; // compile/warm
                let t0 = Instant::now();
                loaded.infer(&x)?;
                est.update(i, b, t0.elapsed().as_secs_f64() * 1_000.0);
            }
        }

        // Workload generator thread (open loop, Poisson per model).
        let (tx, rx) = mpsc::channel::<(usize, Req)>();
        let gen_models: Vec<(f64, f64)> =
            cfg.models.iter().map(|m| (m.rate, m.slo_ms)).collect();
        let seed = cfg.seed;
        let duration = cfg.duration;
        let start = Instant::now();
        let gen = std::thread::spawn(move || {
            let mut rngs: Vec<Pcg32> =
                (0..gen_models.len()).map(|i| Pcg32::new(seed, i as u64 + 1)).collect();
            // Next arrival instant per model (seconds from start).
            let mut next: Vec<f64> = gen_models
                .iter()
                .enumerate()
                .map(|(i, (r, _))| if *r > 0.0 { rngs[i].exp(*r) } else { f64::INFINITY })
                .collect();
            let mut count = 0u64;
            loop {
                // total_cmp: a NaN arrival time (degenerate rate input)
                // sorts last instead of panicking the generator thread.
                let (i, t) = next
                    .iter()
                    .enumerate()
                    .min_by(|a, b| a.1.total_cmp(b.1))
                    .map(|(i, t)| (i, *t))
                    .unwrap();
                if t.is_infinite() || t > duration.as_secs_f64() {
                    break;
                }
                let when = start + Duration::from_secs_f64(t);
                let now = Instant::now();
                if when > now {
                    std::thread::sleep(when - now);
                }
                let arrival = Instant::now();
                let req = Req {
                    arrival,
                    deadline: arrival + Duration::from_secs_f64(gen_models[i].1 / 1_000.0),
                    payload_seed: count,
                };
                count += 1;
                if tx.send((i, req)).is_err() {
                    break;
                }
                next[i] = t + rngs[i].exp(gen_models[i].0);
            }
        });

        // Dispatcher loop.
        let mut queues: Vec<VecDeque<Req>> = vec![VecDeque::new(); n];
        let mut offered = vec![0u64; n];
        let mut served = vec![0u64; n];
        let mut in_slo = vec![0u64; n];
        let mut nbatches = vec![0u64; n];
        let mut lats: Vec<Vec<f64>> = vec![Vec::new(); n];
        let mut scoreboard = vec![0u64; n];
        let deadline_all = start + duration;

        loop {
            // Ingest without blocking; if idle, block briefly.
            let mut got = false;
            while let Ok((i, req)) = rx.try_recv() {
                offered[i] += 1;
                queues[i].push_back(req);
                got = true;
            }
            let now = Instant::now();
            if now >= deadline_all && queues.iter().all(|q| q.is_empty()) {
                break;
            }
            let elapsed_s = (now - start).as_secs_f64().max(0.1);
            let rates: Vec<f64> = offered.iter().map(|&o| o as f64 / elapsed_s).collect();
            let pick = self.pick(cfg, &queues, &scoreboard, &est, &batches_of, &rates);
            let Some((i, batch)) = pick else {
                if !got {
                    match rx.recv_timeout(Duration::from_millis(1)) {
                        Ok((i, req)) => {
                            offered[i] += 1;
                            queues[i].push_back(req);
                        }
                        Err(mpsc::RecvTimeoutError::Disconnected)
                            if queues.iter().all(|q| q.is_empty()) =>
                        {
                            break
                        }
                        Err(_) => {}
                    }
                }
                continue;
            };
            // Assemble the batch: take up to `batch` requests, pad the rest.
            let take = (queues[i].len() as u32).min(batch) as usize;
            let reqs: Vec<Req> = (0..take).map(|_| queues[i].pop_front().unwrap()).collect();
            let loaded = self.rt.get(&cfg.models[i].name, batch).expect("preloaded");
            let item_len: usize =
                loaded.artifact.input_shape.iter().skip(1).product();
            let mut input = vec![0f32; batch as usize * item_len];
            for (slot, r) in reqs.iter().enumerate() {
                fill_payload(&mut input[slot * item_len..(slot + 1) * item_len], r.payload_seed);
            }
            let t0 = Instant::now();
            let _logits = loaded.infer(&input)?;
            let done = Instant::now();
            est.update(i, batch, (done - t0).as_secs_f64() * 1_000.0);
            nbatches[i] += 1;
            scoreboard[i] += 1;
            for r in &reqs {
                served[i] += 1;
                if done <= r.deadline {
                    in_slo[i] += 1;
                }
                lats[i].push((done - r.arrival).as_secs_f64() * 1_000.0);
            }
        }
        drop(rx);
        let _ = gen.join();

        let wall_s = start.elapsed().as_secs_f64();
        let per_model = cfg
            .models
            .iter()
            .enumerate()
            .map(|(i, m)| ServeModelReport {
                name: m.name.clone(),
                offered: offered[i],
                served: served[i],
                in_slo: in_slo[i],
                batches: nbatches[i],
                latency: Summary::from_samples(&lats[i]),
            })
            .collect();
        Ok(ServeReport {
            policy: match cfg.policy {
                ServePolicy::DstackRt => "dstack_rt",
                ServePolicy::Fifo => "fifo",
            },
            wall_s,
            per_model,
        })
    }

    /// Scheduling decision: which (model, batch-executable) to run now.
    fn pick(
        &self,
        cfg: &ServeConfig,
        queues: &[VecDeque<Req>],
        scoreboard: &[u64],
        est: &LatEst,
        batches_of: &[Vec<u32>],
        rates: &[f64],
    ) -> Option<(usize, u32)> {
        let now = Instant::now();
        // Online §5 optimization: among the AOT batch variants, the
        // efficacy-optimal batch maximizes measured items/s = b / f_L(b)
        // (on a backend with no batch amortization this is the smallest
        // batch; on accelerators it grows — learned, not assumed).
        let b_star = |i: usize| -> u32 {
            most_efficacious(batches_of[i].iter().copied(), |b| b as f64 / est.get(i, b))
                .unwrap()
        };
        let best_batch = |i: usize| -> u32 {
            let queued = queues[i].len() as u32;
            // Most efficacious batch the queue can fill, else smallest.
            most_efficacious(batches_of[i].iter().copied().filter(|&b| b <= queued), |b| {
                b as f64 / est.get(i, b)
            })
            .unwrap_or(batches_of[i][0])
        };
        match cfg.policy {
            ServePolicy::Fifo => {
                // Oldest head request wins (Triton FCFS).
                queues
                    .iter()
                    .enumerate()
                    .filter(|(_, q)| !q.is_empty())
                    .min_by_key(|(_, q)| q.front().unwrap().arrival)
                    .map(|(i, _)| (i, best_batch(i)))
            }
            ServePolicy::DstackRt => {
                // 1. Deadline-pressured models, EDF.
                let mut urgent: Option<(Instant, usize)> = None;
                for (i, q) in queues.iter().enumerate() {
                    let Some(head) = q.front() else { continue };
                    let b = best_batch(i);
                    let need = Duration::from_secs_f64(est.get(i, b) / 1_000.0);
                    let slack_need = need.mul_f64(2.5) + Duration::from_millis(2);
                    if head.deadline.saturating_duration_since(now) <= slack_need
                        && urgent.is_none_or(|(d, _)| head.deadline < d)
                    {
                        urgent = Some((head.deadline, i));
                    }
                }
                if let Some((_, i)) = urgent {
                    return Some((i, best_batch(i)));
                }
                // 2. Queues that can fill their efficacy-optimal batch,
                //    scoreboard-fair.
                let mut order: Vec<usize> = (0..queues.len()).collect();
                order.sort_by_key(|&i| (scoreboard[i], i));
                for i in order {
                    // Eq. 11: the batch must also be assemblable within
                    // half the SLO at the observed arrival rate.
                    let assembly_cap =
                        ((rates[i] * cfg.models[i].slo_ms / 2_000.0).floor() as u32).max(1);
                    let target = b_star(i).min(assembly_cap);
                    if queues[i].len() as u32 >= target {
                        return Some((i, best_batch(i)));
                    }
                }
                None
            }
        }
    }
}

/// Largest-efficacy batch among `batches` under the learned items/s
/// score `eff` (= b / estimated latency). Comparison uses
/// [`f64::total_cmp`]: a NaN score — a corrupt or zero latency estimate
/// — ranks above every finite value instead of panicking the dispatcher
/// mid-serve, so the batch still launches and the next EMA measurement
/// washes the bad estimate out.
fn most_efficacious<I>(batches: I, mut eff: impl FnMut(u32) -> f64) -> Option<u32>
where
    I: IntoIterator<Item = u32>,
{
    batches.into_iter().max_by(|&a, &b| eff(a).total_cmp(&eff(b)))
}

/// Deterministic synthetic payload (stands in for a decoded image or
/// embedded sentence — the workload content does not affect scheduling).
fn fill_payload(buf: &mut [f32], seed: u64) {
    let mut rng = Pcg32::seeded(seed);
    for v in buf.iter_mut() {
        *v = rng.f64_range(-1.0, 1.0) as f32;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn payload_deterministic() {
        let mut a = [0f32; 16];
        let mut b = [0f32; 16];
        fill_payload(&mut a, 9);
        fill_payload(&mut b, 9);
        assert_eq!(a, b);
        fill_payload(&mut b, 10);
        assert_ne!(a, b);
    }

    #[test]
    fn latency_estimator_ema() {
        let mut est = LatEst { est: vec![Default::default()] };
        assert_eq!(est.get(0, 16), 5.0); // prior
        est.update(0, 16, 10.0);
        assert!((est.get(0, 16) - 10.0).abs() < 1e-9);
        est.update(0, 16, 20.0);
        let v = est.get(0, 16);
        assert!(v > 10.0 && v < 20.0, "{v}");
    }

    #[test]
    fn batch_selection_survives_nan_estimate() {
        // Regression: a single NaN latency estimate used to abort the
        // whole serving loop through partial_cmp().unwrap() in the
        // efficacy comparators. With total_cmp the selection completes;
        // the poisoned batch may win one round but the dispatcher lives
        // to re-measure it.
        let mut est = LatEst { est: vec![Default::default()] };
        est.update(0, 1, 4.0);
        est.update(0, 8, f64::NAN); // corrupt measurement
        est.update(0, 16, 12.0);
        let batches = [1u32, 8, 16];
        let picked = most_efficacious(batches.iter().copied(), |b| b as f64 / est.get(0, b));
        assert!(picked.is_some(), "selection must not panic on NaN efficacy");
        // The queue-filtered variant (best_batch path) must survive too.
        let filtered =
            most_efficacious(batches.iter().copied().filter(|&b| b <= 8), |b| {
                b as f64 / est.get(0, b)
            });
        assert!(filtered.is_some());
        // And over clean estimates the comparator still picks max items/s.
        let clean = most_efficacious([1u32, 16].iter().copied(), |b| {
            b as f64 / est.get(0, b)
        });
        assert_eq!(clean, Some(16), "16/12 items/ms beats 1/4");
    }
}
