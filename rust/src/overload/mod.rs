//! Overload control: the third lever between "serve on time" and
//! "shed" (ISSUE 10; DARIS-style controlled degradation, cf. PAPERS.md).
//!
//! Three mechanisms compose with the `faults` front door on every
//! driver:
//!
//! 1. **Retry with virtual-clock backoff** — a deadline / unroutable /
//!    breaker-open reject is not terminal: the request re-enters the
//!    arrival stream after a deterministic exponential backoff
//!    (`backoff_base_ms · 2^(attempt-1)`, capped at `backoff_cap_ms`),
//!    provided the release time still precedes its absolute deadline
//!    and the attempt budget (`max_retries`) is not spent. A retry that
//!    cannot meet either budget becomes a typed `retry_exhausted`
//!    reject. Retries still queued when the horizon ends are drained as
//!    `retry_exhausted` too, so request conservation
//!    (`served + dropped + rejected == offered`) always holds.
//! 2. **Per-engine circuit breakers** — every admission estimate feeds
//!    the target engine's breaker: `breaker_k` consecutive would-miss
//!    estimates (or hedge losses) within `breaker_window_ms` trip it
//!    open for `breaker_cooldown_ms`, removing the engine from routing
//!    with no fault timeline required. After the cooldown the breaker
//!    is half-open: the engine is routable again and the first request
//!    actually dispatched to it is the probe that closes the breaker; a
//!    would-miss estimate while half-open re-opens it instead.
//! 3. **Brownout variant fallback** — a model may declare degraded
//!    variants (`variants: [{name, knee_pct, latency_scale, mem_mib}]`
//!    in the config). Variants are real fleet members: separate
//!    profiles (calibrated to the declared knee at
//!    `latency_scale × primary runtime`), separate replicas co-located
//!    with the primary where knee/memory headroom allows, and — on the
//!    lifecycle/unified drivers — separately resident `ModelStore`
//!    entries. When best-case admission fails for the primary, the
//!    front door re-estimates against the variant's replicas (resident
//!    ones only on lifecycle paths) and serves the cheap variant
//!    instead, counted as `degraded_served` per SLO class.
//!
//! Determinism: every decision above is made at an existing driver
//! barrier (arrival, retry release, or control event) from
//! virtual-clock state only, so reports stay byte-identical across
//! exec_mode × threads × {materialized, streamed}. Retry releases
//! surface through `EpochDriver::next_event`, and any driver with an
//! active overload layer stops eliding barriers.
//!
//! Typed-reject taxonomy: terminal rejects are counted exactly once.
//! With retries enabled (`max_retries > 0`) every terminal front-door
//! reject is `retry_exhausted` (per SLO class); with retries disabled
//! the original cause stands — per-class deadline and unroutable
//! rejects (in `ResilienceStats`) or `breaker_open_rejects` (here).

use crate::analytic::calibrate;
use crate::cluster::placement::{op_point, Placement};
use crate::faults::SloClass;
use crate::gpu::{ms_to_us, Us};
use crate::profile::{GpuSpec, ModelProfile, V100};
use crate::util::json::Json;
use crate::workload::Request;

/// Knobs for the overload-control layer (the `"overload"` config block).
#[derive(Debug, Clone)]
pub struct OverloadCfg {
    /// Retry budget per request; 0 disables retries entirely.
    pub max_retries: u32,
    /// First backoff delay in virtual ms; doubles per attempt.
    pub backoff_base_ms: f64,
    /// Backoff ceiling in virtual ms.
    pub backoff_cap_ms: f64,
    /// Consecutive would-miss estimates that trip an engine's breaker;
    /// 0 disables breakers.
    pub breaker_k: u32,
    /// Misses further apart than this window restart the count.
    pub breaker_window_ms: f64,
    /// How long a tripped breaker stays hard-open before half-opening.
    pub breaker_cooldown_ms: f64,
    /// Serve declared degraded variants when primary admission fails.
    pub brownout: bool,
}

impl Default for OverloadCfg {
    fn default() -> OverloadCfg {
        OverloadCfg {
            max_retries: 2,
            backoff_base_ms: 10.0,
            backoff_cap_ms: 160.0,
            breaker_k: 0,
            breaker_window_ms: 500.0,
            breaker_cooldown_ms: 250.0,
            brownout: true,
        }
    }
}

impl OverloadCfg {
    pub fn validate(&self) -> Result<(), String> {
        if !(self.backoff_base_ms.is_finite() && self.backoff_base_ms > 0.0) {
            return Err(format!("overload: backoff_base_ms must be > 0, got {}", self.backoff_base_ms));
        }
        if !(self.backoff_cap_ms.is_finite() && self.backoff_cap_ms >= self.backoff_base_ms) {
            return Err(format!(
                "overload: backoff_cap_ms ({}) must be >= backoff_base_ms ({})",
                self.backoff_cap_ms, self.backoff_base_ms
            ));
        }
        if !(self.breaker_window_ms.is_finite() && self.breaker_window_ms > 0.0) {
            return Err(format!(
                "overload: breaker_window_ms must be > 0, got {}",
                self.breaker_window_ms
            ));
        }
        if !(self.breaker_cooldown_ms.is_finite() && self.breaker_cooldown_ms > 0.0) {
            return Err(format!(
                "overload: breaker_cooldown_ms must be > 0, got {}",
                self.breaker_cooldown_ms
            ));
        }
        Ok(())
    }
}

/// A declared degraded variant of a primary model.
#[derive(Debug, Clone)]
pub struct VariantSpec {
    pub name: String,
    /// Knee GPU% of the variant on the V100 (its own operating point).
    pub knee_pct: u32,
    /// Variant runtime as a fraction of the primary's (0 < scale <= 1).
    pub latency_scale: f64,
    /// GPU memory footprint of the variant, MiB.
    pub mem_mib: u64,
}

impl VariantSpec {
    pub fn validate(&self) -> Result<(), String> {
        if self.name.is_empty() {
            return Err("variant: name must be non-empty".into());
        }
        if self.knee_pct == 0 || self.knee_pct > 100 {
            return Err(format!("variant '{}': knee_pct must be in 1..=100, got {}", self.name, self.knee_pct));
        }
        if !(self.latency_scale.is_finite() && self.latency_scale > 0.0 && self.latency_scale <= 1.0) {
            return Err(format!(
                "variant '{}': latency_scale must be in (0, 1], got {}",
                self.name, self.latency_scale
            ));
        }
        if self.mem_mib == 0 {
            return Err(format!("variant '{}': mem_mib must be >= 1", self.name));
        }
        Ok(())
    }
}

/// Primary↔variant index structure over the *expanded* model space:
/// global indices `0..n_primary` are the declared models, variants are
/// appended after them in declaration order.
#[derive(Debug, Clone)]
pub struct VariantMap {
    pub n_primary: usize,
    /// Per global model: its primary's index (`None` for primaries).
    pub primary_of: Vec<Option<usize>>,
    /// Per global model: its variants' global indices (empty for variants).
    pub variants_of: Vec<Vec<usize>>,
}

impl VariantMap {
    /// No variants: every model is its own family.
    pub fn trivial(n_models: usize) -> VariantMap {
        VariantMap {
            n_primary: n_models,
            primary_of: vec![None; n_models],
            variants_of: vec![Vec::new(); n_models],
        }
    }

    pub fn n_total(&self) -> usize {
        self.primary_of.len()
    }

    /// The family head (primary) of any global model index.
    pub fn family_of(&self, m: usize) -> usize {
        self.primary_of[m].unwrap_or(m)
    }
}

/// Derive a variant's `ModelProfile` from its primary: calibrated so the
/// variant's latency at its declared knee is `latency_scale ×` the
/// primary's published runtime, with the primary's SLO/batch and the
/// declared memory footprint. Cold-load time scales with the memory
/// ratio (smaller weights upload faster).
pub fn variant_profile(primary: &ModelProfile, spec: &VariantSpec) -> ModelProfile {
    let runtime_ms = primary.runtime_ms * spec.latency_scale;
    let serial_frac =
        if primary.dnn.t_p > 0.0 { primary.dnn.t_np / primary.dnn.t_p } else { 0.35 };
    let knee_sms = V100.sms_for_pct(spec.knee_pct);
    let dnn = calibrate(knee_sms, runtime_ms, primary.opt_batch as f64, V100.sms, serial_frac);
    let mem_ratio = spec.mem_mib as f64 / primary.mem_mib.max(1) as f64;
    ModelProfile {
        name: spec.name.clone(),
        knee_pct: spec.knee_pct,
        slo_ms: primary.slo_ms,
        opt_batch: primary.opt_batch,
        runtime_ms,
        dnn,
        load_ms: primary.load_ms * mem_ratio,
        mem_mib: spec.mem_mib,
        kernels: Vec::new(),
        max_batch: primary.max_batch,
    }
}

/// Expand a primary fleet with declared variants: returns the extended
/// profile list (primaries first, variants appended in declaration
/// order) and the index map. `decls` pairs each variant with its
/// primary's index.
pub fn expand_profiles(
    base: &[ModelProfile],
    decls: &[(usize, VariantSpec)],
) -> Result<(Vec<ModelProfile>, VariantMap), String> {
    let n_primary = base.len();
    let mut profiles: Vec<ModelProfile> = base.to_vec();
    let mut map = VariantMap::trivial(n_primary);
    for (primary, spec) in decls {
        if *primary >= n_primary {
            return Err(format!(
                "variant '{}': primary index {primary} out of range ({n_primary} models)",
                spec.name
            ));
        }
        spec.validate()?;
        if profiles.iter().any(|p| p.name == spec.name) {
            return Err(format!("variant '{}': name collides with an existing model", spec.name));
        }
        let v = profiles.len();
        profiles.push(variant_profile(&base[*primary], spec));
        map.primary_of.push(Some(*primary));
        map.variants_of.push(Vec::new());
        map.variants_of[*primary].push(v);
    }
    Ok((profiles, map))
}

/// Co-locate variant replicas with their primaries on an already-packed
/// placement: for every GPU hosting the primary, add one variant
/// replica if the GPU's knee budget (≤ 100%) and memory still fit. The
/// placement arrays grow from `n_primary` to the expanded model count;
/// a variant with no feasible replica stays unadmitted (brownout simply
/// never fires for it).
pub fn co_locate_variants(
    pl: &mut Placement,
    profiles: &[ModelProfile],
    map: &VariantMap,
    gpus: &[GpuSpec],
) {
    assert_eq!(pl.replicas.len(), map.n_primary, "co_locate_variants: placement already expanded");
    let n_gpus = pl.n_gpus();
    let mut used_mem = vec![0u64; n_gpus];
    for g in 0..n_gpus {
        used_mem[g] = pl.hosted[g].iter().map(|&m| profiles[m].mem_mib).sum();
    }
    for _ in map.n_primary..map.n_total() {
        pl.replicas.push(Vec::new());
        pl.admitted.push(false);
        pl.shed_rps.push(0.0);
    }
    for m in 0..map.n_primary {
        for &v in &map.variants_of[m] {
            // Distinct GPUs hosting the primary, in ascending order.
            let mut host_gpus: Vec<usize> = pl.replicas[m].iter().map(|r| r.gpu).collect();
            host_gpus.sort_unstable();
            host_gpus.dedup();
            for g in host_gpus {
                let (pct, batch, capacity_rps) = op_point(&profiles[v], &gpus[g]);
                if pl.knee_load[g] + pct > 100 || used_mem[g] + profiles[v].mem_mib > gpus[g].mem_mib
                {
                    continue;
                }
                let local = pl.hosted[g].len();
                pl.replicas[v].push(crate::cluster::placement::Replica {
                    gpu: g,
                    local,
                    pct,
                    batch,
                    capacity_rps,
                });
                pl.hosted[g].push(v);
                pl.knee_load[g] += pct;
                used_mem[g] += profiles[v].mem_mib;
                pl.admitted[v] = true;
            }
        }
    }
}

/// Why the front door could not dispatch a request to a model family.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RejectKind {
    /// Best-case estimate misses the absolute deadline.
    Deadline,
    /// No healthy replica exists.
    Unroutable,
    /// Healthy replicas exist but every breaker is open.
    BreakerOpen,
}

/// Counters for the overload layer, serialized as
/// `ClusterReport.overload` only when the layer is active.
#[derive(Debug, Clone, Default, PartialEq)]
pub struct OverloadStats {
    pub retries_scheduled: u64,
    /// Retried requests that were eventually dispatched (primary or variant).
    pub retries_succeeded: u64,
    pub retry_exhausted_critical: u64,
    pub retry_exhausted_bulk: u64,
    pub breaker_trips: u64,
    /// Half-open probe dispatches that closed a breaker.
    pub breaker_probes: u64,
    /// Terminal rejects whose cause was every-breaker-open (retries off).
    pub breaker_open_rejects: u64,
    pub degraded_served_critical: u64,
    pub degraded_served_bulk: u64,
}

impl OverloadStats {
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("retries_scheduled", Json::from(self.retries_scheduled)),
            ("retries_succeeded", Json::from(self.retries_succeeded)),
            ("retry_exhausted_critical", Json::from(self.retry_exhausted_critical)),
            ("retry_exhausted_bulk", Json::from(self.retry_exhausted_bulk)),
            ("breaker_trips", Json::from(self.breaker_trips)),
            ("breaker_probes", Json::from(self.breaker_probes)),
            ("breaker_open_rejects", Json::from(self.breaker_open_rejects)),
            ("degraded_served_critical", Json::from(self.degraded_served_critical)),
            ("degraded_served_bulk", Json::from(self.degraded_served_bulk)),
        ])
    }

    pub fn retry_exhausted_total(&self) -> u64 {
        self.retry_exhausted_critical + self.retry_exhausted_bulk
    }

    pub fn degraded_served_total(&self) -> u64 {
        self.degraded_served_critical + self.degraded_served_bulk
    }
}

#[derive(Debug, Clone, Copy, PartialEq)]
enum BreakerState {
    Closed,
    /// Hard-open until `until`; half-open (routable, probe pending) after.
    Open { until: Us },
}

#[derive(Debug, Clone)]
struct Breaker {
    state: BreakerState,
    consec: u32,
    last_miss: Us,
}

#[derive(Debug, Clone)]
struct RetryEntry {
    release: Us,
    seq: u64,
    attempt: u32,
    req: Request,
}

/// Per-run overload state: one instance per driver, mutated only at
/// barriers. Bundle `cfg` + `map` (see [`expand_profiles`]) to arm it.
#[derive(Debug, Clone)]
pub struct OverloadSpec {
    pub cfg: OverloadCfg,
    pub map: VariantMap,
}

#[derive(Debug)]
pub struct Overload {
    pub cfg: OverloadCfg,
    pub map: VariantMap,
    pub stats: OverloadStats,
    breakers: Vec<Breaker>,
    retry_q: Vec<RetryEntry>,
    seq: u64,
}

impl Overload {
    pub fn new(spec: &OverloadSpec, n_gpus: usize) -> Overload {
        Overload {
            cfg: spec.cfg.clone(),
            map: spec.map.clone(),
            stats: OverloadStats::default(),
            breakers: vec![
                Breaker { state: BreakerState::Closed, consec: 0, last_miss: 0 };
                n_gpus
            ],
            retry_q: Vec::new(),
            seq: 0,
        }
    }

    /// Service order for a request to model `m`: the primary first, then
    /// its declared variants (brownout candidates) in declaration order.
    pub fn service_order(&self, m: usize) -> Vec<usize> {
        let mut order = vec![m];
        if self.cfg.brownout {
            order.extend(self.map.variants_of[self.map.family_of(m)].iter().copied());
        }
        order
    }

    /// Is engine `g` routable as far as its breaker is concerned
    /// (closed, or past its cooldown ⇒ half-open)?
    pub fn allows(&self, t: Us, g: usize) -> bool {
        match self.breakers[g].state {
            BreakerState::Closed => true,
            BreakerState::Open { until } => t >= until,
        }
    }

    /// Feed one admission estimate for engine `g`: `miss` is whether the
    /// best-case completion would overrun the request deadline.
    pub fn note_estimate(&mut self, t: Us, g: usize, miss: bool) {
        if self.cfg.breaker_k == 0 {
            return;
        }
        let cooldown = ms_to_us(self.cfg.breaker_cooldown_ms).max(1);
        let window = ms_to_us(self.cfg.breaker_window_ms).max(1);
        let b = &mut self.breakers[g];
        match b.state {
            BreakerState::Open { until } if t < until => {} // hard-open: not routable, ignore
            BreakerState::Open { .. } => {
                // Half-open: a would-miss estimate re-opens immediately.
                if miss {
                    b.state = BreakerState::Open { until: t.saturating_add(cooldown) };
                    b.consec = 0;
                    b.last_miss = t;
                    self.stats.breaker_trips += 1;
                }
            }
            BreakerState::Closed => {
                if !miss {
                    b.consec = 0;
                    return;
                }
                if t.saturating_sub(b.last_miss) > window {
                    b.consec = 1;
                } else {
                    b.consec += 1;
                }
                b.last_miss = t;
                if b.consec >= self.cfg.breaker_k {
                    b.state = BreakerState::Open { until: t.saturating_add(cooldown) };
                    b.consec = 0;
                    self.stats.breaker_trips += 1;
                }
            }
        }
    }

    /// A hedge moved work off engine `g` (it lost the race): counts as a
    /// breaker miss.
    pub fn note_hedge_loss(&mut self, t: Us, g: usize) {
        self.note_estimate(t, g, true);
    }

    /// A request was dispatched to engine `g`: closes a half-open
    /// breaker (this dispatch is the probe).
    pub fn note_dispatch(&mut self, t: Us, g: usize) {
        if let BreakerState::Open { until } = self.breakers[g].state {
            if t >= until {
                self.breakers[g].state = BreakerState::Closed;
                self.breakers[g].consec = 0;
                self.stats.breaker_probes += 1;
            }
        }
    }

    /// Earliest pending retry release, for `EpochDriver::next_event`.
    pub fn next_release(&self) -> Option<Us> {
        self.retry_q.iter().map(|e| e.release).min()
    }

    /// Deterministic exponential backoff for attempt `n` (1-based).
    pub fn backoff_us(&self, attempt: u32) -> Us {
        let exp = 2f64.powi(attempt.saturating_sub(1).min(52) as i32);
        ms_to_us((self.cfg.backoff_base_ms * exp).min(self.cfg.backoff_cap_ms)).max(1)
    }

    /// Try to queue a retry as attempt `next_attempt`; `false` means the
    /// attempt or deadline budget is spent (caller issues the terminal
    /// typed reject).
    pub fn try_schedule_retry(&mut self, t: Us, req: &Request, next_attempt: u32) -> bool {
        if self.cfg.max_retries == 0 || next_attempt > self.cfg.max_retries {
            return false;
        }
        let release = t.saturating_add(self.backoff_us(next_attempt));
        if release >= req.deadline {
            return false; // cannot meet the remaining deadline
        }
        self.retry_q.push(RetryEntry { release, seq: self.seq, attempt: next_attempt, req: req.clone() });
        self.seq += 1;
        self.stats.retries_scheduled += 1;
        true
    }

    /// Drain retries due at `t`, ordered by (release, schedule order).
    pub fn due_retries(&mut self, t: Us) -> Vec<(u32, Request)> {
        if self.retry_q.iter().all(|e| e.release > t) {
            return Vec::new();
        }
        let mut due: Vec<RetryEntry> = Vec::new();
        self.retry_q.retain_mut(|e| {
            if e.release <= t {
                due.push(e.clone());
                false
            } else {
                true
            }
        });
        due.sort_by(|a, b| a.release.cmp(&b.release).then(a.seq.cmp(&b.seq)));
        due.into_iter().map(|e| (e.attempt, e.req)).collect()
    }

    /// Retries still queued when the run ends, in deterministic order;
    /// the driver accounts each as a `retry_exhausted` reject.
    pub fn drain_leftover(&mut self) -> Vec<(u32, Request)> {
        let mut rest = std::mem::take(&mut self.retry_q);
        rest.sort_by(|a, b| a.release.cmp(&b.release).then(a.seq.cmp(&b.seq)));
        rest.into_iter().map(|e| (e.attempt, e.req)).collect()
    }

    pub fn note_retry_served(&mut self) {
        self.stats.retries_succeeded += 1;
    }

    pub fn note_degraded(&mut self, class: SloClass) {
        match class {
            SloClass::LatencyCritical => self.stats.degraded_served_critical += 1,
            SloClass::Bulk => self.stats.degraded_served_bulk += 1,
        }
    }

    pub fn note_retry_exhausted(&mut self, class: SloClass) {
        match class {
            SloClass::LatencyCritical => self.stats.retry_exhausted_critical += 1,
            SloClass::Bulk => self.stats.retry_exhausted_bulk += 1,
        }
    }

    pub fn note_breaker_reject(&mut self) {
        self.stats.breaker_open_rejects += 1;
    }

    /// Terminal accounting for a reject that could not be retried:
    /// `retry_exhausted` when retries are configured (the budget ran
    /// out), else the original cause. Returns the cause the caller must
    /// forward to `ResilienceStats` (deadline/unroutable), if any.
    pub fn note_terminal(&mut self, kind: RejectKind, class: SloClass) -> Option<RejectKind> {
        if self.cfg.max_retries > 0 {
            self.note_retry_exhausted(class);
            return None;
        }
        match kind {
            RejectKind::BreakerOpen => {
                self.note_breaker_reject();
                None
            }
            other => Some(other),
        }
    }

    pub fn finalize(self) -> OverloadStats {
        self.stats
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::profile::by_name;

    fn req(model: usize, arrival: Us, deadline: Us) -> Request {
        Request { id: 1, model, arrival, deadline }
    }

    fn spec(cfg: OverloadCfg, n_models: usize) -> OverloadSpec {
        OverloadSpec { cfg, map: VariantMap::trivial(n_models) }
    }

    #[test]
    fn backoff_doubles_and_caps() {
        let ovl = Overload::new(
            &spec(
                OverloadCfg { backoff_base_ms: 10.0, backoff_cap_ms: 35.0, ..Default::default() },
                1,
            ),
            1,
        );
        assert_eq!(ovl.backoff_us(1), ms_to_us(10.0));
        assert_eq!(ovl.backoff_us(2), ms_to_us(20.0));
        assert_eq!(ovl.backoff_us(3), ms_to_us(35.0)); // capped, not 40
        assert_eq!(ovl.backoff_us(9), ms_to_us(35.0));
    }

    #[test]
    fn retry_budget_and_deadline_checked() {
        let cfg = OverloadCfg {
            max_retries: 2,
            backoff_base_ms: 10.0,
            backoff_cap_ms: 160.0,
            ..Default::default()
        };
        let mut ovl = Overload::new(&spec(cfg, 1), 1);
        let r = req(0, 0, ms_to_us(100.0));
        assert!(ovl.try_schedule_retry(0, &r, 1));
        assert!(ovl.try_schedule_retry(0, &r, 2));
        assert!(!ovl.try_schedule_retry(0, &r, 3), "attempt budget spent");
        // A release past the deadline is refused outright.
        let tight = req(0, 0, ms_to_us(5.0));
        assert!(!ovl.try_schedule_retry(0, &tight, 1));
        assert_eq!(ovl.stats.retries_scheduled, 2);
        // Releases surface in order through next_release/due_retries.
        assert_eq!(ovl.next_release(), Some(ms_to_us(10.0)));
        let due = ovl.due_retries(ms_to_us(10.0));
        assert_eq!(due.len(), 1);
        assert_eq!(due[0].0, 1);
        assert_eq!(ovl.next_release(), Some(ms_to_us(20.0)));
        let rest = ovl.drain_leftover();
        assert_eq!(rest.len(), 1);
        assert_eq!(rest[0].0, 2);
        assert_eq!(ovl.next_release(), None);
    }

    #[test]
    fn breaker_trips_half_opens_and_closes() {
        let cfg = OverloadCfg {
            breaker_k: 3,
            breaker_window_ms: 100.0,
            breaker_cooldown_ms: 50.0,
            ..Default::default()
        };
        let mut ovl = Overload::new(&spec(cfg, 1), 2);
        let ms = ms_to_us;
        for i in 0..3 {
            assert!(ovl.allows(ms(i as f64), 0));
            ovl.note_estimate(ms(i as f64), 0, true);
        }
        assert_eq!(ovl.stats.breaker_trips, 1);
        assert!(!ovl.allows(ms(3.0), 0), "tripped breaker removes the engine");
        assert!(ovl.allows(ms(3.0), 1), "other engines unaffected");
        // Half-open after the cooldown; the probe dispatch closes it.
        assert!(ovl.allows(ms(52.0) + ms(1.0), 0));
        ovl.note_dispatch(ms(53.0), 0);
        assert_eq!(ovl.stats.breaker_probes, 1);
        assert!(ovl.allows(ms(54.0), 0));
        // A fresh miss while closed starts a new count (window reset).
        ovl.note_estimate(ms(60.0), 0, true);
        ovl.note_estimate(ms(200.0), 0, true); // > window since last miss
        ovl.note_estimate(ms(201.0), 0, true);
        assert_eq!(ovl.stats.breaker_trips, 1, "window gap must reset the count");
        ovl.note_estimate(ms(202.0), 0, true);
        assert_eq!(ovl.stats.breaker_trips, 2);
    }

    #[test]
    fn half_open_miss_reopens() {
        let cfg = OverloadCfg {
            breaker_k: 1,
            breaker_cooldown_ms: 50.0,
            ..Default::default()
        };
        let mut ovl = Overload::new(&spec(cfg, 1), 1);
        ovl.note_estimate(0, 0, true);
        assert!(!ovl.allows(ms_to_us(10.0), 0));
        // Past cooldown: half-open, but a miss re-opens it.
        ovl.note_estimate(ms_to_us(60.0), 0, true);
        assert_eq!(ovl.stats.breaker_trips, 2);
        assert!(!ovl.allows(ms_to_us(80.0), 0));
    }

    #[test]
    fn successes_reset_consecutive_count() {
        let cfg = OverloadCfg { breaker_k: 2, ..Default::default() };
        let mut ovl = Overload::new(&spec(cfg, 1), 1);
        ovl.note_estimate(1, 0, true);
        ovl.note_estimate(2, 0, false);
        ovl.note_estimate(3, 0, true);
        assert_eq!(ovl.stats.breaker_trips, 0, "an ok estimate must reset the streak");
        ovl.note_estimate(4, 0, true);
        assert_eq!(ovl.stats.breaker_trips, 1);
    }

    #[test]
    fn expand_profiles_builds_family_map() {
        let base = vec![by_name("resnet50").unwrap(), by_name("alexnet").unwrap()];
        let decl = VariantSpec {
            name: "resnet50_lite".into(),
            knee_pct: 20,
            latency_scale: 0.4,
            mem_mib: 400,
        };
        let (profiles, map) = expand_profiles(&base, &[(0, decl)]).unwrap();
        assert_eq!(profiles.len(), 3);
        assert_eq!(map.n_primary, 2);
        assert_eq!(map.variants_of[0], vec![2]);
        assert!(map.variants_of[1].is_empty());
        assert_eq!(map.primary_of[2], Some(0));
        assert_eq!(map.family_of(2), 0);
        let v = &profiles[2];
        assert_eq!(v.name, "resnet50_lite");
        assert_eq!(v.knee_pct, 20);
        assert_eq!(v.mem_mib, 400);
        assert_eq!(v.slo_ms, base[0].slo_ms);
        // The calibrated variant is genuinely cheaper at its knee.
        let prim_rt = base[0].latency_ms(base[0].knee_pct, base[0].opt_batch);
        let var_rt = v.latency_ms(v.knee_pct, v.opt_batch);
        assert!(
            (var_rt - 0.4 * base[0].runtime_ms).abs() / base[0].runtime_ms < 1e-6,
            "variant runtime {var_rt} vs target {}",
            0.4 * base[0].runtime_ms
        );
        assert!(var_rt < prim_rt);
    }

    #[test]
    fn expand_profiles_rejects_bad_decls() {
        let base = vec![by_name("resnet50").unwrap()];
        let ok = VariantSpec { name: "v".into(), knee_pct: 20, latency_scale: 0.5, mem_mib: 100 };
        assert!(expand_profiles(&base, &[(1, ok.clone())]).is_err(), "primary out of range");
        let dup = VariantSpec { name: "resnet50".into(), ..ok.clone() };
        assert!(expand_profiles(&base, &[(0, dup)]).is_err(), "name collision");
        let bad_scale = VariantSpec { latency_scale: 1.5, ..ok.clone() };
        assert!(expand_profiles(&base, &[(0, bad_scale)]).is_err());
        let bad_knee = VariantSpec { knee_pct: 0, ..ok };
        assert!(expand_profiles(&base, &[(0, bad_knee)]).is_err());
    }

    #[test]
    fn service_order_respects_brownout_flag() {
        let base = vec![by_name("resnet50").unwrap()];
        let decl = VariantSpec { name: "lite".into(), knee_pct: 20, latency_scale: 0.5, mem_mib: 300 };
        let (_, map) = expand_profiles(&base, &[(0, decl)]).unwrap();
        let on = Overload::new(
            &OverloadSpec { cfg: OverloadCfg { brownout: true, ..Default::default() }, map: map.clone() },
            1,
        );
        assert_eq!(on.service_order(0), vec![0, 1]);
        let off = Overload::new(
            &OverloadSpec { cfg: OverloadCfg { brownout: false, ..Default::default() }, map },
            1,
        );
        assert_eq!(off.service_order(0), vec![0]);
    }

    #[test]
    fn terminal_typing_matches_retry_mode() {
        let mut with = Overload::new(
            &spec(OverloadCfg { max_retries: 2, ..Default::default() }, 1),
            1,
        );
        assert_eq!(with.note_terminal(RejectKind::Deadline, SloClass::LatencyCritical), None);
        assert_eq!(with.stats.retry_exhausted_critical, 1);
        let mut without = Overload::new(
            &spec(OverloadCfg { max_retries: 0, ..Default::default() }, 1),
            1,
        );
        assert_eq!(
            without.note_terminal(RejectKind::Deadline, SloClass::Bulk),
            Some(RejectKind::Deadline)
        );
        assert_eq!(without.note_terminal(RejectKind::BreakerOpen, SloClass::Bulk), None);
        assert_eq!(without.stats.breaker_open_rejects, 1);
        assert_eq!(without.stats.retry_exhausted_bulk, 0);
    }

    #[test]
    fn cfg_validation_bounds() {
        assert!(OverloadCfg::default().validate().is_ok());
        let bad = OverloadCfg { backoff_base_ms: 0.0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = OverloadCfg { backoff_cap_ms: 1.0, backoff_base_ms: 2.0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = OverloadCfg { breaker_window_ms: -1.0, ..Default::default() };
        assert!(bad.validate().is_err());
        let bad = OverloadCfg { breaker_cooldown_ms: f64::NAN, ..Default::default() };
        assert!(bad.validate().is_err());
    }
}
