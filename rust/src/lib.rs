//! # D-STACK — spatio-temporal GPU inference scheduling
//!
//! Reproduction of *"D-STACK: High Throughput DNN Inference by Effective
//! Multiplexing and Spatio-Temporal Scheduling of GPUs"* (Dhakal,
//! Kulkarni, Ramakrishnan, 2023) as a three-layer Rust + JAX + Pallas
//! system:
//!
//! - **L3 (this crate)** — the paper's contribution: request routing,
//!   batching, the knee/efficacy analytical models (§4–5), and the
//!   D-STACK spatio-temporal scheduler plus all baselines (§6–7), driven
//!   either in virtual time (paper-scale experiments on the GPU
//!   simulator) or in real time against PJRT-executed model artifacts.
//! - **L2** — `python/compile/model.py`: the JAX mini model zoo,
//!   AOT-lowered to HLO text by `python/compile/aot.py`.
//! - **L1** — `python/compile/kernels/`: Pallas kernels (matmul, conv,
//!   attention, layernorm) called from L2, validated against pure-jnp
//!   oracles.
//!
//! See `DESIGN.md` for the full system inventory and the per-experiment
//! index mapping every paper table/figure to a module and bench target.

pub mod analytic;
pub mod batching;
pub mod bench;
pub mod cluster;
pub mod config;
pub mod controlplane;
pub mod coordinator;
pub mod faults;
pub mod figures;
pub mod gpu;
pub mod lifecycle;
pub mod metrics;
pub mod obs;
pub mod optimizer;
pub mod overload;
pub mod profile;
pub mod runtime;
pub mod sched;
pub mod sim;
pub mod unified;
pub mod util;
pub mod workload;
